//! Elastic scaling demo: parties keep joining mid-training (§III-C) and
//! the service transitions seamlessly from the in-memory path to the
//! distributed path the moment the predicted load crosses the node's
//! memory — including the preemptive redirect the paper describes in
//! §III-D3 (parties are told to send their NEXT update to the store).
//!
//! Run: `cargo run --release --offline --example elastic_scale`

use elastiagg::client::SyntheticParty;
use elastiagg::config::ServiceConfig;
use elastiagg::coordinator::{AdaptiveService, WorkloadClass};
use elastiagg::dfs::{DfsClient, NameNode};
use elastiagg::engine::XlaEngine;
use elastiagg::fusion::FedAvg;
use elastiagg::mapreduce::ExecutorConfig;
use elastiagg::metrics::Breakdown;
use elastiagg::runtime::Runtime;
use elastiagg::util::fmt;

fn main() {
    let root = std::env::temp_dir().join(format!("elastiagg-elastic-{}", std::process::id()));
    let nn = NameNode::create(&root, 3, 2, 8 << 20).expect("dfs");
    let dfs = DfsClient::new(nn);

    let update_len = 50_000usize; // 200 KB updates
    let update_bytes = (update_len * 4) as u64;

    let mut cfg = ServiceConfig::default();
    cfg.node.memory_bytes = 8 << 20; // 8 MiB node memory
    cfg.node.cores = 4;
    cfg.monitor_timeout_s = 10.0;
    let xla = Runtime::load_default().ok().and_then(|r| XlaEngine::auto(r, 16).ok());
    let service = AdaptiveService::new(
        cfg,
        dfs.clone(),
        xla,
        ExecutorConfig { executors: 2, cores_per_executor: 2, ..Default::default() },
    );

    println!("node memory: 8 MiB, update size: {}", fmt::bytes(update_bytes));
    println!("party ceiling (FedAvg): {}", service.classifier.party_ceiling(update_bytes, &FedAvg));
    println!();

    // Party population grows each round: 4 -> 8 -> 16 -> 32 -> 64.
    let mut transitioned = false;
    for (round, parties) in [4usize, 8, 16, 32, 64].into_iter().enumerate() {
        let round = round as u32;
        let class = service.classify(update_bytes, parties, &FedAvg);
        let redirect_next = service.should_redirect(update_bytes, parties * 2, &FedAvg);

        let report = match class {
            WorkloadClass::Small => {
                let updates: Vec<_> = (0..parties as u64)
                    .map(|p| SyntheticParty::new(p, round as u64).make_update(round, update_len))
                    .collect();
                let (_, report) = service.aggregate_small(&FedAvg, &updates, round).unwrap();
                report
            }
            // this demo dispatches on the binary Algorithm-1 oracle, so
            // the streaming class never fires here; see `quickstart` for
            // the streaming round and DESIGN.md for when the planner
            // prefers it over MapReduce
            WorkloadClass::Streaming => {
                let updates: Vec<_> = (0..parties as u64)
                    .map(|p| SyntheticParty::new(p, round as u64).make_update(round, update_len))
                    .collect();
                let (_, report) = service.aggregate_streaming(&FedAvg, &updates, round).unwrap();
                report
            }
            WorkloadClass::Large => {
                if !transitioned {
                    println!(">>> TRANSITION: load exceeds node memory — spinning up the");
                    println!(">>> executor pool (one-time cost) and aggregating via the store");
                    transitioned = true;
                }
                let mut bd = Breakdown::new();
                for p in 0..parties as u64 {
                    let mut party = SyntheticParty::new(p, round as u64);
                    let u = party.make_update(round, update_len);
                    dfs.put_update(&u, &mut bd).unwrap();
                }
                let (_, report) = service
                    .aggregate_large(&FedAvg, round, parties, update_bytes)
                    .unwrap();
                report
            }
        };
        println!(
            "round {round}: {parties:>3} parties -> {:?} ({})  redirect-next={}  [{}]",
            report.class,
            report.engine,
            redirect_next,
            report.breakdown.summary()
        );
    }

    assert!(transitioned, "the demo must cross the memory boundary");
    assert!(service.spark_started());
    let _ = std::fs::remove_dir_all(&root);
    println!("\nelastic_scale OK — small rounds in memory, large rounds via MapReduce");
}
