//! Tour of the ElastiStore block store (the HDFS analog): replication,
//! failure tolerance, the Algorithm-1 monitor, and the scalability
//! argument (capacity bounded by storage, not node memory).
//!
//! Run: `cargo run --release --offline --example dfs_tour`

use std::time::Duration;

use elastiagg::client::fleet_upload_dfs;
use elastiagg::dfs::{DfsClient, Monitor, NameNode};
use elastiagg::util::fmt;

fn main() {
    let root = std::env::temp_dir().join(format!("elastiagg-dfstour-{}", std::process::id()));
    let nn = NameNode::create(&root, 3, 2, 1 << 20).expect("dfs"); // 1 MiB blocks
    let dfs = DfsClient::new(nn.clone());

    // --- block splitting + replication --------------------------------
    let payload = vec![0xABu8; (2.5 * (1 << 20) as f64) as usize]; // 2.5 MiB
    dfs.write("/demo/file", &payload).unwrap();
    let st = nn.stat("/demo/file").unwrap();
    println!(
        "wrote {} -> {} blocks x {} replicas each",
        fmt::bytes(payload.len() as u64),
        st.blocks.len(),
        st.blocks[0].replicas.len()
    );
    assert_eq!(st.blocks.len(), 3);

    // --- failure tolerance ---------------------------------------------
    let victim = st.blocks[0].replicas[0];
    nn.datanode(victim).set_alive(false);
    let read_back = dfs.read("/demo/file").unwrap();
    assert_eq!(read_back, payload);
    println!("datanode {victim} killed — file still readable from replicas");
    nn.datanode(victim).set_alive(true);

    // --- the Algorithm-1 monitor ----------------------------------------
    let monitor = Monitor::new(nn.clone());
    let dfs_bg = dfs.clone();
    let writer = std::thread::spawn(move || {
        let avg = fleet_upload_dfs(&dfs_bg, 7, 20, 5_000, 4, 99);
        println!("fleet uploaded 20 updates, avg write {}", fmt::secs(avg));
    });
    let outcome = monitor.watch(&DfsClient::round_prefix(7), 20, Duration::from_secs(10));
    writer.join().unwrap();
    println!("monitor: ready={} count={}", outcome.is_ready(), outcome.count());
    assert!(outcome.is_ready());

    // --- the webHDFS REST facade (paper Fig 4 step ①) --------------------
    let rest = elastiagg::dfs::WebHdfsServer::serve("127.0.0.1:0", dfs.clone()).unwrap();
    let http = elastiagg::dfs::WebHdfsClient::new(rest.addr());
    http.create("/rest/party9", b"uploaded over HTTP").unwrap();
    assert_eq!(dfs.read("/rest/party9").unwrap(), b"uploaded over HTTP");
    println!("webHDFS REST facade on http://{} — PUT ?op=CREATE verified", rest.addr());

    // --- storage accounting ----------------------------------------------
    println!(
        "store now holds {} across {} datanodes (replication included)",
        fmt::bytes(nn.stored_bytes()),
        nn.datanodes().len()
    );

    let _ = std::fs::remove_dir_all(&root);
    println!("dfs_tour OK");
}
