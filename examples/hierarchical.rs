//! Hierarchical quickstart: a 2-tier aggregation tree on localhost —
//! 2 edge relays × 4 simulated clients each, one root.
//!
//! Each relay runs its local quorum round over its cohort, pre-folds the
//! updates into ONE weighted partial aggregate (raw accumulator state, so
//! the result is exact), forwards it to the root, then fetches the fused
//! model back and republishes it for its own clients.  The root's quorum
//! counts cohort MEMBERS, not frames: 8 parties arrive as 2 partials.
//!
//! Run: `cargo run --release --offline --example hierarchical`

use std::sync::Arc;
use std::time::Duration;

use elastiagg::client::SyntheticParty;
use elastiagg::config::{NodeRole, ServiceConfig};
use elastiagg::coordinator::{AdaptiveService, RoundOutcome};
use elastiagg::dfs::{DfsClient, NameNode};
use elastiagg::fusion::FedAvg;
use elastiagg::mapreduce::ExecutorConfig;
use elastiagg::net::{Message, NetClient};
use elastiagg::server::{FlServer, RelayServer};

const UPDATE_LEN: usize = 2_000; // 8 KB updates
const EDGES: usize = 2;
const COHORT: usize = 4;

fn make_node(
    role: NodeRole,
    parent: Option<String>,
    edge_id: u64,
    dir: &std::path::Path,
) -> Arc<FlServer> {
    let nn = NameNode::create(dir, 2, 1, 1 << 20).expect("store");
    let mut cfg = ServiceConfig::default();
    cfg.node.memory_bytes = 1 << 20;
    cfg.node.cores = 2;
    cfg.role = role;
    cfg.parent_addr = parent;
    cfg.edge_id = edge_id;
    let svc = AdaptiveService::new(
        cfg,
        DfsClient::new(nn),
        None,
        ExecutorConfig { executors: 1, cores_per_executor: 2, ..Default::default() },
    );
    FlServer::new(svc, Arc::new(FedAvg), (UPDATE_LEN * 4) as u64)
}

fn main() {
    let scratch =
        std::env::temp_dir().join(format!("elastiagg-hier-{}", std::process::id()));
    std::fs::create_dir_all(&scratch).expect("scratch");

    // --- the tree: one root, two relays (same binary, role from config) --
    let root = make_node(NodeRole::Root, None, 0, &scratch.join("root"));
    let root_handle = root.start("127.0.0.1:0").expect("bind root");
    let root_addr = root_handle.addr().to_string();
    println!("root  on {root_addr}");

    let mut relays = Vec::new();
    let mut relay_handles = Vec::new();
    for e in 0..EDGES as u64 {
        let server = make_node(
            NodeRole::Relay,
            Some(root_addr.clone()),
            e,
            &scratch.join(format!("edge{e}")),
        );
        let handle = server.start("127.0.0.1:0").expect("bind relay");
        println!("edge{e} on {} -> {root_addr}", handle.addr());
        let relay = RelayServer::from_config(server).expect("relay config");
        relays.push((relay, handle.addr().to_string()));
        relay_handles.push(handle);
    }

    // --- one round: cohorts upload to their edge, edges forward ---------
    let total = EDGES * COHORT;
    let (root_run, relay_runs) = std::thread::scope(|s| {
        let drive =
            s.spawn(|| root.run_round_quorum(total, total, Duration::from_secs(10)));
        for (e, (_, addr)) in relays.iter().enumerate() {
            for i in 0..COHORT as u64 {
                let party = e as u64 * COHORT as u64 + i;
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = NetClient::connect(&addr).expect("connect relay");
                    let mut p = SyntheticParty::new(party, 0xED6E);
                    let u = p.make_update(0, UPDATE_LEN);
                    let r = c.call(&Message::Upload(u)).expect("upload");
                    assert!(matches!(r, Message::Ack { redirect_to_dfs: false }), "{r:?}");
                });
            }
        }
        let runs: Vec<_> = relays
            .iter()
            .map(|(relay, _)| {
                s.spawn(move || {
                    relay
                        .run_relay_round(
                            COHORT,
                            COHORT,
                            Duration::from_secs(5),
                            Duration::from_secs(5),
                        )
                        .expect("relay round")
                })
            })
            .collect();
        let relay_runs: Vec<_> = runs.into_iter().map(|h| h.join().unwrap()).collect();
        (drive.join().unwrap().expect("root round"), relay_runs)
    });

    for (e, run) in relay_runs.iter().enumerate() {
        println!(
            "edge{e}: folded {} members locally, forwarded 1 partial ({:?}), model republished: {}",
            run.folded,
            run.forwarded.as_ref().map(|m| match m {
                Message::Ack { .. } => "Ack",
                Message::Duplicate { .. } => "Duplicate",
                Message::Late { .. } => "Late",
                _ => "Error",
            }),
            run.model_published
        );
        assert_eq!(run.outcome, RoundOutcome::Complete);
        assert!(run.model_published);
    }
    println!(
        "root : outcome {:?}, {} members folded from {} partial frames, ingest {} bytes",
        root_run.outcome,
        root_run.folded,
        EDGES,
        root_handle.bytes_in.load(std::sync::atomic::Ordering::Relaxed)
    );
    assert_eq!(root_run.outcome, RoundOutcome::Complete);
    assert_eq!(root_run.folded, total, "quorum counts cohort members");

    // --- clients fetch the fused model from their OWN edge --------------
    let (_, edge0_addr) = &relays[0];
    let mut c = NetClient::connect(edge0_addr).expect("connect relay");
    match c.call(&Message::GetModel { round: 0 }).expect("get model") {
        Message::Model { round, weights } => {
            let (fused, _) = root_run.result.expect("published");
            assert_eq!(round, 0);
            assert_eq!(weights, fused, "the relay serves the root's exact model");
            println!(
                "model: {} params served from edge0, fused[0..3] = {:?}",
                weights.len(),
                &weights[..3]
            );
        }
        other => panic!("{other:?}"),
    }

    let _ = std::fs::remove_dir_all(&scratch);
    println!("hierarchical OK — {total} clients, {EDGES} partials, one exact fused model");
}
