//! Quickstart: stand up the adaptive aggregation service, feed it a small
//! round, a past-the-ceiling streaming round, and a holistic round that
//! must go distributed — and watch it pick the right path each time.
//!
//! Run: `cargo run --release --offline --example quickstart`

use std::sync::Arc;
use std::time::Duration;

use elastiagg::client::{SyntheticParty, Transport};
use elastiagg::config::ServiceConfig;
use elastiagg::coordinator::{AdaptiveService, WorkloadClass};
use elastiagg::dfs::{DfsClient, NameNode};
use elastiagg::engine::XlaEngine;
use elastiagg::fusion::FedAvg;
use elastiagg::mapreduce::ExecutorConfig;
use elastiagg::metrics::Breakdown;
use elastiagg::net::{Message, NetClient};
use elastiagg::runtime::Runtime;
use elastiagg::server::FlServer;

fn main() {
    // --- 1. bring up the store + service + TCP front -----------------
    let root = std::env::temp_dir().join(format!("elastiagg-quickstart-{}", std::process::id()));
    let nn = NameNode::create(&root, 3, 2, 8 << 20).expect("dfs");
    let dfs = DfsClient::new(nn);

    let update_len = 10_000usize; // 40 KB updates
    let update_bytes = (update_len * 4) as u64;

    let mut cfg = ServiceConfig::default();
    cfg.node.memory_bytes = 1 << 20; // 1 MiB node: >12 updates spill
    cfg.node.cores = 4;
    cfg.monitor_timeout_s = 10.0;

    let xla = Runtime::load_default().ok().and_then(|r| XlaEngine::auto(r, 16).ok());
    println!("XLA hot path available: {}", xla.is_some());
    let service = AdaptiveService::new(
        cfg,
        dfs.clone(),
        xla,
        ExecutorConfig { executors: 2, cores_per_executor: 2, ..Default::default() },
    );
    let server = FlServer::new(service, Arc::new(FedAvg), update_bytes);
    let handle = server.start("127.0.0.1:0").expect("bind");
    println!("server on {}", handle.addr());

    // --- 2. small round: 8 parties over TCP ---------------------------
    let addr = handle.addr().to_string();
    std::thread::scope(|s| {
        for p in 0..8u64 {
            let addr = addr.clone();
            s.spawn(move || {
                let mut c = NetClient::connect(&addr).unwrap();
                c.call(&Message::Register { party: p }).unwrap();
                let mut party = SyntheticParty::new(p, 0xA11CE);
                let u = party.make_update(0, update_len);
                c.call(&Message::Upload(u)).unwrap();
            });
        }
    });
    // The fleet grows to 64 BEFORE round 0 finishes, so round 1 opens
    // against the full registry (§III-D3 preemptive classification).
    {
        let mut c = NetClient::connect(&addr).unwrap();
        for p in 8..64u64 {
            c.call(&Message::Register { party: p }).unwrap();
        }
    }
    let (fused, report) = server.run_round(8, Duration::from_secs(5)).unwrap();
    assert_eq!(report.class, WorkloadClass::Small);
    println!(
        "round 0: class={:?} engine={} parties={} fused[0..4]={:?}  [{}]",
        report.class,
        report.engine,
        report.parties,
        &fused[..4],
        report.breakdown.summary()
    );

    // --- 3. 64 parties: STREAM past the buffered ceiling ----------------
    // 64 × 40 KB × dup 2.0 exceeds the 1 MiB node, but FedAvg is an
    // associative fold — so instead of redirecting everyone to the store,
    // round 1 classifies Streaming: every TCP upload folds into one O(C)
    // accumulator on receipt and its buffer is freed.  Spark never starts.
    std::thread::scope(|s| {
        for p in 0..64u64 {
            let addr = addr.clone();
            s.spawn(move || {
                let mut c = NetClient::connect(&addr).unwrap();
                let mut party = SyntheticParty::new(p, 0xB0B);
                let u = party.make_update(1, update_len);
                c.call(&Message::Upload(u)).unwrap();
            });
        }
    });
    let (fused, report) = server.run_round(64, Duration::from_secs(10)).unwrap();
    assert_eq!(report.class, WorkloadClass::Streaming);
    assert!(!server.service.spark_started());
    println!(
        "round 1: class={:?} engine={} parties={} fused[0..4]={:?}  [{}]",
        report.class,
        report.engine,
        report.parties,
        &fused[..4],
        report.breakdown.summary()
    );

    // --- 4. a holistic fusion cannot stream: store + MapReduce ----------
    // Coordinate-wise median needs the full update set, so the same fleet
    // takes the distributed path: updates land in the store, the monitor
    // gates the job, Sparklet fuses with per-executor combiners.
    let mut bd = Breakdown::new();
    for p in 0..64u64 {
        let mut party = SyntheticParty::new(p, 0xC0DE);
        let u = party.make_update(2, update_len);
        party.ship(&u, &Transport::Dfs, Some(&dfs), &mut bd).unwrap();
    }
    let (fused, report) = server
        .service
        .aggregate_large(&elastiagg::fusion::CoordMedian, 2, 64, update_bytes)
        .unwrap();
    assert_eq!(report.engine, "mapreduce");
    println!(
        "round 2: class={:?} engine={} parties={} partitions={} fused[0..4]={:?}  [{}]",
        report.class,
        report.engine,
        report.parties,
        report.partitions,
        &fused[..4],
        report.breakdown.summary()
    );

    let _ = std::fs::remove_dir_all(&root);
    println!("quickstart OK");
}
