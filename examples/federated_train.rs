//! End-to-end validation driver (DESIGN.md §End-to-end validation):
//! federated training of the L2 model on synthetic non-IID shards.
//!
//! Every layer composes here:
//! * L1/L2 — parties train locally by executing the AOT `train_step`
//!   artifact (JAX fwd/bwd lowered to HLO; Pallas fusion kernels in the
//!   aggregation graph);
//! * L3 — the adaptive service classifies each round and fuses on the XLA
//!   FedAvg hot path (or MapReduce-over-DFS when memory-constrained);
//! * the printed loss curve is recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --offline --example federated_train -- [parties] [rounds]`

use elastiagg::bench::{federated_train, TrainConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parties = args.first().and_then(|s| s.parse().ok()).unwrap_or(16);
    let rounds = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(30);

    let cfg = TrainConfig {
        parties,
        rounds,
        local_steps: 10,
        lr: 0.05,
        skew: 2.0, // non-IID shards: each party favours one class
        seed: 42,
        node_memory: 1 << 30,
        print_every: 1,
    };
    println!(
        "federated training: {} parties x {} rounds x {} local steps (non-IID skew {})",
        cfg.parties, cfg.rounds, cfg.local_steps, cfg.skew
    );
    let root = std::env::temp_dir().join(format!("elastiagg-fedtrain-{}", std::process::id()));
    let log = federated_train(&cfg, &root);
    let _ = std::fs::remove_dir_all(&root);

    println!("\nloss curve (round, eval_nll, eval_acc):");
    for r in &log.rounds {
        println!("  {:>3}  {:.4}  {:.3}", r.round, r.eval_nll, r.eval_acc);
    }
    println!(
        "\nRESULT  nll {:.4} -> {:.4}  acc {:.3}  (engine mix: {} xla / {} mapreduce rounds)",
        log.first_nll(),
        log.final_nll(),
        log.final_acc(),
        log.rounds.iter().filter(|r| r.engine == "xla").count(),
        log.rounds.iter().filter(|r| r.engine == "mapreduce").count(),
    );
    assert!(log.final_nll() < log.first_nll(), "training must reduce loss");
}
