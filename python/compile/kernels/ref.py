"""Pure-jnp oracles for the Pallas fusion kernels.

These are the correctness ground truth: `python/tests/test_kernels.py` pins
every kernel in `fusion.py` against these with hypothesis-driven shape/value
sweeps, and the rust engines are pinned against the same math through the
AOT artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-6  # the paper's epsilon in Eq. (1)


def weighted_sum(updates, weights):
    """out[c] = sum_k weights[k] * updates[k, c] — f32[C]."""
    return jnp.einsum("k,kc->c", weights, updates)


def clipped_weighted_sum(updates, weights, clip):
    """Weighted sum of per-element-clipped updates."""
    return jnp.einsum("k,kc->c", weights, jnp.clip(updates, -clip, clip))


def squared_distances(updates, center):
    """Per-client squared L2 distance to center — f32[K]."""
    d = updates - center[None, :]
    return jnp.sum(d * d, axis=1)


def fedavg(updates, counts):
    """Paper Eq. (1): M = sum_i n_i * w_i / (n_total + eps).

    ``counts`` are per-client sample counts; the weighted mean is taken with
    the paper's epsilon in the denominator.
    """
    num = weighted_sum(updates, counts)
    return num / (jnp.sum(counts) + EPS)


def iteravg(updates):
    """Simple mean over clients (IBMFL Iterative Averaging)."""
    k = updates.shape[0]
    return weighted_sum(updates, jnp.full((k,), 1.0, jnp.float32)) / k


def coordinate_median(updates):
    """Coordinate-wise median (Yin et al. 2018)."""
    return jnp.median(updates, axis=0)
