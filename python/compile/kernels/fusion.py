"""L1 — Pallas fusion kernels: the aggregation hot-spot.

The paper's fusion algorithms (FedAvg, IterAvg, ClippedAvg, ...) all reduce a
stack of client model updates ``[K, C]`` with per-client weights ``[K]`` to a
single fused vector ``[C]``.  That streaming reduction is the compute
hot-spot of the aggregation service, so it is written as a Pallas kernel:

* the update stack is tiled along the parameter axis ``C`` with a
  ``BlockSpec`` of ``(K, BLOCK_C)`` — this is the HBM<->VMEM schedule (the
  role Spark partitions play in the paper's cluster implementation);
* each grid step loads one ``(K, BLOCK_C)`` tile plus the ``[K]`` weight
  vector into VMEM and produces a ``(BLOCK_C,)`` partial result with a
  single pass (vector ops on the VPU — fusion is element-wise, no MXU).

Kernels MUST be lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls (see /opt/xla-example/README.md).  Correctness is
pinned against the pure-jnp oracle in ``ref.py`` by ``python/tests``.

VMEM accounting (for the DESIGN.md §Perf roofline estimate): a tile holds
``K * BLOCK_C * 4`` bytes of updates + ``BLOCK_C * 4`` output + ``K * 4``
weights.  The AOT geometry (``model.block_c_for``) targets a ~4 MiB tile —
K=16 × BLOCK_C=65536 × 4 B — which leaves room for double-buffering inside
a 16 MiB VMEM while being large enough that the grid loop is not
overhead-bound (§Perf: on the CPU interpret path, 8192-wide tiles ran at
0.44 GB/s vs 20 GB/s at one 16×65536 grid step).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile along the parameter axis.  Must divide the padded chunk
# length C used by aot.py.
DEFAULT_BLOCK_C = 8192


def _wsum_kernel(w_ref, x_ref, o_ref):
    """o[c] = sum_k w[k] * x[k, c] over one (K, BLOCK_C) tile."""
    x = x_ref[...]              # (K, BLOCK_C)
    w = w_ref[...]              # (K,)
    # Single fused multiply-reduce over the client axis.  dot() would engage
    # the MXU on TPU for a (1,K)x(K,BC) matmul; for K this small the VPU
    # broadcast-multiply + tree-sum is the better schedule and is what the
    # weighted-average loop in the paper's Numba path expresses.
    o_ref[...] = jnp.sum(x * w[:, None], axis=0)


def _clipped_wsum_kernel(w_ref, clip_ref, x_ref, o_ref):
    """Like _wsum_kernel but each update is clamped to [-clip, clip] first.

    This is the building block of IBMFL-style ClippedAveraging: clipping is
    applied per-client *before* weighting, inside the same VMEM tile so the
    stack is still read exactly once.
    """
    x = x_ref[...]
    w = w_ref[...]
    clip = clip_ref[0]
    xc = jnp.clip(x, -clip, clip)
    o_ref[...] = jnp.sum(xc * w[:, None], axis=0)


def _sq_dist_kernel(x_ref, c_ref, o_ref):
    """Per-client squared L2 distance to a center over one tile.

    o[k] += sum_c (x[k,c] - center[c])^2 ; used by Krum / Zeno scoring.
    Accumulates across the C-grid, so the output block must be initialised
    on the first grid step.
    """
    i = pl.program_id(0)
    x = x_ref[...]                       # (K, BLOCK_C)
    c = c_ref[...]                       # (BLOCK_C,)
    d = x - c[None, :]
    part = jnp.sum(d * d, axis=1)        # (K,)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


def _grid(c: int, block_c: int) -> int:
    if c % block_c != 0:
        raise ValueError(f"C={c} must be a multiple of BLOCK_C={block_c}")
    return c // block_c


@functools.partial(jax.jit, static_argnames=("block_c",))
def weighted_sum(updates: jax.Array, weights: jax.Array,
                 block_c: int = DEFAULT_BLOCK_C) -> jax.Array:
    """Fused weighted sum: ``out[c] = sum_k weights[k] * updates[k, c]``.

    ``updates``: f32[K, C] stacked flat client updates (zero-padded tail is
    harmless because padded rows carry weight 0).
    ``weights``: f32[K] per-client weights (sample counts for FedAvg,
    1/K for IterAvg).
    """
    k, c = updates.shape
    grid = _grid(c, block_c)
    return pl.pallas_call(
        _wsum_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),           # weights: replicated
            pl.BlockSpec((k, block_c), lambda i: (0, i)),  # update tile
        ],
        out_specs=pl.BlockSpec((block_c,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((c,), jnp.float32),
        interpret=True,
    )(weights, updates)


@functools.partial(jax.jit, static_argnames=("block_c",))
def clipped_weighted_sum(updates: jax.Array, weights: jax.Array,
                         clip: jax.Array,
                         block_c: int = DEFAULT_BLOCK_C) -> jax.Array:
    """Weighted sum with per-element clipping to ``[-clip, clip]``."""
    k, c = updates.shape
    grid = _grid(c, block_c)
    clip_v = jnp.reshape(clip.astype(jnp.float32), (1,))
    return pl.pallas_call(
        _clipped_wsum_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((k, block_c), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block_c,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((c,), jnp.float32),
        interpret=True,
    )(weights, clip_v, updates)


@functools.partial(jax.jit, static_argnames=("block_c",))
def squared_distances(updates: jax.Array, center: jax.Array,
                      block_c: int = DEFAULT_BLOCK_C) -> jax.Array:
    """Per-client squared L2 distance to ``center``: f32[K]."""
    k, c = updates.shape
    grid = _grid(c, block_c)
    return pl.pallas_call(
        _sq_dist_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((k, block_c), lambda i: (0, i)),
            pl.BlockSpec((block_c,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((k,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
        interpret=True,
    )(updates, center)
