"""AOT compiler: lower every L2 graph to HLO *text* + a manifest.

Interchange format is HLO text, NOT ``HloModuleProto.serialize()`` —
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Outputs ``<out-dir>/<name>.hlo.txt`` per graph plus ``manifest.json``
describing each artifact's inputs/outputs, which the rust runtime
(`rust/src/runtime/`) consumes.  All graphs are lowered with
``return_tuple=True`` so the rust side always unwraps a tuple.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Fixed AOT geometry.  C is the flat-chunk length every model update is
# sliced into (zero-padded tail); K is the stack height (padded rows get
# weight zero).  BLOCK_C is the Pallas tile - it must divide C.
CHUNK_C = 65536
STACK_KS = (16, 64)
MEDIAN_KS = (8, 16, 32)
TRAIN_BATCH = 32
EVAL_BATCH = 256


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_entry(shape, dtype) -> Dict[str, Any]:
    return {"shape": list(shape), "dtype": str(dtype)}


class Emitter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries: List[Dict[str, Any]] = []

    def emit(self, name: str, fn, in_specs, meta: Dict[str, Any],
             outputs: List[Dict[str, Any]]) -> None:
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        self.entries.append({
            "name": name,
            "file": f"{name}.hlo.txt",
            "inputs": [_shape_entry(s.shape, s.dtype) for s in in_specs],
            "outputs": outputs,
            "meta": meta,
        })
        print(f"  {name}: {len(text)} chars")

    def manifest(self, extra: Dict[str, Any]) -> None:
        man = {"version": 1, "chunk_c": CHUNK_C, "artifacts": self.entries}
        man.update(extra)
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(man, f, indent=1)


def emit_fusion(em: Emitter) -> None:
    f32 = jnp.float32
    for k in STACK_KS:
        stack = _spec((k, CHUNK_C), f32)
        w = _spec((k,), f32)
        em.emit(
            f"wsum_k{k}", model.fused_weighted_sum, (stack, w),
            meta={"kind": "wsum", "k": k, "c": CHUNK_C},
            outputs=[_shape_entry((CHUNK_C,), f32), _shape_entry((), f32)],
        )
        em.emit(
            f"clipsum_k{k}", model.fused_clipped_sum,
            (stack, w, _spec((), f32)),
            meta={"kind": "clipsum", "k": k, "c": CHUNK_C},
            outputs=[_shape_entry((CHUNK_C,), f32), _shape_entry((), f32)],
        )
    for k in MEDIAN_KS:
        stack = _spec((k, CHUNK_C), f32)
        em.emit(
            f"median_k{k}", model.coordinate_median, (stack,),
            meta={"kind": "median", "k": k, "c": CHUNK_C},
            outputs=[_shape_entry((CHUNK_C,), f32)],
        )
    k = STACK_KS[0]
    em.emit(
        f"krum_k{k}", model.krum_scores,
        (_spec((k, CHUNK_C), jnp.float32), _spec((k,), jnp.float32)),
        meta={"kind": "krum", "k": k, "c": CHUNK_C},
        outputs=[_shape_entry((k,), f32)],
    )


def emit_model(em: Emitter) -> None:
    f32, i32 = jnp.float32, jnp.int32
    layers = model.DEFAULT_LAYERS
    p = model.param_count(layers)
    flat = _spec((p,), f32)

    em.emit(
        "init_params", lambda seed: (model.init_params(seed, layers),),
        (_spec((), i32),),
        meta={"kind": "init", "param_count": p, "layers": list(layers)},
        outputs=[_shape_entry((p,), f32)],
    )
    em.emit(
        "train_step",
        lambda fl, x, y, lr: model.train_step(fl, x, y, lr, layers),
        (flat, _spec((TRAIN_BATCH, layers[0]), f32),
         _spec((TRAIN_BATCH,), i32), _spec((), f32)),
        meta={"kind": "train_step", "param_count": p, "layers": list(layers),
              "batch": TRAIN_BATCH},
        outputs=[_shape_entry((p,), f32), _shape_entry((), f32)],
    )
    em.emit(
        "eval_model",
        lambda fl, x, y: model.eval_model(fl, x, y, layers),
        (flat, _spec((EVAL_BATCH, layers[0]), f32), _spec((EVAL_BATCH,), i32)),
        meta={"kind": "eval", "param_count": p, "layers": list(layers),
              "batch": EVAL_BATCH},
        outputs=[_shape_entry((), f32), _shape_entry((), f32)],
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    em = Emitter(args.out_dir)
    print("emitting fusion artifacts (L1 pallas, interpret=True)...")
    emit_fusion(em)
    print("emitting model artifacts (L2 train/eval)...")
    emit_model(em)
    em.manifest({
        "stack_ks": list(STACK_KS),
        "median_ks": list(MEDIAN_KS),
        "train_batch": TRAIN_BATCH,
        "eval_batch": EVAL_BATCH,
        "layers": list(model.DEFAULT_LAYERS),
        "param_count": model.param_count(),
    })
    print(f"wrote manifest with {len(em.entries)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
