"""L2 — the JAX compute graphs that get AOT-lowered for the rust runtime.

Two families of graphs:

1. **Fusion graphs** — the aggregation math of the paper's fusion algorithms
   (FedAvg Eq. (1), IterAvg, ClippedAvg, coordinate median, Krum scoring),
   expressed over a fixed-K stack of flat client updates and calling the
   Pallas kernels in ``kernels/fusion.py`` for the hot reduction.  The rust
   coordinator handles arbitrary party counts by zero-weight padding to K
   and combining partial (sum, weight-total) pairs across K-groups — the
   algebra is associative, which `python/tests` verifies.

2. **The FL client model** — a small dense classifier whose parameters live
   in ONE flat f32 vector (so a model update is exactly the flat buffer the
   aggregation service ships around).  ``train_step`` does fwd/bwd/SGD over
   a minibatch; ``init_params`` and ``eval_model`` complete the loop for the
   end-to-end driver (examples/federated_train.rs).

Every public function here has static shapes; ``aot.py`` lowers them to HLO
text once at build time.  Python never runs on the request path.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import fusion
from .kernels.ref import EPS

# --------------------------------------------------------------------------
# Fusion graphs (call the L1 Pallas kernels)
# --------------------------------------------------------------------------


def block_c_for(k: int, c: int) -> int:
    """Pallas tile length along C for a K-row stack.

    §Perf (see EXPERIMENTS.md): target a ~4 MiB VMEM tile — big enough that
    the HBM→VMEM pipeline is not grid-overhead-bound (on the CPU interpret
    path each grid step costs a dynamic-slice round trip: block 8192 ran at
    0.44 GB/s vs 2.15 GB/s at one 64×65536 grid step), small enough that a
    double-buffered tile pair still fits a 16 MiB VMEM.
    """
    target_bytes = 4 << 20
    bc = max(256, min(c, target_bytes // (4 * max(k, 1))))
    # largest power-of-two divisor of c not exceeding bc
    while c % bc != 0:
        bc //= 2
    return max(bc, 1)


def fused_weighted_average(stack: jax.Array, weights: jax.Array) -> jax.Array:
    """FedAvg, paper Eq. (1): sum_k w_k * x_k / (sum_k w_k + eps).

    ``stack`` f32[K, C]; ``weights`` f32[K] (zero for padded rows).
    Returns f32[C].
    """
    k, c = stack.shape
    num = fusion.weighted_sum(stack, weights, block_c=block_c_for(k, c))
    return num / (jnp.sum(weights) + EPS)


def fused_weighted_sum(stack: jax.Array, weights: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """MapReduce building block: (partial weighted sum f32[C], weight total).

    Partials from different K-groups combine by plain addition; the rust
    side finalises with num / (wtot + eps).  This is the artifact the
    mapreduce map tasks and the single-node XLA engine both execute.
    """
    k, c = stack.shape
    num = fusion.weighted_sum(stack, weights, block_c=block_c_for(k, c))
    return num, jnp.sum(weights)


def fused_clipped_sum(stack: jax.Array, weights: jax.Array,
                      clip: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """ClippedAveraging partial: clip each update then weighted-sum."""
    k, c = stack.shape
    num = fusion.clipped_weighted_sum(stack, weights, clip,
                                      block_c=block_c_for(k, c))
    return num, jnp.sum(weights)


def coordinate_median(stack: jax.Array) -> jax.Array:
    """Coordinate-wise median over an exact-K stack (no padding trick —
    median is not weight-linear, so the rust side only dispatches here when
    the group is exactly K)."""
    return jnp.median(stack, axis=0)


def krum_scores(stack: jax.Array, weights: jax.Array) -> jax.Array:
    """Krum-style pairwise score: for each client, the sum of its squared
    distances to every other (non-padded) client, computed via the Pallas
    squared-distance kernel against each row as center.  f32[K]."""
    k, c = stack.shape
    bc = block_c_for(k, c)

    def one(center_row):
        return fusion.squared_distances(stack, center_row, block_c=bc)

    d = jax.vmap(one)(stack)                       # (K, K): d[i, j] = |x_j - x_i|^2
    mask = (weights > 0).astype(jnp.float32)       # padded rows excluded
    scores = jnp.sum(d * mask[None, :], axis=1)    # row i: sum over real j
    # exclude self-distance (zero anyway) and make padded rows worst-score
    big = jnp.float32(3.4e38)
    return jnp.where(mask > 0, scores, big)


# --------------------------------------------------------------------------
# FL client model: dense classifier over flat params
# --------------------------------------------------------------------------

# Layer widths: input -> hidden... -> classes.  The default gives ~0.57 M
# parameters (2.3 MB update, between the paper's CNN4.6/100 and ResNet50/100
# scaled sizes); aot.py can emit variants.
DEFAULT_LAYERS = (784, 512, 256, 10)


def param_count(layers: Sequence[int] = DEFAULT_LAYERS) -> int:
    """Total flat parameter count (weights + biases)."""
    return sum(layers[i] * layers[i + 1] + layers[i + 1]
               for i in range(len(layers) - 1))


def _unflatten(flat: jax.Array, layers: Sequence[int]) -> List[Tuple[jax.Array, jax.Array]]:
    """Slice the flat parameter vector into per-layer (W, b) views."""
    out = []
    off = 0
    for i in range(len(layers) - 1):
        fan_in, fan_out = layers[i], layers[i + 1]
        w = flat[off:off + fan_in * fan_out].reshape(fan_in, fan_out)
        off += fan_in * fan_out
        b = flat[off:off + fan_out]
        off += fan_out
        out.append((w, b))
    return out


def init_params(seed: jax.Array, layers: Sequence[int] = DEFAULT_LAYERS) -> jax.Array:
    """He-initialised flat parameter vector from an i32 seed."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for i in range(len(layers) - 1):
        key, wk = jax.random.split(key)
        fan_in, fan_out = layers[i], layers[i + 1]
        scale = jnp.sqrt(2.0 / fan_in)
        chunks.append((jax.random.normal(wk, (fan_in * fan_out,), jnp.float32) * scale))
        chunks.append(jnp.zeros((fan_out,), jnp.float32))
    return jnp.concatenate(chunks)


def _forward(flat: jax.Array, x: jax.Array, layers: Sequence[int]) -> jax.Array:
    """Logits for a batch: relu MLP."""
    h = x
    params = _unflatten(flat, layers)
    for i, (w, b) in enumerate(params):
        h = h @ w + b
        if i + 1 < len(params):
            h = jax.nn.relu(h)
    return h


def _loss(flat: jax.Array, x: jax.Array, y: jax.Array,
          layers: Sequence[int]) -> jax.Array:
    logits = _forward(flat, x, layers)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def train_step(flat: jax.Array, x: jax.Array, y: jax.Array, lr: jax.Array,
               layers: Sequence[int] = DEFAULT_LAYERS) -> Tuple[jax.Array, jax.Array]:
    """One SGD step on a minibatch: returns (new flat params, loss)."""
    loss, grad = jax.value_and_grad(_loss)(flat, x, y, layers)
    return flat - lr * grad, loss


def eval_model(flat: jax.Array, x: jax.Array, y: jax.Array,
               layers: Sequence[int] = DEFAULT_LAYERS) -> Tuple[jax.Array, jax.Array]:
    """(mean NLL, accuracy) over an eval batch."""
    logits = _forward(flat, x, layers)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
    return nll, acc
