"""L1 correctness: every Pallas kernel against the pure-jnp oracle.

Hypothesis sweeps shapes and value distributions; assert_allclose is the
gate.  These run at build time (`make test`) — if they fail, the artifacts
are wrong and nothing downstream can be trusted.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fusion, ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


# Shapes: K small-ish, C must be a multiple of block_c; sweep both.
ks = st.integers(min_value=1, max_value=24)
blocks = st.sampled_from([8, 64, 256])
nblocks = st.integers(min_value=1, max_value=6)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=40, deadline=None)
@given(k=ks, bc=blocks, nb=nblocks, seed=seeds)
def test_weighted_sum_matches_ref(k, bc, nb, seed):
    c = bc * nb
    x = rand((k, c), seed)
    w = jnp.abs(rand((k,), seed + 1, 10.0))
    got = fusion.weighted_sum(x, w, block_c=bc)
    want = ref.weighted_sum(x, w)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=30, deadline=None)
@given(k=ks, bc=blocks, nb=nblocks, seed=seeds,
       clip=st.floats(min_value=0.01, max_value=3.0))
def test_clipped_weighted_sum_matches_ref(k, bc, nb, seed, clip):
    c = bc * nb
    x = rand((k, c), seed)
    w = jnp.abs(rand((k,), seed + 1, 5.0))
    clip_arr = jnp.float32(clip)
    got = fusion.clipped_weighted_sum(x, w, clip_arr, block_c=bc)
    want = ref.clipped_weighted_sum(x, w, clip)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=30, deadline=None)
@given(k=ks, bc=blocks, nb=nblocks, seed=seeds)
def test_squared_distances_matches_ref(k, bc, nb, seed):
    c = bc * nb
    x = rand((k, c), seed)
    center = rand((c,), seed + 2)
    got = fusion.squared_distances(x, center, block_c=bc)
    want = ref.squared_distances(x, center)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_weighted_sum_zero_weight_rows_are_padding():
    """Zero-weight padding rows must not perturb the result — the rust
    coordinator relies on this to handle arbitrary party counts."""
    x = rand((8, 256), 7)
    w = jnp.asarray([1.0, 2.0, 3.0, 0.0, 0.0, 0.0, 0.0, 0.0], jnp.float32)
    got = fusion.weighted_sum(x, w)if False else fusion.weighted_sum(x, w, block_c=64)
    want = ref.weighted_sum(x[:3], w[:3])
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_weighted_sum_is_associative_across_groups():
    """Group partial sums combine by addition — the MapReduce invariant."""
    x = rand((12, 512), 11)
    w = jnp.abs(rand((12,), 12, 4.0))
    whole = ref.weighted_sum(x, w)
    part = (fusion.weighted_sum(x[:6], w[:6], block_c=128)
            + fusion.weighted_sum(x[6:], w[6:], block_c=128))
    np.testing.assert_allclose(part, whole, rtol=2e-5, atol=2e-5)


def test_bad_block_raises():
    x = rand((4, 100), 0)
    w = jnp.ones((4,), jnp.float32)
    with pytest.raises(ValueError):
        fusion.weighted_sum(x, w, block_c=64)


def test_fedavg_eq1_epsilon():
    """Eq. (1) uses n_total + 1e-6 in the denominator."""
    x = rand((3, 64), 5)
    counts = jnp.asarray([10.0, 20.0, 30.0], jnp.float32)
    got = ref.fedavg(x, counts)
    want = ref.weighted_sum(x, counts) / (60.0 + 1e-6)
    np.testing.assert_allclose(got, want, rtol=1e-6)
