"""L2 correctness: fusion graphs + the FL client model.

Pins the L2 graphs (which call the Pallas kernels) against the jnp oracle,
checks the flat-parameter plumbing, and verifies train_step actually learns
on a separable toy problem — the guarantee the end-to-end rust driver
(examples/federated_train.rs) builds on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


class TestFusionGraphs:
    def test_weighted_average_matches_eq1(self):
        x = rand((16, 8192), 3)
        w = jnp.abs(rand((16,), 4, 20.0))
        got = model.fused_weighted_average(x, w)
        np.testing.assert_allclose(got, ref.fedavg(x, w), rtol=2e-5, atol=2e-5)

    def test_weighted_sum_partials_combine(self):
        """rust combines (num, wtot) partials by addition then divides."""
        x = rand((32, 8192), 5)
        w = jnp.abs(rand((32,), 6, 10.0))
        n1, t1 = model.fused_weighted_sum(x[:16], w[:16])
        n2, t2 = model.fused_weighted_sum(x[16:], w[16:])
        fused = (n1 + n2) / (t1 + t2 + ref.EPS)
        np.testing.assert_allclose(fused, ref.fedavg(x, w), rtol=2e-5, atol=2e-5)

    def test_clipped_sum(self):
        x = rand((16, 8192), 7, 2.0)
        w = jnp.abs(rand((16,), 8, 3.0))
        num, tot = model.fused_clipped_sum(x, w, jnp.float32(0.5))
        np.testing.assert_allclose(
            num, ref.clipped_weighted_sum(x, w, 0.5), rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(tot, jnp.sum(w), rtol=1e-6)

    def test_coordinate_median(self):
        x = rand((16, 8192), 9)
        np.testing.assert_allclose(
            model.coordinate_median(x), ref.coordinate_median(x), rtol=1e-6)

    def test_krum_scores_prefer_cluster(self):
        """An outlier update must get a worse (larger) Krum score."""
        base = rand((1, 8192), 10, 0.1)
        stack = jnp.concatenate([base + rand((15, 8192), 11, 0.01),
                                 rand((1, 8192), 12, 5.0)])  # last = outlier
        w = jnp.ones((16,), jnp.float32)
        scores = model.krum_scores(stack, w)
        assert int(jnp.argmax(scores)) == 15

    def test_krum_padded_rows_excluded(self):
        stack = rand((16, 8192), 13)
        w = jnp.concatenate([jnp.ones((8,)), jnp.zeros((8,))]).astype(jnp.float32)
        scores = model.krum_scores(stack, w)
        assert bool(jnp.all(scores[8:] > 1e37))
        assert bool(jnp.all(scores[:8] < 1e37))


class TestClientModel:
    def test_param_count_matches_init(self):
        p = model.param_count()
        flat = model.init_params(jnp.int32(0))
        assert flat.shape == (p,)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=1000))
    def test_init_is_deterministic_and_seed_sensitive(self, seed):
        a = model.init_params(jnp.int32(seed))
        b = model.init_params(jnp.int32(seed))
        c = model.init_params(jnp.int32(seed + 1))
        np.testing.assert_array_equal(a, b)
        assert not np.allclose(a, c)

    def test_train_step_shapes_and_loss_finite(self):
        flat = model.init_params(jnp.int32(1))
        x = rand((model.param_count() and 32, 784), 2)
        y = jnp.asarray(np.random.default_rng(3).integers(0, 10, 32), jnp.int32)
        new, loss = model.train_step(flat, x, y, jnp.float32(0.1))
        assert new.shape == flat.shape
        assert np.isfinite(float(loss))

    def test_sgd_learns_separable_toy(self):
        """A few hundred steps on a linearly separable synthetic problem
        must drive loss down and accuracy up — the e2e driver's guarantee."""
        rng = np.random.default_rng(0)
        centers = rng.normal(0, 1, (10, 784)).astype(np.float32)
        flat = model.init_params(jnp.int32(7))
        lr = jnp.float32(0.05)
        first_loss = None
        for step in range(120):
            y = rng.integers(0, 10, 32)
            x = centers[y] + rng.normal(0, 0.3, (32, 784)).astype(np.float32)
            flat, loss = model.train_step(
                flat, jnp.asarray(x), jnp.asarray(y, jnp.int32), lr)
            if first_loss is None:
                first_loss = float(loss)
        ye = rng.integers(0, 10, 256)
        xe = centers[ye] + rng.normal(0, 0.3, (256, 784)).astype(np.float32)
        nll, acc = model.eval_model(
            flat, jnp.asarray(xe), jnp.asarray(ye, jnp.int32))
        assert float(nll) < first_loss * 0.5
        assert float(acc) > 0.8

    def test_eval_outputs_scalars(self):
        flat = model.init_params(jnp.int32(2))
        x = rand((256, 784), 4)
        y = jnp.zeros((256,), jnp.int32)
        nll, acc = model.eval_model(flat, x, y)
        assert nll.shape == () and acc.shape == ()
        assert 0.0 <= float(acc) <= 1.0


class TestAotGeometry:
    def test_chunk_is_block_multiple(self):
        from compile import aot
        from compile.kernels import fusion as fk
        assert aot.CHUNK_C % fk.DEFAULT_BLOCK_C == 0

    def test_param_count_is_manifest_value(self):
        # The manifest's param_count must equal the model's, or the rust
        # runtime would mis-size its buffers.
        assert model.param_count() == model.param_count(model.DEFAULT_LAYERS)
