"""Repo-root pytest config: make `python/` importable so the final
verification command (`pytest python/tests/ -q` from the repo root) works
the same as `cd python && pytest tests/`."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "python"))
