#!/usr/bin/env python3
"""Diff the current CI run's BENCH_<fig>.json artifacts against the previous
run's, and fail on perf regressions past a threshold.

Usage:
    bench_trend.py <current_dir> <baseline_dir> [--threshold 0.2]

Both directories hold BENCH_*.json files as emitted by the Rust benches
(`elastiagg::bench::BenchJson`).  Files are paired by name; rounds are keyed
by (label, round); numeric meta leaves are keyed by their JSON path.  A
missing baseline (first run on a branch, expired artifact, new figure) is
NOT a failure -- the script reports what it skipped and exits 0.  Exit 1
means at least one tracked metric regressed more than the threshold beyond
its noise floor.

Only stdlib; no third-party imports.
"""

import argparse
import json
import math
import os
import sys

# Key-name patterns that decide what a metric means.  Anything that matches
# neither list (geometry like "parties", config echoes like "trim_fraction")
# is informational and never gates.
LOWER_IS_BETTER = (
    "_s", "_ms", "_usd", "bytes", "_rms", "err", "latency", "drift", "cpu",
)
HIGHER_IS_BETTER = (
    "throughput", "ops_per", "gbps", "mbps", "speedup", "per_sec",
)


def direction(key):
    """-1 = lower is better, +1 = higher is better, 0 = untracked."""
    k = key.lower()
    for pat in HIGHER_IS_BETTER:
        if pat in k:
            return 1
    for pat in LOWER_IS_BETTER:
        if k.endswith(pat) or pat in k:
            return -1
    return 0


def noise_floor(key):
    """Absolute change below which a metric is treated as run-to-run noise.

    Wall-clock seconds on shared CI runners jitter tens of milliseconds;
    byte counts jitter by a frame or two of protocol framing; everything
    else gets a small generic floor so bit-stable metrics still gate.
    """
    k = key.lower()
    if k.endswith("_s") or "latency" in k or "cpu" in k:
        return 0.05
    if k.endswith("_ms"):
        return 50.0
    if "bytes" in k:
        return 4096.0
    if k.endswith("_usd"):
        return 1e-6
    return 1e-3


def numeric_leaves(node, path):
    """Yield (path, value) for every numeric leaf under a parsed JSON node."""
    if isinstance(node, bool):
        return
    if isinstance(node, (int, float)):
        if not (isinstance(node, float) and math.isnan(node)):
            yield path, float(node)
    elif isinstance(node, dict):
        for k in sorted(node):
            yield from numeric_leaves(node[k], f"{path}.{k}" if path else k)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from numeric_leaves(v, f"{path}[{i}]")


def flatten(doc):
    """One flat {key: value} map per bench file.

    Rounds are keyed by (label, round) so reordering or appending rows
    never misaligns the diff; meta is keyed by JSON path.
    """
    out = {}
    for key, val in numeric_leaves(doc.get("meta", {}), "meta"):
        out[key] = val
    for rec in doc.get("rounds", []):
        tag = f"rounds[{rec.get('label', '?')}#{rec.get('round', '?')}]"
        for field, val in sorted(rec.items()):
            if field in ("label", "round"):
                continue
            for key, leaf in numeric_leaves(val, f"{tag}.{field}"):
                out[key] = leaf
    return out


def metric_name(key):
    """The field name that decides direction/floor: the last path segment."""
    tail = key.rsplit(".", 1)[-1]
    return tail.split("[", 1)[0]


def compare(fig, cur, base, threshold):
    """Return a list of regression strings for one bench file."""
    regressions = []
    for key in sorted(set(cur) & set(base)):
        name = metric_name(key)
        sign = direction(name)
        if sign == 0:
            continue
        c, b = cur[key], base[key]
        floor = noise_floor(name)
        if sign < 0:
            worse = c - b
        else:
            worse = b - c
        allowed = abs(b) * threshold + floor
        if worse > allowed:
            arrow = "rose" if sign < 0 else "fell"
            regressions.append(
                f"{fig}: {key} {arrow} {b:.6g} -> {c:.6g} "
                f"(worse by {worse:.6g}, allowed {allowed:.6g})"
            )
    return regressions


def load(path):
    with open(path, "r", encoding="utf-8") as fh:
        return json.load(fh)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="directory with this run's BENCH_*.json")
    ap.add_argument("baseline", help="directory with the previous run's artifacts")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="relative regression allowed before failing (default 0.2)")
    args = ap.parse_args()

    if not os.path.isdir(args.current):
        print(f"bench-trend: current dir {args.current!r} missing -- nothing to check")
        return 0
    current = sorted(f for f in os.listdir(args.current)
                     if f.startswith("BENCH_") and f.endswith(".json"))
    if not current:
        print(f"bench-trend: no BENCH_*.json in {args.current!r} -- nothing to check")
        return 0
    if not os.path.isdir(args.baseline):
        print(f"bench-trend: no baseline at {args.baseline!r} "
              "(first run or expired artifact) -- skipping trend gate")
        return 0

    regressions, compared, skipped = [], 0, []
    for fname in current:
        base_path = os.path.join(args.baseline, fname)
        if not os.path.exists(base_path):
            skipped.append(fname)
            continue
        try:
            cur_doc, base_doc = load(os.path.join(args.current, fname)), load(base_path)
        except (OSError, json.JSONDecodeError) as err:
            skipped.append(f"{fname} (unreadable: {err})")
            continue
        fig = cur_doc.get("fig", fname)
        regressions += compare(fig, flatten(cur_doc), flatten(base_doc), args.threshold)
        compared += 1

    print(f"bench-trend: compared {compared} figure(s) "
          f"at threshold {args.threshold:.0%}")
    for s in skipped:
        print(f"bench-trend: skipped {s} -- no baseline")
    if regressions:
        print(f"bench-trend: {len(regressions)} regression(s):")
        for r in regressions:
            print(f"  REGRESSION {r}")
        return 1
    print("bench-trend: no regressions past the threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
