//! Figs 9 + 10 — distributed FedAvg (9) and IterAvg (10) across the model
//! ladder, each at 3× the single-node party capacity.
//!
//! Paper anchor: "we show a 3X increase over baseline for the number of
//! clients that can be supported for each model size", with the
//! read_partition_sum / reduce breakdown.

use elastiagg::bench::{paper_cluster, time, BenchDfs};
use elastiagg::cluster::{FEDAVG_DUP_FACTOR, ITERAVG_DUP_FACTOR};
use elastiagg::config::ModelZoo;
use elastiagg::fusion::{FedAvg, FusionAlgorithm, IterAvg};
use elastiagg::mapreduce::{scheduler::JobConfig, ExecutorConfig, SparkContext};
use elastiagg::metrics::Breakdown;
use elastiagg::util::fmt;

fn main() {
    let vc = paper_cluster();
    elastiagg::bench::banner(
        "Figs 9/10 — distributed aggregation across model sizes at 3x capacity",
        "every model size supports 3x the single-node party count",
    );

    for (figure, algo_name, dup, flops) in
        [("Fig 9 (FedAvg)", "fedavg", FEDAVG_DUP_FACTOR, 1.0),
         ("Fig 10 (IterAvg)", "iteravg", ITERAVG_DUP_FACTOR, 0.8f64)]
    {
        println!("\n[paper-scale, virtual] {figure}: 3x single-node capacity per size:");
        let mut t = fmt::Table::new(&[
            "model", "1-node cap", "3x parties", "read_partition_sum", "reduce", "total",
        ]);
        for m in ModelZoo::cnn_ladder() {
            let cap = vc.single_node_capacity(170 << 30, m.size_bytes, dup);
            let n = cap * 3;
            let cache = m.size_bytes < (64 << 20);
            let bd = vc.distributed_breakdown(m.size_bytes, n, cache);
            let _ = flops;
            t.row(&[
                m.name.to_string(),
                cap.to_string(),
                n.to_string(),
                fmt::secs(bd.get("read_partition") + bd.get("sum")),
                fmt::secs(bd.get("reduce")),
                fmt::secs(bd.total()),
            ]);
        }
        t.print();
        let _ = algo_name;
    }

    // ---- measured at 1:100 scale: ladder subset, 3x scaled capacity ----
    println!("\n[measured, 1:100 scale] real store + scheduler (3x a 12 MB virtual node):");
    let node_scaled = 12u64 << 20; // scaled stand-in for the single node
    let mut t = fmt::Table::new(&["model", "algo", "parties (3x cap)", "read+sum", "reduce", "total"]);
    for name in ["CNN4.6", "CNN73", "CNN179"] {
        let m = ModelZoo::get(name).unwrap();
        let scaled = m.scaled_bytes(0.01);
        let cap = (node_scaled as f64 / (scaled as f64 * FEDAVG_DUP_FACTOR)) as usize;
        let n = (cap * 3).clamp(6, 600);
        let env = BenchDfs::new(3, 2);
        env.seed_round(0, n, (scaled / 4) as usize, 17);
        let sc = SparkContext::start(
            env.dfs.clone(),
            ExecutorConfig { executors: 2, cores_per_executor: 2, ..Default::default() },
        );
        for (an, algo) in [("fedavg", &FedAvg as &dyn FusionAlgorithm), ("iteravg", &IterAvg)] {
            let mut bd = Breakdown::new();
            let (_, total) = time(|| {
                sc.aggregate(algo, "/rounds/0/updates/", &JobConfig::default(), &mut bd).unwrap()
            });
            t.row(&[
                m.name.to_string(),
                an.to_string(),
                n.to_string(),
                fmt::secs(bd.get("read_partition") + bd.get("sum")),
                fmt::secs(bd.get("reduce")),
                fmt::secs(total),
            ]);
        }
    }
    t.print();
    println!("\nfig9/10 OK — 3x party capacity at every size on the distributed path");
}
