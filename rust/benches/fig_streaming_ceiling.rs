//! Fig S (beyond the paper's numbered figures) — buffered vs streaming
//! ingest: peak resident bytes and round latency across party counts.
//!
//! The paper's Fig 1 party ceiling is the buffered path's O(K·C) resident
//! set.  The streaming fold runs the same round in S·O(C): S shard-lane
//! accumulators plus one in-flight update, independent of K.  This bench
//! measures both shapes with the real budgeted `RoundState` — peak bytes
//! from the memory accountant's high-water mark, latency as ingest+fold
//! through publish — and then demonstrates the ceiling lift: a party count
//! that OOMs buffered ingest under a small budget completes streaming.
//!
//! Machine-readable output: `BENCH_fig_streaming_ceiling.json`.

use std::sync::Arc;
use std::time::Instant;

use elastiagg::bench::{BenchJson, RoundRecord};
use elastiagg::coordinator::{RoundError, RoundState, WorkloadClass};
use elastiagg::engine::{AggregationEngine, SerialEngine};
use elastiagg::fusion::FedAvg;
use elastiagg::memsim::MemoryBudget;
use elastiagg::metrics::Breakdown;
use elastiagg::tensorstore::ModelUpdate;
use elastiagg::util::fmt;
use elastiagg::util::json::Json;
use elastiagg::util::rng::Rng;

const UPDATE_LEN: usize = 25_000; // 100 KB updates
const UPDATE_BYTES: u64 = (UPDATE_LEN * 4) as u64;

fn gen_update(p: u64, rng: &mut Rng) -> ModelUpdate {
    let mut d = vec![0f32; UPDATE_LEN];
    rng.fill_gaussian_f32(&mut d, 1.0);
    ModelUpdate::new(p, 1.0 + rng.gen_range(32) as f32, 0, d)
}

/// Buffered round: ingest all, then batch-aggregate.  Returns
/// (peak resident bytes, wall seconds).
fn run_buffered(updates: &[ModelUpdate]) -> (u64, f64) {
    let budget = MemoryBudget::unbounded();
    let st = RoundState::new(0, WorkloadClass::Small, budget.clone());
    let t0 = Instant::now();
    for u in updates {
        st.ingest(u.clone()).unwrap();
    }
    let collected = st.begin_aggregation().unwrap();
    let mut bd = Breakdown::new();
    let fused = SerialEngine::unbounded().aggregate(&FedAvg, &collected, &mut bd).unwrap();
    st.publish(fused).unwrap();
    (budget.high_water(), t0.elapsed().as_secs_f64())
}

/// Streaming round: every ingest folds immediately into one of S=4 shard
/// lanes; finish is the S-way merge + finalize.  Peak resident is the S
/// lane accumulators plus one in-flight update (sequential driver).
fn run_streaming(updates: &[ModelUpdate]) -> (u64, f64) {
    let budget = MemoryBudget::unbounded();
    let st = RoundState::new_streaming(
        0,
        WorkloadClass::Streaming,
        budget.clone(),
        Arc::new(FedAvg),
        4,
    )
    .unwrap();
    let t0 = Instant::now();
    for u in updates {
        st.ingest(u.clone()).unwrap();
    }
    let (fused, _folded) = st.finish_streaming().unwrap();
    st.publish(fused).unwrap();
    (budget.high_water(), t0.elapsed().as_secs_f64())
}

fn main() {
    elastiagg::bench::banner(
        "Fig S — buffered vs streaming ingest: peak memory and latency",
        "buffered peaks at O(K*C); streaming holds S*O(C) at any party count",
    );

    let mut rng = Rng::new(17);
    println!("\n[measured] {UPDATE_LEN}-param (100 KB) updates, FedAvg:");
    let mut out = BenchJson::new("fig_streaming_ceiling");
    out.meta("update_len", Json::num(UPDATE_LEN as f64));
    out.meta("lanes", Json::num(4.0));
    let mut t = fmt::Table::new(&[
        "parties",
        "buffered peak",
        "streaming peak",
        "peak ratio",
        "buffered round",
        "streaming round",
    ]);
    let mut stream_peaks = Vec::new();
    for parties in [8usize, 32, 128, 512] {
        let updates: Vec<ModelUpdate> =
            (0..parties as u64).map(|p| gen_update(p, &mut rng)).collect();
        let (buf_peak, buf_s) = run_buffered(&updates);
        let (str_peak, str_s) = run_streaming(&updates);
        stream_peaks.push(str_peak);
        // buffered parks every update: peak grows with K
        assert!(
            buf_peak >= parties as u64 * UPDATE_BYTES,
            "buffered peak {buf_peak} must hold all {parties} updates"
        );
        // streaming: S=4 lane accumulators + one in-flight update, no
        // matter the K
        assert!(
            str_peak <= (4 + 1) * UPDATE_BYTES,
            "streaming peak {str_peak} must stay S*O(C)"
        );
        t.row(&[
            parties.to_string(),
            fmt::bytes(buf_peak),
            fmt::bytes(str_peak),
            format!("{:.1}x", buf_peak as f64 / str_peak as f64),
            fmt::secs(buf_s),
            fmt::secs(str_s),
        ]);
        out.round(RoundRecord {
            round: parties as u32,
            label: format!("buffered(parties={parties})"),
            latency_s: buf_s,
            peak_bytes: buf_peak,
            ..Default::default()
        });
        out.round(RoundRecord {
            round: parties as u32,
            label: format!("streaming(parties={parties})"),
            latency_s: str_s,
            peak_bytes: str_peak,
            ..Default::default()
        });
    }
    t.print();
    assert!(
        stream_peaks.iter().all(|p| *p == stream_peaks[0]),
        "streaming peak must be independent of the party count: {stream_peaks:?}"
    );

    // ---- the Fig 1 lift: same budget, buffered OOMs, streaming completes
    let budget_bytes = 1 << 20; // 1 MiB node: ~10 buffered updates
    println!(
        "\n[measured] ceiling lift under a {} node budget:",
        fmt::bytes(budget_bytes)
    );
    let budget = MemoryBudget::new(budget_bytes);
    let st = RoundState::new(0, WorkloadClass::Small, budget.clone());
    let mut ceiling = 0usize;
    loop {
        match st.ingest(gen_update(ceiling as u64, &mut rng)) {
            Ok(_) => ceiling += 1,
            Err(RoundError::Memory(_)) => break,
            Err(e) => panic!("{e}"),
        }
    }
    drop(st);

    let parties = ceiling * 20;
    let budget = MemoryBudget::new(budget_bytes);
    let st = RoundState::new_streaming(
        0,
        WorkloadClass::Streaming,
        budget.clone(),
        Arc::new(FedAvg),
        4,
    )
    .unwrap();
    for p in 0..parties as u64 {
        st.ingest(gen_update(p, &mut rng)).unwrap();
    }
    let (fused, folded) = st.finish_streaming().unwrap();
    assert_eq!(folded, parties);
    assert_eq!(fused.len(), UPDATE_LEN);
    println!(
        "  buffered OOMs at {ceiling} parties; streaming completed {parties} \
         (peak {} of {})",
        fmt::bytes(budget.high_water()),
        fmt::bytes(budget_bytes)
    );
    assert!(budget.high_water() <= (4 + 1) * UPDATE_BYTES);
    out.meta("buffered_ceiling", Json::num(ceiling as f64));
    out.round(RoundRecord {
        round: parties as u32,
        label: format!("ceiling-lift(streamed={parties},buffered_ceiling={ceiling})"),
        peak_bytes: budget.high_water(),
        ..Default::default()
    });

    match out.write() {
        Ok(p) => println!("machine-readable log: {}", p.display()),
        Err(e) => println!("bench json not written: {e}"),
    }
    println!("\nfigS OK — streaming holds the round at S*O(C) and lifts the party ceiling");
}
