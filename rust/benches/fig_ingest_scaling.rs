//! Fig I (beyond the paper's numbered figures) — sharded zero-copy ingest
//! vs the global-lock fold: rounds/s and peak resident bytes across
//! concurrent party counts.
//!
//! PR 2's streaming fold lifted the Fig 1 *memory* ceiling but left
//! ingest *throughput* serialized: every concurrent upload queued on one
//! `Mutex<StreamingFold>`.  The sharded ingest gives each connection one
//! of S shard-local folds (S ≈ cores), so handlers fold concurrently and
//! the lock lane disappears from the hot path.  This bench measures both
//! shapes with the real budgeted `RoundState`:
//!
//! * part 1 sweeps the concurrent party count and reports rounds/s for
//!   lanes=1 (the global-lock baseline) vs lanes=S, asserting sharded
//!   ingest wins at ≥8 parties and that the fused output matches the
//!   serial batch within the merge-associativity tolerance;
//! * part 2 checks the memory envelope: peak resident ≤ S·C·4 plus one
//!   in-flight frame under a sequential driver;
//! * part 3 runs a real TCP round through `FlServer` and prints the
//!   per-round `bytes_in`/`bytes_out` counters the planner's arrival-span
//!   calibration consumes, plus the borrowed-vs-copied decode tallies
//!   (zero-copy health of the wire path).
//!
//! Machine-readable output: `BENCH_fig_ingest_scaling.json`.

use std::sync::Arc;
use std::time::Instant;

use elastiagg::bench::{BenchJson, RoundRecord};
use elastiagg::client::SyntheticParty;
use elastiagg::config::ServiceConfig;
use elastiagg::coordinator::{AdaptiveService, RoundState, WorkloadClass};
use elastiagg::dfs::{DfsClient, NameNode};
use elastiagg::engine::{AggregationEngine, SerialEngine};
use elastiagg::fusion::FedAvg;
use elastiagg::mapreduce::ExecutorConfig;
use elastiagg::memsim::MemoryBudget;
use elastiagg::metrics::Breakdown;
use elastiagg::net::{Message, NetClient};
use elastiagg::server::FlServer;
use elastiagg::tensorstore::{decode_stats, ModelUpdate};
use elastiagg::util::fmt;
use elastiagg::util::json::Json;
use elastiagg::util::prop::all_close;
use elastiagg::util::rng::Rng;

const UPDATE_LEN: usize = 64 * 1024; // 256 KB updates: fold work dominates
const UPDATE_BYTES: u64 = (UPDATE_LEN * 4) as u64;
const UPDATES_PER_PARTY: usize = 4;

fn gen_updates(parties: usize) -> Vec<ModelUpdate> {
    let mut rng = Rng::new(23);
    (0..(parties * UPDATES_PER_PARTY) as u64)
        .map(|p| {
            let mut d = vec![0f32; UPDATE_LEN];
            rng.fill_gaussian_f32(&mut d, 1.0);
            ModelUpdate::new(p, 1.0 + rng.gen_range(16) as f32, 0, d)
        })
        .collect()
}

/// One streaming round: `parties` threads ingest their updates
/// concurrently into a round with `lanes` shard lanes.  Returns
/// (fused weights, peak resident bytes, wall seconds).
fn run_round(updates: &[ModelUpdate], parties: usize, lanes: usize) -> (Vec<f32>, u64, f64) {
    let budget = MemoryBudget::unbounded();
    let st = RoundState::new_streaming(
        0,
        WorkloadClass::Streaming,
        budget.clone(),
        Arc::new(FedAvg),
        lanes,
    )
    .unwrap();
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for chunk in updates.chunks(updates.len() / parties) {
            let st = &st;
            s.spawn(move || {
                for u in chunk {
                    // zero-copy shape: fold straight from a borrowed view
                    st.ingest_view(&u.as_view()).unwrap();
                }
            });
        }
    });
    let (fused, folded) = st.finish_streaming().unwrap();
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(folded, updates.len());
    (fused, budget.high_water(), dt)
}

fn main() {
    elastiagg::bench::banner(
        "Fig I — sharded zero-copy ingest vs the global fold lock",
        "ingest throughput scales with connections instead of one lock lane",
    );

    let lanes = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    println!("\n[measured] {UPDATE_LEN}-param (256 KB) updates, FedAvg, S={lanes} lanes:");

    let mut out = BenchJson::new("fig_ingest_scaling");
    out.meta("lanes", Json::num(lanes as f64));
    out.meta("update_len", Json::num(UPDATE_LEN as f64));

    // ---- part 1: throughput sweep over concurrent parties --------------
    let mut t = fmt::Table::new(&[
        "parties",
        "lock rounds/s",
        "sharded rounds/s",
        "speedup",
        "lock peak",
        "sharded peak",
    ]);
    let mut bd = Breakdown::new();
    for parties in [1usize, 2, 4, 8, 16] {
        let updates = gen_updates(parties);
        let want = SerialEngine::unbounded().aggregate(&FedAvg, &updates, &mut bd).unwrap();
        // average a few repetitions of each shape (allocator warm by rep 2)
        let reps = 3;
        let (mut lock_s, mut shard_s) = (0.0f64, 0.0f64);
        let (mut lock_peak, mut shard_peak) = (0u64, 0u64);
        for _ in 0..reps {
            let (fused, peak, dt) = run_round(&updates, parties, 1);
            all_close(&fused, &want, 1e-4, 1e-5).unwrap();
            lock_s += dt;
            lock_peak = lock_peak.max(peak);
            let (fused, peak, dt) = run_round(&updates, parties, lanes);
            all_close(&fused, &want, 1e-4, 1e-5).unwrap();
            shard_s += dt;
            shard_peak = shard_peak.max(peak);
        }
        let lock_rps = reps as f64 / lock_s;
        let shard_rps = reps as f64 / shard_s;
        if parties >= 8 && lanes >= 2 {
            // the acceptance bar: past the thundering-herd knee the
            // sharded server must beat the single lock lane
            assert!(
                shard_rps > lock_rps,
                "sharded {shard_rps:.2} r/s must beat lock {lock_rps:.2} r/s at {parties} parties"
            );
        }
        t.row(&[
            parties.to_string(),
            format!("{lock_rps:.2}"),
            format!("{shard_rps:.2}"),
            format!("{:.2}x", shard_rps / lock_rps),
            fmt::bytes(lock_peak),
            fmt::bytes(shard_peak),
        ]);
        out.round(RoundRecord {
            round: parties as u32,
            label: format!("lock(parties={parties})"),
            latency_s: lock_s / reps as f64,
            peak_bytes: lock_peak,
            ..Default::default()
        });
        out.round(RoundRecord {
            round: parties as u32,
            label: format!("sharded(parties={parties},lanes={lanes})"),
            latency_s: shard_s / reps as f64,
            peak_bytes: shard_peak,
            ..Default::default()
        });
    }
    t.print();

    // ---- part 2: memory envelope (sequential driver) -------------------
    // Peak resident ≤ S·C·4 + one in-flight frame: the budget-charged
    // shape the classifier and the planner assume.
    let budget = MemoryBudget::unbounded();
    let st = RoundState::new_streaming(
        0,
        WorkloadClass::Streaming,
        budget.clone(),
        Arc::new(FedAvg),
        lanes,
    )
    .unwrap();
    for u in gen_updates(4) {
        st.ingest(u).unwrap();
    }
    let (_, folded) = st.finish_streaming().unwrap();
    assert_eq!(folded, 4 * UPDATES_PER_PARTY);
    assert!(
        budget.high_water() <= (lanes as u64 + 1) * UPDATE_BYTES,
        "peak {} exceeds S*C + one frame ({})",
        budget.high_water(),
        (lanes as u64 + 1) * UPDATE_BYTES
    );
    println!(
        "\n[measured] sequential peak {} ≤ S·C+frame {} (S={lanes})",
        fmt::bytes(budget.high_water()),
        fmt::bytes((lanes as u64 + 1) * UPDATE_BYTES)
    );

    // ---- part 3: real TCP round with wire-volume counters ---------------
    let root = std::env::temp_dir().join(format!(
        "elastiagg-figi-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&root).unwrap();
    let nn = NameNode::create(&root, 2, 1, 1 << 20).unwrap();
    let parties = 32usize;
    let mut cfg = ServiceConfig::default();
    // 32 × 256 KB buffered needs ~18.4 MB (dup 2.0 × headroom 1.1): a
    // 14 MB node spills — the round streams over TCP, sharded and
    // zero-copy, with ≤ (S + parties)·C transient resident.
    cfg.node.memory_bytes = 14 << 20;
    cfg.node.cores = lanes.min(8);
    cfg.monitor_timeout_s = 5.0;
    let svc = AdaptiveService::new(
        cfg,
        DfsClient::new(nn),
        None,
        ExecutorConfig { executors: 1, cores_per_executor: 2, ..Default::default() },
    );
    let server = FlServer::new(svc, Arc::new(FedAvg), UPDATE_BYTES);
    let handle = server.start("127.0.0.1:0").unwrap();
    let addr = handle.addr().to_string();
    // register the fleet first so run_round's re-classification sees it
    for p in 0..parties as u64 {
        let mut c = NetClient::connect(&addr).unwrap();
        c.call(&Message::Register { party: p }).unwrap();
    }
    let decode_mark = decode_stats();
    let (fused, report) = std::thread::scope(|s| {
        let aggregator = s.spawn(|| server.run_round(parties, std::time::Duration::from_secs(30)));
        // give the aggregator a beat to reopen the round as Streaming
        std::thread::sleep(std::time::Duration::from_millis(100));
        for p in 0..parties as u64 {
            let addr = addr.clone();
            s.spawn(move || {
                let mut c = NetClient::connect(&addr).unwrap();
                let mut party = SyntheticParty::new(p, 11);
                let u = party.make_update(0, UPDATE_LEN);
                let r = c.call(&Message::Upload(u)).unwrap();
                assert!(matches!(r, Message::Ack { .. }), "{r:?}");
            });
        }
        aggregator.join().unwrap().unwrap()
    });
    assert_eq!(fused.len(), UPDATE_LEN);
    assert_eq!(report.engine, "streaming", "the spilled round must stream");
    // the fused model comes back over the zero-copy Arc reply path
    let mut c = NetClient::connect(&addr).unwrap();
    match c.call(&Message::GetModel { round: 0 }).unwrap() {
        Message::Model { round, weights } => {
            assert_eq!(round, 0);
            assert_eq!(weights, fused);
        }
        other => panic!("{other:?}"),
    }
    let bytes_in = handle.bytes_in.load(std::sync::atomic::Ordering::Relaxed);
    let bytes_out = handle.bytes_out.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "\n[measured] TCP round: {} parties, engine={}, bytes_in={} bytes_out={}",
        report.parties,
        report.engine,
        fmt::bytes(bytes_in),
        fmt::bytes(bytes_out)
    );
    // every upload frame crossed the counter (32 × ≥256 KB in), and the
    // model fetch dominates the reply bytes (≥ one 256 KB frame out)
    assert!(bytes_in >= parties as u64 * UPDATE_BYTES, "{bytes_in}");
    assert!(bytes_out >= UPDATE_BYTES, "{bytes_out}");
    // zero-copy health: each upload decoded exactly once on ingest, and
    // dense-f32 wire payloads should borrow rather than copy
    let decode = decode_stats().since(decode_mark);
    println!(
        "[measured] wire decodes: borrowed={} copied={} (dense f32 uploads borrow)",
        decode.borrowed, decode.copied
    );
    assert!(
        decode.borrowed + decode.copied >= parties as u64,
        "every upload decodes once: borrowed={} copied={}",
        decode.borrowed,
        decode.copied
    );
    out.meta("decode_borrowed", Json::num(decode.borrowed as f64));
    out.meta("decode_copied", Json::num(decode.copied as f64));
    out.round(RoundRecord {
        round: 100,
        label: format!("tcp(parties={parties},engine={})", report.engine),
        peak_bytes: bytes_in,
        ..Default::default()
    });
    let _ = std::fs::remove_dir_all(&root);

    match out.write() {
        Ok(p) => println!("machine-readable log: {}", p.display()),
        Err(e) => println!("bench json not written: {e}"),
    }
    println!("\nfigI OK — sharded ingest scales past the global lock at identical output");
}
