//! Fig 6 — NumPy vs Numba aggregation time for the 4.6 MB model (a, b)
//! and ResNet50 (c, d), FedAvg + IterAvg, across party counts.
//!
//! Paper anchors: 36% reduction for the 4.6 MB model (many parties);
//! 39.6% for ResNet50 FedAvg at 900 parties; Numba ≈ NumPy for few
//! parties; IterAvg gains less (simpler arithmetic).

use elastiagg::bench::{gen_updates, paper_cluster, time};
use elastiagg::cluster::EngineKind;
use elastiagg::config::ModelZoo;
use elastiagg::engine::{AggregationEngine, ParallelEngine, SerialEngine};
use elastiagg::fusion::{FedAvg, IterAvg};
use elastiagg::metrics::Breakdown;
use elastiagg::util::fmt;

fn main() {
    let vc = paper_cluster();
    elastiagg::bench::banner(
        "Fig 6 — NumPy vs Numba: 4.6 MB + ResNet50, FedAvg + IterAvg",
        "-36% @4.6MB many parties; -39.6% @ResNet50 900 parties; ≈0% few parties",
    );

    for (model, parties) in [("CNN4.6", vec![500usize, 2000, 8000, 16000]),
                             ("Resnet50", vec![100, 300, 600, 900])] {
        let spec = ModelZoo::get(model).unwrap();
        println!("\n[paper-scale, virtual] {model} ({}), 64 cores:", fmt::bytes(spec.size_bytes));
        let mut t = fmt::Table::new(&["parties", "fedavg numpy", "fedavg numba", "impr", "iteravg numpy", "iteravg numba", "impr"]);
        let mut last_fed_imp = 0.0;
        for n in &parties {
            let fs = vc.single_node_time(spec.size_bytes, *n, 64, EngineKind::Serial, 1.0);
            let fp = vc.single_node_time(spec.size_bytes, *n, 64, EngineKind::Parallel, 1.0);
            let is = vc.single_node_time(spec.size_bytes, *n, 64, EngineKind::Serial, 0.8);
            let ip = vc.single_node_time(spec.size_bytes, *n, 64, EngineKind::Parallel, 0.8);
            let fimp = 100.0 * (fs - fp) / fs;
            let iimp = 100.0 * (is - ip) / is;
            last_fed_imp = fimp;
            t.row(&[
                n.to_string(),
                fmt::secs(fs), fmt::secs(fp), format!("{fimp:.1}%"),
                fmt::secs(is), fmt::secs(ip), format!("{iimp:.1}%"),
            ]);
        }
        t.print();
        // paper anchors: 36% (4.6MB) / 39.6% (resnet@900) — the model must
        // land in that band at the largest party count
        assert!((28.0..45.0).contains(&last_fed_imp), "{model}: {last_fed_imp}");
    }

    println!("\n[measured, 1:100 scale] ResNet50/100 ({} KB), party sweep, real engines:",
             ModelZoo::get("Resnet50").unwrap().scaled_bytes(0.01) / 1024);
    let len = ModelZoo::get("Resnet50").unwrap().scaled_params(0.01);
    let mut t = fmt::Table::new(&["parties", "serial fedavg", "parallel(4) fedavg", "serial iteravg", "parallel(4) iteravg"]);
    for n in [32usize, 128, 512] {
        let updates = gen_updates(n as u64, n, len);
        let mut bd = Breakdown::new();
        let (r, fs) = time(|| SerialEngine::unbounded().aggregate(&FedAvg, &updates, &mut bd));
        r.unwrap();
        let (r, fp) = time(|| ParallelEngine::new(4).aggregate(&FedAvg, &updates, &mut bd));
        r.unwrap();
        let (r, is) = time(|| SerialEngine::unbounded().aggregate(&IterAvg, &updates, &mut bd));
        r.unwrap();
        let (r, ip) = time(|| ParallelEngine::new(4).aggregate(&IterAvg, &updates, &mut bd));
        r.unwrap();
        t.row(&[n.to_string(), fmt::secs(fs), fmt::secs(fp), fmt::secs(is), fmt::secs(ip)]);
    }
    t.print();
    println!("\nfig6 OK");
}
