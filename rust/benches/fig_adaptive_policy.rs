//! Fig A (beyond the paper's numbered figures) — cost-aware adaptive
//! dispatch vs. static policies on a mixed small/large round trace.
//!
//! The paper's headline claim is that *adaptive* aggregation lets users
//! manage the cost/efficiency trade-off (2×+ cost reduction, 8× time
//! efficiency vs. static provisioning).  This bench makes that concrete:
//! the dispatch planner prices every candidate plan per round and the
//! `Balanced` policy must STRICTLY dominate at least one static extreme —
//! always-single-node or always-distributed-at-max-k — on BOTH total
//! latency and modeled cost over the trace.  Part 2 runs the real service
//! with planned rounds and prints each round's predicted-vs-observed pair
//! so calibration drift is visible.

use std::time::Duration;

use elastiagg::cluster::{CostModel, VirtualCluster};
use elastiagg::config::ServiceConfig;
use elastiagg::coordinator::{AdaptiveService, WorkloadClass, WorkloadClassifier};
use elastiagg::dfs::{DfsClient, NameNode};
use elastiagg::fusion::FedAvg;
use elastiagg::mapreduce::ExecutorConfig;
use elastiagg::planner::{
    Autoscaler, AutoscalerConfig, DispatchPlanner, DispatchPolicy, PlanKind, PlannerConfig,
    PricingModel,
};
use elastiagg::tensorstore::ModelUpdate;
use elastiagg::util::fmt;
use elastiagg::util::rng::Rng;

const UPDATE_46MB: u64 = (4.6 * 1024.0 * 1024.0) as u64;
const UPDATE_956MB: u64 = 956 << 20;
const MAX_K: usize = 10; // the paper's 10-executor context

#[derive(Default)]
struct Tally {
    latency: f64,
    usd: f64,
    infeasible: usize,
}

fn main() {
    elastiagg::bench::banner(
        "Fig A — adaptive dispatch (Balanced policy) vs static extremes",
        "adaptive aggregation manages the cost/efficiency trade-off (2x+ cost, 8x time)",
    );

    // ---- part 1: paper-scale model comparison (nominal constants) -----
    // A realistic FL trace: mostly modest rounds that fit the 170 GB node,
    // with occasional population bursts that spill (including one
    // big-model round, 956 MB × 91).  Forcing the modest rounds through
    // the store + Spark is what makes static distributed provisioning pay
    // on both axes — exactly the paper's argument for adaptivity.
    let trace: &[(usize, u64)] = &[
        (400, UPDATE_46MB),
        (700, UPDATE_46MB),
        (30_000, UPDATE_46MB),
        (1_000, UPDATE_46MB),
        (500, UPDATE_46MB),
        (91, UPDATE_956MB),
        (1_200, UPDATE_46MB),
        (800, UPDATE_46MB),
        (600, UPDATE_46MB),
        (20_000, UPDATE_46MB),
        (300, UPDATE_46MB),
        (900, UPDATE_46MB),
        (1_100, UPDATE_46MB),
    ];

    let classifier = WorkloadClassifier::new(170 << 30, 1.1);
    let planner = DispatchPlanner::new(
        classifier.clone(),
        VirtualCluster::paper(CostModel::nominal()),
        PricingModel::default(),
        PlannerConfig {
            policy: DispatchPolicy::Balanced(0.5),
            max_executors: MAX_K,
            cores_per_executor: 3, // the paper's 3-core containers
            node_cores: 64,
            ingest_lanes: 64, // streaming priced at the sharded width
            edges: 0,         // this figure compares FLAT plans only
            xla_available: true,
            feedback_beta: 0.3,
            expected_participation: 1.0, // this trace has no dropout
            async_buffer: 0,             // sync candidates only
            staleness_exponent: 0.5,
            ..PlannerConfig::default() // dense-f32 uplinks
        },
    );
    let mut scaler = Autoscaler::new(
        AutoscalerConfig { max_executors: MAX_K, ..Default::default() },
        1, // one warm container (the elastic floor)
    );

    let mut adaptive = Tally::default();
    let mut static_single = Tally::default();
    let mut static_dist = Tally::default();
    let mut warm_adaptive = scaler.current();
    let mut warm_static = 0usize; // the static pool pays its spin-up once

    let mut table = fmt::Table::new(&[
        "round", "parties", "model", "class", "adaptive plan", "adaptive", "always-single",
        "always-dist(k=10)",
    ]);
    for (round, &(parties, bytes)) in trace.iter().enumerate() {
        let class = classifier.classify(bytes, parties, &FedAvg);

        // adaptive: plan against the elastically warm pool
        let plan = planner.plan(bytes, parties, &FedAvg, warm_adaptive);
        warm_adaptive = scaler.observe(plan.chosen.kind.executors()).target();
        adaptive.latency += plan.chosen.cost.latency_s;
        adaptive.usd += plan.chosen.cost.usd;
        let plan_label = match plan.chosen.kind {
            PlanKind::Distributed { executors } => format!("mapreduce(k={executors})"),
            k => k.engine_label().to_string(),
        };

        // static single-node: the parallel engine, or OOM on Large rounds
        let single_cell = if class == WorkloadClass::Small {
            let c = plan
                .candidates
                .iter()
                .find(|c| c.kind == PlanKind::Parallel)
                .expect("small rounds have a parallel candidate");
            static_single.latency += c.cost.latency_s;
            static_single.usd += c.cost.usd;
            format!("{} / ${:.4}", fmt::secs(c.cost.latency_s), c.cost.usd)
        } else {
            static_single.infeasible += 1;
            "OOM".to_string()
        };

        // static distributed at max k: same pricing model, pool always 10
        let dist_plan = planner.plan(bytes, parties, &FedAvg, warm_static);
        let c = dist_plan
            .candidates
            .iter()
            .find(|c| c.kind == PlanKind::Distributed { executors: MAX_K })
            .expect("k=10 candidate always enumerated");
        static_dist.latency += c.cost.latency_s;
        static_dist.usd += c.cost.usd;
        warm_static = MAX_K;

        table.row(&[
            round.to_string(),
            parties.to_string(),
            fmt::bytes(bytes),
            format!("{class:?}"),
            plan_label,
            format!("{} / ${:.4}", fmt::secs(plan.chosen.cost.latency_s), plan.chosen.cost.usd),
            single_cell,
            format!("{} / ${:.4}", fmt::secs(c.cost.latency_s), c.cost.usd),
        ]);
    }
    println!("\n[paper-scale, virtual] per-round plans and (latency / modeled $):");
    table.print();

    println!("\ntrace totals:");
    println!(
        "  adaptive (balanced:0.5) : {} / ${:.4}",
        fmt::secs(adaptive.latency),
        adaptive.usd
    );
    println!(
        "  always-single-node      : {} / ${:.4}  (OOM on {} of {} rounds)",
        fmt::secs(static_single.latency),
        static_single.usd,
        static_single.infeasible,
        trace.len()
    );
    println!(
        "  always-dist (k={MAX_K})      : {} / ${:.4}",
        fmt::secs(static_dist.latency),
        static_dist.usd
    );
    let lat_gain = static_dist.latency / adaptive.latency;
    let usd_gain = static_dist.usd / adaptive.usd;
    println!(
        "  adaptive vs always-dist : {lat_gain:.2}x faster, {usd_gain:.2}x cheaper (strict dominance)"
    );

    // The acceptance bar: Balanced strictly dominates a static extreme on
    // both axes, and the other extreme cannot even run the trace.
    assert!(
        adaptive.latency < static_dist.latency && adaptive.usd < static_dist.usd,
        "adaptive must strictly dominate always-distributed: \
         {:.1}s/${:.4} vs {:.1}s/${:.4}",
        adaptive.latency,
        adaptive.usd,
        static_dist.latency,
        static_dist.usd
    );
    assert!(
        static_single.infeasible > 0,
        "the trace must contain rounds the single node cannot hold"
    );

    // ---- part 2: measured planned rounds on the real service ----------
    println!("\n[measured, 1:100 scale] planned rounds, predicted vs observed:");
    let root = std::env::temp_dir().join(format!("elastiagg-figA-{}", std::process::id()));
    let nn = NameNode::create(&root, 3, 2, 8 << 20).expect("dfs");
    let dfs = DfsClient::new(nn);
    let mut cfg = ServiceConfig::default();
    cfg.node.memory_bytes = 6 << 20; // 6 MiB node: 24 × 200 KB spills
    cfg.node.cores = 4;
    cfg.monitor_timeout_s = 30.0;
    let service = AdaptiveService::new(
        cfg,
        dfs,
        None,
        ExecutorConfig {
            executors: 2,
            cores_per_executor: 2,
            startup: Duration::from_millis(20),
            ..Default::default()
        },
    );

    let update_len = 50_000usize; // 200 KB updates
    let mut rng = Rng::new(41);
    let mut gen = |parties: usize, round: u32| -> Vec<ModelUpdate> {
        (0..parties as u64)
            .map(|p| {
                let mut d = vec![0f32; update_len];
                rng.fill_gaussian_f32(&mut d, 0.5);
                ModelUpdate::new(p, 1.0 + p as f32, round, d)
            })
            .collect()
    };
    // machine-readable trajectory: BENCH_fig_adaptive_policy.json
    let mut bench_json = elastiagg::bench::BenchJson::new("fig_adaptive_policy");
    bench_json.meta("trace", elastiagg::util::json::Json::str("4/24-party alternating"));
    let mut small_single = 0usize;
    let mut spill_streaming = 0usize;
    for round in 0..8u32 {
        let parties = if round % 2 == 0 { 4 } else { 24 };
        let updates = gen(parties, round);
        let (_, report) = service.aggregate_planned(&FedAvg, &updates, round).unwrap();
        let cal = *service.calibration_ledger().last().unwrap();
        bench_json.round(elastiagg::bench::RoundRecord::from_calibration(
            &cal,
            report.engine,
            0,
        ));
        println!(
            "  round {round}: {parties:>2} parties -> {:?}({}, k={})  {}",
            report.class,
            report.engine,
            report.executors,
            cal.log_line()
        );
        match report.class {
            WorkloadClass::Small if report.engine != "mapreduce" => small_single += 1,
            WorkloadClass::Streaming if report.engine == "streaming" => spill_streaming += 1,
            _ => {}
        }
    }
    assert_eq!(
        spill_streaming, 4,
        "every 24-party FedAvg round must stream past the buffered ceiling"
    );
    assert_eq!(small_single, 4, "every 4-party round must stay on the node");
    assert!(!service.spark_started(), "streaming spills must not start Spark");

    // Holistic fusion cannot stream: the same spilling rounds DO go
    // through the store + MapReduce (and spin the executor pool up).
    let mut large_mapreduce = 0usize;
    for round in 8..10u32 {
        let updates = gen(24, round);
        let (_, report) = service
            .aggregate_planned(&elastiagg::fusion::CoordMedian, &updates, round)
            .unwrap();
        let cal = *service.calibration_ledger().last().unwrap();
        println!(
            "  round {round}: 24 parties (median) -> {:?}({}, k={})  {}",
            report.class,
            report.engine,
            report.executors,
            cal.log_line()
        );
        if report.engine == "mapreduce" {
            large_mapreduce += 1;
        }
        bench_json.round(elastiagg::bench::RoundRecord::from_calibration(
            &cal,
            report.engine,
            0,
        ));
    }
    assert_eq!(large_mapreduce, 2, "holistic spills must go to MapReduce");
    let scale_events = service.spark().counters.lock().unwrap().get("scale_events");
    println!(
        "\npool scale events across the alternating trace: {scale_events} (hysteresis holds)"
    );
    match bench_json.write() {
        Ok(p) => println!("machine-readable log: {}", p.display()),
        Err(e) => println!("bench json not written: {e}"),
    }
    let _ = std::fs::remove_dir_all(&root);
    println!("\nfigA OK — Balanced policy strictly dominates always-distributed(k={MAX_K})");
}
