//! Figs 12 + 13 — end-to-end distributed aggregation with simulated
//! clients: {CNN956×6, CNN478×12, ResNet50×60, CNN73×84, CNN4.6×1272},
//! reporting avg per-client write time, phase latencies and partition
//! counts; Fig 13 details the 1272-party run (60 partitions in the paper).
//!
//! Measured at 1:100 scale with REAL party counts (1272 real uploads), so
//! the write-contention and partitioning behaviour is genuine; paper-scale
//! write times come from the 1 GbE + replicated-store contention model.

use elastiagg::bench::{paper_cluster, time, BenchDfs};
use elastiagg::client::fleet_upload_dfs;
use elastiagg::config::ModelZoo;
use elastiagg::dfs::{DfsClient, Monitor};
use elastiagg::fusion::FedAvg;
use elastiagg::mapreduce::{scheduler::JobConfig, ExecutorConfig, SparkContext};
use elastiagg::metrics::Breakdown;
use elastiagg::util::fmt;

fn main() {
    let vc = paper_cluster();
    elastiagg::bench::banner(
        "Figs 12/13 — end-to-end with simulated clients (FedAvg)",
        "write time dominates for big models; 1272-party run partitions ~60",
    );

    println!("\n[paper-scale, virtual] avg per-client write time over 1 GbE:");
    let mut t = fmt::Table::new(&["model", "parties", "avg write", "agg total"]);
    for (m, parties) in ModelZoo::fig12_set() {
        let w = vc.client_write_time(m.size_bytes, parties);
        let bd = vc.distributed_breakdown(m.size_bytes, parties, m.size_bytes < (64 << 20));
        t.row(&[
            m.name.to_string(),
            parties.to_string(),
            fmt::secs(w),
            fmt::secs(bd.total()),
        ]);
    }
    t.print();

    println!("\n[measured, 1:100 scale, REAL party counts] full pipeline per Fig 12:");
    let mut t = fmt::Table::new(&[
        "model", "parties", "avg write", "monitor", "read+sum", "reduce", "partitions",
    ]);
    let mut fig13: Option<(String, Breakdown, usize, f64)> = None;
    for (m, parties) in ModelZoo::fig12_set() {
        let len = m.scaled_params(0.01);
        let env = BenchDfs::new(3, 2);
        // real fleet upload from 6 uploader threads (the 6 client machines)
        let (avg_write, _) = time(|| fleet_upload_dfs(&env.dfs, 0, parties, len, 6, 31));
        let monitor = Monitor::new(env.dfs.namenode().clone());
        let (outcome, mon_secs) = time(|| {
            monitor.watch(&DfsClient::round_prefix(0), parties, std::time::Duration::from_secs(30))
        });
        assert!(outcome.is_ready());
        let sc = SparkContext::start(
            env.dfs.clone(),
            ExecutorConfig { executors: 2, cores_per_executor: 2, ..Default::default() },
        );
        let cache = m.size_bytes < (64 << 20);
        let mut bd = Breakdown::new();
        let ((_, parts), _) = time(|| {
            sc.aggregate(&FedAvg, "/rounds/0/updates/", &JobConfig { cache, ..Default::default() }, &mut bd)
                .unwrap()
        });
        t.row(&[
            m.name.to_string(),
            parties.to_string(),
            fmt::secs(avg_write),
            fmt::secs(mon_secs),
            fmt::secs(bd.get("read_partition") + bd.get("sum")),
            fmt::secs(bd.get("reduce")),
            parts.to_string(),
        ]);
        if m.name == "CNN4.6" {
            fig13 = Some((m.name.to_string(), bd, parts, avg_write));
        }
    }
    t.print();

    let (name, bd, parts, avg_write) = fig13.expect("CNN4.6 run present");
    println!("\nFig 13 — step breakdown of the {name} x 1272-party round:");
    println!("  avg client write : {}", fmt::secs(avg_write));
    for (phase, secs) in bd.phases() {
        println!("  {phase:<16}: {}", fmt::secs(*secs));
    }
    println!("  partitions       : {parts} (paper: 60)");
    println!("\nfig12/13 OK");
}
