//! Fig H (beyond the paper's numbered figures) — flat vs 2-tier
//! hierarchical aggregation.
//!
//! The paper puts the aggregator in a resource-capped edge DC precisely
//! because hauling every client update to one point is the cost and
//! latency bottleneck; the standard edge-FL answer is a 2-tier tree where
//! edge aggregators pre-fold their cohort and forward ONE weighted partial
//! (EdgeFL, arXiv:2309.02936).  This bench pins the crossover:
//!
//! * **[model]** — at the paper's 1 GbE geometry the 2-tier topology must
//!   beat the flat streaming round on BOTH root-ingest bytes and
//!   end-to-end latency at ≥ 32 parties, and must NOT pay off below the
//!   tier barrier; the planner's `Hierarchical` candidate is selected in
//!   exactly those regimes and its EWMA family calibrates independently;
//! * **[measured]** — a real 2-tier round (2 relay servers × N/2 simulated
//!   clients each, forwarding partials to a root over localhost TCP)
//!   ingests a fraction of the flat round's bytes at the root and fuses
//!   the same model (within the documented merge tolerance).
//!
//! Machine-readable output: `BENCH_fig_hierarchical_scaling.json`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use elastiagg::bench::{BenchJson, RoundRecord};
use elastiagg::client::SyntheticParty;
use elastiagg::cluster::{CostModel, VirtualCluster};
use elastiagg::config::{NodeRole, ServiceConfig};
use elastiagg::coordinator::{AdaptiveService, RoundOutcome, WorkloadClassifier};
use elastiagg::dfs::{DfsClient, NameNode};
use elastiagg::fusion::FedAvg;
use elastiagg::mapreduce::ExecutorConfig;
use elastiagg::net::{Message, NetClient};
use elastiagg::planner::{DispatchPlanner, DispatchPolicy, PlanKind, PlannerConfig, PricingModel};
use elastiagg::server::{FlServer, RelayServer};
use elastiagg::util::fmt;
use elastiagg::util::json::Json;
use elastiagg::util::prop::all_close;

const UPDATE_46MB: u64 = (4.6 * 1024.0 * 1024.0) as u64;
const EDGES: usize = 4;

fn make_node(
    role: NodeRole,
    parent: Option<String>,
    edge_id: u64,
    dir: &std::path::Path,
) -> Arc<FlServer> {
    let nn = NameNode::create(dir, 2, 1, 1 << 20).expect("store");
    let mut cfg = ServiceConfig::default();
    cfg.node.memory_bytes = 1 << 20;
    cfg.node.cores = 4;
    cfg.role = role;
    cfg.parent_addr = parent;
    cfg.edge_id = edge_id;
    let svc = AdaptiveService::new(
        cfg,
        DfsClient::new(nn),
        None,
        ExecutorConfig { executors: 1, cores_per_executor: 2, ..Default::default() },
    );
    FlServer::new(svc, Arc::new(FedAvg), (UPDATE_LEN * 4) as u64)
}

const UPDATE_LEN: usize = 2_000; // 8 KB updates for the measured part

fn main() {
    elastiagg::bench::banner(
        "Fig H — flat vs 2-tier hierarchical aggregation",
        "edge pre-folding forwards one weighted partial per edge (EdgeFL shape)",
    );
    let mut bench_json = BenchJson::new("fig_hierarchical_scaling");
    bench_json.meta("edges", Json::num(EDGES as f64));
    bench_json.meta("update_bytes_model", Json::num(UPDATE_46MB as f64));

    // ---- part 1: paper-scale model (1 GbE, 64-core nodes) --------------
    let v = VirtualCluster::paper(CostModel::nominal());
    let mut t = fmt::Table::new(&[
        "parties", "flat s", "2-tier s", "flat root bytes", "2-tier root bytes", "winner",
    ]);
    for &n in &[4usize, 8, 16, 32, 64, 128, 1024, 30_000] {
        let flat_s = v.streaming_time(UPDATE_46MB, n, 64, 64);
        let hier_s = v.hierarchical_time(UPDATE_46MB, n, 64, 64, EDGES);
        let flat_b = v.flat_root_bytes(UPDATE_46MB, n);
        let hier_b = v.hierarchical_root_bytes(UPDATE_46MB, n, EDGES);
        t.row(&[
            n.to_string(),
            format!("{flat_s:.2}"),
            format!("{hier_s:.2}"),
            fmt::bytes(flat_b),
            fmt::bytes(hier_b),
            if hier_s < flat_s { "2-tier" } else { "flat" }.to_string(),
        ]);
        bench_json.round(RoundRecord {
            round: n as u32,
            label: "model:flat".into(),
            latency_s: flat_s,
            peak_bytes: flat_b,
            ..Default::default()
        });
        bench_json.round(RoundRecord {
            round: n as u32,
            label: format!("model:hierarchical(e={EDGES})"),
            latency_s: hier_s,
            peak_bytes: hier_b,
            ..Default::default()
        });
        if n >= 32 {
            assert!(
                hier_s < flat_s && hier_b < flat_b,
                "n={n}: 2-tier must beat flat on BOTH axes: {hier_s} vs {flat_s}, {hier_b} vs {flat_b}"
            );
        }
        if n <= 8 {
            assert!(
                hier_s > flat_s,
                "n={n}: a tiny fleet must not pay the tier barrier: {hier_s} vs {flat_s}"
            );
        }
    }
    println!("\n[paper-scale, virtual] flat streaming vs 2-tier (e={EDGES}):");
    t.print();

    // The planner selects Hierarchical in EXACTLY the winning regimes.
    // The aggregator is the paper's resource-capped edge DC: with 64 MB
    // of aggregation memory every fleet ≥ ~7 parties is past the buffered
    // ceiling, so the contest is flat-streaming vs 2-tier — the regime
    // the crossover above describes.  (A 170 GB datacenter node would
    // buffer these rounds and fold them off the ingest clock entirely;
    // hierarchy is an EDGE answer.)
    let edge_planner = || {
        DispatchPlanner::new(
            WorkloadClassifier::new(64 << 20, 1.1),
            VirtualCluster::paper(CostModel::nominal()),
            PricingModel::default(),
            PlannerConfig {
                policy: DispatchPolicy::MinLatency,
                max_executors: 10,
                cores_per_executor: 3,
                node_cores: 64,
                ingest_lanes: 64,
                edges: EDGES,
                xla_available: false,
                feedback_beta: 0.3,
                expected_participation: 1.0,
                async_buffer: 0, // flat-vs-tree only: no async candidate
                staleness_exponent: 0.5,
                ..PlannerConfig::default() // dense-f32 uplinks
            },
        )
    };
    let planner = edge_planner();
    for &n in &[32usize, 64, 128, 1024, 30_000] {
        let plan = planner.plan(UPDATE_46MB, n, &FedAvg, 0);
        assert_eq!(
            plan.chosen.kind,
            PlanKind::Hierarchical { edges: EDGES },
            "n={n}: MinLatency must take the tier division"
        );
    }
    for &n in &[4usize, 8] {
        let plan = planner.plan(UPDATE_46MB, n, &FedAvg, 0);
        assert_ne!(
            plan.chosen.kind,
            PlanKind::Hierarchical { edges: EDGES },
            "n={n}: below the crossover the flat plan stays chosen"
        );
    }
    println!("planner: Hierarchical(e={EDGES}) chosen at n ≥ 32, flat below — as modeled");

    // ... and is priced within the EWMA band once observations flow back.
    let mut cal_planner = edge_planner();
    let base = cal_planner.plan(UPDATE_46MB, 1024, &FedAvg, 0).chosen.cost.latency_s;
    let truth = base * 1.5; // the real tree runs 1.5× slower than nominal
    let mut last_drift = f64::INFINITY;
    for round in 0..8 {
        let plan = cal_planner.plan(UPDATE_46MB, 1024, &FedAvg, 0);
        last_drift = cal_planner.observe(round, &plan.chosen, truth).drift();
    }
    let corr = cal_planner.correction_for(PlanKind::Hierarchical { edges: EDGES });
    assert!(
        (corr - 1.5).abs() < 0.25,
        "hierarchical EWMA family must absorb the 1.5x drift, got {corr}"
    );
    assert!((last_drift - 1.0).abs() < 0.15, "late rounds predict within the band: {last_drift}");
    println!("EWMA: hierarchical family calibrated to x{corr:.2}, final drift x{last_drift:.2}");

    // ---- part 2: measured 2-tier round over real TCP -------------------
    const N: usize = 32;
    let scratch = std::env::temp_dir().join(format!("elastiagg-figH-{}", std::process::id()));
    std::fs::create_dir_all(&scratch).expect("scratch");
    let updates: Vec<_> = (0..N as u64)
        .map(|p| SyntheticParty::new(p, 0xF16).make_update(0, UPDATE_LEN))
        .collect();

    // flat: all 32 clients straight into one root
    let flat_root = make_node(NodeRole::Root, None, 0, &scratch.join("flat"));
    let flat_handle = flat_root.start("127.0.0.1:0").expect("bind");
    let flat_addr = flat_handle.addr().to_string();
    let t0 = Instant::now();
    let flat_run = std::thread::scope(|s| {
        let drive = s.spawn(|| flat_root.run_round_quorum(N, N, Duration::from_secs(20)));
        for u in updates.clone() {
            let addr = flat_addr.clone();
            s.spawn(move || {
                let mut c = NetClient::connect(&addr).unwrap();
                let r = c.call(&Message::Upload(u)).unwrap();
                assert!(matches!(r, Message::Ack { .. }), "{r:?}");
            });
        }
        drive.join().unwrap().unwrap()
    });
    let flat_s = t0.elapsed().as_secs_f64();
    let flat_bytes = flat_handle.bytes_in.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(flat_run.outcome, RoundOutcome::Complete);
    println!("  flat   {}", flat_run.log_line());
    let flat_fused = flat_run.result.unwrap().0;

    // 2-tier: 2 relays × 16 clients each, one partial per relay to the root
    let root = make_node(NodeRole::Root, None, 0, &scratch.join("root"));
    let root_handle = root.start("127.0.0.1:0").expect("bind");
    let root_addr = root_handle.addr().to_string();
    let mut relay_handles = Vec::new();
    let relays: Vec<(RelayServer, String)> = (0..2u64)
        .map(|e| {
            let server = make_node(
                NodeRole::Relay,
                Some(root_addr.clone()),
                e,
                &scratch.join(format!("edge{e}")),
            );
            let handle = server.start("127.0.0.1:0").expect("bind");
            let addr = handle.addr().to_string();
            relay_handles.push(handle);
            (RelayServer::from_config(server).expect("relay cfg"), addr)
        })
        .collect();
    let t0 = Instant::now();
    let hier_run = std::thread::scope(|s| {
        let drive = s.spawn(|| root.run_round_quorum(N, N, Duration::from_secs(20)));
        for (e, (_, addr)) in relays.iter().enumerate() {
            let cohort: Vec<_> = updates[e * 16..(e + 1) * 16].to_vec();
            let addr = addr.clone();
            s.spawn(move || {
                std::thread::scope(|cs| {
                    for u in cohort {
                        let addr = addr.clone();
                        cs.spawn(move || {
                            let mut c = NetClient::connect(&addr).unwrap();
                            let r = c.call(&Message::Upload(u)).unwrap();
                            assert!(matches!(r, Message::Ack { .. }), "{r:?}");
                        });
                    }
                });
            });
        }
        // both relay rounds run CONCURRENTLY: each forwards its partial,
        // then polls the root for the fused model (which the root only
        // publishes once BOTH partials folded)
        let relay_runs: Vec<_> = relays
            .iter()
            .map(|(relay, _)| {
                s.spawn(move || {
                    relay
                        .run_relay_round(16, 16, Duration::from_secs(10), Duration::from_secs(10))
                        .unwrap()
                })
            })
            .collect();
        for h in relay_runs {
            let run = h.join().unwrap();
            assert_eq!(run.folded, 16);
            assert!(matches!(run.forwarded, Some(Message::Ack { .. })), "{run:?}");
            assert!(run.model_published, "each relay republishes the fused model");
        }
        drive.join().unwrap().unwrap()
    });
    let hier_s = t0.elapsed().as_secs_f64();
    let hier_bytes = root_handle.bytes_in.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(hier_run.outcome, RoundOutcome::Complete);
    assert_eq!(hier_run.folded, N, "the root counted cohort members");
    println!("  2-tier {}", hier_run.log_line());
    let hier_fused = hier_run.result.unwrap().0;
    all_close(&flat_fused, &hier_fused, 1e-4, 1e-5).expect("flat/2-tier parity");

    println!("\n[measured, localhost] {N} parties, {UPDATE_LEN}-param updates:");
    println!(
        "  flat   : {:>10} root-ingest bytes, {} round",
        flat_bytes,
        fmt::secs(flat_s)
    );
    println!(
        "  2-tier : {:>10} root-ingest bytes, {} round (2 relays × 16)",
        hier_bytes,
        fmt::secs(hier_s)
    );
    assert!(
        hier_bytes * 4 < flat_bytes,
        "the root must ingest a FRACTION of the flat bytes: {hier_bytes} vs {flat_bytes}"
    );
    bench_json.meta("measured_flat_root_bytes", Json::num(flat_bytes as f64));
    bench_json.meta("measured_hier_root_bytes", Json::num(hier_bytes as f64));
    bench_json.round(RoundRecord {
        round: 0,
        label: "measured:flat".into(),
        latency_s: flat_s,
        peak_bytes: flat_bytes,
        ..Default::default()
    });
    bench_json.round(RoundRecord {
        round: 0,
        label: "measured:hierarchical(e=2)".into(),
        latency_s: hier_s,
        peak_bytes: hier_bytes,
        ..Default::default()
    });
    match bench_json.write() {
        Ok(p) => println!("machine-readable log: {}", p.display()),
        Err(e) => println!("bench json not written: {e}"),
    }
    let _ = std::fs::remove_dir_all(&scratch);
    println!("\nfigH OK — one partial per edge lifts the root's ingest ceiling");
}
