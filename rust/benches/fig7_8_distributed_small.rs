//! Figs 7 + 8 — distributed aggregation of the 4.6 MB model up to 100 000
//! parties (FedAvg with read/sum/reduce breakdown; IterAvg total).
//!
//! Paper anchors: 100 000 parties supported vs 18 900 single-node for
//! FedAvg (+429.1% scalability) and 32 400 for IterAvg (+207.7%);
//! reduce time small when caching is on (small models).

use elastiagg::bench::{paper_cluster, time, BenchDfs};
use elastiagg::cluster::{FEDAVG_DUP_FACTOR, ITERAVG_DUP_FACTOR};
use elastiagg::fusion::{FedAvg, IterAvg};
use elastiagg::mapreduce::{scheduler::JobConfig, ExecutorConfig, SparkContext};
use elastiagg::metrics::Breakdown;
use elastiagg::util::fmt;

const UPDATE_46MB: u64 = (4.6 * 1024.0 * 1024.0) as u64;

fn main() {
    let vc = paper_cluster();
    elastiagg::bench::banner(
        "Figs 7/8 — distributed aggregation, 4.6 MB model, up to 100k parties",
        "+429.1% party scalability (FedAvg), +207.7% (IterAvg); cached reduce is cheap",
    );

    // ---- scalability headline -----------------------------------------
    let fed_cap = vc.single_node_capacity(170 << 30, UPDATE_46MB, FEDAVG_DUP_FACTOR);
    let iter_cap = vc.single_node_capacity(170 << 30, UPDATE_46MB, ITERAVG_DUP_FACTOR);
    let fed_gain = 100.0 * (100_000.0 - fed_cap as f64) / fed_cap as f64;
    let iter_gain = 100.0 * (100_000.0 - iter_cap as f64) / iter_cap as f64;
    println!("\nscalability at 100 000 parties vs single-node ceiling:");
    println!("  FedAvg : single-node {fed_cap} -> +{fed_gain:.1}%   (paper: +429.1%)");
    println!("  IterAvg: single-node {iter_cap} -> +{iter_gain:.1}%   (paper: +207.7%)");
    assert!((300.0..600.0).contains(&fed_gain), "{fed_gain}");
    assert!((150.0..300.0).contains(&iter_gain), "{iter_gain}");
    // storage, not memory, is the distributed bound (2.6 TB HDFS in paper)
    let cap = vc.distributed_capacity(UPDATE_46MB, 2600u64 << 30);
    println!("  distributed capacity bound (2.6 TB HDFS, repl 2): {cap} parties");
    assert!(cap > 100_000);

    // ---- virtual: paper-scale breakdowns -------------------------------
    println!("\n[paper-scale, virtual] FedAvg phase breakdown (cached):");
    let mut t = fmt::Table::new(&["parties", "read time", "sum time", "reduce time", "total"]);
    for n in [20_000usize, 40_000, 60_000, 80_000, 100_000] {
        let bd = vc.distributed_breakdown(UPDATE_46MB, n, true);
        t.row(&[
            n.to_string(),
            fmt::secs(bd.get("read_partition")),
            fmt::secs(bd.get("sum")),
            fmt::secs(bd.get("reduce")),
            fmt::secs(bd.total()),
        ]);
        // cached reduce stays far below read (the paper's Fig-7 shape)
        assert!(bd.get("reduce") < bd.get("read_partition"));
    }
    t.print();

    println!("\n[paper-scale, virtual] IterAvg total time:");
    let mut t = fmt::Table::new(&["parties", "total"]);
    for n in [20_000usize, 60_000, 100_000] {
        let bd = vc.distributed_breakdown(UPDATE_46MB, n, true);
        t.row(&[n.to_string(), fmt::secs(bd.total() * 0.9)]); // no weight pass
    }
    t.print();

    // ---- measured: real DFS + MapReduce at 1:100 scale ------------------
    println!("\n[measured, 1:100 scale] real store + scheduler, 46 KB updates:");
    let mut t = fmt::Table::new(&["parties", "algo", "read_partition", "sum", "reduce", "total", "parts"]);
    for n in [200usize, 500, 1000, 2000] {
        let env = BenchDfs::new(3, 2);
        env.seed_round(0, n, (UPDATE_46MB / 100 / 4) as usize, n as u64);
        let sc = SparkContext::start(
            env.dfs.clone(),
            ExecutorConfig { executors: 2, cores_per_executor: 2, ..Default::default() },
        );
        for (name, algo) in [("fedavg", &FedAvg as &dyn elastiagg::fusion::FusionAlgorithm),
                             ("iteravg", &IterAvg)] {
            let mut bd = Breakdown::new();
            let ((_, parts), total) = time(|| {
                sc.aggregate(algo, "/rounds/0/updates/", &JobConfig::default(), &mut bd)
                    .unwrap()
            });
            t.row(&[
                n.to_string(),
                name.to_string(),
                fmt::secs(bd.get("read_partition")),
                fmt::secs(bd.get("sum")),
                fmt::secs(bd.get("reduce")),
                fmt::secs(total),
                parts.to_string(),
            ]);
        }
    }
    t.print();
    println!("\nfig7/8 OK — distributed path unbound by node memory");
}
