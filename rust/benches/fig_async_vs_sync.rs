//! Fig A (beyond the paper's numbered figures) — FedBuff-style async
//! rounds vs the sync quorum barrier.
//!
//! The quorum round's clock is its slowest needed client: under a
//! heavy-tail latency distribution the tail IS the round time, no matter
//! how fast the aggregator folds.  The async mode publishes a model every
//! K arrivals instead, discounting stale updates by `s(δ) = (1+δ)^-a`
//! rather than rejecting them.  This bench pins the three claims that
//! make the mode safe to plan:
//!
//! * part 1 — BOTH planner regimes: `MinLatency` under straggler turnout
//!   takes the async plan (its clock is one K-sized publish, not the
//!   fleet); `MinCost` at full turnout keeps the sync streaming quorum
//!   (staleness-discounted weight makes async node-seconds buy less, so
//!   sync is the cheaper $/round);
//! * part 2 — the exactness boundary: with zero staleness (buffer ≥ N,
//!   every update fresh) the async drain is BIT-IDENTICAL to the sync
//!   streaming fold — `assert_eq`, not tolerance;
//! * part 3 — the seeded heavy-tail scenario against the real TCP server:
//!   async publishes off the fast body while the sync quorum clock sits
//!   in the tail band, and every buffered update folds exactly once.
//!
//! Emits `BENCH_fig_async_vs_sync.json` (see `$BENCH_JSON_DIR`).

use std::borrow::Cow;

use elastiagg::bench::{self, BenchJson, RoundRecord};
use elastiagg::cluster::{CostModel, VirtualCluster};
use elastiagg::coordinator::{AsyncRound, WorkloadClassifier};
use elastiagg::engine::StreamingFold;
use elastiagg::fusion::{DiscountedFusion, FedAvg, StalenessDiscount};
use elastiagg::memsim::MemoryBudget;
use elastiagg::planner::{
    DispatchPlanner, DispatchPolicy, PlanKind, PlannerConfig, PricingModel,
};
use elastiagg::sim::{run_async_scenario, straggler_schedules, StragglerConfig};
use elastiagg::tensorstore::ModelUpdateView;
use elastiagg::util::fmt;
use elastiagg::util::json::Json;

fn planner(policy: DispatchPolicy, buffer: usize, participation: f64) -> DispatchPlanner {
    DispatchPlanner::new(
        WorkloadClassifier::new(170 << 30, 1.1),
        VirtualCluster::paper(CostModel::nominal()),
        PricingModel::default(),
        PlannerConfig {
            policy,
            max_executors: 10,
            cores_per_executor: 3,
            node_cores: 64,
            ingest_lanes: 64,
            edges: 0,
            xla_available: false,
            feedback_beta: 0.3,
            expected_participation: participation,
            async_buffer: buffer,
            staleness_exponent: 0.5,
            ..PlannerConfig::default() // dense-f32 uplinks
        },
    )
}

fn main() {
    bench::banner(
        "Fig A — async (FedBuff-style) rounds vs the sync quorum barrier",
        "publish every K arrivals; discount staleness instead of rejecting it",
    );
    let mut out = BenchJson::new("fig_async_vs_sync");

    // ---- part 1: both planner regimes ------------------------------------
    let update = (4.6 * 1024.0 * 1024.0) as u64;
    let parties = 30_000usize;
    out.meta("parties", Json::num(parties as f64));
    out.meta("update_bytes", Json::num(update as f64));

    let mut t = fmt::Table::new(&["policy", "turnout", "chosen", "latency s", "$"]);
    for (policy, turnout, want_async) in [
        (DispatchPolicy::MinLatency, 0.4, true),
        (DispatchPolicy::MinCost, 1.0, false),
    ] {
        let p = planner(policy, 64, turnout);
        let plan = p.plan(update, parties, &FedAvg, 0);
        let stream = plan
            .candidates
            .iter()
            .find(|c| c.kind == PlanKind::Streaming)
            .expect("streaming candidate");
        let asynch = plan
            .candidates
            .iter()
            .find(|c| matches!(c.kind, PlanKind::Async { .. }))
            .expect("async candidate");
        if want_async {
            assert!(
                matches!(plan.chosen.kind, PlanKind::Async { buffer: 64 }),
                "MinLatency under straggler turnout must take async: {:?}",
                plan.chosen
            );
            assert!(
                asynch.cost.latency_s < stream.cost.latency_s / 10.0,
                "one K-publish beats the fleet-wide quorum span: {} vs {}",
                asynch.cost.latency_s,
                stream.cost.latency_s
            );
        } else {
            assert_eq!(
                plan.chosen.kind,
                PlanKind::Streaming,
                "MinCost at full turnout keeps the sync quorum: {:?}",
                plan.chosen
            );
            assert!(
                asynch.cost.usd > stream.cost.usd,
                "discounted async node-seconds buy less effective weight: ${} vs ${}",
                asynch.cost.usd,
                stream.cost.usd
            );
        }
        t.row(&[
            format!("{policy:?}"),
            format!("{:.0}%", turnout * 100.0),
            format!("{:?}", plan.chosen.kind),
            format!("{:.2}", plan.chosen.cost.latency_s),
            format!("{:.4}", plan.chosen.cost.usd),
        ]);
        for c in [stream, asynch] {
            out.round(RoundRecord {
                round: (turnout * 1000.0) as u32,
                label: format!("{policy:?}/{}(turnout={turnout})", c.kind.engine_label()),
                predicted_s: c.cost.latency_s,
                predicted_usd: c.cost.usd,
                ..Default::default()
            });
        }
    }
    t.print();

    // ---- part 2: zero-discount bit-parity --------------------------------
    println!("\n[exactness] buffer ≥ N, every update fresh: async drain ≡ sync fold");
    let n = 48;
    let len = 100_000;
    let us = bench::gen_updates(7, n, len);
    let algo = FedAvg;
    let (want, sync_s) = bench::time(|| {
        let mut f = StreamingFold::new(&algo, 1, MemoryBudget::unbounded()).unwrap();
        for u in &us {
            f.fold(&algo, u).unwrap();
        }
        f.finish(&algo).unwrap()
    });
    let (got, async_s) = bench::time(|| {
        let ar = AsyncRound::new(n, MemoryBudget::unbounded());
        for u in &us {
            ar.offer(u.party, u.party ^ 0xA5, u.round, u.count, &u.data).unwrap();
        }
        let curve = StalenessDiscount::fedbuff();
        let mut f = StreamingFold::new(&algo, 1, MemoryBudget::unbounded()).unwrap();
        for e in ar.drain() {
            let d = DiscountedFusion::for_delta(&algo, curve, e.delta);
            let v = ModelUpdateView {
                party: e.party,
                count: e.count,
                round: e.trained_version,
                data: Cow::Borrowed(&e.data[..]),
            };
            f.fold_view(&d, &v).unwrap();
        }
        f.finish(&algo).unwrap()
    });
    assert_eq!(got, want, "zero-discount async must be bit-identical to sync");
    println!("  n={n} len={len}: sync {sync_s:.4}s, async {async_s:.4}s — identical bits");
    out.meta("parity_n", Json::num(n as f64));
    out.meta("parity_bit_identical", Json::Bool(true));
    out.round(RoundRecord {
        round: 0,
        label: "parity/sync-fold".into(),
        latency_s: sync_s,
        ..Default::default()
    });
    out.round(RoundRecord {
        round: 0,
        label: "parity/async-drain".into(),
        latency_s: async_s,
        ..Default::default()
    });

    // ---- part 3: the seeded heavy-tail scenario over real TCP ------------
    println!("\n[scenario] heavy-tail fleet: async publishes off the body, sync waits on the tail");
    let cfg = (0..256u64)
        .map(|i| StragglerConfig { seed: 42 + i, ..StragglerConfig::default() })
        .find(|c| {
            let s = straggler_schedules(c);
            let body = s.iter().filter(|c| !c.drops_out && !c.straggler).count();
            let tail = s.iter().filter(|c| !c.drops_out && c.straggler).count();
            let quorum = ((c.clients as f64) * c.quorum_frac).ceil() as usize;
            body >= c.buffer && tail >= 1 && body < quorum && body + tail >= quorum
        })
        .expect("a heavy-tail seed exists in the sweep");
    let report = run_async_scenario(&cfg);
    let first = report.first_publish_ms.expect("≥ K survivors");
    let seal = report.sync_quorum_ms.expect("quorum survivors");
    assert!(first < seal, "async publishes at {first}ms; sync would seal at {seal}ms");
    assert_eq!(report.drained, report.admitted as u64, "exactly-once conservation");
    let mut t = fmt::Table::new(&["clock", "virtual ms"]);
    t.row(&["async first publish (K-th arrival)".into(), first.to_string()]);
    t.row(&["sync quorum seal (quorum-th arrival)".into(), seal.to_string()]);
    t.print();
    println!(
        "  publishes={} folded={} max_delta={} wall={:.3}s digest={:016x}",
        report.publishes.len(),
        report.drained,
        report.publishes.iter().map(|p| p.max_delta).max().unwrap_or(0),
        report.wall_s,
        report.digest()
    );
    out.meta("scenario_seed", Json::num(cfg.seed as f64));
    out.meta("first_publish_ms", Json::num(first as f64));
    out.meta("sync_quorum_ms", Json::num(seal as f64));
    out.meta("publishes", Json::num(report.publishes.len() as f64));
    out.round(RoundRecord {
        round: report.final_version,
        label: format!("scenario(seed={},publishes={})", cfg.seed, report.publishes.len()),
        latency_s: report.wall_s,
        ..Default::default()
    });

    let path = out.write().expect("write BENCH_fig_async_vs_sync.json");
    println!("\n[json] {}", path.display());
    println!("\nfigA OK — async takes the latency regime, sync keeps the cost regime, δ=0 is exact");
}
