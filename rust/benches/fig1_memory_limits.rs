//! Fig 1 — single-node aggregation under different memory capacities
//! (FedAvg and IterAvg, 4.6 MB updates).
//!
//! Paper anchors: at 170 GB the node supports 18 900 parties (FedAvg) /
//! 32 400 (IterAvg) before OOM; smaller capacities hit the wall sooner;
//! time grows linearly with parties until the wall.
//!
//! Measured part: real ingest-until-OOM with the budgeted round state and
//! scaled (1:100) updates, plus real serial-fusion timings.
//! Virtual part: paper geometry through the calibrated cost model.

use elastiagg::bench::{gen_updates, paper_cluster, time};
use elastiagg::cluster::{EngineKind, FEDAVG_DUP_FACTOR, ITERAVG_DUP_FACTOR};
use elastiagg::coordinator::{RoundState, WorkloadClass};
use elastiagg::engine::{AggregationEngine, SerialEngine};
use elastiagg::fusion::{FedAvg, FusionAlgorithm, IterAvg};
use elastiagg::memsim::MemoryBudget;
use elastiagg::metrics::Breakdown;
use elastiagg::util::fmt;

const UPDATE_46MB: u64 = (4.6 * 1024.0 * 1024.0) as u64;

fn measured_oom_ceiling(budget_bytes: u64, update_len: usize, dup: f64) -> usize {
    // Real budgeted ingest: the round state charges every update; the dup
    // factor models the fusion working set on top (reserved up front).
    let budget = MemoryBudget::new(budget_bytes);
    let working = ((dup - 1.0) * budget_bytes as f64 / dup) as u64;
    let _working = budget.reserve(working).unwrap();
    let st = RoundState::new(0, WorkloadClass::Small, budget.clone());
    let mut n = 0usize;
    loop {
        let u = elastiagg::tensorstore::ModelUpdate::new(n as u64, 1.0, 0, vec![0.0; update_len]);
        match st.ingest(u) {
            Ok(_) => n += 1,
            Err(_) => break,
        }
        if n > 500_000 {
            break;
        }
    }
    n
}

fn main() {
    let vc = paper_cluster();
    elastiagg::bench::banner(
        "Fig 1 — single-node aggregation under memory caps (4.6 MB updates)",
        "OOM at 18 900 parties (FedAvg) / 32 400 (IterAvg) @ 170 GB; fewer at lower caps",
    );

    // ---- virtual: paper geometry --------------------------------------
    println!("\n[paper-scale, virtual] party ceilings by memory capacity:");
    let mut t = fmt::Table::new(&["memory", "FedAvg ceiling", "IterAvg ceiling"]);
    for gb in [32u64, 64, 128, 170] {
        let mem = gb << 30;
        t.row(&[
            format!("{gb} GB"),
            vc.single_node_capacity(mem, UPDATE_46MB, FEDAVG_DUP_FACTOR).to_string(),
            vc.single_node_capacity(mem, UPDATE_46MB, ITERAVG_DUP_FACTOR).to_string(),
        ]);
    }
    t.print();
    let fed170 = vc.single_node_capacity(170 << 30, UPDATE_46MB, FEDAVG_DUP_FACTOR);
    let iter170 = vc.single_node_capacity(170 << 30, UPDATE_46MB, ITERAVG_DUP_FACTOR);
    println!("paper anchors: FedAvg 18 900 (model: {fed170}), IterAvg 32 400 (model: {iter170})");
    assert!((15_000..23_000).contains(&fed170));
    assert!((28_000..37_000).contains(&iter170));

    println!("\n[paper-scale, virtual] FedAvg wall-clock vs parties (64 cores, serial numpy):");
    let mut t = fmt::Table::new(&["parties", "time @170GB", "status @32GB"]);
    let cap32 = vc.single_node_capacity(32 << 30, UPDATE_46MB, FEDAVG_DUP_FACTOR);
    for n in [1000usize, 4000, 8000, 16000, 18000] {
        let secs = vc.single_node_time(UPDATE_46MB, n, 64, EngineKind::Serial, 1.0);
        t.row(&[
            n.to_string(),
            fmt::secs(secs),
            if n <= cap32 { "ok".into() } else { "OOM".into() },
        ]);
    }
    t.print();

    // ---- measured: real budgeted ingest at 1:100 scale ------------------
    println!("\n[measured, 1:100 scale] real ingest-until-OOM (46 KB updates):");
    let update_len = (UPDATE_46MB / 100 / 4) as usize;
    let mut t = fmt::Table::new(&["budget", "FedAvg ceiling", "IterAvg ceiling", "expected ratio 170GB:paper"]);
    for mb in [64u64, 128, 256] {
        let budget = mb << 20;
        let fed = measured_oom_ceiling(budget, update_len, FEDAVG_DUP_FACTOR);
        let iter = measured_oom_ceiling(budget, update_len, ITERAVG_DUP_FACTOR);
        assert!(iter > fed, "iteravg must outlast fedavg: {iter} !> {fed}");
        t.row(&[
            format!("{mb} MB"),
            fed.to_string(),
            iter.to_string(),
            format!("{:.2}", fed as f64 / (budget as f64 / (UPDATE_46MB as f64 / 100.0 * FEDAVG_DUP_FACTOR))),
        ]);
    }
    t.print();

    // ---- measured: fusion time grows linearly with parties --------------
    println!("\n[measured, 1:100 scale] serial fusion time vs parties:");
    let mut t = fmt::Table::new(&["parties", "FedAvg", "IterAvg"]);
    let mut prev = 0.0;
    for n in [64usize, 128, 256, 512] {
        let updates = gen_updates(n as u64, n, update_len);
        let e = SerialEngine::unbounded();
        let mut row = vec![n.to_string()];
        for algo in [&FedAvg as &dyn FusionAlgorithm, &IterAvg] {
            let mut bd = Breakdown::new();
            let (r, secs) = time(|| e.aggregate(algo, &updates, &mut bd));
            r.unwrap();
            row.push(fmt::secs(secs));
            if algo.name() == "fedavg" {
                prev = secs;
            }
        }
        let _ = prev;
        t.row(&row);
    }
    t.print();
    println!("\nfig1 OK — memory is the scalability wall; IterAvg ceiling > FedAvg ceiling");
}
