//! Table I — the benchmark model zoo, with the scaled sizes the real
//! (one-box) runs use and per-model fusion cost sanity.

use elastiagg::bench::{gen_updates, time};
use elastiagg::config::ModelZoo;
use elastiagg::engine::{AggregationEngine, SerialEngine};
use elastiagg::fusion::FedAvg;
use elastiagg::metrics::Breakdown;
use elastiagg::util::fmt;

fn main() {
    elastiagg::bench::banner("Table I — model specifications", "CNN4.6 … CNN956 + Resnet50 + VGG16");
    let scale = 0.01;
    let mut t = fmt::Table::new(&[
        "model", "paper size", "params", "scaled size (1:100)", "fuse 8 updates (measured)",
    ]);
    for m in ModelZoo::all() {
        let len = m.scaled_params(scale);
        let updates = gen_updates(7, 8, len);
        let e = SerialEngine::unbounded();
        let mut bd = Breakdown::new();
        let (r, secs) = time(|| e.aggregate(&FedAvg, &updates, &mut bd));
        r.unwrap();
        t.row(&[
            m.name.to_string(),
            fmt::bytes(m.size_bytes),
            format!("{:.1} M", m.param_count() as f64 / 1e6),
            fmt::bytes(m.scaled_bytes(scale)),
            fmt::secs(secs),
        ]);
    }
    t.print();
    println!("\nfusion cost is linear in update bytes — the property that makes the");
    println!("1:100 scaled measurements + calibrated extrapolation sound (DESIGN.md).");
}
