//! Ablations beyond the paper's figures — the design choices DESIGN.md
//! calls out:
//!
//! A. partition-cache on/off (the paper's "caching is not efficient for
//!    large models" policy);
//! B. partition-count sweep (Spark's adaptive executor sizing, §IV-B1);
//! C. executor spin-up cost (§III-D3: 10 containers < 30 s);
//! D. XLA stack height K=16 vs K=64;
//! E. Byzantine-robust fusion cost (the §V future-work algorithms);
//! F. Algorithm-1 monitor threshold vs timeout behaviour.

use std::time::Duration;

use elastiagg::bench::{gen_updates, paper_cluster, time, BenchDfs};
use elastiagg::dfs::{DfsClient, Monitor};
use elastiagg::engine::{AggregationEngine, ParallelEngine, XlaEngine};
use elastiagg::fusion::{CoordMedian, FedAvg, FusionAlgorithm, Krum, Zeno};
use elastiagg::mapreduce::{scheduler::JobConfig, ExecutorConfig, SparkContext};
use elastiagg::metrics::Breakdown;
use elastiagg::runtime::Runtime;
use elastiagg::util::fmt;

fn main() {
    ablation_cache();
    ablation_partitions();
    ablation_startup();
    ablation_stack_k();
    ablation_robust();
    ablation_monitor();
    println!("\nablations OK");
}

fn ablation_cache() {
    elastiagg::bench::banner("Ablation A — partition cache on/off", "cache helps small models");
    let mut t = fmt::Table::new(&["model bytes", "parties", "cached", "uncached", "speedup"]);
    for (len, n) in [(12_000usize, 400usize), (1_200_000, 24)] {
        let env = BenchDfs::new(3, 2);
        env.seed_round(0, n, len, 5);
        let sc = SparkContext::start(
            env.dfs.clone(),
            ExecutorConfig { executors: 2, cores_per_executor: 2, ..Default::default() },
        );
        let mut bd = Breakdown::new();
        let (_, cached) = time(|| {
            sc.aggregate(&FedAvg, "/rounds/0/updates/",
                         &JobConfig { cache: true, ..Default::default() }, &mut bd).unwrap()
        });
        let (_, uncached) = time(|| {
            sc.aggregate(&FedAvg, "/rounds/0/updates/",
                         &JobConfig { cache: false, ..Default::default() }, &mut bd).unwrap()
        });
        t.row(&[
            fmt::bytes(len as u64 * 4),
            n.to_string(),
            fmt::secs(cached),
            fmt::secs(uncached),
            format!("{:.2}x", uncached / cached),
        ]);
    }
    t.print();
}

fn ablation_partitions() {
    elastiagg::bench::banner("Ablation B — partition-count sweep", "too few starves cores; too many pays task overhead");
    let env = BenchDfs::new(3, 2);
    env.seed_round(0, 400, 12_000, 6);
    let sc = SparkContext::start(
        env.dfs.clone(),
        ExecutorConfig { executors: 2, cores_per_executor: 2, ..Default::default() },
    );
    let mut t = fmt::Table::new(&["partitions", "total"]);
    for parts in [1usize, 4, 8, 32, 128] {
        let mut bd = Breakdown::new();
        let (_, secs) = time(|| {
            sc.aggregate(&FedAvg, "/rounds/0/updates/",
                         &JobConfig { cache: false, partitions: Some(parts), ..Default::default() },
                         &mut bd).unwrap()
        });
        t.row(&[parts.to_string(), fmt::secs(secs)]);
    }
    t.print();
}

fn ablation_startup() {
    elastiagg::bench::banner("Ablation C — executor spin-up (seamless-transition cost)",
                             "paper: 10 x (30 GB, 3 cores) containers in < 30 s");
    let vc = paper_cluster();
    let mut t = fmt::Table::new(&["executors", "virtual spin-up", "measured spin-up (50 ms/container sim)"]);
    for execs in [2usize, 5, 10] {
        let (_pool, secs) = time(|| {
            elastiagg::mapreduce::ExecutorPool::start(ExecutorConfig {
                executors: execs,
                cores_per_executor: 1,
                startup: Duration::from_millis(50 * execs as u64),
                ..Default::default()
            })
        });
        t.row(&[
            execs.to_string(),
            fmt::secs(vc.executor_startup(execs)),
            fmt::secs(secs),
        ]);
    }
    t.print();
    assert!(vc.executor_startup(10) < 30.0);
}

fn ablation_stack_k() {
    elastiagg::bench::banner("Ablation D — XLA fusion stack height K", "bigger K amortises exec overhead for many parties");
    let Some(rtm) = Runtime::load_default().ok() else {
        println!("(artifacts unavailable — skipped)");
        return;
    };
    let updates = gen_updates(9, 256, 70_000);
    let mut t = fmt::Table::new(&["K", "time (256 parties x 280 KB)"]);
    for k in [16usize, 64] {
        let e = XlaEngine::new(rtm.clone(), k).unwrap();
        let mut bd = Breakdown::new();
        let (r, secs) = time(|| e.aggregate(&FedAvg, &updates, &mut bd));
        r.unwrap();
        t.row(&[k.to_string(), fmt::secs(secs)]);
    }
    t.print();
}

fn ablation_robust() {
    elastiagg::bench::banner("Ablation E — Byzantine-robust fusion cost (§V future work)",
                             "median/krum/zeno are far costlier than averaging -> distributed matters more");
    let updates = gen_updates(13, 24, 100_000);
    let e = ParallelEngine::new(4);
    let mut t = fmt::Table::new(&["algorithm", "time (24 parties x 400 KB)", "vs fedavg"]);
    let mut base = 0.0;
    for algo in [
        Box::new(FedAvg) as Box<dyn FusionAlgorithm>,
        Box::new(CoordMedian),
        Box::new(Zeno { trim_b: 2 }),
        Box::new(Krum { byzantine_f: 2 }),
    ] {
        let mut bd = Breakdown::new();
        let (r, secs) = time(|| e.aggregate(algo.as_ref(), &updates, &mut bd));
        r.unwrap();
        if algo.name() == "fedavg" {
            base = secs;
        }
        t.row(&[
            algo.name().to_string(),
            fmt::secs(secs),
            format!("{:.1}x", secs / base),
        ]);
    }
    t.print();
}

fn ablation_monitor() {
    elastiagg::bench::banner("Ablation F — Algorithm-1 monitor threshold vs timeout",
                             "timeout bounds straggler wait; threshold controls completeness");
    let env = BenchDfs::new(1, 1);
    env.seed_round(0, 30, 1000, 7);
    let monitor = Monitor::new(env.dfs.namenode().clone());
    let mut t = fmt::Table::new(&["threshold", "timeout", "outcome", "count", "waited"]);
    for (th, to_ms) in [(30usize, 1000u64), (40, 80), (10, 1000)] {
        let (out, secs) = time(|| {
            monitor.watch(&DfsClient::round_prefix(0), th, Duration::from_millis(to_ms))
        });
        t.row(&[
            th.to_string(),
            format!("{to_ms} ms"),
            if out.is_ready() { "ready".into() } else { "timeout".into() },
            out.count().to_string(),
            fmt::secs(secs),
        ]);
    }
    t.print();
}
