//! Fig R (beyond the paper's numbered figures) — Byzantine-robust trimmed
//! aggregation through the hierarchy.
//!
//! The paper's aggregation service assumes every shipped update is honest;
//! this bench pins what the robust layer buys when that assumption breaks,
//! and what it costs when it holds:
//!
//! * **[sweep]** — coordinate-wise trimmed mean (trim 0.2 → k = 4 of
//!   n = 20) against a `Scale(500)` poisoning attack, attacker count `a`
//!   swept 0..=9.  Below the per-side breakdown point (`a ≤ k`) the error
//!   vs the honest-only trimmed reference stays at the honest-data scale —
//!   INDEPENDENT of the 500× attack magnitude; past it (`a = 9`, where one
//!   side always carries ≥ 5 poisoned values) the leak is unbounded and
//!   the error degrades by an order of magnitude.  Every sweep point runs
//!   BOTH flat-exact and the 2-relay extremes-sketch path (cap 8 ≥ k: the
//!   exact regime) and the two must agree to merge tolerance — robustness
//!   survives the tier division.
//! * **[planner]** — the trimmed mean's hierarchical candidate is
//!   enumerated AND priced strictly above FedAvg's on latency and dollars
//!   (every forwarded partial hauls `2·cap` sketch lanes), but below the
//!   naive `(1 + partial_overhead)` ceiling: only the root leg and the
//!   relay→root wire pay the premium.
//! * **[measured]** — a real 2-tier round over localhost TCP (3 relays ×
//!   6 clients, a 2-party colluding cohort behind one relay) fuses within
//!   merge tolerance of the flat exact trimmed mean and beats the naive
//!   unweighted mean by ≥ 2× on distance to the honest-only reference.
//!
//! Machine-readable output: `BENCH_fig_robust_hierarchy.json`.

use elastiagg::bench::{BenchJson, RoundRecord};
use elastiagg::cluster::{CostModel, VirtualCluster};
use elastiagg::coordinator::{RoundOutcome, WorkloadClassifier};
use elastiagg::engine::StreamingFold;
use elastiagg::fusion::{exact_trimmed_mean, FedAvg, FusionAlgorithm, TrimmedMean};
use elastiagg::memsim::MemoryBudget;
use elastiagg::planner::{DispatchPlanner, DispatchPolicy, PlanKind, PlannerConfig, PricingModel};
use elastiagg::sim::byzantine::{byz_update, fleet_updates};
use elastiagg::sim::{run_byzantine_tier_scenario, Attack, ByzTierConfig};
use elastiagg::tensorstore::{ModelUpdate, PartialAggregate, PartialAggregateView};
use elastiagg::util::json::Json;
use elastiagg::util::prop::all_close;

const SEED: u64 = 0xB12A;
const N: usize = 20;
const LEN: usize = 1024;
const TRIM: f32 = 0.2;
const CAP: usize = 8;
const ATTACK: Attack = Attack::Scale(500.0);
const UPDATE_46MB: u64 = 46 << 20;
const EDGES: usize = 4;

/// The sweep fleet at attacker count `a`: parties `0..a` ship poison.
fn sweep_fleet(a: usize) -> Vec<ModelUpdate> {
    (0..N as u64)
        .map(|p| byz_update(SEED, p, 0, LEN, (p < a as u64).then_some(ATTACK)))
        .collect()
}

/// Fold `us` through 2 relays (extremes-sketch partials over the real wire
/// encoding) into a root trimmed mean.
fn tier_trimmed(algo: &TrimmedMean, us: &[ModelUpdate]) -> Vec<f32> {
    let relay = |chunk: &[ModelUpdate], edge: u64| {
        let mut f = StreamingFold::new(algo, 1, MemoryBudget::unbounded()).unwrap();
        for u in chunk {
            f.fold(algo, u).unwrap();
        }
        let acc = f.into_accumulator().unwrap();
        let parties: Vec<u64> = chunk.iter().map(|u| u.party).collect();
        PartialAggregate::new(edge, 0, acc.wtot, parties, acc.sum).with_sketch(acc.sketch)
    };
    let (pa, pb) = (relay(&us[..N / 2], 0), relay(&us[N / 2..], 1));
    let mut root = StreamingFold::new(algo, 1, MemoryBudget::unbounded()).unwrap();
    for p in [pa, pb] {
        let wire = p.encode();
        let v = PartialAggregateView::decode(&wire).unwrap();
        root.fold_partial_sketch(algo, &v.sum, v.wtot, v.parties.len() as u64, v.sketch.as_deref())
            .unwrap();
    }
    root.finish(algo).unwrap()
}

fn rms(a: &[f32], b: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        s += ((x - y) as f64).powi(2);
    }
    (s / a.len() as f64).sqrt()
}

fn main() {
    elastiagg::bench::banner(
        "Fig R — Byzantine-robust trimmed aggregation through the hierarchy",
        "bounded below the breakdown point, priced by the planner, measured over TCP",
    );
    let mut bench_json = BenchJson::new("fig_robust_hierarchy");
    bench_json.meta("clients", Json::num(N as f64));
    bench_json.meta("trim_fraction", Json::num(TRIM as f64));
    bench_json.meta("sketch_cap", Json::num(CAP as f64));

    // ---- part 1: attack-fraction sweep, flat vs 2-tier sketch path -----
    let algo = TrimmedMean::new(TRIM, CAP);
    let k = algo.k_for(N as u64);
    assert_eq!(k, 4, "n=20 at trim 0.2 trims 4 per side");
    let honest: Vec<ModelUpdate> = sweep_fleet(0);
    let honest_refs: Vec<&ModelUpdate> = honest.iter().collect();
    let reference = exact_trimmed_mean(&honest_refs, TRIM);

    let mut errs = Vec::new();
    println!("\n[sweep] n={N}, len={LEN}, trim {TRIM} (k={k}), attack {ATTACK:?}:");
    for a in 0..=9usize {
        let fleet = sweep_fleet(a);
        let refs: Vec<&ModelUpdate> = fleet.iter().collect();
        let flat = exact_trimmed_mean(&refs, TRIM);
        let tier = tier_trimmed(&algo, &fleet);
        // cap 8 ≥ k = 4: the sketch path is exact — tiers change nothing
        // beyond f32 re-association (the poison's ±500σ terms cancel in
        // the sum-then-subtract path, so absolute noise is ~1e-4).
        all_close(&tier, &flat, 1e-3, 1e-4)
            .unwrap_or_else(|e| panic!("a={a}: flat/2-tier trimmed parity: {e}"));
        let err = rms(&tier, &reference);
        println!("  a={a}: rms error vs honest-only reference = {err:.5}");
        bench_json.round(RoundRecord {
            round: a as u32,
            label: format!("sweep:attackers={a}"),
            ..Default::default()
        });
        errs.push(err);
    }
    bench_json.meta("sweep_rms_err", Json::Arr(errs.iter().map(|&e| Json::num(e)).collect()));

    let bounded_max = errs[..=k].iter().cloned().fold(0.0f64, f64::max);
    assert!(
        bounded_max < 0.1,
        "a ≤ k: the error must stay at the honest-data scale (σ = 0.1), got {bounded_max}"
    );
    assert!(
        errs[9] > 0.5 && errs[9] > 4.0 * bounded_max,
        "a = 9 (one side always leaks ≥ 1 poisoned value past k = 4): the error \
         must degrade, got {} vs bounded max {bounded_max}",
        errs[9]
    );
    println!(
        "  bounded regime (a ≤ {k}) max {bounded_max:.5}; past breakdown (a=9) {:.5}",
        errs[9]
    );

    // ---- part 2: the planner prices the sketch premium -----------------
    // Same datacenter-grade classifier as the planner's own tests: the
    // trimmed partial's (1 + 2·cap)× working set must stay feasible so the
    // contest is about PRICE, not admission.
    let planner = DispatchPlanner::new(
        WorkloadClassifier::new(170 << 30, 1.1),
        VirtualCluster::paper(CostModel::nominal()),
        PricingModel::default(),
        PlannerConfig {
            policy: DispatchPolicy::MinLatency,
            max_executors: 10,
            cores_per_executor: 3,
            node_cores: 64,
            ingest_lanes: 64,
            edges: EDGES,
            xla_available: false,
            feedback_beta: 0.3,
            ..PlannerConfig::default()
        },
    );
    let tm = TrimmedMean::new(TRIM, CAP);
    let hier = |plan: &elastiagg::planner::RoundPlan| {
        plan.candidates
            .iter()
            .find(|c| c.kind == PlanKind::Hierarchical { edges: EDGES })
            .copied()
            .expect("hierarchical candidate enumerated")
    };
    let robust = hier(&planner.plan(UPDATE_46MB, 30_000, &tm, 0));
    let plain = hier(&planner.plan(UPDATE_46MB, 30_000, &FedAvg, 0));
    assert!(
        robust.cost.latency_s > plain.cost.latency_s && robust.cost.usd > plain.cost.usd,
        "the sketch premium must price the robust tree dearer on both axes: \
         {:?} vs {:?}",
        robust.cost,
        plain.cost
    );
    assert!(
        robust.cost.latency_s < plain.cost.latency_s * (1.0 + tm.partial_overhead()),
        "only the root leg and relay→root wire pay the 2·cap factor — the \
         whole round must not: {:?} vs {:?}",
        robust.cost,
        plain.cost
    );
    println!(
        "\n[planner] Hierarchical(e={EDGES}) at 46 MB × 30k parties: \
         FedAvg {:.2}s / ${:.4}, TrimmedMean(cap {CAP}) {:.2}s / ${:.4}",
        plain.cost.latency_s,
        plain.cost.usd,
        robust.cost.latency_s,
        robust.cost.usd
    );
    bench_json.round(RoundRecord {
        round: 0,
        label: "planner:hierarchical:fedavg".into(),
        predicted_s: plain.cost.latency_s,
        predicted_usd: plain.cost.usd,
        ..Default::default()
    });
    bench_json.round(RoundRecord {
        round: 0,
        label: "planner:hierarchical:trimmed".into(),
        predicted_s: robust.cost.latency_s,
        predicted_usd: robust.cost.usd,
        ..Default::default()
    });

    // ---- part 3: measured 2-tier robust round over real TCP ------------
    let cfg = ByzTierConfig::default();
    let fleet = fleet_updates(&cfg);
    let report = run_byzantine_tier_scenario(&cfg);
    assert_eq!(report.outcome, RoundOutcome::Complete);
    assert_eq!(report.folded, cfg.edges * cfg.clients_per_edge);

    let refs: Vec<&ModelUpdate> = fleet.iter().collect();
    let flat_exact = exact_trimmed_mean(&refs, cfg.trim);
    all_close(&report.fused, &flat_exact, 1e-3, 1e-4)
        .expect("the TCP tier round matches the flat exact trimmed mean");

    // distance to the honest-only reference: trimmed beats the naive mean
    let honest_tier: Vec<ModelUpdate> = (0..(cfg.edges * cfg.clients_per_edge) as u64)
        .map(|p| byz_update(cfg.seed, p, 0, cfg.update_len, None))
        .collect();
    let honest_tier_refs: Vec<&ModelUpdate> = honest_tier.iter().collect();
    let tier_reference = exact_trimmed_mean(&honest_tier_refs, cfg.trim);
    let naive: Vec<f32> = (0..cfg.update_len)
        .map(|c| fleet.iter().map(|u| u.data[c]).sum::<f32>() / fleet.len() as f32)
        .collect();
    let (robust_err, naive_err) =
        (rms(&report.fused, &tier_reference), rms(&naive, &tier_reference));
    assert!(
        robust_err < 0.5 * naive_err,
        "the trimmed tier must at least halve the naive mean's poisoning error: \
         {robust_err} vs {naive_err}"
    );
    println!(
        "\n[measured] {} clients through {} relays ({} colluders): round {:.2}s, \
         rms vs honest-only reference {robust_err:.5} (naive mean {naive_err:.5})",
        report.folded,
        cfg.edges,
        report.colluders,
        report.round_s
    );
    bench_json.meta("measured_robust_rms", Json::num(robust_err));
    bench_json.meta("measured_naive_rms", Json::num(naive_err));
    bench_json.round(RoundRecord {
        round: 0,
        label: "measured:tier-trimmed".into(),
        latency_s: report.round_s,
        ..Default::default()
    });

    match bench_json.write() {
        Ok(p) => println!("machine-readable log: {}", p.display()),
        Err(e) => println!("bench json not written: {e}"),
    }
    println!("\nfigR OK — the trimmed mean survives the tier division and the planner bills it");
}
