//! Fig 14 — Dask vs PySpark for FedAvg on ResNet50.
//!
//! Paper: "Dask is unable to compete with Spark in terms of efficiency as
//! it spends more time in I/O and conversion to the native Bag type."
//! The bag engine reproduces Dask's mechanism (read-all pass, then a
//! convert-all pass, no partition caching or streamed accumulate); the
//! phase breakdown makes the difference visible.

use elastiagg::bag::BagContext;
use elastiagg::bench::{time, BenchDfs};
use elastiagg::config::ModelZoo;
use elastiagg::fusion::FedAvg;
use elastiagg::mapreduce::{scheduler::JobConfig, ExecutorConfig, SparkContext};
use elastiagg::metrics::Breakdown;
use elastiagg::util::fmt;
use elastiagg::util::prop::all_close;

fn main() {
    elastiagg::bench::banner(
        "Fig 14 — Dask(bag) vs Spark(mapreduce), FedAvg, ResNet50",
        "bag loses: extra I/O + native-type conversion pass",
    );
    let m = ModelZoo::get("Resnet50").unwrap();
    let len = m.scaled_params(0.01);

    println!("\n[measured, 1:100 scale, 4 workers each]:");
    let mut t = fmt::Table::new(&[
        "parties", "spark total", "spark read+sum/reduce", "bag total", "bag read/convert/fold", "bag/spark",
    ]);
    for n in [60usize, 120, 240, 480] {
        let env = BenchDfs::new(3, 2);
        env.seed_round(0, n, len, 41);

        let sc = SparkContext::start(
            env.dfs.clone(),
            ExecutorConfig { executors: 2, cores_per_executor: 2, ..Default::default() },
        );
        let mut sbd = Breakdown::new();
        let ((spark_out, _), spark_total) = time(|| {
            sc.aggregate(&FedAvg, "/rounds/0/updates/", &JobConfig::default(), &mut sbd).unwrap()
        });

        let bag = BagContext::new(env.dfs.clone(), 4);
        let mut bbd = Breakdown::new();
        let (bag_out, bag_total) =
            time(|| bag.aggregate(&FedAvg, "/rounds/0/updates/", &mut bbd).unwrap());

        // both engines must agree bit-for-bit on the math
        all_close(&spark_out, &bag_out, 1e-4, 1e-5).unwrap();

        t.row(&[
            n.to_string(),
            fmt::secs(spark_total),
            format!(
                "{}/{}",
                fmt::secs(sbd.get("read_partition") + sbd.get("sum")),
                fmt::secs(sbd.get("reduce"))
            ),
            fmt::secs(bag_total),
            format!(
                "{}/{}/{}",
                fmt::secs(bbd.get("read")),
                fmt::secs(bbd.get("convert")),
                fmt::secs(bbd.get("fold"))
            ),
            format!("{:.2}x", bag_total / spark_total),
        ]);
    }
    t.print();
    println!("\nthe bag engine's separate convert pass (absent from the spark path, which");
    println!("streams decode into the fold) is the paper's measured Dask penalty.");
    println!("\nfig14 OK");
}
