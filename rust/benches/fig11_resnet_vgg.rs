//! Fig 11 — distributed aggregation with ResNet50 and VGG16 (both
//! algorithms) at 3× the single-node capacity.

use elastiagg::bench::{paper_cluster, time, BenchDfs};
use elastiagg::cluster::{FEDAVG_DUP_FACTOR, ITERAVG_DUP_FACTOR};
use elastiagg::config::ModelZoo;
use elastiagg::fusion::{FedAvg, FusionAlgorithm, IterAvg};
use elastiagg::mapreduce::{scheduler::JobConfig, ExecutorConfig, SparkContext};
use elastiagg::metrics::Breakdown;
use elastiagg::util::fmt;

fn main() {
    let vc = paper_cluster();
    elastiagg::bench::banner(
        "Fig 11 — ResNet50 + VGG16 on the distributed path (3x capacity)",
        "3x party scalability for both real-architecture models",
    );

    println!("\n[paper-scale, virtual]:");
    let mut t = fmt::Table::new(&["model", "algo", "1-node cap", "3x parties", "total time"]);
    for name in ["Resnet50", "VGG16"] {
        let m = ModelZoo::get(name).unwrap();
        for (an, dup) in [("fedavg", FEDAVG_DUP_FACTOR), ("iteravg", ITERAVG_DUP_FACTOR)] {
            let cap = vc.single_node_capacity(170 << 30, m.size_bytes, dup);
            let n = cap * 3;
            let bd = vc.distributed_breakdown(m.size_bytes, n, m.size_bytes < (64 << 20));
            t.row(&[
                m.name.to_string(),
                an.to_string(),
                cap.to_string(),
                n.to_string(),
                fmt::secs(bd.total()),
            ]);
        }
    }
    t.print();

    println!("\n[measured, 1:100 scale]:");
    let mut t = fmt::Table::new(&["model", "algo", "parties", "read+sum", "reduce", "total"]);
    for (name, n) in [("Resnet50", 180usize), ("VGG16", 36)] {
        let m = ModelZoo::get(name).unwrap();
        let len = m.scaled_params(0.01);
        let env = BenchDfs::new(3, 2);
        env.seed_round(0, n, len, 23);
        let sc = SparkContext::start(
            env.dfs.clone(),
            ExecutorConfig { executors: 2, cores_per_executor: 2, ..Default::default() },
        );
        for (an, algo) in [("fedavg", &FedAvg as &dyn FusionAlgorithm), ("iteravg", &IterAvg)] {
            let mut bd = Breakdown::new();
            let (_, total) = time(|| {
                sc.aggregate(algo, "/rounds/0/updates/", &JobConfig::default(), &mut bd).unwrap()
            });
            t.row(&[
                m.name.to_string(),
                an.to_string(),
                n.to_string(),
                fmt::secs(bd.get("read_partition") + bd.get("sum")),
                fmt::secs(bd.get("reduce")),
                fmt::secs(total),
            ]);
        }
    }
    t.print();
    println!("\nfig11 OK");
}
