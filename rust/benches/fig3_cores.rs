//! Fig 3 — the NumPy-style baseline ignores extra CPU cores.
//!
//! Paper: "there is very little difference in execution time with respect
//! to the number of cores" for the IBMFL/NumPy fusion path.  The serial
//! engine is that baseline; the parallel engine is the counter-example
//! the paper's design goal 4 demands.

use elastiagg::bench::{gen_updates, paper_cluster, time};
use elastiagg::cluster::EngineKind;
use elastiagg::engine::{AggregationEngine, ParallelEngine, SerialEngine};
use elastiagg::fusion::FedAvg;
use elastiagg::metrics::Breakdown;
use elastiagg::util::fmt;

const UPDATE_46MB: u64 = (4.6 * 1024.0 * 1024.0) as u64;

fn main() {
    let vc = paper_cluster();
    elastiagg::bench::banner(
        "Fig 3 — FedAvg under different core counts (170 GB constant memory)",
        "NumPy baseline flat across 8..64 cores; a parallel engine is not",
    );

    println!("\n[paper-scale, virtual] 8000 parties x 4.6 MB:");
    let mut t = fmt::Table::new(&["cores", "serial (numpy analog)", "parallel (numba analog)"]);
    let mut serial_times = Vec::new();
    for cores in [8usize, 16, 32, 64] {
        let s = vc.single_node_time(UPDATE_46MB, 8000, cores, EngineKind::Serial, 1.0);
        let p = vc.single_node_time(UPDATE_46MB, 8000, cores, EngineKind::Parallel, 1.0);
        serial_times.push(s);
        t.row(&[cores.to_string(), fmt::secs(s), fmt::secs(p)]);
    }
    t.print();
    // the Fig-3 claim: serial is EXACTLY flat in the model
    assert!(serial_times.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-9));

    println!("\n[measured, 1:100 scale] 256 parties x 46 KB (this box has 1 physical core —");
    println!(" real thread scaling is not observable here; structure check only):");
    let updates = gen_updates(5, 256, (UPDATE_46MB / 100 / 4) as usize);
    let mut t = fmt::Table::new(&["engine(threads)", "time"]);
    let mut bd = Breakdown::new();
    let e = SerialEngine::unbounded();
    let (r, s) = time(|| e.aggregate(&FedAvg, &updates, &mut bd));
    r.unwrap();
    t.row(&["serial".to_string(), fmt::secs(s)]);
    for threads in [1usize, 2, 4] {
        let e = ParallelEngine::new(threads);
        let (r, p) = time(|| e.aggregate(&FedAvg, &updates, &mut bd));
        r.unwrap();
        t.row(&[format!("parallel({threads})"), fmt::secs(p)]);
    }
    t.print();
    println!("\nfig3 OK — the baseline cannot use cores; the parallel engine is built to");
}
