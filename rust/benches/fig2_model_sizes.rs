//! Fig 2 — single-node aggregation with multiple model sizes at constant
//! memory (170 GB): bigger models support fewer parties and take longer.
//!
//! Paper anchor: "for the 956 MB model less than 150 clients can be
//! supported".

use elastiagg::bench::{gen_updates, paper_cluster, time};
use elastiagg::cluster::{EngineKind, FEDAVG_DUP_FACTOR, ITERAVG_DUP_FACTOR};
use elastiagg::config::ModelZoo;
use elastiagg::engine::{AggregationEngine, SerialEngine};
use elastiagg::fusion::{FedAvg, IterAvg};
use elastiagg::metrics::Breakdown;
use elastiagg::util::fmt;

fn main() {
    let vc = paper_cluster();
    elastiagg::bench::banner(
        "Fig 2 — single node, 170 GB, model-size ladder",
        "party capacity shrinks with model size; <150 clients @956 MB",
    );

    println!("\n[paper-scale, virtual] capacity + time at half-capacity load:");
    let mut t = fmt::Table::new(&[
        "model", "FedAvg cap", "IterAvg cap", "FedAvg t(cap/2)", "IterAvg t(cap/2)",
    ]);
    let mut prev_cap = usize::MAX;
    for m in ModelZoo::cnn_ladder() {
        let fed = vc.single_node_capacity(170 << 30, m.size_bytes, FEDAVG_DUP_FACTOR);
        let iter = vc.single_node_capacity(170 << 30, m.size_bytes, ITERAVG_DUP_FACTOR);
        assert!(fed < prev_cap, "capacity must shrink with size");
        prev_cap = fed;
        t.row(&[
            m.name.to_string(),
            fed.to_string(),
            iter.to_string(),
            fmt::secs(vc.single_node_time(m.size_bytes, fed / 2, 64, EngineKind::Serial, 1.0)),
            fmt::secs(vc.single_node_time(m.size_bytes, iter / 2, 64, EngineKind::Serial, 0.8)),
        ]);
    }
    t.print();
    let cap956 = vc.single_node_capacity(170 << 30, 956 << 20, FEDAVG_DUP_FACTOR);
    println!("paper anchor: <150 clients @956 MB (model: {cap956})");
    assert!(cap956 < 150, "{cap956}");

    println!("\n[measured, 1:100 scale] serial FedAvg/IterAvg, 64 parties per size:");
    let scale = 0.01;
    let mut t = fmt::Table::new(&["model", "scaled size", "FedAvg", "IterAvg"]);
    let mut prev = 0.0f64;
    for m in ModelZoo::cnn_ladder() {
        let len = m.scaled_params(scale);
        let updates = gen_updates(3, 64, len);
        let e = SerialEngine::unbounded();
        let mut bd = Breakdown::new();
        let (r, fed_s) = time(|| e.aggregate(&FedAvg, &updates, &mut bd));
        r.unwrap();
        let (r, iter_s) = time(|| e.aggregate(&IterAvg, &updates, &mut bd));
        r.unwrap();
        assert!(fed_s > prev * 0.3, "time should grow with size");
        prev = fed_s;
        t.row(&[
            m.name.to_string(),
            fmt::bytes(m.scaled_bytes(scale)),
            fmt::secs(fed_s),
            fmt::secs(iter_s),
        ]);
    }
    t.print();
    println!("\nfig2 OK — capacity and time both degrade with model size");
}
