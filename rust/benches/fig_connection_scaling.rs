//! Fig N (beyond the paper's numbered figures) — connection scaling after
//! retiring thread-per-connection.
//!
//! The paper's edge aggregator is priced for IoT fleets, but the repo's
//! original `NetServer` spent one OS thread per connected client: at the
//! fleet sizes the cost model covers, the socket layer OOMs on stacks
//! long before the fold runs out of budget.  The readiness reactor caps
//! the server at `1 + workers` OS threads regardless of connection count.
//! This bench pins that claim from three sides:
//!
//! * part 1 holds a sweep of REAL socket fleets (32 → 128 persistent
//!   connections) against a 4-worker reactor and reads the process's OS
//!   thread count from `/proc/self/status` at each point: the count must
//!   not grow with connections (and at 128 it must be far below one
//!   thread per client);
//! * part 2 runs a 10 000-virtual-client quorum round through the fleet
//!   harness (`elastiagg::sim::fleet`) — every survivor folded exactly
//!   once, OS thread count unchanged by fleet size;
//! * part 3 replays the SAME 64-client seeded scenario over the reactor
//!   and over the legacy thread-per-connection backend and requires
//!   bit-identical round digests: the backend swap changed how bytes
//!   reach the fold, provably not what the fold computes.

use std::sync::Arc;
use std::time::{Duration, Instant};

use elastiagg::bench::{BenchJson, RoundRecord};
use elastiagg::net::{Message, NetClient, NetServer, ReactorConfig};
use elastiagg::sim::{run_fleet, run_scenario_on, FleetConfig, ScenarioConfig};
use elastiagg::util::fmt;
use elastiagg::util::json::Json;

/// OS threads in this process, from `/proc/self/status` (`None` where
/// procfs is absent — the sweep still runs, the thread pins are skipped).
fn os_threads() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()
}

fn main() {
    elastiagg::bench::banner(
        "Fig N — connection scaling: readiness reactor vs thread-per-connection",
        "server threads bounded by the worker pool, not the fleet size",
    );

    let mut out = BenchJson::new("fig_connection_scaling");
    const WORKERS: usize = 4;
    out.meta("workers", Json::num(WORKERS as f64));

    // ---- part 1: OS threads vs live socket count -------------------------
    let mut handle = NetServer::serve_with(
        "127.0.0.1:0",
        Arc::new(|m: Message| m),
        ReactorConfig { workers: WORKERS },
    )
    .expect("reactor server");
    let addr = handle.addr().to_string();

    let mut t = fmt::Table::new(&["connections", "os threads", "sweep s"]);
    let mut thread_counts = Vec::new();
    for conns in [32usize, 128] {
        let t0 = Instant::now();
        let mut clients: Vec<NetClient> = (0..conns)
            .map(|_| NetClient::connect(&addr).expect("bench client"))
            .collect();
        // every connection live and served at once, one call each
        for (i, c) in clients.iter_mut().enumerate() {
            let m = c.call(&Message::Register { party: i as u64 }).expect("echo");
            assert!(matches!(m, Message::Register { .. }));
        }
        let threads = os_threads();
        let sweep_s = t0.elapsed().as_secs_f64();
        assert_eq!(handle.active_connections(), conns, "every socket tracked");
        drop(clients);
        // let the reactor reap the hangups before the next sweep point
        let drain = Instant::now() + Duration::from_secs(5);
        while handle.active_connections() > 0 && Instant::now() < drain {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(handle.active_connections(), 0, "clean hangups must drain");
        if let Some(n) = threads {
            thread_counts.push((conns, n));
        }
        t.row(&[
            conns.to_string(),
            threads.map_or_else(|| "n/a".into(), |n| n.to_string()),
            format!("{sweep_s:.3}"),
        ]);
        out.meta(&format!("threads_at_{conns}_conns"), Json::num(threads.unwrap_or(0) as f64));
        out.round(RoundRecord {
            round: conns as u32,
            label: format!("sockets(conns={conns})"),
            latency_s: sweep_s,
            ..Default::default()
        });
    }
    handle.stop();
    if let [(few, at_few), (many, at_many)] = thread_counts[..] {
        assert!(
            at_many <= at_few + 2,
            "OS threads grew with connections ({few} conns -> {at_few}, {many} -> {at_many})"
        );
        assert!(
            at_many < many as u64,
            "thread-per-connection shape is back: {at_many} threads for {many} sockets"
        );
    }
    t.print();

    // ---- part 2: a 10k-virtual-client round on one aggregator ------------
    let before = os_threads();
    let fleet = FleetConfig { clients: 10_000, update_len: 32, ..FleetConfig::default() };
    let report = run_fleet(&fleet);
    let after = os_threads();
    assert!(
        report.folded >= report.quorum && report.fused_len == fleet.update_len,
        "10k fleet round must publish: {report:?}"
    );
    assert_eq!(report.rejected, 0, "no virtual client drew an error reply");
    if let (Some(b), Some(a)) = (before, after) {
        assert!(
            a <= b + 2,
            "the virtual fleet must not cost threads: {b} before, {a} after"
        );
    }
    println!(
        "\n[fleet] 10k virtual clients: folded {}/{} (quorum {}) in {:.2}s",
        report.folded, report.expected, report.quorum, report.round_s
    );
    out.meta("fleet_clients", Json::num(fleet.clients as f64));
    out.meta("fleet_folded", Json::num(report.folded as f64));
    out.round(RoundRecord {
        round: fleet.clients as u32,
        label: format!("fleet(folded={},{:?})", report.folded, report.outcome),
        latency_s: report.round_s,
        ..Default::default()
    });

    // ---- part 3: reactor vs threaded — bit-identical round digests -------
    let cfg = ScenarioConfig {
        seed: 42,
        clients: 64,
        update_len: 64,
        deadline: Duration::from_secs(3),
        ..ScenarioConfig::default()
    };
    let reactor = run_scenario_on(&cfg, false);
    let threaded = run_scenario_on(&cfg, true);
    assert_eq!(
        reactor.digest(),
        threaded.digest(),
        "backend swap changed the round: reactor {reactor:?} vs threaded {threaded:?}"
    );
    println!(
        "[parity] 64-client scenario digest {:#018x} identical across backends",
        reactor.digest()
    );
    out.meta("parity_bit_identical", Json::Bool(true));
    out.meta("parity_digest", Json::str(&format!("{:#018x}", reactor.digest())));
    for (label, r) in [("reactor", &reactor), ("threaded", &threaded)] {
        out.round(RoundRecord {
            round: cfg.clients as u32,
            label: format!("parity-{label}(folded={},{:?})", r.folded, r.outcome),
            latency_s: r.round_s,
            ..Default::default()
        });
    }

    let path = out.write().expect("bench json");
    println!("\nwrote {}", path.display());
}
