//! Fig N (beyond the paper's numbered figures) — connection scaling after
//! retiring thread-per-connection.
//!
//! The paper's edge aggregator is priced for IoT fleets, but the repo's
//! original `NetServer` spent one OS thread per connected client: at the
//! fleet sizes the cost model covers, the socket layer OOMs on stacks
//! long before the fold runs out of budget.  The readiness reactor caps
//! the server at `1 + workers` OS threads regardless of connection count.
//! This bench pins that claim from three sides:
//!
//! * part 1 holds a sweep of REAL socket fleets (32 → 128 persistent
//!   connections) against a 4-worker reactor and reads the process's OS
//!   thread count from `/proc/self/status` at each point: the count must
//!   not grow with connections (and at 128 it must be far below one
//!   thread per client);
//! * part 2 runs a 10 000-virtual-client quorum round through the fleet
//!   harness (`elastiagg::sim::fleet`) — every survivor folded exactly
//!   once, OS thread count unchanged by fleet size;
//! * part 3 replays the SAME 64-client seeded scenario over the reactor
//!   and over the legacy thread-per-connection backend and requires
//!   bit-identical round digests: the backend swap changed how bytes
//!   reach the fold, provably not what the fold computes;
//! * part 4 (Linux) parks ≥1024 IDLE connections on the reactor and
//!   measures the poll thread's CPU over a quiet window, once on the
//!   epoll waiter and once on the portable sweep: epoll wakes on
//!   O(ready) events so an idle fleet costs ~nothing, while the sweep
//!   re-probes every socket each cycle and its cost grows with the
//!   fleet — the number the tentpole exists to change, pinned.

use std::sync::Arc;
use std::time::{Duration, Instant};

use elastiagg::bench::{BenchJson, RoundRecord};
use elastiagg::net::{Message, NetClient, NetServer, ReactorConfig, WaiterKind};
use elastiagg::sim::{run_fleet, run_scenario_on, FleetConfig, ScenarioConfig};
use elastiagg::util::fmt;
use elastiagg::util::json::Json;

/// OS threads in this process, from `/proc/self/status` (`None` where
/// procfs is absent — the sweep still runs, the thread pins are skipped).
fn os_threads() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()
}

fn main() {
    elastiagg::bench::banner(
        "Fig N — connection scaling: readiness reactor vs thread-per-connection",
        "server threads bounded by the worker pool, not the fleet size",
    );

    let mut out = BenchJson::new("fig_connection_scaling");
    const WORKERS: usize = 4;
    out.meta("workers", Json::num(WORKERS as f64));

    // ---- part 1: OS threads vs live socket count -------------------------
    let mut handle = NetServer::serve_with(
        "127.0.0.1:0",
        Arc::new(|m: Message| m),
        ReactorConfig { workers: WORKERS, ..Default::default() },
    )
    .expect("reactor server");
    let addr = handle.addr().to_string();

    let mut t = fmt::Table::new(&["connections", "os threads", "sweep s"]);
    let mut thread_counts = Vec::new();
    for conns in [32usize, 128] {
        let t0 = Instant::now();
        let mut clients: Vec<NetClient> = (0..conns)
            .map(|_| NetClient::connect(&addr).expect("bench client"))
            .collect();
        // every connection live and served at once, one call each
        for (i, c) in clients.iter_mut().enumerate() {
            let m = c.call(&Message::Register { party: i as u64 }).expect("echo");
            assert!(matches!(m, Message::Register { .. }));
        }
        let threads = os_threads();
        let sweep_s = t0.elapsed().as_secs_f64();
        assert_eq!(handle.active_connections(), conns, "every socket tracked");
        drop(clients);
        // let the reactor reap the hangups before the next sweep point
        let drain = Instant::now() + Duration::from_secs(5);
        while handle.active_connections() > 0 && Instant::now() < drain {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(handle.active_connections(), 0, "clean hangups must drain");
        if let Some(n) = threads {
            thread_counts.push((conns, n));
        }
        t.row(&[
            conns.to_string(),
            threads.map_or_else(|| "n/a".into(), |n| n.to_string()),
            format!("{sweep_s:.3}"),
        ]);
        out.meta(&format!("threads_at_{conns}_conns"), Json::num(threads.unwrap_or(0) as f64));
        out.round(RoundRecord {
            round: conns as u32,
            label: format!("sockets(conns={conns})"),
            latency_s: sweep_s,
            ..Default::default()
        });
    }
    handle.stop();
    if let [(few, at_few), (many, at_many)] = thread_counts[..] {
        assert!(
            at_many <= at_few + 2,
            "OS threads grew with connections ({few} conns -> {at_few}, {many} -> {at_many})"
        );
        assert!(
            at_many < many as u64,
            "thread-per-connection shape is back: {at_many} threads for {many} sockets"
        );
    }
    t.print();

    // ---- part 2: a 10k-virtual-client round on one aggregator ------------
    let before = os_threads();
    let fleet = FleetConfig { clients: 10_000, update_len: 32, ..FleetConfig::default() };
    let report = run_fleet(&fleet);
    let after = os_threads();
    assert!(
        report.folded >= report.quorum && report.fused_len == fleet.update_len,
        "10k fleet round must publish: {report:?}"
    );
    assert_eq!(report.rejected, 0, "no virtual client drew an error reply");
    if let (Some(b), Some(a)) = (before, after) {
        assert!(
            a <= b + 2,
            "the virtual fleet must not cost threads: {b} before, {a} after"
        );
    }
    println!(
        "\n[fleet] 10k virtual clients: folded {}/{} (quorum {}) in {:.2}s",
        report.folded, report.expected, report.quorum, report.round_s
    );
    out.meta("fleet_clients", Json::num(fleet.clients as f64));
    out.meta("fleet_folded", Json::num(report.folded as f64));
    out.round(RoundRecord {
        round: fleet.clients as u32,
        label: format!("fleet(folded={},{:?})", report.folded, report.outcome),
        latency_s: report.round_s,
        ..Default::default()
    });

    // ---- part 3: reactor vs threaded — bit-identical round digests -------
    let cfg = ScenarioConfig {
        seed: 42,
        clients: 64,
        update_len: 64,
        deadline: Duration::from_secs(3),
        ..ScenarioConfig::default()
    };
    let reactor = run_scenario_on(&cfg, false);
    let threaded = run_scenario_on(&cfg, true);
    assert_eq!(
        reactor.digest(),
        threaded.digest(),
        "backend swap changed the round: reactor {reactor:?} vs threaded {threaded:?}"
    );
    println!(
        "[parity] 64-client scenario digest {:#018x} identical across backends",
        reactor.digest()
    );
    out.meta("parity_bit_identical", Json::Bool(true));
    out.meta("parity_digest", Json::str(&format!("{:#018x}", reactor.digest())));
    for (label, r) in [("reactor", &reactor), ("threaded", &threaded)] {
        out.round(RoundRecord {
            round: cfg.clients as u32,
            label: format!("parity-{label}(folded={},{:?})", r.folded, r.outcome),
            latency_s: r.round_s,
            ..Default::default()
        });
    }

    // ---- part 4: idle-fleet CPU — epoll O(ready) vs sweep O(connections) --
    #[cfg(target_os = "linux")]
    idle_fleet_cpu(&mut out);

    let path = out.write().expect("bench json");
    println!("\nwrote {}", path.display());
}

/// Raise `RLIMIT_NOFILE` toward `want` (best-effort: capped at the hard
/// limit) so the idle-fleet sweep can hold >1024 sockets plus their
/// server-side twins.  Hand-rolled FFI — the repo takes no libc crate.
#[cfg(target_os = "linux")]
fn raise_nofile(want: u64) -> u64 {
    #[repr(C)]
    struct Rlimit {
        rlim_cur: u64,
        rlim_max: u64,
    }
    const RLIMIT_NOFILE: i32 = 7;
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
    }
    let mut lim = Rlimit { rlim_cur: 0, rlim_max: 0 };
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
        return 0;
    }
    if lim.rlim_cur < want {
        let new = Rlimit { rlim_cur: want.min(lim.rlim_max), rlim_max: lim.rlim_max };
        if unsafe { setrlimit(RLIMIT_NOFILE, &new) } == 0 {
            lim.rlim_cur = new.rlim_cur;
        }
    }
    lim.rlim_cur
}

/// Thread ids currently named after the reactor
/// (`/proc/self/task/<tid>/comm`).
#[cfg(target_os = "linux")]
fn reactor_tids() -> Vec<String> {
    let mut tids = Vec::new();
    let Ok(dir) = std::fs::read_dir("/proc/self/task") else {
        return tids;
    };
    for entry in dir.flatten() {
        let tid = entry.file_name().to_string_lossy().into_owned();
        if let Ok(comm) = std::fs::read_to_string(entry.path().join("comm")) {
            if comm.trim_end() == elastiagg::net::REACTOR_THREAD_NAME {
                tids.push(tid);
            }
        }
    }
    tids
}

/// utime+stime of one thread in seconds, from `/proc/self/task/<tid>/stat`
/// (fields 14/15 counted from after the parenthesized comm; USER_HZ 100).
#[cfg(target_os = "linux")]
fn thread_cpu_seconds(tid: &str) -> Option<f64> {
    let stat = std::fs::read_to_string(format!("/proc/self/task/{tid}/stat")).ok()?;
    let close = stat.rfind(')')?;
    let fields: Vec<&str> = stat.get(close + 2..)?.split(' ').collect();
    let utime: u64 = fields.get(11)?.parse().ok()?;
    let stime: u64 = fields.get(12)?.parse().ok()?;
    Some((utime + stime) as f64 / 100.0)
}

/// Park `IDLE_CONNS` idle sockets on one backend and return the poll
/// thread's CPU seconds over a `WINDOW` quiet window, plus the backend the
/// waiter actually picked (`ELASTIAGG_NO_EPOLL=1` downgrades Epoll to the
/// sweep — the caller skips the comparison instead of mis-pinning it).
#[cfg(target_os = "linux")]
fn idle_cpu_on(waiter: WaiterKind, conns: usize, window: Duration) -> (f64, &'static str) {
    let before = reactor_tids();
    let mut handle = NetServer::serve_with(
        "127.0.0.1:0",
        Arc::new(|m: Message| m),
        ReactorConfig { workers: 1, waiter },
    )
    .expect("idle-fleet server");
    let backend = handle.backend_name();
    let ours: Vec<String> = reactor_tids().into_iter().filter(|t| !before.contains(t)).collect();
    let addr = handle.addr().to_string();

    let mut clients: Vec<NetClient> = Vec::with_capacity(conns);
    for i in 0..conns {
        let mut c = NetClient::connect(&addr).expect("idle client");
        // one echo so the connection is registered, served and back to
        // read-interest before the quiet window starts
        let m = c.call(&Message::Register { party: i as u64 }).expect("echo");
        assert!(matches!(m, Message::Register { .. }));
        clients.push(c);
    }
    assert_eq!(handle.active_connections(), conns, "every idle socket tracked");

    let cpu0: f64 = ours.iter().filter_map(|t| thread_cpu_seconds(t)).sum();
    std::thread::sleep(window);
    let cpu1: f64 = ours.iter().filter_map(|t| thread_cpu_seconds(t)).sum();

    drop(clients);
    handle.stop();
    (cpu1 - cpu0, backend)
}

#[cfg(target_os = "linux")]
fn idle_fleet_cpu(out: &mut BenchJson) {
    const IDLE_CONNS: usize = 1024;
    const WINDOW: Duration = Duration::from_secs(2);
    // 1024 clients + 1024 accepted twins + store/scratch fds need headroom
    let limit = raise_nofile(4 * IDLE_CONNS as u64);
    if limit < (2 * IDLE_CONNS + 64) as u64 {
        println!("\n[idle] skipped: RLIMIT_NOFILE {limit} too low for {IDLE_CONNS} sockets");
        return;
    }

    let (epoll_cpu, epoll_backend) = idle_cpu_on(WaiterKind::Epoll, IDLE_CONNS, WINDOW);
    let (sweep_cpu, sweep_backend) = idle_cpu_on(WaiterKind::Sweep, IDLE_CONNS, WINDOW);
    assert_eq!(sweep_backend, "sweep");
    println!(
        "\n[idle] {IDLE_CONNS} idle conns over {:.0}s: {epoll_backend} {epoll_cpu:.3}s CPU \
         vs sweep {sweep_cpu:.3}s CPU",
        WINDOW.as_secs_f64()
    );
    out.meta("idle_conns", Json::num(IDLE_CONNS as f64));
    out.meta("idle_window_s", Json::num(WINDOW.as_secs_f64()));
    out.meta(
        &format!("idle_reactor_cpu_s_{epoll_backend}"),
        Json::num(epoll_cpu),
    );
    out.meta("idle_reactor_cpu_s_sweep", Json::num(sweep_cpu));
    if epoll_backend == "epoll" {
        // The tentpole's number: readiness from the OS queue makes an idle
        // fleet ~free, while the sweep pays O(connections) every cycle.
        assert!(
            epoll_cpu < sweep_cpu && epoll_cpu <= 0.5 * sweep_cpu + 0.05,
            "idle fleet must be cheaper on epoll: epoll {epoll_cpu:.3}s vs sweep {sweep_cpu:.3}s"
        );
    } else {
        println!("[idle] epoll unavailable (forced sweep?) — comparison not pinned");
    }
}
