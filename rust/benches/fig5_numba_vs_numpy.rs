//! Fig 5 — Numba vs NumPy for FedAvg across model sizes.
//!
//! Paper shape: the parallel (Numba) path wins most for SMALL models (many
//! parties fit -> lots of parallelism); for large models fewer parties fit
//! and the gap narrows.

use elastiagg::bench::{gen_updates, paper_cluster, time};
use elastiagg::cluster::{EngineKind, FEDAVG_DUP_FACTOR};
use elastiagg::config::ModelZoo;
use elastiagg::engine::{AggregationEngine, ParallelEngine, SerialEngine, XlaEngine};
use elastiagg::fusion::FedAvg;
use elastiagg::metrics::Breakdown;
use elastiagg::runtime::Runtime;
use elastiagg::util::fmt;

fn main() {
    let vc = paper_cluster();
    elastiagg::bench::banner(
        "Fig 5 — Numba vs NumPy, FedAvg, model-size ladder (at capacity load)",
        "parallel wins ~35-40% for small models; gap narrows as size grows",
    );

    println!("\n[paper-scale, virtual] each model at its 170 GB party capacity, 64 cores:");
    let mut t = fmt::Table::new(&["model", "parties", "numpy", "numba", "improvement"]);
    let mut improvements = Vec::new();
    for m in ModelZoo::cnn_ladder() {
        let cap = vc.single_node_capacity(170 << 30, m.size_bytes, FEDAVG_DUP_FACTOR);
        let s = vc.single_node_time(m.size_bytes, cap, 64, EngineKind::Serial, 1.0);
        let p = vc.single_node_time(m.size_bytes, cap, 64, EngineKind::Parallel, 1.0);
        let imp = 100.0 * (s - p) / s;
        improvements.push((m.name, cap, imp));
        t.row(&[
            m.name.to_string(),
            cap.to_string(),
            fmt::secs(s),
            fmt::secs(p),
            format!("{imp:.1}%"),
        ]);
    }
    t.print();
    // parallel must always win at capacity load with 64 cores
    assert!(improvements.iter().all(|(_, _, imp)| *imp > 0.0));

    println!("\n[measured, 1:100 scale] serial vs parallel(4) vs xla, 64 parties per size:");
    let scale = 0.01;
    let xla = Runtime::load_default().ok().and_then(|r| XlaEngine::new(r, 64).ok());
    let mut t = fmt::Table::new(&["model", "serial", "parallel(4)", "xla(k=64)"]);
    for m in ModelZoo::cnn_ladder() {
        let len = m.scaled_params(scale);
        let updates = gen_updates(11, 64, len);
        let mut bd = Breakdown::new();
        let (r, s) = time(|| SerialEngine::unbounded().aggregate(&FedAvg, &updates, &mut bd));
        r.unwrap();
        let (r, p) = time(|| ParallelEngine::new(4).aggregate(&FedAvg, &updates, &mut bd));
        r.unwrap();
        let x = xla.as_ref().map(|x| {
            let (r, t) = time(|| x.aggregate(&FedAvg, &updates, &mut bd));
            r.unwrap();
            t
        });
        t.row(&[
            m.name.to_string(),
            fmt::secs(s),
            fmt::secs(p),
            x.map(fmt::secs).unwrap_or_else(|| "n/a".into()),
        ]);
    }
    t.print();
    println!("\nfig5 OK");
}
