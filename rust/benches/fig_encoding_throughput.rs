//! Fig E (beyond the paper's numbered figures) — SIMD fold kernels and
//! compressed wire encodings, priced end to end.
//!
//! Three pins, one per layer of the PR:
//!
//! * **[kernel]** — the runtime-dispatched fold (`fusion::kernels`) must
//!   beat the guaranteed-scalar reference ≥ 2× on a ≥ 1M-element
//!   weighted accumulate, at *bit-identical* output (the exactness
//!   contract every parity test leans on).  The denominator is
//!   [`strict_scalar_accumulate`] — the plain fallback is autovectorised
//!   in release builds, so measuring against it would compare SIMD with
//!   SIMD.
//! * **[codec]** — each compressed encoding's real encode→decode
//!   roundtrip, with the wire-byte ratio vs dense f32 and the process-wide
//!   borrowed-vs-copied decode counters surfaced in the output.
//! * **[model]** — compression shrinks every client→aggregator leg but
//!   never the relay→root partials (those are dense f32 by construction),
//!   so the flat-vs-2-tier crossover `fig_hierarchical_scaling` pins at
//!   the dense geometry must move to LARGER fleets under f16/int8/top-k
//!   uplinks.
//!
//! Machine-readable output: `BENCH_fig_encoding_throughput.json`.
//!
//! [`strict_scalar_accumulate`]: elastiagg::fusion::kernels::strict_scalar_accumulate

use std::time::Instant;

use elastiagg::bench::{BenchJson, RoundRecord};
use elastiagg::cluster::{CostModel, VirtualCluster};
use elastiagg::fusion::kernels;
use elastiagg::tensorstore::{codec, decode_stats, EncodedUpdateView, Encoding, ModelUpdate};
use elastiagg::util::fmt;
use elastiagg::util::rng::Rng;

// 1M elements (4 MB): the sum+data working set stays L3-resident so the
// pin measures the kernels, not the DRAM controller.
const FOLD_ELEMS: usize = 1 << 20;
const UPDATE_46MB: u64 = (4.6 * 1024.0 * 1024.0) as u64;
const EDGES: usize = 4;

/// Best-of-N wall time of one full accumulate pass over `sum`/`data`.
fn time_fold<F: FnMut(&mut [f32], &[f32])>(
    sum: &mut [f32],
    data: &[f32],
    reps: usize,
    mut f: F,
) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f(sum, data);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    elastiagg::bench::banner(
        "Fig E — SIMD fold kernels + compressed wire encodings",
        "vector folds, quantized/sparse uplinks, and the crossover they move",
    );
    let mut out = BenchJson::new("fig_encoding_throughput");
    out.meta(
        "kernel",
        elastiagg::util::json::Json::str(kernels::kernel_name()),
    );
    out.meta(
        "fold_elems",
        elastiagg::util::json::Json::num(FOLD_ELEMS as f64),
    );

    // ---- part 1: SIMD vs strict-scalar fold ----------------------------
    let mut rng = Rng::new(0xE0);
    let mut data = vec![0f32; FOLD_ELEMS];
    let mut init = vec![0f32; FOLD_ELEMS];
    rng.fill_gaussian_f32(&mut data, 1.0);
    rng.fill_gaussian_f32(&mut init, 1.0);
    let w = 0.731_f32;

    // bit-parity first: the speedup claim is only meaningful if the two
    // loops compute the same bits
    let mut fast = init.clone();
    kernels::accumulate(&mut fast, &data, w);
    let mut slow = init.clone();
    kernels::strict_scalar_accumulate(&mut slow, &data, w);
    assert!(
        fast.iter().zip(&slow).all(|(a, b)| a.to_bits() == b.to_bits()),
        "dispatched kernel must be bit-identical to the scalar loop"
    );
    println!(
        "\n[kernel] dispatch={}, {} elements, bit-parity with strict scalar: OK",
        kernels::kernel_name(),
        FOLD_ELEMS
    );

    // warm both paths once, then take best-of-7 (shared-CI jitter)
    let reps = 7;
    let mut scratch = init.clone();
    let simd_s = time_fold(&mut scratch, &data, reps, |s, d| kernels::accumulate(s, d, w));
    let mut scratch = init.clone();
    let scalar_s =
        time_fold(&mut scratch, &data, reps, |s, d| kernels::strict_scalar_accumulate(s, d, w));
    let speedup = scalar_s / simd_s;
    let bytes_per_pass = (FOLD_ELEMS * 4) as f64;
    println!(
        "  strict scalar: {} ({}/s)",
        fmt::secs(scalar_s),
        fmt::bytes((bytes_per_pass / scalar_s) as u64)
    );
    println!(
        "  dispatched   : {} ({}/s)  speedup {:.2}x",
        fmt::secs(simd_s),
        fmt::bytes((bytes_per_pass / simd_s) as u64),
        speedup
    );
    out.meta("fold_speedup", elastiagg::util::json::Json::num(speedup));
    out.round(RoundRecord {
        round: 0,
        label: format!("fold:{}", kernels::kernel_name()),
        latency_s: simd_s,
        ..Default::default()
    });
    out.round(RoundRecord {
        round: 0,
        label: "fold:strict_scalar".into(),
        latency_s: scalar_s,
        ..Default::default()
    });
    if kernels::kernel_name() != "scalar" {
        // the acceptance bar: ≥ 2x on a ≥ 1M-element fold whenever a
        // vector kernel dispatched (scalar dispatch = nothing to pin)
        assert!(
            speedup >= 2.0,
            "SIMD fold must be >= 2x the strict scalar loop, got {speedup:.2}x"
        );
    } else {
        println!("  (scalar dispatch — speedup pin skipped)");
    }

    // ---- part 2: codec throughput + decode counters --------------------
    let elems = 1 << 20; // 4 MB dense update
    let mut weights = vec![0f32; elems];
    Rng::new(0xE1).fill_gaussian_f32(&mut weights, 1.0);
    let update = ModelUpdate::new(7, 3.0, 0, weights);
    let dense_wire = Encoding::DenseF32.wire_bytes(elems as u64);
    println!("\n[codec] {elems}-param update, encode -> decode -> dequantize:");
    let mut t = fmt::Table::new(&["encoding", "wire bytes", "vs dense", "enc+dec s", "MB/s dense-equiv"]);
    let before = decode_stats();
    for enc in [
        Encoding::DenseF32,
        Encoding::DenseF16,
        Encoding::QuantI8,
        Encoding::TopK { permille: 100 },
    ] {
        let t0 = Instant::now();
        let frame = codec::encode_update(&update, enc);
        let view = EncodedUpdateView::decode(&frame).expect("own frame");
        let decoded = view.decode_data().expect("own payload");
        let dt = t0.elapsed().as_secs_f64().max(1e-9);
        assert_eq!(decoded.len(), elems);
        let wire = enc.wire_bytes(elems as u64);
        t.row(&[
            enc.token(),
            wire.to_string(),
            format!("{:.3}x", wire as f64 / dense_wire as f64),
            fmt::secs(dt),
            format!("{:.0}", (elems * 4) as f64 / dt / 1e6),
        ]);
        out.round(RoundRecord {
            round: 1,
            label: format!("codec:{}", enc.token()),
            latency_s: dt,
            peak_bytes: wire,
            ..Default::default()
        });
    }
    t.print();
    let delta = decode_stats().since(before);
    println!(
        "  decode counters this sweep: borrowed={} copied={} (dense f32 borrows, compressed \
         payloads dequantize into owned buffers)",
        delta.borrowed, delta.copied
    );
    assert!(delta.borrowed >= 1, "the dense-f32 decode must borrow zero-copy");
    assert!(delta.copied >= 3, "each compressed decode materialises a copy");
    out.meta("decode_borrowed", elastiagg::util::json::Json::num(delta.borrowed as f64));
    out.meta("decode_copied", elastiagg::util::json::Json::num(delta.copied as f64));

    // ---- part 3: the crossover shift -----------------------------------
    // Smallest fleet where the 2-tier plan beats the flat streaming fold,
    // per uplink encoding, at the paper's 1 GbE geometry.  The relay→root
    // partials stay dense f32 whatever the clients ship, so compression
    // helps the flat plan more: the crossover must recede.
    let v = VirtualCluster::paper(CostModel::nominal());
    let xover = |enc: Encoding| -> usize {
        for n in 2..100_000usize {
            let flat = v.streaming_time_enc(UPDATE_46MB, n, 64, 64, enc);
            let hier = v.hierarchical_time_enc(UPDATE_46MB, n, 64, 64, EDGES, enc);
            if hier < flat {
                return n;
            }
        }
        panic!("no crossover below 100k parties for {enc:?}");
    };
    let dense_x = xover(Encoding::DenseF32);
    let f16_x = xover(Encoding::DenseF16);
    let quant_x = xover(Encoding::QuantI8);
    let topk_x = xover(Encoding::TopK { permille: 100 });
    println!("\n[model] flat->2-tier crossover (e={EDGES}, 4.6 MB updates, 1 GbE):");
    let mut t = fmt::Table::new(&["uplink encoding", "crossover parties"]);
    for (enc, x) in [
        (Encoding::DenseF32, dense_x),
        (Encoding::DenseF16, f16_x),
        (Encoding::QuantI8, quant_x),
        (Encoding::TopK { permille: 100 }, topk_x),
    ] {
        t.row(&[enc.token(), x.to_string()]);
        out.round(RoundRecord {
            round: 2,
            label: format!("crossover:{}", enc.token()),
            peak_bytes: x as u64,
            ..Default::default()
        });
    }
    t.print();
    // the dense crossover is the fig_hierarchical_scaling regime (2-tier
    // wins by 32 parties, never by 8)...
    assert!(
        dense_x > 8 && dense_x <= 32,
        "dense crossover {dense_x} must sit in the pinned (8, 32] band"
    );
    // ... and every compressed uplink moves it to a LARGER fleet
    assert!(f16_x > dense_x, "f16 {f16_x} !> dense {dense_x}");
    assert!(quant_x > f16_x, "int8 {quant_x} !> f16 {f16_x}");
    assert!(topk_x > quant_x, "topk {topk_x} !> int8 {quant_x}");

    match out.write() {
        Ok(p) => println!("machine-readable log: {}", p.display()),
        Err(e) => println!("bench json not written: {e}"),
    }
    println!("\nfigE OK — vector folds, cheaper wires, and a crossover that recedes");
}
