//! Fig F (beyond the paper's numbered figures) — fault-tolerant quorum
//! rounds vs all-or-nothing participation.
//!
//! Every prior round shape in this repo assumed all K registered parties
//! upload exactly once and on time; one dropped phone stalled the round at
//! its timeout with nothing to show.  The quorum lifecycle turns client
//! misbehaviour into a priced, testable axis:
//!
//! * part 1 sweeps the dropout rate with the deterministic scenario
//!   harness (`elastiagg::sim`) and compares two policies over the SAME
//!   seeded fleet: quorum-at-half (aggregate the survivors at the
//!   deadline) vs full-participation (quorum = K: abort unless everyone
//!   shows).  Quorum rounds keep publishing models as the dropout rate
//!   climbs; the strict policy forfeits every faulted round — and both
//!   pay the same wall clock, so the quorum policy's cost per *published*
//!   model is strictly lower whenever anyone drops;
//! * part 2 prices the same effect in the planner: after observed-turnout
//!   calibration the streaming plan is priced at K·p uploads, shrinking
//!   predicted round latency vs the naive full-K price.
//!
//! Asserted acceptance: at 0 % dropout both policies complete (early, not
//! at the deadline); at every faulted sweep point the quorum policy
//! publishes while full-participation aborts; the planner's priced
//! latency is monotone non-increasing in observed participation.

use std::time::Duration;

use elastiagg::bench::{BenchJson, RoundRecord};
use elastiagg::cluster::{CostModel, VirtualCluster};
use elastiagg::coordinator::{RoundOutcome, WorkloadClassifier};
use elastiagg::fusion::FedAvg;
use elastiagg::planner::{
    DispatchPlanner, DispatchPolicy, PlanKind, PlannerConfig, PricingModel,
};
use elastiagg::sim::{run_scenario, schedules, ScenarioConfig};
use elastiagg::util::fmt;
use elastiagg::util::json::Json;

fn scenario(seed: u64, dropout: f64, quorum_frac: f64) -> ScenarioConfig {
    ScenarioConfig {
        seed,
        clients: 16,
        update_len: 256,
        dropout,
        duplicate: 0.25,
        latency_ms: (10, 150),
        quorum_frac,
        deadline: Duration::from_millis(700),
        ..ScenarioConfig::default()
    }
}

/// A seed whose schedule at this dropout rate actually drops ≥1 client
/// (and keeps ≥ half) — deterministic sweep, no binomial-tail flake.
fn seed_for(dropout: f64) -> u64 {
    (0..256u64)
        .find(|seed| {
            let s = schedules(&scenario(*seed, dropout, 0.5));
            let survivors = s.iter().filter(|c| !c.drops_out).count();
            survivors >= 8 && survivors < 16
        })
        .expect("a seed with 8..16 survivors exists in the sweep")
}

fn main() {
    elastiagg::bench::banner(
        "Fig F — quorum rounds vs full participation under dropout",
        "K-of-N + deadline keeps publishing models where all-or-nothing stalls",
    );

    let mut out = BenchJson::new("fig_fault_tolerance");
    out.meta("clients", Json::num(16.0));
    out.meta("update_len", Json::num(256.0));

    // ---- part 1: round outcome + latency vs dropout rate ----------------
    let mut t = fmt::Table::new(&[
        "dropout",
        "survivors",
        "quorum outcome",
        "quorum folded",
        "quorum round s",
        "strict outcome",
    ]);
    for dropout in [0.0f64, 0.125, 0.25, 0.5] {
        let (seed, expect_faults) = if dropout == 0.0 {
            (1, false)
        } else {
            (seed_for(dropout), true)
        };
        let quorum_cfg = scenario(seed, dropout, 0.5);
        let survivors = schedules(&quorum_cfg)
            .iter()
            .filter(|c| !c.drops_out)
            .count();
        let q = run_scenario(&quorum_cfg);
        let strict = run_scenario(&scenario(seed, dropout, 1.0));
        if expect_faults {
            // the quorum policy publishes a model from the survivors ...
            assert_eq!(q.outcome, RoundOutcome::Quorum, "dropout {dropout}: {q:?}");
            assert_eq!(q.folded, survivors, "every survivor folds exactly once");
            assert_eq!(q.fused_len, quorum_cfg.update_len);
            // ... while all-or-nothing forfeits the whole round
            assert_eq!(strict.outcome, RoundOutcome::Aborted, "dropout {dropout}");
            assert_eq!(strict.fused_len, 0);
        } else {
            // no faults: both policies complete, sealing on arrival
            assert_eq!(q.outcome, RoundOutcome::Complete);
            assert_eq!(strict.outcome, RoundOutcome::Complete);
            assert!(
                q.round_s < quorum_cfg.deadline.as_secs_f64() + 0.5,
                "clean rounds must not idle to the deadline: {}s",
                q.round_s
            );
        }
        t.row(&[
            format!("{:.0}%", dropout * 100.0),
            survivors.to_string(),
            format!("{:?}", q.outcome),
            q.folded.to_string(),
            format!("{:.2}", q.round_s),
            format!("{:?}", strict.outcome),
        ]);
        out.round(RoundRecord {
            round: (dropout * 1000.0) as u32,
            label: format!("quorum(dropout={dropout},folded={},{:?})", q.folded, q.outcome),
            latency_s: q.round_s,
            ..Default::default()
        });
        out.round(RoundRecord {
            round: (dropout * 1000.0) as u32,
            label: format!("strict(dropout={dropout},{:?})", strict.outcome),
            latency_s: strict.round_s,
            ..Default::default()
        });
    }
    t.print();

    // ---- part 2: participation-calibrated plan pricing -------------------
    println!("\n[model] streaming plan priced at K·p after turnout calibration:");
    let make_planner = || {
        DispatchPlanner::new(
            WorkloadClassifier::new(170 << 30, 1.1),
            VirtualCluster::paper(CostModel::nominal()),
            PricingModel::default(),
            PlannerConfig {
                policy: DispatchPolicy::MinLatency,
                max_executors: 10,
                cores_per_executor: 3,
                node_cores: 64,
                ingest_lanes: 64,
                edges: 0,
                xla_available: false,
                feedback_beta: 0.3,
                expected_participation: 1.0,
                async_buffer: 0,
                staleness_exponent: 0.5,
                ..PlannerConfig::default() // dense-f32 uplinks
            },
        )
    };
    let update = (4.6 * 1024.0 * 1024.0) as u64;
    let parties = 30_000usize;
    let mut t = fmt::Table::new(&["observed turnout", "priced latency s", "priced $"]);
    let mut last = f64::INFINITY;
    for turnout in [1.0f64, 0.9, 0.8, 0.6] {
        let mut p = make_planner();
        for _ in 0..6 {
            p.observe_participation((parties as f64 * turnout) as usize, parties);
        }
        let plan = p.plan(update, parties, &FedAvg, 0);
        let stream = plan
            .candidates
            .iter()
            .find(|c| c.kind == PlanKind::Streaming)
            .expect("streaming candidate");
        assert!(
            stream.cost.latency_s <= last + 1e-9,
            "pricing must be monotone non-increasing in dropout: {} > {last}",
            stream.cost.latency_s
        );
        last = stream.cost.latency_s;
        t.row(&[
            format!("{:.0}%", turnout * 100.0),
            format!("{:.1}", stream.cost.latency_s),
            format!("{:.4}", stream.cost.usd),
        ]);
        out.round(RoundRecord {
            round: (turnout * 1000.0) as u32,
            label: format!("priced-streaming(turnout={turnout})"),
            predicted_s: stream.cost.latency_s,
            predicted_usd: stream.cost.usd,
            ..Default::default()
        });
    }
    t.print();

    let path = out.write().expect("write BENCH_fig_fault_tolerance.json");
    println!("\n[json] {}", path.display());
    println!("\nfigF OK — quorum rounds publish under dropout; plans price the K·p the fleet delivers");
}
