//! FedBuff-style asynchronous round state: a bounded buffer of the K
//! freshest updates, published on buffer-full or cadence instead of a
//! quorum seal.
//!
//! The sync [`RoundState`](super::RoundState) is a lockstep barrier — an
//! update either makes the round's deadline or is `Late`-rejected.  At
//! fleet scale stragglers are the common case, not the fault case, so the
//! async mode inverts the contract: *every* well-formed upload is
//! admitted, tagged with the model-version delta observed at ingest
//! (`δ = current_version − trained_version`), and buffered until the
//! driver drains.  Staleness is handled by *weight*, not rejection — the
//! drain folds each update through a
//! [`DiscountedFusion`](crate::fusion::DiscountedFusion) scaled by
//! `s(δ)`, so a straggler's work still counts, just less.
//!
//! Buffer contract (each clause pinned by a table test below):
//!
//! * **Bounded**: at most K updates are buffered; each holds a
//!   [`MemoryBudget`] reservation for its payload, so the buffer's
//!   footprint is K·C against the node budget, never fleet-sized.
//! * **Eviction is oldest-version-first** over `buffer ∪ {incoming}`:
//!   a full buffer admits a fresher update by evicting the oldest-version
//!   entry (ties broken earliest-arrival); an incoming update that is
//!   itself the oldest is rejected as [`AsyncError::Stale`] — the buffer
//!   always holds the K freshest versions seen.
//! * **Exactly once**: per-buffer dedup by party id (retransmits get
//!   [`AsyncError::Duplicate`] with the accepted nonce, the sync round's
//!   idempotent-retry contract); [`AsyncRound::drain`] swaps the buffer
//!   out under the lock, so an upload racing a publish lands in the
//!   *next* buffer — admitted exactly once, never dropped silently.
//! * **Abort returns every reservation**: buffered entries carry RAII
//!   [`Reservation`]s; [`AsyncRound::abort`] (or a drop mid-buffer)
//!   releases them all — `in_use == 0` after, like the sync abort path.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::memsim::{MemoryBudget, OutOfMemory, Reservation};

/// Why an async offer was not buffered.
#[derive(Debug)]
pub enum AsyncError {
    /// This party already has an update in the current buffer; `nonce` is
    /// the accepted upload's nonce (the retransmit-idempotency contract).
    Duplicate { party: u64, nonce: u64 },
    /// The buffer is full and the incoming update's version is the oldest
    /// of `buffer ∪ {incoming}` — buffering it would evict fresher work.
    /// `version` is the current model version, so the client can pull the
    /// new model and retrain instead of retrying.
    Stale { version: u32 },
    /// The update disagreed with the established parameter count.
    ShapeMismatch { want: usize, got: usize },
    /// The node budget cannot hold another buffered update.
    Memory(OutOfMemory),
}

impl std::fmt::Display for AsyncError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AsyncError::Duplicate { party, nonce } => {
                write!(f, "party {party} already buffered (nonce {nonce})")
            }
            AsyncError::Stale { version } => {
                write!(f, "update too stale for the buffer; model is at version {version}")
            }
            AsyncError::ShapeMismatch { want, got } => {
                write!(f, "update length {got} != buffer's {want}")
            }
            AsyncError::Memory(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for AsyncError {}

impl From<OutOfMemory> for AsyncError {
    fn from(e: OutOfMemory) -> AsyncError {
        AsyncError::Memory(e)
    }
}

/// A successfully buffered offer: what the server echoes back to the
/// client as an `AsyncAck` frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Admitted {
    /// Current model version at ingest.
    pub version: u32,
    /// Staleness delta observed for this update.
    pub delta: u32,
}

/// One buffered update, owning its payload and its budget reservation.
/// Dropping it (fold consumed, eviction, abort) releases the bytes.
pub struct BufferedUpdate {
    pub party: u64,
    pub nonce: u64,
    /// Model version the client trained against (the wire `round` field,
    /// reinterpreted as a version tag in async mode).
    pub trained_version: u32,
    /// Staleness delta at ingest time.
    pub delta: u32,
    pub count: f32,
    pub data: Vec<f32>,
    _reservation: Reservation,
}

struct Buffer {
    /// Arrival order — the drain folds in this order.
    entries: Vec<BufferedUpdate>,
    /// Party → accepted nonce, for the current buffer only.
    accepted: HashMap<u64, u64>,
}

impl Buffer {
    fn new() -> Buffer {
        Buffer { entries: Vec::new(), accepted: HashMap::new() }
    }
}

/// The async round: model version counter + bounded staleness buffer.
pub struct AsyncRound {
    capacity: usize,
    budget: MemoryBudget,
    version: AtomicU32,
    /// Parameter count pinned by the first offer: 0 until set, `len + 1`
    /// after (the [`ShardedFold`](crate::engine::ShardedFold) idiom).
    expect_len: AtomicUsize,
    buffer: Mutex<Buffer>,
    /// Latest published model, served to `GetModel` in async mode.
    model: Mutex<Option<Arc<Vec<f32>>>>,
    /// Cumulative oldest-version-first evictions (reporting only).
    evicted: AtomicU64,
    /// Cumulative updates drained into folds (reporting only).
    drained: AtomicU64,
}

impl AsyncRound {
    pub fn new(capacity: usize, budget: MemoryBudget) -> AsyncRound {
        AsyncRound {
            capacity: capacity.max(1),
            budget,
            version: AtomicU32::new(0),
            expect_len: AtomicUsize::new(0),
            buffer: Mutex::new(Buffer::new()),
            model: Mutex::new(None),
            evicted: AtomicU64::new(0),
            drained: AtomicU64::new(0),
        }
    }

    /// Current model version (bumped by each [`AsyncRound::install`]).
    pub fn version(&self) -> u32 {
        self.version.load(Ordering::Acquire)
    }

    /// Updates in the current buffer.
    pub fn collected(&self) -> usize {
        self.buffer.lock().unwrap().entries.len()
    }

    /// Whether the buffer has reached its K — the driver's publish
    /// trigger (the other trigger being the cadence timer).
    pub fn is_full(&self) -> bool {
        self.collected() >= self.capacity
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Cumulative oldest-version-first evictions.
    pub fn evicted(&self) -> u64 {
        self.evicted.load(Ordering::Relaxed)
    }

    /// Cumulative updates handed to drains.
    pub fn drained(&self) -> u64 {
        self.drained.load(Ordering::Relaxed)
    }

    /// Latest published model, if any round has published yet.
    pub fn model(&self) -> Option<Arc<Vec<f32>>> {
        self.model.lock().unwrap().clone()
    }

    /// Offer one update to the buffer.  `trained_version` is the wire
    /// frame's round field reinterpreted as the model version the client
    /// trained against; the staleness delta is computed here, at ingest,
    /// against the current version.
    pub fn offer(
        &self,
        party: u64,
        nonce: u64,
        trained_version: u32,
        count: f32,
        data: &[f32],
    ) -> Result<Admitted, AsyncError> {
        // Pin (or check) the parameter count outside the buffer lock; the
        // CAS makes two racing first offers of different shapes resolve to
        // one pinned shape and one typed rejection.
        match self.expect_len.compare_exchange(
            0,
            data.len() + 1,
            Ordering::AcqRel,
            Ordering::Acquire,
        ) {
            Ok(_) => {}
            Err(cur) if cur - 1 == data.len() => {}
            Err(cur) => {
                return Err(AsyncError::ShapeMismatch { want: cur - 1, got: data.len() })
            }
        }
        let version = self.version();
        let delta = version.saturating_sub(trained_version);
        let mut buf = self.buffer.lock().unwrap();
        if let Some(&nonce) = buf.accepted.get(&party) {
            return Err(AsyncError::Duplicate { party, nonce });
        }
        if buf.entries.len() >= self.capacity {
            // Oldest-version-first over buffer ∪ {incoming}.  `min_by_key`
            // returns the FIRST minimum, so ties evict the earliest
            // arrival; an incoming update tying the minimum loses to the
            // buffered entry (it "arrived last") and is rejected.
            let (idx, oldest) = buf
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.trained_version)
                .map(|(i, e)| (i, e.trained_version))
                .expect("full buffer is non-empty");
            if trained_version <= oldest {
                return Err(AsyncError::Stale { version });
            }
            let gone = buf.entries.remove(idx);
            buf.accepted.remove(&gone.party);
            self.evicted.fetch_add(1, Ordering::Relaxed);
            // `gone` drops here, releasing its reservation before the
            // incoming reserve — a full budget still admits the swap.
        }
        let reservation = self.budget.reserve(data.len() as u64 * 4)?;
        buf.accepted.insert(party, nonce);
        buf.entries.push(BufferedUpdate {
            party,
            nonce,
            trained_version,
            delta,
            count,
            data: data.to_vec(),
            _reservation: reservation,
        });
        Ok(Admitted { version, delta })
    }

    /// Swap the buffer out for the drain-and-fold.  Runs under the same
    /// lock `offer` takes, so an upload racing the publish lands cleanly
    /// in the fresh buffer — the *next* publish folds it.  Entries come
    /// out in arrival order; each still owns its reservation, released as
    /// the fold consumes (drops) it.
    pub fn drain(&self) -> Vec<BufferedUpdate> {
        let mut buf = self.buffer.lock().unwrap();
        let taken = std::mem::replace(&mut *buf, Buffer::new());
        self.drained.fetch_add(taken.entries.len() as u64, Ordering::Relaxed);
        taken.entries
    }

    /// Publish a fused model: store it and bump the version.  Offers from
    /// here on observe the new version (their deltas grow by one).
    pub fn install(&self, model: Vec<f32>) -> u32 {
        let mut slot = self.model.lock().unwrap();
        *slot = Some(Arc::new(model));
        self.version.fetch_add(1, Ordering::AcqRel) + 1
    }

    /// Drop every buffered update, returning all reservations to the
    /// budget (`in_use` falls by the buffer's full footprint).
    pub fn abort(&self) {
        let mut buf = self.buffer.lock().unwrap();
        *buf = Buffer::new();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round(cap: usize, budget: &MemoryBudget) -> AsyncRound {
        AsyncRound::new(cap, budget.clone())
    }

    #[test]
    fn offers_buffer_until_full_and_drain_preserves_arrival_order() {
        let b = MemoryBudget::unbounded();
        let r = round(3, &b);
        for p in 0..3u64 {
            let a = r.offer(p, 100 + p, 0, 1.0, &[p as f32; 8]).unwrap();
            assert_eq!(a, Admitted { version: 0, delta: 0 });
        }
        assert!(r.is_full());
        let drained = r.drain();
        assert_eq!(drained.iter().map(|e| e.party).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(r.collected(), 0);
        assert_eq!(r.drained(), 3);
    }

    #[test]
    fn duplicate_party_in_one_buffer_returns_accepted_nonce() {
        let b = MemoryBudget::unbounded();
        let r = round(4, &b);
        r.offer(7, 111, 0, 1.0, &[1.0; 4]).unwrap();
        assert!(matches!(
            r.offer(7, 999, 0, 1.0, &[1.0; 4]),
            Err(AsyncError::Duplicate { party: 7, nonce: 111 })
        ));
        // after the drain the party may upload again — new buffer, new dedup
        let _ = r.drain();
        assert!(r.offer(7, 999, 0, 1.0, &[1.0; 4]).is_ok());
    }

    #[test]
    fn eviction_is_oldest_version_first() {
        let b = MemoryBudget::unbounded();
        let r = round(2, &b);
        r.install(vec![0.0; 4]); // version 1
        r.install(vec![0.0; 4]); // version 2
        r.offer(0, 1, 0, 1.0, &[1.0; 4]).unwrap(); // oldest
        r.offer(1, 2, 2, 1.0, &[1.0; 4]).unwrap();
        // buffer full; a fresher update evicts party 0 (version 0)
        r.offer(2, 3, 1, 1.0, &[1.0; 4]).unwrap();
        assert_eq!(r.evicted(), 1);
        let parties: Vec<u64> = r.drain().iter().map(|e| e.party).collect();
        assert_eq!(parties, vec![1, 2]);
    }

    #[test]
    fn evicted_party_can_reupload_fresher() {
        let b = MemoryBudget::unbounded();
        let r = round(1, &b);
        r.install(vec![0.0; 4]);
        r.offer(5, 10, 0, 1.0, &[1.0; 4]).unwrap();
        // a fresher update from ANOTHER party evicts 5's stale one...
        r.offer(6, 11, 1, 1.0, &[1.0; 4]).unwrap();
        // ...and 5 is no longer "accepted": its retrained upload is admitted
        // once something fresher than version 1 displaces party 6
        r.install(vec![0.0; 4]);
        let a = r.offer(5, 12, 2, 1.0, &[1.0; 4]).unwrap();
        assert_eq!(a.delta, 0);
        assert_eq!(r.evicted(), 2);
    }

    #[test]
    fn incoming_oldest_is_rejected_stale_with_current_version() {
        let b = MemoryBudget::unbounded();
        let r = round(1, &b);
        r.install(vec![0.0; 4]);
        r.install(vec![0.0; 4]);
        r.offer(0, 1, 2, 1.0, &[1.0; 4]).unwrap();
        // full buffer, incoming version 1 < buffered version 2 → stale
        assert!(matches!(r.offer(1, 2, 1, 1.0, &[1.0; 4]), Err(AsyncError::Stale { version: 2 })));
        // version TIE also rejects the incomer: earliest arrival wins
        assert!(matches!(r.offer(2, 3, 2, 1.0, &[1.0; 4]), Err(AsyncError::Stale { version: 2 })));
        assert_eq!(r.evicted(), 0);
        assert_eq!(r.collected(), 1);
    }

    #[test]
    fn abort_mid_buffer_returns_every_reservation() {
        let b = MemoryBudget::new(1 << 16);
        let r = round(8, &b);
        for p in 0..5u64 {
            r.offer(p, p, 0, 1.0, &[1.0; 64]).unwrap();
        }
        assert_eq!(b.in_use(), 5 * 64 * 4);
        r.abort();
        assert_eq!(b.in_use(), 0, "abort must return every reservation");
        assert_eq!(r.collected(), 0);
    }

    #[test]
    fn eviction_and_drain_release_budget_bytes() {
        let b = MemoryBudget::new(2 * 64 * 4);
        let r = round(2, &b);
        r.offer(0, 1, 0, 1.0, &[1.0; 64]).unwrap();
        r.offer(1, 2, 1, 1.0, &[1.0; 64]).unwrap();
        assert_eq!(b.in_use(), 2 * 64 * 4);
        // budget is exactly full: the eviction must release its bytes
        // BEFORE the incoming reserve or this offer would OOM
        r.offer(2, 3, 2, 1.0, &[1.0; 64]).unwrap();
        assert_eq!(b.in_use(), 2 * 64 * 4);
        let drained = r.drain();
        assert_eq!(b.in_use(), 2 * 64 * 4, "drained entries still own their bytes");
        drop(drained);
        assert_eq!(b.in_use(), 0, "folding (dropping) a drained entry releases it");
    }

    #[test]
    fn memory_exhaustion_is_typed_and_leak_free() {
        let b = MemoryBudget::new(64 * 4);
        let r = round(4, &b);
        r.offer(0, 1, 0, 1.0, &[1.0; 64]).unwrap();
        assert!(matches!(r.offer(1, 2, 0, 1.0, &[1.0; 64]), Err(AsyncError::Memory(_))));
        assert_eq!(b.in_use(), 64 * 4, "failed offer must not leak or double-charge");
        assert_eq!(r.collected(), 1);
    }

    #[test]
    fn shape_is_pinned_by_first_offer() {
        let b = MemoryBudget::unbounded();
        let r = round(4, &b);
        r.offer(0, 1, 0, 1.0, &[1.0; 16]).unwrap();
        assert!(matches!(
            r.offer(1, 2, 0, 1.0, &[1.0; 17]),
            Err(AsyncError::ShapeMismatch { want: 16, got: 17 })
        ));
    }

    #[test]
    fn install_bumps_version_and_serves_model() {
        let b = MemoryBudget::unbounded();
        let r = round(2, &b);
        assert_eq!(r.version(), 0);
        assert!(r.model().is_none());
        assert_eq!(r.install(vec![1.5; 4]), 1);
        assert_eq!(r.version(), 1);
        assert_eq!(*r.model().unwrap(), vec![1.5; 4]);
        // deltas now reflect the new version
        let a = r.offer(0, 1, 0, 1.0, &[1.0; 4]).unwrap();
        assert_eq!(a, Admitted { version: 1, delta: 1 });
        let fresh = r.offer(1, 2, 1, 1.0, &[1.0; 4]).unwrap();
        assert_eq!(fresh.delta, 0);
    }

    #[test]
    fn upload_racing_a_publish_lands_in_the_next_buffer() {
        let b = MemoryBudget::unbounded();
        let r = round(2, &b);
        r.offer(0, 1, 0, 1.0, &[1.0; 4]).unwrap();
        r.offer(1, 2, 0, 1.0, &[1.0; 4]).unwrap();
        let first = r.drain();
        // the "racing" upload arrives between drain and install
        r.offer(2, 3, 0, 1.0, &[1.0; 4]).unwrap();
        r.install(vec![0.0; 4]);
        assert_eq!(first.len(), 2);
        let second = r.drain();
        assert_eq!(second.len(), 1, "racing upload folds into the NEXT buffer");
        assert_eq!(second[0].party, 2);
        assert_eq!(r.drained(), 3, "admitted exactly once, never dropped");
    }
}
