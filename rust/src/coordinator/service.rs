//! The adaptive aggregation service (paper §III-D, Algorithm 1).
//!
//! One facade owning both paths:
//!
//! * **small** — updates collected in node memory, fused by the XLA engine
//!   (AOT Pallas weighted-sum) with the multi-core parallel engine as the
//!   fallback for algorithms the fixed-K artifacts don't cover;
//! * **large** — updates land in the DFS, the Algorithm-1 monitor waits for
//!   threshold/timeout, and the Sparklet MapReduce job fuses them.
//!
//! *Seamless transition* (§III-D3): after each round the service predicts
//! the next round's class from the live registry count; when it flips to
//! Large the server's Ack tells parties to send their next update to the
//! store instead of the message-passing channel (and the Spark context is
//! spun up once, off the critical path).

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::ServiceConfig;
use crate::coordinator::{WorkloadClass, WorkloadClassifier};
use crate::dfs::{DfsClient, Monitor, MonitorOutcome};
use crate::engine::{AggregationEngine, EngineError, ParallelEngine, XlaEngine};
use crate::fusion::FusionAlgorithm;
use crate::mapreduce::{scheduler::JobConfig, ExecutorConfig, SparkContext};
use crate::metrics::Breakdown;
use crate::tensorstore::ModelUpdate;

#[derive(Debug)]
pub enum ServiceError {
    Engine(EngineError),
    Job(crate::mapreduce::JobError),
    Dfs(crate::dfs::DfsError),
    NoUpdates,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::Engine(e) => write!(f, "engine: {e}"),
            ServiceError::Job(e) => write!(f, "job: {e}"),
            ServiceError::Dfs(e) => write!(f, "dfs: {e}"),
            ServiceError::NoUpdates => write!(f, "no updates"),
        }
    }
}

impl std::error::Error for ServiceError {}

/// What one aggregation produced (the benches print these).
#[derive(Clone, Debug)]
pub struct ServiceReport {
    pub round: u32,
    pub class: WorkloadClass,
    pub engine: &'static str,
    pub parties: usize,
    pub partitions: usize,
    pub breakdown: Breakdown,
    pub monitor: Option<MonitorOutcome>,
}

pub struct AdaptiveService {
    pub classifier: WorkloadClassifier,
    cfg: ServiceConfig,
    dfs: DfsClient,
    monitor: Monitor,
    parallel: ParallelEngine,
    xla: Option<XlaEngine>,
    /// Spark context is started lazily on the first Large round (the
    /// §III-D3 one-time transition cost) and kept for later rounds.
    spark: Mutex<Option<Arc<SparkContext>>>,
    executor_cfg: ExecutorConfig,
}

impl AdaptiveService {
    pub fn new(
        cfg: ServiceConfig,
        dfs: DfsClient,
        xla: Option<XlaEngine>,
        executor_cfg: ExecutorConfig,
    ) -> AdaptiveService {
        let monitor = Monitor::new(dfs.namenode().clone());
        AdaptiveService {
            classifier: WorkloadClassifier::new(cfg.node.memory_bytes, cfg.memory_headroom),
            parallel: ParallelEngine::new(cfg.node.cores),
            monitor,
            dfs,
            xla,
            spark: Mutex::new(None),
            executor_cfg,
            cfg,
        }
    }

    pub fn dfs(&self) -> &DfsClient {
        &self.dfs
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Classify the coming round (Algorithm 1's `if S < M`).
    pub fn classify(&self, update_bytes: u64, parties: usize, algo: &dyn FusionAlgorithm) -> WorkloadClass {
        self.classifier.classify(update_bytes, parties, algo)
    }

    /// Predict whether parties should be redirected to the store for the
    /// *next* round (preemptive seamless transition).
    pub fn should_redirect(&self, update_bytes: u64, expected_parties: usize, algo: &dyn FusionAlgorithm) -> bool {
        self.classify(update_bytes, expected_parties, algo) == WorkloadClass::Large
    }

    /// Small-path aggregation over in-memory updates.  Prefers the XLA
    /// engine; falls back to the parallel engine when the artifact set
    /// doesn't cover the algorithm (Krum/Zeno, median with n∉{8,16,32}).
    pub fn aggregate_small(
        &self,
        algo: &dyn FusionAlgorithm,
        updates: &[ModelUpdate],
        round: u32,
    ) -> Result<(Vec<f32>, ServiceReport), ServiceError> {
        let mut bd = Breakdown::new();
        let (out, engine): (Vec<f32>, &'static str) = match &self.xla {
            Some(x) => match x.aggregate(algo, updates, &mut bd) {
                Ok(v) => (v, "xla"),
                Err(EngineError::Runtime(_)) => {
                    let v = self
                        .parallel
                        .aggregate(algo, updates, &mut bd)
                        .map_err(ServiceError::Engine)?;
                    (v, "parallel")
                }
                Err(e) => return Err(ServiceError::Engine(e)),
            },
            None => {
                let v = self
                    .parallel
                    .aggregate(algo, updates, &mut bd)
                    .map_err(ServiceError::Engine)?;
                (v, "parallel")
            }
        };
        Ok((
            out.clone(),
            ServiceReport {
                round,
                class: WorkloadClass::Small,
                engine,
                parties: updates.len(),
                partitions: 0,
                breakdown: bd,
                monitor: None,
            },
        ))
    }

    /// Get (or lazily start) the Spark context.
    pub fn spark(&self) -> Arc<SparkContext> {
        let mut guard = self.spark.lock().unwrap();
        if guard.is_none() {
            *guard = Some(Arc::new(SparkContext::start(
                self.dfs.clone(),
                self.executor_cfg.clone(),
            )));
        }
        guard.as_ref().unwrap().clone()
    }

    /// Whether the Spark context has been started (transition happened).
    pub fn spark_started(&self) -> bool {
        self.spark.lock().unwrap().is_some()
    }

    /// Large-path aggregation: monitor the round prefix, then MapReduce.
    /// `expected` is the monitor threshold (scaled by config threshold).
    pub fn aggregate_large(
        &self,
        algo: &dyn FusionAlgorithm,
        round: u32,
        expected: usize,
        update_bytes: u64,
    ) -> Result<(Vec<f32>, ServiceReport), ServiceError> {
        let prefix = DfsClient::round_prefix(round);
        let threshold = ((expected as f64) * self.cfg.monitor_threshold).ceil() as usize;
        let outcome = self.monitor.watch(
            &prefix,
            threshold,
            Duration::from_secs_f64(self.cfg.monitor_timeout_s),
        );
        if outcome.count() == 0 {
            return Err(ServiceError::NoUpdates);
        }
        let sc = self.spark();
        let mut bd = Breakdown::new();
        // The paper caches decoded RDDs for small models only.
        let cache = update_bytes < (64 << 20);
        let job = JobConfig { cache, ..Default::default() };
        let (out, partitions) = sc
            .aggregate(algo, &prefix, &job, &mut bd)
            .map_err(ServiceError::Job)?;
        // Publish the fused model back to the store (Fig 4 step ⑤).
        let fused_bytes = crate::tensorstore::f32s_as_bytes(&out).to_vec();
        self.dfs
            .write(&DfsClient::model_path(round), &fused_bytes)
            .map_err(ServiceError::Dfs)?;
        Ok((
            out.clone(),
            ServiceReport {
                round,
                class: WorkloadClass::Large,
                engine: "mapreduce",
                parties: outcome.count(),
                partitions,
                breakdown: bd,
                monitor: Some(outcome),
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfs::datanode::tempdir::TempDir;
    use crate::dfs::NameNode;
    use crate::engine::SerialEngine;
    use crate::fusion::{FedAvg, Krum};
    use crate::util::prop::all_close;
    use crate::util::rng::Rng;

    fn service(mem: u64) -> (AdaptiveService, TempDir) {
        let td = TempDir::new();
        let nn = NameNode::create(td.path(), 2, 2, 1 << 20).unwrap();
        let dfs = DfsClient::new(nn);
        let mut cfg = ServiceConfig::default();
        cfg.node.memory_bytes = mem;
        cfg.node.cores = 2;
        cfg.monitor_timeout_s = 5.0;
        let exec = ExecutorConfig { executors: 2, cores_per_executor: 1, ..Default::default() };
        (AdaptiveService::new(cfg, dfs, None, exec), td)
    }

    fn updates(n: usize, len: usize) -> Vec<ModelUpdate> {
        let mut rng = Rng::new(3);
        (0..n)
            .map(|p| {
                let mut d = vec![0f32; len];
                rng.fill_gaussian_f32(&mut d, 1.0);
                ModelUpdate::new(p as u64, 1.0 + p as f32, 0, d)
            })
            .collect()
    }

    #[test]
    fn small_path_parallel_fallback_matches_serial() {
        let (svc, _td) = service(1 << 30);
        let us = updates(8, 500);
        let (out, report) = svc.aggregate_small(&FedAvg, &us, 0).unwrap();
        assert_eq!(report.engine, "parallel");
        assert_eq!(report.class, WorkloadClass::Small);
        let mut bd = Breakdown::new();
        let want = SerialEngine::unbounded().aggregate(&FedAvg, &us, &mut bd).unwrap();
        all_close(&out, &want, 1e-4, 1e-5).unwrap();
    }

    #[test]
    fn large_path_monitor_plus_mapreduce() {
        let (svc, _td) = service(1 << 30);
        let us = updates(10, 300);
        let mut bd = Breakdown::new();
        for u in &us {
            let mut u = u.clone();
            u.round = 4;
            svc.dfs().put_update(&u, &mut bd).unwrap();
        }
        assert!(!svc.spark_started());
        let (out, report) = svc.aggregate_large(&FedAvg, 4, 10, 300 * 4).unwrap();
        assert!(svc.spark_started());
        assert_eq!(report.parties, 10);
        assert!(report.monitor.as_ref().unwrap().is_ready());
        assert!(report.partitions >= 1);
        // fused model published to the store
        assert!(svc.dfs().exists(&DfsClient::model_path(4)));
        let mut bd2 = Breakdown::new();
        let mut us4 = us.clone();
        for u in us4.iter_mut() {
            u.round = 4;
        }
        let want = SerialEngine::unbounded().aggregate(&FedAvg, &us4, &mut bd2).unwrap();
        all_close(&out, &want, 1e-4, 1e-5).unwrap();
    }

    #[test]
    fn classification_drives_redirect() {
        let (svc, _td) = service(10 << 20); // 10 MiB node
        // 2 × 1 MiB fits; 100 × 1 MiB does not
        assert!(!svc.should_redirect(1 << 20, 2, &FedAvg));
        assert!(svc.should_redirect(1 << 20, 100, &FedAvg));
    }

    #[test]
    fn krum_works_via_parallel_fallback() {
        let (svc, _td) = service(1 << 30);
        let us = updates(9, 64);
        let (_, report) = svc.aggregate_small(&Krum { byzantine_f: 1 }, &us, 0).unwrap();
        assert_eq!(report.engine, "parallel");
    }

    #[test]
    fn large_path_times_out_with_partial_set() {
        let (svc, _td) = service(1 << 20);
        let mut cfgd = svc.cfg.clone();
        cfgd.monitor_timeout_s = 0.05;
        let svc = AdaptiveService::new(
            cfgd,
            svc.dfs.clone(),
            None,
            ExecutorConfig { executors: 1, cores_per_executor: 1, ..Default::default() },
        );
        let mut bd = Breakdown::new();
        let mut u = updates(1, 50)[0].clone();
        u.round = 9;
        svc.dfs().put_update(&u, &mut bd).unwrap();
        let (_, report) = svc.aggregate_large(&FedAvg, 9, 100, 200).unwrap();
        assert!(!report.monitor.as_ref().unwrap().is_ready());
        assert_eq!(report.parties, 1);
    }

    #[test]
    fn empty_round_is_no_updates() {
        let (svc, _td) = service(1 << 20);
        let mut cfgd = svc.cfg.clone();
        cfgd.monitor_timeout_s = 0.02;
        let svc = AdaptiveService::new(
            cfgd,
            svc.dfs.clone(),
            None,
            ExecutorConfig::default(),
        );
        assert!(matches!(
            svc.aggregate_large(&FedAvg, 77, 5, 100),
            Err(ServiceError::NoUpdates)
        ));
    }
}
