//! The adaptive aggregation service (paper §III-D, Algorithm 1 —
//! generalized by the cost-aware planner).
//!
//! One facade owning both paths:
//!
//! * **small** — updates collected in node memory, fused by the XLA engine
//!   (AOT Pallas weighted-sum) with the multi-core parallel engine as the
//!   fallback for algorithms the fixed-K artifacts don't cover (a serial
//!   engine is also held for rounds the planner prices as too small to be
//!   worth thread launches);
//! * **large** — updates land in the DFS, the Algorithm-1 monitor waits for
//!   threshold/timeout, and the Sparklet MapReduce job fuses them.
//!
//! Dispatch is decided by the [`DispatchPlanner`]: each round it prices
//! serial/parallel/XLA single-node plans and the MapReduce path at every
//! candidate executor count, then selects under the configured
//! [`DispatchPolicy`] (`ServiceConfig::policy`).  The binary Algorithm-1
//! classifier remains the planner's feasibility oracle and is still
//! exposed directly ([`AdaptiveService::classify`]) for callers that only
//! need the small/large split.  After every round the observed wall-clock
//! feeds back into the planner ([`AdaptiveService::observe_round`]) and
//! the [`Autoscaler`] grows/shrinks the executor pool with hysteresis
//! instead of re-provisioning it statically.
//!
//! *Seamless transition* (§III-D3): after each round the service predicts
//! the next round's class from the live registry count; when it flips to
//! Large the server's Ack tells parties to send their next update to the
//! store instead of the message-passing channel (and the Spark context is
//! spun up once, off the critical path).

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::cluster::{CostModel, VirtualCluster};
use crate::config::ServiceConfig;
use crate::coordinator::{RoundError, WorkloadClass, WorkloadClassifier};
use crate::dfs::{DfsClient, Monitor, MonitorOutcome};
use crate::engine::{
    AggregationEngine, EngineError, ParallelEngine, SerialEngine, StreamingFold, XlaEngine,
};
use crate::fusion::FusionAlgorithm;
use crate::mapreduce::{scheduler::JobConfig, ExecutorConfig, SparkContext};
use crate::memsim::MemoryBudget;
use crate::metrics::{Breakdown, Stopwatch};
use crate::planner::{
    Autoscaler, AutoscalerConfig, CandidatePlan, DispatchPlanner, DispatchPolicy, PlanCost,
    PlanKind, PlannerConfig, PricingModel, RoundCalibration, RoundPlan, ScaleDecision,
};
use crate::tensorstore::ModelUpdate;

#[derive(Debug)]
pub enum ServiceError {
    Engine(EngineError),
    Job(crate::mapreduce::JobError),
    Dfs(crate::dfs::DfsError),
    /// A round-state protocol error (wrong phase / shape / mode).
    Round(RoundError),
    NoUpdates,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::Engine(e) => write!(f, "engine: {e}"),
            ServiceError::Job(e) => write!(f, "job: {e}"),
            ServiceError::Dfs(e) => write!(f, "dfs: {e}"),
            ServiceError::Round(e) => write!(f, "round: {e}"),
            ServiceError::NoUpdates => write!(f, "no updates"),
        }
    }
}

impl std::error::Error for ServiceError {}

/// What one aggregation produced (the benches print these).
#[derive(Clone, Debug)]
pub struct ServiceReport {
    pub round: u32,
    pub class: WorkloadClass,
    pub engine: &'static str,
    pub parties: usize,
    pub partitions: usize,
    /// Executor containers the round ran on (0 for single-node engines).
    pub executors: usize,
    pub breakdown: Breakdown,
    pub monitor: Option<MonitorOutcome>,
    /// The planner's predicted (latency, $) for the chosen plan, when the
    /// round went through [`AdaptiveService::aggregate_planned`].
    pub predicted: Option<PlanCost>,
}

pub struct AdaptiveService {
    pub classifier: WorkloadClassifier,
    cfg: ServiceConfig,
    dfs: DfsClient,
    monitor: Monitor,
    serial: SerialEngine,
    parallel: ParallelEngine,
    xla: Option<XlaEngine>,
    /// Spark context is started lazily on the first Large round (the
    /// §III-D3 one-time transition cost) and kept for later rounds.
    spark: Mutex<Option<Arc<SparkContext>>>,
    executor_cfg: ExecutorConfig,
    planner: Mutex<DispatchPlanner>,
    autoscaler: Mutex<Autoscaler>,
}

impl AdaptiveService {
    pub fn new(
        cfg: ServiceConfig,
        dfs: DfsClient,
        xla: Option<XlaEngine>,
        executor_cfg: ExecutorConfig,
    ) -> AdaptiveService {
        let monitor = Monitor::new(dfs.namenode().clone());
        let classifier = WorkloadClassifier::new(cfg.node.memory_bytes, cfg.memory_headroom);
        let max_executors = cfg.max_executors.max(1);
        let planner = DispatchPlanner::new(
            classifier.clone(),
            VirtualCluster::new(cfg.cluster.clone(), CostModel::nominal()),
            PricingModel {
                node_usd_per_s: cfg.node_usd_per_s,
                executor_usd_per_s: cfg.executor_usd_per_s,
                ..PricingModel::default()
            },
            PlannerConfig {
                policy: cfg.policy,
                max_executors,
                cores_per_executor: executor_cfg.cores_per_executor.max(1),
                node_cores: cfg.node.cores.max(1),
                // the FL server shards its streaming ingest one lane per
                // core — price the plan against that width
                ingest_lanes: cfg.node.cores.max(1),
                // the reactor's fold worker pool bounds how many of those
                // lanes can actually fold; 0 = sized to the node's cores
                reactor_workers: if cfg.reactor_workers == 0 {
                    cfg.node.cores.max(1)
                } else {
                    cfg.reactor_workers
                },
                edges: cfg.edges,
                xla_available: xla.is_some(),
                feedback_beta: 0.3,
                expected_participation: cfg.expected_participation,
                // async candidates are only enumerated when the service is
                // actually running the FedBuff ingest mode
                async_buffer: if cfg.async_mode { cfg.async_buffer.max(1) } else { 0 },
                staleness_exponent: cfg.staleness_exponent,
                // the fleet's configured uplink encoding prices every
                // ingest-coupled candidate
                encoding: cfg.encoding,
            },
        );
        let autoscaler = Autoscaler::new(
            AutoscalerConfig { max_executors, ..Default::default() },
            executor_cfg.executors.max(1),
        );
        AdaptiveService {
            classifier,
            serial: SerialEngine::unbounded(),
            parallel: ParallelEngine::new(cfg.node.cores),
            monitor,
            dfs,
            xla,
            spark: Mutex::new(None),
            executor_cfg,
            planner: Mutex::new(planner),
            autoscaler: Mutex::new(autoscaler),
            cfg,
        }
    }

    pub fn dfs(&self) -> &DfsClient {
        &self.dfs
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Classify the coming round (Algorithm 1's `if S < M`).  This is the
    /// binary buffered-vs-distributed oracle; [`AdaptiveService::classify_full`]
    /// adds the streaming middle class.
    pub fn classify(&self, update_bytes: u64, parties: usize, algo: &dyn FusionAlgorithm) -> WorkloadClass {
        self.classifier.classify(update_bytes, parties, algo)
    }

    /// Three-way classification: rounds past the buffered ceiling stream
    /// on the node when the algorithm decomposes and the O(C) working set
    /// fits; only the rest go distributed.
    pub fn classify_full(&self, update_bytes: u64, parties: usize, algo: &dyn FusionAlgorithm) -> WorkloadClass {
        self.classifier.classify_with_streaming(update_bytes, parties, algo)
    }

    /// The hierarchy gate (see [`WorkloadClassifier::hierarchy_feasible`]):
    /// whether this node can fold forwarded partial aggregates (root) or
    /// pre-fold a cohort into one (relay) for this algorithm.
    pub fn hierarchy_feasible(&self, update_bytes: u64, algo: &dyn FusionAlgorithm) -> bool {
        self.classifier.hierarchy_feasible(update_bytes, algo)
    }

    /// Predict whether parties should be redirected to the store for the
    /// *next* round (preemptive seamless transition).  Streaming rounds
    /// keep the message-passing channel — the whole point is that they no
    /// longer need the store.
    pub fn should_redirect(&self, update_bytes: u64, expected_parties: usize, algo: &dyn FusionAlgorithm) -> bool {
        self.classify_full(update_bytes, expected_parties, algo) == WorkloadClass::Large
    }

    // ------------------------------------------------------------------
    // Cost-aware planning
    // ------------------------------------------------------------------

    /// Price every candidate plan for the coming round and select under
    /// the configured policy.  The warm executor-pool size is taken from
    /// the live Spark context so distributed candidates only pay spin-up
    /// for the executors they would add.
    pub fn plan_round(
        &self,
        update_bytes: u64,
        parties: usize,
        algo: &dyn FusionAlgorithm,
    ) -> RoundPlan {
        let current = {
            let guard = self.spark.lock().unwrap();
            guard.as_ref().map(|sc| sc.current_executors()).unwrap_or(0)
        };
        self.planner.lock().unwrap().plan(update_bytes, parties, algo, current)
    }

    /// Feed a plan's desired executor count through the autoscaler and,
    /// when it decides to act, resize the live pool.  Returns the pool's
    /// target size after the decision.
    pub fn apply_scale(&self, plan: &RoundPlan) -> usize {
        let desired = plan.chosen.kind.executors();
        let decision = { self.autoscaler.lock().unwrap().observe(desired) };
        match decision {
            ScaleDecision::ScaleTo(n) => {
                let sc = { self.spark.lock().unwrap().as_ref().cloned() };
                if let Some(sc) = sc {
                    sc.scale_to(n);
                }
                n
            }
            ScaleDecision::Hold(n) => n,
        }
    }

    /// Record a round's observed wall-clock against its chosen plan: the
    /// planner's EWMA corrections absorb the drift and the pair lands in
    /// the calibration ledger.  `upload_s` is the store-upload portion of
    /// `observed_s` (0 for single-node rounds or when unknown), priced at
    /// the node-only rate exactly like the prediction.
    pub fn observe_round(
        &self,
        round: u32,
        chosen: &CandidatePlan,
        observed_s: f64,
        upload_s: f64,
    ) -> RoundCalibration {
        self.planner.lock().unwrap().observe_split(round, chosen, observed_s, upload_s)
    }

    /// The full predicted-vs-observed calibration history.
    pub fn calibration_ledger(&self) -> Vec<RoundCalibration> {
        self.planner.lock().unwrap().ledger().to_vec()
    }

    /// Record a sealed round's delivered-vs-expected turnout: the planner
    /// prices the next round against the fleet's observed participation
    /// (K·p uploads) instead of the full register.  Returns the updated
    /// factor.
    pub fn observe_participation(&self, delivered: usize, expected: usize) -> f64 {
        self.planner.lock().unwrap().observe_participation(delivered, expected)
    }

    /// Blend the registry's heartbeat-derived live fraction into the same
    /// participation EWMA sealed rounds feed (see
    /// [`DispatchPlanner::observe_liveness`]).  Returns the updated factor.
    pub fn observe_liveness(&self, live: usize, registered: usize) -> f64 {
        self.planner.lock().unwrap().observe_liveness(live, registered)
    }

    /// The participation factor the planner currently prices against.
    pub fn participation(&self) -> f64 {
        self.planner.lock().unwrap().participation()
    }

    pub fn policy(&self) -> DispatchPolicy {
        self.planner.lock().unwrap().policy()
    }

    /// Change the cost/latency trade-off knob between rounds.
    pub fn set_policy(&self, policy: DispatchPolicy) {
        self.planner.lock().unwrap().set_policy(policy);
    }

    /// Swap freshly calibrated cost-model constants into the planner
    /// (e.g. from [`CostModel::calibrate`]).
    pub fn recalibrate(&self, cost: CostModel) {
        self.planner.lock().unwrap().set_cost_model(cost);
    }

    /// One fully planned round over in-memory updates: plan → autoscale →
    /// dispatch to the chosen substrate (uploading to the store first for
    /// distributed plans) → feed the observed wall-clock back into the
    /// cost model.
    pub fn aggregate_planned(
        &self,
        algo: &dyn FusionAlgorithm,
        updates: &[ModelUpdate],
        round: u32,
    ) -> Result<(Vec<f32>, ServiceReport), ServiceError> {
        if updates.is_empty() {
            return Err(ServiceError::NoUpdates);
        }
        let update_bytes = updates.iter().map(|u| u.data.len() as u64 * 4).max().unwrap_or(0);
        let plan = self.plan_round(update_bytes, updates.len(), algo);
        let pool_target = self.apply_scale(&plan);
        // The autoscaler may hold the pool at a size other than the chosen
        // plan's k (hysteresis); the round then actually runs at the held
        // size, so dispatch/observe against THAT candidate's prediction.
        let mut chosen = plan.chosen;
        if let PlanKind::Distributed { executors } = chosen.kind {
            if executors != pool_target {
                if let Some(c) = plan
                    .candidates
                    .iter()
                    .find(|c| c.kind == PlanKind::Distributed { executors: pool_target })
                {
                    chosen = *c;
                }
            }
        }
        let t0 = Instant::now();
        let (out, mut report, upload_s) = match chosen.kind {
            PlanKind::Distributed { .. } => {
                let mut bd = Breakdown::new();
                for u in updates {
                    if u.round == round {
                        self.dfs.put_update(u, &mut bd).map_err(ServiceError::Dfs)?;
                    } else {
                        let mut u = u.clone();
                        u.round = round;
                        self.dfs.put_update(&u, &mut bd).map_err(ServiceError::Dfs)?;
                    }
                }
                let upload_s = t0.elapsed().as_secs_f64();
                let (out, report) =
                    self.aggregate_large(algo, round, updates.len(), update_bytes)?;
                (out, report, upload_s)
            }
            PlanKind::Streaming => {
                let (out, report) = self.aggregate_streaming(algo, updates, round)?;
                (out, report, 0.0)
            }
            // A hierarchical plan describes a multi-DC deployment (relays +
            // root over TCP); over an in-memory batch the root's fold IS
            // the streaming fold, so execute that — identical algebra — and
            // let the observation calibrate the hierarchical family.
            PlanKind::Hierarchical { .. } => {
                let (out, report) = self.aggregate_streaming(algo, updates, round)?;
                (out, report, 0.0)
            }
            // An async plan describes the live buffered-publish ingest mode
            // (the server's AsyncRound); over an already-collected batch
            // every update is fresh (δ = 0, discount exactly 1), so the
            // fold IS the streaming fold — execute that and let the
            // observation calibrate the async family.
            PlanKind::Async { .. } => {
                let (out, report) = self.aggregate_streaming(algo, updates, round)?;
                (out, report, 0.0)
            }
            kind => {
                let (out, report) = self.aggregate_single(kind, algo, updates, round)?;
                (out, report, 0.0)
            }
        };
        let observed_s = t0.elapsed().as_secs_f64();
        self.planner
            .lock()
            .unwrap()
            .observe_split(round, &chosen, observed_s, upload_s);
        report.predicted = Some(chosen.cost);
        // The report's class is the round's feasibility class from the
        // plan; `engine` names the substrate the policy actually chose
        // (a Small round may well run on the streaming fold).
        report.class = plan.class;
        Ok((out, report))
    }

    // ------------------------------------------------------------------
    // Execution paths
    // ------------------------------------------------------------------

    /// Small-path aggregation over in-memory updates.  Prefers the XLA
    /// engine; falls back to the parallel engine when the artifact set
    /// doesn't cover the algorithm (Krum/Zeno, median with n∉{8,16,32}).
    pub fn aggregate_small(
        &self,
        algo: &dyn FusionAlgorithm,
        updates: &[ModelUpdate],
        round: u32,
    ) -> Result<(Vec<f32>, ServiceReport), ServiceError> {
        self.aggregate_single(PlanKind::Xla, algo, updates, round)
    }

    /// Run a single-node plan.  `PlanKind::Xla` keeps the historical
    /// fallback chain (XLA, then parallel); `Serial`/`Parallel` run their
    /// engine directly.
    fn aggregate_single(
        &self,
        kind: PlanKind,
        algo: &dyn FusionAlgorithm,
        updates: &[ModelUpdate],
        round: u32,
    ) -> Result<(Vec<f32>, ServiceReport), ServiceError> {
        let mut bd = Breakdown::new();
        let (out, engine): (Vec<f32>, &'static str) = match kind {
            PlanKind::Serial => (
                self.serial.aggregate(algo, updates, &mut bd).map_err(ServiceError::Engine)?,
                "serial",
            ),
            PlanKind::Xla => match &self.xla {
                Some(x) => match x.aggregate(algo, updates, &mut bd) {
                    Ok(v) => (v, "xla"),
                    Err(EngineError::Runtime(_)) => {
                        let v = self
                            .parallel
                            .aggregate(algo, updates, &mut bd)
                            .map_err(ServiceError::Engine)?;
                        (v, "parallel")
                    }
                    Err(e) => return Err(ServiceError::Engine(e)),
                },
                None => {
                    let v = self
                        .parallel
                        .aggregate(algo, updates, &mut bd)
                        .map_err(ServiceError::Engine)?;
                    (v, "parallel")
                }
            },
            _ => (
                self.parallel.aggregate(algo, updates, &mut bd).map_err(ServiceError::Engine)?,
                "parallel",
            ),
        };
        Ok((
            out.clone(),
            ServiceReport {
                round,
                class: WorkloadClass::Small,
                engine,
                parties: updates.len(),
                partitions: 0,
                executors: 0,
                breakdown: bd,
                monitor: None,
                predicted: None,
            },
        ))
    }

    /// Streaming-path aggregation over a ready update sequence: fold each
    /// update into one O(C) accumulator and finalize — the substrate the
    /// planner prices as `PlanKind::Streaming`.  Peak engine memory is the
    /// accumulator, independent of the party count, which is what lets
    /// rounds past the Fig 1 buffered ceiling stay on the node.  (On the
    /// coordinator's live ingest path the same fold runs inside
    /// [`RoundState`](crate::coordinator::RoundState) as updates arrive,
    /// overlapping ingest and compute; this entry point drives it over an
    /// already-collected batch so the planner can dispatch to it.)
    pub fn aggregate_streaming(
        &self,
        algo: &dyn FusionAlgorithm,
        updates: &[ModelUpdate],
        round: u32,
    ) -> Result<(Vec<f32>, ServiceReport), ServiceError> {
        if updates.is_empty() {
            return Err(ServiceError::NoUpdates);
        }
        let mut bd = Breakdown::new();
        let mut sw = Stopwatch::start();
        let mut fold = StreamingFold::new(
            algo,
            self.cfg.node.cores.max(1),
            MemoryBudget::unbounded(),
        )
        .map_err(ServiceError::Engine)?;
        for u in updates {
            fold.fold(algo, u).map_err(ServiceError::Engine)?;
        }
        sw.lap_into(&mut bd, "fold");
        let out = fold.finish(algo).map_err(ServiceError::Engine)?;
        sw.lap_into(&mut bd, "reduce");
        Ok((
            out,
            ServiceReport {
                round,
                class: WorkloadClass::Streaming,
                engine: "streaming",
                parties: updates.len(),
                partitions: 0,
                executors: 0,
                breakdown: bd,
                monitor: None,
                predicted: None,
            },
        ))
    }

    /// Get (or lazily start) the Spark context.  The pool is started
    /// directly at the autoscaler's current target so one provisioning
    /// event pays the spin-up delay exactly once.
    pub fn spark(&self) -> Arc<SparkContext> {
        let mut guard = self.spark.lock().unwrap();
        if guard.is_none() {
            let target = self.autoscaler.lock().unwrap().current();
            let mut exec_cfg = self.executor_cfg.clone();
            exec_cfg.executors = target;
            *guard = Some(Arc::new(SparkContext::start(self.dfs.clone(), exec_cfg)));
        }
        guard.as_ref().unwrap().clone()
    }

    /// Whether the Spark context has been started (transition happened).
    pub fn spark_started(&self) -> bool {
        self.spark.lock().unwrap().is_some()
    }

    /// Large-path aggregation: monitor the round prefix, then MapReduce.
    /// `expected` is the monitor threshold (scaled by config threshold).
    pub fn aggregate_large(
        &self,
        algo: &dyn FusionAlgorithm,
        round: u32,
        expected: usize,
        update_bytes: u64,
    ) -> Result<(Vec<f32>, ServiceReport), ServiceError> {
        let prefix = DfsClient::round_prefix(round);
        let threshold = ((expected as f64) * self.cfg.monitor_threshold).ceil() as usize;
        let outcome = self.monitor.watch(
            &prefix,
            threshold,
            Duration::from_secs_f64(self.cfg.monitor_timeout_s),
        );
        if outcome.count() == 0 {
            return Err(ServiceError::NoUpdates);
        }
        let sc = self.spark();
        let mut bd = Breakdown::new();
        // The paper caches decoded RDDs for small models only.
        let cache = update_bytes < (64 << 20);
        let job = JobConfig { cache, ..Default::default() };
        let (out, partitions) = sc
            .aggregate(algo, &prefix, &job, &mut bd)
            .map_err(ServiceError::Job)?;
        // Publish the fused model back to the store (Fig 4 step ⑤).
        let fused_bytes = crate::tensorstore::f32s_as_bytes(&out).to_vec();
        self.dfs
            .write(&DfsClient::model_path(round), &fused_bytes)
            .map_err(ServiceError::Dfs)?;
        Ok((
            out.clone(),
            ServiceReport {
                round,
                class: WorkloadClass::Large,
                engine: "mapreduce",
                parties: outcome.count(),
                partitions,
                executors: sc.current_executors(),
                breakdown: bd,
                monitor: Some(outcome),
                predicted: None,
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfs::datanode::tempdir::TempDir;
    use crate::dfs::NameNode;
    use crate::engine::SerialEngine;
    use crate::fusion::{FedAvg, Krum};
    use crate::util::prop::all_close;
    use crate::util::rng::Rng;

    fn service(mem: u64) -> (AdaptiveService, TempDir) {
        let td = TempDir::new();
        let nn = NameNode::create(td.path(), 2, 2, 1 << 20).unwrap();
        let dfs = DfsClient::new(nn);
        let mut cfg = ServiceConfig::default();
        cfg.node.memory_bytes = mem;
        cfg.node.cores = 2;
        cfg.monitor_timeout_s = 5.0;
        let exec = ExecutorConfig { executors: 2, cores_per_executor: 1, ..Default::default() };
        (AdaptiveService::new(cfg, dfs, None, exec), td)
    }

    fn updates(n: usize, len: usize) -> Vec<ModelUpdate> {
        let mut rng = Rng::new(3);
        (0..n)
            .map(|p| {
                let mut d = vec![0f32; len];
                rng.fill_gaussian_f32(&mut d, 1.0);
                ModelUpdate::new(p as u64, 1.0 + p as f32, 0, d)
            })
            .collect()
    }

    #[test]
    fn small_path_parallel_fallback_matches_serial() {
        let (svc, _td) = service(1 << 30);
        let us = updates(8, 500);
        let (out, report) = svc.aggregate_small(&FedAvg, &us, 0).unwrap();
        assert_eq!(report.engine, "parallel");
        assert_eq!(report.class, WorkloadClass::Small);
        let mut bd = Breakdown::new();
        let want = SerialEngine::unbounded().aggregate(&FedAvg, &us, &mut bd).unwrap();
        all_close(&out, &want, 1e-4, 1e-5).unwrap();
    }

    #[test]
    fn large_path_monitor_plus_mapreduce() {
        let (svc, _td) = service(1 << 30);
        let us = updates(10, 300);
        let mut bd = Breakdown::new();
        for u in &us {
            let mut u = u.clone();
            u.round = 4;
            svc.dfs().put_update(&u, &mut bd).unwrap();
        }
        assert!(!svc.spark_started());
        let (out, report) = svc.aggregate_large(&FedAvg, 4, 10, 300 * 4).unwrap();
        assert!(svc.spark_started());
        assert_eq!(report.parties, 10);
        assert!(report.monitor.as_ref().unwrap().is_ready());
        assert!(report.partitions >= 1);
        assert!(report.executors >= 1);
        // fused model published to the store
        assert!(svc.dfs().exists(&DfsClient::model_path(4)));
        let mut bd2 = Breakdown::new();
        let mut us4 = us.clone();
        for u in us4.iter_mut() {
            u.round = 4;
        }
        let want = SerialEngine::unbounded().aggregate(&FedAvg, &us4, &mut bd2).unwrap();
        all_close(&out, &want, 1e-4, 1e-5).unwrap();
    }

    #[test]
    fn classification_drives_redirect() {
        use crate::fusion::CoordMedian;
        let (svc, _td) = service(10 << 20); // 10 MiB node
        // 2 × 1 MiB fits buffered: no redirect
        assert!(!svc.should_redirect(1 << 20, 2, &FedAvg));
        // 100 × 1 MiB spills the buffer, but FedAvg streams in O(C):
        // the round STAYS on the message-passing channel
        assert!(!svc.should_redirect(1 << 20, 100, &FedAvg));
        assert_eq!(svc.classify_full(1 << 20, 100, &FedAvg), WorkloadClass::Streaming);
        // holistic algorithms cannot stream: redirect to the store
        assert!(svc.should_redirect(1 << 20, 100, &CoordMedian));
        // nor can updates whose O(C) working set alone exceeds the node
        assert!(svc.should_redirect(8 << 20, 100, &FedAvg));
    }

    #[test]
    fn krum_works_via_parallel_fallback() {
        let (svc, _td) = service(1 << 30);
        let us = updates(9, 64);
        let (_, report) = svc.aggregate_small(&Krum { byzantine_f: 1 }, &us, 0).unwrap();
        assert_eq!(report.engine, "parallel");
    }

    #[test]
    fn large_path_times_out_with_partial_set() {
        let (svc, _td) = service(1 << 20);
        let mut cfgd = svc.cfg.clone();
        cfgd.monitor_timeout_s = 0.05;
        let svc = AdaptiveService::new(
            cfgd,
            svc.dfs.clone(),
            None,
            ExecutorConfig { executors: 1, cores_per_executor: 1, ..Default::default() },
        );
        let mut bd = Breakdown::new();
        let mut u = updates(1, 50)[0].clone();
        u.round = 9;
        svc.dfs().put_update(&u, &mut bd).unwrap();
        let (_, report) = svc.aggregate_large(&FedAvg, 9, 100, 200).unwrap();
        assert!(!report.monitor.as_ref().unwrap().is_ready());
        assert_eq!(report.parties, 1);
    }

    #[test]
    fn empty_round_is_no_updates() {
        let (svc, _td) = service(1 << 20);
        let mut cfgd = svc.cfg.clone();
        cfgd.monitor_timeout_s = 0.02;
        let svc = AdaptiveService::new(
            cfgd,
            svc.dfs.clone(),
            None,
            ExecutorConfig::default(),
        );
        assert!(matches!(
            svc.aggregate_large(&FedAvg, 77, 5, 100),
            Err(ServiceError::NoUpdates)
        ));
    }

    #[test]
    fn planned_small_round_runs_single_node_and_matches_serial() {
        let (svc, _td) = service(1 << 30);
        let us = updates(8, 500);
        let (out, report) = svc.aggregate_planned(&FedAvg, &us, 0).unwrap();
        assert_eq!(report.class, WorkloadClass::Small);
        assert!(
            matches!(report.engine, "serial" | "parallel" | "streaming"),
            "{}",
            report.engine
        );
        assert!(report.predicted.is_some());
        let mut bd = Breakdown::new();
        let want = SerialEngine::unbounded().aggregate(&FedAvg, &us, &mut bd).unwrap();
        all_close(&out, &want, 1e-4, 1e-5).unwrap();
        // the round landed in the calibration ledger
        let ledger = svc.calibration_ledger();
        assert_eq!(ledger.len(), 1);
        assert!(!ledger[0].kind.is_distributed());
        assert!(ledger[0].observed_s > 0.0);
    }

    #[test]
    fn planned_spill_round_streams_on_the_node() {
        // 1 MiB node: 10 × 200 KB spills the buffer, but the O(C) fold
        // fits — the round that used to redirect to MapReduce by default
        // now streams, with no store hop and no executors.
        let (svc, _td) = service(1 << 20);
        let us = updates(10, 50_000);
        let (out, report) = svc.aggregate_planned(&FedAvg, &us, 3).unwrap();
        assert_eq!(report.class, WorkloadClass::Streaming);
        assert_eq!(report.engine, "streaming");
        assert_eq!(report.executors, 0);
        assert!(!svc.spark_started(), "streaming must not spin up Spark");
        assert!(report.predicted.is_some());
        let mut bd = Breakdown::new();
        let want = SerialEngine::unbounded().aggregate(&FedAvg, &us, &mut bd).unwrap();
        all_close(&out, &want, 1e-4, 1e-5).unwrap();
        let ledger = svc.calibration_ledger();
        assert_eq!(ledger.len(), 1);
        assert_eq!(ledger[0].kind, PlanKind::Streaming);
    }

    #[test]
    fn planned_holistic_round_uploads_and_goes_distributed() {
        use crate::fusion::CoordMedian;
        // median cannot stream, so the same spilling round takes the
        // store + MapReduce path exactly as before.
        let (svc, _td) = service(1 << 20);
        let us = updates(10, 50_000);
        let (out, report) = svc.aggregate_planned(&CoordMedian, &us, 3).unwrap();
        assert_eq!(report.class, WorkloadClass::Large);
        assert_eq!(report.engine, "mapreduce");
        assert!(report.executors >= 1);
        assert!(svc.spark_started());
        assert!(report.predicted.is_some());
        let mut us3 = us.clone();
        for u in us3.iter_mut() {
            u.round = 3;
        }
        let mut bd = Breakdown::new();
        let want = SerialEngine::unbounded().aggregate(&CoordMedian, &us3, &mut bd).unwrap();
        all_close(&out, &want, 1e-4, 1e-5).unwrap();
        let ledger = svc.calibration_ledger();
        assert_eq!(ledger.len(), 1);
        assert!(ledger[0].kind.is_distributed());
    }

    #[test]
    fn streaming_path_matches_serial() {
        let (svc, _td) = service(1 << 30);
        let us = updates(12, 700);
        let (out, report) = svc.aggregate_streaming(&FedAvg, &us, 5).unwrap();
        assert_eq!(report.engine, "streaming");
        assert_eq!(report.class, WorkloadClass::Streaming);
        assert_eq!(report.parties, 12);
        assert!(report.breakdown.phases().iter().any(|(p, _)| p == "fold"));
        let mut bd = Breakdown::new();
        let want = SerialEngine::unbounded().aggregate(&FedAvg, &us, &mut bd).unwrap();
        all_close(&out, &want, 1e-4, 1e-5).unwrap();
        // holistic algorithms are rejected, empty rounds are NoUpdates
        assert!(matches!(
            svc.aggregate_streaming(&crate::fusion::CoordMedian, &us, 5),
            Err(ServiceError::Engine(_))
        ));
        assert!(matches!(
            svc.aggregate_streaming(&FedAvg, &[], 5),
            Err(ServiceError::NoUpdates)
        ));
    }

    #[test]
    fn planned_rounds_feed_calibration_and_stay_stable() {
        // A mixed small/spilling trace: dispatch keeps matching the class
        // and the ledger records every round.  The spilling rounds stream
        // (FedAvg decomposes) instead of paying for the store + Spark.
        let (svc, _td) = service(1 << 20);
        let small = updates(3, 200);
        let spill = updates(8, 50_000);
        for round in 0..4u32 {
            let us = if round % 2 == 0 { &small } else { &spill };
            let (_, report) = svc.aggregate_planned(&FedAvg, us, round).unwrap();
            if round % 2 == 0 {
                assert_eq!(report.class, WorkloadClass::Small, "round {round}");
            } else {
                assert_eq!(report.engine, "streaming", "round {round}");
                assert_eq!(report.class, WorkloadClass::Streaming, "round {round}");
            }
        }
        assert_eq!(svc.calibration_ledger().len(), 4);
        assert!(!svc.spark_started());
    }

    #[test]
    fn policy_knob_is_settable() {
        let (svc, _td) = service(1 << 30);
        assert_eq!(svc.policy(), DispatchPolicy::Balanced(0.5));
        svc.set_policy(DispatchPolicy::MinCost);
        assert_eq!(svc.policy(), DispatchPolicy::MinCost);
    }

    #[test]
    fn planned_empty_round_is_no_updates() {
        let (svc, _td) = service(1 << 30);
        assert!(matches!(
            svc.aggregate_planned(&FedAvg, &[], 0),
            Err(ServiceError::NoUpdates)
        ));
    }
}
