//! Round state machine: each FL round collects updates (in memory, folded
//! on arrival, or in the store, depending on the classified path),
//! aggregates, and publishes the fused model for parties to fetch.
//!
//! Two ingest modes:
//!
//! * **buffered** ([`RoundState::new`]) — every update is parked in node
//!   memory until `begin_aggregation` hands the whole set to a batch
//!   engine: K reservations of O(C) each, the paper's Fig 1 party
//!   ceiling;
//! * **streaming** ([`RoundState::new_streaming`]) — each arriving update
//!   folds into one of S shard-local O(C) accumulators
//!   ([`ShardedFold`]) and its buffer is released immediately: at most
//!   S reservations against the node budget (plus the transient in-flight
//!   updates), independent of the party count.  The round-level mutex is
//!   held only long enough to grab the shard set — concurrent connection
//!   handlers fold in parallel, contending 1/S as often as the global
//!   lock they replaced.
//!
//! Phase misuse and shape mismatches surface as [`RoundError`] — a
//! misbehaving party can no longer crash the coordinator with an assert.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::WorkloadClass;
use crate::engine::{EngineError, FoldError, ShardedFold};
use crate::fusion::{FusionAlgorithm, FusionError};
use crate::memsim::{MemoryBudget, OutOfMemory, Reservation};
use crate::tensorstore::{ModelUpdate, ModelUpdateView};

/// Lifecycle phase of a round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundPhase {
    Collecting,
    Aggregating,
    Published,
}

/// What went wrong with a round-state operation.  These are *protocol*
/// errors: the coordinator reports them to the offending party (or caller)
/// and keeps serving everyone else.
#[derive(Debug)]
pub enum RoundError {
    /// The operation is only valid in `expected`; the round is in `actual`.
    WrongPhase { round: u32, expected: RoundPhase, actual: RoundPhase },
    /// An update disagreed with the round's established parameter count.
    ShapeMismatch { want: usize, got: usize },
    /// The node budget is exhausted (the Fig 1 ceiling, as an error).
    Memory(OutOfMemory),
    /// A streaming-only operation was called on a buffered round.
    NotStreaming,
    /// A buffered-only operation was called on a streaming round.
    NotBuffered,
    /// The streaming fold failed below the coordinator.
    Engine(EngineError),
}

impl std::fmt::Display for RoundError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RoundError::WrongPhase { round, expected, actual } => {
                write!(f, "round {round} is {actual:?}, not {expected:?}")
            }
            RoundError::ShapeMismatch { want, got } => {
                write!(f, "update length {got} != round's {want}")
            }
            RoundError::Memory(e) => write!(f, "memory: {e}"),
            RoundError::NotStreaming => write!(f, "round is buffered, not streaming"),
            RoundError::NotBuffered => write!(f, "round is streaming, not buffered"),
            RoundError::Engine(e) => write!(f, "engine: {e}"),
        }
    }
}

impl std::error::Error for RoundError {}

impl From<OutOfMemory> for RoundError {
    fn from(e: OutOfMemory) -> Self {
        RoundError::Memory(e)
    }
}

impl From<EngineError> for RoundError {
    fn from(e: EngineError) -> Self {
        match e {
            EngineError::Memory(m) => RoundError::Memory(m),
            EngineError::Fusion(FusionError::ShapeMismatch { want, got }) => {
                RoundError::ShapeMismatch { want, got }
            }
            other => RoundError::Engine(other),
        }
    }
}

/// How a round holds what parties sent so far.
enum IngestState {
    /// Small path: updates parked until aggregation, each charged O(C).
    Buffered {
        updates: Vec<(ModelUpdate, Reservation)>,
        /// Parameter count fixed by the first ingested update.
        len: Option<usize>,
    },
    /// Streaming path: S shard-local O(C) folds; buffers released on
    /// arrival.  Behind an `Arc` so the hot path clones the handle under
    /// the state lock and folds *outside* it — the `ShardedFold`'s seal
    /// makes the drop of the lock safe against a racing finish.
    Streaming {
        fold: Arc<ShardedFold>,
        algo: Arc<dyn FusionAlgorithm>,
    },
    /// Updates (or the fold) have been handed to the aggregation step.
    Drained,
}

/// One round's mutable state.
pub struct RoundState {
    pub round: u32,
    pub class: WorkloadClass,
    phase: Mutex<RoundPhase>,
    ingest: Mutex<IngestState>,
    fused: Mutex<Option<Arc<Vec<f32>>>>,
    budget: MemoryBudget,
}

impl RoundState {
    /// A buffered round (the historical collect-then-aggregate shape).
    pub fn new(round: u32, class: WorkloadClass, budget: MemoryBudget) -> RoundState {
        RoundState {
            round,
            class,
            phase: Mutex::new(RoundPhase::Collecting),
            ingest: Mutex::new(IngestState::Buffered { updates: Vec::new(), len: None }),
            fused: Mutex::new(None),
            budget,
        }
    }

    /// A streaming round: arriving updates fold into one of `lanes`
    /// shard-local O(C) accumulators and are released immediately; lanes
    /// fold concurrently (one per ingesting connection, typically sized to
    /// the node's cores).  Fails for holistic algorithms, which cannot
    /// stream.
    pub fn new_streaming(
        round: u32,
        class: WorkloadClass,
        budget: MemoryBudget,
        algo: Arc<dyn FusionAlgorithm>,
        lanes: usize,
    ) -> Result<RoundState, EngineError> {
        let fold = Arc::new(ShardedFold::new(algo.as_ref(), lanes, budget.clone())?);
        Ok(RoundState {
            round,
            class,
            phase: Mutex::new(RoundPhase::Collecting),
            ingest: Mutex::new(IngestState::Streaming { fold, algo }),
            fused: Mutex::new(None),
            budget,
        })
    }

    pub fn phase(&self) -> RoundPhase {
        *self.phase.lock().unwrap()
    }

    pub fn is_streaming(&self) -> bool {
        matches!(&*self.ingest.lock().unwrap(), IngestState::Streaming { .. })
    }

    fn require_phase(&self, expected: RoundPhase) -> Result<(), RoundError> {
        let actual = self.phase();
        if actual != expected {
            return Err(RoundError::WrongPhase { round: self.round, expected, actual });
        }
        Ok(())
    }

    /// Grab the streaming shard set without holding the state lock past
    /// the clone — the fold itself runs lock-free with respect to the
    /// round (only the chosen shard's lane lock is taken).
    fn streaming_lane(
        &self,
    ) -> Result<Option<(Arc<ShardedFold>, Arc<dyn FusionAlgorithm>)>, RoundError> {
        match &*self.ingest.lock().unwrap() {
            IngestState::Streaming { fold, algo } => Ok(Some((fold.clone(), algo.clone()))),
            IngestState::Buffered { .. } => Ok(None),
            // Drained only happens once aggregation started; never lock
            // `phase` here (lock order is phase -> ingest elsewhere).
            IngestState::Drained => Err(RoundError::WrongPhase {
                round: self.round,
                expected: RoundPhase::Collecting,
                actual: RoundPhase::Aggregating,
            }),
        }
    }

    /// Map a sharded-fold rejection onto the round's protocol errors: a
    /// seal means `finish_streaming` won the race — the same straggler
    /// story as an upload after `begin_aggregation`.
    fn map_fold_err(&self, e: FoldError) -> RoundError {
        match e {
            FoldError::Sealed => RoundError::WrongPhase {
                round: self.round,
                expected: RoundPhase::Collecting,
                actual: self.phase(),
            },
            FoldError::Engine(e) => e.into(),
        }
    }

    /// How long a streaming ingest waits out *transient* memory pressure
    /// (concurrent in-flight frames racing for the same headroom) before
    /// reporting OOM: under the thundering herd the edge node applies
    /// backpressure — the upload completes a moment later — instead of
    /// failing work that fits as soon as a neighbouring fold drains.  A
    /// genuinely over-budget round still errors (fast when the update
    /// can never fit, after the grace window otherwise).
    const INGEST_BACKPRESSURE: Duration = Duration::from_secs(2);

    /// Streaming-side fold with the in-flight charge and backpressure:
    /// reserve the frame's bytes, run the fold, retry transient OOMs
    /// until the grace window closes.
    fn fold_streaming<F>(&self, fold: &ShardedFold, bytes: u64, fold_once: F) -> Result<usize, RoundError>
    where
        F: Fn() -> Result<u64, FoldError>,
    {
        // Fail fast when no amount of waiting can help: the frame alone
        // exceeds the budget, or no lane holds an accumulator yet and
        // in-flight + a fresh O(C) scratch can never coexist (waiting
        // would only park a connection thread for the whole grace window).
        if bytes > self.budget.budget()
            || (!fold.has_active_lane() && bytes.saturating_mul(2) > self.budget.budget())
        {
            return Err(RoundError::Memory(OutOfMemory {
                requested: bytes,
                in_use: self.budget.in_use(),
                budget: self.budget.budget(),
            }));
        }
        let deadline = Instant::now() + Self::INGEST_BACKPRESSURE;
        loop {
            // Charge the in-flight buffer for the duration of the fold
            // only: steady-state resident is the lane accumulators plus
            // the frames currently being folded.  `would_fit` gates the
            // spin so a backpressure wait doesn't spam OOM events.
            let last = if self.budget.would_fit(bytes) {
                match self.budget.reserve(bytes) {
                    Ok(inflight) => match fold_once() {
                        Ok(n) => return Ok(n as usize),
                        Err(FoldError::Engine(EngineError::Memory(m))) => {
                            drop(inflight);
                            RoundError::Memory(m)
                        }
                        Err(e) => return Err(self.map_fold_err(e)),
                    },
                    Err(oom) => RoundError::Memory(oom),
                }
            } else {
                RoundError::Memory(OutOfMemory {
                    requested: bytes,
                    in_use: self.budget.in_use(),
                    budget: self.budget.budget(),
                })
            };
            if Instant::now() >= deadline {
                return Err(last);
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    /// Ingest an update on the message-passing path.  Buffered rounds
    /// charge node memory per update — the exact mechanism behind the
    /// paper's Fig 1 party ceiling; streaming rounds fold the update into
    /// a shard-local accumulator and release its buffer before returning.
    /// Both paths shape-check against the round's first update.
    pub fn ingest(&self, u: ModelUpdate) -> Result<usize, RoundError> {
        self.require_phase(RoundPhase::Collecting)?;
        if let Some((fold, algo)) = self.streaming_lane()? {
            let n = self.fold_streaming(&fold, u.mem_bytes(), || fold.fold(algo.as_ref(), &u))?;
            drop(u); // buffer released here, not at aggregation time
            return Ok(n);
        }
        self.ingest_buffered(u)
    }

    /// Zero-copy ingest: the update's weights still live in the caller's
    /// wire buffer.  Streaming rounds fold them in place — the upload path
    /// never materialises an owned `Vec<f32>`; buffered rounds copy once
    /// (parking an update past the life of the wire buffer requires it).
    pub fn ingest_view(&self, v: &ModelUpdateView<'_>) -> Result<usize, RoundError> {
        self.require_phase(RoundPhase::Collecting)?;
        if let Some((fold, algo)) = self.streaming_lane()? {
            return self.fold_streaming(&fold, v.mem_bytes(), || fold.fold_view(algo.as_ref(), v));
        }
        self.ingest_buffered(v.to_update())
    }

    fn ingest_buffered(&self, u: ModelUpdate) -> Result<usize, RoundError> {
        let mut state = self.ingest.lock().unwrap();
        match &mut *state {
            IngestState::Buffered { updates, len } => {
                match *len {
                    Some(want) if want != u.data.len() => {
                        return Err(RoundError::ShapeMismatch { want, got: u.data.len() })
                    }
                    Some(_) => {}
                    None => *len = Some(u.data.len()),
                }
                let r = self.budget.reserve(u.mem_bytes())?;
                updates.push((u, r));
                Ok(updates.len())
            }
            // The state can only have changed under our feet towards
            // Drained (streaming_lane saw Buffered moments ago).
            _ => Err(RoundError::WrongPhase {
                round: self.round,
                expected: RoundPhase::Collecting,
                actual: RoundPhase::Aggregating,
            }),
        }
    }

    /// Updates received so far (buffered count or folded count).
    pub fn collected(&self) -> usize {
        match &*self.ingest.lock().unwrap() {
            IngestState::Buffered { updates, .. } => updates.len(),
            IngestState::Streaming { fold, .. } => fold.folded() as usize,
            IngestState::Drained => 0,
        }
    }

    /// Transition Collecting -> Aggregating, taking the buffered updates
    /// out.  Streaming rounds use [`RoundState::finish_streaming`].
    pub fn begin_aggregation(&self) -> Result<Vec<ModelUpdate>, RoundError> {
        let mut phase = self.phase.lock().unwrap();
        if *phase != RoundPhase::Collecting {
            return Err(RoundError::WrongPhase {
                round: self.round,
                expected: RoundPhase::Collecting,
                actual: *phase,
            });
        }
        let mut state = self.ingest.lock().unwrap();
        let taken = std::mem::replace(&mut *state, IngestState::Drained);
        match taken {
            IngestState::Buffered { updates, .. } => {
                *phase = RoundPhase::Aggregating;
                // Reservations drop here: aggregation scratch is charged by
                // the engine itself; the raw buffers move to the engine call.
                Ok(updates.into_iter().map(|(u, _r)| u).collect())
            }
            other @ IngestState::Streaming { .. } => {
                *state = other; // put the fold back untouched
                Err(RoundError::NotBuffered)
            }
            // Unreachable while the phase guard holds (Drained implies the
            // phase already left Collecting), but keep the misuse contract
            // uniform with `ingest` rather than returning a hollow Ok.
            IngestState::Drained => Err(RoundError::WrongPhase {
                round: self.round,
                expected: RoundPhase::Collecting,
                actual: RoundPhase::Aggregating,
            }),
        }
    }

    /// Streaming rounds: transition Collecting -> Aggregating, seal the
    /// sharded fold and merge its lane partials into fused weights.
    /// Because every update was folded at ingest time, this is only the
    /// S-way O(C) merge plus the finalize — ingest and compute already
    /// overlapped.  Returns the weights together with the folded update
    /// count, read under the seal so a straggler that slips in just before
    /// the transition is either merged *and* counted, or rejected whole.
    pub fn finish_streaming(&self) -> Result<(Vec<f32>, usize), RoundError> {
        let mut phase = self.phase.lock().unwrap();
        if *phase != RoundPhase::Collecting {
            return Err(RoundError::WrongPhase {
                round: self.round,
                expected: RoundPhase::Collecting,
                actual: *phase,
            });
        }
        let mut state = self.ingest.lock().unwrap();
        let taken = std::mem::replace(&mut *state, IngestState::Drained);
        match taken {
            IngestState::Streaming { fold, algo } => {
                *phase = RoundPhase::Aggregating;
                let (out, folded) = fold.finish(algo.as_ref())?;
                Ok((out, folded as usize))
            }
            other => {
                *state = other; // put the buffered set back untouched
                Err(RoundError::NotStreaming)
            }
        }
    }

    /// Publish the fused model: Aggregating -> Published.
    pub fn publish(&self, fused: Vec<f32>) -> Result<(), RoundError> {
        let mut phase = self.phase.lock().unwrap();
        if *phase != RoundPhase::Aggregating {
            return Err(RoundError::WrongPhase {
                round: self.round,
                expected: RoundPhase::Aggregating,
                actual: *phase,
            });
        }
        *self.fused.lock().unwrap() = Some(Arc::new(fused));
        *phase = RoundPhase::Published;
        Ok(())
    }

    pub fn fused(&self) -> Option<Arc<Vec<f32>>> {
        self.fused.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::FedAvg;

    fn upd(p: u64, len: usize) -> ModelUpdate {
        ModelUpdate::new(p, 1.0, 0, vec![1.0; len])
    }

    #[test]
    fn lifecycle_happy_path() {
        let r = RoundState::new(0, WorkloadClass::Small, MemoryBudget::new(1 << 20));
        assert_eq!(r.phase(), RoundPhase::Collecting);
        r.ingest(upd(0, 100)).unwrap();
        r.ingest(upd(1, 100)).unwrap();
        assert_eq!(r.collected(), 2);
        let us = r.begin_aggregation().unwrap();
        assert_eq!(us.len(), 2);
        assert_eq!(r.phase(), RoundPhase::Aggregating);
        r.publish(vec![0.5; 100]).unwrap();
        assert_eq!(r.phase(), RoundPhase::Published);
        assert_eq!(r.fused().unwrap().len(), 100);
    }

    #[test]
    fn ingest_hits_memory_ceiling() {
        let r = RoundState::new(0, WorkloadClass::Small, MemoryBudget::new(1000));
        r.ingest(upd(0, 200)).unwrap(); // 800 bytes
        let err = r.ingest(upd(1, 200)).unwrap_err();
        match err {
            RoundError::Memory(e) => assert_eq!(e.in_use, 800),
            other => panic!("want Memory, got {other:?}"),
        }
        assert_eq!(r.collected(), 1);
    }

    #[test]
    fn begin_aggregation_releases_memory() {
        let budget = MemoryBudget::new(1000);
        let r = RoundState::new(0, WorkloadClass::Small, budget.clone());
        r.ingest(upd(0, 200)).unwrap();
        assert_eq!(budget.in_use(), 800);
        let _us = r.begin_aggregation().unwrap();
        assert_eq!(budget.in_use(), 0);
    }

    #[test]
    fn phase_misuse_is_an_error_not_a_panic() {
        let r = RoundState::new(3, WorkloadClass::Small, MemoryBudget::unbounded());
        let _ = r.begin_aggregation().unwrap();
        // a straggler upload after aggregation started must not crash
        assert!(matches!(
            r.ingest(upd(0, 10)),
            Err(RoundError::WrongPhase { round: 3, expected: RoundPhase::Collecting, .. })
        ));
        // double begin_aggregation is equally survivable
        assert!(matches!(r.begin_aggregation(), Err(RoundError::WrongPhase { .. })));
        // publish before aggregating (fresh round) errors too
        let r2 = RoundState::new(4, WorkloadClass::Small, MemoryBudget::unbounded());
        assert!(matches!(
            r2.publish(vec![]),
            Err(RoundError::WrongPhase { expected: RoundPhase::Aggregating, .. })
        ));
    }

    #[test]
    fn ingest_shape_checks_both_modes() {
        let r = RoundState::new(0, WorkloadClass::Small, MemoryBudget::unbounded());
        r.ingest(upd(0, 64)).unwrap();
        assert!(matches!(
            r.ingest(upd(1, 65)),
            Err(RoundError::ShapeMismatch { want: 64, got: 65 })
        ));
        assert_eq!(r.collected(), 1, "the bad update must not be parked");

        let s = RoundState::new_streaming(
            0,
            WorkloadClass::Streaming,
            MemoryBudget::unbounded(),
            Arc::new(FedAvg),
            2,
        )
        .unwrap();
        s.ingest(upd(0, 64)).unwrap();
        assert!(matches!(
            s.ingest(upd(1, 63)),
            Err(RoundError::ShapeMismatch { want: 64, got: 63 })
        ));
        assert_eq!(s.collected(), 1);
    }

    #[test]
    fn streaming_round_folds_and_publishes() {
        let budget = MemoryBudget::new(1 << 20);
        let s = RoundState::new_streaming(
            7,
            WorkloadClass::Streaming,
            budget.clone(),
            Arc::new(FedAvg),
            1,
        )
        .unwrap();
        assert!(s.is_streaming());
        for p in 0..10u64 {
            s.ingest(upd(p, 128)).unwrap();
        }
        assert_eq!(s.collected(), 10);
        // buffered-only API is a typed error on streaming rounds
        assert!(matches!(s.begin_aggregation(), Err(RoundError::NotBuffered)));
        let (out, folded) = s.finish_streaming().unwrap();
        assert_eq!(folded, 10);
        assert_eq!(out.len(), 128);
        assert!((out[0] - 1.0).abs() < 1e-4); // avg of all-ones
        s.publish(out).unwrap();
        assert_eq!(s.phase(), RoundPhase::Published);
        assert_eq!(budget.in_use(), 0, "fold scratch released");
    }

    #[test]
    fn streaming_round_concurrent_ingest_no_global_lock_loss() {
        // 8 threads fold concurrently into 4 lanes; every update must land
        // exactly once and the fused mean must be exact.
        let s = Arc::new(
            RoundState::new_streaming(
                0,
                WorkloadClass::Streaming,
                MemoryBudget::unbounded(),
                Arc::new(FedAvg),
                4,
            )
            .unwrap(),
        );
        std::thread::scope(|scope| {
            for t in 0..8u64 {
                let s = s.clone();
                scope.spawn(move || {
                    for k in 0..4u64 {
                        s.ingest(upd(t * 4 + k, 256)).unwrap();
                    }
                });
            }
        });
        assert_eq!(s.collected(), 32);
        let (out, folded) = s.finish_streaming().unwrap();
        assert_eq!(folded, 32);
        assert!((out[0] - 1.0).abs() < 1e-4); // mean of all-ones
    }

    #[test]
    fn streaming_backpressure_absorbs_transient_pressure() {
        // Budget fits one lane accumulator + two in-flight frames; 8
        // concurrent uploaders racing for that headroom must ALL succeed
        // — the ingest waits out the pressure instead of failing uploads
        // that fit as soon as a neighbouring fold drains.
        const LEN: usize = 512;
        let budget = MemoryBudget::new((3 * LEN * 4) as u64);
        let s = Arc::new(
            RoundState::new_streaming(
                0,
                WorkloadClass::Streaming,
                budget.clone(),
                Arc::new(FedAvg),
                4,
            )
            .unwrap(),
        );
        std::thread::scope(|scope| {
            for t in 0..8u64 {
                let s = s.clone();
                scope.spawn(move || {
                    for k in 0..8u64 {
                        s.ingest(upd(t * 8 + k, LEN)).unwrap();
                    }
                });
            }
        });
        assert_eq!(s.collected(), 64);
        let (out, folded) = s.finish_streaming().unwrap();
        assert_eq!(folded, 64);
        assert!((out[0] - 1.0).abs() < 1e-4);
        assert_eq!(budget.in_use(), 0, "all scratch and in-flight released");
    }

    #[test]
    fn never_fitting_streaming_update_fails_fast() {
        // 500 B frame + 500 B lane scratch can never coexist in 600 B:
        // the ingest must report OOM immediately, not park the connection
        // thread for the whole backpressure grace window.
        let s = RoundState::new_streaming(
            0,
            WorkloadClass::Streaming,
            MemoryBudget::new(600),
            Arc::new(FedAvg),
            2,
        )
        .unwrap();
        let t0 = std::time::Instant::now();
        assert!(matches!(s.ingest(upd(0, 125)), Err(RoundError::Memory(_))));
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "fast-fail must not wait out the grace window"
        );
    }

    #[test]
    fn streaming_ingest_view_folds_in_place() {
        let budget = MemoryBudget::new(1 << 20);
        let s = RoundState::new_streaming(
            1,
            WorkloadClass::Streaming,
            budget.clone(),
            Arc::new(FedAvg),
            2,
        )
        .unwrap();
        for p in 0..6u64 {
            let u = upd(p, 100);
            s.ingest_view(&u.as_view()).unwrap();
        }
        assert_eq!(s.collected(), 6);
        // wrong-shape views are rejected like owned updates
        assert!(matches!(
            s.ingest_view(&upd(9, 99).as_view()),
            Err(RoundError::ShapeMismatch { want: 100, got: 99 })
        ));
        let (out, folded) = s.finish_streaming().unwrap();
        assert_eq!(folded, 6);
        assert_eq!(out.len(), 100);
        // a straggler view after the finish is a phase error, not a panic
        assert!(matches!(
            s.ingest_view(&upd(10, 100).as_view()),
            Err(RoundError::WrongPhase { .. })
        ));
    }

    #[test]
    fn buffered_ingest_view_copies_once_and_parks() {
        let r = RoundState::new(0, WorkloadClass::Small, MemoryBudget::new(1 << 20));
        let u = upd(0, 50);
        r.ingest_view(&u.as_view()).unwrap();
        assert_eq!(r.collected(), 1);
        let got = r.begin_aggregation().unwrap();
        assert_eq!(got[0], u);
    }

    /// The Fig 1 lift, as a unit test: a party count that OOMs the
    /// buffered path completes under the same budget when streaming —
    /// peak round memory is O(C), independent of N.
    #[test]
    fn streaming_breaks_the_buffered_party_ceiling() {
        const LEN: usize = 200; // 800-byte updates
        const BUDGET: u64 = 4096;

        // buffered: 5 × 800 B fit, the 6th trips OutOfMemory
        let buffered = RoundState::new(0, WorkloadClass::Small, MemoryBudget::new(BUDGET));
        for p in 0..5u64 {
            buffered.ingest(upd(p, LEN)).unwrap();
        }
        assert!(matches!(buffered.ingest(upd(5, LEN)), Err(RoundError::Memory(_))));

        // streaming under the SAME budget takes 64 parties (and would take
        // any N): peak resident = the S=2 lane accumulators + one
        // in-flight update (sequential driver), independent of N.
        let budget = MemoryBudget::new(BUDGET);
        let streaming = RoundState::new_streaming(
            0,
            WorkloadClass::Streaming,
            budget.clone(),
            Arc::new(FedAvg),
            2,
        )
        .unwrap();
        for p in 0..64u64 {
            streaming.ingest(upd(p, LEN)).unwrap();
        }
        assert_eq!(streaming.collected(), 64);
        assert!(
            budget.high_water() <= (2 + 1) * (LEN as u64 * 4),
            "peak {} must be O(S*C), not O(N*C)",
            budget.high_water()
        );
        let (out, folded) = streaming.finish_streaming().unwrap();
        assert_eq!(folded, 64);
        assert_eq!(out.len(), LEN);
        assert!((out[0] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn streaming_rejects_holistic_algorithms() {
        assert!(RoundState::new_streaming(
            0,
            WorkloadClass::Streaming,
            MemoryBudget::unbounded(),
            Arc::new(crate::fusion::CoordMedian),
            1,
        )
        .is_err());
    }

    #[test]
    fn finish_streaming_on_buffered_round_is_typed_error() {
        let r = RoundState::new(0, WorkloadClass::Small, MemoryBudget::unbounded());
        r.ingest(upd(0, 16)).unwrap();
        assert!(matches!(r.finish_streaming(), Err(RoundError::NotStreaming)));
        // and the buffered set survived the failed call
        assert_eq!(r.collected(), 1);
        assert_eq!(r.begin_aggregation().unwrap().len(), 1);
    }
}
