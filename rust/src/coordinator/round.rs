//! Round state machine: each FL round collects updates (in memory or in
//! the store, depending on the classified path), aggregates, and publishes
//! the fused model for parties to fetch.

use std::sync::{Arc, Mutex};

use crate::coordinator::WorkloadClass;
use crate::memsim::{MemoryBudget, OutOfMemory, Reservation};
use crate::tensorstore::ModelUpdate;

/// Lifecycle phase of a round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundPhase {
    Collecting,
    Aggregating,
    Published,
}

/// One round's mutable state.
pub struct RoundState {
    pub round: u32,
    pub class: WorkloadClass,
    phase: Mutex<RoundPhase>,
    /// In-memory updates (small path); each charged to the node budget.
    updates: Mutex<Vec<(ModelUpdate, Reservation)>>,
    fused: Mutex<Option<Arc<Vec<f32>>>>,
    budget: MemoryBudget,
}

impl RoundState {
    pub fn new(round: u32, class: WorkloadClass, budget: MemoryBudget) -> RoundState {
        RoundState {
            round,
            class,
            phase: Mutex::new(RoundPhase::Collecting),
            updates: Mutex::new(Vec::new()),
            fused: Mutex::new(None),
            budget,
        }
    }

    pub fn phase(&self) -> RoundPhase {
        *self.phase.lock().unwrap()
    }

    /// Ingest an update on the message-passing path, charging node memory
    /// — the exact mechanism behind the paper's Fig 1 party ceiling.
    pub fn ingest(&self, u: ModelUpdate) -> Result<usize, OutOfMemory> {
        assert_eq!(self.phase(), RoundPhase::Collecting, "round not collecting");
        let r = self.budget.reserve(u.mem_bytes())?;
        let mut v = self.updates.lock().unwrap();
        v.push((u, r));
        Ok(v.len())
    }

    pub fn collected(&self) -> usize {
        self.updates.lock().unwrap().len()
    }

    /// Transition Collecting -> Aggregating, taking the updates out.
    pub fn begin_aggregation(&self) -> Vec<ModelUpdate> {
        let mut phase = self.phase.lock().unwrap();
        assert_eq!(*phase, RoundPhase::Collecting);
        *phase = RoundPhase::Aggregating;
        let mut v = self.updates.lock().unwrap();
        // Reservations drop here: aggregation scratch is charged by the
        // engine itself; the raw update buffers move to the engine call.
        v.drain(..).map(|(u, _r)| u).collect()
    }

    /// Publish the fused model: Aggregating -> Published.
    pub fn publish(&self, fused: Vec<f32>) {
        let mut phase = self.phase.lock().unwrap();
        assert_eq!(*phase, RoundPhase::Aggregating);
        *self.fused.lock().unwrap() = Some(Arc::new(fused));
        *phase = RoundPhase::Published;
    }

    pub fn fused(&self) -> Option<Arc<Vec<f32>>> {
        self.fused.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upd(p: u64, len: usize) -> ModelUpdate {
        ModelUpdate::new(p, 1.0, 0, vec![1.0; len])
    }

    #[test]
    fn lifecycle_happy_path() {
        let r = RoundState::new(0, WorkloadClass::Small, MemoryBudget::new(1 << 20));
        assert_eq!(r.phase(), RoundPhase::Collecting);
        r.ingest(upd(0, 100)).unwrap();
        r.ingest(upd(1, 100)).unwrap();
        assert_eq!(r.collected(), 2);
        let us = r.begin_aggregation();
        assert_eq!(us.len(), 2);
        assert_eq!(r.phase(), RoundPhase::Aggregating);
        r.publish(vec![0.5; 100]);
        assert_eq!(r.phase(), RoundPhase::Published);
        assert_eq!(r.fused().unwrap().len(), 100);
    }

    #[test]
    fn ingest_hits_memory_ceiling() {
        let r = RoundState::new(0, WorkloadClass::Small, MemoryBudget::new(1000));
        r.ingest(upd(0, 200)).unwrap(); // 800 bytes
        let err = r.ingest(upd(1, 200)).unwrap_err();
        assert_eq!(err.in_use, 800);
        assert_eq!(r.collected(), 1);
    }

    #[test]
    fn begin_aggregation_releases_memory() {
        let budget = MemoryBudget::new(1000);
        let r = RoundState::new(0, WorkloadClass::Small, budget.clone());
        r.ingest(upd(0, 200)).unwrap();
        assert_eq!(budget.in_use(), 800);
        let _us = r.begin_aggregation();
        assert_eq!(budget.in_use(), 0);
    }

    #[test]
    #[should_panic(expected = "round not collecting")]
    fn ingest_after_aggregation_panics() {
        let r = RoundState::new(0, WorkloadClass::Small, MemoryBudget::unbounded());
        let _ = r.begin_aggregation();
        let _ = r.ingest(upd(0, 10));
    }
}
