//! Round state machine: each FL round collects updates (in memory, folded
//! on arrival, or in the store, depending on the classified path),
//! aggregates, and publishes the fused model for parties to fetch.
//!
//! Two ingest modes:
//!
//! * **buffered** ([`RoundState::new`]) — every update is parked in node
//!   memory until `begin_aggregation` hands the whole set to a batch
//!   engine: K reservations of O(C) each, the paper's Fig 1 party
//!   ceiling;
//! * **streaming** ([`RoundState::new_streaming`]) — each arriving update
//!   folds into one of S shard-local O(C) accumulators
//!   ([`ShardedFold`]) and its buffer is released immediately: at most
//!   S reservations against the node budget (plus the transient in-flight
//!   updates), independent of the party count.  The round-level mutex is
//!   held only long enough to grab the shard set — concurrent connection
//!   handlers fold in parallel, contending 1/S as often as the global
//!   lock they replaced.
//!
//! Phase misuse and shape mismatches surface as [`RoundError`] — a
//! misbehaving party can no longer crash the coordinator with an assert.
//!
//! **Fault model** (edge fleets misbehave; the round survives):
//!
//! * *retransmission* — every upload is admitted through a per-round dedup
//!   ledger (sharded by party id so different parties don't contend)
//!   before any fold lane is picked, so a duplicated frame folds exactly
//!   once; the retransmit gets a typed [`RoundError::Duplicate`] carrying
//!   the accepted upload's nonce once the original durably folded, or
//!   [`RoundError::InFlight`] (retry) while it is still folding;
//! * *stragglers* — an upload racing the seal (quorum reached, deadline
//!   hit, or abort) maps to [`RoundError::WrongPhase`], never a panic;
//! * *dropouts* — a round that cannot reach its quorum is
//!   [aborted](RoundState::abort): the parked updates (buffered) or the
//!   sharded fold's lane scratch (streaming) are dropped and their
//!   reservations released back to the [`MemoryBudget`], so a dead round
//!   cannot leak the node's aggregation memory.  [`RoundOutcome`] names
//!   how a driven round ended (see `FlServer::run_round_quorum`).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::WorkloadClass;
use crate::engine::{EngineError, FoldError, ShardedFold};
use crate::fusion::{Accumulator, FusionAlgorithm, FusionError};
use crate::memsim::{MemoryBudget, OutOfMemory, Reservation};
use crate::tensorstore::{ModelUpdate, ModelUpdateView, PartialAggregateView};

/// Lifecycle phase of a round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundPhase {
    Collecting,
    Aggregating,
    Published,
    /// The round was abandoned (below quorum at its deadline, or the owner
    /// cancelled it); its ingest state is dropped and every memory
    /// reservation released.  Terminal.
    Aborted,
}

/// How a driven round ended — the typed result of the quorum lifecycle
/// (`Open → Ingest → {Complete | Quorum | Aborted}`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundOutcome {
    /// Every expected upload arrived before the deadline.
    Complete,
    /// The deadline hit with at least the quorum (but not all expected)
    /// folded; the round aggregated the partial set.
    Quorum,
    /// The deadline hit below quorum: the round was aborted and its memory
    /// reservations released — no model was published.
    Aborted,
}

/// What went wrong with a round-state operation.  These are *protocol*
/// errors: the coordinator reports them to the offending party (or caller)
/// and keeps serving everyone else.
#[derive(Debug)]
pub enum RoundError {
    /// The operation is only valid in `expected`; the round is in `actual`.
    WrongPhase { round: u32, expected: RoundPhase, actual: RoundPhase },
    /// An update disagreed with the round's established parameter count.
    ShapeMismatch { want: usize, got: usize },
    /// This party's update was already folded into the round; `nonce` is
    /// the accepted upload's nonce, so a retransmitting client can tell
    /// "my frame landed" apart from "someone else used my id".
    Duplicate { party: u64, nonce: u64 },
    /// This party's upload is admitted but still folding on another
    /// connection: it is NOT yet durably absorbed (the fold may still
    /// fail and release the slot), so the retransmit must retry rather
    /// than be told `Duplicate`.  The server surfaces this as a plain
    /// (retryable) error reply.
    InFlight { party: u64 },
    /// A partial aggregate listed the same party twice: its pre-folded
    /// sums count that member twice no matter what the ledger does, so
    /// the frame is rejected outright.  Deliberately NOT `Duplicate` —
    /// that reply means "an earlier upload for this party was accepted",
    /// which would make the relay count the cohort as folded.
    MalformedCohort { party: u64 },
    /// The robust admission gate turned the upload away before any fold:
    /// its L2 norm exceeded the round's rejection threshold (a multiple of
    /// the last sealed median norm).  Typed — the server maps it to a
    /// dedicated wire reply so an honest-but-misconfigured client can tell
    /// "my update was judged hostile" apart from every transport error,
    /// and the coordinator decays the sender's trust score.
    Rejected { party: u64, norm: f32 },
    /// The node budget is exhausted (the Fig 1 ceiling, as an error).
    Memory(OutOfMemory),
    /// A streaming-only operation was called on a buffered round.
    NotStreaming,
    /// A buffered-only operation was called on a streaming round.
    NotBuffered,
    /// The streaming fold failed below the coordinator.
    Engine(EngineError),
}

impl std::fmt::Display for RoundError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RoundError::WrongPhase { round, expected, actual } => {
                write!(f, "round {round} is {actual:?}, not {expected:?}")
            }
            RoundError::ShapeMismatch { want, got } => {
                write!(f, "update length {got} != round's {want}")
            }
            RoundError::Duplicate { party, nonce } => {
                write!(f, "party {party} already folded (accepted nonce {nonce:#x})")
            }
            RoundError::InFlight { party } => {
                write!(f, "party {party} upload still folding; retry")
            }
            RoundError::MalformedCohort { party } => {
                write!(f, "partial lists party {party} more than once")
            }
            RoundError::Rejected { party, norm } => {
                write!(f, "party {party} rejected: update norm {norm} beyond threshold")
            }
            RoundError::Memory(e) => write!(f, "memory: {e}"),
            RoundError::NotStreaming => write!(f, "round is buffered, not streaming"),
            RoundError::NotBuffered => write!(f, "round is streaming, not buffered"),
            RoundError::Engine(e) => write!(f, "engine: {e}"),
        }
    }
}

impl std::error::Error for RoundError {}

impl From<OutOfMemory> for RoundError {
    fn from(e: OutOfMemory) -> Self {
        RoundError::Memory(e)
    }
}

impl From<EngineError> for RoundError {
    fn from(e: EngineError) -> Self {
        match e {
            EngineError::Memory(m) => RoundError::Memory(m),
            EngineError::Fusion(FusionError::ShapeMismatch { want, got }) => {
                RoundError::ShapeMismatch { want, got }
            }
            other => RoundError::Engine(other),
        }
    }
}

/// How a round holds what parties sent so far.
enum IngestState {
    /// Small path: updates parked until aggregation, each charged O(C).
    Buffered {
        updates: Vec<(ModelUpdate, Reservation)>,
        /// Parameter count fixed by the first ingested update.
        len: Option<usize>,
    },
    /// Streaming path: S shard-local O(C) folds; buffers released on
    /// arrival.  Behind an `Arc` so the hot path clones the handle under
    /// the state lock and folds *outside* it — the `ShardedFold`'s seal
    /// makes the drop of the lock safe against a racing finish.
    Streaming {
        fold: Arc<ShardedFold>,
        algo: Arc<dyn FusionAlgorithm>,
    },
    /// Updates (or the fold) have been handed to the aggregation step.
    Drained,
}

/// The admission-ledger shard count: dedup must serialize same-party
/// frames, but uploads from *different* parties should contend no more
/// than the sharded fold they feed — so the ledger shards by party id
/// instead of reintroducing one global lock on the ingest hot path.
const LEDGER_SHARDS: usize = 16;

/// One party's admission slot: claimed at ingest, marked folded once the
/// fold durably landed.  The distinction drives the retransmit reply —
/// `Duplicate` only after the fold succeeded, `InFlight` while it might
/// still fail and release the slot.
struct Slot {
    nonce: u64,
    folded: bool,
}

/// One round's mutable state.
pub struct RoundState {
    pub round: u32,
    pub class: WorkloadClass,
    phase: Mutex<RoundPhase>,
    ingest: Mutex<IngestState>,
    fused: Mutex<Option<Arc<Vec<f32>>>>,
    budget: MemoryBudget,
    /// Dedup admission ledger: party id → admission [`Slot`], sharded by
    /// party.  Checked (and claimed) *before* any fold lane is picked, so
    /// a retransmitted frame racing its original through the sharded
    /// ingest cannot fold twice — one of the two claims the slot, the
    /// other gets [`RoundError::Duplicate`] (folded) or
    /// [`RoundError::InFlight`] (original still folding).
    seen: Vec<Mutex<BTreeMap<u64, Slot>>>,
}

impl RoundState {
    /// A buffered round (the historical collect-then-aggregate shape).
    pub fn new(round: u32, class: WorkloadClass, budget: MemoryBudget) -> RoundState {
        RoundState {
            round,
            class,
            phase: Mutex::new(RoundPhase::Collecting),
            ingest: Mutex::new(IngestState::Buffered { updates: Vec::new(), len: None }),
            fused: Mutex::new(None),
            budget,
            seen: (0..LEDGER_SHARDS).map(|_| Mutex::new(BTreeMap::new())).collect(),
        }
    }

    /// A streaming round: arriving updates fold into one of `lanes`
    /// shard-local O(C) accumulators and are released immediately; lanes
    /// fold concurrently (one per ingesting connection, typically sized to
    /// the node's cores).  Fails for holistic algorithms, which cannot
    /// stream.
    pub fn new_streaming(
        round: u32,
        class: WorkloadClass,
        budget: MemoryBudget,
        algo: Arc<dyn FusionAlgorithm>,
        lanes: usize,
    ) -> Result<RoundState, EngineError> {
        let fold = Arc::new(ShardedFold::new(algo.as_ref(), lanes, budget.clone())?);
        Ok(RoundState {
            round,
            class,
            phase: Mutex::new(RoundPhase::Collecting),
            ingest: Mutex::new(IngestState::Streaming { fold, algo }),
            fused: Mutex::new(None),
            budget,
            seen: (0..LEDGER_SHARDS).map(|_| Mutex::new(BTreeMap::new())).collect(),
        })
    }

    pub fn phase(&self) -> RoundPhase {
        *self.phase.lock().unwrap()
    }

    pub fn is_streaming(&self) -> bool {
        matches!(&*self.ingest.lock().unwrap(), IngestState::Streaming { .. })
    }

    fn require_phase(&self, expected: RoundPhase) -> Result<(), RoundError> {
        let actual = self.phase();
        if actual != expected {
            return Err(RoundError::WrongPhase { round: self.round, expected, actual });
        }
        Ok(())
    }

    /// Grab the streaming shard set without holding the state lock past
    /// the clone — the fold itself runs lock-free with respect to the
    /// round (only the chosen shard's lane lock is taken).
    fn streaming_lane(
        &self,
    ) -> Result<Option<(Arc<ShardedFold>, Arc<dyn FusionAlgorithm>)>, RoundError> {
        match &*self.ingest.lock().unwrap() {
            IngestState::Streaming { fold, algo } => Ok(Some((fold.clone(), algo.clone()))),
            IngestState::Buffered { .. } => Ok(None),
            // Drained only happens once aggregation started; never lock
            // `phase` here (lock order is phase -> ingest elsewhere).
            IngestState::Drained => Err(RoundError::WrongPhase {
                round: self.round,
                expected: RoundPhase::Collecting,
                actual: RoundPhase::Aggregating,
            }),
        }
    }

    /// Map a sharded-fold rejection onto the round's protocol errors: a
    /// seal means `finish_streaming` won the race — the same straggler
    /// story as an upload after `begin_aggregation`.
    fn map_fold_err(&self, e: FoldError) -> RoundError {
        match e {
            FoldError::Sealed => RoundError::WrongPhase {
                round: self.round,
                expected: RoundPhase::Collecting,
                actual: self.phase(),
            },
            FoldError::Engine(e) => e.into(),
        }
    }

    /// How long a streaming ingest waits out *transient* memory pressure
    /// (concurrent in-flight frames racing for the same headroom) before
    /// reporting OOM: under the thundering herd the edge node applies
    /// backpressure — the upload completes a moment later — instead of
    /// failing work that fits as soon as a neighbouring fold drains.  A
    /// genuinely over-budget round still errors (fast when the update
    /// can never fit, after the grace window otherwise).
    const INGEST_BACKPRESSURE: Duration = Duration::from_secs(2);

    /// Streaming-side fold with the in-flight charge and backpressure:
    /// reserve the frame's bytes, run the fold, retry transient OOMs
    /// until the grace window closes.
    fn fold_streaming<F>(&self, fold: &ShardedFold, bytes: u64, fold_once: F) -> Result<usize, RoundError>
    where
        F: Fn() -> Result<u64, FoldError>,
    {
        // Fail fast when no amount of waiting can help: the frame alone
        // exceeds the budget, or no lane holds an accumulator yet and
        // in-flight + a fresh O(C) scratch can never coexist (waiting
        // would only park a connection thread for the whole grace window).
        if bytes > self.budget.budget()
            || (!fold.has_active_lane() && bytes.saturating_mul(2) > self.budget.budget())
        {
            return Err(RoundError::Memory(OutOfMemory {
                requested: bytes,
                in_use: self.budget.in_use(),
                budget: self.budget.budget(),
            }));
        }
        let deadline = Instant::now() + Self::INGEST_BACKPRESSURE;
        loop {
            // Charge the in-flight buffer for the duration of the fold
            // only: steady-state resident is the lane accumulators plus
            // the frames currently being folded.  `would_fit` gates the
            // spin so a backpressure wait doesn't spam OOM events.
            let last = if self.budget.would_fit(bytes) {
                match self.budget.reserve(bytes) {
                    Ok(inflight) => match fold_once() {
                        Ok(n) => return Ok(n as usize),
                        Err(FoldError::Engine(EngineError::Memory(m))) => {
                            drop(inflight);
                            RoundError::Memory(m)
                        }
                        Err(e) => return Err(self.map_fold_err(e)),
                    },
                    Err(oom) => RoundError::Memory(oom),
                }
            } else {
                RoundError::Memory(OutOfMemory {
                    requested: bytes,
                    in_use: self.budget.in_use(),
                    budget: self.budget.budget(),
                })
            };
            if Instant::now() >= deadline {
                return Err(last);
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    /// Claim this party's once-per-round admission slot.  MUST run before
    /// any lane is picked or byte is charged: the sharded fold assigns
    /// lanes round-robin, so two copies of the same frame admitted
    /// concurrently would land on different lanes and both fold — the
    /// ledger is the only serialization point ahead of that.
    fn ledger(&self, party: u64) -> &Mutex<BTreeMap<u64, Slot>> {
        &self.seen[(party as usize) % LEDGER_SHARDS]
    }

    fn admit(&self, party: u64, nonce: u64) -> Result<(), RoundError> {
        match self.ledger(party).lock().unwrap().entry(party) {
            std::collections::btree_map::Entry::Occupied(e) => {
                let slot = e.get();
                if slot.folded {
                    Err(RoundError::Duplicate { party, nonce: slot.nonce })
                } else {
                    // The original is still folding and may yet fail: the
                    // retransmit must not be told "landed" prematurely.
                    Err(RoundError::InFlight { party })
                }
            }
            std::collections::btree_map::Entry::Vacant(v) => {
                v.insert(Slot { nonce, folded: false });
                Ok(())
            }
        }
    }

    /// Claim a whole cohort's admission slots ATOMICALLY — the hierarchical
    /// twin of [`RoundState::admit`].  A forwarded partial aggregate is one
    /// frame carrying many parties' already-folded contributions; claiming
    /// its slots one by one would open a window where a stray direct upload
    /// from a cohort member lands between two claims and double-folds that
    /// party.  Instead every involved ledger shard is locked (in ascending
    /// shard order, so the multi-lock cannot deadlock against the
    /// single-shard `admit`), all slots are checked vacant, and only then
    /// are they all inserted.
    ///
    /// On ANY conflict the whole partial is rejected — the cohort's sums
    /// are pre-folded, so the conflicting member's contribution cannot be
    /// subtracted out.  The typed `Duplicate` names the first conflicting
    /// party (and the nonce its accepted upload carried) so the edge
    /// aggregator knows exactly which member poisoned the cohort and can
    /// exclude it next round.  Nothing is claimed on rejection: the other
    /// members remain free to upload directly.
    fn admit_cohort(&self, parties: &[u64], nonce: u64) -> Result<(), RoundError> {
        let mut sorted: Vec<u64> = parties.to_vec();
        sorted.sort_unstable();
        if let Some(w) = sorted.windows(2).find(|w| w[0] == w[1]) {
            return Err(RoundError::MalformedCohort { party: w[0] });
        }
        let mut shard_ids: Vec<usize> =
            sorted.iter().map(|p| (*p as usize) % LEDGER_SHARDS).collect();
        shard_ids.sort_unstable();
        shard_ids.dedup();
        let mut guards: Vec<_> =
            shard_ids.iter().map(|i| self.seen[*i].lock().unwrap()).collect();
        let slot_of = |p: u64| {
            shard_ids
                .binary_search(&((p as usize) % LEDGER_SHARDS))
                .expect("every party's shard was locked")
        };
        for &p in &sorted {
            if let Some(slot) = guards[slot_of(p)].get(&p) {
                return if slot.folded {
                    Err(RoundError::Duplicate { party: p, nonce: slot.nonce })
                } else {
                    Err(RoundError::InFlight { party: p })
                };
            }
        }
        for &p in &sorted {
            guards[slot_of(p)].insert(p, Slot { nonce, folded: false });
        }
        Ok(())
    }

    /// The fold durably landed: retransmits from here on are `Duplicate`.
    fn mark_folded(&self, party: u64) {
        if let Some(slot) = self.ledger(party).lock().unwrap().get_mut(&party) {
            slot.folded = true;
        }
    }

    /// Release a claimed slot after a failed fold (OOM, shape, seal race)
    /// so an honest retry is not condemned to `Duplicate` forever.
    fn unadmit(&self, party: u64) {
        self.ledger(party).lock().unwrap().remove(&party);
    }

    /// Ingest an update on the message-passing path.  Buffered rounds
    /// charge node memory per update — the exact mechanism behind the
    /// paper's Fig 1 party ceiling; streaming rounds fold the update into
    /// a shard-local accumulator and release its buffer before returning.
    /// Both paths shape-check against the round's first update and dedup
    /// on party id (the nonce defaults to the party id — use
    /// [`RoundState::ingest_tagged`] to carry the wire nonce).
    pub fn ingest(&self, u: ModelUpdate) -> Result<usize, RoundError> {
        let nonce = u.party;
        self.ingest_tagged(u, nonce)
    }

    /// [`RoundState::ingest`] with an explicit retransmission nonce: the
    /// nonce is recorded in the admission ledger and echoed in the typed
    /// `Duplicate` a retransmit receives.
    pub fn ingest_tagged(&self, u: ModelUpdate, nonce: u64) -> Result<usize, RoundError> {
        self.require_phase(RoundPhase::Collecting)?;
        let party = u.party;
        self.admit(party, nonce)?;
        let r = self.ingest_inner(u);
        match &r {
            Ok(_) => self.mark_folded(party),
            Err(_) => self.unadmit(party),
        }
        r
    }

    fn ingest_inner(&self, u: ModelUpdate) -> Result<usize, RoundError> {
        if let Some((fold, algo)) = self.streaming_lane()? {
            let n = self.fold_streaming(&fold, u.mem_bytes(), || fold.fold(algo.as_ref(), &u))?;
            drop(u); // buffer released here, not at aggregation time
            return Ok(n);
        }
        self.ingest_buffered(u)
    }

    /// Zero-copy ingest: the update's weights still live in the caller's
    /// wire buffer.  Streaming rounds fold them in place — the upload path
    /// never materialises an owned `Vec<f32>`; buffered rounds copy once
    /// (parking an update past the life of the wire buffer requires it).
    pub fn ingest_view(&self, v: &ModelUpdateView<'_>) -> Result<usize, RoundError> {
        self.ingest_view_tagged(v, v.party)
    }

    /// [`RoundState::ingest_view`] with an explicit retransmission nonce.
    pub fn ingest_view_tagged(
        &self,
        v: &ModelUpdateView<'_>,
        nonce: u64,
    ) -> Result<usize, RoundError> {
        self.require_phase(RoundPhase::Collecting)?;
        self.admit(v.party, nonce)?;
        let r = self.ingest_view_inner(v);
        match &r {
            Ok(_) => self.mark_folded(v.party),
            Err(_) => self.unadmit(v.party),
        }
        r
    }

    fn ingest_view_inner(&self, v: &ModelUpdateView<'_>) -> Result<usize, RoundError> {
        if let Some((fold, algo)) = self.streaming_lane()? {
            return self.fold_streaming(&fold, v.mem_bytes(), || fold.fold_view(algo.as_ref(), v));
        }
        self.ingest_buffered(v.to_update())
    }

    /// Ingest a weighted partial aggregate — an edge cohort pre-folded by a
    /// relay — as a first-class object: the whole cohort's admission slots
    /// are claimed atomically (see [`RoundState::admit_cohort`]), the
    /// partial folds through the algebra's `combine` on a streaming lane,
    /// and the fold counter advances by the cohort's MEMBER count, so
    /// quorum logic counts contributing parties, not frames.
    ///
    /// Only streaming rounds can fold partials (a buffered round parks
    /// owned `ModelUpdate`s; a partial is not one) — buffered rounds return
    /// the typed [`RoundError::NotStreaming`], which the server maps to an
    /// error reply telling the relay this aggregator is not running a
    /// hierarchical ingest.
    pub fn ingest_partial(&self, v: &PartialAggregateView<'_>) -> Result<usize, RoundError> {
        self.ingest_partial_tagged(v, v.edge)
    }

    /// [`RoundState::ingest_partial`] with an explicit retransmission nonce
    /// (recorded against every cohort member's slot).
    pub fn ingest_partial_tagged(
        &self,
        v: &PartialAggregateView<'_>,
        nonce: u64,
    ) -> Result<usize, RoundError> {
        self.require_phase(RoundPhase::Collecting)?;
        if v.parties.is_empty() {
            return Err(RoundError::Engine(EngineError::Fusion(FusionError::Empty)));
        }
        self.admit_cohort(&v.parties, nonce)?;
        let r = self.ingest_partial_inner(v);
        match &r {
            Ok(_) => {
                for p in v.parties.iter() {
                    self.mark_folded(*p);
                }
            }
            Err(_) => {
                for p in v.parties.iter() {
                    self.unadmit(*p);
                }
            }
        }
        r
    }

    fn ingest_partial_inner(&self, v: &PartialAggregateView<'_>) -> Result<usize, RoundError> {
        match self.streaming_lane()? {
            Some((fold, algo)) => self.fold_streaming(&fold, v.mem_bytes(), || {
                fold.fold_partial_sketch(
                    algo.as_ref(),
                    &v.sum,
                    v.wtot,
                    v.parties.len() as u64,
                    v.sketch.as_deref(),
                )
            }),
            None => Err(RoundError::NotStreaming),
        }
    }

    fn ingest_buffered(&self, u: ModelUpdate) -> Result<usize, RoundError> {
        let mut state = self.ingest.lock().unwrap();
        match &mut *state {
            IngestState::Buffered { updates, len } => {
                match *len {
                    Some(want) if want != u.data.len() => {
                        return Err(RoundError::ShapeMismatch { want, got: u.data.len() })
                    }
                    Some(_) => {}
                    None => *len = Some(u.data.len()),
                }
                let r = self.budget.reserve(u.mem_bytes())?;
                updates.push((u, r));
                Ok(updates.len())
            }
            // The state can only have changed under our feet towards
            // Drained (streaming_lane saw Buffered moments ago).
            _ => Err(RoundError::WrongPhase {
                round: self.round,
                expected: RoundPhase::Collecting,
                actual: RoundPhase::Aggregating,
            }),
        }
    }

    /// Updates received so far (buffered count or folded count).
    pub fn collected(&self) -> usize {
        match &*self.ingest.lock().unwrap() {
            IngestState::Buffered { updates, .. } => updates.len(),
            IngestState::Streaming { fold, .. } => fold.folded() as usize,
            IngestState::Drained => 0,
        }
    }

    /// Transition Collecting -> Aggregating, taking the buffered updates
    /// out.  Streaming rounds use [`RoundState::finish_streaming`].
    pub fn begin_aggregation(&self) -> Result<Vec<ModelUpdate>, RoundError> {
        let mut phase = self.phase.lock().unwrap();
        if *phase != RoundPhase::Collecting {
            return Err(RoundError::WrongPhase {
                round: self.round,
                expected: RoundPhase::Collecting,
                actual: *phase,
            });
        }
        let mut state = self.ingest.lock().unwrap();
        let taken = std::mem::replace(&mut *state, IngestState::Drained);
        match taken {
            IngestState::Buffered { updates, .. } => {
                *phase = RoundPhase::Aggregating;
                // Reservations drop here: aggregation scratch is charged by
                // the engine itself; the raw buffers move to the engine call.
                Ok(updates.into_iter().map(|(u, _r)| u).collect())
            }
            other @ IngestState::Streaming { .. } => {
                *state = other; // put the fold back untouched
                Err(RoundError::NotBuffered)
            }
            // Unreachable while the phase guard holds (Drained implies the
            // phase already left Collecting), but keep the misuse contract
            // uniform with `ingest` rather than returning a hollow Ok.
            IngestState::Drained => Err(RoundError::WrongPhase {
                round: self.round,
                expected: RoundPhase::Collecting,
                actual: RoundPhase::Aggregating,
            }),
        }
    }

    /// Streaming rounds: transition Collecting -> Aggregating, seal the
    /// sharded fold and merge its lane partials into fused weights.
    /// Because every update was folded at ingest time, this is only the
    /// S-way O(C) merge plus the finalize — ingest and compute already
    /// overlapped.  Returns the weights together with the folded update
    /// count, read under the seal so a straggler that slips in just before
    /// the transition is either merged *and* counted, or rejected whole.
    pub fn finish_streaming(&self) -> Result<(Vec<f32>, usize), RoundError> {
        let mut phase = self.phase.lock().unwrap();
        if *phase != RoundPhase::Collecting {
            return Err(RoundError::WrongPhase {
                round: self.round,
                expected: RoundPhase::Collecting,
                actual: *phase,
            });
        }
        let mut state = self.ingest.lock().unwrap();
        let taken = std::mem::replace(&mut *state, IngestState::Drained);
        match taken {
            IngestState::Streaming { fold, algo } => {
                *phase = RoundPhase::Aggregating;
                let (out, folded) = fold.finish(algo.as_ref())?;
                Ok((out, folded as usize))
            }
            other => {
                *state = other; // put the buffered set back untouched
                Err(RoundError::NotStreaming)
            }
        }
    }

    /// Streaming rounds, relay flavour: seal and drain like
    /// [`RoundState::finish_streaming`] but stop BEFORE the finalize,
    /// returning the raw merged [`Accumulator`], the folded member count
    /// and the folded party set — exactly the pieces an edge aggregator
    /// forwards upstream as a weighted partial aggregate.  (Finalizing at
    /// the edge would divide by `wtot + EPS`; the root could never undo
    /// that exactly.)
    ///
    /// The party set is read from the admission ledger after the seal.  An
    /// upload whose fold completed in the final instruction window before
    /// the seal but whose ledger slot was not yet marked can be counted in
    /// the accumulator while missing from the set — the same residual
    /// window `reopen_round` documents; the relay's settle beat before
    /// sealing covers it, and the miss direction is conservative (the root
    /// counts `parties.len()` members, never more than truly folded).
    pub fn finish_streaming_partial(
        &self,
    ) -> Result<(Accumulator, usize, Vec<u64>), RoundError> {
        let mut phase = self.phase.lock().unwrap();
        if *phase != RoundPhase::Collecting {
            return Err(RoundError::WrongPhase {
                round: self.round,
                expected: RoundPhase::Collecting,
                actual: *phase,
            });
        }
        let mut state = self.ingest.lock().unwrap();
        let taken = std::mem::replace(&mut *state, IngestState::Drained);
        match taken {
            IngestState::Streaming { fold, algo } => {
                *phase = RoundPhase::Aggregating;
                let (acc, folded) = fold.finish_partial(algo.as_ref())?;
                drop(state);
                drop(phase);
                let parties = self.folded_parties();
                Ok((acc, folded as usize, parties))
            }
            other => {
                *state = other; // put the buffered set back untouched
                Err(RoundError::NotStreaming)
            }
        }
    }

    /// Parties whose uploads durably folded into this round (ascending).
    /// Stable once the round sealed; mid-collection it is a live snapshot.
    pub fn folded_parties(&self) -> Vec<u64> {
        let mut out = Vec::new();
        for shard in &self.seen {
            out.extend(
                shard
                    .lock()
                    .unwrap()
                    .iter()
                    .filter(|(_, s)| s.folded)
                    .map(|(p, _)| *p),
            );
        }
        out.sort_unstable();
        out
    }

    /// Publish the fused model: Aggregating -> Published.
    pub fn publish(&self, fused: Vec<f32>) -> Result<(), RoundError> {
        let mut phase = self.phase.lock().unwrap();
        if *phase != RoundPhase::Aggregating {
            return Err(RoundError::WrongPhase {
                round: self.round,
                expected: RoundPhase::Aggregating,
                actual: *phase,
            });
        }
        *self.fused.lock().unwrap() = Some(Arc::new(fused));
        *phase = RoundPhase::Published;
        Ok(())
    }

    pub fn fused(&self) -> Option<Arc<Vec<f32>>> {
        self.fused.lock().unwrap().clone()
    }

    /// Abandon the round (below quorum at its deadline, or cancelled by
    /// the owner): drop the ingest state — the buffered updates' per-party
    /// reservations, or the sharded fold's lane scratch — releasing every
    /// byte back to the [`MemoryBudget`].  Valid from `Collecting` or
    /// `Aggregating`; a published or already-aborted round is `WrongPhase`.
    ///
    /// Streaming rounds are *sealed* before the state is dropped, so an
    /// upload racing the abort is either folded-then-discarded with the
    /// rest of the lane scratch or rejected with the same `WrongPhase` a
    /// straggler after `finish_streaming` gets — never a panic, never a
    /// leaked in-flight reservation (the in-flight charge is RAII-scoped
    /// to the fold call itself).
    pub fn abort(&self) -> Result<(), RoundError> {
        let mut phase = self.phase.lock().unwrap();
        match *phase {
            RoundPhase::Collecting | RoundPhase::Aggregating => {}
            actual => {
                return Err(RoundError::WrongPhase {
                    round: self.round,
                    expected: RoundPhase::Collecting,
                    actual,
                })
            }
        }
        let mut state = self.ingest.lock().unwrap();
        if let IngestState::Streaming { fold, .. } = &*state {
            fold.seal();
        }
        // Dropping the state releases the buffered reservations; the
        // sharded fold's lane scratch follows when the last transient
        // handler clone drops (immediately, absent a mid-flight fold).
        *state = IngestState::Drained;
        *phase = RoundPhase::Aborted;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::FedAvg;

    fn upd(p: u64, len: usize) -> ModelUpdate {
        ModelUpdate::new(p, 1.0, 0, vec![1.0; len])
    }

    #[test]
    fn lifecycle_happy_path() {
        let r = RoundState::new(0, WorkloadClass::Small, MemoryBudget::new(1 << 20));
        assert_eq!(r.phase(), RoundPhase::Collecting);
        r.ingest(upd(0, 100)).unwrap();
        r.ingest(upd(1, 100)).unwrap();
        assert_eq!(r.collected(), 2);
        let us = r.begin_aggregation().unwrap();
        assert_eq!(us.len(), 2);
        assert_eq!(r.phase(), RoundPhase::Aggregating);
        r.publish(vec![0.5; 100]).unwrap();
        assert_eq!(r.phase(), RoundPhase::Published);
        assert_eq!(r.fused().unwrap().len(), 100);
    }

    #[test]
    fn ingest_hits_memory_ceiling() {
        let r = RoundState::new(0, WorkloadClass::Small, MemoryBudget::new(1000));
        r.ingest(upd(0, 200)).unwrap(); // 800 bytes
        let err = r.ingest(upd(1, 200)).unwrap_err();
        match err {
            RoundError::Memory(e) => assert_eq!(e.in_use, 800),
            other => panic!("want Memory, got {other:?}"),
        }
        assert_eq!(r.collected(), 1);
    }

    #[test]
    fn begin_aggregation_releases_memory() {
        let budget = MemoryBudget::new(1000);
        let r = RoundState::new(0, WorkloadClass::Small, budget.clone());
        r.ingest(upd(0, 200)).unwrap();
        assert_eq!(budget.in_use(), 800);
        let _us = r.begin_aggregation().unwrap();
        assert_eq!(budget.in_use(), 0);
    }

    #[test]
    fn phase_misuse_is_an_error_not_a_panic() {
        let r = RoundState::new(3, WorkloadClass::Small, MemoryBudget::unbounded());
        let _ = r.begin_aggregation().unwrap();
        // a straggler upload after aggregation started must not crash
        assert!(matches!(
            r.ingest(upd(0, 10)),
            Err(RoundError::WrongPhase { round: 3, expected: RoundPhase::Collecting, .. })
        ));
        // double begin_aggregation is equally survivable
        assert!(matches!(r.begin_aggregation(), Err(RoundError::WrongPhase { .. })));
        // publish before aggregating (fresh round) errors too
        let r2 = RoundState::new(4, WorkloadClass::Small, MemoryBudget::unbounded());
        assert!(matches!(
            r2.publish(vec![]),
            Err(RoundError::WrongPhase { expected: RoundPhase::Aggregating, .. })
        ));
    }

    #[test]
    fn ingest_shape_checks_both_modes() {
        let r = RoundState::new(0, WorkloadClass::Small, MemoryBudget::unbounded());
        r.ingest(upd(0, 64)).unwrap();
        assert!(matches!(
            r.ingest(upd(1, 65)),
            Err(RoundError::ShapeMismatch { want: 64, got: 65 })
        ));
        assert_eq!(r.collected(), 1, "the bad update must not be parked");

        let s = RoundState::new_streaming(
            0,
            WorkloadClass::Streaming,
            MemoryBudget::unbounded(),
            Arc::new(FedAvg),
            2,
        )
        .unwrap();
        s.ingest(upd(0, 64)).unwrap();
        assert!(matches!(
            s.ingest(upd(1, 63)),
            Err(RoundError::ShapeMismatch { want: 64, got: 63 })
        ));
        assert_eq!(s.collected(), 1);
    }

    #[test]
    fn streaming_round_folds_and_publishes() {
        let budget = MemoryBudget::new(1 << 20);
        let s = RoundState::new_streaming(
            7,
            WorkloadClass::Streaming,
            budget.clone(),
            Arc::new(FedAvg),
            1,
        )
        .unwrap();
        assert!(s.is_streaming());
        for p in 0..10u64 {
            s.ingest(upd(p, 128)).unwrap();
        }
        assert_eq!(s.collected(), 10);
        // buffered-only API is a typed error on streaming rounds
        assert!(matches!(s.begin_aggregation(), Err(RoundError::NotBuffered)));
        let (out, folded) = s.finish_streaming().unwrap();
        assert_eq!(folded, 10);
        assert_eq!(out.len(), 128);
        assert!((out[0] - 1.0).abs() < 1e-4); // avg of all-ones
        s.publish(out).unwrap();
        assert_eq!(s.phase(), RoundPhase::Published);
        assert_eq!(budget.in_use(), 0, "fold scratch released");
    }

    #[test]
    fn streaming_round_concurrent_ingest_no_global_lock_loss() {
        // 8 threads fold concurrently into 4 lanes; every update must land
        // exactly once and the fused mean must be exact.
        let s = Arc::new(
            RoundState::new_streaming(
                0,
                WorkloadClass::Streaming,
                MemoryBudget::unbounded(),
                Arc::new(FedAvg),
                4,
            )
            .unwrap(),
        );
        std::thread::scope(|scope| {
            for t in 0..8u64 {
                let s = s.clone();
                scope.spawn(move || {
                    for k in 0..4u64 {
                        s.ingest(upd(t * 4 + k, 256)).unwrap();
                    }
                });
            }
        });
        assert_eq!(s.collected(), 32);
        let (out, folded) = s.finish_streaming().unwrap();
        assert_eq!(folded, 32);
        assert!((out[0] - 1.0).abs() < 1e-4); // mean of all-ones
    }

    #[test]
    fn streaming_backpressure_absorbs_transient_pressure() {
        // Budget fits one lane accumulator + two in-flight frames; 8
        // concurrent uploaders racing for that headroom must ALL succeed
        // — the ingest waits out the pressure instead of failing uploads
        // that fit as soon as a neighbouring fold drains.
        const LEN: usize = 512;
        let budget = MemoryBudget::new((3 * LEN * 4) as u64);
        let s = Arc::new(
            RoundState::new_streaming(
                0,
                WorkloadClass::Streaming,
                budget.clone(),
                Arc::new(FedAvg),
                4,
            )
            .unwrap(),
        );
        std::thread::scope(|scope| {
            for t in 0..8u64 {
                let s = s.clone();
                scope.spawn(move || {
                    for k in 0..8u64 {
                        s.ingest(upd(t * 8 + k, LEN)).unwrap();
                    }
                });
            }
        });
        assert_eq!(s.collected(), 64);
        let (out, folded) = s.finish_streaming().unwrap();
        assert_eq!(folded, 64);
        assert!((out[0] - 1.0).abs() < 1e-4);
        assert_eq!(budget.in_use(), 0, "all scratch and in-flight released");
    }

    #[test]
    fn never_fitting_streaming_update_fails_fast() {
        // 500 B frame + 500 B lane scratch can never coexist in 600 B:
        // the ingest must report OOM immediately, not park the connection
        // thread for the whole backpressure grace window.
        let s = RoundState::new_streaming(
            0,
            WorkloadClass::Streaming,
            MemoryBudget::new(600),
            Arc::new(FedAvg),
            2,
        )
        .unwrap();
        let t0 = std::time::Instant::now();
        assert!(matches!(s.ingest(upd(0, 125)), Err(RoundError::Memory(_))));
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "fast-fail must not wait out the grace window"
        );
    }

    #[test]
    fn streaming_ingest_view_folds_in_place() {
        let budget = MemoryBudget::new(1 << 20);
        let s = RoundState::new_streaming(
            1,
            WorkloadClass::Streaming,
            budget.clone(),
            Arc::new(FedAvg),
            2,
        )
        .unwrap();
        for p in 0..6u64 {
            let u = upd(p, 100);
            s.ingest_view(&u.as_view()).unwrap();
        }
        assert_eq!(s.collected(), 6);
        // wrong-shape views are rejected like owned updates
        assert!(matches!(
            s.ingest_view(&upd(9, 99).as_view()),
            Err(RoundError::ShapeMismatch { want: 100, got: 99 })
        ));
        let (out, folded) = s.finish_streaming().unwrap();
        assert_eq!(folded, 6);
        assert_eq!(out.len(), 100);
        // a straggler view after the finish is a phase error, not a panic
        assert!(matches!(
            s.ingest_view(&upd(10, 100).as_view()),
            Err(RoundError::WrongPhase { .. })
        ));
    }

    #[test]
    fn buffered_ingest_view_copies_once_and_parks() {
        let r = RoundState::new(0, WorkloadClass::Small, MemoryBudget::new(1 << 20));
        let u = upd(0, 50);
        r.ingest_view(&u.as_view()).unwrap();
        assert_eq!(r.collected(), 1);
        let got = r.begin_aggregation().unwrap();
        assert_eq!(got[0], u);
    }

    /// The Fig 1 lift, as a unit test: a party count that OOMs the
    /// buffered path completes under the same budget when streaming —
    /// peak round memory is O(C), independent of N.
    #[test]
    fn streaming_breaks_the_buffered_party_ceiling() {
        const LEN: usize = 200; // 800-byte updates
        const BUDGET: u64 = 4096;

        // buffered: 5 × 800 B fit, the 6th trips OutOfMemory
        let buffered = RoundState::new(0, WorkloadClass::Small, MemoryBudget::new(BUDGET));
        for p in 0..5u64 {
            buffered.ingest(upd(p, LEN)).unwrap();
        }
        assert!(matches!(buffered.ingest(upd(5, LEN)), Err(RoundError::Memory(_))));

        // streaming under the SAME budget takes 64 parties (and would take
        // any N): peak resident = the S=2 lane accumulators + one
        // in-flight update (sequential driver), independent of N.
        let budget = MemoryBudget::new(BUDGET);
        let streaming = RoundState::new_streaming(
            0,
            WorkloadClass::Streaming,
            budget.clone(),
            Arc::new(FedAvg),
            2,
        )
        .unwrap();
        for p in 0..64u64 {
            streaming.ingest(upd(p, LEN)).unwrap();
        }
        assert_eq!(streaming.collected(), 64);
        assert!(
            budget.high_water() <= (2 + 1) * (LEN as u64 * 4),
            "peak {} must be O(S*C), not O(N*C)",
            budget.high_water()
        );
        let (out, folded) = streaming.finish_streaming().unwrap();
        assert_eq!(folded, 64);
        assert_eq!(out.len(), LEN);
        assert!((out[0] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn streaming_rejects_holistic_algorithms() {
        assert!(RoundState::new_streaming(
            0,
            WorkloadClass::Streaming,
            MemoryBudget::unbounded(),
            Arc::new(crate::fusion::CoordMedian),
            1,
        )
        .is_err());
    }

    #[test]
    fn duplicate_upload_folds_exactly_once_both_modes() {
        // Same party, same round: the second frame is a typed Duplicate
        // carrying the accepted nonce, and only one update lands.
        let buffered = RoundState::new(0, WorkloadClass::Small, MemoryBudget::unbounded());
        buffered.ingest_tagged(upd(5, 32), 0xA).unwrap();
        assert!(matches!(
            buffered.ingest_tagged(upd(5, 32), 0xB),
            Err(RoundError::Duplicate { party: 5, nonce: 0xA })
        ));
        assert_eq!(buffered.collected(), 1);

        let streaming = RoundState::new_streaming(
            0,
            WorkloadClass::Streaming,
            MemoryBudget::unbounded(),
            Arc::new(FedAvg),
            4,
        )
        .unwrap();
        streaming.ingest_tagged(upd(5, 32), 0xA).unwrap();
        assert!(matches!(
            streaming.ingest_tagged(upd(5, 32), 0xA),
            Err(RoundError::Duplicate { party: 5, nonce: 0xA })
        ));
        // views dedup through the same ledger
        assert!(matches!(
            streaming.ingest_view_tagged(&upd(5, 32).as_view(), 0xC),
            Err(RoundError::Duplicate { party: 5, .. })
        ));
        let (_, folded) = streaming.finish_streaming().unwrap();
        assert_eq!(folded, 1);
    }

    /// The sharded-path retransmit window, as a regression test: lanes are
    /// picked round-robin, so WITHOUT admission-time dedup a duplicate
    /// racing its original lands on a second lane and folds twice.  Racing
    /// the two frames from two threads must always yield exactly one fold
    /// and one typed Duplicate.
    #[test]
    fn duplicate_racing_original_folds_exactly_once() {
        for trial in 0..48u64 {
            let s = Arc::new(
                RoundState::new_streaming(
                    0,
                    WorkloadClass::Streaming,
                    MemoryBudget::unbounded(),
                    Arc::new(FedAvg),
                    4,
                )
                .unwrap(),
            );
            let barrier = Arc::new(std::sync::Barrier::new(2));
            let results: Vec<Result<usize, RoundError>> = std::thread::scope(|scope| {
                let handles: Vec<_> = (0..2)
                    .map(|_| {
                        let s = s.clone();
                        let b = barrier.clone();
                        scope.spawn(move || {
                            b.wait();
                            s.ingest_tagged(upd(7, 64), 0xBEEF)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            let oks = results.iter().filter(|r| r.is_ok()).count();
            // the loser sees Duplicate (winner already folded) or InFlight
            // (winner mid-fold) — never a second Ok, never a panic
            let rejected = results
                .iter()
                .filter(|r| {
                    matches!(
                        r,
                        Err(RoundError::Duplicate { party: 7, nonce: 0xBEEF })
                            | Err(RoundError::InFlight { party: 7 })
                    )
                })
                .count();
            assert_eq!((oks, rejected), (1, 1), "trial {trial}: {results:?}");
            assert_eq!(s.collected(), 1, "trial {trial}");
            let (out, folded) = s.finish_streaming().unwrap();
            assert_eq!(folded, 1);
            assert!((out[0] - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn failed_fold_releases_the_admission_slot() {
        // An update that OOMs (or otherwise fails) must not burn its
        // party's once-per-round slot: the retry is NOT a Duplicate.
        let s = RoundState::new_streaming(
            0,
            WorkloadClass::Streaming,
            MemoryBudget::new(600),
            Arc::new(FedAvg),
            2,
        )
        .unwrap();
        // 500 B frame + 500 B lane scratch cannot coexist in 600 B
        assert!(matches!(s.ingest_tagged(upd(3, 125), 1), Err(RoundError::Memory(_))));
        // the smaller retry from the same party is admitted and folds
        s.ingest_tagged(upd(3, 16), 2).unwrap();
        assert_eq!(s.collected(), 1);
        // ... and only NOW is the slot burned
        assert!(matches!(
            s.ingest_tagged(upd(3, 16), 3),
            Err(RoundError::Duplicate { party: 3, nonce: 2 })
        ));
    }

    #[test]
    fn abort_releases_memory_both_modes() {
        // buffered: the parked updates' reservations return to the budget
        let budget = MemoryBudget::new(1 << 20);
        let r = RoundState::new(2, WorkloadClass::Small, budget.clone());
        r.ingest(upd(0, 200)).unwrap();
        r.ingest(upd(1, 200)).unwrap();
        assert_eq!(budget.in_use(), 1600);
        r.abort().unwrap();
        assert_eq!(r.phase(), RoundPhase::Aborted);
        assert_eq!(budget.in_use(), 0, "buffered abort must release the parked updates");

        // streaming: the sharded fold's lane scratch returns too
        let budget = MemoryBudget::new(1 << 20);
        let s = RoundState::new_streaming(
            3,
            WorkloadClass::Streaming,
            budget.clone(),
            Arc::new(FedAvg),
            2,
        )
        .unwrap();
        for p in 0..6u64 {
            s.ingest(upd(p, 128)).unwrap();
        }
        assert!(budget.in_use() > 0);
        s.abort().unwrap();
        assert_eq!(budget.in_use(), 0, "streaming abort must release the lane scratch");
        // the sealed fold rejects stragglers as WrongPhase, not a panic
        assert!(matches!(
            s.ingest(upd(9, 128)),
            Err(RoundError::WrongPhase { actual: RoundPhase::Aborted, .. })
        ));
    }

    #[test]
    fn quorum_abort_transition_table() {
        // Table-driven over both modes: which phases may abort, and what
        // every operation returns afterwards.
        #[derive(Clone, Copy)]
        enum Mode {
            Buffered,
            Streaming,
        }
        for mode in [Mode::Buffered, Mode::Streaming] {
            let make = |round: u32| match mode {
                Mode::Buffered => {
                    RoundState::new(round, WorkloadClass::Small, MemoryBudget::unbounded())
                }
                Mode::Streaming => RoundState::new_streaming(
                    round,
                    WorkloadClass::Streaming,
                    MemoryBudget::unbounded(),
                    Arc::new(FedAvg),
                    2,
                )
                .unwrap(),
            };

            // Collecting -> Aborted is the dropout path
            let r = make(0);
            r.ingest(upd(0, 16)).unwrap();
            r.abort().unwrap();
            assert_eq!(r.phase(), RoundPhase::Aborted);
            // every later operation is a typed WrongPhase against Aborted
            assert!(matches!(
                r.ingest(upd(1, 16)),
                Err(RoundError::WrongPhase { actual: RoundPhase::Aborted, .. })
            ));
            assert!(matches!(r.begin_aggregation(), Err(RoundError::WrongPhase { .. })));
            assert!(matches!(r.finish_streaming(), Err(RoundError::WrongPhase { .. })));
            assert!(matches!(r.publish(vec![]), Err(RoundError::WrongPhase { .. })));
            assert!(matches!(
                r.abort(),
                Err(RoundError::WrongPhase { actual: RoundPhase::Aborted, .. })
            ));
            assert!(r.fused().is_none(), "an aborted round never publishes");
            assert_eq!(r.collected(), 0);

            // Aggregating -> Aborted is the owner-cancel path
            let r = make(1);
            r.ingest(upd(0, 16)).unwrap();
            match mode {
                Mode::Buffered => drop(r.begin_aggregation().unwrap()),
                Mode::Streaming => drop(r.finish_streaming().unwrap()),
            }
            r.abort().unwrap();
            assert_eq!(r.phase(), RoundPhase::Aborted);

            // Published rounds are immutable: abort is WrongPhase
            let r = make(2);
            r.ingest(upd(0, 16)).unwrap();
            let fused = match mode {
                Mode::Buffered => {
                    let us = r.begin_aggregation().unwrap();
                    vec![0.5; us[0].data.len()]
                }
                Mode::Streaming => r.finish_streaming().unwrap().0,
            };
            r.publish(fused).unwrap();
            assert!(matches!(
                r.abort(),
                Err(RoundError::WrongPhase { actual: RoundPhase::Published, .. })
            ));
            assert!(r.fused().is_some());
        }
    }

    #[test]
    fn seal_vs_ingest_race_is_typed_both_modes() {
        // Concurrent finish/ingest: every ingest either lands before the
        // seal (counted) or gets a typed WrongPhase — never a panic, and
        // the fold count always equals the successful ingests.
        for _ in 0..16 {
            let s = Arc::new(
                RoundState::new_streaming(
                    0,
                    WorkloadClass::Streaming,
                    MemoryBudget::unbounded(),
                    Arc::new(FedAvg),
                    4,
                )
                .unwrap(),
            );
            s.ingest(upd(1000, 64)).unwrap(); // the finisher must see ≥1
            let (oks, folded) = std::thread::scope(|scope| {
                let uploaders: Vec<_> = (0..4u64)
                    .map(|t| {
                        let s = s.clone();
                        scope.spawn(move || {
                            let mut oks = 0usize;
                            for k in 0..8u64 {
                                match s.ingest(upd(t * 8 + k, 64)) {
                                    Ok(_) => oks += 1,
                                    Err(RoundError::WrongPhase { .. }) => {}
                                    Err(e) => panic!("unexpected: {e}"),
                                }
                            }
                            oks
                        })
                    })
                    .collect();
                let finisher = {
                    let s = s.clone();
                    scope.spawn(move || {
                        std::thread::sleep(Duration::from_micros(200));
                        s.finish_streaming().unwrap().1
                    })
                };
                let oks: usize = uploaders.into_iter().map(|h| h.join().unwrap()).sum();
                (oks, finisher.join().unwrap())
            });
            assert_eq!(folded, oks + 1, "every successful ingest is merged and counted");
        }

        // buffered flavour: begin_aggregation racing ingest
        let r = Arc::new(RoundState::new(0, WorkloadClass::Small, MemoryBudget::unbounded()));
        r.ingest(upd(500, 16)).unwrap();
        std::thread::scope(|scope| {
            let uploader = {
                let r = r.clone();
                scope.spawn(move || {
                    for p in 0..32u64 {
                        match r.ingest(upd(p, 16)) {
                            Ok(_) | Err(RoundError::WrongPhase { .. }) => {}
                            Err(e) => panic!("unexpected: {e}"),
                        }
                    }
                })
            };
            let taken = scope.spawn(|| r.begin_aggregation().unwrap().len());
            uploader.join().unwrap();
            assert!(taken.join().unwrap() >= 1);
        });
    }

    /// An edge cohort pre-folded into a partial over all-ones weight-1.0
    /// updates: sum = |cohort| per element, wtot = |cohort|.
    fn partial(edge: u64, parties: Vec<u64>, len: usize) -> crate::tensorstore::PartialAggregate {
        let k = parties.len();
        crate::tensorstore::PartialAggregate::new(
            edge,
            0,
            k as f64,
            parties,
            vec![k as f32; len],
        )
    }

    fn streaming_round() -> RoundState {
        RoundState::new_streaming(
            0,
            WorkloadClass::Streaming,
            MemoryBudget::unbounded(),
            Arc::new(FedAvg),
            2,
        )
        .unwrap()
    }

    #[test]
    fn partial_ingest_folds_cohort_and_counts_members() {
        let s = streaming_round();
        s.ingest(upd(100, 64)).unwrap();
        s.ingest(upd(101, 64)).unwrap();
        let p = partial(7, vec![1, 2, 3, 4], 64);
        let n = s.ingest_partial(&p.as_view()).unwrap();
        assert_eq!(n, 6, "cohort MEMBERS advance the count, not frames");
        assert_eq!(s.collected(), 6);
        let (out, folded) = s.finish_streaming().unwrap();
        assert_eq!(folded, 6);
        // 2 direct all-ones + a 4-member all-ones partial: exact mean 1.0
        assert!((out[0] - 1.0).abs() < 1e-5);
        assert_eq!(s.folded_parties(), vec![1, 2, 3, 4, 100, 101]);
    }

    #[test]
    fn partial_and_direct_upload_cannot_double_fold() {
        // direct first: the cohort claiming that party is rejected WHOLE,
        // and nothing else is claimed — the other members stay free
        let s = streaming_round();
        s.ingest_tagged(upd(3, 16), 0xD).unwrap();
        let p = partial(9, vec![2, 3, 4], 16);
        assert!(matches!(
            s.ingest_partial_tagged(&p.as_view(), 0xE),
            Err(RoundError::Duplicate { party: 3, nonce: 0xD })
        ));
        assert_eq!(s.collected(), 1, "the poisoned cohort must not fold");
        s.ingest(upd(4, 16)).unwrap(); // member 4 was never claimed
        assert_eq!(s.collected(), 2);

        // partial first: a stray direct upload from a cohort member is the
        // plain typed Duplicate carrying the partial's nonce
        let s = streaming_round();
        s.ingest_partial_tagged(&partial(9, vec![5, 6], 16).as_view(), 0xAB)
            .unwrap();
        assert!(matches!(
            s.ingest_tagged(upd(6, 16), 0xCC),
            Err(RoundError::Duplicate { party: 6, nonce: 0xAB })
        ));
        // ... and so is a retransmit of the partial itself
        assert!(matches!(
            s.ingest_partial_tagged(&partial(9, vec![5, 6], 16).as_view(), 0xAD),
            Err(RoundError::Duplicate { party: 5, nonce: 0xAB })
        ));
        assert_eq!(s.collected(), 2);
    }

    #[test]
    fn buffered_round_rejects_partials_without_claiming_slots() {
        let r = RoundState::new(0, WorkloadClass::Small, MemoryBudget::unbounded());
        let p = partial(1, vec![10, 11], 16);
        assert!(matches!(
            r.ingest_partial(&p.as_view()),
            Err(RoundError::NotStreaming)
        ));
        // the failed ingest released the cohort's slots
        r.ingest(upd(10, 16)).unwrap();
        assert_eq!(r.collected(), 1);
    }

    #[test]
    fn malformed_partials_are_typed_errors() {
        let s = streaming_round();
        // empty cohort
        assert!(matches!(
            s.ingest_partial(&partial(1, vec![], 16).as_view()),
            Err(RoundError::Engine(EngineError::Fusion(FusionError::Empty)))
        ));
        // in-cohort duplicate party: a dedicated error, NOT Duplicate —
        // Duplicate would tell the relay an earlier upload was accepted
        assert!(matches!(
            s.ingest_partial_tagged(&partial(1, vec![7, 8, 7], 16).as_view(), 0x1),
            Err(RoundError::MalformedCohort { party: 7 })
        ));
        // neither claimed anything
        s.ingest(upd(7, 16)).unwrap();
        // wrong shape: rejected at ingest, slots released for a retry
        s.ingest_partial(&partial(1, vec![20, 21], 17).as_view()).unwrap_err();
        s.ingest_partial(&partial(1, vec![20, 21], 16).as_view()).unwrap();
        assert_eq!(s.collected(), 3);
    }

    #[test]
    fn finish_streaming_partial_returns_raw_state() {
        let budget = MemoryBudget::new(1 << 20);
        let s = RoundState::new_streaming(
            5,
            WorkloadClass::Streaming,
            budget.clone(),
            Arc::new(FedAvg),
            2,
        )
        .unwrap();
        for p in [4u64, 9, 2] {
            s.ingest(upd(p, 32)).unwrap();
        }
        let (acc, folded, parties) = s.finish_streaming_partial().unwrap();
        assert_eq!(folded, 3);
        assert_eq!(parties, vec![2, 4, 9]);
        assert_eq!(acc.n, 3);
        assert_eq!(acc.wtot, 3.0);
        // RAW weighted sums (3 × 1.0 × 1.0), not the finalized mean
        assert!((acc.sum[0] - 3.0).abs() < 1e-5);
        assert_eq!(budget.in_use(), 0, "the drain released the lane scratch");
        // the relay can still publish the parent's fused model locally
        assert_eq!(s.phase(), RoundPhase::Aggregating);
        s.publish(vec![0.5; 32]).unwrap();
        // a buffered round gets the typed error
        let r = RoundState::new(0, WorkloadClass::Small, MemoryBudget::unbounded());
        assert!(matches!(r.finish_streaming_partial(), Err(RoundError::NotStreaming)));
    }

    #[test]
    fn finish_streaming_on_buffered_round_is_typed_error() {
        let r = RoundState::new(0, WorkloadClass::Small, MemoryBudget::unbounded());
        r.ingest(upd(0, 16)).unwrap();
        assert!(matches!(r.finish_streaming(), Err(RoundError::NotStreaming)));
        // and the buffered set survived the failed call
        assert_eq!(r.collected(), 1);
        assert_eq!(r.begin_aggregation().unwrap().len(), 1);
    }
}
