//! Party registry: membership, liveness, per-round selection and the
//! reputation ledger.
//!
//! FL parties join during training and drop out at any time (§III-C); the
//! registry is the coordinator's source of truth for "how many updates
//! should I expect next round" — the quantity the classifier turns into a
//! path decision and the monitor into a threshold.
//!
//! It also persists each party's **trust score** across rounds: 1.0 for a
//! party in good standing, multiplied by `trust_decay` every time its
//! update lands far from the fleet (norm beyond twice the sealed median)
//! or is rejected outright, and recovered additively (`+0.1` per honest
//! round, capped at exactly 1.0 so uniform-trust rounds stay bit-identical
//! to FedAvg).  [`TrustWeighted`](crate::fusion::TrustWeighted) reads the
//! score as a fusion-layer weight multiplier.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::rng::Rng;

/// Additive trust recovered by an honest round (capped at 1.0).
const TRUST_RECOVER_STEP: f32 = 0.1;

/// A norm counts as an outlier when it exceeds this multiple of the
/// sealed median norm.
const OUTLIER_FACTOR: f32 = 2.0;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PartyInfo {
    pub id: u64,
    /// Round at which the party joined.
    pub joined_round: u32,
    pub active: bool,
    /// Sample count the party reported (its FedAvg weight).
    pub samples: u64,
}

#[derive(Default)]
pub struct PartyRegistry {
    parties: Mutex<BTreeMap<u64, PartyInfo>>,
    /// Per-party trust scores in `[0, 1]`; absent means 1.0 (fully
    /// trusted).  Kept out of [`PartyInfo`] so the membership record
    /// stays `Eq` and join/leave stays reputation-neutral.
    trust: Mutex<BTreeMap<u64, f32>>,
    /// L2 norms observed this round, sealed into a median at round end.
    norms: Mutex<Vec<(u64, f32)>>,
    /// Median update norm of the last sealed round — the clip/reject
    /// reference for the next one.  `None` until a first honest round
    /// establishes it.
    norm_ref: Mutex<Option<f32>>,
    /// Per-party `last_seen` heartbeat stamps (join / upload / explicit
    /// heartbeat all refresh it).  Kept out of [`PartyInfo`] — like
    /// `trust` — so the membership record stays `Eq`; this is the
    /// edge-node liveness record (node id + last-heartbeat timestamp)
    /// that lets [`PartyRegistry::evict_stale`] drop silent parties from
    /// quorum accounting instead of awaiting them to the deadline.
    seen: Mutex<BTreeMap<u64, Instant>>,
}

impl PartyRegistry {
    pub fn new() -> PartyRegistry {
        PartyRegistry::default()
    }

    /// Register (or re-activate) a party; returns its id.
    pub fn join(&self, id: u64, round: u32, samples: u64) -> u64 {
        {
            let mut m = self.parties.lock().unwrap();
            m.entry(id)
                .and_modify(|p| {
                    p.active = true;
                    p.samples = samples;
                })
                .or_insert(PartyInfo { id, joined_round: round, active: true, samples });
        }
        // Joining IS a liveness signal (lock released above; `seen` and
        // `parties` are never held together from this path).
        self.note_seen(id);
        id
    }

    /// Refresh a party's `last_seen` stamp — called on join, on every
    /// upload, and on an explicit [`Heartbeat`](crate::net::Message)
    /// frame.
    pub fn note_seen(&self, id: u64) {
        self.seen.lock().unwrap().insert(id, Instant::now());
    }

    /// When the party last gave a liveness signal.
    pub fn last_seen(&self, id: u64) -> Option<Instant> {
        self.seen.lock().unwrap().get(&id).copied()
    }

    /// Deactivate every active party whose last liveness signal is older
    /// than `ttl` as of `now`; returns the evicted ids.  An evicted party
    /// leaves quorum accounting (`active_count`) immediately — the round
    /// loop uses that to seal on the live population instead of awaiting
    /// dead clients to the deadline — and rejoins normally on its next
    /// register/upload/heartbeat.
    pub fn evict_stale(&self, ttl: Duration, now: Instant) -> Vec<u64> {
        let stale: Vec<u64> = {
            let seen = self.seen.lock().unwrap();
            self.parties
                .lock()
                .unwrap()
                .values()
                .filter(|p| p.active)
                .filter(|p| match seen.get(&p.id) {
                    Some(&t) => now.saturating_duration_since(t) > ttl,
                    None => true, // no signal ever: stale by definition
                })
                .map(|p| p.id)
                .collect()
        };
        if !stale.is_empty() {
            let mut m = self.parties.lock().unwrap();
            for id in &stale {
                if let Some(p) = m.get_mut(id) {
                    p.active = false;
                }
            }
        }
        stale
    }

    /// Heartbeat-derived live fraction: of all registered parties, how
    /// many produced a liveness signal within `ttl` of `now`.  Returns
    /// `(live, registered)`.  Read-only — nobody is evicted here (that is
    /// [`PartyRegistry::evict_stale`]'s job); the round loop feeds this
    /// pair into the planner's turnout EWMA so a fleet that stops
    /// heartbeating lowers the priced participation even before quorum
    /// accounting catches up.
    pub fn live_fraction(&self, ttl: Duration, now: Instant) -> (usize, usize) {
        let seen = self.seen.lock().unwrap();
        let parties = self.parties.lock().unwrap();
        let registered = parties.len();
        let live = parties
            .values()
            .filter(|p| match seen.get(&p.id) {
                Some(&t) => now.saturating_duration_since(t) <= ttl,
                None => false,
            })
            .count();
        (live, registered)
    }

    /// Mark a party dropped out.
    pub fn leave(&self, id: u64) {
        if let Some(p) = self.parties.lock().unwrap().get_mut(&id) {
            p.active = false;
        }
    }

    pub fn active_count(&self) -> usize {
        self.parties.lock().unwrap().values().filter(|p| p.active).count()
    }

    pub fn total_count(&self) -> usize {
        self.parties.lock().unwrap().len()
    }

    pub fn get(&self, id: u64) -> Option<PartyInfo> {
        self.parties.lock().unwrap().get(&id).cloned()
    }

    /// Select up to `k` active parties for a round (uniform without
    /// replacement — the Bonawitz-style sampling the paper contrasts with).
    pub fn select(&self, k: usize, rng: &mut Rng) -> Vec<u64> {
        let ids: Vec<u64> = self
            .parties
            .lock()
            .unwrap()
            .values()
            .filter(|p| p.active)
            .map(|p| p.id)
            .collect();
        if k >= ids.len() {
            return ids;
        }
        let mut idx = rng.sample_indices(ids.len(), k);
        idx.sort_unstable();
        idx.into_iter().map(|i| ids[i]).collect()
    }

    /// The party's trust score; 1.0 for parties never penalised.
    pub fn trust(&self, id: u64) -> f32 {
        *self.trust.lock().unwrap().get(&id).unwrap_or(&1.0)
    }

    /// Multiply the party's trust by `decay` (a rejection or a sealed
    /// outlier verdict).  `decay` is sanitised to `[0, 1]` at use — a
    /// NaN or out-of-range knob cannot *raise* trust.
    pub fn penalize(&self, id: u64, decay: f32) -> f32 {
        let decay = if decay.is_finite() { decay.clamp(0.0, 1.0) } else { 0.5 };
        let mut m = self.trust.lock().unwrap();
        let t = m.entry(id).or_insert(1.0);
        *t *= decay;
        *t
    }

    /// Record an accepted update's L2 norm for this round's median.
    pub fn observe_norm(&self, id: u64, norm: f32) {
        if norm.is_finite() && norm >= 0.0 {
            self.norms.lock().unwrap().push((id, norm));
        }
    }

    /// The clip/reject reference: median update norm of the last sealed
    /// round.
    pub fn norm_ref(&self) -> Option<f32> {
        *self.norm_ref.lock().unwrap()
    }

    /// Force the norm reference (tests and warm restarts).
    pub fn set_norm_ref(&self, r: Option<f32>) {
        *self.norm_ref.lock().unwrap() = r;
    }

    /// Drop this round's norm observations without judging anyone — an
    /// aborted round must not move trust or the reference.
    pub fn reset_norms(&self) {
        self.norms.lock().unwrap().clear();
    }

    /// Seal a round: fold the observed norms into a median, judge each
    /// contributor against it (outlier distance beyond
    /// [`OUTLIER_FACTOR`]× the median decays trust, honest standing
    /// recovers it toward exactly 1.0), publish the median as the next
    /// round's norm reference, and clear the observations.  Returns the
    /// sealed median, or `None` when the round folded nothing.
    pub fn seal_norms(&self, trust_decay: f32) -> Option<f32> {
        let obs: Vec<(u64, f32)> = std::mem::take(&mut *self.norms.lock().unwrap());
        if obs.is_empty() {
            return None;
        }
        let mut vals: Vec<f32> = obs.iter().map(|&(_, n)| n).collect();
        vals.sort_by(|a, b| a.total_cmp(b));
        // Upper median: deterministic, no averaging — bit-stable digests.
        let median = vals[vals.len() / 2];
        {
            let mut trust = self.trust.lock().unwrap();
            for &(id, norm) in &obs {
                if norm > OUTLIER_FACTOR * median {
                    let decay =
                        if trust_decay.is_finite() { trust_decay.clamp(0.0, 1.0) } else { 0.5 };
                    let t = trust.entry(id).or_insert(1.0);
                    *t *= decay;
                } else if let Some(t) = trust.get_mut(&id) {
                    // Honest recovery; parties at exactly 1.0 have no
                    // entry to touch, so good standing stays bit-free.
                    *t = (*t + TRUST_RECOVER_STEP).min(1.0);
                }
            }
        }
        *self.norm_ref.lock().unwrap() = Some(median);
        Some(median)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn join_leave_rejoin() {
        let r = PartyRegistry::new();
        r.join(1, 0, 100);
        r.join(2, 0, 200);
        assert_eq!(r.active_count(), 2);
        r.leave(1);
        assert_eq!(r.active_count(), 1);
        assert_eq!(r.total_count(), 2);
        r.join(1, 5, 150);
        assert_eq!(r.active_count(), 2);
        let p = r.get(1).unwrap();
        assert_eq!(p.samples, 150);
        assert_eq!(p.joined_round, 0); // original join round preserved
    }

    #[test]
    fn leave_unknown_is_noop() {
        let r = PartyRegistry::new();
        r.leave(99);
        assert_eq!(r.total_count(), 0);
    }

    #[test]
    fn select_subset_is_active_only() {
        let r = PartyRegistry::new();
        for i in 0..20 {
            r.join(i, 0, 10);
        }
        r.leave(3);
        r.leave(7);
        let mut rng = Rng::new(1);
        let sel = r.select(10, &mut rng);
        assert_eq!(sel.len(), 10);
        assert!(!sel.contains(&3) || !sel.contains(&7) || true);
        for id in &sel {
            assert!(r.get(*id).unwrap().active);
        }
    }

    #[test]
    fn select_more_than_available_returns_all_active() {
        let r = PartyRegistry::new();
        for i in 0..5 {
            r.join(i, 0, 1);
        }
        r.leave(0);
        let mut rng = Rng::new(2);
        let sel = r.select(100, &mut rng);
        assert_eq!(sel.len(), 4);
    }

    #[test]
    fn trust_defaults_to_one_and_decays_on_penalty() {
        let r = PartyRegistry::new();
        assert_eq!(r.trust(7), 1.0);
        assert_eq!(r.penalize(7, 0.5), 0.5);
        assert_eq!(r.penalize(7, 0.5), 0.25);
        // sanitised decay: NaN falls back, never raises trust
        let t = r.penalize(7, f32::NAN);
        assert!(t <= 0.25);
        assert!(r.penalize(8, 2.0) <= 1.0, "decay > 1 must clamp");
    }

    #[test]
    fn seal_norms_judges_outliers_and_publishes_median() {
        let r = PartyRegistry::new();
        for (id, norm) in [(1, 1.0f32), (2, 1.1), (3, 0.9), (4, 1.05), (5, 50.0)] {
            r.observe_norm(id, norm);
        }
        let med = r.seal_norms(0.5).unwrap();
        assert_eq!(med, 1.05, "upper median of the sorted norms");
        assert_eq!(r.norm_ref(), Some(1.05));
        assert_eq!(r.trust(5), 0.5, "50.0 > 2x median decays");
        for id in 1..=4 {
            assert_eq!(r.trust(id), 1.0, "honest party {id} keeps exact 1.0");
        }
        // next seal with honest behaviour recovers the outlier
        for id in 1..=5 {
            r.observe_norm(id, 1.0);
        }
        r.seal_norms(0.5);
        assert_eq!(r.trust(5), 0.6);
    }

    #[test]
    fn seal_empty_round_is_none_and_reset_drops_observations() {
        let r = PartyRegistry::new();
        assert_eq!(r.seal_norms(0.5), None);
        assert_eq!(r.norm_ref(), None);
        r.observe_norm(1, 3.0);
        r.observe_norm(2, f32::NAN); // ignored at observe
        r.reset_norms();
        assert_eq!(r.seal_norms(0.5), None, "aborted round judged nobody");
        assert_eq!(r.trust(1), 1.0);
    }

    #[test]
    fn join_stamps_liveness_and_evict_drops_silent_parties() {
        let r = PartyRegistry::new();
        for id in 0..4 {
            r.join(id, 0, 10);
            assert!(r.last_seen(id).is_some(), "join is a liveness signal");
        }
        // evaluated right now: nobody is stale yet
        assert!(r.evict_stale(Duration::from_millis(100), Instant::now()).is_empty());
        assert_eq!(r.active_count(), 4);
        // evaluated 250ms in the future with a 200ms ttl: every stamp has
        // aged out (BTreeMap order makes the eviction list deterministic)
        let later = Instant::now() + Duration::from_millis(250);
        let evicted = r.evict_stale(Duration::from_millis(200), later);
        assert_eq!(evicted, vec![0, 1, 2, 3], "everyone is silent 250ms out");
        assert_eq!(r.active_count(), 0);
        // an evicted party rejoins (and re-stamps) normally
        r.join(2, 7, 10);
        assert_eq!(r.active_count(), 1);
        assert!(r.evict_stale(Duration::from_millis(200), Instant::now()).is_empty());
    }

    #[test]
    fn evict_respects_fresh_heartbeats() {
        let r = PartyRegistry::new();
        for id in 0..4 {
            r.join(id, 0, 10);
        }
        let t0 = Instant::now();
        std::thread::sleep(Duration::from_millis(30));
        r.note_seen(1);
        r.note_seen(3);
        // ttl covering the heartbeat gap but not the join stamps: the
        // heartbeating parties survive, the silent ones are evicted
        let now = t0 + Duration::from_millis(30);
        let evicted = r.evict_stale(Duration::from_millis(20), now);
        assert_eq!(evicted, vec![0, 2]);
        assert_eq!(r.active_count(), 2);
        assert!(r.get(1).unwrap().active);
        assert!(!r.get(0).unwrap().active);
    }

    #[test]
    fn live_fraction_counts_fresh_stamps_without_evicting() {
        let r = PartyRegistry::new();
        for id in 0..4 {
            r.join(id, 0, 10);
        }
        let t0 = Instant::now();
        std::thread::sleep(Duration::from_millis(30));
        r.note_seen(1);
        r.note_seen(3);
        // ttl covering the heartbeat gap but not the join stamps
        let now = t0 + Duration::from_millis(30);
        assert_eq!(r.live_fraction(Duration::from_millis(20), now), (2, 4));
        // read-only: nobody was deactivated by asking
        assert_eq!(r.active_count(), 4);
        // a generous ttl counts everyone; an empty registry is (0, 0)
        assert_eq!(r.live_fraction(Duration::from_secs(60), now), (4, 4));
        assert_eq!(
            PartyRegistry::new().live_fraction(Duration::from_secs(1), Instant::now()),
            (0, 0)
        );
    }

    #[test]
    fn party_with_no_liveness_record_is_stale() {
        let r = PartyRegistry::new();
        r.join(5, 0, 1);
        // wipe the stamp to model a registry restored without stamps
        r.seen.lock().unwrap().clear();
        let evicted = r.evict_stale(Duration::from_secs(3600), Instant::now());
        assert_eq!(evicted, vec![5]);
    }

    #[test]
    fn concurrent_joins_are_safe() {
        let r = std::sync::Arc::new(PartyRegistry::new());
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let r = r.clone();
                s.spawn(move || {
                    for i in 0..100u64 {
                        r.join(t * 1000 + i, 0, 1);
                    }
                });
            }
        });
        assert_eq!(r.total_count(), 400);
    }
}
