//! Party registry: membership, liveness and per-round selection.
//!
//! FL parties join during training and drop out at any time (§III-C); the
//! registry is the coordinator's source of truth for "how many updates
//! should I expect next round" — the quantity the classifier turns into a
//! path decision and the monitor into a threshold.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PartyInfo {
    pub id: u64,
    /// Round at which the party joined.
    pub joined_round: u32,
    pub active: bool,
    /// Sample count the party reported (its FedAvg weight).
    pub samples: u64,
}

#[derive(Default)]
pub struct PartyRegistry {
    parties: Mutex<BTreeMap<u64, PartyInfo>>,
}

impl PartyRegistry {
    pub fn new() -> PartyRegistry {
        PartyRegistry::default()
    }

    /// Register (or re-activate) a party; returns its id.
    pub fn join(&self, id: u64, round: u32, samples: u64) -> u64 {
        let mut m = self.parties.lock().unwrap();
        m.entry(id)
            .and_modify(|p| {
                p.active = true;
                p.samples = samples;
            })
            .or_insert(PartyInfo { id, joined_round: round, active: true, samples });
        id
    }

    /// Mark a party dropped out.
    pub fn leave(&self, id: u64) {
        if let Some(p) = self.parties.lock().unwrap().get_mut(&id) {
            p.active = false;
        }
    }

    pub fn active_count(&self) -> usize {
        self.parties.lock().unwrap().values().filter(|p| p.active).count()
    }

    pub fn total_count(&self) -> usize {
        self.parties.lock().unwrap().len()
    }

    pub fn get(&self, id: u64) -> Option<PartyInfo> {
        self.parties.lock().unwrap().get(&id).cloned()
    }

    /// Select up to `k` active parties for a round (uniform without
    /// replacement — the Bonawitz-style sampling the paper contrasts with).
    pub fn select(&self, k: usize, rng: &mut Rng) -> Vec<u64> {
        let ids: Vec<u64> = self
            .parties
            .lock()
            .unwrap()
            .values()
            .filter(|p| p.active)
            .map(|p| p.id)
            .collect();
        if k >= ids.len() {
            return ids;
        }
        let mut idx = rng.sample_indices(ids.len(), k);
        idx.sort_unstable();
        idx.into_iter().map(|i| ids[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn join_leave_rejoin() {
        let r = PartyRegistry::new();
        r.join(1, 0, 100);
        r.join(2, 0, 200);
        assert_eq!(r.active_count(), 2);
        r.leave(1);
        assert_eq!(r.active_count(), 1);
        assert_eq!(r.total_count(), 2);
        r.join(1, 5, 150);
        assert_eq!(r.active_count(), 2);
        let p = r.get(1).unwrap();
        assert_eq!(p.samples, 150);
        assert_eq!(p.joined_round, 0); // original join round preserved
    }

    #[test]
    fn leave_unknown_is_noop() {
        let r = PartyRegistry::new();
        r.leave(99);
        assert_eq!(r.total_count(), 0);
    }

    #[test]
    fn select_subset_is_active_only() {
        let r = PartyRegistry::new();
        for i in 0..20 {
            r.join(i, 0, 10);
        }
        r.leave(3);
        r.leave(7);
        let mut rng = Rng::new(1);
        let sel = r.select(10, &mut rng);
        assert_eq!(sel.len(), 10);
        assert!(!sel.contains(&3) || !sel.contains(&7) || true);
        for id in &sel {
            assert!(r.get(*id).unwrap().active);
        }
    }

    #[test]
    fn select_more_than_available_returns_all_active() {
        let r = PartyRegistry::new();
        for i in 0..5 {
            r.join(i, 0, 1);
        }
        r.leave(0);
        let mut rng = Rng::new(2);
        let sel = r.select(100, &mut rng);
        assert_eq!(sel.len(), 4);
    }

    #[test]
    fn concurrent_joins_are_safe() {
        let r = std::sync::Arc::new(PartyRegistry::new());
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let r = r.clone();
                s.spawn(move || {
                    for i in 0..100u64 {
                        r.join(t * 1000 + i, 0, 1);
                    }
                });
            }
        });
        assert_eq!(r.total_count(), 400);
    }
}
