//! Workload classification (paper §III-C + Algorithm 1).
//!
//! `S = w_s × n` — the round's total update volume — is compared against
//! the single node's usable memory.  *Small* workloads fit and take the
//! in-memory path; *large* ones go distributed.  The effective memory
//! requirement is inflated by (a) a configurable headroom for the result
//! buffer and framework overhead, and (b) the fusion algorithm's
//! duplication factor (holistic algorithms must materialise the whole set;
//! the IBMFL averaging implementations hold input + working copies — the
//! factors are fitted from the paper's Fig 1 OOM points, see `cluster`).
//!
//! Since the cost-aware planner landed, this binary test is no longer the
//! dispatch decision itself: the classifier is the *feasibility oracle*
//! the [`DispatchPlanner`](crate::planner::DispatchPlanner) consults —
//! single-node plans are only enumerated (and priced) when the round
//! classifies `Small`; which feasible plan actually runs is chosen by the
//! configured [`DispatchPolicy`](crate::planner::DispatchPolicy).

use crate::cluster::{FEDAVG_DUP_FACTOR, ITERAVG_DUP_FACTOR};
use crate::fusion::FusionAlgorithm;

/// Where a round's aggregation should run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadClass {
    /// Fits the aggregator node: single-node parallel path.
    Small,
    /// The buffered set would spill, but the algorithm is an associative
    /// fold: updates stream through an O(C) accumulator on the node
    /// instead of redirecting to MapReduce (the Fig 1 ceiling lift).
    Streaming,
    /// Exceeds node memory even for streaming (or the algorithm is
    /// holistic): distributed MapReduce-over-DFS path.
    Large,
}

#[derive(Clone, Debug)]
pub struct WorkloadClassifier {
    /// Usable aggregation memory of the single node (bytes).
    pub memory_bytes: u64,
    /// Safety multiplier on the estimated requirement (default 1.10).
    pub headroom: f64,
}

impl WorkloadClassifier {
    pub fn new(memory_bytes: u64, headroom: f64) -> WorkloadClassifier {
        WorkloadClassifier { memory_bytes, headroom }
    }

    /// Memory-duplication factor for an algorithm: how many bytes the
    /// single-node implementation needs per update byte.
    pub fn dup_factor(algo: &dyn FusionAlgorithm) -> f64 {
        if !algo.decomposable() {
            // Holistic algorithms hold the entire update set + scratch.
            2.2
        } else {
            match algo.name() {
                "fedavg" | "gradavg" | "clipped" => FEDAVG_DUP_FACTOR,
                "iteravg" => ITERAVG_DUP_FACTOR,
                _ => FEDAVG_DUP_FACTOR,
            }
        }
    }

    /// Estimated bytes the single-node path needs for this round.
    pub fn required_bytes(&self, update_bytes: u64, parties: usize, algo: &dyn FusionAlgorithm) -> u64 {
        let s = update_bytes as f64 * parties as f64;
        (s * Self::dup_factor(algo) * self.headroom) as u64
    }

    /// Algorithm 1's test: `if S < M` → same-node, else distributed.
    pub fn classify(
        &self,
        update_bytes: u64,
        parties: usize,
        algo: &dyn FusionAlgorithm,
    ) -> WorkloadClass {
        if self.required_bytes(update_bytes, parties, algo) < self.memory_bytes {
            WorkloadClass::Small
        } else {
            WorkloadClass::Large
        }
    }

    /// Resident bytes of the streaming-fold path's *minimum feasible
    /// shape*: one O(C) running accumulator plus one in-flight update
    /// buffer, inflated by headroom.  Independent of the party count —
    /// that is the whole point.  The sharded server prefers S ≈ cores
    /// lane accumulators (S·O(C)) but its budget fallback degrades
    /// gracefully to this single-lane shape, so feasibility deliberately
    /// guarantees only the floor; the planner separately caps the lane
    /// width it prices at what the budget admits.
    pub fn streaming_required_bytes(&self, update_bytes: u64) -> u64 {
        (update_bytes as f64 * 2.0 * self.headroom) as u64
    }

    /// Whether the streaming fold can run this round at all: the algorithm
    /// must be partial-foldable and the O(C) working set must fit the
    /// node.  The single source of truth shared by
    /// `classify_with_streaming` and the planner's candidate enumeration.
    ///
    /// Partial-foldable is wider than decomposable: a sketch-carrying
    /// robust algorithm (trimmed mean) folds mergeable state that is not
    /// weight-linear.  Its working set is the O(C) accumulator *plus* the
    /// per-lane sketch — `2·cap` extreme values per coordinate — so the
    /// feasibility test charges `partial_overhead()` on top of the plain
    /// accumulator + in-flight pair.  For overhead-0 algorithms this is
    /// arithmetically identical to the old `decomposable` gate.
    pub fn streaming_feasible(&self, update_bytes: u64, algo: &dyn FusionAlgorithm) -> bool {
        algo.partial_foldable()
            && (update_bytes as f64 * (2.0 + algo.partial_overhead()) * self.headroom) as u64
                < self.memory_bytes
    }

    /// The hierarchy gate: whether this node can participate in a 2-tier
    /// topology for this algorithm — fold forwarded partial aggregates (as
    /// a root) or pre-fold a cohort and forward one partial (as a relay).
    /// Exactly the streaming-fold feasibility test: the algebra must be
    /// partial-foldable (a partial IS a `combine` operand — weight-linear
    /// algorithms trivially, the trimmed mean via its mergeable extremes
    /// sketch; coordinate-wise median, Krum and Zeno have no meaningful
    /// partial, so those deployments stay flat) and the O(C) accumulator
    /// plus any sketch overhead must fit the node.
    pub fn hierarchy_feasible(&self, update_bytes: u64, algo: &dyn FusionAlgorithm) -> bool {
        self.streaming_feasible(update_bytes, algo)
    }

    /// The three-way dispatch test the streaming path adds to Algorithm 1:
    /// rounds that fit buffered stay `Small`; rounds that would trip the
    /// Fig 1 ceiling stream on the node when the algorithm decomposes and
    /// the O(C) working set fits; only the rest go distributed.
    pub fn classify_with_streaming(
        &self,
        update_bytes: u64,
        parties: usize,
        algo: &dyn FusionAlgorithm,
    ) -> WorkloadClass {
        match self.classify(update_bytes, parties, algo) {
            WorkloadClass::Small => WorkloadClass::Small,
            _ if self.streaming_feasible(update_bytes, algo) => WorkloadClass::Streaming,
            _ => WorkloadClass::Large,
        }
    }

    /// Max parties the single-node path supports at this update size —
    /// published to the registry so the service can *preemptively* redirect
    /// parties to the store when the next round is predicted to spill.
    pub fn party_ceiling(&self, update_bytes: u64, algo: &dyn FusionAlgorithm) -> usize {
        if update_bytes == 0 {
            return usize::MAX;
        }
        let per_party = update_bytes as f64 * Self::dup_factor(algo) * self.headroom;
        (self.memory_bytes as f64 / per_party) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::{CoordMedian, FedAvg, IterAvg};
    use crate::util::prop::check;

    #[test]
    fn small_vs_large_boundary() {
        let c = WorkloadClassifier::new(1 << 30, 1.0); // 1 GiB, no headroom
        // FedAvg dup 2.0: 100 × 4 MiB × 2 = 800 MiB < 1 GiB -> small
        assert_eq!(c.classify(4 << 20, 100, &FedAvg), WorkloadClass::Small);
        // 200 × 4 MiB × 2 = 1.6 GiB -> large
        assert_eq!(c.classify(4 << 20, 200, &FedAvg), WorkloadClass::Large);
    }

    #[test]
    fn exact_boundary_classifies_large() {
        // Algorithm 1's test is strict (`S < M`): at S == M exactly the
        // round must go distributed — the single node has zero slack.
        let c = WorkloadClassifier::new(1000, 1.0);
        // 2 × 250 B × dup 2.0 (FedAvg) = 1000 == M
        assert_eq!(c.required_bytes(250, 2, &FedAvg), 1000);
        assert_eq!(c.classify(250, 2, &FedAvg), WorkloadClass::Large);
        // one byte of slack flips it back
        let c = WorkloadClassifier::new(1001, 1.0);
        assert_eq!(c.classify(250, 2, &FedAvg), WorkloadClass::Small);
    }

    #[test]
    fn required_bytes_inflated_by_headroom_and_dup_factor() {
        let plain = WorkloadClassifier::new(1 << 30, 1.0);
        let padded = WorkloadClassifier::new(1 << 30, 1.25);
        // headroom inflates the estimate linearly (±1 byte of f64 rounding)
        let ratio = padded.required_bytes(1 << 20, 10, &IterAvg) as f64
            / plain.required_bytes(1 << 20, 10, &IterAvg) as f64;
        assert!((ratio - 1.25).abs() < 1e-6, "{ratio}");
        // FedAvg's working copies (dup 2.0) need more than IterAvg's 1.15
        assert!(
            plain.required_bytes(1 << 20, 10, &FedAvg)
                > plain.required_bytes(1 << 20, 10, &IterAvg)
        );
        // holistic algorithms are the most conservative of all
        assert!(
            plain.required_bytes(1 << 20, 10, &CoordMedian)
                > plain.required_bytes(1 << 20, 10, &FedAvg)
        );
    }

    #[test]
    fn iteravg_supports_more_parties_than_fedavg() {
        let c = WorkloadClassifier::new(1 << 30, 1.1);
        let fed = c.party_ceiling(4 << 20, &FedAvg);
        let iter = c.party_ceiling(4 << 20, &IterAvg);
        assert!(iter > fed, "{iter} !> {fed}"); // matches Fig 1a vs 1b
    }

    #[test]
    fn holistic_algorithms_classified_more_conservatively() {
        let c = WorkloadClassifier::new(1 << 30, 1.0);
        assert!(c.party_ceiling(4 << 20, &CoordMedian) < c.party_ceiling(4 << 20, &IterAvg));
    }

    #[test]
    fn prop_ceiling_consistent_with_classify() {
        check("ceiling-classify-consistency", 50, |_, rng| {
            let mem = 1u64 << (20 + rng.gen_range(12));
            let update = 1u64 << (10 + rng.gen_range(14));
            let c = WorkloadClassifier::new(mem, 1.0 + rng.next_f64() * 0.5);
            let ceil = c.party_ceiling(update, &FedAvg);
            if ceil > 0 && ceil < 1_000_000 {
                crate::prop_assert!(
                    c.classify(update, ceil, &FedAvg) == WorkloadClass::Small,
                    "ceiling {ceil} must classify small"
                );
                crate::prop_assert!(
                    c.classify(update, ceil + ceil.max(2), &FedAvg) == WorkloadClass::Large,
                    "2x ceiling must classify large"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn streaming_class_lifts_the_party_ceiling_for_decomposable_algos() {
        let c = WorkloadClassifier::new(1 << 30, 1.0); // 1 GiB
        // 200 × 4 MiB buffered spills (1.6 GiB), but the O(C) fold needs
        // only 8 MiB -> the round streams instead of going distributed.
        assert_eq!(c.classify(4 << 20, 200, &FedAvg), WorkloadClass::Large);
        assert_eq!(
            c.classify_with_streaming(4 << 20, 200, &FedAvg),
            WorkloadClass::Streaming
        );
        // ... at ANY party count: the streaming class is N-independent.
        assert_eq!(
            c.classify_with_streaming(4 << 20, 10_000_000, &FedAvg),
            WorkloadClass::Streaming
        );
        // rounds that fit buffered stay Small
        assert_eq!(
            c.classify_with_streaming(4 << 20, 100, &FedAvg),
            WorkloadClass::Small
        );
    }

    #[test]
    fn holistic_and_oversized_rounds_still_go_distributed() {
        let c = WorkloadClassifier::new(1 << 30, 1.0);
        // holistic algorithms cannot stream
        assert_eq!(
            c.classify_with_streaming(4 << 20, 200, &CoordMedian),
            WorkloadClass::Large
        );
        // an update whose O(C) working set alone exceeds the node
        assert_eq!(c.streaming_required_bytes(600 << 20), 1200 << 20);
        assert_eq!(
            c.classify_with_streaming(600 << 20, 4, &FedAvg),
            WorkloadClass::Large
        );
    }

    #[test]
    fn hierarchy_gate_matches_decomposability_and_working_set() {
        let c = WorkloadClassifier::new(1 << 30, 1.0);
        // decomposable + O(C) fits: both relay and root roles are feasible
        assert!(c.hierarchy_feasible(4 << 20, &FedAvg));
        // holistic algorithms have no meaningful partial: stay flat
        assert!(!c.hierarchy_feasible(4 << 20, &CoordMedian));
        // an O(C) working set that exceeds the node cannot fold anywhere
        assert!(!c.hierarchy_feasible(600 << 20, &FedAvg));
    }

    #[test]
    fn sketch_algorithms_stream_when_their_overhead_fits() {
        use crate::fusion::TrimmedMean;
        let c = WorkloadClassifier::new(1 << 30, 1.0); // 1 GiB
        // TrimmedMean(cap 8): working set = (2 + 2·8) × update bytes.
        // 4 MiB updates → 72 MiB, fits easily: the robust round streams
        // (and hence rides the hierarchy) despite NOT being decomposable.
        let tm = TrimmedMean::new(0.2, 8);
        assert!(!tm.decomposable());
        assert!(c.streaming_feasible(4 << 20, &tm));
        assert!(c.hierarchy_feasible(4 << 20, &tm));
        assert_eq!(
            c.classify_with_streaming(4 << 20, 200, &tm),
            WorkloadClass::Streaming
        );
        // ... but a working set inflated past the node budget is rejected
        // even though plain FedAvg at the same size would fit: the sketch
        // overhead is priced, not ignored.
        assert!(c.streaming_feasible(100 << 20, &FedAvg));
        assert!(!c.streaming_feasible(100 << 20, &tm));
        // holistic algorithms are still flat-only
        assert!(!c.hierarchy_feasible(4 << 20, &CoordMedian));
    }

    #[test]
    fn zero_parties_always_small() {
        let c = WorkloadClassifier::new(1024, 1.0);
        assert_eq!(c.classify(1 << 30, 0, &FedAvg), WorkloadClass::Small);
    }

    #[test]
    fn headroom_shrinks_ceiling() {
        let a = WorkloadClassifier::new(1 << 30, 1.0);
        let b = WorkloadClassifier::new(1 << 30, 1.5);
        assert!(b.party_ceiling(4 << 20, &FedAvg) < a.party_ceiling(4 << 20, &FedAvg));
    }
}
