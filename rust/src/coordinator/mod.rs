//! The coordinator — the paper's system contribution.
//!
//! * [`classifier`] — Algorithm 1's dispatch test: `S = w_s × n` against
//!   the node memory budget, with headroom and per-algorithm duplication
//!   factors;
//! * [`registry`] — the party registry (join/dropout/selection — FL parties
//!   "can join during training ... and drop out anytime", §III-C);
//! * [`round`] — the round state machine (collecting → aggregating →
//!   published);
//! * [`service`] — the adaptive aggregation service itself: owns the
//!   engines and the Spark/DFS path, classifies each round, transitions
//!   seamlessly (preemptively redirecting parties to the store when the
//!   next round is predicted to spill), and aggregates.

pub mod classifier;
pub mod registry;
pub mod round;
pub mod service;

pub use classifier::{WorkloadClass, WorkloadClassifier};
pub use registry::PartyRegistry;
pub use round::{RoundPhase, RoundState};
pub use service::{AdaptiveService, ServiceError, ServiceReport};
