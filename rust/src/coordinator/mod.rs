//! The coordinator — the paper's system contribution.
//!
//! * [`classifier`] — Algorithm 1's dispatch test: `S = w_s × n` against
//!   the node memory budget, with headroom and per-algorithm duplication
//!   factors; it survives as the feasibility oracle inside the cost-aware
//!   [`planner`](crate::planner), which prices every feasible plan rather
//!   than just picking a side of the boundary;
//! * [`registry`] — the party registry (join/dropout/selection — FL parties
//!   "can join during training ... and drop out anytime", §III-C);
//! * [`round`] — the round state machine (collecting → aggregating →
//!   published, or aborted), with two ingest modes: buffered (O(K·C)) and
//!   streaming (each update folds into an O(C) accumulator on arrival),
//!   per-party dedup of retransmitted uploads, and an abort path that
//!   returns every reservation to the node budget;
//! * [`async_round`] — the FedBuff-style asynchronous alternative to the
//!   quorum barrier: a bounded buffer of the K freshest updates with
//!   oldest-version-first eviction, per-update staleness deltas computed
//!   at ingest, and publish on buffer-full or cadence;
//! * [`service`] — the adaptive aggregation service itself: owns the
//!   engines, the Spark/DFS path, the planner and the autoscaler; plans
//!   each round, transitions seamlessly (preemptively redirecting parties
//!   to the store when the next round is predicted to spill), aggregates,
//!   and feeds observed timings back into the cost model.

pub mod async_round;
pub mod classifier;
pub mod registry;
pub mod round;
pub mod service;

pub use async_round::{Admitted, AsyncError, AsyncRound, BufferedUpdate};
pub use classifier::{WorkloadClass, WorkloadClassifier};
pub use registry::PartyRegistry;
pub use round::{RoundError, RoundOutcome, RoundPhase, RoundState};
pub use service::{AdaptiveService, ServiceError, ServiceReport};
