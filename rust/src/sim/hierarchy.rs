//! Multi-tier scenarios: seeded 2-tier fleets — per-edge cohort schedules,
//! whole-edge dropout, partial-vs-direct races — replayed against REAL
//! relay servers over real TCP sockets.
//!
//! Topology under test: `edges` × [`RelayServer`] (each an [`FlServer`] in
//! the `relay` role with its own cohort of scheduled clients) forwarding
//! weighted partial aggregates to one root `FlServer`.  Nothing is mocked:
//! the partial wire frames, the cohort-atomic admission ledger, the
//! member-counting quorum and the relays' model fan-out all execute.
//!
//! Determinism contract (what makes [`TierReport::digest`] bit-stable):
//!
//! * every client's behaviour is a pure function of the seed (forked
//!   [`Rng`] streams, exactly like the flat harness);
//! * *racing* clients send their stray direct upload to the root at ~t=0,
//!   while relays forward only at their local deadline — the direct frame
//!   always wins the race, so the conflicted partial's typed `Duplicate`
//!   is a scheduled outcome, not a timing accident.  (This requires
//!   `latency_ms.1` to sit well below `relay_deadline`; the default
//!   config keeps a ~4× margin.)
//!
//! A partial carrying an already-claimed party is rejected WHOLE (the
//! cohort's sums are pre-folded; the conflicting member cannot be
//! subtracted) — the conservative no-double-fold answer the round layer
//! pins.  The race scenario therefore asserts *at-most-once* per party,
//! and the edge-dropout scenario (no races) asserts *exactly-once* for
//! every survivor.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::client::SyntheticParty;
use crate::config::{NodeRole, ServiceConfig};
use crate::coordinator::{AdaptiveService, RoundOutcome};
use crate::dfs::{DfsClient, NameNode};
use crate::fusion::FedAvg;
use crate::mapreduce::ExecutorConfig;
use crate::net::{Message, NetClient};
use crate::server::{FlServer, RelayServer};
use crate::sim::{classify, mix, ReplyKind};
use crate::util::rng::Rng;

/// One 2-tier scenario: the tree shape plus its fault-injection knobs.
#[derive(Clone, Debug)]
pub struct TierConfig {
    pub seed: u64,
    /// Edge aggregators (each runs a real `RelayServer`).
    pub edges: usize,
    /// Cohort size per edge; total fleet = `edges × clients_per_edge`.
    pub clients_per_edge: usize,
    /// Parameters per update (bytes = 4×).
    pub update_len: usize,
    /// Probability a client drops out (never uploads anywhere).
    pub dropout: f64,
    /// Probability an ENTIRE edge drops: the relay acks its cohort but
    /// crashes before forwarding — the root sees one missing partial.
    pub edge_dropout: f64,
    /// Probability a surviving client ALSO sends its raw update straight
    /// to the root at ~t=0 (a stale-config straggler) — the
    /// partial-vs-direct race.
    pub direct_race: f64,
    /// Uniform per-client upload latency, drawn from `[min, max)` ms.
    /// Keep `max` well under `relay_deadline` (see module docs).
    pub latency_ms: (u64, u64),
    /// Root quorum as a fraction of the TOTAL fleet (member-counted).
    pub quorum_frac: f64,
    /// Each relay's local collection deadline (it forwards at this beat).
    pub relay_deadline: Duration,
    /// The root's quorum deadline (must exceed `relay_deadline` plus the
    /// forward hop).
    pub root_deadline: Duration,
    /// How long a relay polls the root for the fused model.
    pub parent_wait: Duration,
    /// Node memory of every aggregator (root and relays).
    pub node_memory: u64,
    /// Node cores = streaming ingest lanes.
    pub cores: usize,
}

impl Default for TierConfig {
    fn default() -> TierConfig {
        TierConfig {
            seed: 42,
            edges: 3,
            clients_per_edge: 6,
            update_len: 256, // 1 KB updates
            dropout: 0.15,
            edge_dropout: 0.0,
            direct_race: 0.0,
            latency_ms: (10, 140),
            quorum_frac: 0.5,
            relay_deadline: Duration::from_millis(600),
            root_deadline: Duration::from_millis(1800),
            parent_wait: Duration::from_secs(5),
            node_memory: 64 << 10,
            cores: 4,
        }
    }
}

/// What one scheduled client will do — a pure function of the seed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TierClientSchedule {
    pub party: u64,
    pub nonce: u64,
    pub drops_out: bool,
    pub delay_ms: u64,
    /// Also uploads directly to the root at ~t=0 (same party id, same
    /// nonce — the stray frame the cohort-atomic ledger must fence).
    pub races_direct: bool,
}

/// One edge's schedule: its cohort plus whether the whole edge drops.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EdgeSchedule {
    pub edge: u64,
    /// The relay acks its cohort but never forwards (crash after ingest).
    pub drops_out: bool,
    pub clients: Vec<TierClientSchedule>,
}

/// Expand a tier scenario into per-edge, per-client schedules.  Each edge
/// and each client draws from its own forked [`Rng`] stream.
pub fn tier_schedules(cfg: &TierConfig) -> Vec<EdgeSchedule> {
    let mut root = Rng::new(cfg.seed);
    (0..cfg.edges as u64)
        .map(|edge| {
            let mut er = root.fork(edge.wrapping_add(0x5EED));
            let drops_out = er.next_f64() < cfg.edge_dropout;
            let clients = (0..cfg.clients_per_edge as u64)
                .map(|i| {
                    let party = edge * cfg.clients_per_edge as u64 + i;
                    let mut r = er.fork(i.wrapping_add(1));
                    let nonce = r.next_u64();
                    let drops_out = r.next_f64() < cfg.dropout;
                    let span = cfg.latency_ms.1.saturating_sub(cfg.latency_ms.0).max(1);
                    let delay_ms = cfg.latency_ms.0 + r.gen_range(span);
                    let races_direct = !drops_out && r.next_f64() < cfg.direct_race;
                    TierClientSchedule { party, nonce, drops_out, delay_ms, races_direct }
                })
                .collect();
            EdgeSchedule { edge, drops_out, clients }
        })
        .collect()
}

/// Digest of the injected faults alone (pre-run).
pub fn tier_schedule_digest(scheds: &[EdgeSchedule]) -> u64 {
    let mut h = 0x71E2_5C7Eu64; // "tier schedule"
    for e in scheds {
        h = mix(h, e.edge);
        h = mix(h, u64::from(e.drops_out));
        for c in &e.clients {
            h = mix(h, c.party);
            h = mix(h, c.nonce);
            h = mix(h, u64::from(c.drops_out));
            h = mix(h, c.delay_ms);
            h = mix(h, u64::from(c.races_direct));
        }
    }
    h
}

/// One client's observable behaviour.
#[derive(Clone, Debug)]
pub struct TierClientRecord {
    pub party: u64,
    pub dropped: bool,
    /// Reply to the upload sent to this client's RELAY (`None` if dropped).
    pub relay_reply: Option<ReplyKind>,
    /// Reply to the stray direct upload to the ROOT (`None` unless racing).
    pub direct_reply: Option<ReplyKind>,
}

/// One edge's observable behaviour.
#[derive(Clone, Debug)]
pub struct EdgeRecord {
    pub edge: u64,
    pub dropped: bool,
    /// Members the relay folded locally at its seal.
    pub relay_folded: usize,
    /// The root's reply to the forwarded partial (`None` when the edge
    /// dropped, aborted empty, or could not reach the root).
    pub partial_reply: Option<ReplyKind>,
    /// Whether the relay fetched + republished the root's fused model.
    pub model_published: bool,
    pub clients: Vec<TierClientRecord>,
}

/// Everything a tier scenario produced, reduced to its deterministic core.
#[derive(Clone, Debug)]
pub struct TierReport {
    pub outcome: RoundOutcome,
    /// Members folded at the ROOT's seal (cohort members + stray directs).
    pub folded: usize,
    pub quorum: usize,
    /// Total fleet size (`edges × clients_per_edge`).
    pub expected: usize,
    pub edges: Vec<EdgeRecord>,
    /// Parameter count of the root's published model (0 on abort).
    pub fused_len: usize,
    /// Wall seconds — informational, never part of the digest.
    pub round_s: f64,
}

impl TierReport {
    /// Bit-stable outcome digest: root outcome/counts plus every edge's
    /// and every client's typed replies, in (edge, party) order.
    pub fn digest(&self) -> u64 {
        let mut h = 0x2_71E2u64; // "tier"
        h = mix(
            h,
            match self.outcome {
                RoundOutcome::Complete => 1,
                RoundOutcome::Quorum => 2,
                RoundOutcome::Aborted => 3,
            },
        );
        h = mix(h, self.folded as u64);
        h = mix(h, self.quorum as u64);
        h = mix(h, self.expected as u64);
        h = mix(h, self.fused_len as u64);
        let code = |r: &Option<ReplyKind>| r.map(|k| k.code()).unwrap_or(0);
        for e in &self.edges {
            h = mix(h, e.edge);
            h = mix(h, u64::from(e.dropped));
            h = mix(h, e.relay_folded as u64);
            h = mix(h, code(&e.partial_reply));
            h = mix(h, u64::from(e.model_published));
            for c in &e.clients {
                h = mix(h, c.party);
                h = mix(h, u64::from(c.dropped));
                h = mix(h, code(&c.relay_reply));
                h = mix(h, code(&c.direct_reply));
            }
        }
        h
    }

}

/// Unique scratch roots across runs in one process.
static TIER_SEQ: AtomicU64 = AtomicU64::new(0);

fn make_node(
    role: NodeRole,
    parent: Option<String>,
    edge_id: u64,
    cfg: &TierConfig,
    dir: &std::path::Path,
) -> Arc<FlServer> {
    let nn = NameNode::create(dir, 2, 1, 1 << 20).expect("tier store");
    let mut scfg = ServiceConfig::default();
    scfg.node.memory_bytes = cfg.node_memory;
    scfg.node.cores = cfg.cores.max(1);
    scfg.monitor_timeout_s = cfg.root_deadline.as_secs_f64();
    scfg.role = role;
    scfg.parent_addr = parent;
    scfg.edge_id = edge_id;
    let svc = AdaptiveService::new(
        scfg,
        DfsClient::new(nn),
        None,
        ExecutorConfig { executors: 1, cores_per_executor: 2, ..Default::default() },
    );
    FlServer::new(svc, Arc::new(FedAvg), (cfg.update_len * 4) as u64)
}

fn drive_tier_client(
    relay_addr: &str,
    root_addr: &str,
    s: &TierClientSchedule,
    cfg: &TierConfig,
) -> TierClientRecord {
    if s.drops_out {
        return TierClientRecord {
            party: s.party,
            dropped: true,
            relay_reply: None,
            direct_reply: None,
        };
    }
    let mut party = SyntheticParty::new(s.party, cfg.seed);
    let u = party.make_update(0, cfg.update_len);
    // the stray direct frame goes out FIRST (t≈0): it deterministically
    // beats the relay's deadline-gated forward to the root's ledger
    let direct_reply = if s.races_direct {
        Some(match NetClient::connect(root_addr) {
            Ok(mut c) => c
                .call(&Message::UploadNonce { nonce: s.nonce, update: u.clone() })
                .map(|m| classify(&m))
                .unwrap_or(ReplyKind::Rejected),
            Err(_) => ReplyKind::Rejected,
        })
    } else {
        None
    };
    std::thread::sleep(Duration::from_millis(s.delay_ms));
    let relay_reply = Some(match NetClient::connect(relay_addr) {
        Ok(mut c) => c
            .call(&Message::UploadNonce { nonce: s.nonce, update: u })
            .map(|m| classify(&m))
            .unwrap_or(ReplyKind::Rejected),
        Err(_) => ReplyKind::Rejected,
    });
    TierClientRecord { party: s.party, dropped: false, relay_reply, direct_reply }
}

/// Run one seeded 2-tier scenario end to end: real root, real relays, real
/// TCP, one member-counted quorum round at the root.
pub fn run_tier_scenario(cfg: &TierConfig) -> TierReport {
    let scheds = tier_schedules(cfg);
    let seq = TIER_SEQ.fetch_add(1, Ordering::Relaxed);
    let scratch = std::env::temp_dir().join(format!(
        "elastiagg-tier-{}-{}-{}",
        std::process::id(),
        cfg.seed,
        seq
    ));
    std::fs::create_dir_all(&scratch).expect("tier scratch dir");

    let root_server = make_node(NodeRole::Root, None, 0, cfg, &scratch.join("root"));
    let root_handle = root_server.start("127.0.0.1:0").expect("root server");
    let root_addr = root_handle.addr().to_string();

    struct Edge {
        sched: EdgeSchedule,
        relay: RelayServer,
        _handle: crate::net::ServerHandle,
        addr: String,
    }
    let edges: Vec<Edge> = scheds
        .into_iter()
        .map(|sched| {
            let server = make_node(
                NodeRole::Relay,
                Some(root_addr.clone()),
                sched.edge,
                cfg,
                &scratch.join(format!("edge{}", sched.edge)),
            );
            let handle = server.start("127.0.0.1:0").expect("relay server");
            let addr = handle.addr().to_string();
            let relay = RelayServer::from_config(server).expect("relay config");
            Edge { sched, relay, _handle: handle, addr }
        })
        .collect();

    let expected = (cfg.edges * cfg.clients_per_edge).max(1);
    let quorum = (((expected as f64) * cfg.quorum_frac).ceil() as usize).max(1);

    let t0 = Instant::now();
    let (root_run, edge_records) = std::thread::scope(|scope| {
        let root = scope
            .spawn(|| root_server.run_round_quorum(expected, quorum, cfg.root_deadline));
        let edge_threads: Vec<_> = edges
            .iter()
            .map(|edge| {
                let root_addr = root_addr.clone();
                scope.spawn(move || {
                    // cohort clients upload to THIS relay (racers also to
                    // the root), each on its own thread
                    let (relay_run, clients) = std::thread::scope(|es| {
                        let client_threads: Vec<_> = edge
                            .sched
                            .clients
                            .iter()
                            .map(|c| {
                                let relay_addr = edge.addr.clone();
                                let root_addr = root_addr.clone();
                                es.spawn(move || {
                                    drive_tier_client(&relay_addr, &root_addr, c, cfg)
                                })
                            })
                            .collect();
                        let relay_run = if edge.sched.drops_out {
                            None // the relay crashed after acking: no forward
                        } else {
                            Some(
                                edge.relay
                                    .run_relay_round(
                                        cfg.clients_per_edge,
                                        1,
                                        cfg.relay_deadline,
                                        cfg.parent_wait,
                                    )
                                    .expect("relay round"),
                            )
                        };
                        let clients: Vec<TierClientRecord> = client_threads
                            .into_iter()
                            .map(|h| h.join().expect("client thread"))
                            .collect();
                        (relay_run, clients)
                    });
                    EdgeRecord {
                        edge: edge.sched.edge,
                        dropped: edge.sched.drops_out,
                        relay_folded: relay_run.as_ref().map(|r| r.folded).unwrap_or(0),
                        partial_reply: relay_run
                            .as_ref()
                            .and_then(|r| r.forwarded.as_ref())
                            .map(classify),
                        model_published: relay_run
                            .as_ref()
                            .map(|r| r.model_published)
                            .unwrap_or(false),
                        clients,
                    }
                })
            })
            .collect();
        let edge_records: Vec<EdgeRecord> =
            edge_threads.into_iter().map(|h| h.join().expect("edge thread")).collect();
        (root.join().expect("root thread"), edge_records)
    });
    let round_s = t0.elapsed().as_secs_f64();
    let run = root_run.expect("root quorum round");
    let fused_len = run.result.as_ref().map(|(w, _)| w.len()).unwrap_or(0);
    let report = TierReport {
        outcome: run.outcome,
        folded: run.folded,
        quorum,
        expected,
        edges: edge_records,
        fused_len,
        round_s,
    };
    drop(root_handle);
    let _ = std::fs::remove_dir_all(&scratch);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_schedules_are_pure_functions_of_the_seed() {
        let cfg = TierConfig::default();
        assert_eq!(tier_schedules(&cfg), tier_schedules(&cfg));
        assert_eq!(
            tier_schedule_digest(&tier_schedules(&cfg)),
            tier_schedule_digest(&tier_schedules(&cfg))
        );
        let other = TierConfig { seed: 43, ..cfg.clone() };
        assert_ne!(
            tier_schedule_digest(&tier_schedules(&cfg)),
            tier_schedule_digest(&tier_schedules(&other))
        );
        // party ids are globally unique across edges
        let s = tier_schedules(&cfg);
        let mut ids: Vec<u64> =
            s.iter().flat_map(|e| e.clients.iter().map(|c| c.party)).collect();
        let n = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n);
    }

    #[test]
    fn tier_knobs_saturate() {
        let all = TierConfig { edge_dropout: 1.0, ..TierConfig::default() };
        assert!(tier_schedules(&all).iter().all(|e| e.drops_out));
        let none = TierConfig { edge_dropout: 0.0, ..TierConfig::default() };
        assert!(tier_schedules(&none).iter().all(|e| !e.drops_out));
        let race = TierConfig { direct_race: 1.0, dropout: 0.0, ..TierConfig::default() };
        assert!(tier_schedules(&race)
            .iter()
            .all(|e| e.clients.iter().all(|c| c.races_direct)));
        // racing requires surviving: dropouts never race
        let mixed = TierConfig { direct_race: 1.0, dropout: 1.0, ..TierConfig::default() };
        assert!(tier_schedules(&mixed)
            .iter()
            .all(|e| e.clients.iter().all(|c| !c.races_direct)));
    }

    #[test]
    fn tier_digest_distinguishes_fields() {
        let base = TierReport {
            outcome: RoundOutcome::Quorum,
            folded: 12,
            quorum: 9,
            expected: 18,
            edges: vec![EdgeRecord {
                edge: 0,
                dropped: false,
                relay_folded: 6,
                partial_reply: Some(ReplyKind::Accepted),
                model_published: true,
                clients: vec![TierClientRecord {
                    party: 0,
                    dropped: false,
                    relay_reply: Some(ReplyKind::Accepted),
                    direct_reply: None,
                }],
            }],
            fused_len: 256,
            round_s: 1.0,
        };
        let d = base.digest();
        let mut flip = base.clone();
        flip.edges[0].partial_reply = Some(ReplyKind::Duplicate);
        assert_ne!(flip.digest(), d);
        let mut flip = base.clone();
        flip.edges[0].clients[0].direct_reply = Some(ReplyKind::Accepted);
        assert_ne!(flip.digest(), d);
        let mut flip = base.clone();
        flip.round_s = 99.0;
        assert_eq!(flip.digest(), d, "wall time is informational only");
    }
}
