//! Seeded heavy-tail straggler scenarios for the async ingest mode.
//!
//! The sync harness ([`run_scenario`](super::run_scenario)) injects
//! *uniform* latency — every client is a little late.  Real edge fleets
//! are bimodal: a fast body and a heavy tail of stragglers 10–100× slower
//! (low-power links, duty-cycled radios).  Under a quorum barrier the tail
//! IS the round clock; the FedBuff-style async mode exists precisely so it
//! isn't.  This module makes that regime a seeded, replayable scenario:
//!
//! * [`straggler_schedules`] expands one seed into per-client schedules
//!   drawn from a body band or a tail band (plus churn and duplicate
//!   knobs), each client on its own forked [`Rng`] stream;
//! * [`run_async_scenario`] replays the schedule against a REAL async-mode
//!   [`FlServer`] over real TCP — clients upload in virtual-arrival order
//!   (sorted by scheduled delay, ties by party), the driver publishes on
//!   buffer-full and once more at the end for the partial remainder.  The
//!   sequential replay is what makes every field of the report — replies,
//!   per-update deltas, publish sizes, versions — a pure function of the
//!   seed, so [`AsyncReport::digest`] is bit-stable across replays;
//! * the report also carries the *schedule-derived* round clocks: the
//!   async mode's first publish fires at the K-th surviving arrival
//!   ([`AsyncReport::first_publish_ms`]), while a sync quorum seals only
//!   at the quorum-th ([`AsyncReport::sync_quorum_ms`]) — on a heavy-tail
//!   schedule the latter sits in the tail band, which is exactly the
//!   "async publishes while sync still waits" acceptance pin in
//!   `rust/tests/sim_scenarios.rs`.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::{mix, SCENARIO_SEQ};
use crate::client::SyntheticParty;
use crate::config::ServiceConfig;
use crate::coordinator::AdaptiveService;
use crate::dfs::{DfsClient, NameNode};
use crate::fusion::FedAvg;
use crate::mapreduce::ExecutorConfig;
use crate::net::{Message, NetClient};
use crate::server::FlServer;
use crate::util::rng::Rng;

/// One heavy-tail scenario: fleet shape + the bimodal latency knobs + the
/// async buffer knobs.  Everything that varies derives from `seed`.
#[derive(Clone, Debug)]
pub struct StragglerConfig {
    pub seed: u64,
    /// Registered fleet size.
    pub clients: usize,
    /// Parameters per update (bytes = 4×).
    pub update_len: usize,
    /// Probability a client is in the heavy tail.
    pub tail_frac: f64,
    /// Body latency band `[min, max)` ms — the fast majority.
    pub body_ms: (u64, u64),
    /// Tail latency band `[min, max)` ms — the stragglers.
    pub tail_ms: (u64, u64),
    /// Probability a client churns out (never uploads).
    pub dropout: f64,
    /// Probability a surviving client retransmits its frame once.
    pub duplicate: f64,
    /// Async buffer capacity K (publish-on-full trigger).
    pub buffer: usize,
    /// Staleness-discount exponent of the async fold.
    pub staleness_exponent: f64,
    /// Quorum fraction of the *sync comparison* clock (not enforced by the
    /// async run — it has no quorum — but used to derive
    /// [`AsyncReport::sync_quorum_ms`] from the same schedule).
    pub quorum_frac: f64,
    /// Aggregator node memory (must hold K·C plus the fold's O(C)).
    pub node_memory: u64,
    pub cores: usize,
}

impl Default for StragglerConfig {
    fn default() -> StragglerConfig {
        StragglerConfig {
            seed: 42,
            clients: 24,
            update_len: 256, // 1 KB updates
            tail_frac: 0.25,
            body_ms: (10, 60),
            tail_ms: (800, 1200),
            dropout: 0.15,
            duplicate: 0.2,
            buffer: 8,
            staleness_exponent: 0.5,
            quorum_frac: 0.7,
            node_memory: 64 << 10,
            cores: 4,
        }
    }
}

/// What one client will do — a pure function of the scenario seed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StragglerSchedule {
    pub party: u64,
    /// Retransmission nonce carried on every copy of the frame.
    pub nonce: u64,
    /// Churned out: never uploads.
    pub drops_out: bool,
    /// In the heavy tail (drawn from `tail_ms` instead of `body_ms`).
    pub straggler: bool,
    /// Scheduled upload latency in virtual ms.
    pub delay_ms: u64,
    /// Extra copies sent after the original (same nonce).
    pub retransmits: u32,
}

/// Expand a scenario into per-client schedules.  Each client draws from
/// its own forked stream, so adding knobs later cannot shift the draws of
/// existing clients within a seed.
pub fn straggler_schedules(cfg: &StragglerConfig) -> Vec<StragglerSchedule> {
    let mut root = Rng::new(cfg.seed);
    (0..cfg.clients as u64)
        .map(|party| {
            let mut r = root.fork(party.wrapping_add(1));
            let nonce = r.next_u64();
            let drops_out = r.next_f64() < cfg.dropout;
            let straggler = r.next_f64() < cfg.tail_frac;
            let band = if straggler { cfg.tail_ms } else { cfg.body_ms };
            let span = band.1.saturating_sub(band.0).max(1);
            let delay_ms = band.0 + r.gen_range(span);
            let retransmits = u32::from(r.next_f64() < cfg.duplicate);
            StragglerSchedule { party, nonce, drops_out, straggler, delay_ms, retransmits }
        })
        .collect()
}

/// Digest of the injected schedule alone (pre-run).
pub fn straggler_schedule_digest(scheds: &[StragglerSchedule]) -> u64 {
    let mut h = 0x57A6_617Eu64; // "straggle"
    for s in scheds {
        h = mix(h, s.party);
        h = mix(h, s.nonce);
        h = mix(h, u64::from(s.drops_out));
        h = mix(h, u64::from(s.straggler));
        h = mix(h, s.delay_ms);
        h = mix(h, u64::from(s.retransmits));
    }
    h
}

/// How the async server answered one upload frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AsyncReplyKind {
    /// Buffered, with this staleness delta observed at ingest.
    Admitted { delta: u32 },
    /// Retransmit absorbed (same buffer, accepted nonce echoed).
    Duplicate,
    /// Rejected stale against a full buffer (`Late` carrying the version).
    Stale,
    /// Anything else (error reply, connection failure).
    Rejected,
}

impl AsyncReplyKind {
    fn code(self) -> u64 {
        match self {
            AsyncReplyKind::Admitted { delta } => 0x100 + delta as u64,
            AsyncReplyKind::Duplicate => 2,
            AsyncReplyKind::Stale => 3,
            AsyncReplyKind::Rejected => 4,
        }
    }
}

/// One client's observable behaviour during the replay.
#[derive(Clone, Debug)]
pub struct AsyncClientRecord {
    pub party: u64,
    pub dropped: bool,
    pub straggler: bool,
    /// Reply per frame sent: original first, then each retransmit.
    pub replies: Vec<AsyncReplyKind>,
}

/// One publish the driver performed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PublishRecord {
    /// Model version after this publish.
    pub version: u32,
    /// Updates folded into it.
    pub folded: usize,
    /// Largest staleness delta among them.
    pub max_delta: u32,
}

/// Everything an async scenario produced, reduced to its deterministic
/// core (wall time is informational only).
#[derive(Clone, Debug)]
pub struct AsyncReport {
    pub clients: Vec<AsyncClientRecord>,
    pub publishes: Vec<PublishRecord>,
    pub final_version: u32,
    /// Frames the server admitted into a buffer.
    pub admitted: usize,
    /// Updates handed to drains (conservation: `== admitted` — every
    /// buffered update folds exactly once, never dropped, never twice).
    pub drained: u64,
    /// Oldest-version-first evictions the buffer performed.
    pub evicted: u64,
    /// Parameter count of the last published model (0 if none).
    pub fused_len: usize,
    /// Virtual ms of the K-th surviving arrival — when the async buffer
    /// first fills and publishes.  `None` if fewer than K survive.
    pub first_publish_ms: Option<u64>,
    /// Virtual ms of the quorum-th surviving arrival — when a sync quorum
    /// round over the SAME schedule would seal.  `None` if the quorum
    /// never arrives (the sync round would sit at its deadline and abort).
    pub sync_quorum_ms: Option<u64>,
    /// Wall seconds of the replay — NOT part of the digest.
    pub wall_s: f64,
}

impl AsyncReport {
    /// The bit-stable digest: every deterministic field, in a fixed order.
    pub fn digest(&self) -> u64 {
        let mut h = 0xA5D1_6E57u64; // "async digest"
        for c in &self.clients {
            h = mix(h, c.party);
            h = mix(h, u64::from(c.dropped));
            h = mix(h, u64::from(c.straggler));
            h = mix(h, c.replies.len() as u64);
            for r in &c.replies {
                h = mix(h, r.code());
            }
        }
        for p in &self.publishes {
            h = mix(h, p.version as u64);
            h = mix(h, p.folded as u64);
            h = mix(h, p.max_delta as u64);
        }
        h = mix(h, self.final_version as u64);
        h = mix(h, self.admitted as u64);
        h = mix(h, self.drained);
        h = mix(h, self.evicted);
        h = mix(h, self.fused_len as u64);
        h = mix(h, self.first_publish_ms.map(|v| v + 1).unwrap_or(0));
        h = mix(h, self.sync_quorum_ms.map(|v| v + 1).unwrap_or(0));
        h
    }
}

/// The schedule-derived round clocks: sort surviving arrivals, read off
/// the K-th (async first publish) and the quorum-th (sync seal).
fn virtual_clocks(
    cfg: &StragglerConfig,
    scheds: &[StragglerSchedule],
) -> (Option<u64>, Option<u64>) {
    let mut arrivals: Vec<u64> =
        scheds.iter().filter(|s| !s.drops_out).map(|s| s.delay_ms).collect();
    arrivals.sort_unstable();
    let k = cfg.buffer.max(1);
    let quorum = (((cfg.clients as f64) * cfg.quorum_frac).ceil() as usize).max(1);
    let first_publish = arrivals.get(k - 1).copied();
    let sync_seal = arrivals.get(quorum - 1).copied();
    (first_publish, sync_seal)
}

/// Replay one seeded heavy-tail scenario against a real async-mode TCP
/// [`FlServer`].
///
/// Clients upload in virtual-arrival order (schedule delay, ties by
/// party): the fast body lands first, the tail last — exactly the order a
/// wall-clock race would produce, minus the nondeterminism.  Stragglers
/// upload version-0 updates (they trained long ago); body clients upload
/// the model version current at their turn, so tail updates accrue real
/// staleness deltas as body-filled buffers publish ahead of them.  The
/// driver publishes whenever the buffer fills and once at the end for the
/// partial remainder.
pub fn run_async_scenario(cfg: &StragglerConfig) -> AsyncReport {
    let scheds = straggler_schedules(cfg);
    let (first_publish_ms, sync_quorum_ms) = virtual_clocks(cfg, &scheds);
    let seq = SCENARIO_SEQ.fetch_add(1, Ordering::Relaxed);
    let root = std::env::temp_dir().join(format!(
        "elastiagg-straggler-{}-{}-{}",
        std::process::id(),
        cfg.seed,
        seq
    ));
    std::fs::create_dir_all(&root).expect("scenario scratch dir");
    let nn = NameNode::create(&root, 2, 1, 1 << 20).expect("scenario store");
    let mut scfg = ServiceConfig::default();
    scfg.node.memory_bytes = cfg.node_memory;
    scfg.node.cores = cfg.cores.max(1);
    scfg.async_mode = true;
    scfg.async_buffer = cfg.buffer;
    scfg.staleness_exponent = cfg.staleness_exponent;
    let svc = AdaptiveService::new(
        scfg,
        DfsClient::new(nn),
        None,
        ExecutorConfig { executors: 1, cores_per_executor: 2, ..Default::default() },
    );
    let update_bytes = (cfg.update_len * 4) as u64;
    let server = FlServer::new(svc, Arc::new(FedAvg), update_bytes);
    for s in &scheds {
        server.registry.join(s.party, 0, 16);
    }
    let handle = server.start("127.0.0.1:0").expect("scenario server");
    let addr = handle.addr().to_string();
    let ar = server.async_state().expect("async mode on").clone();

    // Virtual-arrival order: delay, ties by party (both schedule-derived).
    let mut order: Vec<&StragglerSchedule> = scheds.iter().filter(|s| !s.drops_out).collect();
    order.sort_by_key(|s| (s.delay_ms, s.party));

    let t0 = Instant::now();
    let mut records: Vec<AsyncClientRecord> = scheds
        .iter()
        .map(|s| AsyncClientRecord {
            party: s.party,
            dropped: s.drops_out,
            straggler: s.straggler,
            replies: Vec::new(),
        })
        .collect();
    let mut publishes = Vec::new();
    let mut admitted = 0usize;
    let publish = |server: &FlServer, publishes: &mut Vec<PublishRecord>| {
        let run = server.run_async_round(Duration::ZERO).expect("async publish");
        if run.folded > 0 {
            publishes.push(PublishRecord {
                version: run.version,
                folded: run.folded,
                max_delta: run.max_delta,
            });
        }
    };
    for s in &order {
        let mut c = NetClient::connect(&addr).expect("client connect");
        // Stragglers trained against the genesis model long ago; body
        // clients are fresh against the version current at their arrival.
        let version = if s.straggler { 0 } else { ar.version() };
        let u = SyntheticParty::new(s.party, cfg.seed).make_update(version, cfg.update_len);
        for _ in 0..=s.retransmits {
            let kind = match c.call(&Message::UploadNonce { nonce: s.nonce, update: u.clone() }) {
                Ok(Message::AsyncAck { delta, .. }) => {
                    admitted += 1;
                    AsyncReplyKind::Admitted { delta }
                }
                Ok(Message::Duplicate { .. }) => AsyncReplyKind::Duplicate,
                Ok(Message::Late { .. }) => AsyncReplyKind::Stale,
                _ => AsyncReplyKind::Rejected,
            };
            records[s.party as usize].replies.push(kind);
        }
        if ar.is_full() {
            publish(&server, &mut publishes);
        }
    }
    // Final cadence tick: drain the partial remainder, if any.
    publish(&server, &mut publishes);
    let wall_s = t0.elapsed().as_secs_f64();
    let fused_len = ar.model().map(|m| m.len()).unwrap_or(0);
    let report = AsyncReport {
        clients: records,
        publishes,
        final_version: ar.version(),
        admitted,
        drained: ar.drained(),
        evicted: ar.evicted(),
        fused_len,
        first_publish_ms,
        sync_quorum_ms,
        wall_s,
    };
    drop(handle);
    let _ = std::fs::remove_dir_all(&root);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_are_a_pure_function_of_the_seed() {
        let cfg = StragglerConfig::default();
        assert_eq!(straggler_schedules(&cfg), straggler_schedules(&cfg));
        let other = StragglerConfig { seed: 43, ..cfg.clone() };
        assert_ne!(
            straggler_schedule_digest(&straggler_schedules(&cfg)),
            straggler_schedule_digest(&straggler_schedules(&other))
        );
    }

    #[test]
    fn latency_is_bimodal_by_construction() {
        let cfg = StragglerConfig { clients: 2000, ..StragglerConfig::default() };
        let s = straggler_schedules(&cfg);
        for c in &s {
            let band = if c.straggler { cfg.tail_ms } else { cfg.body_ms };
            assert!((band.0..band.1).contains(&c.delay_ms), "{c:?}");
        }
        let tail = s.iter().filter(|c| c.straggler).count() as f64 / 2000.0;
        assert!((0.20..0.30).contains(&tail), "{tail}");
        // the bands must not overlap — the whole point of the family
        assert!(cfg.body_ms.1 <= cfg.tail_ms.0);
    }

    #[test]
    fn virtual_clocks_put_sync_in_the_tail() {
        // With K well below the body count, the async publish clock reads
        // from the body band; with the quorum past it, the sync clock
        // reads from the tail band.
        let cfg = StragglerConfig::default();
        let s = straggler_schedules(&cfg);
        let (first, quorum) = virtual_clocks(&cfg, &s);
        let first = first.expect("≥ K survivors at these knobs");
        let quorum = quorum.expect("quorum survivors at these knobs");
        assert!(first < cfg.body_ms.1, "{first}");
        assert!(quorum >= cfg.tail_ms.0, "{quorum}");
    }

    #[test]
    fn digest_covers_the_deterministic_fields_only() {
        let cfg = StragglerConfig { clients: 6, buffer: 3, ..StragglerConfig::default() };
        let a = run_async_scenario(&cfg);
        let mut b = a.clone();
        b.wall_s = 99.0;
        assert_eq!(a.digest(), b.digest(), "wall time must not enter the digest");
        let mut b = a.clone();
        b.final_version += 1;
        assert_ne!(a.digest(), b.digest());
    }
}
