//! The virtual-client fleet: 100k-party rounds without 100k sockets.
//!
//! The socketed scenario harness ([`super::run_scenario`]) is the fidelity
//! anchor — real frames, real connections — but it buys that fidelity with
//! one OS thread and one file descriptor per client, which caps it at a
//! few hundred parties under CI rlimits.  This module trades the socket
//! layer (and ONLY the socket layer) for scale: each virtual client's
//! upload is encoded to the exact wire payload a real client would send,
//! loaded into a 4-aligned [`FrameBuf`] — the same pooled-buffer base the
//! reactor's reads land in — and handed to [`FlServer::inject_frame`],
//! the zero-copy frame path the reactor dispatches to.  Everything above
//! the socket executes for real: borrowed-view decode, the sharded
//! streaming fold, nonce dedup, the memory budget and the quorum driver.
//!
//! Injection order is a pure function of the seed (schedules sorted by
//! simulated delay, ties by party id), no thread races a deadline, and no
//! wall clock is sampled into the report's deterministic fields — so a
//! fleet run's [`FleetReport::digest`] is bit-identical across runs of
//! the same seed, at any fleet size.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::client::SyntheticParty;
use crate::config::ServiceConfig;
use crate::coordinator::{AdaptiveService, RoundOutcome};
use crate::dfs::{DfsClient, NameNode};
use crate::fusion::FedAvg;
use crate::mapreduce::ExecutorConfig;
use crate::net::{FrameBuf, Message, Reply};
use crate::server::FlServer;

use super::{classify, mix, schedules, ClientSchedule, ReplyKind, ScenarioConfig};

/// One fleet round: the shape knobs shared with [`ScenarioConfig`], minus
/// everything that only exists because of real sockets (latency sleeps,
/// the wall-clock deadline race).
#[derive(Clone, Debug)]
pub struct FleetConfig {
    pub seed: u64,
    /// Registered fleet size (the round's `expected`).
    pub clients: usize,
    /// Parameters per update (bytes = 4×).
    pub update_len: usize,
    /// Probability a client drops out (never uploads this round).
    pub dropout: f64,
    /// Probability a surviving client retransmits its frame once.
    pub duplicate: f64,
    /// Round quorum as a fraction of the fleet.
    pub quorum_frac: f64,
    /// Aggregator node memory: size it below the buffered K·C requirement
    /// so the round classifies Streaming (the default does at the default
    /// fleet size) — the sharded fold is what makes huge fleets O(S·C).
    pub node_memory: u64,
    /// Node cores = streaming ingest lanes.
    pub cores: usize,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            seed: 42,
            clients: 10_000,
            update_len: 32,
            dropout: 0.1,
            duplicate: 0.1,
            quorum_frac: 0.5,
            node_memory: 64 << 10,
            cores: 4,
        }
    }
}

impl FleetConfig {
    /// The scenario view of this fleet — [`schedules`] is reused verbatim,
    /// so a fleet's injected faults are the same pure function of the seed
    /// the socketed harness draws.
    fn scenario(&self) -> ScenarioConfig {
        ScenarioConfig {
            seed: self.seed,
            clients: self.clients,
            update_len: self.update_len,
            dropout: self.dropout,
            duplicate: self.duplicate,
            quorum_frac: self.quorum_frac,
            node_memory: self.node_memory,
            cores: self.cores,
            ..ScenarioConfig::default()
        }
    }
}

/// What one fleet round produced, reduced to its deterministic core.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub outcome: RoundOutcome,
    /// Updates folded at seal time (≡ surviving clients: nothing races).
    pub folded: usize,
    pub quorum: usize,
    pub expected: usize,
    /// Frames injected: originals + in-round retransmits + the late probe.
    pub injected: u64,
    /// Frames answered `Ack` (folded or parked).
    pub accepted: u64,
    /// Retransmits absorbed by the nonce window (`Duplicate`).
    pub duplicates: u64,
    /// Frames answered with the typed `Late` reply.
    pub late: u64,
    /// Anything else (error replies, robust-mode rejections).
    pub rejected: u64,
    /// Parameter count of the published model (0 on abort).
    pub fused_len: usize,
    /// Wall seconds of the whole run — informational; NOT in the digest.
    pub round_s: f64,
}

impl FleetReport {
    /// Bit-stable digest of the round's deterministic fields (everything
    /// but the wall clock).
    pub fn digest(&self) -> u64 {
        let mut h = 0xF1EE_7Du64; // "fleet"
        h = mix(
            h,
            match self.outcome {
                RoundOutcome::Complete => 1,
                RoundOutcome::Quorum => 2,
                RoundOutcome::Aborted => 3,
            },
        );
        h = mix(h, self.folded as u64);
        h = mix(h, self.quorum as u64);
        h = mix(h, self.expected as u64);
        h = mix(h, self.injected);
        h = mix(h, self.accepted);
        h = mix(h, self.duplicates);
        h = mix(h, self.late);
        h = mix(h, self.rejected);
        h = mix(h, self.fused_len as u64);
        h
    }
}

/// Unique scratch roots across runs in one process.
static FLEET_SEQ: AtomicU64 = AtomicU64::new(0);

/// Run one seeded fleet round in-process against a real [`FlServer`].
///
/// The fleet is registered up front, every surviving client's
/// `UploadNonce` frame (original, then each same-nonce retransmit) is
/// injected in simulated-arrival order, the round is driven with
/// [`FlServer::run_round_quorum`], and one post-seal retransmit pins the
/// typed `Late` path.
pub fn run_fleet(cfg: &FleetConfig) -> FleetReport {
    let scheds = schedules(&cfg.scenario());
    let seq = FLEET_SEQ.fetch_add(1, Ordering::Relaxed);
    let root = std::env::temp_dir().join(format!(
        "elastiagg-fleet-{}-{}-{}",
        std::process::id(),
        cfg.seed,
        seq
    ));
    std::fs::create_dir_all(&root).expect("fleet scratch dir");
    let nn = NameNode::create(&root, 2, 1, 1 << 20).expect("fleet store");
    let mut scfg = ServiceConfig::default();
    scfg.node.memory_bytes = cfg.node_memory;
    scfg.node.cores = cfg.cores.max(1);
    let svc = AdaptiveService::new(
        scfg,
        DfsClient::new(nn),
        None,
        ExecutorConfig { executors: 1, cores_per_executor: 2, ..Default::default() },
    );
    let update_bytes = (cfg.update_len * 4) as u64;
    let server = FlServer::new(svc, Arc::new(FedAvg), update_bytes);
    for s in &scheds {
        server.registry.join(s.party, 0, 16);
    }
    // Re-open round 0 so its class reflects the registered fleet.  (The
    // socketed harness gets this from the driver's empty-round
    // reclassification; here frames land before the driver runs.)
    server.open_round(0);
    let expected = cfg.clients.max(1);
    let quorum = (((cfg.clients as f64) * cfg.quorum_frac).ceil() as usize).max(1);

    // Simulated arrival order: the latency draw, ties by party id.
    let mut order: Vec<&ClientSchedule> = scheds.iter().filter(|s| !s.drops_out).collect();
    order.sort_by_key(|s| (s.delay_ms, s.party));

    let t0 = Instant::now();
    let mut frame = Vec::new();
    let mut buf = FrameBuf::new();
    let (mut injected, mut accepted, mut duplicates, mut late, mut rejected) =
        (0u64, 0u64, 0u64, 0u64, 0u64);
    let mut inject = |frame: &[u8], buf: &mut FrameBuf| {
        // Load the framed payload into the 4-aligned pool buffer — the
        // same base address class a reactor read gives — so the upload
        // decodes as a borrowed view, not the copy fallback.
        buf.fill(&frame[5..]);
        injected += 1;
        match server.inject_frame(frame[0], buf.as_slice()) {
            Ok(Reply::Msg(m)) => match classify(&m) {
                ReplyKind::Accepted => accepted += 1,
                ReplyKind::Duplicate => duplicates += 1,
                ReplyKind::Late => late += 1,
                ReplyKind::Rejected => rejected += 1,
            },
            _ => rejected += 1,
        }
    };
    for s in &order {
        let mut party = SyntheticParty::new(s.party, cfg.seed);
        let u = party.make_update(0, cfg.update_len);
        Message::UploadNonce { nonce: s.nonce, update: u }
            .encode_into(&mut frame)
            .expect("fleet frame fits");
        // original + each retransmit carry the SAME nonce — the dedup
        // window must absorb the copies without folding twice
        for _ in 0..=s.retransmits {
            inject(&frame, &mut buf);
        }
    }
    let run = server
        .run_round_quorum(expected, quorum, Duration::from_millis(250))
        .expect("fleet round");
    // One straggler re-sends after the seal: the round has moved on, so
    // the reply must be the typed Late, not silence or an error.
    if let Some(s) = order.first() {
        let mut party = SyntheticParty::new(s.party, cfg.seed);
        let u = party.make_update(0, cfg.update_len);
        Message::UploadNonce { nonce: s.nonce, update: u }
            .encode_into(&mut frame)
            .expect("fleet frame fits");
        inject(&frame, &mut buf);
    }
    let round_s = t0.elapsed().as_secs_f64();
    let fused_len = run.result.as_ref().map(|(w, _)| w.len()).unwrap_or(0);
    let _ = std::fs::remove_dir_all(&root);
    FleetReport {
        outcome: run.outcome,
        folded: run.folded,
        quorum,
        expected,
        injected,
        accepted,
        duplicates,
        late,
        rejected,
        fused_len,
        round_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Small enough to run in seconds, poor enough in memory that the
    /// round classifies Streaming (200 × 128 B × dup 2.0 × 1.1 ≈ 56 KB).
    fn small_fleet(seed: u64) -> FleetConfig {
        FleetConfig { seed, clients: 200, node_memory: 8 << 10, ..FleetConfig::default() }
    }

    #[test]
    fn fleet_round_folds_every_survivor_exactly_once() {
        let cfg = small_fleet(42);
        let scheds = schedules(&cfg.scenario());
        let survivors = scheds.iter().filter(|s| !s.drops_out).count() as u64;
        let dups: u64 =
            scheds.iter().filter(|s| !s.drops_out).map(|s| u64::from(s.retransmits)).sum();
        assert!(survivors > 0 && dups > 0, "seed must exercise both paths");
        let r = run_fleet(&cfg);
        assert_eq!(r.outcome, RoundOutcome::Quorum);
        assert_eq!(r.folded as u64, survivors);
        assert_eq!(r.accepted, survivors, "each survivor folded exactly once");
        assert_eq!(r.duplicates, dups, "every retransmit absorbed, none folded");
        assert_eq!(r.late, 1, "the post-seal probe got the typed Late");
        assert_eq!(r.rejected, 0);
        assert_eq!(r.injected, survivors + dups + 1);
        assert_eq!(r.fused_len, cfg.update_len);
    }

    #[test]
    fn fleet_digest_is_bit_stable_and_seeded() {
        let a = run_fleet(&small_fleet(42));
        let b = run_fleet(&small_fleet(42));
        assert_eq!(a.digest(), b.digest(), "same seed, same digest");
        let c = run_fleet(&small_fleet(43));
        assert_ne!(a.digest(), c.digest(), "different seed, different round");
    }
}
