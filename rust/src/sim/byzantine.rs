//! Byzantine scenarios: seeded attacker cohorts replayed against REAL
//! servers over real TCP sockets — the robustness counterpart of the
//! straggler and tier harnesses.
//!
//! Two shapes, matching the two robust layers:
//!
//! * **Flat, trust-weighted** ([`run_byzantine_scenario`]): a fleet with a
//!   seeded attacker subset drives TWO quorum rounds against one
//!   [`FlServer`] whose config arms the robust admission gate
//!   (`clip_factor > 0`, so the fusion layer is wrapped in
//!   [`TrustWeighted`](crate::fusion::TrustWeighted)).  Round 0 is honest
//!   everywhere — it exists to seal the median-norm reference.  In round 1
//!   the attackers ship their poisoned updates: norm-inflating attacks hit
//!   the hard gate and draw the typed `Rejected` wire reply plus a trust
//!   decay, while the honest cohort folds untouched.
//! * **2-tier, trimmed-mean** ([`run_byzantine_tier_scenario`]): a
//!   colluding cohort sits behind ONE relay of a real 2-tier tree running
//!   [`TrimmedMean`](crate::fusion::TrimmedMean) end to end.  The poisoned
//!   extremes ride the relay's extremes sketch across the backhaul and are
//!   trimmed at the ROOT — the property that makes the robust algorithm
//!   "survive the hierarchy".
//!
//! Determinism contract: every client's data AND its attack are pure
//! functions of the seed ([`byz_update`] rebuilds the exact bytes a client
//! shipped), so the in-process references ([`honest_fedavg_reference`],
//! [`exact_trimmed_mean`] over [`fleet_updates`]) compare against the
//! fused model numerically, and the reply-kind digests are bit-stable
//! across runs.  Fused *weights* stay out of the digests for the same
//! reason as everywhere else in `sim`: lane/arrival order re-associates
//! float adds within the documented merge tolerance.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::client::SyntheticParty;
use crate::config::{NodeRole, ServiceConfig};
use crate::coordinator::{AdaptiveService, RoundOutcome};
use crate::dfs::{DfsClient, NameNode};
use crate::fusion::{FusionAlgorithm, TrimmedMean};
use crate::mapreduce::ExecutorConfig;
use crate::net::{Message, NetClient};
use crate::server::{FlServer, RelayServer};
use crate::sim::{classify, mix, ReplyKind};
use crate::tensorstore::ModelUpdate;
use crate::util::rng::Rng;

/// What a Byzantine party does to its honest update before shipping it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Attack {
    /// Multiply every coordinate by this factor (norm-inflating — the
    /// attack the clip/reject gate catches).
    Scale(f32),
    /// Flip every sign.  Norm-preserving, so it sails PAST the norm gate —
    /// the attack only a rank-based fold (trimmed mean) absorbs.
    Negate,
    /// Replace the update with large Gaussian noise (σ = 25): both
    /// norm-inflating and direction-destroying.
    Random,
}

impl Attack {
    /// Apply the attack in place.  `rng` feeds only [`Attack::Random`];
    /// callers pass the party's forked stream so the poisoned bytes are a
    /// pure function of (seed, party).
    pub fn apply(&self, data: &mut [f32], rng: &mut Rng) {
        match self {
            Attack::Scale(s) => {
                let s = if s.is_finite() { *s } else { 1.0 };
                for v in data.iter_mut() {
                    *v *= s;
                }
            }
            Attack::Negate => {
                for v in data.iter_mut() {
                    *v = -*v;
                }
            }
            Attack::Random => rng.fill_gaussian_f32(data, 25.0),
        }
    }

    fn digest_code(&self) -> u64 {
        match self {
            Attack::Scale(s) => mix(1, s.to_bits() as u64),
            Attack::Negate => 2,
            Attack::Random => 3,
        }
    }
}

/// The update party `party` ships in `round` — honest Gaussian data with
/// the attack applied when `attack` is `Some`.  Pure function of its
/// arguments: the driving client and every in-process reference rebuild
/// bit-identical bytes from it.
pub fn byz_update(
    seed: u64,
    party: u64,
    round: u32,
    len: usize,
    attack: Option<Attack>,
) -> ModelUpdate {
    let mut u = SyntheticParty::new(party, seed).make_update(round, len);
    if let Some(a) = attack {
        let mut r = Rng::new(seed ^ party.wrapping_mul(0x00A7_7AC4));
        a.apply(&mut u.data, &mut r);
    }
    u
}

/// One flat Byzantine scenario: fleet shape, attacker rate, robust knobs.
#[derive(Clone, Debug)]
pub struct ByzConfig {
    pub seed: u64,
    /// Registered fleet size.
    pub clients: usize,
    /// Parameters per update (bytes = 4×).
    pub update_len: usize,
    /// Probability a party is Byzantine (drawn per party from the seed).
    pub attack_fraction: f64,
    pub attack: Attack,
    /// The server's robust admission knob (`ServiceConfig::clip_factor`);
    /// > 0 arms the gate and wraps fusion in `TrustWeighted`.
    pub clip_factor: f64,
    pub trust_decay: f64,
    /// Quorum as a fraction of the fleet.
    pub quorum_frac: f64,
    /// Per-round deadline.  The attacked round always runs to it (rejected
    /// frames never count as collected), so keep it tight.
    pub deadline: Duration,
    pub node_memory: u64,
    pub cores: usize,
}

impl Default for ByzConfig {
    fn default() -> ByzConfig {
        ByzConfig {
            seed: 42,
            clients: 16,
            update_len: 256, // 1 KB updates: past the 32 KB buffer ceiling
            attack_fraction: 0.25,
            attack: Attack::Scale(50.0),
            clip_factor: 3.0,
            trust_decay: 0.5,
            quorum_frac: 0.5,
            deadline: Duration::from_millis(1500),
            node_memory: 32 << 10,
            cores: 4,
        }
    }
}

/// What one scheduled party will do — a pure function of the seed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ByzClientSchedule {
    pub party: u64,
    pub nonce: u64,
    pub attacker: bool,
    pub delay_ms: u64,
}

/// Expand a flat Byzantine scenario into per-party schedules.
pub fn byz_schedules(cfg: &ByzConfig) -> Vec<ByzClientSchedule> {
    let mut root = Rng::new(cfg.seed);
    (0..cfg.clients as u64)
        .map(|party| {
            let mut r = root.fork(party.wrapping_add(0xB12A));
            let nonce = r.next_u64();
            let attacker = r.next_f64() < cfg.attack_fraction;
            let delay_ms = 5 + r.gen_range(40);
            ByzClientSchedule { party, nonce, attacker, delay_ms }
        })
        .collect()
}

/// Digest of the injected attack plan alone (pre-run).
pub fn byz_schedule_digest(cfg: &ByzConfig, scheds: &[ByzClientSchedule]) -> u64 {
    let mut h = 0xB12A_717Eu64; // "byzantine"
    h = mix(h, cfg.attack.digest_code());
    for s in scheds {
        h = mix(h, s.party);
        h = mix(h, s.nonce);
        h = mix(h, u64::from(s.attacker));
        h = mix(h, s.delay_ms);
    }
    h
}

/// One party's observable behaviour across both rounds.
#[derive(Clone, Debug)]
pub struct ByzClientRecord {
    pub party: u64,
    pub attacker: bool,
    /// Reply to the honest round-0 upload.
    pub honest_reply: ReplyKind,
    /// Reply to the round-1 upload (poisoned for attackers).
    pub attacked_reply: ReplyKind,
    /// Trust score after the attacked round sealed.
    pub trust: f32,
}

/// Everything a flat Byzantine scenario produced.
#[derive(Clone, Debug)]
pub struct ByzReport {
    pub honest_outcome: RoundOutcome,
    pub attacked_outcome: RoundOutcome,
    pub honest_folded: usize,
    pub attacked_folded: usize,
    pub quorum: usize,
    pub expected: usize,
    /// Per-party records, in party order.
    pub clients: Vec<ByzClientRecord>,
    /// Round-0 fused model (honest everywhere) — numeric checks only,
    /// never digested.
    pub honest_fused: Vec<f32>,
    /// Round-1 fused model (attacked) — numeric checks only.
    pub attacked_fused: Vec<f32>,
    /// Wall seconds — informational, never part of the digest.
    pub round_s: f64,
}

fn outcome_code(o: RoundOutcome) -> u64 {
    match o {
        RoundOutcome::Complete => 1,
        RoundOutcome::Quorum => 2,
        RoundOutcome::Aborted => 3,
    }
}

impl ByzReport {
    /// Bit-stable digest: both outcomes and counts, plus every party's
    /// attacker flag, typed reply pair and post-round trust bits.  (Trust
    /// is deterministic: a decay multiplication per rejection plus the
    /// seal's outlier/recovery arithmetic, all in a fixed party order.)
    pub fn digest(&self) -> u64 {
        let mut h = 0xB12A_D16Eu64;
        h = mix(h, outcome_code(self.honest_outcome));
        h = mix(h, outcome_code(self.attacked_outcome));
        h = mix(h, self.honest_folded as u64);
        h = mix(h, self.attacked_folded as u64);
        h = mix(h, self.quorum as u64);
        h = mix(h, self.expected as u64);
        for c in &self.clients {
            h = mix(h, c.party);
            h = mix(h, u64::from(c.attacker));
            h = mix(h, c.honest_reply.code());
            h = mix(h, c.attacked_reply.code());
            h = mix(h, c.trust.to_bits() as u64);
        }
        h
    }
}

/// The honest-only weighted FedAvg the attacked round should converge to
/// once the gate rejects every norm-inflating attacker: Σwᵢdᵢ / Σwᵢ over
/// the honest subset, rebuilt from the seed.
pub fn honest_fedavg_reference(cfg: &ByzConfig, round: u32) -> Vec<f32> {
    let scheds = byz_schedules(cfg);
    let mut sum = vec![0.0f64; cfg.update_len];
    let mut wtot = 0.0f64;
    for s in scheds.iter().filter(|s| !s.attacker) {
        let u = byz_update(cfg.seed, s.party, round, cfg.update_len, None);
        for (a, &v) in sum.iter_mut().zip(&u.data) {
            *a += u.count as f64 * v as f64;
        }
        wtot += u.count as f64;
    }
    sum.iter().map(|&v| (v / wtot.max(1e-12)) as f32).collect()
}

/// Unique scratch roots across runs in one process.
static BYZ_SEQ: AtomicU64 = AtomicU64::new(0);

fn scratch_dir(tag: &str, seed: u64) -> std::path::PathBuf {
    let seq = BYZ_SEQ.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!(
        "elastiagg-{tag}-{}-{seed}-{seq}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).expect("byzantine scratch dir");
    dir
}

fn drive_byz_client(addr: &str, s: &ByzClientSchedule, cfg: &ByzConfig, round: u32) -> ReplyKind {
    std::thread::sleep(Duration::from_millis(s.delay_ms));
    let attack = (round > 0 && s.attacker).then_some(cfg.attack);
    let u = byz_update(cfg.seed, s.party, round, cfg.update_len, attack);
    // round-distinct nonce: a retransmission ledger keyed per round never
    // confuses the two uploads
    let nonce = s.nonce ^ u64::from(round);
    match NetClient::connect(addr) {
        Ok(mut c) => c
            .call(&Message::UploadNonce { nonce, update: u })
            .map(|m| classify(&m))
            .unwrap_or(ReplyKind::Rejected),
        Err(_) => ReplyKind::Rejected,
    }
}

/// Run one flat Byzantine scenario end to end: an honest calibration round
/// that seals the median-norm reference, then the attacked round against
/// the armed gate — real server, real TCP, typed `Rejected` replies.
pub fn run_byzantine_scenario(cfg: &ByzConfig) -> ByzReport {
    let scheds = byz_schedules(cfg);
    let root = scratch_dir("byz", cfg.seed);
    let nn = NameNode::create(&root, 2, 1, 1 << 20).expect("byzantine store");
    let mut scfg = ServiceConfig::default();
    scfg.node.memory_bytes = cfg.node_memory;
    scfg.node.cores = cfg.cores.max(1);
    scfg.monitor_timeout_s = cfg.deadline.as_secs_f64();
    scfg.clip_factor = cfg.clip_factor;
    scfg.trust_decay = cfg.trust_decay;
    let svc = AdaptiveService::new(
        scfg,
        DfsClient::new(nn),
        None,
        ExecutorConfig { executors: 1, cores_per_executor: 2, ..Default::default() },
    );
    let update_bytes = (cfg.update_len * 4) as u64;
    let server = FlServer::new(svc, Arc::new(crate::fusion::FedAvg), update_bytes);
    for s in &scheds {
        server.registry.join(s.party, 0, 16);
    }
    let handle = server.start("127.0.0.1:0").expect("byzantine server");
    let addr = handle.addr().to_string();
    let expected = cfg.clients.max(1);
    let quorum = (((cfg.clients as f64) * cfg.quorum_frac).ceil() as usize).max(1);

    let t0 = Instant::now();
    let drive_round = |round: u32| {
        std::thread::scope(|scope| {
            let agg =
                scope.spawn(|| server.run_round_quorum(expected, quorum, cfg.deadline));
            std::thread::sleep(Duration::from_millis(40));
            let clients: Vec<_> = scheds
                .iter()
                .map(|s| {
                    let addr = addr.clone();
                    scope.spawn(move || drive_byz_client(&addr, s, cfg, round))
                })
                .collect();
            let replies: Vec<ReplyKind> =
                clients.into_iter().map(|h| h.join().expect("client thread")).collect();
            (agg.join().expect("aggregator thread").expect("quorum round"), replies)
        })
    };
    let (honest_run, honest_replies) = drive_round(0);
    let (attacked_run, attacked_replies) = drive_round(1);
    let round_s = t0.elapsed().as_secs_f64();

    let clients = scheds
        .iter()
        .enumerate()
        .map(|(i, s)| ByzClientRecord {
            party: s.party,
            attacker: s.attacker,
            honest_reply: honest_replies[i],
            attacked_reply: attacked_replies[i],
            trust: server.registry.trust(s.party),
        })
        .collect();
    let fused = |run: &crate::server::RoundRun| {
        run.result.as_ref().map(|(w, _)| w.clone()).unwrap_or_default()
    };
    let report = ByzReport {
        honest_outcome: honest_run.outcome,
        attacked_outcome: attacked_run.outcome,
        honest_folded: honest_run.folded,
        attacked_folded: attacked_run.folded,
        quorum,
        expected,
        clients,
        honest_fused: fused(&honest_run),
        attacked_fused: fused(&attacked_run),
        round_s,
    };
    drop(handle);
    let _ = std::fs::remove_dir_all(&root);
    report
}

/// One 2-tier Byzantine scenario: a colluding cohort behind ONE relay of a
/// trimmed-mean tree.
#[derive(Clone, Debug)]
pub struct ByzTierConfig {
    pub seed: u64,
    pub edges: usize,
    pub clients_per_edge: usize,
    pub update_len: usize,
    /// Byzantine parties, ALL behind edge 0 (the colluding cohort).
    pub colluders: usize,
    pub attack: Attack,
    /// Per-side trimmed fraction of the tree's `TrimmedMean`.
    pub trim: f32,
    /// Extremes-sketch per-side capacity (≥ k for the exact regime).
    pub sketch_cap: usize,
    pub quorum_frac: f64,
    pub relay_deadline: Duration,
    pub root_deadline: Duration,
    pub parent_wait: Duration,
    pub node_memory: u64,
    pub cores: usize,
}

impl Default for ByzTierConfig {
    fn default() -> ByzTierConfig {
        ByzTierConfig {
            seed: 42,
            edges: 3,
            clients_per_edge: 6,
            update_len: 64,
            colluders: 2,
            attack: Attack::Scale(50.0),
            trim: 0.2,
            sketch_cap: 8,
            quorum_frac: 0.5,
            relay_deadline: Duration::from_millis(600),
            root_deadline: Duration::from_millis(1800),
            parent_wait: Duration::from_secs(5),
            node_memory: 64 << 10,
            cores: 4,
        }
    }
}

impl ByzTierConfig {
    /// The attack every scheduled party ships (colluders sit at the FRONT
    /// of edge 0's cohort — deterministic by construction).
    pub fn attack_for(&self, party: u64) -> Option<Attack> {
        (party < self.colluders.min(self.clients_per_edge) as u64).then_some(self.attack)
    }
}

/// Rebuild the whole fleet's shipped updates (poison included) from the
/// seed — the operand set for [`exact_trimmed_mean`] references.
///
/// [`exact_trimmed_mean`]: crate::fusion::exact_trimmed_mean
pub fn fleet_updates(cfg: &ByzTierConfig) -> Vec<ModelUpdate> {
    (0..(cfg.edges * cfg.clients_per_edge) as u64)
        .map(|p| byz_update(cfg.seed, p, 0, cfg.update_len, cfg.attack_for(p)))
        .collect()
}

/// One edge's observable behaviour in the tier scenario.
#[derive(Clone, Debug)]
pub struct ByzEdgeRecord {
    pub edge: u64,
    pub relay_folded: usize,
    pub partial_reply: Option<ReplyKind>,
    pub model_published: bool,
    /// Per-cohort-client replies, in party order.
    pub replies: Vec<ReplyKind>,
}

/// Everything a tier Byzantine scenario produced.
#[derive(Clone, Debug)]
pub struct ByzTierReport {
    pub outcome: RoundOutcome,
    pub folded: usize,
    pub quorum: usize,
    pub expected: usize,
    pub colluders: usize,
    pub edges: Vec<ByzEdgeRecord>,
    /// The root's fused (trimmed-mean) model — numeric checks only.
    pub fused: Vec<f32>,
    pub round_s: f64,
}

impl ByzTierReport {
    /// Bit-stable digest over the structural outcome (never the floats).
    pub fn digest(&self) -> u64 {
        let mut h = 0xB12A_71E2u64;
        h = mix(h, outcome_code(self.outcome));
        h = mix(h, self.folded as u64);
        h = mix(h, self.quorum as u64);
        h = mix(h, self.expected as u64);
        h = mix(h, self.colluders as u64);
        let code = |r: &Option<ReplyKind>| r.map(|k| k.code()).unwrap_or(0);
        for e in &self.edges {
            h = mix(h, e.edge);
            h = mix(h, e.relay_folded as u64);
            h = mix(h, code(&e.partial_reply));
            h = mix(h, u64::from(e.model_published));
            for r in &e.replies {
                h = mix(h, r.code());
            }
        }
        h
    }
}

fn make_tier_node(
    role: NodeRole,
    parent: Option<String>,
    edge_id: u64,
    cfg: &ByzTierConfig,
    algo: Arc<dyn FusionAlgorithm>,
    dir: &std::path::Path,
) -> Arc<FlServer> {
    let nn = NameNode::create(dir, 2, 1, 1 << 20).expect("byz tier store");
    let mut scfg = ServiceConfig::default();
    scfg.node.memory_bytes = cfg.node_memory;
    scfg.node.cores = cfg.cores.max(1);
    scfg.monitor_timeout_s = cfg.root_deadline.as_secs_f64();
    scfg.trim_fraction = cfg.trim as f64;
    scfg.role = role;
    scfg.parent_addr = parent;
    scfg.edge_id = edge_id;
    let svc = AdaptiveService::new(
        scfg,
        DfsClient::new(nn),
        None,
        ExecutorConfig { executors: 1, cores_per_executor: 2, ..Default::default() },
    );
    FlServer::new(svc, algo, (cfg.update_len * 4) as u64)
}

/// Run one seeded tier Byzantine scenario: colluders poison ONE cohort,
/// their extremes cross the backhaul inside the relay's sketch, and the
/// root's trimmed mean cuts them — real relays, real TCP, one
/// member-counted quorum round.
pub fn run_byzantine_tier_scenario(cfg: &ByzTierConfig) -> ByzTierReport {
    let scratch = scratch_dir("byz-tier", cfg.seed);
    let algo: Arc<dyn FusionAlgorithm> =
        Arc::new(TrimmedMean::new(cfg.trim, cfg.sketch_cap));

    let root_server = make_tier_node(
        NodeRole::Root,
        None,
        0,
        cfg,
        algo.clone(),
        &scratch.join("root"),
    );
    let root_handle = root_server.start("127.0.0.1:0").expect("byz root server");
    let root_addr = root_handle.addr().to_string();

    struct Edge {
        edge: u64,
        relay: RelayServer,
        _handle: crate::net::ServerHandle,
        addr: String,
    }
    let edges: Vec<Edge> = (0..cfg.edges as u64)
        .map(|edge| {
            let server = make_tier_node(
                NodeRole::Relay,
                Some(root_addr.clone()),
                edge,
                cfg,
                algo.clone(),
                &scratch.join(format!("edge{edge}")),
            );
            let handle = server.start("127.0.0.1:0").expect("byz relay server");
            let addr = handle.addr().to_string();
            let relay = RelayServer::from_config(server).expect("byz relay config");
            Edge { edge, relay, _handle: handle, addr }
        })
        .collect();

    let expected = (cfg.edges * cfg.clients_per_edge).max(1);
    let quorum = (((expected as f64) * cfg.quorum_frac).ceil() as usize).max(1);

    let t0 = Instant::now();
    let (root_run, edge_records) = std::thread::scope(|scope| {
        let root = scope
            .spawn(|| root_server.run_round_quorum(expected, quorum, cfg.root_deadline));
        let edge_threads: Vec<_> = edges
            .iter()
            .map(|edge| {
                scope.spawn(move || {
                    let (relay_run, replies) = std::thread::scope(|es| {
                        let client_threads: Vec<_> = (0..cfg.clients_per_edge as u64)
                            .map(|i| {
                                let party = edge.edge * cfg.clients_per_edge as u64 + i;
                                let addr = edge.addr.clone();
                                es.spawn(move || {
                                    // small deterministic stagger keeps the
                                    // sockets from thundering one accept loop
                                    std::thread::sleep(Duration::from_millis(
                                        5 + (party % 7) * 10,
                                    ));
                                    let u = byz_update(
                                        cfg.seed,
                                        party,
                                        0,
                                        cfg.update_len,
                                        cfg.attack_for(party),
                                    );
                                    match NetClient::connect(&addr) {
                                        Ok(mut c) => c
                                            .call(&Message::UploadNonce {
                                                nonce: party.wrapping_mul(0x9E37_79B9),
                                                update: u,
                                            })
                                            .map(|m| classify(&m))
                                            .unwrap_or(ReplyKind::Rejected),
                                        Err(_) => ReplyKind::Rejected,
                                    }
                                })
                            })
                            .collect();
                        let relay_run = edge
                            .relay
                            .run_relay_round(
                                cfg.clients_per_edge,
                                1,
                                cfg.relay_deadline,
                                cfg.parent_wait,
                            )
                            .expect("byz relay round");
                        let replies: Vec<ReplyKind> = client_threads
                            .into_iter()
                            .map(|h| h.join().expect("byz client thread"))
                            .collect();
                        (relay_run, replies)
                    });
                    ByzEdgeRecord {
                        edge: edge.edge,
                        relay_folded: relay_run.folded,
                        partial_reply: relay_run.forwarded.as_ref().map(classify),
                        model_published: relay_run.model_published,
                        replies,
                    }
                })
            })
            .collect();
        let edge_records: Vec<ByzEdgeRecord> =
            edge_threads.into_iter().map(|h| h.join().expect("byz edge thread")).collect();
        (root.join().expect("byz root thread"), edge_records)
    });
    let round_s = t0.elapsed().as_secs_f64();
    let run = root_run.expect("byz root quorum round");
    let fused = run.result.as_ref().map(|(w, _)| w.clone()).unwrap_or_default();
    let report = ByzTierReport {
        outcome: run.outcome,
        folded: run.folded,
        quorum,
        expected,
        colluders: cfg.colluders.min(cfg.clients_per_edge),
        edges: edge_records,
        fused,
        round_s,
    };
    drop(root_handle);
    let _ = std::fs::remove_dir_all(&scratch);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byz_schedules_are_pure_functions_of_the_seed() {
        let cfg = ByzConfig::default();
        assert_eq!(byz_schedules(&cfg), byz_schedules(&cfg));
        assert_eq!(
            byz_schedule_digest(&cfg, &byz_schedules(&cfg)),
            byz_schedule_digest(&cfg, &byz_schedules(&cfg))
        );
        let other = ByzConfig { seed: 43, ..cfg.clone() };
        assert_ne!(
            byz_schedule_digest(&cfg, &byz_schedules(&cfg)),
            byz_schedule_digest(&other, &byz_schedules(&other))
        );
        // swapping the attack flips the digest even with identical schedules
        let negated = ByzConfig { attack: Attack::Negate, ..cfg.clone() };
        assert_eq!(byz_schedules(&cfg), byz_schedules(&negated));
        assert_ne!(
            byz_schedule_digest(&cfg, &byz_schedules(&cfg)),
            byz_schedule_digest(&negated, &byz_schedules(&negated))
        );
    }

    #[test]
    fn attack_knobs_saturate_and_apply() {
        let all = ByzConfig { attack_fraction: 1.0, ..ByzConfig::default() };
        assert!(byz_schedules(&all).iter().all(|s| s.attacker));
        let none = ByzConfig { attack_fraction: 0.0, ..ByzConfig::default() };
        assert!(byz_schedules(&none).iter().all(|s| !s.attacker));

        let mut r = Rng::new(1);
        let mut d = vec![1.0f32, -2.0, 3.0];
        Attack::Scale(10.0).apply(&mut d, &mut r);
        assert_eq!(d, vec![10.0, -20.0, 30.0]);
        Attack::Negate.apply(&mut d, &mut r);
        assert_eq!(d, vec![-10.0, 20.0, -30.0]);
        // a NaN scale factor must not poison the update into unfoldability
        let mut d = vec![1.0f32; 4];
        Attack::Scale(f32::NAN).apply(&mut d, &mut r);
        assert!(d.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn byz_update_is_deterministic_and_attack_inflates_the_norm() {
        let a = byz_update(42, 3, 1, 32, Some(Attack::Scale(50.0)));
        let b = byz_update(42, 3, 1, 32, Some(Attack::Scale(50.0)));
        assert_eq!(a.data, b.data);
        let honest = byz_update(42, 3, 1, 32, None);
        let n = |d: &[f32]| d.iter().map(|v| (v * v) as f64).sum::<f64>().sqrt();
        assert!((n(&a.data) / n(&honest.data) - 50.0).abs() < 1e-3);
        // Negate preserves the norm exactly — the gate cannot see it
        let neg = byz_update(42, 3, 1, 32, Some(Attack::Negate));
        assert_eq!(n(&neg.data), n(&honest.data));
    }

    #[test]
    fn honest_reference_ignores_attackers() {
        let cfg = ByzConfig::default();
        let r0 = honest_fedavg_reference(&cfg, 0);
        assert_eq!(r0.len(), cfg.update_len);
        // the reference is attack-independent by construction
        let scaled = ByzConfig { attack: Attack::Random, ..cfg.clone() };
        assert_eq!(honest_fedavg_reference(&scaled, 0), r0);
    }

    #[test]
    fn tier_colluders_sit_behind_edge_zero() {
        let cfg = ByzTierConfig::default();
        let us = fleet_updates(&cfg);
        assert_eq!(us.len(), 18);
        // exactly `colluders` poisoned updates, all in edge 0's id range
        let n = |d: &[f32]| d.iter().map(|v| (v * v) as f64).sum::<f64>().sqrt();
        let honest_scale: f64 = n(&byz_update(cfg.seed, 5, 0, cfg.update_len, None).data);
        let poisoned: Vec<u64> = us
            .iter()
            .filter(|u| n(&u.data) > 10.0 * honest_scale)
            .map(|u| u.party)
            .collect();
        assert_eq!(poisoned, vec![0, 1]);
        assert!(poisoned.iter().all(|&p| p < cfg.clients_per_edge as u64));
    }
}
