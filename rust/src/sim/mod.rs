//! Deterministic fault-injection scenario harness.
//!
//! The paper's edge aggregator must stay cost-effective under the changing
//! demands of IoT fleets: partial participation, stragglers and
//! retransmission are the *defining* edge conditions (Lim et al., EdgeFL),
//! yet they are exactly what ad-hoc integration tests cannot reproduce on
//! demand.  This module makes client misbehaviour a seeded, replayable
//! scenario axis:
//!
//! * [`schedules`] expands one `u64` seed into per-client schedules —
//!   dropout, upload latency, duplicate retransmission — via the repo's
//!   [`Rng`] streams, so the *injected* faults are a pure function of the
//!   seed;
//! * [`run_scenario`] runs those clients against the REAL [`FlServer`]
//!   over real TCP sockets (nothing is mocked: frames, the sharded fold,
//!   the memory budget and the quorum deadline all execute), driving one
//!   quorum round with [`FlServer::run_round_quorum`];
//! * the resulting [`ScenarioReport`] reduces what happened to the fields
//!   that are deterministic for a seed — the round outcome, the folded
//!   count and every client's typed reply sequence — and hashes them into
//!   a [`ScenarioReport::digest`] that is bit-identical across runs of the
//!   same seed.  (The fused *weights* are deliberately excluded: the
//!   sharded fold's lane assignment follows arrival order, so their low
//!   bits vary run to run within the documented merge tolerance.)
//!
//! The scenario suite (`rust/tests/sim_scenarios.rs`) pins the acceptance
//! bar: a 20 %-dropout fleet completes at quorum under the deadline, folds
//! each surviving client exactly once with duplicates rejected, and
//! reproduces its digest bit-for-bit on a second run.

pub mod byzantine;
pub mod fleet;
pub mod hierarchy;
pub mod straggler;

pub use fleet::{run_fleet, FleetConfig, FleetReport};

pub use byzantine::{
    byz_schedules, run_byzantine_scenario, run_byzantine_tier_scenario, Attack, ByzConfig,
    ByzReport, ByzTierConfig, ByzTierReport,
};
pub use hierarchy::{run_tier_scenario, tier_schedules, TierConfig, TierReport};
pub use straggler::{
    run_async_scenario, straggler_schedule_digest, straggler_schedules, AsyncReplyKind,
    AsyncReport, StragglerConfig, StragglerSchedule,
};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::client::SyntheticParty;
use crate::config::ServiceConfig;
use crate::coordinator::{AdaptiveService, RoundOutcome};
use crate::dfs::{DfsClient, NameNode};
use crate::fusion::FedAvg;
use crate::mapreduce::ExecutorConfig;
use crate::net::{Message, NetClient, WaiterKind};
use crate::server::FlServer;
use crate::util::rng::Rng;

/// One scenario: a fleet shape plus its fault-injection knobs.  Everything
/// that varies between runs is derived from `seed`.
#[derive(Clone, Debug)]
pub struct ScenarioConfig {
    pub seed: u64,
    /// Registered fleet size (the round's `expected`).
    pub clients: usize,
    /// Parameters per update (bytes = 4×).
    pub update_len: usize,
    /// Probability a client drops out (never uploads this round).
    pub dropout: f64,
    /// Probability a surviving client retransmits its frame once.
    pub duplicate: f64,
    /// Uniform per-client upload latency, drawn from `[min, max)` ms.
    pub latency_ms: (u64, u64),
    /// Round quorum as a fraction of the fleet (`ceil(frac × clients)`).
    pub quorum_frac: f64,
    /// Round deadline — the quorum timer of `run_round_quorum`.
    pub deadline: Duration,
    /// Aggregator node memory: size it below the buffered K·C requirement
    /// to exercise the sharded streaming path (the default does).
    pub node_memory: u64,
    /// Node cores = streaming ingest lanes.
    pub cores: usize,
}

impl Default for ScenarioConfig {
    fn default() -> ScenarioConfig {
        ScenarioConfig {
            seed: 42,
            clients: 20,
            update_len: 256, // 1 KB updates
            dropout: 0.2,
            duplicate: 0.25,
            latency_ms: (30, 250),
            quorum_frac: 0.5,
            deadline: Duration::from_millis(1500),
            // 20 × 1 KB × dup 2.0 × headroom 1.1 = 44 KB > 32 KB: the
            // round classifies Streaming and folds through the sharded
            // ingest — the path whose dedup window the harness targets.
            node_memory: 32 << 10,
            cores: 4,
        }
    }
}

/// What one simulated client will do this round — a pure function of the
/// scenario seed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClientSchedule {
    pub party: u64,
    /// Retransmission nonce carried on every copy of the upload frame.
    pub nonce: u64,
    /// Never uploads this round.
    pub drops_out: bool,
    /// Sleep before connecting (simulated network/compute latency).
    pub delay_ms: u64,
    /// Extra copies of the frame sent after the original (same nonce).
    pub retransmits: u32,
}

/// Expand a scenario into its per-client schedules.  Each client draws
/// from its own forked [`Rng`] stream, so adding knobs later cannot shift
/// the draws of existing clients within a seed.
pub fn schedules(cfg: &ScenarioConfig) -> Vec<ClientSchedule> {
    let mut root = Rng::new(cfg.seed);
    (0..cfg.clients as u64)
        .map(|party| {
            let mut r = root.fork(party.wrapping_add(1));
            let nonce = r.next_u64();
            let drops_out = r.next_f64() < cfg.dropout;
            let span = cfg.latency_ms.1.saturating_sub(cfg.latency_ms.0).max(1);
            let delay_ms = cfg.latency_ms.0 + r.gen_range(span);
            let retransmits = u32::from(r.next_f64() < cfg.duplicate);
            ClientSchedule { party, nonce, drops_out, delay_ms, retransmits }
        })
        .collect()
}

/// Order-sensitive 64-bit fold (one SplitMix64 scramble per word) — the
/// digest primitive.  Not cryptographic; collision-resistant enough to
/// flag any drift in a scenario's deterministic fields.
pub(crate) fn mix(acc: u64, v: u64) -> u64 {
    let mut z = acc.rotate_left(7) ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Digest of the *injected* faults alone (pre-run): the property tests pin
/// that different seeds produce different schedules — a seed-insensitive
/// generator would silently collapse every scenario into one.
pub fn schedule_digest(scheds: &[ClientSchedule]) -> u64 {
    let mut h = 0x5C7E_D01Eu64; // "schedule"
    for s in scheds {
        h = mix(h, s.party);
        h = mix(h, s.nonce);
        h = mix(h, u64::from(s.drops_out));
        h = mix(h, s.delay_ms);
        h = mix(h, u64::from(s.retransmits));
    }
    h
}

/// How the server answered one upload frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplyKind {
    /// Folded (or parked) — the Ack.
    Accepted,
    /// Typed duplicate: the retransmit was absorbed, not folded again.
    Duplicate,
    /// Typed late: the frame missed the round's seal.
    Late,
    /// Anything else (error reply, connection failure).
    Rejected,
}

impl ReplyKind {
    pub(crate) fn code(self) -> u64 {
        match self {
            ReplyKind::Accepted => 1,
            ReplyKind::Duplicate => 2,
            ReplyKind::Late => 3,
            ReplyKind::Rejected => 4,
        }
    }
}

pub(crate) fn classify(m: &Message) -> ReplyKind {
    match m {
        Message::Ack { .. } => ReplyKind::Accepted,
        Message::Duplicate { .. } => ReplyKind::Duplicate,
        Message::Late { .. } => ReplyKind::Late,
        _ => ReplyKind::Rejected,
    }
}

/// One client's observable behaviour during the round.
#[derive(Clone, Debug)]
pub struct ClientRecord {
    pub party: u64,
    pub dropped: bool,
    /// Reply per frame sent: original first, then each retransmit.
    pub replies: Vec<ReplyKind>,
}

/// Everything a scenario run produced, reduced to its deterministic core.
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    pub outcome: RoundOutcome,
    /// Updates folded at seal time (≡ surviving clients when none race
    /// the deadline).
    pub folded: usize,
    pub quorum: usize,
    pub expected: usize,
    /// Per-client records, in party order.
    pub clients: Vec<ClientRecord>,
    /// Parameter count of the published model (0 on abort).
    pub fused_len: usize,
    /// Wall seconds of the driven round — informational; NOT part of the
    /// digest (wall clocks are never bit-stable).
    pub round_s: f64,
}

impl ScenarioReport {
    /// The bit-stable round-outcome digest: outcome, counts and every
    /// client's typed reply sequence, folded in party order.
    pub fn digest(&self) -> u64 {
        let mut h = 0xD16E_57u64; // "digest"
        h = mix(
            h,
            match self.outcome {
                RoundOutcome::Complete => 1,
                RoundOutcome::Quorum => 2,
                RoundOutcome::Aborted => 3,
            },
        );
        h = mix(h, self.folded as u64);
        h = mix(h, self.quorum as u64);
        h = mix(h, self.expected as u64);
        h = mix(h, self.fused_len as u64);
        for c in &self.clients {
            h = mix(h, c.party);
            h = mix(h, u64::from(c.dropped));
            h = mix(h, c.replies.len() as u64);
            for r in &c.replies {
                h = mix(h, r.code());
            }
        }
        h
    }
}

/// Unique scratch roots across runs in one process (two runs of the same
/// seed must not collide on the service's store directory).
static SCENARIO_SEQ: AtomicU64 = AtomicU64::new(0);

fn drive_client(addr: &str, s: &ClientSchedule, cfg: &ScenarioConfig) -> ClientRecord {
    if s.drops_out {
        return ClientRecord { party: s.party, dropped: true, replies: Vec::new() };
    }
    std::thread::sleep(Duration::from_millis(s.delay_ms));
    let mut replies = Vec::new();
    match NetClient::connect(addr) {
        Ok(mut c) => {
            let mut party = SyntheticParty::new(s.party, cfg.seed);
            let u = party.make_update(0, cfg.update_len);
            // original + each retransmit carry the SAME nonce: the wire
            // shape of a client re-sending an unacknowledged frame
            for _ in 0..=s.retransmits {
                match c.call(&Message::UploadNonce { nonce: s.nonce, update: u.clone() }) {
                    Ok(m) => replies.push(classify(&m)),
                    Err(_) => replies.push(ReplyKind::Rejected),
                }
            }
        }
        Err(_) => replies.push(ReplyKind::Rejected),
    }
    ClientRecord { party: s.party, dropped: false, replies }
}

/// Run one seeded scenario end to end against a real TCP [`FlServer`].
///
/// The fleet is registered up front (the round classifies against the true
/// party count before any upload lands — deterministic), every scheduled
/// client runs on its own thread, and the round is driven with
/// [`FlServer::run_round_quorum`] at `ceil(quorum_frac × clients)`.
pub fn run_scenario(cfg: &ScenarioConfig) -> ScenarioReport {
    run_scenario_on(cfg, false)
}

/// [`run_scenario`] with an explicit network backend: `threaded = false`
/// serves through the readiness reactor ([`FlServer::start`]), `true`
/// through the legacy thread-per-connection server
/// ([`FlServer::start_threaded`]).  Everything above the socket layer is
/// identical, so the same seed must produce the same
/// [`ScenarioReport::digest`] on both — the parity pin
/// `benches/fig_connection_scaling` holds the reactor to.
pub fn run_scenario_on(cfg: &ScenarioConfig, threaded: bool) -> ScenarioReport {
    run_scenario_inner(cfg, threaded, WaiterKind::Auto)
}

/// [`run_scenario`] through the reactor pinned to a specific
/// [`WaiterKind`]: the cross-backend digest-parity pin
/// (`tests/sim_scenarios.rs`) replays one seed over every backend
/// [`WaiterKind::compiled_in`] reports and asserts bit-identical digests —
/// readiness delivery (epoll, kqueue or the portable sweep) must never
/// leak into round outcomes.
pub fn run_scenario_on_waiter(cfg: &ScenarioConfig, waiter: WaiterKind) -> ScenarioReport {
    run_scenario_inner(cfg, false, waiter)
}

fn run_scenario_inner(cfg: &ScenarioConfig, threaded: bool, waiter: WaiterKind) -> ScenarioReport {
    let scheds = schedules(cfg);
    let seq = SCENARIO_SEQ.fetch_add(1, Ordering::Relaxed);
    let root = std::env::temp_dir().join(format!(
        "elastiagg-sim-{}-{}-{}",
        std::process::id(),
        cfg.seed,
        seq
    ));
    std::fs::create_dir_all(&root).expect("scenario scratch dir");
    let nn = NameNode::create(&root, 2, 1, 1 << 20).expect("scenario store");
    let mut scfg = ServiceConfig::default();
    scfg.node.memory_bytes = cfg.node_memory;
    scfg.node.cores = cfg.cores.max(1);
    scfg.monitor_timeout_s = cfg.deadline.as_secs_f64();
    scfg.waiter = waiter;
    let svc = AdaptiveService::new(
        scfg,
        DfsClient::new(nn),
        None,
        ExecutorConfig { executors: 1, cores_per_executor: 2, ..Default::default() },
    );
    let update_bytes = (cfg.update_len * 4) as u64;
    let server = FlServer::new(svc, Arc::new(FedAvg), update_bytes);
    for s in &scheds {
        server.registry.join(s.party, 0, 16);
    }
    let handle = if threaded {
        server.start_threaded("127.0.0.1:0").expect("scenario server")
    } else {
        server.start("127.0.0.1:0").expect("scenario server")
    };
    let addr = handle.addr().to_string();
    let expected = cfg.clients.max(1);
    let quorum = (((cfg.clients as f64) * cfg.quorum_frac).ceil() as usize).max(1);

    let t0 = Instant::now();
    let (run, records) = std::thread::scope(|scope| {
        let agg = scope.spawn(|| server.run_round_quorum(expected, quorum, cfg.deadline));
        // Let the aggregator reclassify the (still-empty) round against
        // the registered fleet before the first frame can land — the same
        // settle beat the ingest bench gives `run_round`.  Client delays
        // stack on top, so this shifts the whole schedule, not its shape.
        std::thread::sleep(Duration::from_millis(40));
        let clients: Vec<_> = scheds
            .iter()
            .map(|s| {
                let addr = addr.clone();
                scope.spawn(move || drive_client(&addr, s, cfg))
            })
            .collect();
        let records: Vec<ClientRecord> =
            clients.into_iter().map(|h| h.join().expect("client thread")).collect();
        (agg.join().expect("aggregator thread"), records)
    });
    let round_s = t0.elapsed().as_secs_f64();
    let run = run.expect("quorum round");
    let fused_len = run.result.as_ref().map(|(w, _)| w.len()).unwrap_or(0);
    let report = ScenarioReport {
        outcome: run.outcome,
        folded: run.folded,
        quorum,
        expected,
        clients: records,
        fused_len,
        round_s,
    };
    drop(handle);
    let _ = std::fs::remove_dir_all(&root);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_are_a_pure_function_of_the_seed() {
        let cfg = ScenarioConfig::default();
        assert_eq!(schedules(&cfg), schedules(&cfg));
        assert_eq!(schedule_digest(&schedules(&cfg)), schedule_digest(&schedules(&cfg)));
        let other = ScenarioConfig { seed: 43, ..cfg.clone() };
        assert_ne!(schedule_digest(&schedules(&cfg)), schedule_digest(&schedules(&other)));
    }

    #[test]
    fn schedule_rates_track_the_knobs() {
        // Over a large fleet the empirical dropout/duplicate rates must
        // sit near their configured probabilities (loose 3σ-ish bands).
        let cfg = ScenarioConfig { clients: 2000, ..ScenarioConfig::default() };
        let s = schedules(&cfg);
        let drops = s.iter().filter(|c| c.drops_out).count() as f64 / 2000.0;
        assert!((0.15..0.25).contains(&drops), "{drops}");
        let dups = s.iter().filter(|c| c.retransmits > 0).count() as f64 / 2000.0;
        assert!((0.20..0.30).contains(&dups), "{dups}");
        for c in &s {
            assert!((cfg.latency_ms.0..cfg.latency_ms.1).contains(&c.delay_ms));
        }
        // extreme knobs saturate
        let all = ScenarioConfig { dropout: 1.0, ..ScenarioConfig::default() };
        assert!(schedules(&all).iter().all(|c| c.drops_out));
        let none = ScenarioConfig { dropout: 0.0, ..ScenarioConfig::default() };
        assert!(schedules(&none).iter().all(|c| !c.drops_out));
    }

    #[test]
    fn digest_distinguishes_every_outcome_field() {
        let base = ScenarioReport {
            outcome: RoundOutcome::Quorum,
            folded: 16,
            quorum: 10,
            expected: 20,
            clients: vec![ClientRecord {
                party: 0,
                dropped: false,
                replies: vec![ReplyKind::Accepted, ReplyKind::Duplicate],
            }],
            fused_len: 256,
            round_s: 1.0,
        };
        let d = base.digest();
        let mut flip = base.clone();
        flip.outcome = RoundOutcome::Complete;
        assert_ne!(flip.digest(), d);
        let mut flip = base.clone();
        flip.folded = 17;
        assert_ne!(flip.digest(), d);
        let mut flip = base.clone();
        flip.clients[0].replies[1] = ReplyKind::Late;
        assert_ne!(flip.digest(), d);
        // wall time is informational, never part of the digest
        let mut flip = base.clone();
        flip.round_s = 99.0;
        assert_eq!(flip.digest(), d);
    }
}
