//! The message-passing communication path (the conventional FL transport
//! the paper's small-workload mode uses, and whose thundering-herd
//! behaviour at the aggregator §III-A Q3 discusses).
//!
//! A length-prefixed binary protocol over TCP:
//!
//! ```text
//! frame := tag u8 | len u32 | payload [u8; len]
//! ```
//!
//! Messages: party registration, update upload, fused-model fetch, and the
//! *redirect* the coordinator sends when the next round is predicted to
//! spill to the distributed path (§III-D3 seamless transition).

pub mod protocol;
pub mod server;

pub use protocol::{Message, ProtoError};
pub use server::{NetServer, ServerHandle};

use std::io::{Read, Write};
use std::net::TcpStream;

/// Blocking client for the aggregation server.
pub struct NetClient {
    stream: TcpStream,
}

impl NetClient {
    pub fn connect(addr: &str) -> std::io::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(NetClient { stream })
    }

    /// Send one message and wait for the reply.
    pub fn call(&mut self, msg: &Message) -> Result<Message, ProtoError> {
        write_frame(&mut self.stream, msg)?;
        read_frame(&mut self.stream)
    }
}

/// Write one frame.
pub fn write_frame<W: Write>(w: &mut W, msg: &Message) -> Result<(), ProtoError> {
    let (tag, payload) = msg.encode();
    w.write_all(&[tag])?;
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Message, ProtoError> {
    let mut head = [0u8; 5];
    r.read_exact(&mut head)?;
    let tag = head[0];
    let len = u32::from_le_bytes(head[1..5].try_into().unwrap()) as usize;
    if len > protocol::MAX_FRAME {
        return Err(ProtoError::FrameTooLarge(len));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Message::decode(tag, &payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensorstore::ModelUpdate;

    #[test]
    fn frame_roundtrip_via_cursor() {
        let msgs = vec![
            Message::Register { party: 42 },
            Message::Registered { party: 42, round: 7 },
            Message::Upload(ModelUpdate::new(1, 2.0, 3, vec![1.0, 2.0])),
            Message::Ack { redirect_to_dfs: true },
            Message::GetModel { round: 9 },
            Message::Model { round: 9, weights: vec![0.5; 100] },
            Message::NoModel { round: 9 },
            Message::Error("boom".to_string()),
        ];
        for m in msgs {
            let mut buf = Vec::new();
            write_frame(&mut buf, &m).unwrap();
            let got = read_frame(&mut std::io::Cursor::new(buf)).unwrap();
            assert_eq!(got, m);
        }
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = vec![0u8; 5];
        buf[0] = 1;
        buf[1..5].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(matches!(
            read_frame(&mut std::io::Cursor::new(buf)),
            Err(ProtoError::FrameTooLarge(_))
        ));
    }
}
