//! The message-passing communication path (the conventional FL transport
//! the paper's small-workload mode uses, and whose thundering-herd
//! behaviour at the aggregator §III-A Q3 discusses).
//!
//! A length-prefixed binary protocol over TCP:
//!
//! ```text
//! frame := tag u8 | len u32 | payload [u8; len]
//! ```
//!
//! Messages: party registration, update upload, fused-model fetch, and the
//! *redirect* the coordinator sends when the next round is predicted to
//! spill to the distributed path (§III-D3 seamless transition).

pub mod protocol;
mod reactor;
pub mod server;
mod threaded;
pub mod waiter;
#[cfg(target_os = "linux")]
mod waiter_epoll;
#[cfg(any(
    target_os = "macos",
    target_os = "freebsd",
    target_os = "openbsd",
    target_os = "dragonfly"
))]
mod waiter_kqueue;

pub use protocol::{checked_frame_len, Message, ProtoError, Reply};
pub use reactor::REACTOR_THREAD_NAME;
pub use server::{Handler, NetServer, ReactorConfig, ServerHandle};
pub use waiter::{TimerDriver, WaiterKind, NO_EPOLL_ENV};

use std::io::{Read, Write};
use std::net::TcpStream;

use crate::tensorstore::f32s_as_bytes;

/// A reusable, 4-byte-aligned frame payload buffer.
///
/// Backing the pool with `Vec<u32>` guarantees the payload base pointer is
/// f32-aligned, so an `Upload` frame read into it decodes through
/// [`ModelUpdateView`](crate::tensorstore::ModelUpdateView) *borrowing* the
/// weights in place (the update header is 28 bytes, a multiple of 4).
/// Reusing the buffer across frames removes the `vec![0u8; len]` the old
/// `read_frame` allocated per message — the second of the two hot-path
/// copies the upload used to pay.
#[derive(Debug, Default)]
pub struct FrameBuf {
    words: Vec<u32>,
    len: usize,
}

impl FrameBuf {
    pub fn new() -> FrameBuf {
        FrameBuf { words: Vec::new(), len: 0 }
    }

    /// Current payload length in bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        // Safety: `words` holds at least `len.div_ceil(4)` initialised u32s
        // (see `reset`), so the first `len` bytes are initialised; u32 is
        // stricter-aligned than u8.
        unsafe { std::slice::from_raw_parts(self.words.as_ptr() as *const u8, self.len) }
    }

    pub(crate) fn as_mut_slice(&mut self) -> &mut [u8] {
        // Safety: as above, plus exclusive access via &mut self.
        unsafe { std::slice::from_raw_parts_mut(self.words.as_mut_ptr() as *mut u8, self.len) }
    }

    /// Resize to `len` bytes, keeping the allocation when shrinking.
    pub(crate) fn reset(&mut self, len: usize) {
        self.words.resize(len.div_ceil(4), 0);
        self.len = len;
    }

    /// Load `bytes` as this buffer's payload.  The virtual-client fleet
    /// injects pre-framed payloads through here so they enter the server
    /// at the same 4-aligned base the reactor's pooled reads give real
    /// sockets — the zero-copy upload decode path is exercised, not
    /// bypassed.
    pub fn fill(&mut self, bytes: &[u8]) {
        self.reset(bytes.len());
        self.as_mut_slice().copy_from_slice(bytes);
    }
}

/// Blocking client for the aggregation server.  Send and receive buffers
/// are pooled across calls, mirroring the server's per-connection pools.
pub struct NetClient {
    stream: TcpStream,
    send: Vec<u8>,
    recv: FrameBuf,
}

impl NetClient {
    pub fn connect(addr: &str) -> std::io::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(NetClient { stream, send: Vec::new(), recv: FrameBuf::new() })
    }

    /// Send one message and wait for the reply.
    pub fn call(&mut self, msg: &Message) -> Result<Message, ProtoError> {
        msg.encode_into(&mut self.send)?;
        self.stream.write_all(&self.send)?;
        self.stream.flush()?;
        let tag = read_frame_into(&mut self.stream, &mut self.recv)?;
        Message::decode(tag, self.recv.as_slice())
    }
}

/// Write one frame.  Rejects oversized payloads with
/// [`ProtoError::FrameTooLarge`] *before* writing anything (a silent
/// `as u32` length truncation would corrupt the stream for good).
pub fn write_frame<W: Write>(w: &mut W, msg: &Message) -> Result<(), ProtoError> {
    let mut buf = Vec::new();
    msg.encode_into(&mut buf)?;
    w.write_all(&buf)?;
    w.flush()?;
    Ok(())
}

/// Write one reply frame, reusing `scratch` as the encode buffer.  Returns
/// the number of bytes put on the wire.
///
/// [`Reply::Model`] takes the gather-write path: a 9-byte stack header
/// (tag, length, round) followed by the shared weights viewed as bytes —
/// the full fused model crosses from the published `Arc` to the socket
/// without ever being cloned or re-buffered.
pub fn write_reply<W: Write>(
    w: &mut W,
    reply: &Reply,
    scratch: &mut Vec<u8>,
) -> Result<usize, ProtoError> {
    match reply {
        Reply::Msg(m) => {
            m.encode_into(scratch)?;
            w.write_all(scratch)?;
            w.flush()?;
            Ok(scratch.len())
        }
        Reply::Model { round, weights } => {
            let body = f32s_as_bytes(weights);
            let len = checked_frame_len(4 + body.len())?;
            let mut head = [0u8; 9];
            head[0] = protocol::TAG_MODEL;
            head[1..5].copy_from_slice(&len.to_le_bytes());
            head[5..9].copy_from_slice(&round.to_le_bytes());
            w.write_all(&head)?;
            w.write_all(body)?;
            w.flush()?;
            Ok(head.len() + body.len())
        }
    }
}

/// Read one frame's tag and payload into the pooled `buf`, distinguishing
/// a CLEAN hangup from a truncated frame:
///
/// * `Ok(None)` — EOF before the first header byte: the peer finished its
///   conversation at a frame boundary and closed.  Not an error.
/// * `Err(ProtoError::Io(UnexpectedEof))` — EOF *mid-frame* (header or
///   payload partially read): the peer died with a frame in flight.  The
///   serving backends count this into `aborted_frames`, the signal the
///   registry's liveness eviction consumes.
///
/// [`read_frame_into`] keeps the old conflated behaviour (any EOF is an
/// io error) for callers that always expect a frame, like the client's
/// reply read.
pub fn try_read_frame_into<R: Read>(
    r: &mut R,
    buf: &mut FrameBuf,
) -> Result<Option<u8>, ProtoError> {
    let mut head = [0u8; 5];
    let mut got = 0;
    while got < head.len() {
        match r.read(&mut head[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(ProtoError::Io(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "connection closed mid-frame header",
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(ProtoError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(head[1..5].try_into().unwrap()) as usize;
    if len > protocol::MAX_FRAME {
        return Err(ProtoError::FrameTooLarge(len));
    }
    buf.reset(len);
    r.read_exact(buf.as_mut_slice())?;
    Ok(Some(head[0]))
}

/// Read one frame's tag and payload into the pooled `buf`.
pub fn read_frame_into<R: Read>(r: &mut R, buf: &mut FrameBuf) -> Result<u8, ProtoError> {
    let mut head = [0u8; 5];
    r.read_exact(&mut head)?;
    let len = u32::from_le_bytes(head[1..5].try_into().unwrap()) as usize;
    if len > protocol::MAX_FRAME {
        return Err(ProtoError::FrameTooLarge(len));
    }
    buf.reset(len);
    r.read_exact(buf.as_mut_slice())?;
    Ok(head[0])
}

/// Read one frame into an owned [`Message`] (allocating; the pooled
/// server path uses [`read_frame_into`] + `Handler::handle_frame`).
pub fn read_frame<R: Read>(r: &mut R) -> Result<Message, ProtoError> {
    let mut buf = FrameBuf::new();
    let tag = read_frame_into(r, &mut buf)?;
    Message::decode(tag, buf.as_slice())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensorstore::ModelUpdate;

    #[test]
    fn frame_roundtrip_via_cursor() {
        let msgs = vec![
            Message::Register { party: 42 },
            Message::Registered { party: 42, round: 7 },
            Message::Upload(ModelUpdate::new(1, 2.0, 3, vec![1.0, 2.0])),
            Message::UploadNonce {
                nonce: 0xA5A5_5A5A,
                update: ModelUpdate::new(1, 2.0, 3, vec![1.0, 2.0]),
            },
            Message::Ack { redirect_to_dfs: true },
            Message::Duplicate { party: 1, nonce: 0xA5A5_5A5A },
            Message::Late { round: 3 },
            Message::GetModel { round: 9 },
            Message::Model { round: 9, weights: vec![0.5; 100] },
            Message::NoModel { round: 9 },
            Message::Error("boom".to_string()),
        ];
        for m in msgs {
            let mut buf = Vec::new();
            write_frame(&mut buf, &m).unwrap();
            let got = read_frame(&mut std::io::Cursor::new(buf)).unwrap();
            assert_eq!(got, m);
        }
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = vec![0u8; 5];
        buf[0] = 1;
        buf[1..5].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(matches!(
            read_frame(&mut std::io::Cursor::new(buf)),
            Err(ProtoError::FrameTooLarge(_))
        ));
    }

    #[test]
    fn pooled_buffer_reused_across_frames() {
        // Three frames of different sizes through ONE FrameBuf; each must
        // decode correctly and Upload must borrow straight from the pool.
        let msgs = vec![
            Message::Upload(ModelUpdate::new(4, 2.0, 1, vec![1.5; 300])),
            Message::Ack { redirect_to_dfs: false },
            Message::Upload(ModelUpdate::new(5, 3.0, 1, vec![-2.0; 50])),
        ];
        let mut wire = Vec::new();
        for m in &msgs {
            write_frame(&mut wire, m).unwrap();
        }
        let mut cursor = std::io::Cursor::new(wire);
        let mut buf = FrameBuf::new();
        for m in &msgs {
            let tag = read_frame_into(&mut cursor, &mut buf).unwrap();
            if tag == protocol::TAG_UPLOAD {
                let v = crate::tensorstore::ModelUpdateView::decode(buf.as_slice()).unwrap();
                assert!(
                    matches!(v.data, std::borrow::Cow::Borrowed(_)),
                    "pool is 4-aligned: upload decode must borrow"
                );
                assert_eq!(&Message::Upload(v.into_owned()), m);
            } else {
                assert_eq!(&Message::decode(tag, buf.as_slice()).unwrap(), m);
            }
        }
    }

    #[test]
    fn model_reply_gather_write_matches_message_encoding() {
        // The zero-copy Reply::Model path must be byte-identical on the
        // wire to the owned Message::Model encoding.
        let weights = vec![0.25f32; 123];
        let mut owned = Vec::new();
        write_frame(&mut owned, &Message::Model { round: 9, weights: weights.clone() }).unwrap();
        let mut gathered = Vec::new();
        let mut scratch = Vec::new();
        let n = write_reply(
            &mut gathered,
            &Reply::Model { round: 9, weights: std::sync::Arc::new(weights) },
            &mut scratch,
        )
        .unwrap();
        assert_eq!(gathered, owned);
        assert_eq!(n, gathered.len());
    }

    #[test]
    fn try_read_distinguishes_clean_eof_from_truncation() {
        let mut buf = FrameBuf::new();
        // empty stream: a clean hangup at a frame boundary
        assert!(matches!(
            try_read_frame_into(&mut std::io::Cursor::new(Vec::<u8>::new()), &mut buf),
            Ok(None)
        ));
        // EOF inside the 5-byte header: mid-frame truncation
        assert!(matches!(
            try_read_frame_into(&mut std::io::Cursor::new(vec![0x03u8, 10, 0]), &mut buf),
            Err(ProtoError::Io(e)) if e.kind() == std::io::ErrorKind::UnexpectedEof
        ));
        // EOF inside the payload: mid-frame truncation
        let mut wire = Vec::new();
        write_frame(&mut wire, &Message::Upload(ModelUpdate::new(0, 1.0, 0, vec![1.0; 64])))
            .unwrap();
        wire.truncate(wire.len() - 10);
        assert!(matches!(
            try_read_frame_into(&mut std::io::Cursor::new(wire), &mut buf),
            Err(ProtoError::Io(e)) if e.kind() == std::io::ErrorKind::UnexpectedEof
        ));
        // a whole frame still reads normally
        let mut wire = Vec::new();
        write_frame(&mut wire, &Message::Late { round: 3 }).unwrap();
        let tag = try_read_frame_into(&mut std::io::Cursor::new(wire), &mut buf).unwrap();
        assert_eq!(tag, Some(protocol::TAG_LATE));
        assert_eq!(Message::decode(tag.unwrap(), buf.as_slice()).unwrap(), Message::Late {
            round: 3
        });
    }

    #[test]
    fn fill_keeps_payload_4_aligned_for_zero_copy_decode() {
        // The fleet's injection path: an encoded UploadNonce payload loaded
        // via fill() must decode borrowing from the pool, like a real read.
        let (tag, payload) =
            Message::UploadNonce { nonce: 7, update: ModelUpdate::new(4, 2.0, 1, vec![1.5; 300]) }
                .encode();
        assert_eq!(tag, protocol::TAG_UPLOAD_NONCE);
        let mut buf = FrameBuf::new();
        buf.fill(&payload);
        let v = crate::tensorstore::ModelUpdateView::decode(&buf.as_slice()[8..]).unwrap();
        assert!(
            matches!(v.data, std::borrow::Cow::Borrowed(_)),
            "filled pool is 4-aligned: nonce-offset upload decode must borrow"
        );
        assert_eq!(v.party, 4);
    }

    #[test]
    fn torn_frame_is_io_error() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &Message::Upload(ModelUpdate::new(0, 1.0, 0, vec![1.0; 64])))
            .unwrap();
        wire.truncate(wire.len() - 10); // connection died mid-payload
        let mut buf = FrameBuf::new();
        assert!(matches!(
            read_frame_into(&mut std::io::Cursor::new(wire), &mut buf),
            Err(ProtoError::Io(_))
        ));
    }
}
