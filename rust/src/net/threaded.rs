//! The retired thread-per-connection backend, kept as the REFERENCE
//! implementation the reactor is pinned against (`fig_connection_scaling`
//! drives the same seeded scenario through both and compares digests) —
//! with its three lifecycle bugs fixed:
//!
//! * **Untracked-connection leak** — when the socket clone that `stop()`
//!   needs cannot be made, the connection is now REFUSED (shut down before
//!   a handler ever runs) instead of served untracked, where `stop()`
//!   could neither unblock nor join it.  Track-or-refuse, no third state.
//! * **Join-handle attach race** — the handler thread now blocks on a
//!   start gate until the accept loop has attached its `JoinHandle` to
//!   the live-map entry, so a handler can never finish (and remove its
//!   entry) before the handle is attached — the window in which the old
//!   code silently dropped the handle and detached the thread.
//! * **Truncated frames** are distinguished from clean hangups via
//!   [`try_read_frame_into`](super::try_read_frame_into) and counted into
//!   `aborted_frames`.

use std::collections::HashMap;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use super::server::{Counters, Handler};
use super::{try_read_frame_into, write_frame, write_reply, FrameBuf, Message, ProtoError};

/// Test failpoint: make the next N `try_clone` calls fail on a specific
/// listener, driving the refuse path deterministically.
#[cfg(test)]
pub(crate) static FAIL_CLONES: super::server::Failpoint = super::server::Failpoint::new();

/// Test failpoint: delay (ms) between spawning a handler and attaching its
/// join handle — widens the historical race window so the start gate is
/// exercised, not just present.
#[cfg(test)]
pub(crate) static ATTACH_DELAY_MS: std::sync::atomic::AtomicU64 =
    std::sync::atomic::AtomicU64::new(0);

/// Live per-connection state: a clone of the socket (so `stop` can shut a
/// blocked read down) plus the handler thread's join handle.  A handler
/// removes its own entry when its connection ends — which the start gate
/// guarantees happens only AFTER the handle was attached.
pub(crate) type ConnMap = Mutex<HashMap<u64, (TcpStream, Option<std::thread::JoinHandle<()>>)>>;

/// The running accept loop's thread and live map, held by `ServerHandle`.
pub(crate) struct Parts {
    pub accept: std::thread::JoinHandle<()>,
    pub live: Arc<ConnMap>,
}

pub(crate) fn spawn<H: Handler>(
    listener: TcpListener,
    handler: Arc<H>,
    counters: Counters,
    stop: Arc<AtomicBool>,
) -> Parts {
    let live: Arc<ConnMap> = Arc::new(Mutex::new(HashMap::new()));
    let accept = {
        let live = live.clone();
        std::thread::spawn(move || {
            #[cfg(test)]
            let local = listener.local_addr().map(|a| a.to_string()).unwrap_or_default();
            let mut next_id = 0u64;
            for stream in listener.incoming() {
                if stop.load(Ordering::Acquire) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let clone = stream.try_clone();
                #[cfg(test)]
                let clone = if FAIL_CLONES.take(&local) {
                    Err(std::io::Error::other("injected clone failure"))
                } else {
                    clone
                };
                // Track-or-refuse: without the clone, stop() could never
                // unblock this connection's read — refuse it rather than
                // serve it untracked.
                let Ok(peer) = clone else {
                    let _ = stream.shutdown(Shutdown::Both);
                    continue;
                };
                counters.connections.fetch_add(1, Ordering::Relaxed);
                let id = next_id;
                next_id += 1;
                live.lock().unwrap().insert(id, (peer, None));
                let handler = handler.clone();
                let live2 = live.clone();
                let counters2 = counters.clone();
                // Start gate: the handler may not serve (or finish and
                // remove its entry) until its JoinHandle is attached below
                // — registration and attach are atomic as far as the
                // handler can observe.
                let (ready_tx, ready_rx) = mpsc::channel::<()>();
                let join = std::thread::spawn(move || {
                    let _ = ready_rx.recv();
                    let _ = handle_conn(stream, handler, counters2);
                    live2.lock().unwrap().remove(&id);
                });
                #[cfg(test)]
                {
                    let ms = ATTACH_DELAY_MS.load(Ordering::Acquire);
                    if ms > 0 {
                        std::thread::sleep(std::time::Duration::from_millis(ms));
                    }
                }
                live.lock()
                    .unwrap()
                    .get_mut(&id)
                    .expect("start gate: handler cannot finish before its handle is attached")
                    .1 = Some(join);
                let _ = ready_tx.send(());
            }
        })
    };
    Parts { accept, live }
}

fn handle_conn<H: Handler>(
    mut stream: TcpStream,
    handler: Arc<H>,
    counters: Counters,
) -> Result<(), ProtoError> {
    stream.set_nodelay(true)?;
    // Per-connection pools, reused for every frame on this socket: the
    // 4-aligned payload buffer (so upload decode borrows in place) and
    // the reply encode scratch.
    let mut payload = FrameBuf::new();
    let mut scratch = Vec::new();
    loop {
        let tag = match try_read_frame_into(&mut stream, &mut payload) {
            Ok(Some(t)) => t,
            Ok(None) => return Ok(()), // clean hangup at a frame boundary
            Err(ProtoError::Io(_)) => {
                // died mid-frame (or stop() shut the socket down under a
                // half-read frame): a truncated frame, not a clean close
                counters.aborted_frames.fetch_add(1, Ordering::Relaxed);
                return Ok(());
            }
            Err(e) => {
                let _ = write_frame(&mut stream, &Message::Error(e.to_string()));
                return Err(e);
            }
        };
        counters.bytes_in.fetch_add(5 + payload.len() as u64, Ordering::Relaxed);
        counters.requests.fetch_add(1, Ordering::Relaxed);
        let reply = match handler.handle_frame(tag, payload.as_slice()) {
            Ok(r) => r,
            Err(e) => {
                let _ = write_frame(&mut stream, &Message::Error(e.to_string()));
                return Err(e);
            }
        };
        let n = write_reply(&mut stream, &reply, &mut scratch)?;
        counters.bytes_out.fetch_add(n as u64, Ordering::Relaxed);
    }
}
