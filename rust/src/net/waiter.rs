//! Readiness waiter for the reactor: who tells the poll loop a socket is
//! ready, and how cheap is an idle fleet.
//!
//! The reactor (see `net/reactor.rs`) drives per-connection frame state
//! machines; *this* module owns the question "which connections should it
//! look at next".  Three backends, one interface:
//!
//! * **epoll** (Linux) — the kernel event queue.  The loop wakes on
//!   O(ready) events instead of probing O(connections) sockets, so an
//!   idle fleet costs the poll thread ~nothing.  Level-triggered, to
//!   match the state machines' "pump until `WouldBlock`" contract.
//! * **kqueue** (macOS/FreeBSD/OpenBSD/DragonFly) — same shape via
//!   `kevent`.
//! * **sweep** — the portable fallback: every registered token is
//!   reported ready on every wait, reproducing the original polling
//!   sweep (including its 300µs idle park) exactly.  This is what ships
//!   on platforms without an OS event queue, and what
//!   `ELASTIAGG_NO_EPOLL=1` forces everywhere.
//!
//! Registration tracks *interest*, not just membership: read-interest
//! while a connection is collecting header/payload bytes, write-interest
//! only while its reply outbox is non-empty, and **no** interest while a
//! frame is at a worker (implemented as removal from the OS set — a
//! level-triggered queue reports `HUP`/`ERR` regardless of the requested
//! mask, so a dead client with a frame in flight would otherwise spin the
//! loop).  Worker→loop completion notifications ride an eventfd (Linux) /
//! self-pipe (BSD) registered like any other fd, or an atomic flag that
//! skips the sweep's park.
//!
//! [`TimerDriver`] is the time half of the same story: round deadlines,
//! the quorum wait's evict cadence and the async-round cadence all block
//! on one condvar that ingest paths poke, replacing the 2ms sleep-polls
//! that used to live in `server/`.

use std::collections::BTreeMap;
use std::io;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// `ELASTIAGG_NO_EPOLL=1` (any value but `0`/empty) forces the portable
/// sweep backend regardless of platform or configuration.
pub const NO_EPOLL_ENV: &str = "ELASTIAGG_NO_EPOLL";

/// Token the reactor registers its listener under (connection ids count
/// up from zero and can never collide with it).
pub(crate) const TOKEN_LISTENER: u64 = u64::MAX;
/// Token the OS backends register their internal notify fd under; drained
/// inside [`Waiter::wait`], never surfaced to the reactor.
pub(crate) const TOKEN_NOTIFY: u64 = u64::MAX - 1;

/// How long the sweep backend parks when a wait finds the loop idle.
/// Sub-millisecond: idle cost is a few wakeups/ms on one thread; latency
/// cost is bounded by this.  The OS backends do not park — they block in
/// the kernel until something is actually ready.
pub(crate) const IDLE_PARK: Duration = Duration::from_micros(300);

/// Which readiness backend the reactor waits on.  `Auto` picks the OS
/// event queue where one exists and the sweep elsewhere.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WaiterKind {
    /// epoll on Linux, kqueue on macOS/BSD, sweep elsewhere.
    #[default]
    Auto,
    /// The portable polling sweep (the pre-waiter reactor behaviour).
    Sweep,
    /// Linux `epoll` (errors at serve time on other platforms).
    Epoll,
    /// macOS/BSD `kqueue` (errors at serve time on other platforms).
    Kqueue,
}

impl WaiterKind {
    /// Parse a config token; `None` for anything unrecognised (the config
    /// layer keeps its default in that case).
    pub fn parse(s: &str) -> Option<WaiterKind> {
        match s {
            "auto" => Some(WaiterKind::Auto),
            "sweep" => Some(WaiterKind::Sweep),
            "epoll" => Some(WaiterKind::Epoll),
            "kqueue" => Some(WaiterKind::Kqueue),
            _ => None,
        }
    }

    /// The canonical config token for this kind.
    pub fn token(&self) -> &'static str {
        match self {
            WaiterKind::Auto => "auto",
            WaiterKind::Sweep => "sweep",
            WaiterKind::Epoll => "epoll",
            WaiterKind::Kqueue => "kqueue",
        }
    }

    /// Every backend this build can instantiate on this platform.  Used
    /// by the digest-parity tests to replay one scenario over all of
    /// them.  (`ELASTIAGG_NO_EPOLL` downgrades the OS backends to sweep
    /// at construction, so parity under that env var is trivial.)
    pub fn compiled_in() -> &'static [WaiterKind] {
        #[cfg(target_os = "linux")]
        {
            &[WaiterKind::Sweep, WaiterKind::Epoll]
        }
        #[cfg(any(
            target_os = "macos",
            target_os = "freebsd",
            target_os = "openbsd",
            target_os = "dragonfly"
        ))]
        {
            &[WaiterKind::Sweep, WaiterKind::Kqueue]
        }
        #[cfg(not(any(
            target_os = "linux",
            target_os = "macos",
            target_os = "freebsd",
            target_os = "openbsd",
            target_os = "dragonfly"
        )))]
        {
            &[WaiterKind::Sweep]
        }
    }
}

/// `ELASTIAGG_NO_EPOLL` semantics shared with the kernels' `NO_SIMD`
/// escape hatch: set and neither empty nor `"0"`.
fn env_truthy(v: Option<&str>) -> bool {
    v.map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

fn forced_sweep() -> bool {
    env_truthy(std::env::var(NO_EPOLL_ENV).ok().as_deref())
}

/// One readiness report: `token` is whatever the caller registered the
/// fd under.  Error/hangup conditions surface as readable *and* writable
/// so whichever pump runs next observes the failure and reaps the
/// connection.
#[derive(Clone, Copy, Debug)]
pub(crate) struct WaitEvent {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

/// The raw fd of a socket, for waiter registration.  On non-unix targets
/// only the sweep backend exists and the fd is never consulted.
#[cfg(unix)]
pub(crate) fn sock_fd<T: std::os::fd::AsRawFd>(s: &T) -> i32 {
    s.as_raw_fd()
}
#[cfg(not(unix))]
pub(crate) fn sock_fd<T>(_s: &T) -> i32 {
    -1
}

/// A cheap, cloneable handle workers use to wake the poll loop after
/// sending a completion.
#[derive(Clone)]
pub(crate) enum Notifier {
    /// Sweep: skip the next idle park.
    Flag(Arc<AtomicBool>),
    #[cfg(target_os = "linux")]
    Eventfd(Arc<super::waiter_epoll::EventFd>),
    #[cfg(any(
        target_os = "macos",
        target_os = "freebsd",
        target_os = "openbsd",
        target_os = "dragonfly"
    ))]
    Pipe(Arc<super::waiter_kqueue::PipePair>),
}

impl Notifier {
    pub fn notify(&self) {
        match self {
            Notifier::Flag(flag) => flag.store(true, Ordering::Release),
            #[cfg(target_os = "linux")]
            Notifier::Eventfd(fd) => fd.signal(),
            #[cfg(any(
                target_os = "macos",
                target_os = "freebsd",
                target_os = "openbsd",
                target_os = "dragonfly"
            ))]
            Notifier::Pipe(p) => p.signal(),
        }
    }
}

/// The portable fallback: every wait reports every registered token ready
/// per its interest, so the reactor probes exactly what the pre-waiter
/// sweep probed.  `wait` parks [`IDLE_PARK`] when the previous sweep made
/// no progress and no worker poked the flag — the original idle
/// behaviour, bit for bit.
pub(crate) struct SweepWaiter {
    /// token → (read, write) interest.  BTreeMap so the sweep order is
    /// deterministic.
    interest: BTreeMap<u64, (bool, bool)>,
    poked: Arc<AtomicBool>,
}

impl SweepWaiter {
    fn new() -> SweepWaiter {
        SweepWaiter { interest: BTreeMap::new(), poked: Arc::new(AtomicBool::new(false)) }
    }

    fn wait(&mut self, events: &mut Vec<WaitEvent>, timeout: Option<Duration>, idle: bool) {
        if idle && !self.poked.swap(false, Ordering::AcqRel) {
            let nap = timeout.map_or(IDLE_PARK, |t| t.min(IDLE_PARK));
            if !nap.is_zero() {
                std::thread::sleep(nap);
            }
        }
        for (&token, &(read, write)) in &self.interest {
            if read || write {
                events.push(WaitEvent { token, readable: read, writable: write });
            }
        }
    }
}

/// The reactor's readiness source.  Construct with [`Waiter::new`]; the
/// chosen backend is fixed for the server's lifetime and exposed through
/// `ServerHandle::backend_name`.
pub(crate) enum Waiter {
    Sweep(SweepWaiter),
    #[cfg(target_os = "linux")]
    Epoll(super::waiter_epoll::EpollWaiter),
    #[cfg(any(
        target_os = "macos",
        target_os = "freebsd",
        target_os = "openbsd",
        target_os = "dragonfly"
    ))]
    Kqueue(super::waiter_kqueue::KqueueWaiter),
}

impl Waiter {
    /// Instantiate `kind`.  `Auto` resolves to the platform's OS event
    /// queue, falling back to sweep if the kernel refuses (fd pressure)
    /// or `ELASTIAGG_NO_EPOLL` is set; explicitly requesting a backend
    /// the platform lacks is an error (a config typo should not silently
    /// change the measured backend).
    pub fn new(kind: WaiterKind) -> io::Result<Waiter> {
        let kind = if forced_sweep() { WaiterKind::Sweep } else { kind };
        match kind {
            WaiterKind::Sweep => Ok(Waiter::Sweep(SweepWaiter::new())),
            WaiterKind::Auto => {
                #[cfg(target_os = "linux")]
                {
                    return Ok(match super::waiter_epoll::EpollWaiter::new() {
                        Ok(w) => Waiter::Epoll(w),
                        Err(_) => Waiter::Sweep(SweepWaiter::new()),
                    });
                }
                #[cfg(any(
                    target_os = "macos",
                    target_os = "freebsd",
                    target_os = "openbsd",
                    target_os = "dragonfly"
                ))]
                {
                    return Ok(match super::waiter_kqueue::KqueueWaiter::new() {
                        Ok(w) => Waiter::Kqueue(w),
                        Err(_) => Waiter::Sweep(SweepWaiter::new()),
                    });
                }
                #[allow(unreachable_code)]
                Ok(Waiter::Sweep(SweepWaiter::new()))
            }
            WaiterKind::Epoll => {
                #[cfg(target_os = "linux")]
                {
                    return super::waiter_epoll::EpollWaiter::new().map(Waiter::Epoll);
                }
                #[allow(unreachable_code)]
                Err(io::Error::new(
                    io::ErrorKind::Unsupported,
                    "epoll waiter requires Linux",
                ))
            }
            WaiterKind::Kqueue => {
                #[cfg(any(
                    target_os = "macos",
                    target_os = "freebsd",
                    target_os = "openbsd",
                    target_os = "dragonfly"
                ))]
                {
                    return super::waiter_kqueue::KqueueWaiter::new().map(Waiter::Kqueue);
                }
                #[allow(unreachable_code)]
                Err(io::Error::new(
                    io::ErrorKind::Unsupported,
                    "kqueue waiter requires macOS/BSD",
                ))
            }
        }
    }

    /// Which backend actually runs (after `Auto`/env resolution).
    pub fn backend_name(&self) -> &'static str {
        match self {
            Waiter::Sweep(_) => "sweep",
            #[cfg(target_os = "linux")]
            Waiter::Epoll(_) => "epoll",
            #[cfg(any(
                target_os = "macos",
                target_os = "freebsd",
                target_os = "openbsd",
                target_os = "dragonfly"
            ))]
            Waiter::Kqueue(_) => "kqueue",
        }
    }

    /// A handle for worker threads to wake the poll loop.
    pub fn notifier(&self) -> Notifier {
        match self {
            Waiter::Sweep(s) => Notifier::Flag(s.poked.clone()),
            #[cfg(target_os = "linux")]
            Waiter::Epoll(e) => Notifier::Eventfd(e.notifier()),
            #[cfg(any(
                target_os = "macos",
                target_os = "freebsd",
                target_os = "openbsd",
                target_os = "dragonfly"
            ))]
            Waiter::Kqueue(k) => Notifier::Pipe(k.notifier()),
        }
    }

    /// Start watching `fd` under `token` with the given interest.
    pub fn register(&mut self, fd: i32, token: u64, read: bool, write: bool) -> io::Result<()> {
        self.set_interest(fd, token, read, write)
    }

    /// Change an already-registered fd's interest.  `(false, false)`
    /// removes it from the OS set (see the module docs on `HUP`).
    pub fn modify(&mut self, fd: i32, token: u64, read: bool, write: bool) -> io::Result<()> {
        self.set_interest(fd, token, read, write)
    }

    fn set_interest(&mut self, fd: i32, token: u64, read: bool, write: bool) -> io::Result<()> {
        match self {
            Waiter::Sweep(s) => {
                if read || write {
                    s.interest.insert(token, (read, write));
                } else {
                    s.interest.remove(&token);
                }
                Ok(())
            }
            #[cfg(target_os = "linux")]
            Waiter::Epoll(e) => e.set_interest(fd, token, read, write),
            #[cfg(any(
                target_os = "macos",
                target_os = "freebsd",
                target_os = "openbsd",
                target_os = "dragonfly"
            ))]
            Waiter::Kqueue(k) => k.set_interest(fd, token, read, write),
        }
    }

    /// Stop watching `fd` entirely (connection reaped).
    pub fn deregister(&mut self, fd: i32, token: u64) {
        match self {
            Waiter::Sweep(s) => {
                s.interest.remove(&token);
            }
            #[cfg(target_os = "linux")]
            Waiter::Epoll(e) => e.deregister(fd, token),
            #[cfg(any(
                target_os = "macos",
                target_os = "freebsd",
                target_os = "openbsd",
                target_os = "dragonfly"
            ))]
            Waiter::Kqueue(k) => k.deregister(fd, token),
        }
    }

    /// Block until something is ready (or `timeout`), appending readiness
    /// reports to `events`.  `idle` tells the sweep backend the previous
    /// iteration made no progress (its cue to park); the OS backends
    /// ignore it — they block in the kernel either way.  `EINTR` returns
    /// an empty event set, not an error.
    pub fn wait(
        &mut self,
        events: &mut Vec<WaitEvent>,
        timeout: Option<Duration>,
        idle: bool,
    ) -> io::Result<()> {
        match self {
            Waiter::Sweep(s) => {
                s.wait(events, timeout, idle);
                Ok(())
            }
            #[cfg(target_os = "linux")]
            Waiter::Epoll(e) => e.wait(events, timeout),
            #[cfg(any(
                target_os = "macos",
                target_os = "freebsd",
                target_os = "openbsd",
                target_os = "dragonfly"
            ))]
            Waiter::Kqueue(k) => k.wait(events, timeout),
        }
    }
}

/// One condvar for every time-driven duty in the round drivers: round
/// deadlines, the quorum wait's evict cadence, the async-round publish
/// cadence.  Ingest paths [`notify`](TimerDriver::notify) it; waiters
/// capture the [`generation`](TimerDriver::generation) *before* checking
/// their predicate and then [`wait_until`](TimerDriver::wait_until) a
/// deadline, so a notify that lands between the check and the wait is
/// never lost.  This replaces the 2ms `sleep` polls the round drivers
/// used to spin on.
#[derive(Debug, Default)]
pub struct TimerDriver {
    generation: Mutex<u64>,
    cv: Condvar,
}

impl TimerDriver {
    pub fn new() -> TimerDriver {
        TimerDriver::default()
    }

    /// The current notify generation.  Capture it BEFORE checking the
    /// condition you are about to wait on.
    pub fn generation(&self) -> u64 {
        *self.generation.lock().unwrap()
    }

    /// Wake every waiter (something observable changed: an update was
    /// ingested, a buffer filled, a party was admitted).
    pub fn notify(&self) {
        let mut gen = self.generation.lock().unwrap();
        *gen = gen.wrapping_add(1);
        self.cv.notify_all();
    }

    /// Block until `deadline` passes or the generation moves past `seen`.
    /// Returns `true` when woken by a notify, `false` on deadline.
    pub fn wait_until(&self, deadline: Instant, seen: u64) -> bool {
        let mut gen = self.generation.lock().unwrap();
        while *gen == seen {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self.cv.wait_timeout(gen, deadline - now).unwrap();
            gen = guard;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_tokens_roundtrip() {
        for kind in
            [WaiterKind::Auto, WaiterKind::Sweep, WaiterKind::Epoll, WaiterKind::Kqueue]
        {
            assert_eq!(WaiterKind::parse(kind.token()), Some(kind));
        }
        assert_eq!(WaiterKind::parse("select"), None);
        assert_eq!(WaiterKind::parse(""), None);
    }

    #[test]
    fn compiled_in_always_includes_sweep() {
        let kinds = WaiterKind::compiled_in();
        assert!(kinds.contains(&WaiterKind::Sweep));
        assert!(!kinds.contains(&WaiterKind::Auto), "Auto is a request, not a backend");
        #[cfg(target_os = "linux")]
        assert!(kinds.contains(&WaiterKind::Epoll));
    }

    #[test]
    fn env_gate_parses_like_no_simd() {
        assert!(!env_truthy(None));
        assert!(!env_truthy(Some("")));
        assert!(!env_truthy(Some("0")));
        assert!(env_truthy(Some("1")));
        assert!(env_truthy(Some("yes")));
    }

    #[test]
    fn sweep_reports_interest_and_forgets_deregistered_tokens() {
        let mut w = Waiter::new(WaiterKind::Sweep).unwrap();
        assert_eq!(w.backend_name(), "sweep");
        w.register(-1, 7, true, false).unwrap();
        w.register(-1, 9, false, true).unwrap();
        w.register(-1, 11, false, false).unwrap(); // no interest: invisible
        let mut events = Vec::new();
        w.wait(&mut events, Some(Duration::ZERO), false).unwrap();
        let mut seen: Vec<(u64, bool, bool)> =
            events.iter().map(|e| (e.token, e.readable, e.writable)).collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![(7, true, false), (9, false, true)]);

        w.modify(-1, 7, false, false).unwrap(); // interest withdrawn
        w.deregister(-1, 9);
        events.clear();
        w.wait(&mut events, Some(Duration::ZERO), false).unwrap();
        assert!(events.is_empty());
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_waiter_reports_listener_readiness() {
        use std::io::Write;
        use std::net::{TcpListener, TcpStream};

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();
        let mut w = match Waiter::new(WaiterKind::Epoll) {
            Ok(w) => w,
            // ELASTIAGG_NO_EPOLL in the environment downgrades to sweep;
            // the parity tests cover that configuration.
            Err(_) => return,
        };
        if w.backend_name() != "epoll" {
            return;
        }
        w.register(sock_fd(&listener), TOKEN_LISTENER, true, false).unwrap();

        // Nothing pending: the wait times out with no events.
        let mut events = Vec::new();
        w.wait(&mut events, Some(Duration::from_millis(10)), false).unwrap();
        assert!(events.is_empty(), "idle listener produced {events:?}");

        // A pending connection: the listener token turns readable.
        let mut client = TcpStream::connect(addr).unwrap();
        client.write_all(&[0u8]).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut readable = false;
        while Instant::now() < deadline && !readable {
            events.clear();
            w.wait(&mut events, Some(Duration::from_millis(50)), false).unwrap();
            readable = events.iter().any(|e| e.token == TOKEN_LISTENER && e.readable);
        }
        assert!(readable, "pending accept never surfaced through epoll");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_notifier_wakes_a_blocked_wait() {
        let mut w = match Waiter::new(WaiterKind::Epoll) {
            Ok(w) => w,
            Err(_) => return,
        };
        if w.backend_name() != "epoll" {
            return;
        }
        let notifier = w.notifier();
        let poker = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            notifier.notify();
        });
        let t0 = Instant::now();
        let mut events = Vec::new();
        // Block far past the poke: the eventfd must cut the wait short.
        w.wait(&mut events, Some(Duration::from_secs(10)), false).unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "notify did not wake the epoll wait"
        );
        assert!(events.is_empty(), "the notify token leaked to the caller: {events:?}");
        poker.join().unwrap();
    }

    #[test]
    fn timer_driver_notify_wakes_before_deadline() {
        let timer = Arc::new(TimerDriver::new());
        let gen = timer.generation();
        let poker = {
            let timer = timer.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(30));
                timer.notify();
            })
        };
        let t0 = Instant::now();
        let woken = timer.wait_until(Instant::now() + Duration::from_secs(10), gen);
        assert!(woken, "notify must report as a wake, not a timeout");
        assert!(t0.elapsed() < Duration::from_secs(5));
        poker.join().unwrap();
    }

    #[test]
    fn timer_driver_times_out_without_notify() {
        let timer = TimerDriver::new();
        let gen = timer.generation();
        let deadline = Instant::now() + Duration::from_millis(20);
        assert!(!timer.wait_until(deadline, gen));
        assert!(Instant::now() >= deadline);
    }

    #[test]
    fn timer_driver_never_loses_a_notify_between_check_and_wait() {
        // The protocol: capture generation, THEN check the predicate, THEN
        // wait.  A notify that lands after the capture must wake the wait
        // immediately even though it fired "before" wait_until ran.
        let timer = TimerDriver::new();
        let gen = timer.generation();
        timer.notify(); // lands between capture and wait
        let t0 = Instant::now();
        assert!(timer.wait_until(Instant::now() + Duration::from_secs(10), gen));
        assert!(t0.elapsed() < Duration::from_secs(1), "stale-generation wake was lost");
    }
}
