//! Readiness-polling reactor: ONE poll thread drives every connection's
//! frame state machine over nonblocking sockets; decoded frames are handed
//! to a bounded worker pool so fold work never blocks the event loop.
//!
//! This replaces the thread-per-connection server: OS threads are now
//! `1 (reactor) + workers`, independent of how many sockets are connected —
//! the property `fig_connection_scaling` pins.  *Which* sockets the loop
//! looks at each iteration is owned by a [`Waiter`]: epoll on Linux and
//! kqueue on macOS/BSD wake the loop on O(ready) events (an idle fleet
//! costs the poll thread ~nothing — the second property
//! `fig_connection_scaling` pins), while the portable sweep fallback
//! reproduces the original "probe every socket, park 300µs when idle"
//! behaviour on everything else (`ELASTIAGG_NO_EPOLL=1` forces it).
//! Interest follows the state machine: read while collecting a frame,
//! write only while a reply is queued, nothing while a frame is at a
//! worker — so a `WouldBlock` on a model reply waits for the kernel's
//! write-ready event instead of the next full sweep.
//!
//! Per-connection state machine (`ReadState`):
//!
//! ```text
//!            header bytes                payload bytes
//! Header{got} ───────────► Payload{tag,got} ───────────► Dispatched
//!    ▲                                                        │ job → worker
//!    │                reply fully flushed                     ▼
//!    └──────────────────── (Outbox drained) ◄───────── worker Done{reply}
//! ```
//!
//! Reads pause while a frame is `Dispatched` and resume only after its
//! reply is flushed, preserving the old server's strict request→reply
//! ordering per connection.  Payload bytes land in the connection's pooled
//! 4-aligned [`FrameBuf`]; the buffer MOVES into the worker's job and moves
//! back with the completion, so the zero-copy upload decode (and the pool)
//! survive the handoff.  Model replies keep the gather-write shape: a
//! 9-byte header plus the published `Arc<Vec<f32>>` viewed as bytes,
//! never cloned.
//!
//! Lifecycle invariants (the three bugs this file exists to close out):
//! a connection is TRACKED (in `conns`, counted in `active`) before any of
//! its bytes are served, or it is refused outright — there is no untracked
//! path; there are no per-connection threads, so there is no join handle
//! to lose; and EOF mid-frame is counted into `aborted_frames` instead of
//! being mistaken for a clean hangup.

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use super::protocol::{self, MAX_FRAME};
use super::server::{Counters, Handler};
use super::waiter::{sock_fd, WaitEvent, Waiter, WaiterKind, TOKEN_LISTENER};
use super::{FrameBuf, Message, ProtoError, Reply};
use crate::tensorstore::f32s_as_bytes;

/// Safety-net cap on a single kernel wait: `stop()` pokes the listener to
/// wake the loop, so this bound only matters if that poke is ever lost —
/// it turns "hung forever" into "stops within half a second".
const WAIT_CAP: Duration = Duration::from_millis(500);

/// The reactor thread's name — short enough to survive the kernel's
/// 15-byte comm truncation, so tests and benches can find this exact
/// thread in `/proc/self/task/*/stat` and meter its CPU time.
pub const REACTOR_THREAD_NAME: &str = "ela-reactor";

/// Test failpoint: refuse the next N admissions on a specific listener
/// (the "cannot track this connection" path — the production analogues
/// are `set_nonblocking` / `set_nodelay` failures).  Regression pin for
/// the untracked-connection leak: a refused connection must be shut down,
/// never served.
#[cfg(test)]
pub(crate) static REFUSE_ADMITS: super::server::Failpoint = super::server::Failpoint::new();

/// A fully received frame on its way to the worker pool.  The pooled
/// payload buffer travels WITH the job and returns in the [`Done`].
struct Job {
    conn: u64,
    tag: u8,
    buf: FrameBuf,
}

/// A worker's completion: the reply to queue and the connection's pooled
/// buffer coming home.
struct Done {
    conn: u64,
    buf: FrameBuf,
    reply: Result<Reply, ProtoError>,
}

/// Where one connection is in its current frame.
#[derive(Clone, Copy)]
enum ReadState {
    /// Collecting the 5-byte `tag | len` header.
    Header { got: usize, head: [u8; 5] },
    /// Collecting `len` payload bytes into the pooled buffer.
    Payload { tag: u8, got: usize },
    /// Frame handed to a worker; reads paused until the reply is flushed.
    Dispatched,
}

/// A reply mid-write: encoded header/frame bytes, plus the shared model
/// body for the gather-write path (`Reply::Model` — the weights go from
/// the published `Arc` to the socket without a clone).
struct Outbox {
    head: Vec<u8>,
    head_off: usize,
    body: Option<Arc<Vec<f32>>>,
    body_off: usize,
}

fn wants_retry(kind: ErrorKind) -> bool {
    kind == ErrorKind::Interrupted
}

/// What one read sweep of a connection produced.
enum ReadOutcome {
    Idle,
    Progress,
    /// A whole frame arrived (tag); caller dispatches it.
    Dispatch(u8),
    /// Peer gone (clean or aborted — `aborted_frames` already counted).
    Closed,
}

struct Conn {
    stream: std::net::TcpStream,
    read: ReadState,
    /// Pooled 4-aligned payload buffer, reused across this connection's
    /// frames; moves into the worker job at dispatch and back at
    /// completion.
    buf: FrameBuf,
    out: Option<Outbox>,
    /// Recycled encode scratch: the last flushed Outbox's head Vec comes
    /// back here so steady-state replies allocate nothing.
    scratch: Vec<u8>,
    close_after_write: bool,
    /// The (read, write) interest currently registered with the waiter;
    /// compared against [`desired_interest`] after every touch so the OS
    /// set sees one syscall per actual transition, not per sweep.
    interest: (bool, bool),
}

impl Conn {
    fn new(stream: std::net::TcpStream) -> Conn {
        Conn {
            stream,
            read: ReadState::Header { got: 0, head: [0; 5] },
            buf: FrameBuf::new(),
            out: None,
            scratch: Vec::new(),
            close_after_write: false,
            interest: (true, false),
        }
    }

    /// Queue an encoded-message reply frame.
    fn queue_msg(&mut self, m: &Message) {
        let mut head = std::mem::take(&mut self.scratch);
        match m.encode_into(&mut head) {
            Ok(()) => {
                self.out = Some(Outbox { head, head_off: 0, body: None, body_off: 0 });
            }
            Err(_) => {
                // Reply too large to frame: nothing recoverable to send.
                self.out = None;
                self.close_after_write = true;
            }
        }
    }

    /// Queue a worker's completion for the wire.
    fn queue_reply(&mut self, reply: Result<Reply, ProtoError>) {
        match reply {
            Ok(Reply::Msg(m)) => self.queue_msg(&m),
            Ok(Reply::Model { round, weights }) => {
                let body_bytes = weights.len() * 4;
                match protocol::checked_frame_len(4 + body_bytes) {
                    Ok(len) => {
                        let mut head = std::mem::take(&mut self.scratch);
                        head.clear();
                        head.push(protocol::TAG_MODEL);
                        head.extend_from_slice(&len.to_le_bytes());
                        head.extend_from_slice(&round.to_le_bytes());
                        self.out = Some(Outbox {
                            head,
                            head_off: 0,
                            body: Some(weights),
                            body_off: 0,
                        });
                    }
                    Err(e) => {
                        self.queue_msg(&Message::Error(e.to_string()));
                        self.close_after_write = true;
                    }
                }
            }
            Err(e) => {
                // Handler error: tell the client, then close (the old
                // server's write-error-frame-then-drop behaviour).
                self.queue_msg(&Message::Error(e.to_string()));
                self.close_after_write = true;
            }
        }
    }

    /// Flush as much of the queued reply as the socket accepts.
    /// `Ok(progressed)`; `Err(())` means close this connection.
    fn pump_write(&mut self, counters: &Counters) -> Result<bool, ()> {
        let Some(out) = self.out.as_mut() else {
            return if self.close_after_write { Err(()) } else { Ok(false) };
        };
        let mut progressed = false;
        while out.head_off < out.head.len() {
            match self.stream.write(&out.head[out.head_off..]) {
                Ok(0) => return Err(()),
                Ok(n) => {
                    out.head_off += n;
                    progressed = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(progressed),
                Err(e) if wants_retry(e.kind()) => continue,
                Err(_) => return Err(()),
            }
        }
        if let Some(body) = &out.body {
            let bytes = f32s_as_bytes(body);
            while out.body_off < bytes.len() {
                match self.stream.write(&bytes[out.body_off..]) {
                    Ok(0) => return Err(()),
                    Ok(n) => {
                        out.body_off += n;
                        progressed = true;
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(progressed),
                    Err(e) if wants_retry(e.kind()) => continue,
                    Err(_) => return Err(()),
                }
            }
        }
        // Fully flushed: count it, recycle the encode buffer, resume reads.
        let total = out.head.len() + out.body.as_ref().map_or(0, |b| b.len() * 4);
        counters.bytes_out.fetch_add(total as u64, Ordering::Relaxed);
        let mut head = self.out.take().expect("outbox present").head;
        head.clear();
        self.scratch = head;
        if self.close_after_write {
            return Err(());
        }
        self.read = ReadState::Header { got: 0, head: [0; 5] };
        Ok(true)
    }

    /// Advance the frame state machine with whatever bytes are ready.
    fn pump_read(&mut self, counters: &Counters) -> ReadOutcome {
        let mut progressed = false;
        loop {
            match self.read {
                ReadState::Dispatched => {
                    return if progressed { ReadOutcome::Progress } else { ReadOutcome::Idle }
                }
                ReadState::Header { got, head } => {
                    let mut head = head;
                    match self.stream.read(&mut head[got..]) {
                        Ok(0) => {
                            if got > 0 {
                                // died inside a frame header
                                counters.aborted_frames.fetch_add(1, Ordering::Relaxed);
                            }
                            return ReadOutcome::Closed;
                        }
                        Ok(n) => {
                            progressed = true;
                            let got = got + n;
                            if got < head.len() {
                                self.read = ReadState::Header { got, head };
                                continue;
                            }
                            let tag = head[0];
                            let len =
                                u32::from_le_bytes(head[1..5].try_into().unwrap()) as usize;
                            if len > MAX_FRAME {
                                // Protocol violation: typed error, then
                                // close — same as the old server.
                                self.queue_msg(&Message::Error(
                                    ProtoError::FrameTooLarge(len).to_string(),
                                ));
                                self.close_after_write = true;
                                self.read = ReadState::Dispatched;
                                return ReadOutcome::Progress;
                            }
                            self.buf.reset(len);
                            if len == 0 {
                                return ReadOutcome::Dispatch(tag);
                            }
                            self.read = ReadState::Payload { tag, got: 0 };
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            return if progressed {
                                ReadOutcome::Progress
                            } else {
                                ReadOutcome::Idle
                            }
                        }
                        Err(e) if wants_retry(e.kind()) => continue,
                        Err(_) => {
                            if got > 0 {
                                counters.aborted_frames.fetch_add(1, Ordering::Relaxed);
                            }
                            return ReadOutcome::Closed;
                        }
                    }
                }
                ReadState::Payload { tag, got } => {
                    let len = self.buf.len();
                    match self.stream.read(&mut self.buf.as_mut_slice()[got..]) {
                        Ok(0) => {
                            // died mid-payload: a truncated frame, NOT a
                            // clean hangup
                            counters.aborted_frames.fetch_add(1, Ordering::Relaxed);
                            return ReadOutcome::Closed;
                        }
                        Ok(n) => {
                            progressed = true;
                            let got = got + n;
                            if got == len {
                                return ReadOutcome::Dispatch(tag);
                            }
                            self.read = ReadState::Payload { tag, got };
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            return if progressed {
                                ReadOutcome::Progress
                            } else {
                                ReadOutcome::Idle
                            }
                        }
                        Err(e) if wants_retry(e.kind()) => continue,
                        Err(_) => {
                            counters.aborted_frames.fetch_add(1, Ordering::Relaxed);
                            return ReadOutcome::Closed;
                        }
                    }
                }
            }
        }
    }
}

/// Where the state machine says the waiter should look next.  Write
/// while a reply is queued (reads stay paused), read while collecting a
/// frame, NOTHING while the frame is at a worker — the connection leaves
/// the OS set entirely until its reply comes back (see `net/waiter.rs` on
/// why level-triggered `HUP` makes "empty mask" insufficient).
fn desired_interest(conn: &Conn) -> (bool, bool) {
    if conn.out.is_some() {
        (false, true)
    } else if conn.close_after_write {
        (false, false)
    } else {
        (!matches!(conn.read, ReadState::Dispatched), false)
    }
}

/// The running reactor's threads and gauges, held by `ServerHandle`.
pub(crate) struct Parts {
    pub reactor: std::thread::JoinHandle<()>,
    pub workers: Vec<std::thread::JoinHandle<()>>,
    /// Connections currently tracked by the poll loop.
    pub active: Arc<AtomicUsize>,
    /// Worker threads currently alive (0 after a completed `stop`).
    pub live_workers: Arc<AtomicUsize>,
    /// Which waiter backend the poll loop runs on ("epoll", "kqueue",
    /// "sweep"), after `Auto`/env resolution.
    pub backend: &'static str,
}

/// Spawn the poll loop plus `workers` fold threads over a bound listener.
pub(crate) fn spawn<H: Handler>(
    listener: TcpListener,
    handler: Arc<H>,
    workers: usize,
    waiter_kind: WaiterKind,
    counters: Counters,
    stop: Arc<AtomicBool>,
) -> std::io::Result<Parts> {
    listener.set_nonblocking(true)?;
    let mut waiter = Waiter::new(waiter_kind)?;
    let backend = waiter.backend_name();
    let notifier = waiter.notifier();
    #[cfg(test)]
    let local = listener.local_addr().map(|a| a.to_string()).unwrap_or_default();
    let active = Arc::new(AtomicUsize::new(0));
    let live_workers = Arc::new(AtomicUsize::new(0));

    let (job_tx, job_rx) = mpsc::channel::<Job>();
    let job_rx = Arc::new(Mutex::new(job_rx));
    let (done_tx, done_rx) = mpsc::channel::<Done>();

    let mut worker_handles = Vec::with_capacity(workers.max(1));
    for _ in 0..workers.max(1) {
        let rx = job_rx.clone();
        let tx = done_tx.clone();
        let handler = handler.clone();
        let live = live_workers.clone();
        let notifier = notifier.clone();
        live.fetch_add(1, Ordering::AcqRel);
        worker_handles.push(std::thread::spawn(move || {
            loop {
                // Hold the receiver lock only for the blocking recv — the
                // handler runs outside it, so workers fold in parallel.
                let job = match rx.lock().unwrap().recv() {
                    Ok(j) => j,
                    Err(_) => break, // reactor gone and queue drained
                };
                let reply = handler.handle_frame(job.tag, job.buf.as_slice());
                if tx.send(Done { conn: job.conn, buf: job.buf, reply }).is_err() {
                    break; // reactor gone: reply has nowhere to go
                }
                // Wake the poll loop: a completion is waiting on done_rx.
                notifier.notify();
            }
            live.fetch_sub(1, Ordering::AcqRel);
        }));
    }
    drop(done_tx); // only worker clones remain

    let reactor = {
        let active = active.clone();
        std::thread::Builder::new()
            .name(REACTOR_THREAD_NAME.into())
            .spawn(move || {
                let mut conns: HashMap<u64, Conn> = HashMap::new();
                let mut next_id = 0u64;
                let mut dead: Vec<u64> = Vec::new();
                let mut events: Vec<WaitEvent> = Vec::new();
                let mut touched: Vec<u64> = Vec::new();
                if waiter.register(sock_fd(&listener), TOKEN_LISTENER, true, false).is_err() {
                    // A listener the waiter cannot watch serves nothing:
                    // bail out — dropping job_tx lets the workers drain
                    // and exit, and stop() still joins everything.
                    return;
                }
                let mut idle = false;
                while !stop.load(Ordering::Acquire) {
                    events.clear();
                    if waiter.wait(&mut events, Some(WAIT_CAP), idle).is_err() {
                        // Kernel queue gone bad (EBADF after fd exhaustion,
                        // …): nothing useful left to wait on.
                        break;
                    }
                    let mut progress = false;

                    // 1) worker completions: reply queued, pooled buffer
                    //    home, flush attempted immediately (the socket is
                    //    almost always writable here — no extra wait).
                    while let Ok(done) = done_rx.try_recv() {
                        progress = true;
                        if let Some(conn) = conns.get_mut(&done.conn) {
                            conn.buf = done.buf;
                            conn.queue_reply(done.reply);
                            match conn.pump_write(&counters) {
                                Ok(_) => touched.push(done.conn),
                                Err(()) => dead.push(done.conn),
                            }
                        }
                    }

                    // 2) readiness events
                    for ev in events.drain(..) {
                        if ev.token == TOKEN_LISTENER {
                            // admit new connections (track-or-refuse: a
                            // connection the loop cannot poll is shut
                            // down, never served)
                            loop {
                                match listener.accept() {
                                    Ok((stream, _)) => {
                                        progress = true;
                                        #[cfg(test)]
                                        if REFUSE_ADMITS.take(&local) {
                                            let _ = stream.shutdown(Shutdown::Both);
                                            continue;
                                        }
                                        if stream.set_nonblocking(true).is_err()
                                            || stream.set_nodelay(true).is_err()
                                        {
                                            let _ = stream.shutdown(Shutdown::Both);
                                            continue;
                                        }
                                        let fd = sock_fd(&stream);
                                        if waiter
                                            .register(fd, next_id, true, false)
                                            .is_err()
                                        {
                                            let _ = stream.shutdown(Shutdown::Both);
                                            continue;
                                        }
                                        counters.connections.fetch_add(1, Ordering::Relaxed);
                                        active.fetch_add(1, Ordering::AcqRel);
                                        conns.insert(next_id, Conn::new(stream));
                                        next_id += 1;
                                    }
                                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                                    Err(e) if wants_retry(e.kind()) => continue,
                                    Err(_) => break,
                                }
                            }
                            continue;
                        }
                        let id = ev.token;
                        let Some(conn) = conns.get_mut(&id) else {
                            continue; // reaped earlier this iteration
                        };
                        if ev.writable {
                            match conn.pump_write(&counters) {
                                Ok(p) => progress |= p,
                                Err(()) => {
                                    dead.push(id);
                                    continue;
                                }
                            }
                        }
                        if ev.readable && conn.out.is_none() && !conn.close_after_write {
                            match conn.pump_read(&counters) {
                                ReadOutcome::Idle => {}
                                ReadOutcome::Progress => progress = true,
                                ReadOutcome::Dispatch(tag) => {
                                    progress = true;
                                    conn.read = ReadState::Dispatched;
                                    let buf = std::mem::take(&mut conn.buf);
                                    counters
                                        .bytes_in
                                        .fetch_add(5 + buf.len() as u64, Ordering::Relaxed);
                                    counters.requests.fetch_add(1, Ordering::Relaxed);
                                    if job_tx.send(Job { conn: id, tag, buf }).is_err() {
                                        dead.push(id);
                                        continue;
                                    }
                                }
                                ReadOutcome::Closed => {
                                    dead.push(id);
                                    continue;
                                }
                            }
                        }
                        touched.push(id);
                    }

                    // 3) re-register interest where the state machine
                    //    moved (one syscall per transition, none per
                    //    steady-state event)
                    for id in touched.drain(..) {
                        if dead.contains(&id) {
                            continue;
                        }
                        if let Some(conn) = conns.get_mut(&id) {
                            let want = desired_interest(conn);
                            if want != conn.interest {
                                let fd = sock_fd(&conn.stream);
                                if waiter.modify(fd, id, want.0, want.1).is_err() {
                                    dead.push(id);
                                } else {
                                    conn.interest = want;
                                }
                            }
                        }
                    }

                    // 4) reap
                    for id in dead.drain(..) {
                        if let Some(conn) = conns.remove(&id) {
                            waiter.deregister(sock_fd(&conn.stream), id);
                            let _ = conn.stream.shutdown(Shutdown::Both);
                            active.fetch_sub(1, Ordering::AcqRel);
                        }
                    }

                    idle = !progress;
                }
                // Stop: shut every tracked socket down.  Dropping `job_tx`
                // (with this closure) disconnects the job channel; workers
                // drain whatever was queued, then exit — `stop()` joins
                // them, so no fold thread outlives the handle.
                for (_, conn) in conns.drain() {
                    let _ = conn.stream.shutdown(Shutdown::Both);
                    active.fetch_sub(1, Ordering::AcqRel);
                }
            })
            .expect("spawn reactor thread")
    };

    Ok(Parts { reactor, workers: worker_handles, active, live_workers, backend })
}
