//! Threaded TCP server: one handler thread per connection (the aggregator
//! is the paper's bottleneck under the thundering herd; per-connection
//! threads make the contention measurable rather than hiding it behind a
//! queue).

use std::collections::HashMap;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::{read_frame_into, write_frame, write_reply, FrameBuf, Message, ProtoError, Reply};

/// Live per-connection state: a clone of the socket (so `stop` can shut a
/// blocked read down) plus the handler thread's join handle.  A handler
/// removes its own entry when its connection ends, so the map holds only
/// connections that are actually alive.
type ConnMap = Mutex<HashMap<u64, (TcpStream, Option<std::thread::JoinHandle<()>>)>>;

/// Application hook: map a request message to a reply.
pub trait Handler: Send + Sync + 'static {
    fn handle(&self, msg: Message) -> Message;

    /// Zero-copy hook: map a raw frame (already length-checked, payload in
    /// the connection's 4-aligned pool) to a reply.  The default decodes an
    /// owned [`Message`] and delegates to [`Handler::handle`]; the FL
    /// server overrides it to fold uploads straight out of the wire buffer
    /// and to frame model replies from the published `Arc` without cloning.
    fn handle_frame(&self, tag: u8, payload: &[u8]) -> Result<Reply, ProtoError> {
        Ok(Reply::Msg(self.handle(Message::decode(tag, payload)?)))
    }
}

impl<F> Handler for F
where
    F: Fn(Message) -> Message + Send + Sync + 'static,
{
    fn handle(&self, msg: Message) -> Message {
        self(msg)
    }
}

/// Running server; dropping the handle shuts the listener down.
pub struct ServerHandle {
    addr: String,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    /// Live connections: socket clone + handler join handle, drained by
    /// [`ServerHandle::stop`] so no handler thread outlives the handle.
    live: Arc<ConnMap>,
    pub connections: Arc<AtomicU64>,
    pub requests: Arc<AtomicU64>,
    /// Frame bytes read off all connections (headers + payloads) — the
    /// real ingest volume the planner's arrival-span term models.
    pub bytes_in: Arc<AtomicU64>,
    /// Frame bytes written as replies.
    pub bytes_out: Arc<AtomicU64>,
}

impl ServerHandle {
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Connections with a live handler thread right now.
    pub fn active_connections(&self) -> usize {
        self.live.lock().unwrap().len()
    }

    /// Shut the server down COMPLETELY: stop accepting, then shut every
    /// live connection's stream down (unblocking handlers parked in
    /// `read`) and join their threads.  Historically only the accept
    /// thread was joined — per-connection handlers were detached and could
    /// outlive the drop of this handle, folding into rounds whose owner
    /// believed the server gone.  On return, no handler thread survives.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        // Poke the listener so accept() returns.
        let _ = TcpStream::connect(&self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // Drain the live connections OUTSIDE the lock: a handler that ends
        // normally takes the same lock to remove itself, so joining while
        // holding it would deadlock.
        let drained: Vec<(TcpStream, Option<std::thread::JoinHandle<()>>)> = {
            let mut map = self.live.lock().unwrap();
            map.drain().map(|(_, v)| v).collect()
        };
        for (stream, _) in &drained {
            let _ = stream.shutdown(Shutdown::Both);
        }
        for (_, handle) in drained {
            if let Some(h) = handle {
                let _ = h.join();
            }
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

pub struct NetServer;

impl NetServer {
    /// Bind `addr` (use port 0 for ephemeral) and serve `handler`.
    pub fn serve<H: Handler>(addr: &str, handler: Arc<H>) -> std::io::Result<ServerHandle> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?.to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let live: Arc<ConnMap> = Arc::new(Mutex::new(HashMap::new()));
        let connections = Arc::new(AtomicU64::new(0));
        let requests = Arc::new(AtomicU64::new(0));
        let bytes_in = Arc::new(AtomicU64::new(0));
        let bytes_out = Arc::new(AtomicU64::new(0));

        let accept_thread = {
            let stop = stop.clone();
            let live = live.clone();
            let connections = connections.clone();
            let requests = requests.clone();
            let bytes_in = bytes_in.clone();
            let bytes_out = bytes_out.clone();
            std::thread::spawn(move || {
                let mut next_id = 0u64;
                for stream in listener.incoming() {
                    if stop.load(Ordering::Acquire) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    connections.fetch_add(1, Ordering::Relaxed);
                    let id = next_id;
                    next_id += 1;
                    // Register the socket clone BEFORE the handler runs so
                    // `stop` can always unblock it; the handler removes the
                    // entry itself when the connection ends normally.
                    let tracked = match stream.try_clone() {
                        Ok(peer) => {
                            live.lock().unwrap().insert(id, (peer, None));
                            true
                        }
                        Err(_) => false,
                    };
                    let handler = handler.clone();
                    let live2 = live.clone();
                    let requests = requests.clone();
                    let bytes_in = bytes_in.clone();
                    let bytes_out = bytes_out.clone();
                    let join = std::thread::spawn(move || {
                        let _ = Self::handle_conn(stream, handler, requests, bytes_in, bytes_out);
                        if tracked {
                            live2.lock().unwrap().remove(&id);
                        }
                    });
                    // Attach the join handle unless the handler already
                    // finished (and removed the entry) — then it detaches.
                    if tracked {
                        if let Some(entry) = live.lock().unwrap().get_mut(&id) {
                            entry.1 = Some(join);
                        }
                    }
                }
            })
        };

        Ok(ServerHandle {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
            live,
            connections,
            requests,
            bytes_in,
            bytes_out,
        })
    }

    fn handle_conn<H: Handler>(
        mut stream: TcpStream,
        handler: Arc<H>,
        requests: Arc<AtomicU64>,
        bytes_in: Arc<AtomicU64>,
        bytes_out: Arc<AtomicU64>,
    ) -> Result<(), ProtoError> {
        stream.set_nodelay(true)?;
        // Per-connection pools, reused for every frame on this socket: the
        // 4-aligned payload buffer (so upload decode borrows in place) and
        // the reply encode scratch.  No per-frame allocation on the steady
        // state of the upload hot path.
        let mut payload = FrameBuf::new();
        let mut scratch = Vec::new();
        loop {
            let tag = match read_frame_into(&mut stream, &mut payload) {
                Ok(t) => t,
                Err(ProtoError::Io(_)) => return Ok(()), // client hung up
                Err(e) => {
                    let _ = write_frame(&mut stream, &Message::Error(e.to_string()));
                    return Err(e);
                }
            };
            bytes_in.fetch_add(5 + payload.len() as u64, Ordering::Relaxed);
            requests.fetch_add(1, Ordering::Relaxed);
            let reply = match handler.handle_frame(tag, payload.as_slice()) {
                Ok(r) => r,
                Err(e) => {
                    let _ = write_frame(&mut stream, &Message::Error(e.to_string()));
                    return Err(e);
                }
            };
            let n = write_reply(&mut stream, &reply, &mut scratch)?;
            bytes_out.fetch_add(n as u64, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NetClient;
    use crate::tensorstore::ModelUpdate;
    use std::sync::Mutex;

    #[test]
    fn echo_roundtrip() {
        let handle = NetServer::serve(
            "127.0.0.1:0",
            Arc::new(|m: Message| match m {
                Message::Register { party } => Message::Registered { party, round: 1 },
                other => other,
            }),
        )
        .unwrap();
        let mut c = NetClient::connect(handle.addr()).unwrap();
        let reply = c.call(&Message::Register { party: 9 }).unwrap();
        assert_eq!(reply, Message::Registered { party: 9, round: 1 });
    }

    #[test]
    fn concurrent_uploads_all_arrive() {
        let store: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
        let s2 = store.clone();
        let handle = NetServer::serve(
            "127.0.0.1:0",
            Arc::new(move |m: Message| {
                if let Message::Upload(u) = m {
                    s2.lock().unwrap().push(u.party);
                }
                Message::Ack { redirect_to_dfs: false }
            }),
        )
        .unwrap();
        let addr = handle.addr().to_string();
        std::thread::scope(|s| {
            for p in 0..16u64 {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = NetClient::connect(&addr).unwrap();
                    let u = ModelUpdate::new(p, 1.0, 0, vec![p as f32; 100]);
                    let r = c.call(&Message::Upload(u)).unwrap();
                    assert_eq!(r, Message::Ack { redirect_to_dfs: false });
                });
            }
        });
        let mut got = store.lock().unwrap().clone();
        got.sort();
        assert_eq!(got, (0..16).collect::<Vec<_>>());
        assert!(handle.connections.load(Ordering::Relaxed) >= 16);
    }

    #[test]
    fn handler_folds_uploads_on_receipt() {
        // The O(C) ingest shape at the socket layer: the handler folds
        // every Upload into a shared StreamingFold as it is read off the
        // wire, instead of parking K update buffers until aggregation.
        use crate::engine::StreamingFold;
        use crate::fusion::FedAvg;
        use crate::memsim::MemoryBudget;

        let budget = MemoryBudget::new(1 << 20);
        let fold = Arc::new(Mutex::new(
            StreamingFold::new(&FedAvg, 1, budget.clone()).unwrap(),
        ));
        let f2 = fold.clone();
        let handle = NetServer::serve(
            "127.0.0.1:0",
            Arc::new(move |m: Message| match m {
                Message::Upload(u) => match f2.lock().unwrap().fold(&FedAvg, &u) {
                    Ok(()) => Message::Ack { redirect_to_dfs: false },
                    Err(e) => Message::Error(e.to_string()),
                },
                other => other,
            }),
        )
        .unwrap();

        let addr = handle.addr().to_string();
        const LEN: usize = 256;
        std::thread::scope(|s| {
            for p in 0..16u64 {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = NetClient::connect(&addr).unwrap();
                    let u = ModelUpdate::new(p, 1.0, 0, vec![p as f32; LEN]);
                    let r = c.call(&Message::Upload(u)).unwrap();
                    assert_eq!(r, Message::Ack { redirect_to_dfs: false });
                });
            }
        });

        // resident state after 16 network ingests: ONE C-sized accumulator
        assert_eq!(budget.in_use(), (LEN * 4) as u64);
        let done = {
            let mut guard = fold.lock().unwrap();
            std::mem::replace(
                &mut *guard,
                StreamingFold::new(&FedAvg, 1, MemoryBudget::unbounded()).unwrap(),
            )
        };
        assert_eq!(done.folded(), 16);
        let out = done.finish(&FedAvg).unwrap();
        // mean of 0..16 = 7.5 in every coordinate
        assert!(out.iter().all(|v| (v - 7.5).abs() < 1e-3), "{:?}", &out[..4]);
    }

    #[test]
    fn persistent_connection_multiple_calls() {
        let handle = NetServer::serve(
            "127.0.0.1:0",
            Arc::new(|_m: Message| Message::Ack { redirect_to_dfs: false }),
        )
        .unwrap();
        let mut c = NetClient::connect(handle.addr()).unwrap();
        for round in 0..5 {
            let r = c.call(&Message::GetModel { round }).unwrap();
            assert_eq!(r, Message::Ack { redirect_to_dfs: false });
        }
        assert_eq!(handle.requests.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn byte_counters_track_wire_volume() {
        let handle = NetServer::serve(
            "127.0.0.1:0",
            Arc::new(|_m: Message| Message::Ack { redirect_to_dfs: false }),
        )
        .unwrap();
        let mut c = NetClient::connect(handle.addr()).unwrap();
        let u = ModelUpdate::new(1, 1.0, 0, vec![0.5; 100]);
        let in_frame = 5 + Message::Upload(u.clone()).encode().1.len() as u64;
        let out_frame = 5 + Message::Ack { redirect_to_dfs: false }.encode().1.len() as u64;
        for _ in 0..3 {
            c.call(&Message::Upload(u.clone())).unwrap();
        }
        // the reply write and its counter update race the client's recv by
        // a few instructions; poll briefly instead of sleeping blind
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        while handle.bytes_out.load(Ordering::Relaxed) < 3 * out_frame
            && std::time::Instant::now() < deadline
        {
            std::thread::yield_now();
        }
        assert_eq!(handle.bytes_in.load(Ordering::Relaxed), 3 * in_frame);
        assert_eq!(handle.bytes_out.load(Ordering::Relaxed), 3 * out_frame);
    }

    #[test]
    fn stop_drains_handler_threads_mid_round() {
        use std::io::{Read, Write};
        use std::time::{Duration, Instant};

        let mut handle = NetServer::serve("127.0.0.1:0", Arc::new(|m: Message| m)).unwrap();
        let addr = handle.addr().to_string();

        // A client mid-round: the frame header promises 200 payload bytes
        // but only 50 ever arrive — the handler thread parks inside
        // read_exact, exactly the state that used to outlive stop().
        let mut c = std::net::TcpStream::connect(&addr).unwrap();
        c.write_all(&[0x03, 200, 0, 0, 0]).unwrap();
        c.write_all(&[0u8; 50]).unwrap();

        let deadline = Instant::now() + Duration::from_secs(2);
        while handle.active_connections() == 0 && Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(handle.active_connections(), 1, "the handler picked the connection up");

        let t0 = Instant::now();
        handle.stop();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "stop() must unblock the parked read, not wait it out"
        );
        assert_eq!(
            handle.active_connections(),
            0,
            "no handler thread survives stop() while a client is mid-round"
        );

        // the server side of the socket is truly gone
        c.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let mut buf = [0u8; 8];
        assert!(matches!(c.read(&mut buf), Ok(0) | Err(_)), "connection must be dead");

        // idempotent: the Drop-driven second stop is a no-op
        handle.stop();
        assert_eq!(handle.active_connections(), 0);
    }

    #[test]
    fn stop_shuts_down() {
        let mut handle = NetServer::serve(
            "127.0.0.1:0",
            Arc::new(|m: Message| m),
        )
        .unwrap();
        let addr = handle.addr().to_string();
        handle.stop();
        // subsequent connections should fail (eventually)
        std::thread::sleep(std::time::Duration::from_millis(20));
        let ok = NetClient::connect(&addr)
            .and_then(|mut c| {
                c.call(&Message::GetModel { round: 0 })
                    .map_err(|_| std::io::Error::new(std::io::ErrorKind::Other, "x"))
            })
            .is_ok();
        assert!(!ok);
    }
}
