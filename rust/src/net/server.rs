//! TCP serving front end of the aggregation protocol.
//!
//! [`NetServer::serve`] runs the readiness-polling **reactor**
//! ([`reactor`](super::reactor)): one poll thread drives every
//! connection's frame state machine and a bounded worker pool folds the
//! decoded frames — OS threads are `1 + workers` regardless of how many
//! sockets are connected, which is what lets the aggregator face an edge
//! fleet instead of a thread table.  [`NetServer::serve_threaded`] keeps
//! the retired thread-per-connection backend (bugs fixed) as the
//! reference implementation the reactor's wire behaviour is pinned
//! against.  Both run behind the same [`ServerHandle`].

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use super::{reactor, threaded, Message, ProtoError, Reply};

/// Application hook: map a request message to a reply.
pub trait Handler: Send + Sync + 'static {
    fn handle(&self, msg: Message) -> Message;

    /// Zero-copy hook: map a raw frame (already length-checked, payload in
    /// the connection's 4-aligned pool) to a reply.  The default decodes an
    /// owned [`Message`] and delegates to [`Handler::handle`]; the FL
    /// server overrides it to fold uploads straight out of the wire buffer
    /// and to frame model replies from the published `Arc` without cloning.
    fn handle_frame(&self, tag: u8, payload: &[u8]) -> Result<Reply, ProtoError> {
        Ok(Reply::Msg(self.handle(Message::decode(tag, payload)?)))
    }
}

impl<F> Handler for F
where
    F: Fn(Message) -> Message + Send + Sync + 'static,
{
    fn handle(&self, msg: Message) -> Message {
        self(msg)
    }
}

/// Wire/ingest gauges shared between a running backend and its
/// [`ServerHandle`].
#[derive(Clone)]
pub(crate) struct Counters {
    pub connections: Arc<AtomicU64>,
    pub requests: Arc<AtomicU64>,
    pub bytes_in: Arc<AtomicU64>,
    pub bytes_out: Arc<AtomicU64>,
    pub aborted_frames: Arc<AtomicU64>,
}

impl Counters {
    fn new() -> Counters {
        Counters {
            connections: Arc::new(AtomicU64::new(0)),
            requests: Arc::new(AtomicU64::new(0)),
            bytes_in: Arc::new(AtomicU64::new(0)),
            bytes_out: Arc::new(AtomicU64::new(0)),
            aborted_frames: Arc::new(AtomicU64::new(0)),
        }
    }
}

/// Reactor sizing knobs.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReactorConfig {
    /// Fold worker threads (the pool decoded frames are dispatched to).
    /// `0` = one per available core.  Total server OS threads are
    /// `1 + workers`, independent of the connection count.
    pub workers: usize,
    /// Readiness backend the poll loop waits on (default `Auto`: epoll on
    /// Linux, kqueue on macOS/BSD, sweep elsewhere;
    /// `ELASTIAGG_NO_EPOLL=1` forces sweep regardless).
    pub waiter: super::waiter::WaiterKind,
}

impl ReactorConfig {
    fn resolved_workers(&self) -> usize {
        if self.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            self.workers
        }
    }
}

/// Which serving machinery sits behind a [`ServerHandle`].
enum Backend {
    Reactor {
        reactor: Option<std::thread::JoinHandle<()>>,
        workers: Vec<std::thread::JoinHandle<()>>,
        active: Arc<std::sync::atomic::AtomicUsize>,
        live_workers: Arc<std::sync::atomic::AtomicUsize>,
        /// Waiter backend name after `Auto`/env resolution.
        waiter: &'static str,
    },
    Threaded {
        accept: Option<std::thread::JoinHandle<()>>,
        live: Arc<threaded::ConnMap>,
    },
}

/// Running server; dropping the handle shuts the listener down.
pub struct ServerHandle {
    addr: String,
    stop: Arc<AtomicBool>,
    backend: Backend,
    pub connections: Arc<AtomicU64>,
    pub requests: Arc<AtomicU64>,
    /// Frame bytes read off all connections (headers + payloads) — the
    /// real ingest volume the planner's arrival-span term models.
    pub bytes_in: Arc<AtomicU64>,
    /// Frame bytes written as replies.
    pub bytes_out: Arc<AtomicU64>,
    /// Frames whose connection died MID-frame (header or payload partially
    /// read) — truncations, distinguished from clean hangups at a frame
    /// boundary.  The straggler/fault sims produce exactly this shape, and
    /// the registry's liveness eviction treats it as silence, not
    /// participation.
    pub aborted_frames: Arc<AtomicU64>,
}

impl ServerHandle {
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Which machinery serves this handle: `"epoll"`, `"kqueue"` or
    /// `"sweep"` for the reactor's waiter backends (after `Auto` and
    /// `ELASTIAGG_NO_EPOLL` resolution), `"threaded"` for the legacy
    /// thread-per-connection backend.
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            Backend::Reactor { waiter, .. } => waiter,
            Backend::Threaded { .. } => "threaded",
        }
    }

    /// Connections currently tracked by the serving backend.
    pub fn active_connections(&self) -> usize {
        match &self.backend {
            Backend::Reactor { active, .. } => active.load(Ordering::Acquire),
            Backend::Threaded { live, .. } => live.lock().unwrap().len(),
        }
    }

    /// Serving threads currently alive beyond the accept/poll loop: fold
    /// workers on the reactor, per-connection handlers on the threaded
    /// backend.  0 after a completed [`ServerHandle::stop`] — the "no
    /// leaked workers" invariant the churn soak pins.
    pub fn live_workers(&self) -> usize {
        match &self.backend {
            Backend::Reactor { live_workers, .. } => live_workers.load(Ordering::Acquire),
            Backend::Threaded { live, .. } => live.lock().unwrap().len(),
        }
    }

    /// Shut the server down COMPLETELY.  On the reactor: stop the poll
    /// loop (which shuts every tracked socket down and disconnects the
    /// job queue), then join the workers — they drain already-accepted
    /// frames first, so folds that were promised an Ack still land.  On
    /// the threaded backend: stop accepting, shut every live connection's
    /// stream down (unblocking handlers parked in `read`) and join their
    /// threads.  On return, no serving thread survives.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        // Poke the listener so a parked accept() returns (the reactor's
        // poll loop needs no poke, but the connect is harmless there).
        let _ = TcpStream::connect(&self.addr);
        match &mut self.backend {
            Backend::Reactor { reactor, workers, .. } => {
                if let Some(t) = reactor.take() {
                    let _ = t.join();
                }
                for t in workers.drain(..) {
                    let _ = t.join();
                }
            }
            Backend::Threaded { accept, live } => {
                if let Some(t) = accept.take() {
                    let _ = t.join();
                }
                // Drain the live connections OUTSIDE the lock: a handler
                // that ends normally takes the same lock to remove itself,
                // so joining while holding it would deadlock.
                let drained: Vec<(TcpStream, Option<std::thread::JoinHandle<()>>)> = {
                    let mut map = live.lock().unwrap();
                    map.drain().map(|(_, v)| v).collect()
                };
                for (stream, _) in &drained {
                    let _ = stream.shutdown(std::net::Shutdown::Both);
                }
                for (_, handle) in drained {
                    if let Some(h) = handle {
                        let _ = h.join();
                    }
                }
            }
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Addr-keyed test failpoint: unit tests run in parallel inside one
/// process, so an injected failure must hit only the server it was armed
/// for, never a neighbour test's listener.
#[cfg(test)]
pub(crate) struct Failpoint {
    armed: std::sync::Mutex<Option<(String, usize)>>,
}

#[cfg(test)]
impl Failpoint {
    pub(crate) const fn new() -> Failpoint {
        Failpoint { armed: std::sync::Mutex::new(None) }
    }

    /// Arm `n` triggers against the server listening on `addr`.
    pub(crate) fn arm(&self, addr: &str, n: usize) {
        *self.armed.lock().unwrap() = Some((addr.to_string(), n));
    }

    /// Consume one trigger if armed for `addr`.
    pub(crate) fn take(&self, addr: &str) -> bool {
        let mut g = self.armed.lock().unwrap();
        match g.as_mut() {
            Some((a, n)) if a == addr && *n > 0 => {
                *n -= 1;
                true
            }
            _ => false,
        }
    }
}

pub struct NetServer;

impl NetServer {
    /// Bind `addr` (use port 0 for ephemeral) and serve `handler` on the
    /// reactor with default sizing.
    pub fn serve<H: Handler>(addr: &str, handler: Arc<H>) -> std::io::Result<ServerHandle> {
        Self::serve_with(addr, handler, ReactorConfig::default())
    }

    /// Serve on the reactor with explicit sizing.
    pub fn serve_with<H: Handler>(
        addr: &str,
        handler: Arc<H>,
        cfg: ReactorConfig,
    ) -> std::io::Result<ServerHandle> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?.to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let counters = Counters::new();
        let parts = reactor::spawn(
            listener,
            handler,
            cfg.resolved_workers(),
            cfg.waiter,
            counters.clone(),
            stop.clone(),
        )?;
        Ok(ServerHandle {
            addr: local,
            stop,
            backend: Backend::Reactor {
                reactor: Some(parts.reactor),
                workers: parts.workers,
                active: parts.active,
                live_workers: parts.live_workers,
                waiter: parts.backend,
            },
            connections: counters.connections,
            requests: counters.requests,
            bytes_in: counters.bytes_in,
            bytes_out: counters.bytes_out,
            aborted_frames: counters.aborted_frames,
        })
    }

    /// Serve on the retired thread-per-connection backend — kept (with its
    /// lifecycle bugs fixed) as the reference implementation the reactor's
    /// wire behaviour is pinned against in `fig_connection_scaling`.
    pub fn serve_threaded<H: Handler>(
        addr: &str,
        handler: Arc<H>,
    ) -> std::io::Result<ServerHandle> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?.to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let counters = Counters::new();
        let parts = threaded::spawn(listener, handler, counters.clone(), stop.clone());
        Ok(ServerHandle {
            addr: local,
            stop,
            backend: Backend::Threaded { accept: Some(parts.accept), live: parts.live },
            connections: counters.connections,
            requests: counters.requests,
            bytes_in: counters.bytes_in,
            bytes_out: counters.bytes_out,
            aborted_frames: counters.aborted_frames,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NetClient;
    use crate::tensorstore::ModelUpdate;
    use std::sync::Mutex;
    use std::time::{Duration, Instant};

    fn echo() -> Arc<impl Handler> {
        Arc::new(|m: Message| m)
    }

    /// Run the same closure against both backends: the reactor must be
    /// wire-compatible with the threaded reference, so every ported
    /// behaviour test is a parity test.
    fn on_both_backends<F: Fn(&mut ServerHandle)>(handler: Arc<impl Handler + Clone>, f: F) {
        let mut reactor = NetServer::serve("127.0.0.1:0", Arc::new((*handler).clone())).unwrap();
        f(&mut reactor);
        reactor.stop();
        let mut threaded = NetServer::serve_threaded("127.0.0.1:0", handler).unwrap();
        f(&mut threaded);
        threaded.stop();
    }

    #[test]
    fn echo_roundtrip() {
        on_both_backends(
            Arc::new(|m: Message| match m {
                Message::Register { party } => Message::Registered { party, round: 1 },
                other => other,
            }),
            |handle| {
                let mut c = NetClient::connect(handle.addr()).unwrap();
                let reply = c.call(&Message::Register { party: 9 }).unwrap();
                assert_eq!(reply, Message::Registered { party: 9, round: 1 });
            },
        );
    }

    #[test]
    fn concurrent_uploads_all_arrive() {
        let store: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
        let s2 = store.clone();
        let handle = NetServer::serve(
            "127.0.0.1:0",
            Arc::new(move |m: Message| {
                if let Message::Upload(u) = m {
                    s2.lock().unwrap().push(u.party);
                }
                Message::Ack { redirect_to_dfs: false }
            }),
        )
        .unwrap();
        let addr = handle.addr().to_string();
        std::thread::scope(|s| {
            for p in 0..16u64 {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = NetClient::connect(&addr).unwrap();
                    let u = ModelUpdate::new(p, 1.0, 0, vec![p as f32; 100]);
                    let r = c.call(&Message::Upload(u)).unwrap();
                    assert_eq!(r, Message::Ack { redirect_to_dfs: false });
                });
            }
        });
        let mut got = store.lock().unwrap().clone();
        got.sort();
        assert_eq!(got, (0..16).collect::<Vec<_>>());
        assert!(handle.connections.load(Ordering::Relaxed) >= 16);
    }

    #[test]
    fn handler_folds_uploads_on_receipt() {
        // The O(C) ingest shape at the socket layer: the handler folds
        // every Upload into a shared StreamingFold as it is read off the
        // wire, instead of parking K update buffers until aggregation.
        use crate::engine::StreamingFold;
        use crate::fusion::FedAvg;
        use crate::memsim::MemoryBudget;

        let budget = MemoryBudget::new(1 << 20);
        let fold = Arc::new(Mutex::new(
            StreamingFold::new(&FedAvg, 1, budget.clone()).unwrap(),
        ));
        let f2 = fold.clone();
        let handle = NetServer::serve(
            "127.0.0.1:0",
            Arc::new(move |m: Message| match m {
                Message::Upload(u) => match f2.lock().unwrap().fold(&FedAvg, &u) {
                    Ok(()) => Message::Ack { redirect_to_dfs: false },
                    Err(e) => Message::Error(e.to_string()),
                },
                other => other,
            }),
        )
        .unwrap();

        let addr = handle.addr().to_string();
        const LEN: usize = 256;
        std::thread::scope(|s| {
            for p in 0..16u64 {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = NetClient::connect(&addr).unwrap();
                    let u = ModelUpdate::new(p, 1.0, 0, vec![p as f32; LEN]);
                    let r = c.call(&Message::Upload(u)).unwrap();
                    assert_eq!(r, Message::Ack { redirect_to_dfs: false });
                });
            }
        });

        // resident state after 16 network ingests: ONE C-sized accumulator
        assert_eq!(budget.in_use(), (LEN * 4) as u64);
        let done = {
            let mut guard = fold.lock().unwrap();
            std::mem::replace(
                &mut *guard,
                StreamingFold::new(&FedAvg, 1, MemoryBudget::unbounded()).unwrap(),
            )
        };
        assert_eq!(done.folded(), 16);
        let out = done.finish(&FedAvg).unwrap();
        // mean of 0..16 = 7.5 in every coordinate
        assert!(out.iter().all(|v| (v - 7.5).abs() < 1e-3), "{:?}", &out[..4]);
    }

    #[test]
    fn persistent_connection_multiple_calls() {
        on_both_backends(
            Arc::new(|_m: Message| Message::Ack { redirect_to_dfs: false }),
            |handle| {
                let mut c = NetClient::connect(handle.addr()).unwrap();
                for round in 0..5 {
                    let r = c.call(&Message::GetModel { round }).unwrap();
                    assert_eq!(r, Message::Ack { redirect_to_dfs: false });
                }
                assert_eq!(handle.requests.load(Ordering::Relaxed), 5);
            },
        );
    }

    #[test]
    fn byte_counters_track_wire_volume() {
        on_both_backends(
            Arc::new(|_m: Message| Message::Ack { redirect_to_dfs: false }),
            |handle| {
                let mut c = NetClient::connect(handle.addr()).unwrap();
                let u = ModelUpdate::new(1, 1.0, 0, vec![0.5; 100]);
                let in_frame = 5 + Message::Upload(u.clone()).encode().1.len() as u64;
                let out_frame =
                    5 + Message::Ack { redirect_to_dfs: false }.encode().1.len() as u64;
                for _ in 0..3 {
                    c.call(&Message::Upload(u.clone())).unwrap();
                }
                // the reply write and its counter update race the client's
                // recv by a few instructions; poll briefly
                let deadline = Instant::now() + Duration::from_secs(2);
                while handle.bytes_out.load(Ordering::Relaxed) < 3 * out_frame
                    && Instant::now() < deadline
                {
                    std::thread::yield_now();
                }
                assert_eq!(handle.bytes_in.load(Ordering::Relaxed), 3 * in_frame);
                assert_eq!(handle.bytes_out.load(Ordering::Relaxed), 3 * out_frame);
            },
        );
    }

    #[test]
    fn stop_drains_handler_threads_mid_round() {
        use std::io::{Read, Write};

        let mut handle = NetServer::serve("127.0.0.1:0", echo()).unwrap();
        let addr = handle.addr().to_string();

        // A client mid-round: the frame header promises 200 payload bytes
        // but only 50 ever arrive — the connection sits in the Payload
        // state, exactly the shape that used to park a handler thread in
        // read_exact past stop().
        let mut c = std::net::TcpStream::connect(&addr).unwrap();
        c.write_all(&[0x03, 200, 0, 0, 0]).unwrap();
        c.write_all(&[0u8; 50]).unwrap();

        let deadline = Instant::now() + Duration::from_secs(2);
        while handle.active_connections() == 0 && Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(handle.active_connections(), 1, "the reactor tracked the connection");

        let t0 = Instant::now();
        handle.stop();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "stop() must not wait the half-read frame out"
        );
        assert_eq!(
            handle.active_connections(),
            0,
            "no tracked connection survives stop() while a client is mid-round"
        );
        assert_eq!(handle.live_workers(), 0, "no fold worker survives stop()");

        // the server side of the socket is truly gone
        c.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let mut buf = [0u8; 8];
        assert!(matches!(c.read(&mut buf), Ok(0) | Err(_)), "connection must be dead");

        // idempotent: the Drop-driven second stop is a no-op
        handle.stop();
        assert_eq!(handle.active_connections(), 0);
    }

    #[test]
    fn stop_shuts_down() {
        let mut handle = NetServer::serve("127.0.0.1:0", echo()).unwrap();
        let addr = handle.addr().to_string();
        handle.stop();
        // subsequent connections should fail (eventually)
        std::thread::sleep(Duration::from_millis(20));
        let ok = NetClient::connect(&addr)
            .and_then(|mut c| {
                c.call(&Message::GetModel { round: 0 })
                    .map_err(|_| std::io::Error::other("x"))
            })
            .is_ok();
        assert!(!ok);
    }

    // ------------------------------------------------------------------
    // Lifecycle-bug regression pins.  Each of these FAILS against the
    // pre-reactor server shape.
    // ------------------------------------------------------------------

    #[test]
    fn refused_admission_never_serves_untracked_connections() {
        // Bug 1 (untracked-connection leak): when a connection cannot be
        // tracked, it must be REFUSED — the old shape served it with
        // `tracked=false`, so the call below SUCCEEDED on a connection
        // stop() could neither observe nor join.
        let mut handle = NetServer::serve("127.0.0.1:0", echo()).unwrap();
        reactor::REFUSE_ADMITS.arm(handle.addr(), 1);

        let mut c = NetClient::connect(handle.addr()).unwrap();
        assert!(
            c.call(&Message::GetModel { round: 0 }).is_err(),
            "a refused connection must never be served"
        );
        assert_eq!(handle.active_connections(), 0, "refused connection was never tracked");

        // the server keeps serving: the refusal cost one connection, not
        // the listener
        let mut c2 = NetClient::connect(handle.addr()).unwrap();
        assert_eq!(
            c2.call(&Message::GetModel { round: 3 }).unwrap(),
            Message::GetModel { round: 3 }
        );
        handle.stop();
        assert_eq!(handle.active_connections(), 0);
    }

    #[test]
    fn threaded_clone_failure_refuses_instead_of_serving_untracked() {
        // Bug 1 on the reference backend, driven by the injected
        // `try_clone` failure the old shape turned into `tracked=false`.
        let mut handle = NetServer::serve_threaded("127.0.0.1:0", echo()).unwrap();
        threaded::FAIL_CLONES.arm(handle.addr(), 1);

        let mut c = NetClient::connect(handle.addr()).unwrap();
        assert!(
            c.call(&Message::GetModel { round: 0 }).is_err(),
            "clone failure must refuse the connection, not serve it untracked"
        );
        assert_eq!(handle.active_connections(), 0);

        let mut c2 = NetClient::connect(handle.addr()).unwrap();
        assert_eq!(
            c2.call(&Message::GetModel { round: 3 }).unwrap(),
            Message::GetModel { round: 3 }
        );
        handle.stop();
        assert_eq!(handle.active_connections(), 0);
    }

    #[test]
    fn handler_waits_for_its_join_handle_attach() {
        // Bug 2 (join-handle attach race): with the historical race window
        // widened to 60 ms, the handler must still not serve a byte until
        // its JoinHandle is attached — the pre-gate shape replied
        // immediately and, if it finished inside the window, silently
        // detached its thread from stop().
        let mut handle = NetServer::serve_threaded("127.0.0.1:0", echo()).unwrap();
        threaded::ATTACH_DELAY_MS.store(60, Ordering::Release);
        let t0 = Instant::now();
        let mut c = NetClient::connect(handle.addr()).unwrap();
        let r = c.call(&Message::GetModel { round: 1 });
        threaded::ATTACH_DELAY_MS.store(0, Ordering::Release);
        assert_eq!(r.unwrap(), Message::GetModel { round: 1 });
        assert!(
            t0.elapsed() >= Duration::from_millis(50),
            "handler served before its join handle was attached"
        );
        handle.stop();
        assert_eq!(handle.active_connections(), 0, "stop() joined every handler");
    }

    #[test]
    fn truncated_frame_counts_as_aborted_clean_close_does_not() {
        // Bug 3: the old shape mapped every ProtoError::Io to "client hung
        // up", so a mid-frame death was indistinguishable from a clean
        // close and counted nowhere.
        use std::io::Write;

        let mut handle = NetServer::serve("127.0.0.1:0", echo()).unwrap();

        // clean: a full exchange, then close at the frame boundary
        {
            let mut c = NetClient::connect(handle.addr()).unwrap();
            c.call(&Message::GetModel { round: 0 }).unwrap();
        }
        let deadline = Instant::now() + Duration::from_secs(2);
        while handle.active_connections() > 0 && Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(
            handle.aborted_frames.load(Ordering::Relaxed),
            0,
            "a clean close at a frame boundary is not an abort"
        );

        // aborted: header promises 200 bytes, 50 arrive, client dies
        {
            let mut c = std::net::TcpStream::connect(handle.addr()).unwrap();
            c.write_all(&[0x03, 200, 0, 0, 0]).unwrap();
            c.write_all(&[0u8; 50]).unwrap();
        }
        let deadline = Instant::now() + Duration::from_secs(2);
        while handle.aborted_frames.load(Ordering::Relaxed) == 0 && Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(
            handle.aborted_frames.load(Ordering::Relaxed),
            1,
            "a mid-frame death must be counted as an aborted frame"
        );
        handle.stop();
    }

    #[test]
    fn worker_pool_is_bounded_and_drains_on_stop() {
        // 64 short-lived connections through a ONE-worker reactor: every
        // request is served (the pool is a queue, not a drop gate), and
        // stop() leaves zero workers alive.
        let mut handle = NetServer::serve_with(
            "127.0.0.1:0",
            echo(),
            ReactorConfig { workers: 1, ..ReactorConfig::default() },
        )
        .unwrap();
        assert_eq!(handle.live_workers(), 1);
        for round in 0..64 {
            let mut c = NetClient::connect(handle.addr()).unwrap();
            assert_eq!(
                c.call(&Message::GetModel { round }).unwrap(),
                Message::GetModel { round }
            );
        }
        handle.stop();
        assert_eq!(handle.active_connections(), 0);
        assert_eq!(handle.live_workers(), 0, "stop() must join the fold workers");
    }

    #[test]
    fn model_reply_gather_write_survives_the_reactor() {
        // The zero-copy Reply::Model path through the nonblocking Outbox
        // must be wire-identical to the owned Message::Model encoding.
        struct ModelHandler(Arc<Vec<f32>>);
        impl Handler for ModelHandler {
            fn handle(&self, _m: Message) -> Message {
                unreachable!("handle_frame is overridden")
            }
            fn handle_frame(&self, _tag: u8, _payload: &[u8]) -> Result<Reply, ProtoError> {
                Ok(Reply::Model { round: 7, weights: self.0.clone() })
            }
        }
        let weights: Vec<f32> = (0..2048).map(|i| i as f32 * 0.25).collect();
        let mut handle = NetServer::serve(
            "127.0.0.1:0",
            Arc::new(ModelHandler(Arc::new(weights.clone()))),
        )
        .unwrap();
        let mut c = NetClient::connect(handle.addr()).unwrap();
        let got = c.call(&Message::GetModel { round: 7 }).unwrap();
        assert_eq!(got, Message::Model { round: 7, weights });
        handle.stop();
    }
}
