//! kqueue backend for the reactor's [`Waiter`](super::waiter::Waiter) —
//! macOS, FreeBSD, OpenBSD, DragonFly.  (NetBSD's `struct kevent` layout
//! differs; it takes the sweep fallback.)
//!
//! Same contract as the epoll backend: level-triggered readiness (kqueue
//! is level-triggered unless `EV_CLEAR` is set, which we never set),
//! interest expressed as per-filter ADD/DELETE deltas, worker→loop
//! notifications over a nonblocking self-pipe registered like any other
//! fd.  Tokens are kept in a userspace fd→token map instead of `udata`
//! so the shim never depends on pointer-width casts.

use std::collections::HashMap;
use std::io;
use std::ptr;
use std::sync::Arc;
use std::time::Duration;

use super::waiter::WaitEvent;

const EVFILT_READ: i16 = -1;
const EVFILT_WRITE: i16 = -2;
const EV_ADD: u16 = 0x0001;
const EV_DELETE: u16 = 0x0002;
const EV_EOF: u16 = 0x8000;
const EV_ERROR: u16 = 0x4000;

const F_SETFL: i32 = 4;
const O_NONBLOCK: i32 = 0x0004;

/// Mirrors `struct kevent` on the gated platforms (64-bit layouts).
#[repr(C)]
struct KEvent {
    ident: usize,
    filter: i16,
    flags: u16,
    fflags: u32,
    data: isize,
    udata: *mut std::ffi::c_void,
}

/// Mirrors `struct timespec` on 64-bit macOS/BSD.
#[repr(C)]
struct Timespec {
    tv_sec: i64,
    tv_nsec: i64,
}

extern "C" {
    fn kqueue() -> i32;
    fn kevent(
        kq: i32,
        changelist: *const KEvent,
        nchanges: i32,
        eventlist: *mut KEvent,
        nevents: i32,
        timeout: *const Timespec,
    ) -> i32;
    fn pipe(fds: *mut i32) -> i32;
    fn fcntl(fd: i32, cmd: i32, arg: i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn close(fd: i32) -> i32;
}

/// A nonblocking self-pipe: workers `signal` the write end, the poll loop
/// drains the read end inside `wait`.  A full pipe (`EAGAIN`) means a
/// wakeup is already pending — signals coalesce.
pub(crate) struct PipePair {
    read_fd: i32,
    write_fd: i32,
}

impl PipePair {
    fn new() -> io::Result<PipePair> {
        let mut fds = [0i32; 2];
        if unsafe { pipe(fds.as_mut_ptr()) } < 0 {
            return Err(io::Error::last_os_error());
        }
        let pair = PipePair { read_fd: fds[0], write_fd: fds[1] };
        for fd in fds {
            if unsafe { fcntl(fd, F_SETFL, O_NONBLOCK) } < 0 {
                return Err(io::Error::last_os_error()); // Drop closes both
            }
        }
        Ok(pair)
    }

    pub(crate) fn signal(&self) {
        let one = [1u8];
        let _ = unsafe { write(self.write_fd, one.as_ptr(), 1) };
    }

    fn drain(&self) {
        let mut buf = [0u8; 64];
        while unsafe { read(self.read_fd, buf.as_mut_ptr(), buf.len()) } > 0 {}
    }
}

impl Drop for PipePair {
    fn drop(&mut self) {
        let _ = unsafe { close(self.read_fd) };
        let _ = unsafe { close(self.write_fd) };
    }
}

pub(crate) struct KqueueWaiter {
    kq: i32,
    notify: Arc<PipePair>,
    /// fd → (token, read-interest, write-interest); per-filter deltas are
    /// derived from the previous interest on each change.
    registered: HashMap<i32, (u64, bool, bool)>,
}

impl KqueueWaiter {
    pub(crate) fn new() -> io::Result<KqueueWaiter> {
        let kq = unsafe { kqueue() };
        if kq < 0 {
            return Err(io::Error::last_os_error());
        }
        let notify = match PipePair::new() {
            Ok(p) => Arc::new(p),
            Err(e) => {
                let _ = unsafe { close(kq) };
                return Err(e);
            }
        };
        let mut w = KqueueWaiter { kq, notify, registered: HashMap::new() };
        let read_fd = w.notify.read_fd;
        w.apply(&[Self::change(read_fd, EVFILT_READ, EV_ADD)])?;
        Ok(w)
    }

    pub(crate) fn notifier(&self) -> Arc<PipePair> {
        self.notify.clone()
    }

    fn change(fd: i32, filter: i16, flags: u16) -> KEvent {
        KEvent {
            ident: fd as usize,
            filter,
            flags,
            fflags: 0,
            data: 0,
            udata: ptr::null_mut(),
        }
    }

    fn apply(&self, changes: &[KEvent]) -> io::Result<()> {
        if changes.is_empty() {
            return Ok(());
        }
        let rc = unsafe {
            kevent(self.kq, changes.as_ptr(), changes.len() as i32, ptr::null_mut(), 0, ptr::null())
        };
        if rc < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(())
        }
    }

    pub(crate) fn set_interest(
        &mut self,
        fd: i32,
        token: u64,
        read: bool,
        write: bool,
    ) -> io::Result<()> {
        let (had_read, had_write) =
            self.registered.get(&fd).map_or((false, false), |&(_, r, w)| (r, w));
        let mut changes = Vec::with_capacity(2);
        if read != had_read {
            changes.push(Self::change(fd, EVFILT_READ, if read { EV_ADD } else { EV_DELETE }));
        }
        if write != had_write {
            changes.push(Self::change(fd, EVFILT_WRITE, if write { EV_ADD } else { EV_DELETE }));
        }
        self.apply(&changes)?;
        if read || write {
            self.registered.insert(fd, (token, read, write));
        } else {
            self.registered.remove(&fd);
        }
        Ok(())
    }

    pub(crate) fn deregister(&mut self, fd: i32, _token: u64) {
        if let Some((_, read, write)) = self.registered.remove(&fd) {
            let mut changes = Vec::with_capacity(2);
            if read {
                changes.push(Self::change(fd, EVFILT_READ, EV_DELETE));
            }
            if write {
                changes.push(Self::change(fd, EVFILT_WRITE, EV_DELETE));
            }
            // The fd may already be closed/implicitly removed; best-effort.
            let _ = self.apply(&changes);
        }
    }

    pub(crate) fn wait(
        &mut self,
        events: &mut Vec<WaitEvent>,
        timeout: Option<Duration>,
    ) -> io::Result<()> {
        let ts = timeout.map(|t| Timespec {
            tv_sec: t.as_secs().min(i64::MAX as u64) as i64,
            tv_nsec: i64::from(t.subsec_nanos()),
        });
        let ts_ptr = ts.as_ref().map_or(ptr::null(), |t| t as *const Timespec);
        let mut buf: [KEvent; 64] = std::array::from_fn(|_| Self::change(0, 0, 0));
        let n = unsafe {
            kevent(self.kq, ptr::null(), 0, buf.as_mut_ptr(), buf.len() as i32, ts_ptr)
        };
        if n < 0 {
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(err);
        }
        for ev in &buf[..n as usize] {
            let fd = ev.ident as i32;
            if fd == self.notify.read_fd {
                self.notify.drain();
                continue;
            }
            let Some(&(token, _, _)) = self.registered.get(&fd) else {
                continue; // raced with a deregister
            };
            let failed = ev.flags & (EV_EOF | EV_ERROR) != 0;
            events.push(WaitEvent {
                token,
                readable: ev.filter == EVFILT_READ || failed,
                writable: ev.filter == EVFILT_WRITE || failed,
            });
        }
        Ok(())
    }
}

impl Drop for KqueueWaiter {
    fn drop(&mut self) {
        let _ = unsafe { close(self.kq) };
    }
}
