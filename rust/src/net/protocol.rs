//! Wire messages of the aggregation protocol.

use std::sync::Arc;

use crate::tensorstore::{bytes_to_f32s, f32s_as_bytes, ModelUpdate, PartialAggregate, WireError};

/// 2 GiB frame cap — a single full-size CNN956 update is ~1 GiB; anything
/// larger than this is a corrupt header, rejected before allocation.
/// `MAX_FRAME < u32::MAX`, so a length that passes [`checked_frame_len`]
/// always fits the wire's u32 length field exactly.
pub const MAX_FRAME: usize = 2 << 30;

/// Frame tags (the `tag u8` of every frame).
pub const TAG_REGISTER: u8 = 0x01;
pub const TAG_REGISTERED: u8 = 0x02;
pub const TAG_UPLOAD: u8 = 0x03;
pub const TAG_ACK: u8 = 0x04;
pub const TAG_GET_MODEL: u8 = 0x05;
pub const TAG_MODEL: u8 = 0x06;
pub const TAG_NO_MODEL: u8 = 0x07;
/// Upload with a leading retransmission nonce (8 bytes, outside the
/// update's CRC) — the fault-tolerant sibling of [`TAG_UPLOAD`].
pub const TAG_UPLOAD_NONCE: u8 = 0x08;
/// Reply: this party's update was already folded this round.
pub const TAG_DUPLICATE: u8 = 0x09;
/// Reply: the upload arrived after the round sealed (quorum/deadline/abort).
pub const TAG_LATE: u8 = 0x0A;
/// Upload of a weighted *partial aggregate* (an already-folded edge
/// cohort): 8-byte retransmission nonce, then the CRC-covered
/// [`PartialAggregate`] bytes — the same nonce-ahead layout as
/// [`TAG_UPLOAD_NONCE`], so the partial's f32 sums still decode zero-copy
/// at the 4-aligned offset inside the pooled frame buffer.
pub const TAG_UPLOAD_PARTIAL: u8 = 0x0B;
/// Reply: an async-mode upload was admitted to the staleness buffer.
/// Carries the current model version and the staleness delta the server
/// computed for the update — the client learns how discounted its work
/// was and which version to pull before its next local round.
pub const TAG_ASYNC_ACK: u8 = 0x0C;
/// Upload of a *compression-encoded* update: 8-byte retransmission nonce,
/// then a CRC-covered encoded frame (see [`codec`](crate::tensorstore::codec)
/// — magic "EA02", an encoding tag byte negotiates dense f32 / f16 /
/// int8-quantized / top-k sparse per upload).  The nonce-ahead layout
/// matches [`TAG_UPLOAD_NONCE`]; the encoded header is 40 bytes, so a
/// `DenseF32` payload sits 4-aligned inside the pooled frame buffer and
/// still decodes zero-copy.
pub const TAG_UPLOAD_ENC: u8 = 0x0D;
/// Reply: the robust admission gate judged the upload hostile (L2 norm
/// beyond the rejection threshold) and refused to fold it.  Typed — NOT
/// [`TAG_ERROR`] — so an honest-but-misconfigured client can tell "my
/// update was rejected as an outlier" apart from a transport failure and
/// stop burning its trust score on retransmits.
pub const TAG_REJECTED: u8 = 0x0E;
/// Party liveness beacon: "still here, still training" — nothing but the
/// party id.  The server notes the party's `last_seen` (the signal the
/// registry's liveness eviction consumes) and replies [`TAG_REGISTERED`]
/// with the current round, so a heartbeat doubles as a cheap round poll.
pub const TAG_HEARTBEAT: u8 = 0x0F;
pub const TAG_ERROR: u8 = 0x7F;

/// Validate a payload length before it is cast into the wire's u32 length
/// field.  Without this check an oversized payload would be silently
/// truncated by `as u32` and frame-corrupt the stream for every later
/// message on the connection.
pub fn checked_frame_len(len: usize) -> Result<u32, ProtoError> {
    if len > MAX_FRAME {
        return Err(ProtoError::FrameTooLarge(len));
    }
    Ok(len as u32)
}

#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Party announces itself; server replies `Registered`.
    Register { party: u64 },
    Registered { party: u64, round: u32 },
    /// Party uploads its update over the message-passing path.
    Upload(ModelUpdate),
    /// Upload tagged with a retransmission nonce: the coordinator folds
    /// each party at most once per round and answers a retransmit with
    /// [`Message::Duplicate`] instead of double-folding (the nonce rides
    /// ahead of the update bytes so the CRC-covered payload is unchanged
    /// and still decodes zero-copy at an 8-byte offset).
    UploadNonce { nonce: u64, update: ModelUpdate },
    /// An edge aggregator uploads its cohort's weighted partial aggregate.
    /// Carries a retransmission nonce exactly like [`Message::UploadNonce`];
    /// the coordinator claims the whole cohort's dedup slots atomically.
    UploadPartial { nonce: u64, partial: PartialAggregate },
    /// Server ack; `redirect_to_dfs` tells the party to write its NEXT
    /// update to the shared store instead (seamless transition, §III-D3).
    Ack { redirect_to_dfs: bool },
    /// The round already folded this party's update; `nonce` is the
    /// accepted upload's nonce (retransmit absorbed, not an error).
    Duplicate { party: u64, nonce: u64 },
    /// The upload missed the round: it sealed (quorum reached at the
    /// deadline, or aborted) before the frame arrived.
    Late { round: u32 },
    /// Fetch the fused model of a round.
    GetModel { round: u32 },
    Model { round: u32, weights: Vec<f32> },
    NoModel { round: u32 },
    /// Async-mode upload admitted: `version` is the model version at
    /// ingest, `delta` the staleness the fold will discount by.  In async
    /// mode the upload frame's round id is reinterpreted as the version
    /// the client trained against, so stale work is weighted, not
    /// `Late`-rejected.
    AsyncAck { version: u32, delta: u32 },
    /// Nonce-tagged upload whose body is a compression-encoded frame
    /// (kept as raw bytes here; the server decodes it straight out of the
    /// pooled buffer so a dense-f32 payload still borrows zero-copy).
    /// [`Message::decode`] validates the frame (CRC/magic/tag/lengths)
    /// before accepting it.
    UploadEnc { nonce: u64, frame: Vec<u8> },
    /// The robust admission gate rejected this party's upload: its L2
    /// norm exceeded the round's rejection threshold.  The sender's trust
    /// score has been decayed; the update was NOT folded.
    Rejected { party: u64, norm: f32 },
    /// Party liveness beacon: refreshes the registry's `last_seen` stamp
    /// so a slow-but-alive trainer is not evicted from quorum accounting
    /// mid-round.  Answered with [`Message::Registered`] carrying the
    /// current round.
    Heartbeat { party: u64 },
    Error(String),
}

#[derive(Debug)]
pub enum ProtoError {
    Io(std::io::Error),
    UnknownTag(u8),
    FrameTooLarge(usize),
    BadPayload(String),
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Io(e) => write!(f, "io: {e}"),
            ProtoError::UnknownTag(t) => write!(f, "unknown tag {t:#x}"),
            ProtoError::FrameTooLarge(n) => write!(f, "frame too large: {n}"),
            ProtoError::BadPayload(m) => write!(f, "bad payload: {m}"),
        }
    }
}

impl std::error::Error for ProtoError {}

impl From<std::io::Error> for ProtoError {
    fn from(e: std::io::Error) -> Self {
        ProtoError::Io(e)
    }
}

impl From<WireError> for ProtoError {
    fn from(e: WireError) -> Self {
        ProtoError::BadPayload(e.to_string())
    }
}

impl Message {
    /// Append this message's payload to `out`; returns the frame tag.
    fn payload_into(&self, out: &mut Vec<u8>) -> u8 {
        match self {
            Message::Register { party } => {
                out.extend_from_slice(&party.to_le_bytes());
                TAG_REGISTER
            }
            Message::Registered { party, round } => {
                out.extend_from_slice(&party.to_le_bytes());
                out.extend_from_slice(&round.to_le_bytes());
                TAG_REGISTERED
            }
            Message::Upload(u) => {
                u.encode_into(out);
                TAG_UPLOAD
            }
            Message::UploadNonce { nonce, update } => {
                out.extend_from_slice(&nonce.to_le_bytes());
                update.encode_into(out);
                TAG_UPLOAD_NONCE
            }
            Message::UploadPartial { nonce, partial } => {
                out.extend_from_slice(&nonce.to_le_bytes());
                partial.encode_into(out);
                TAG_UPLOAD_PARTIAL
            }
            Message::Ack { redirect_to_dfs } => {
                out.push(u8::from(*redirect_to_dfs));
                TAG_ACK
            }
            Message::Duplicate { party, nonce } => {
                out.extend_from_slice(&party.to_le_bytes());
                out.extend_from_slice(&nonce.to_le_bytes());
                TAG_DUPLICATE
            }
            Message::Late { round } => {
                out.extend_from_slice(&round.to_le_bytes());
                TAG_LATE
            }
            Message::GetModel { round } => {
                out.extend_from_slice(&round.to_le_bytes());
                TAG_GET_MODEL
            }
            Message::Model { round, weights } => {
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(f32s_as_bytes(weights));
                TAG_MODEL
            }
            Message::NoModel { round } => {
                out.extend_from_slice(&round.to_le_bytes());
                TAG_NO_MODEL
            }
            Message::AsyncAck { version, delta } => {
                out.extend_from_slice(&version.to_le_bytes());
                out.extend_from_slice(&delta.to_le_bytes());
                TAG_ASYNC_ACK
            }
            Message::UploadEnc { nonce, frame } => {
                out.extend_from_slice(&nonce.to_le_bytes());
                out.extend_from_slice(frame);
                TAG_UPLOAD_ENC
            }
            Message::Rejected { party, norm } => {
                out.extend_from_slice(&party.to_le_bytes());
                out.extend_from_slice(&norm.to_le_bytes());
                TAG_REJECTED
            }
            Message::Heartbeat { party } => {
                out.extend_from_slice(&party.to_le_bytes());
                TAG_HEARTBEAT
            }
            Message::Error(m) => {
                out.extend_from_slice(m.as_bytes());
                TAG_ERROR
            }
        }
    }

    /// (tag, payload)
    pub fn encode(&self) -> (u8, Vec<u8>) {
        let mut p = Vec::new();
        let tag = self.payload_into(&mut p);
        (tag, p)
    }

    /// Serialize the whole frame (`tag | len | payload`) into `out`,
    /// reusing its capacity — the per-frame `Vec` the original
    /// `encode()`-then-`write` path allocated disappears on pooled
    /// connections.  Oversized payloads are rejected *before* anything is
    /// written, so a failed encode can never leave a half-frame behind.
    pub fn encode_into(&self, out: &mut Vec<u8>) -> Result<(), ProtoError> {
        out.clear();
        out.extend_from_slice(&[0u8; 5]); // tag + len, patched below
        let tag = self.payload_into(out);
        let len = checked_frame_len(out.len() - 5)?;
        out[0] = tag;
        out[1..5].copy_from_slice(&len.to_le_bytes());
        Ok(())
    }

    pub fn decode(tag: u8, payload: &[u8]) -> Result<Message, ProtoError> {
        let need = |n: usize| -> Result<(), ProtoError> {
            if payload.len() < n {
                Err(ProtoError::BadPayload(format!("need {n} bytes, got {}", payload.len())))
            } else {
                Ok(())
            }
        };
        match tag {
            TAG_REGISTER => {
                need(8)?;
                Ok(Message::Register { party: u64::from_le_bytes(payload[..8].try_into().unwrap()) })
            }
            TAG_REGISTERED => {
                need(12)?;
                Ok(Message::Registered {
                    party: u64::from_le_bytes(payload[..8].try_into().unwrap()),
                    round: u32::from_le_bytes(payload[8..12].try_into().unwrap()),
                })
            }
            TAG_UPLOAD => Ok(Message::Upload(ModelUpdate::decode(payload)?)),
            TAG_UPLOAD_NONCE => {
                need(8)?;
                Ok(Message::UploadNonce {
                    nonce: u64::from_le_bytes(payload[..8].try_into().unwrap()),
                    update: ModelUpdate::decode(&payload[8..])?,
                })
            }
            TAG_UPLOAD_PARTIAL => {
                need(8)?;
                Ok(Message::UploadPartial {
                    nonce: u64::from_le_bytes(payload[..8].try_into().unwrap()),
                    partial: PartialAggregate::decode(&payload[8..])?,
                })
            }
            TAG_ACK => {
                need(1)?;
                Ok(Message::Ack { redirect_to_dfs: payload[0] != 0 })
            }
            TAG_DUPLICATE => {
                need(16)?;
                Ok(Message::Duplicate {
                    party: u64::from_le_bytes(payload[..8].try_into().unwrap()),
                    nonce: u64::from_le_bytes(payload[8..16].try_into().unwrap()),
                })
            }
            TAG_LATE => {
                need(4)?;
                Ok(Message::Late { round: u32::from_le_bytes(payload[..4].try_into().unwrap()) })
            }
            TAG_GET_MODEL => {
                need(4)?;
                Ok(Message::GetModel { round: u32::from_le_bytes(payload[..4].try_into().unwrap()) })
            }
            TAG_MODEL => {
                need(4)?;
                if (payload.len() - 4) % 4 != 0 {
                    return Err(ProtoError::BadPayload("weights not f32-aligned".into()));
                }
                Ok(Message::Model {
                    round: u32::from_le_bytes(payload[..4].try_into().unwrap()),
                    weights: bytes_to_f32s(&payload[4..]),
                })
            }
            TAG_NO_MODEL => {
                need(4)?;
                Ok(Message::NoModel { round: u32::from_le_bytes(payload[..4].try_into().unwrap()) })
            }
            TAG_ASYNC_ACK => {
                need(8)?;
                Ok(Message::AsyncAck {
                    version: u32::from_le_bytes(payload[..4].try_into().unwrap()),
                    delta: u32::from_le_bytes(payload[4..8].try_into().unwrap()),
                })
            }
            TAG_UPLOAD_ENC => {
                need(8)?;
                let frame = &payload[8..];
                // Validate the encoded frame (CRC first, then magic, tag,
                // caps, declared lengths) before accepting the bytes.
                crate::tensorstore::EncodedUpdateView::decode(frame)?;
                Ok(Message::UploadEnc {
                    nonce: u64::from_le_bytes(payload[..8].try_into().unwrap()),
                    frame: frame.to_vec(),
                })
            }
            TAG_REJECTED => {
                need(12)?;
                Ok(Message::Rejected {
                    party: u64::from_le_bytes(payload[..8].try_into().unwrap()),
                    norm: f32::from_le_bytes(payload[8..12].try_into().unwrap()),
                })
            }
            TAG_HEARTBEAT => {
                need(8)?;
                Ok(Message::Heartbeat {
                    party: u64::from_le_bytes(payload[..8].try_into().unwrap()),
                })
            }
            TAG_ERROR => Ok(Message::Error(String::from_utf8_lossy(payload).into_owned())),
            t => Err(ProtoError::UnknownTag(t)),
        }
    }
}

/// What a frame handler produces for one request.
///
/// `Msg` is the ordinary owned reply.  `Model` is the zero-copy fused-model
/// reply: the weights are framed straight out of the shared `Arc` the round
/// published — no `Vec<f32>` clone, no payload materialisation (see
/// [`write_reply`](super::write_reply)).
#[derive(Clone, Debug)]
pub enum Reply {
    Msg(Message),
    Model { round: u32, weights: Arc<Vec<f32>> },
}

impl From<Message> for Reply {
    fn from(m: Message) -> Reply {
        Reply::Msg(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tags_distinct() {
        let msgs = [
            Message::Register { party: 0 }.encode().0,
            Message::Registered { party: 0, round: 0 }.encode().0,
            Message::Upload(ModelUpdate::new(0, 0.0, 0, vec![])).encode().0,
            Message::UploadNonce {
                nonce: 0,
                update: ModelUpdate::new(0, 0.0, 0, vec![]),
            }
            .encode()
            .0,
            Message::UploadPartial {
                nonce: 0,
                partial: PartialAggregate::new(0, 0, 0.0, vec![], vec![]),
            }
            .encode()
            .0,
            Message::Ack { redirect_to_dfs: false }.encode().0,
            Message::Duplicate { party: 0, nonce: 0 }.encode().0,
            Message::Late { round: 0 }.encode().0,
            Message::GetModel { round: 0 }.encode().0,
            Message::Model { round: 0, weights: vec![] }.encode().0,
            Message::NoModel { round: 0 }.encode().0,
            Message::AsyncAck { version: 0, delta: 0 }.encode().0,
            Message::UploadEnc { nonce: 0, frame: vec![] }.encode().0,
            Message::Rejected { party: 0, norm: 0.0 }.encode().0,
            Message::Heartbeat { party: 0 }.encode().0,
            Message::Error(String::new()).encode().0,
        ];
        let mut set = msgs.to_vec();
        set.sort();
        set.dedup();
        assert_eq!(set.len(), msgs.len());
    }

    #[test]
    fn unknown_tag_rejected() {
        assert!(matches!(Message::decode(0x55, &[]), Err(ProtoError::UnknownTag(0x55))));
    }

    #[test]
    fn short_payload_rejected() {
        assert!(Message::decode(0x01, &[1, 2]).is_err());
        assert!(Message::decode(0x06, &[0, 0, 0, 0, 1]).is_err()); // unaligned weights
    }

    #[test]
    fn frame_len_check_rejects_before_u32_truncation() {
        // Anything past MAX_FRAME would either truncate in the `as u32`
        // cast or lie about its length; both must be FrameTooLarge.
        assert!(matches!(
            checked_frame_len(MAX_FRAME + 1),
            Err(ProtoError::FrameTooLarge(n)) if n == MAX_FRAME + 1
        ));
        assert!(matches!(
            checked_frame_len(u32::MAX as usize + 1),
            Err(ProtoError::FrameTooLarge(_))
        ));
        assert_eq!(checked_frame_len(0).unwrap(), 0);
        assert_eq!(checked_frame_len(MAX_FRAME).unwrap(), MAX_FRAME as u32);
        // the cap itself must fit u32, or the Ok cast above would be wrong
        assert!(MAX_FRAME <= u32::MAX as usize);
    }

    #[test]
    fn encode_into_frames_exactly_like_encode() {
        let msgs = [
            Message::Register { party: 7 },
            Message::Upload(ModelUpdate::new(1, 2.0, 3, vec![0.5; 40])),
            Message::Model { round: 2, weights: vec![1.0; 9] },
            Message::Error("x".into()),
        ];
        let mut buf = Vec::new();
        for m in msgs {
            m.encode_into(&mut buf).unwrap();
            let (tag, payload) = m.encode();
            assert_eq!(buf[0], tag);
            assert_eq!(u32::from_le_bytes(buf[1..5].try_into().unwrap()) as usize, payload.len());
            assert_eq!(&buf[5..], &payload[..]);
        }
    }

    #[test]
    fn upload_carries_crc_protection() {
        let u = ModelUpdate::new(5, 1.0, 2, vec![3.0; 10]);
        let (tag, mut payload) = Message::Upload(u).encode();
        payload[30] ^= 0xFF;
        assert!(Message::decode(tag, &payload).is_err());
    }

    #[test]
    fn nonce_upload_roundtrips_and_keeps_crc_protection() {
        let u = ModelUpdate::new(5, 1.0, 2, vec![3.0; 10]);
        let m = Message::UploadNonce { nonce: 0xDEAD_BEEF, update: u.clone() };
        let (tag, payload) = m.encode();
        assert_eq!(tag, TAG_UPLOAD_NONCE);
        assert_eq!(Message::decode(tag, &payload).unwrap(), m);
        // the update body (past the 8-byte nonce) is still CRC-guarded
        let mut corrupt = payload.clone();
        corrupt[8 + 30] ^= 0xFF;
        assert!(Message::decode(tag, &corrupt).is_err());
        // a short frame cannot even carry the nonce
        assert!(Message::decode(TAG_UPLOAD_NONCE, &payload[..7]).is_err());
    }

    #[test]
    fn partial_upload_roundtrips_and_keeps_crc_protection() {
        let p = PartialAggregate::new(3, 2, 40.0, vec![11, 12, 13], vec![1.5; 20]);
        let m = Message::UploadPartial { nonce: 0xFEED, partial: p };
        let (tag, payload) = m.encode();
        assert_eq!(tag, TAG_UPLOAD_PARTIAL);
        assert_eq!(Message::decode(tag, &payload).unwrap(), m);
        // the partial body (past the 8-byte nonce) is still CRC-guarded
        let mut corrupt = payload.clone();
        corrupt[8 + 45] ^= 0xFF;
        assert!(Message::decode(tag, &corrupt).is_err());
        // a frame too short for the nonce is rejected outright
        assert!(Message::decode(TAG_UPLOAD_PARTIAL, &payload[..7]).is_err());
    }

    #[test]
    fn duplicate_and_late_roundtrip() {
        for m in [
            Message::Duplicate { party: 7, nonce: u64::MAX },
            Message::Late { round: 42 },
        ] {
            let (tag, payload) = m.encode();
            assert_eq!(Message::decode(tag, &payload).unwrap(), m);
        }
        assert!(Message::decode(TAG_DUPLICATE, &[0u8; 15]).is_err());
        assert!(Message::decode(TAG_LATE, &[0u8; 3]).is_err());
    }

    #[test]
    fn encoded_upload_roundtrips_and_keeps_crc_protection() {
        use crate::tensorstore::{codec, Encoding};
        let u = ModelUpdate::new(5, 1.0, 2, (0..100).map(|i| i as f32 * 0.25).collect());
        for enc in [
            Encoding::DenseF32,
            Encoding::DenseF16,
            Encoding::QuantI8,
            Encoding::TopK { permille: 200 },
        ] {
            let frame = codec::encode_update(&u, enc);
            let m = Message::UploadEnc { nonce: 0xBEEF, frame: frame.clone() };
            let (tag, payload) = m.encode();
            assert_eq!(tag, TAG_UPLOAD_ENC);
            assert_eq!(Message::decode(tag, &payload).unwrap(), m);
            // the encoded body (past the 8-byte nonce) is CRC-guarded
            let mut corrupt = payload.clone();
            corrupt[8 + 45] ^= 0xFF;
            assert!(Message::decode(tag, &corrupt).is_err(), "{}", enc.token());
        }
        // too short for the nonce, or an empty/garbage frame: rejected
        assert!(Message::decode(TAG_UPLOAD_ENC, &[0u8; 7]).is_err());
        assert!(Message::decode(TAG_UPLOAD_ENC, &[0u8; 20]).is_err());
    }

    #[test]
    fn rejected_roundtrip() {
        let m = Message::Rejected { party: 99, norm: 123.5 };
        let (tag, payload) = m.encode();
        assert_eq!(tag, TAG_REJECTED);
        assert_eq!(Message::decode(tag, &payload).unwrap(), m);
        assert!(Message::decode(TAG_REJECTED, &[0u8; 11]).is_err());
    }

    #[test]
    fn heartbeat_roundtrip() {
        let m = Message::Heartbeat { party: u64::MAX - 3 };
        let (tag, payload) = m.encode();
        assert_eq!(tag, TAG_HEARTBEAT);
        assert_eq!(Message::decode(tag, &payload).unwrap(), m);
        assert!(Message::decode(TAG_HEARTBEAT, &[0u8; 7]).is_err());
    }

    #[test]
    fn async_ack_roundtrip() {
        let m = Message::AsyncAck { version: 0xAB_CDEF, delta: 3 };
        let (tag, payload) = m.encode();
        assert_eq!(tag, TAG_ASYNC_ACK);
        assert_eq!(Message::decode(tag, &payload).unwrap(), m);
        assert!(Message::decode(TAG_ASYNC_ACK, &[0u8; 7]).is_err());
    }
}
