//! Wire messages of the aggregation protocol.

use crate::tensorstore::{bytes_to_f32s, f32s_as_bytes, ModelUpdate, WireError};

/// 2 GiB frame cap — a single full-size CNN956 update is ~1 GiB; anything
/// larger than this is a corrupt header, rejected before allocation.
pub const MAX_FRAME: usize = 2 << 30;

#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Party announces itself; server replies `Registered`.
    Register { party: u64 },
    Registered { party: u64, round: u32 },
    /// Party uploads its update over the message-passing path.
    Upload(ModelUpdate),
    /// Server ack; `redirect_to_dfs` tells the party to write its NEXT
    /// update to the shared store instead (seamless transition, §III-D3).
    Ack { redirect_to_dfs: bool },
    /// Fetch the fused model of a round.
    GetModel { round: u32 },
    Model { round: u32, weights: Vec<f32> },
    NoModel { round: u32 },
    Error(String),
}

#[derive(Debug)]
pub enum ProtoError {
    Io(std::io::Error),
    UnknownTag(u8),
    FrameTooLarge(usize),
    BadPayload(String),
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Io(e) => write!(f, "io: {e}"),
            ProtoError::UnknownTag(t) => write!(f, "unknown tag {t:#x}"),
            ProtoError::FrameTooLarge(n) => write!(f, "frame too large: {n}"),
            ProtoError::BadPayload(m) => write!(f, "bad payload: {m}"),
        }
    }
}

impl std::error::Error for ProtoError {}

impl From<std::io::Error> for ProtoError {
    fn from(e: std::io::Error) -> Self {
        ProtoError::Io(e)
    }
}

impl From<WireError> for ProtoError {
    fn from(e: WireError) -> Self {
        ProtoError::BadPayload(e.to_string())
    }
}

impl Message {
    /// (tag, payload)
    pub fn encode(&self) -> (u8, Vec<u8>) {
        match self {
            Message::Register { party } => (0x01, party.to_le_bytes().to_vec()),
            Message::Registered { party, round } => {
                let mut p = party.to_le_bytes().to_vec();
                p.extend_from_slice(&round.to_le_bytes());
                (0x02, p)
            }
            Message::Upload(u) => (0x03, u.encode()),
            Message::Ack { redirect_to_dfs } => (0x04, vec![u8::from(*redirect_to_dfs)]),
            Message::GetModel { round } => (0x05, round.to_le_bytes().to_vec()),
            Message::Model { round, weights } => {
                let mut p = round.to_le_bytes().to_vec();
                p.extend_from_slice(f32s_as_bytes(weights));
                (0x06, p)
            }
            Message::NoModel { round } => (0x07, round.to_le_bytes().to_vec()),
            Message::Error(m) => (0x7F, m.as_bytes().to_vec()),
        }
    }

    pub fn decode(tag: u8, payload: &[u8]) -> Result<Message, ProtoError> {
        let need = |n: usize| -> Result<(), ProtoError> {
            if payload.len() < n {
                Err(ProtoError::BadPayload(format!("need {n} bytes, got {}", payload.len())))
            } else {
                Ok(())
            }
        };
        match tag {
            0x01 => {
                need(8)?;
                Ok(Message::Register { party: u64::from_le_bytes(payload[..8].try_into().unwrap()) })
            }
            0x02 => {
                need(12)?;
                Ok(Message::Registered {
                    party: u64::from_le_bytes(payload[..8].try_into().unwrap()),
                    round: u32::from_le_bytes(payload[8..12].try_into().unwrap()),
                })
            }
            0x03 => Ok(Message::Upload(ModelUpdate::decode(payload)?)),
            0x04 => {
                need(1)?;
                Ok(Message::Ack { redirect_to_dfs: payload[0] != 0 })
            }
            0x05 => {
                need(4)?;
                Ok(Message::GetModel { round: u32::from_le_bytes(payload[..4].try_into().unwrap()) })
            }
            0x06 => {
                need(4)?;
                if (payload.len() - 4) % 4 != 0 {
                    return Err(ProtoError::BadPayload("weights not f32-aligned".into()));
                }
                Ok(Message::Model {
                    round: u32::from_le_bytes(payload[..4].try_into().unwrap()),
                    weights: bytes_to_f32s(&payload[4..]),
                })
            }
            0x07 => {
                need(4)?;
                Ok(Message::NoModel { round: u32::from_le_bytes(payload[..4].try_into().unwrap()) })
            }
            0x7F => Ok(Message::Error(String::from_utf8_lossy(payload).into_owned())),
            t => Err(ProtoError::UnknownTag(t)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tags_distinct() {
        let msgs = [
            Message::Register { party: 0 }.encode().0,
            Message::Registered { party: 0, round: 0 }.encode().0,
            Message::Upload(ModelUpdate::new(0, 0.0, 0, vec![])).encode().0,
            Message::Ack { redirect_to_dfs: false }.encode().0,
            Message::GetModel { round: 0 }.encode().0,
            Message::Model { round: 0, weights: vec![] }.encode().0,
            Message::NoModel { round: 0 }.encode().0,
            Message::Error(String::new()).encode().0,
        ];
        let mut set = msgs.to_vec();
        set.sort();
        set.dedup();
        assert_eq!(set.len(), msgs.len());
    }

    #[test]
    fn unknown_tag_rejected() {
        assert!(matches!(Message::decode(0x55, &[]), Err(ProtoError::UnknownTag(0x55))));
    }

    #[test]
    fn short_payload_rejected() {
        assert!(Message::decode(0x01, &[1, 2]).is_err());
        assert!(Message::decode(0x06, &[0, 0, 0, 0, 1]).is_err()); // unaligned weights
    }

    #[test]
    fn upload_carries_crc_protection() {
        let u = ModelUpdate::new(5, 1.0, 2, vec![3.0; 10]);
        let (tag, mut payload) = Message::Upload(u).encode();
        payload[30] ^= 0xFF;
        assert!(Message::decode(tag, &payload).is_err());
    }
}
