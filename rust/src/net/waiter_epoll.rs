//! Linux epoll backend for the reactor's [`Waiter`](super::waiter::Waiter).
//!
//! The repo carries zero dependencies, so this is a minimal hand-written
//! FFI shim over the four syscall wrappers libc exports on every Linux
//! target (`epoll_create1`/`epoll_ctl`/`epoll_wait`/`eventfd`) plus
//! `read`/`write`/`close` — the ABI is stable and identical across
//! glibc/musl.  Level-triggered on purpose: the reactor's pumps read and
//! write until `WouldBlock`, so "still ready" must keep reporting until
//! the socket actually drains — exactly level semantics, and the reason
//! the sweep backend and this one can share one state machine.
//!
//! Interest bookkeeping: a token with no interest is REMOVED from the
//! epoll set (`EPOLL_CTL_DEL`), not left in with an empty mask — epoll
//! always reports `EPOLLHUP`/`EPOLLERR` regardless of the requested mask,
//! so a client that dies while its frame is at a worker would otherwise
//! wake the loop in a hot spin until the reply came back.

use std::collections::HashSet;
use std::io;
use std::sync::Arc;
use std::time::Duration;

use super::waiter::{WaitEvent, TOKEN_NOTIFY};

const EPOLLIN: u32 = 0x001;
const EPOLLOUT: u32 = 0x004;
const EPOLLERR: u32 = 0x008;
const EPOLLHUP: u32 = 0x010;

const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;

/// `O_CLOEXEC`; `EPOLL_CLOEXEC` and `EFD_CLOEXEC` alias it.
const CLOEXEC: i32 = 0o2000000;
/// `O_NONBLOCK`; `EFD_NONBLOCK` aliases it.
const NONBLOCK: i32 = 0o4000;

/// Mirrors `struct epoll_event`.  On x86 the kernel ABI packs it (no
/// padding between the 32-bit mask and the 64-bit data); other
/// architectures use natural alignment.
#[cfg_attr(any(target_arch = "x86", target_arch = "x86_64"), repr(C, packed))]
#[cfg_attr(not(any(target_arch = "x86", target_arch = "x86_64")), repr(C))]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout_ms: i32) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn close(fd: i32) -> i32;
}

/// A nonblocking eventfd: workers `signal` it after sending a completion,
/// the poll loop drains it inside `wait`.  The counter coalesces — any
/// number of signals between waits is one wakeup.
pub(crate) struct EventFd(i32);

impl EventFd {
    fn new() -> io::Result<EventFd> {
        let fd = unsafe { eventfd(0, CLOEXEC | NONBLOCK) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(EventFd(fd))
    }

    pub(crate) fn signal(&self) {
        let one: u64 = 1;
        // EAGAIN (counter saturated) means a wakeup is already pending —
        // exactly what we wanted; nothing to handle.
        let _ = unsafe { write(self.0, (&one as *const u64).cast(), 8) };
    }

    fn drain(&self) {
        let mut buf = [0u8; 8];
        while unsafe { read(self.0, buf.as_mut_ptr(), 8) } == 8 {}
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        let _ = unsafe { close(self.0) };
    }
}

pub(crate) struct EpollWaiter {
    epfd: i32,
    notify: Arc<EventFd>,
    /// Tokens currently in the kernel set — decides ADD vs MOD vs DEL.
    registered: HashSet<u64>,
}

impl EpollWaiter {
    pub(crate) fn new() -> io::Result<EpollWaiter> {
        let epfd = unsafe { epoll_create1(CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        let notify = match EventFd::new() {
            Ok(fd) => Arc::new(fd),
            Err(e) => {
                let _ = unsafe { close(epfd) };
                return Err(e);
            }
        };
        let w = EpollWaiter { epfd, notify, registered: HashSet::new() };
        // On error, Drop closes both fds.
        w.ctl(EPOLL_CTL_ADD, w.notify.0, TOKEN_NOTIFY, EPOLLIN)?;
        Ok(w)
    }

    pub(crate) fn notifier(&self) -> Arc<EventFd> {
        self.notify.clone()
    }

    fn ctl(&self, op: i32, fd: i32, token: u64, mask: u32) -> io::Result<()> {
        let mut ev = EpollEvent { events: mask, data: token };
        if unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) } < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(())
        }
    }

    pub(crate) fn set_interest(
        &mut self,
        fd: i32,
        token: u64,
        read: bool,
        write: bool,
    ) -> io::Result<()> {
        let mask = if read { EPOLLIN } else { 0 } | if write { EPOLLOUT } else { 0 };
        let in_set = self.registered.contains(&token);
        match (in_set, mask != 0) {
            (false, true) => {
                self.ctl(EPOLL_CTL_ADD, fd, token, mask)?;
                self.registered.insert(token);
            }
            (true, true) => self.ctl(EPOLL_CTL_MOD, fd, token, mask)?,
            (true, false) => {
                // No interest: out of the set entirely (see module docs).
                self.ctl(EPOLL_CTL_DEL, fd, token, 0)?;
                self.registered.remove(&token);
            }
            (false, false) => {}
        }
        Ok(())
    }

    pub(crate) fn deregister(&mut self, fd: i32, token: u64) {
        if self.registered.remove(&token) {
            // The fd may already be closed/implicitly removed; best-effort.
            let _ = self.ctl(EPOLL_CTL_DEL, fd, token, 0);
        }
    }

    pub(crate) fn wait(
        &mut self,
        events: &mut Vec<WaitEvent>,
        timeout: Option<Duration>,
    ) -> io::Result<()> {
        let timeout_ms = match timeout {
            None => -1,
            Some(t) => {
                let ms = t.as_millis().min(i32::MAX as u128) as i32;
                // Round sub-millisecond timeouts UP so Some(non-zero)
                // never degenerates into a busy 0ms poll.
                if ms == 0 && !t.is_zero() {
                    1
                } else {
                    ms
                }
            }
        };
        let mut buf = [EpollEvent { events: 0, data: 0 }; 64];
        let n = unsafe { epoll_wait(self.epfd, buf.as_mut_ptr(), buf.len() as i32, timeout_ms) };
        if n < 0 {
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::Interrupted {
                return Ok(()); // empty event set; the loop just re-waits
            }
            return Err(err);
        }
        for ev in &buf[..n as usize] {
            let token = ev.data;
            let mask = ev.events;
            if token == TOKEN_NOTIFY {
                self.notify.drain();
                continue; // internal; the reactor drains done_rx anyway
            }
            events.push(WaitEvent {
                token,
                // HUP/ERR surface as both-ready so whichever pump runs
                // observes the failure and reaps the connection.
                readable: mask & (EPOLLIN | EPOLLHUP | EPOLLERR) != 0,
                writable: mask & (EPOLLOUT | EPOLLHUP | EPOLLERR) != 0,
            });
        }
        Ok(())
    }
}

impl Drop for EpollWaiter {
    fn drop(&mut self) {
        let _ = unsafe { close(self.epfd) };
    }
}
