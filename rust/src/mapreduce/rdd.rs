//! RDD analog: a partitioned view over DFS update files.
//!
//! `binary_files` lists a DFS prefix and packs files into size-balanced
//! partitions (greedy LPT — the effect `binaryFiles` + Spark's split
//! computation has on HDFS blocks).  Decoding a partition yields
//! `ModelUpdate`s; a decoded partition can be pinned in the cache so later
//! stages skip the DFS read (paper: "we also enable caching for smaller
//! model sizes ... caching is not efficient for large models").

use std::sync::{Arc, Mutex};

use crate::dfs::{DfsClient, DfsError};
use crate::memsim::MemoryBudget;
use crate::tensorstore::ModelUpdate;

/// One partition: a set of DFS file paths plus their total bytes.
#[derive(Clone, Debug, Default)]
pub struct Partition {
    pub index: usize,
    pub files: Vec<String>,
    pub bytes: u64,
}

/// A partitioned binary-files dataset with an optional decoded cache.
pub struct BinaryFilesRdd {
    pub partitions: Vec<Partition>,
    dfs: DfsClient,
    cache: Vec<Mutex<Option<Arc<Vec<ModelUpdate>>>>>,
    pub cache_enabled: bool,
}

impl BinaryFilesRdd {
    /// List `prefix` and pack into `n_partitions` size-balanced partitions
    /// (greedy longest-processing-time).
    pub fn binary_files(
        dfs: DfsClient,
        prefix: &str,
        n_partitions: usize,
        cache_enabled: bool,
    ) -> BinaryFilesRdd {
        let mut files = dfs.list(prefix);
        // Largest-first for LPT balance.
        files.sort_by(|a, b| b.len.cmp(&a.len).then(a.path.cmp(&b.path)));
        let n = n_partitions.max(1).min(files.len().max(1));
        let mut parts: Vec<Partition> = (0..n)
            .map(|index| Partition { index, ..Default::default() })
            .collect();
        for f in files {
            // least-loaded partition
            let p = parts.iter_mut().min_by_key(|p| p.bytes).unwrap();
            p.bytes += f.len;
            p.files.push(f.path);
        }
        let cache = (0..n).map(|_| Mutex::new(None)).collect();
        BinaryFilesRdd { partitions: parts, dfs, cache, cache_enabled }
    }

    pub fn num_partitions(&self) -> usize {
        self.partitions.len()
    }

    pub fn total_files(&self) -> usize {
        self.partitions.iter().map(|p| p.files.len()).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.partitions.iter().map(|p| p.bytes).sum()
    }

    /// Decode partition `i`, charging `budget` for the decoded bytes.
    /// Serves from cache when pinned.
    pub fn decode_partition(
        &self,
        i: usize,
        budget: &MemoryBudget,
    ) -> Result<Arc<Vec<ModelUpdate>>, RddError> {
        if self.cache_enabled {
            if let Some(hit) = self.cache[i].lock().unwrap().as_ref() {
                return Ok(hit.clone());
            }
        }
        let part = &self.partitions[i];
        let mut out = Vec::with_capacity(part.files.len());
        let mut reservation = budget.reserve(0).map_err(RddError::Memory)?;
        for path in &part.files {
            let bytes = self.dfs.read(path).map_err(RddError::Dfs)?;
            reservation.grow(bytes.len() as u64).map_err(RddError::Memory)?;
            let u = ModelUpdate::decode(&bytes)
                .map_err(|e| RddError::Decode(path.clone(), e.to_string()))?;
            out.push(u);
        }
        let arc = Arc::new(out);
        if self.cache_enabled {
            // Pinned cache keeps the reservation alive for the RDD's life.
            std::mem::forget(reservation);
            *self.cache[i].lock().unwrap() = Some(arc.clone());
        }
        Ok(arc)
    }

    /// Stream partition `i` file-by-file (O(1 update) memory) — the path
    /// decomposable fusions take.
    pub fn stream_partition<F>(&self, i: usize, mut f: F) -> Result<(), RddError>
    where
        F: FnMut(ModelUpdate),
    {
        // Cache hit still serves streaming requests.
        if self.cache_enabled {
            if let Some(hit) = self.cache[i].lock().unwrap().as_ref() {
                for u in hit.iter() {
                    f(u.clone());
                }
                return Ok(());
            }
        }
        for path in &self.partitions[i].files {
            let bytes = self.dfs.read(path).map_err(RddError::Dfs)?;
            let u = ModelUpdate::decode(&bytes)
                .map_err(|e| RddError::Decode(path.clone(), e.to_string()))?;
            f(u);
        }
        Ok(())
    }

    /// Whether partition `i` is currently cached.
    pub fn is_cached(&self, i: usize) -> bool {
        self.cache[i].lock().unwrap().is_some()
    }
}

#[derive(Debug)]
pub enum RddError {
    Dfs(DfsError),
    Memory(crate::memsim::OutOfMemory),
    Decode(String, String),
}

impl std::fmt::Display for RddError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RddError::Dfs(e) => write!(f, "dfs: {e}"),
            RddError::Memory(e) => write!(f, "memory: {e}"),
            RddError::Decode(p, e) => write!(f, "decode {p}: {e}"),
        }
    }
}

impl std::error::Error for RddError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfs::datanode::tempdir::TempDir;
    use crate::dfs::NameNode;
    use crate::metrics::Breakdown;

    fn store_with_updates(n: usize, len: usize) -> (DfsClient, TempDir) {
        let td = TempDir::new();
        let nn = NameNode::create(td.path(), 2, 1, 1 << 20).unwrap();
        let c = DfsClient::new(nn);
        let mut bd = Breakdown::new();
        for p in 0..n as u64 {
            let u = ModelUpdate::new(p, 1.0 + p as f32, 0, vec![p as f32; len]);
            c.put_update(&u, &mut bd).unwrap();
        }
        (c, td)
    }

    #[test]
    fn partitions_are_size_balanced() {
        let (c, _td) = store_with_updates(20, 100);
        let rdd = BinaryFilesRdd::binary_files(c, "/rounds/0/updates/", 4, false);
        assert_eq!(rdd.num_partitions(), 4);
        assert_eq!(rdd.total_files(), 20);
        let sizes: Vec<u64> = rdd.partitions.iter().map(|p| p.bytes).collect();
        let min = *sizes.iter().min().unwrap();
        let max = *sizes.iter().max().unwrap();
        assert!(max - min <= 500, "{sizes:?}");
    }

    #[test]
    fn more_partitions_than_files_clamps() {
        let (c, _td) = store_with_updates(3, 10);
        let rdd = BinaryFilesRdd::binary_files(c, "/rounds/0/updates/", 16, false);
        assert_eq!(rdd.num_partitions(), 3);
    }

    #[test]
    fn decode_yields_all_updates() {
        let (c, _td) = store_with_updates(6, 50);
        let rdd = BinaryFilesRdd::binary_files(c, "/rounds/0/updates/", 2, false);
        let b = MemoryBudget::unbounded();
        let mut total = 0;
        for i in 0..rdd.num_partitions() {
            total += rdd.decode_partition(i, &b).unwrap().len();
        }
        assert_eq!(total, 6);
    }

    #[test]
    fn cache_serves_second_read() {
        let (c, _td) = store_with_updates(4, 50);
        let rdd = BinaryFilesRdd::binary_files(c, "/rounds/0/updates/", 1, true);
        let b = MemoryBudget::unbounded();
        assert!(!rdd.is_cached(0));
        let first = rdd.decode_partition(0, &b).unwrap();
        assert!(rdd.is_cached(0));
        let second = rdd.decode_partition(0, &b).unwrap();
        assert!(Arc::ptr_eq(&first, &second));
    }

    #[test]
    fn decode_respects_memory_budget() {
        let (c, _td) = store_with_updates(4, 1000);
        let rdd = BinaryFilesRdd::binary_files(c, "/rounds/0/updates/", 1, false);
        let b = MemoryBudget::new(2000); // < 4 * ~4 KB
        assert!(matches!(
            rdd.decode_partition(0, &b),
            Err(RddError::Memory(_))
        ));
    }

    #[test]
    fn stream_partition_visits_all() {
        let (c, _td) = store_with_updates(5, 20);
        let rdd = BinaryFilesRdd::binary_files(c, "/rounds/0/updates/", 2, false);
        let mut seen = 0;
        for i in 0..rdd.num_partitions() {
            rdd.stream_partition(i, |_| seen += 1).unwrap();
        }
        assert_eq!(seen, 5);
    }

    #[test]
    fn empty_prefix_single_empty_partition() {
        let (c, _td) = store_with_updates(0, 0);
        let rdd = BinaryFilesRdd::binary_files(c, "/rounds/9/updates/", 4, false);
        assert_eq!(rdd.num_partitions(), 1);
        assert_eq!(rdd.total_files(), 0);
    }
}
