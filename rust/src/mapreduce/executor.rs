//! The executor pool: Yarn-container analog.
//!
//! Each executor owns `cores` worker threads and a [`MemoryBudget`] (the
//! paper caps containers at 35 GB).  Spin-up charges a configurable
//! startup delay — the paper measures ~30 s to start 10 executors of
//! 30 GB / 3 cores, which the `ablations` bench reproduces through the
//! cluster cost model.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::memsim::MemoryBudget;

/// Executor container geometry.
#[derive(Clone, Debug)]
pub struct ExecutorConfig {
    pub executors: usize,
    pub cores_per_executor: usize,
    pub mem_per_executor: u64,
    /// Real startup delay per pool (simulating context/container spin-up).
    pub startup: std::time::Duration,
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        ExecutorConfig {
            executors: 2,
            cores_per_executor: 2,
            mem_per_executor: 1 << 30,
            startup: std::time::Duration::ZERO,
        }
    }
}

type Task = Box<dyn FnOnce(&TaskCtx) + Send>;

/// What a task sees: its executor's identity and memory budget.
pub struct TaskCtx {
    pub executor_id: usize,
    pub core_id: usize,
    pub memory: MemoryBudget,
}

struct Shared {
    rx: Mutex<Receiver<Task>>,
}

/// A pool of `executors × cores_per_executor` worker threads.
pub struct ExecutorPool {
    tx: Option<Sender<Task>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    config: ExecutorConfig,
    budgets: Vec<MemoryBudget>,
    in_flight: Arc<AtomicUsize>,
}

impl ExecutorPool {
    /// Spin up the pool (blocks for `config.startup` — the context cost the
    /// paper's §III-D3 "seamless transition" discussion accounts for).
    pub fn start(config: ExecutorConfig) -> ExecutorPool {
        std::thread::sleep(config.startup);
        let budgets: Vec<MemoryBudget> = (0..config.executors)
            .map(|_| MemoryBudget::new(config.mem_per_executor))
            .collect();
        let (tx, rx) = channel::<Task>();
        let shared = Arc::new(Shared { rx: Mutex::new(rx) });
        let in_flight = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::new();
        for e in 0..config.executors {
            for c in 0..config.cores_per_executor {
                let shared = shared.clone();
                let budget = budgets[e].clone();
                let in_flight = in_flight.clone();
                workers.push(std::thread::spawn(move || {
                    let ctx = TaskCtx { executor_id: e, core_id: c, memory: budget };
                    loop {
                        let task = {
                            let rx = shared.rx.lock().unwrap();
                            rx.recv()
                        };
                        match task {
                            Ok(t) => {
                                t(&ctx);
                                in_flight.fetch_sub(1, Ordering::AcqRel);
                            }
                            Err(_) => break, // pool shut down
                        }
                    }
                }));
            }
        }
        ExecutorPool { tx: Some(tx), workers, config, budgets, in_flight }
    }

    pub fn total_cores(&self) -> usize {
        self.config.executors * self.config.cores_per_executor
    }

    pub fn config(&self) -> &ExecutorConfig {
        &self.config
    }

    pub fn budget(&self, executor: usize) -> &MemoryBudget {
        &self.budgets[executor]
    }

    /// Submit a task; runs on any free worker.
    pub fn submit<F>(&self, f: F)
    where
        F: FnOnce(&TaskCtx) + Send + 'static,
    {
        self.in_flight.fetch_add(1, Ordering::AcqRel);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers gone");
    }

    /// Busy-ish wait until every submitted task finished.
    pub fn join(&self) {
        while self.in_flight.load(Ordering::Acquire) > 0 {
            std::thread::yield_now();
        }
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_tasks() {
        let pool = ExecutorPool::start(ExecutorConfig {
            executors: 2,
            cores_per_executor: 2,
            ..Default::default()
        });
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move |_| {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn tasks_see_executor_identity_and_budget() {
        let pool = ExecutorPool::start(ExecutorConfig {
            executors: 3,
            cores_per_executor: 1,
            mem_per_executor: 12345,
            ..Default::default()
        });
        let seen = Arc::new(Mutex::new(Vec::new()));
        for _ in 0..6 {
            let seen = seen.clone();
            pool.submit(move |ctx| {
                assert_eq!(ctx.memory.budget(), 12345);
                seen.lock().unwrap().push(ctx.executor_id);
            });
        }
        pool.join();
        let seen = seen.lock().unwrap();
        assert_eq!(seen.len(), 6);
        assert!(seen.iter().all(|e| *e < 3));
    }

    #[test]
    fn join_with_no_tasks_returns() {
        let pool = ExecutorPool::start(ExecutorConfig::default());
        pool.join();
    }

    #[test]
    fn startup_delay_applied() {
        let t0 = std::time::Instant::now();
        let _pool = ExecutorPool::start(ExecutorConfig {
            startup: std::time::Duration::from_millis(30),
            ..Default::default()
        });
        assert!(t0.elapsed() >= std::time::Duration::from_millis(30));
    }

    #[test]
    fn executor_budgets_are_independent() {
        let pool = ExecutorPool::start(ExecutorConfig {
            executors: 2,
            cores_per_executor: 1,
            mem_per_executor: 100,
            ..Default::default()
        });
        let r = pool.budget(0).reserve(100).unwrap();
        assert!(pool.budget(0).reserve(1).is_err());
        assert!(pool.budget(1).reserve(100).is_ok());
        drop(r);
    }
}
