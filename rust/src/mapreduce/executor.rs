//! The executor pool: Yarn-container analog, now *elastic*.
//!
//! Each executor owns `cores` worker threads and a [`MemoryBudget`] (the
//! paper caps containers at 35 GB).  Spin-up charges a configurable
//! startup delay — the paper measures ~30 s to start 10 executors of
//! 30 GB / 3 cores, which the `ablations` bench reproduces through the
//! cluster cost model.
//!
//! [`ExecutorPool::scale_to`] grows or shrinks the pool *in place* between
//! rounds (the autoscaler's hook): growing spawns additional executors
//! (paying the startup delay once per scale event, not per job), shrinking
//! retires the highest-indexed workers after their current task.  Workers
//! poll a shared shrink watermark between tasks, so a shrink completes
//! within one poll interval without tearing down the whole pool — the
//! "elastic" alternative to static re-provisioning.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::memsim::MemoryBudget;

/// Executor container geometry.
#[derive(Clone, Debug)]
pub struct ExecutorConfig {
    /// Initial executor count (the pool can be rescaled later).
    pub executors: usize,
    pub cores_per_executor: usize,
    pub mem_per_executor: u64,
    /// Real startup delay per scale-up event (simulating context/container
    /// spin-up).
    pub startup: std::time::Duration,
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        ExecutorConfig {
            executors: 2,
            cores_per_executor: 2,
            mem_per_executor: 1 << 30,
            startup: std::time::Duration::ZERO,
        }
    }
}

type Task = Box<dyn FnOnce(&TaskCtx) + Send>;

/// What a task sees: its executor's identity and memory budget.
pub struct TaskCtx {
    /// Stable container index in `0..executors()`.  Workers retire from
    /// the top on a shrink and regrow reuses the same ids, so a task's
    /// `executor_id` is always a valid index into any per-executor state
    /// sized at submit time — the contract the scheduler's combiner slots
    /// (one partial [`Accumulator`](crate::fusion::Accumulator) per
    /// executor) index by.
    pub executor_id: usize,
    pub core_id: usize,
    pub memory: MemoryBudget,
}

/// How long an idle worker waits on the queue before re-checking the
/// shrink watermark.  Task pickup is NOT delayed by this — `recv_timeout`
/// wakes the moment a task arrives; the interval only bounds how long a
/// retiring worker can linger (shrinks also inject wake-up sentinels, so
/// in practice retirement is immediate) and keeps the idle wake-up rate
/// negligible (~25/s per worker).
const POLL_INTERVAL: Duration = Duration::from_millis(40);

struct Shared {
    rx: Mutex<Receiver<Task>>,
    /// Workers whose global core index is >= this exit after their current
    /// task (the elastic-shrink watermark; also the live core count).
    target_cores: AtomicUsize,
}

struct PoolInner {
    /// Worker handles in global core-index order (executor-major).
    workers: Vec<std::thread::JoinHandle<()>>,
    budgets: Vec<MemoryBudget>,
    executors: usize,
}

/// A pool of `executors × cores_per_executor` worker threads that can be
/// resized between jobs.
pub struct ExecutorPool {
    tx: Option<Sender<Task>>,
    shared: Arc<Shared>,
    inner: Mutex<PoolInner>,
    base: ExecutorConfig,
    in_flight: Arc<AtomicUsize>,
}

impl ExecutorPool {
    /// Spin up the pool (blocks for `config.startup` — the context cost the
    /// paper's §III-D3 "seamless transition" discussion accounts for).
    pub fn start(config: ExecutorConfig) -> ExecutorPool {
        std::thread::sleep(config.startup);
        let (tx, rx) = channel::<Task>();
        let pool = ExecutorPool {
            tx: Some(tx),
            shared: Arc::new(Shared {
                rx: Mutex::new(rx),
                target_cores: AtomicUsize::new(0),
            }),
            inner: Mutex::new(PoolInner {
                workers: Vec::new(),
                budgets: Vec::new(),
                executors: 0,
            }),
            in_flight: Arc::new(AtomicUsize::new(0)),
            base: config,
        };
        {
            let mut inner = pool.inner.lock().unwrap();
            let to = pool.base.executors;
            pool.grow_locked(&mut inner, to);
        }
        pool
    }

    fn spawn_worker(
        &self,
        executor_id: usize,
        core_id: usize,
        budget: MemoryBudget,
    ) -> std::thread::JoinHandle<()> {
        let shared = self.shared.clone();
        let in_flight = self.in_flight.clone();
        let my_core = executor_id * self.base.cores_per_executor + core_id;
        std::thread::spawn(move || {
            let ctx = TaskCtx { executor_id, core_id, memory: budget };
            loop {
                if my_core >= shared.target_cores.load(Ordering::Acquire) {
                    break; // retired by a shrink
                }
                let task = {
                    let rx = shared.rx.lock().unwrap();
                    rx.recv_timeout(POLL_INTERVAL)
                };
                match task {
                    Ok(t) => {
                        t(&ctx);
                        in_flight.fetch_sub(1, Ordering::AcqRel);
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => break, // pool shut down
                }
            }
        })
    }

    /// Grow to `to` executors; caller holds the inner lock.  The watermark
    /// is raised *before* spawning so new workers don't see a stale target
    /// and exit immediately.
    fn grow_locked(&self, inner: &mut PoolInner, to: usize) {
        self.shared
            .target_cores
            .store(to * self.base.cores_per_executor, Ordering::Release);
        for e in inner.executors..to {
            let budget = MemoryBudget::new(self.base.mem_per_executor);
            inner.budgets.push(budget.clone());
            for c in 0..self.base.cores_per_executor {
                let h = self.spawn_worker(e, c, budget.clone());
                inner.workers.push(h);
            }
        }
        inner.executors = to;
    }

    /// Elastically resize the pool to `executors` containers (min 1).
    /// Growing pays the configured startup delay once per event; shrinking
    /// retires the highest-indexed workers after their current task and
    /// joins them.  Queued tasks are unaffected — survivors drain them.
    /// Returns the pool size after the resize.
    pub fn scale_to(&self, executors: usize) -> usize {
        let to = executors.max(1);
        let mut inner = self.inner.lock().unwrap();
        let cur = inner.executors;
        if to > cur {
            std::thread::sleep(self.base.startup);
            self.grow_locked(&mut inner, to);
        } else if to < cur {
            let keep = to * self.base.cores_per_executor;
            self.shared.target_cores.store(keep, Ordering::Release);
            // Wake idle workers with no-op sentinels so retirees notice the
            // watermark now instead of after a poll interval.  Survivors
            // may eat some sentinels — harmless; the poll is the backstop.
            for _ in keep..inner.workers.len() {
                self.submit(|_| {});
            }
            for h in inner.workers.drain(keep..) {
                let _ = h.join();
            }
            inner.budgets.truncate(to);
            inner.executors = to;
        }
        inner.executors
    }

    /// Current executor-container count.
    pub fn executors(&self) -> usize {
        self.inner.lock().unwrap().executors
    }

    /// Live worker-thread count (`executors × cores_per_executor`).
    pub fn total_cores(&self) -> usize {
        self.shared.target_cores.load(Ordering::Acquire)
    }

    /// The geometry the pool was started with (`executors` is the initial
    /// count — see [`ExecutorPool::executors`] for the live one).
    pub fn config(&self) -> &ExecutorConfig {
        &self.base
    }

    pub fn budget(&self, executor: usize) -> MemoryBudget {
        self.inner.lock().unwrap().budgets[executor].clone()
    }

    /// Submit a task; runs on any free worker.
    pub fn submit<F>(&self, f: F)
    where
        F: FnOnce(&TaskCtx) + Send + 'static,
    {
        self.in_flight.fetch_add(1, Ordering::AcqRel);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers gone");
    }

    /// Busy-ish wait until every submitted task finished.
    pub fn join(&self) {
        while self.in_flight.load(Ordering::Acquire) > 0 {
            std::thread::yield_now();
        }
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // disconnects the queue; workers exit
        let mut inner = self.inner.lock().unwrap();
        for w in inner.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_tasks() {
        let pool = ExecutorPool::start(ExecutorConfig {
            executors: 2,
            cores_per_executor: 2,
            ..Default::default()
        });
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move |_| {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn tasks_see_executor_identity_and_budget() {
        let pool = ExecutorPool::start(ExecutorConfig {
            executors: 3,
            cores_per_executor: 1,
            mem_per_executor: 12345,
            ..Default::default()
        });
        let seen = Arc::new(Mutex::new(Vec::new()));
        for _ in 0..6 {
            let seen = seen.clone();
            pool.submit(move |ctx| {
                assert_eq!(ctx.memory.budget(), 12345);
                seen.lock().unwrap().push(ctx.executor_id);
            });
        }
        pool.join();
        let seen = seen.lock().unwrap();
        assert_eq!(seen.len(), 6);
        assert!(seen.iter().all(|e| *e < 3));
    }

    #[test]
    fn join_with_no_tasks_returns() {
        let pool = ExecutorPool::start(ExecutorConfig::default());
        pool.join();
    }

    #[test]
    fn startup_delay_applied() {
        let t0 = std::time::Instant::now();
        let _pool = ExecutorPool::start(ExecutorConfig {
            startup: std::time::Duration::from_millis(30),
            ..Default::default()
        });
        assert!(t0.elapsed() >= std::time::Duration::from_millis(30));
    }

    #[test]
    fn executor_budgets_are_independent() {
        let pool = ExecutorPool::start(ExecutorConfig {
            executors: 2,
            cores_per_executor: 1,
            mem_per_executor: 100,
            ..Default::default()
        });
        let r = pool.budget(0).reserve(100).unwrap();
        assert!(pool.budget(0).reserve(1).is_err());
        assert!(pool.budget(1).reserve(100).is_ok());
        drop(r);
    }

    #[test]
    fn scale_up_adds_live_executors() {
        let pool = ExecutorPool::start(ExecutorConfig {
            executors: 1,
            cores_per_executor: 1,
            mem_per_executor: 777,
            ..Default::default()
        });
        assert_eq!(pool.scale_to(3), 3);
        assert_eq!(pool.executors(), 3);
        assert_eq!(pool.total_cores(), 3);
        assert_eq!(pool.budget(2).budget(), 777);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..30 {
            let c = counter.clone();
            pool.submit(move |ctx| {
                assert!(ctx.executor_id < 3);
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 30);
    }

    #[test]
    fn scale_down_retires_high_executors() {
        let pool = ExecutorPool::start(ExecutorConfig {
            executors: 3,
            cores_per_executor: 1,
            ..Default::default()
        });
        assert_eq!(pool.scale_to(1), 1);
        assert_eq!(pool.executors(), 1);
        assert_eq!(pool.total_cores(), 1);
        // the surviving worker still drains the queue, and only it
        let seen = Arc::new(Mutex::new(Vec::new()));
        for _ in 0..10 {
            let seen = seen.clone();
            pool.submit(move |ctx| {
                seen.lock().unwrap().push(ctx.executor_id);
            });
        }
        pool.join();
        let seen = seen.lock().unwrap();
        assert_eq!(seen.len(), 10);
        assert!(seen.iter().all(|e| *e == 0), "{seen:?}");
    }

    #[test]
    fn scale_is_idempotent_and_clamped() {
        let pool = ExecutorPool::start(ExecutorConfig {
            executors: 2,
            cores_per_executor: 2,
            ..Default::default()
        });
        assert_eq!(pool.scale_to(2), 2);
        assert_eq!(pool.scale_to(0), 1); // clamped to the warm floor
        assert_eq!(pool.executors(), 1);
    }

    #[test]
    fn executor_ids_always_index_per_executor_state() {
        // The combiner contract: every task's executor_id is a valid index
        // into a per-executor slot vector sized when the job starts — even
        // across shrink/regrow cycles.
        let pool = ExecutorPool::start(ExecutorConfig {
            executors: 3,
            cores_per_executor: 2,
            ..Default::default()
        });
        for live in [3usize, 1, 4] {
            pool.scale_to(live);
            let slots: Arc<Vec<AtomicU64>> =
                Arc::new((0..pool.executors()).map(|_| AtomicU64::new(0)).collect());
            for _ in 0..24 {
                let slots = slots.clone();
                pool.submit(move |ctx| {
                    slots[ctx.executor_id].fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.join();
            let total: u64 = slots.iter().map(|s| s.load(Ordering::Relaxed)).sum();
            assert_eq!(total, 24, "live={live}");
        }
    }

    #[test]
    fn regrow_after_shrink_reuses_executor_ids() {
        let pool = ExecutorPool::start(ExecutorConfig {
            executors: 2,
            cores_per_executor: 1,
            ..Default::default()
        });
        pool.scale_to(1);
        pool.scale_to(4);
        assert_eq!(pool.executors(), 4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..40 {
            let c = counter.clone();
            pool.submit(move |ctx| {
                assert!(ctx.executor_id < 4);
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 40);
    }
}
