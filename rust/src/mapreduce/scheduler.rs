//! The job driver: stages, task retry, speculative re-execution, and the
//! paper's phase breakdown (read/partition → sum → reduce).
//!
//! Aggregation job shape (mirrors the paper's PySpark implementation):
//!
//! 1. **read_partition** — `binary_files` lists the round prefix and packs
//!    size-balanced partitions (Fig 4 step ④);
//! 2. **sum** — a light pass extracting `n_total` (Fig 7 "sum time"; for
//!    small models the decoded partitions are cached so later stages reuse
//!    them);
//! 3. **reduce** — map tasks fold their partition into a partial
//!    [`Accumulator`] (streamed file-by-file for decomposable fusions, so
//!    executor memory stays O(update)), each partial then merges into a
//!    per-executor *combiner* slot before anything moves driver-ward —
//!    the reducer merges one partial per executor instead of one per
//!    partition, cutting shuffle volume from `partitions × C` to
//!    `executors × C` (the `combiner_saved` counter records the cut) —
//!    and the surviving partials combine and finalize (Fig 4 step ⑤).
//!
//! Failed tasks are retried up to `max_retries` (replica fallback in the
//! DFS absorbs single-datanode failures; retry absorbs transient ones).
//! Speculative execution re-launches the slowest stragglers once the stage
//! is nearly drained, keeping the first result to finish.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use super::executor::{ExecutorConfig, ExecutorPool};
use super::rdd::BinaryFilesRdd;
use crate::dfs::DfsClient;
use crate::fusion::{Accumulator, FusionAlgorithm, FusionError};
use crate::metrics::{Breakdown, Counters, Stopwatch};
use crate::tensorstore::ModelUpdate;

#[derive(Debug)]
pub enum JobError {
    Fusion(FusionError),
    TaskFailed { partition: usize, attempts: usize, last: String },
    NoUpdates,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobError::Fusion(e) => write!(f, "fusion: {e}"),
            JobError::TaskFailed { partition, attempts, last } => {
                write!(f, "partition {partition} failed after {attempts} attempts: {last}")
            }
            JobError::NoUpdates => write!(f, "no updates under prefix"),
        }
    }
}

impl std::error::Error for JobError {}

/// Driver configuration.
#[derive(Clone, Debug)]
pub struct JobConfig {
    pub max_retries: usize,
    /// Delay before each retry wave (transient faults need time to clear).
    pub retry_backoff: std::time::Duration,
    /// Enable speculative re-execution of stragglers.
    pub speculation: bool,
    /// Cache decoded partitions (the paper: on for small models).
    pub cache: bool,
    pub partitions: Option<usize>,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig {
            max_retries: 3,
            retry_backoff: std::time::Duration::from_millis(5),
            speculation: false,
            cache: true,
            partitions: None,
        }
    }
}

/// The Spark-context analog: owns the executor pool and runs jobs.
pub struct SparkContext {
    pool: ExecutorPool,
    dfs: DfsClient,
    pub counters: Mutex<Counters>,
}

impl SparkContext {
    pub fn start(dfs: DfsClient, config: ExecutorConfig) -> SparkContext {
        SparkContext {
            pool: ExecutorPool::start(config),
            dfs,
            counters: Mutex::new(Counters::new()),
        }
    }

    pub fn total_cores(&self) -> usize {
        self.pool.total_cores()
    }

    /// Live executor-container count (the pool is elastic).
    pub fn current_executors(&self) -> usize {
        self.pool.executors()
    }

    /// Elastically resize the executor pool between jobs — the
    /// autoscaler's hook.  Subsequent jobs partition against the new
    /// width.  Returns the pool size after the resize and counts a
    /// `scale_events` tick when the size actually changed.
    pub fn scale_to(&self, executors: usize) -> usize {
        let before = self.pool.executors();
        let after = self.pool.scale_to(executors);
        if after != before {
            self.counters.lock().unwrap().inc("scale_events", 1);
        }
        after
    }

    pub fn dfs(&self) -> &DfsClient {
        &self.dfs
    }

    /// Run the full aggregation job over every update under `prefix`.
    /// Returns fused weights; fills `bd` with the paper's phase breakdown
    /// and `partitions_out` with the partition count (Fig 12 reports it).
    pub fn aggregate(
        &self,
        algo: &dyn FusionAlgorithm,
        prefix: &str,
        cfg: &JobConfig,
        bd: &mut Breakdown,
    ) -> Result<(Vec<f32>, usize), JobError> {
        let mut sw = Stopwatch::start();

        // Stage 1: read + partition (binaryFiles).
        let nparts = cfg
            .partitions
            .unwrap_or_else(|| super::default_partitions(self.dfs.list(prefix).len(), self.total_cores()));
        let rdd = Arc::new(BinaryFilesRdd::binary_files(
            self.dfs.clone(),
            prefix,
            nparts,
            cfg.cache,
        ));
        if rdd.total_files() == 0 {
            return Err(JobError::NoUpdates);
        }
        let nparts = rdd.num_partitions();
        sw.lap_into(bd, "read_partition");

        if algo.decomposable() {
            // Stage 2: sum — extract n_total (and warm the cache).
            let totals = self.run_stage(cfg, nparts, {
                let rdd = rdd.clone();
                move |p, ctx: &super::executor::TaskCtx| {
                    let mut wtot = 0f64;
                    if cfg_cache_should_decode(&rdd) {
                        let dec = rdd
                            .decode_partition(p, &ctx.memory)
                            .map_err(|e| e.to_string())?;
                        for u in dec.iter() {
                            wtot += u.count as f64;
                        }
                    } else {
                        rdd.stream_partition(p, |u| wtot += u.count as f64)
                            .map_err(|e| e.to_string())?;
                    }
                    Ok(wtot)
                }
            })?;
            let _n_total: f64 = totals.iter().sum();
            sw.lap_into(bd, "sum");

            // Stage 3: reduce — partial accumulators per partition, folded
            // combiner-style into one slot per executor before the driver
            // merge, then combine + finalize.
            // Erase the lifetime: the stage joins the pool before
            // returning, so no task outlives `algo` (see AlgoRef docs).
            let algo_ptr = AlgoRef(unsafe {
                std::mem::transmute::<&dyn FusionAlgorithm, &'static dyn FusionAlgorithm>(algo)
            });
            let partials = self.run_reduce_combined(cfg, nparts, algo_ptr, rdd.clone())?;
            self.counters
                .lock()
                .unwrap()
                .inc("combiner_partials", partials.len() as u64);
            if nparts > partials.len() {
                // shuffle volume cut: partition-partials merged executor-
                // locally instead of travelling to the driver individually
                self.counters
                    .lock()
                    .unwrap()
                    .inc("combiner_saved", (nparts - partials.len()) as u64);
            }
            let mut it = partials.into_iter();
            let mut acc = it.next().ok_or(JobError::NoUpdates)?;
            for p in it {
                if p.sum.len() != acc.sum.len() {
                    return Err(JobError::Fusion(FusionError::ShapeMismatch {
                        want: acc.sum.len(),
                        got: p.sum.len(),
                    }));
                }
                algo.combine(&mut acc, &p);
            }
            let out = algo.finalize(acc);
            sw.lap_into(bd, "reduce");
            Ok((out, nparts))
        } else {
            // Holistic: gather decoded partitions at the driver then fuse.
            let gathered = self.run_stage(cfg, nparts, {
                let rdd = rdd.clone();
                move |p, ctx| {
                    rdd.decode_partition(p, &ctx.memory)
                        .map(|a| a.as_ref().clone())
                        .map_err(|e| e.to_string())
                }
            })?;
            sw.lap_into(bd, "sum");
            let all: Vec<ModelUpdate> = gathered.into_iter().flatten().collect();
            let refs: Vec<&ModelUpdate> = all.iter().collect();
            let out = algo.holistic(&refs).map_err(JobError::Fusion)?;
            sw.lap_into(bd, "reduce");
            Ok((out, nparts))
        }
    }

    /// The reduce stage with executor-local combining: each partition task
    /// folds its files into a partial [`Accumulator`] and merges it into
    /// its executor's combiner slot on the spot, so at most one partial
    /// per *executor* (not per partition) survives to the driver merge —
    /// the shuffle-volume cut a Spark combiner buys.  Retry and
    /// speculation mirror [`SparkContext::run_stage`]; the per-partition
    /// `done` flag is flipped inside the slot lock, so a speculative
    /// duplicate can never double-fold a partition.
    ///
    /// Determinism note: partials merge in task-completion order, so two
    /// identical runs can regroup the float additions differently — like
    /// real Spark combiners, results are reproducible to tolerance (the
    /// combine-associativity property the fusion tests pin down), not to
    /// the bit.  Bit-exact reproducibility lives on the single-node paths.
    fn run_reduce_combined(
        &self,
        cfg: &JobConfig,
        n: usize,
        algo_ptr: AlgoRef,
        rdd: Arc<BinaryFilesRdd>,
    ) -> Result<Vec<Accumulator>, JobError> {
        let executors = self.pool.executors().max(1);
        let combiners: Arc<Vec<Mutex<Option<Accumulator>>>> =
            Arc::new((0..executors).map(|_| Mutex::new(None)).collect());
        let done: Arc<Vec<AtomicBool>> =
            Arc::new((0..n).map(|_| AtomicBool::new(false)).collect());
        let errs: Arc<Mutex<Vec<Option<String>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));

        let launch = |p: usize| {
            let combiners = combiners.clone();
            let done = done.clone();
            let errs = errs.clone();
            let rdd = rdd.clone();
            self.pool.submit(move |ctx| {
                if done[p].load(Ordering::Acquire) {
                    return; // speculative duplicate lost the race
                }
                let algo = algo_ptr.get();
                // Fold this partition into a local partial (streamed
                // file-by-file unless the RDD caches decoded partitions).
                let mut acc: Option<Accumulator> = None;
                let fold = |acc: &mut Option<Accumulator>, u: ModelUpdate| {
                    let a = acc.get_or_insert_with(|| Accumulator::zeros(u.data.len()));
                    if a.sum.len() == u.data.len() {
                        algo.accumulate(a, &u);
                    }
                };
                let r: Result<(), String> = if cfg_cache_should_decode(&rdd) {
                    rdd.decode_partition(p, &ctx.memory).map_err(|e| e.to_string()).map(|dec| {
                        for u in dec.iter() {
                            fold(&mut acc, u.clone());
                        }
                    })
                } else {
                    rdd.stream_partition(p, |u| fold(&mut acc, u)).map_err(|e| e.to_string())
                };
                let partial = match (r, acc) {
                    (Err(e), _) => {
                        if !done[p].load(Ordering::Acquire) {
                            errs.lock().unwrap()[p] = Some(e);
                        }
                        return;
                    }
                    (Ok(()), None) => {
                        if !done[p].load(Ordering::Acquire) {
                            errs.lock().unwrap()[p] = Some("empty partition".to_string());
                        }
                        return;
                    }
                    (Ok(()), Some(a)) => a,
                };
                // Executor-local combine, exactly once per partition: the
                // done flag is checked and flipped under the slot lock.
                let mut slot = combiners[ctx.executor_id % combiners.len()].lock().unwrap();
                if done[p].load(Ordering::Acquire) {
                    return;
                }
                match slot.as_mut() {
                    None => *slot = Some(partial),
                    Some(acc) if acc.sum.len() == partial.sum.len() => {
                        algo.combine(acc, &partial);
                    }
                    Some(acc) => {
                        errs.lock().unwrap()[p] = Some(
                            FusionError::ShapeMismatch {
                                want: acc.sum.len(),
                                got: partial.sum.len(),
                            }
                            .to_string(),
                        );
                        return;
                    }
                }
                done[p].store(true, Ordering::Release);
            });
        };

        for attempt in 0..=cfg.max_retries {
            let pending: Vec<usize> = (0..n).filter(|p| !done[*p].load(Ordering::Acquire)).collect();
            if pending.is_empty() {
                break;
            }
            if attempt > 0 {
                self.counters
                    .lock()
                    .unwrap()
                    .inc("tasks_retried", pending.len() as u64);
                std::thread::sleep(cfg.retry_backoff);
            }
            for p in &pending {
                launch(*p);
            }
            self.pool.join();
            if cfg.speculation {
                let stragglers: Vec<usize> =
                    (0..n).filter(|p| !done[*p].load(Ordering::Acquire)).collect();
                if !stragglers.is_empty() {
                    self.counters
                        .lock()
                        .unwrap()
                        .inc("tasks_speculated", stragglers.len() as u64);
                    for p in stragglers {
                        launch(p);
                    }
                    self.pool.join();
                }
            }
        }

        if let Some(p) = (0..n).find(|p| !done[*p].load(Ordering::Acquire)) {
            let last = errs.lock().unwrap()[p].take().unwrap_or_else(|| "never completed".into());
            return Err(JobError::TaskFailed {
                partition: p,
                attempts: cfg.max_retries + 1,
                last,
            });
        }
        Ok(combiners
            .iter()
            .filter_map(|slot| slot.lock().unwrap().take())
            .collect())
    }

    /// Run one stage of `n` partition-indexed tasks with retry +
    /// speculation; returns per-partition results in index order.
    fn run_stage<T, F>(&self, cfg: &JobConfig, n: usize, task: F) -> Result<Vec<T>, JobError>
    where
        T: Send + 'static,
        F: Fn(usize, &super::executor::TaskCtx) -> Result<T, String> + Send + Sync + 'static,
    {
        let task = Arc::new(task);
        let results: Arc<Mutex<Vec<Option<Result<T, String>>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let done: Arc<Vec<AtomicBool>> =
            Arc::new((0..n).map(|_| AtomicBool::new(false)).collect());

        let launch = |p: usize| {
            let task = task.clone();
            let results = results.clone();
            let done = done.clone();
            self.pool.submit(move |ctx| {
                if done[p].load(Ordering::Acquire) {
                    return; // speculative duplicate lost the race
                }
                let r = task(p, ctx);
                let mut res = results.lock().unwrap();
                if !done[p].swap(r.is_ok(), Ordering::AcqRel) {
                    res[p] = Some(r);
                }
            });
        };

        for attempt in 0..=cfg.max_retries {
            let pending: Vec<usize> = (0..n).filter(|p| !done[*p].load(Ordering::Acquire)).collect();
            if pending.is_empty() {
                break;
            }
            if attempt > 0 {
                self.counters
                    .lock()
                    .unwrap()
                    .inc("tasks_retried", pending.len() as u64);
                std::thread::sleep(cfg.retry_backoff);
            }
            for p in &pending {
                launch(*p);
            }
            self.pool.join();
            // Speculation: re-launch any task that somehow didn't record a
            // success (covers lost/straggling attempts).
            if cfg.speculation {
                let stragglers: Vec<usize> =
                    (0..n).filter(|p| !done[*p].load(Ordering::Acquire)).collect();
                if !stragglers.is_empty() {
                    self.counters
                        .lock()
                        .unwrap()
                        .inc("tasks_speculated", stragglers.len() as u64);
                    for p in stragglers {
                        launch(p);
                    }
                    self.pool.join();
                }
            }
        }

        let mut res = results.lock().unwrap();
        let mut out = Vec::with_capacity(n);
        for (p, slot) in res.iter_mut().enumerate() {
            match slot.take() {
                Some(Ok(v)) => out.push(v),
                Some(Err(e)) => {
                    return Err(JobError::TaskFailed {
                        partition: p,
                        attempts: cfg.max_retries + 1,
                        last: e,
                    })
                }
                None => {
                    return Err(JobError::TaskFailed {
                        partition: p,
                        attempts: cfg.max_retries + 1,
                        last: "never completed".to_string(),
                    })
                }
            }
        }
        Ok(out)
    }
}

/// Decide decode-vs-stream: cached RDDs decode (pin) their partitions; the
/// uncached path streams to keep executor memory O(update).
fn cfg_cache_should_decode(rdd: &BinaryFilesRdd) -> bool {
    rdd.cache_enabled
}

/// `&dyn FusionAlgorithm` smuggled across the 'static task boundary.  The
/// driver blocks (`pool.join()`) inside `run_stage` before returning and
/// results are collected synchronously, so no task can outlive the borrow
/// this wraps; the transmute at the construction site documents the
/// invariant.
#[derive(Clone, Copy)]
struct AlgoRef(&'static dyn FusionAlgorithm);

impl AlgoRef {
    fn get(&self) -> &dyn FusionAlgorithm {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfs::datanode::tempdir::TempDir;
    use crate::dfs::NameNode;
    use crate::engine::{AggregationEngine, SerialEngine};
    use crate::fusion::{CoordMedian, FedAvg, IterAvg};
    use crate::util::prop::all_close;
    use crate::util::rng::Rng;

    fn setup(n_updates: usize, len: usize) -> (SparkContext, Vec<ModelUpdate>, TempDir) {
        let td = TempDir::new();
        let nn = NameNode::create(td.path(), 3, 2, 1 << 20).unwrap();
        let dfs = DfsClient::new(nn);
        let mut rng = Rng::new(99);
        let mut updates = Vec::new();
        let mut bd = Breakdown::new();
        for p in 0..n_updates as u64 {
            let mut d = vec![0f32; len];
            rng.fill_gaussian_f32(&mut d, 1.0);
            let u = ModelUpdate::new(p, 1.0 + rng.gen_range(50) as f32, 0, d);
            dfs.put_update(&u, &mut bd).unwrap();
            updates.push(u);
        }
        let sc = SparkContext::start(
            dfs,
            ExecutorConfig { executors: 2, cores_per_executor: 2, ..Default::default() },
        );
        (sc, updates, td)
    }

    #[test]
    fn distributed_fedavg_matches_serial() {
        let (sc, updates, _td) = setup(13, 300);
        let mut bd = Breakdown::new();
        let (got, parts) = sc
            .aggregate(&FedAvg, "/rounds/0/updates/", &JobConfig::default(), &mut bd)
            .unwrap();
        assert!(parts >= 1);
        let mut bd2 = Breakdown::new();
        let want = SerialEngine::unbounded().aggregate(&FedAvg, &updates, &mut bd2).unwrap();
        all_close(&got, &want, 1e-4, 1e-5).unwrap();
        // the paper's breakdown phases all present
        for phase in ["read_partition", "sum", "reduce"] {
            assert!(bd.phases().iter().any(|(p, _)| p == phase), "{phase}");
        }
    }

    #[test]
    fn uncached_streaming_matches_too() {
        let (sc, updates, _td) = setup(9, 200);
        let cfg = JobConfig { cache: false, ..Default::default() };
        let mut bd = Breakdown::new();
        let (got, _) = sc.aggregate(&IterAvg, "/rounds/0/updates/", &cfg, &mut bd).unwrap();
        let mut bd2 = Breakdown::new();
        let want = SerialEngine::unbounded().aggregate(&IterAvg, &updates, &mut bd2).unwrap();
        all_close(&got, &want, 1e-4, 1e-5).unwrap();
    }

    #[test]
    fn holistic_median_gathers_and_matches() {
        let (sc, updates, _td) = setup(7, 64);
        let mut bd = Breakdown::new();
        let (got, _) = sc
            .aggregate(&CoordMedian, "/rounds/0/updates/", &JobConfig::default(), &mut bd)
            .unwrap();
        let mut bd2 = Breakdown::new();
        let want = SerialEngine::unbounded().aggregate(&CoordMedian, &updates, &mut bd2).unwrap();
        all_close(&got, &want, 1e-5, 1e-6).unwrap();
    }

    #[test]
    fn empty_prefix_is_error() {
        let (sc, _u, _td) = setup(2, 10);
        let mut bd = Breakdown::new();
        assert!(matches!(
            sc.aggregate(&FedAvg, "/rounds/7/updates/", &JobConfig::default(), &mut bd),
            Err(JobError::NoUpdates)
        ));
    }

    #[test]
    fn datanode_failure_is_absorbed_by_replicas() {
        let (sc, updates, _td) = setup(8, 100);
        // Kill one datanode AFTER writes; replication=2 lets reads succeed.
        sc.dfs().namenode().datanode(0).set_alive(false);
        let mut bd = Breakdown::new();
        let (got, _) = sc
            .aggregate(&FedAvg, "/rounds/0/updates/", &JobConfig::default(), &mut bd)
            .unwrap();
        let mut bd2 = Breakdown::new();
        let want = SerialEngine::unbounded().aggregate(&FedAvg, &updates, &mut bd2).unwrap();
        all_close(&got, &want, 1e-4, 1e-5).unwrap();
    }

    #[test]
    fn total_store_failure_reports_task_failure() {
        let (sc, _u, _td) = setup(4, 50);
        for d in sc.dfs().namenode().datanodes() {
            d.set_alive(false);
        }
        let mut bd = Breakdown::new();
        let cfg = JobConfig { cache: false, max_retries: 1, ..Default::default() };
        match sc.aggregate(&FedAvg, "/rounds/0/updates/", &cfg, &mut bd) {
            Err(JobError::TaskFailed { attempts, .. }) => assert_eq!(attempts, 2),
            other => panic!("expected TaskFailed, got {other:?}"),
        }
    }

    #[test]
    fn transient_failure_recovered_by_retry() {
        let (sc, updates, _td) = setup(6, 80);
        // Kill the whole store, then revive it from another thread while
        // the scheduler retries.
        for d in sc.dfs().namenode().datanodes() {
            d.set_alive(false);
        }
        let nn = sc.dfs().namenode().clone();
        let reviver = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            for d in nn.datanodes() {
                d.set_alive(true);
            }
        });
        let mut bd = Breakdown::new();
        let cfg = JobConfig { cache: false, max_retries: 50, ..Default::default() };
        let (got, _) = sc.aggregate(&FedAvg, "/rounds/0/updates/", &cfg, &mut bd).unwrap();
        reviver.join().unwrap();
        let mut bd2 = Breakdown::new();
        let want = SerialEngine::unbounded().aggregate(&FedAvg, &updates, &mut bd2).unwrap();
        all_close(&got, &want, 1e-4, 1e-5).unwrap();
        assert!(sc.counters.lock().unwrap().get("tasks_retried") > 0);
    }

    #[test]
    fn elastic_rescale_between_jobs_keeps_results_exact() {
        let (sc, updates, _td) = setup(11, 120);
        let mut bd = Breakdown::new();
        let (a, _) = sc
            .aggregate(&FedAvg, "/rounds/0/updates/", &JobConfig::default(), &mut bd)
            .unwrap();
        assert_eq!(sc.scale_to(5), 5); // grow between rounds
        assert_eq!(sc.current_executors(), 5);
        let (b, _) = sc
            .aggregate(&FedAvg, "/rounds/0/updates/", &JobConfig::default(), &mut bd)
            .unwrap();
        assert_eq!(sc.scale_to(1), 1); // shrink between rounds
        let (c, _) = sc
            .aggregate(&FedAvg, "/rounds/0/updates/", &JobConfig::default(), &mut bd)
            .unwrap();
        let mut bd2 = Breakdown::new();
        let want = SerialEngine::unbounded().aggregate(&FedAvg, &updates, &mut bd2).unwrap();
        all_close(&a, &want, 1e-4, 1e-5).unwrap();
        all_close(&b, &want, 1e-4, 1e-5).unwrap();
        all_close(&c, &want, 1e-4, 1e-5).unwrap();
        assert_eq!(sc.counters.lock().unwrap().get("scale_events"), 2);
        // resizing to the current size is a no-op, not a scale event
        sc.scale_to(1);
        assert_eq!(sc.counters.lock().unwrap().get("scale_events"), 2);
    }

    #[test]
    fn explicit_partition_count_respected() {
        let (sc, _u, _td) = setup(12, 40);
        let cfg = JobConfig { partitions: Some(5), ..Default::default() };
        let mut bd = Breakdown::new();
        let (_, parts) = sc.aggregate(&FedAvg, "/rounds/0/updates/", &cfg, &mut bd).unwrap();
        assert_eq!(parts, 5);
    }

    #[test]
    fn combiner_cuts_driver_merge_to_executor_count() {
        // 8 partitions over 2 executors: at most 2 partials reach the
        // driver; the other ≥6 merged executor-locally (the shuffle cut).
        let (sc, updates, _td) = setup(16, 150);
        let cfg = JobConfig { partitions: Some(8), ..Default::default() };
        let mut bd = Breakdown::new();
        let (got, parts) = sc.aggregate(&FedAvg, "/rounds/0/updates/", &cfg, &mut bd).unwrap();
        assert_eq!(parts, 8);
        let mut bd2 = Breakdown::new();
        let want = SerialEngine::unbounded().aggregate(&FedAvg, &updates, &mut bd2).unwrap();
        all_close(&got, &want, 1e-4, 1e-5).unwrap();
        let counters = sc.counters.lock().unwrap();
        let partials = counters.get("combiner_partials");
        assert!((1..=2).contains(&partials), "{partials} partials from 2 executors");
        assert_eq!(counters.get("combiner_saved"), 8 - partials);
    }

    #[test]
    fn combiner_preserves_results_under_speculation_and_retry() {
        // Speculative duplicates must never double-fold a partition into
        // the executor combiner (exactly-once is enforced under the slot
        // lock).
        let (sc, updates, _td) = setup(10, 90);
        let cfg = JobConfig { speculation: true, cache: false, ..Default::default() };
        let mut bd = Breakdown::new();
        let (got, _) = sc.aggregate(&IterAvg, "/rounds/0/updates/", &cfg, &mut bd).unwrap();
        let mut bd2 = Breakdown::new();
        let want = SerialEngine::unbounded().aggregate(&IterAvg, &updates, &mut bd2).unwrap();
        all_close(&got, &want, 1e-4, 1e-5).unwrap();
    }
}
