//! Sparklet — the Spark-MapReduce analog (paper §III-D2, Fig 4 steps ③–⑤).
//!
//! * [`rdd`] — partitioned datasets over DFS files (`binaryFiles` analog):
//!   size-balanced partitions, lazy decode, optional caching (the paper
//!   caches decoded RDDs for small models; caching is skipped for large
//!   ones).
//! * [`executor`] — the executor pool: worker threads with per-executor
//!   core and memory budgets and a configurable spin-up cost (the paper's
//!   ~30 s Spark-context start for 10×30 GB executors).
//! * [`scheduler`] — the job driver: read/partition stage, sum stage,
//!   reduce stage, with task retry and speculative re-execution; produces
//!   the same phase breakdown the paper reports in Figs 7–13.

pub mod executor;
pub mod rdd;
pub mod scheduler;

pub use executor::{ExecutorConfig, ExecutorPool};
pub use rdd::{BinaryFilesRdd, Partition};
pub use scheduler::{JobError, SparkContext};

/// How many partitions for `n_files` across `total_cores`: the paper lets
/// Spark pick ~2× core oversubscription but caps tiny jobs at one partition
/// per file.
pub fn default_partitions(n_files: usize, total_cores: usize) -> usize {
    (2 * total_cores).min(n_files).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_default_policy() {
        assert_eq!(default_partitions(1000, 8), 16);
        assert_eq!(default_partitions(3, 8), 3);
        assert_eq!(default_partitions(0, 8), 1);
    }
}
