//! Virtual-time cluster model.
//!
//! This testbed is ONE physical core; the paper's is 4×64-core nodes, 170 GB
//! aggregator RAM, 3 HDFS datanodes and a 1 GbE client switch.  Everything
//! *logical* (partitioning, placement, replication, retry, thresholds) runs
//! for real in this repo; what cannot be measured here is elapsed time at
//! paper scale.  The cost model closes that gap:
//!
//! 1. [`CostModel::calibrate`] measures real per-byte throughputs on this
//!    box (serial fusion, DFS read/write, wire decode);
//! 2. the analytic schedulers below ([`VirtualCluster`]) combine those
//!    constants with a cluster geometry to predict phase times at any
//!    scale, using the same list-scheduling shape the real scheduler has
//!    (`ceil(tasks/cores)` waves × per-task time + overheads).
//!
//! Every figure bench prints BOTH the real measured small-scale points and
//! the model's paper-scale extrapolation, labelled as such.

pub mod calibrate;

pub use calibrate::CostModel;

use crate::config::ClusterSpec;
use crate::metrics::Breakdown;
use crate::tensorstore::Encoding;

/// Which single-node engine a virtual run models (Figs 1–3, 5–6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// NumPy baseline: single stream regardless of core count (Fig 3).
    Serial,
    /// Numba replacement: parallel across cores with imperfect efficiency.
    Parallel,
}

/// Memory-duplication factors of the IBMFL fusion implementations, fitted
/// from the paper's Fig 1 OOM points at 170 GB with 4.6 MB updates:
/// FedAvg OOMs at 18 900 parties -> 170 GB / 18 900 ≈ 2.0× the update size
/// (input list + weighted working copies); IterAvg at 32 400 -> ≈ 1.2×.
pub const FEDAVG_DUP_FACTOR: f64 = 2.0;
pub const ITERAVG_DUP_FACTOR: f64 = 1.15;

/// A cluster geometry + calibrated constants; all returned times are
/// virtual seconds.
#[derive(Clone, Debug)]
pub struct VirtualCluster {
    pub spec: ClusterSpec,
    pub cost: CostModel,
}

impl VirtualCluster {
    pub fn new(spec: ClusterSpec, cost: CostModel) -> VirtualCluster {
        VirtualCluster { spec, cost }
    }

    /// Paper-testbed geometry with constants calibrated on this box.
    pub fn paper(cost: CostModel) -> VirtualCluster {
        VirtualCluster { spec: ClusterSpec::default(), cost }
    }

    pub fn total_cores(&self) -> usize {
        self.spec.workers * self.spec.cores_per_worker
    }

    // ---------------------------------------------------------------
    // Single-node path (Figs 1, 2, 3, 5, 6)
    // ---------------------------------------------------------------

    /// Max parties a single node supports before OOM (Fig 1/2 ceilings).
    pub fn single_node_capacity(&self, mem_bytes: u64, update_bytes: u64, dup: f64) -> usize {
        if update_bytes == 0 {
            return usize::MAX;
        }
        (mem_bytes as f64 / (update_bytes as f64 * dup)) as usize
    }

    /// Virtual seconds to fuse `n` updates of `update_bytes` on one node.
    /// `algo_flops` scales arithmetic intensity (FedAvg≈1, IterAvg≈0.8:
    /// no per-client weight multiply).
    pub fn single_node_time(
        &self,
        update_bytes: u64,
        n: usize,
        cores: usize,
        engine: EngineKind,
        algo_flops: f64,
    ) -> f64 {
        let total = update_bytes as f64 * n as f64 * algo_flops;
        match engine {
            EngineKind::Serial => total / self.cost.fuse_bps,
            EngineKind::Parallel => {
                // Three effects bound the Numba-style speedup:
                // 1. Amdahl with the calibrated serial fraction,
                // 2. the socket's memory-bandwidth ceiling (fusion is a
                //    streaming op; fitted to the paper's −36/−39.6 %),
                // 3. parallel-work availability: Numba parallelises the
                //    per-party loop, so few parties ≈ no gain (the paper:
                //    "comparable performance to Numpy for smaller number
                //    of parties").
                let amdahl = 1.0
                    / (self.cost.parallel_serial_frac
                        + (1.0 - self.cost.parallel_serial_frac) / cores as f64);
                let cap = self.cost.parallel_bw_cap;
                let work_frac = n as f64 / (n as f64 + self.cost.parallel_n_half);
                let speedup = 1.0 + (amdahl.min(cap) - 1.0) * work_frac;
                total / (self.cost.fuse_bps * speedup)
                    + self.cost.parallel_launch_s * cores as f64
            }
        }
    }

    /// Arrival span of `n` message-passing updates pushed through the
    /// shared client switch into the aggregator (no store hop).
    pub fn streaming_ingest_span(&self, update_bytes: u64, n: usize) -> f64 {
        update_bytes as f64 * n as f64 / self.spec.client_link_bps
    }

    /// Virtual seconds for a streaming-fold round: every update folds into
    /// a shard-local O(C) accumulator *as it arrives*, so ingest and
    /// compute overlap and wall time is max(arrival span, fold throughput)
    /// plus the drain (the S-way partial merge and the finalize).
    ///
    /// `lanes` is the server's sharded-ingest width (S): with one lane the
    /// folds serialise on a single lock (the pre-shard design); with S
    /// lanes up to min(S, cores) connection handlers fold concurrently,
    /// scaling throughput until the same memory-bandwidth ceiling that
    /// caps the batch parallel engine (`parallel_bw_cap` — folding is a
    /// streaming op either way).  Contrast with the buffered single-node
    /// path (collection not on the aggregation clock, but O(K·C) memory)
    /// and the distributed path (store upload on the critical path).  The
    /// planner's per-round EWMA correction (`observe_round`) calibrates
    /// the whole expression against the box's observed wall-clock.
    pub fn streaming_time(&self, update_bytes: u64, n: usize, cores: usize, lanes: usize) -> f64 {
        if n == 0 {
            return 0.0;
        }
        let ingest = self.streaming_ingest_span(update_bytes, n);
        let lanes = lanes.clamp(1, cores.max(1));
        let total = update_bytes as f64 * n as f64;
        // Node-side per-update work that serialises on one lock lane:
        // wire decode (CRC + in-place view) plus the fold arithmetic.
        let per_lane = total / self.cost.fuse_bps + self.cost.decode_bytes(total);
        let speedup = (lanes as f64).min(self.cost.parallel_bw_cap);
        let fold = per_lane / speedup;
        // Drain: merge the S lane partials, then finalize — O(C) each.
        let drain = (lanes as f64 + 1.0) * update_bytes as f64 / self.cost.fuse_bps;
        ingest.max(fold) + drain
    }

    /// Encoding-aware [`VirtualCluster::streaming_time`]: the same
    /// overlap model with the *wire* legs priced at the encoding's
    /// per-update byte count and a dequantize term added to the node-side
    /// lane work.  `update_bytes` stays the DENSE size — the accumulator
    /// always folds f32, so the fold-arithmetic and drain terms are
    /// unchanged; compression shrinks the ingest span and the wire-decode
    /// term, and pays `payload/dequant_bps` to rematerialise the floats.
    /// `DenseF32` delegates exactly (bit-identical price) to
    /// [`streaming_time`](VirtualCluster::streaming_time), so every
    /// existing pin on the dense model is untouched.
    pub fn streaming_time_enc(
        &self,
        update_bytes: u64,
        n: usize,
        cores: usize,
        lanes: usize,
        enc: Encoding,
    ) -> f64 {
        if enc.is_dense_f32() {
            return self.streaming_time(update_bytes, n, cores, lanes);
        }
        if n == 0 {
            return 0.0;
        }
        let elems = update_bytes / 4;
        let wire_per = enc.payload_bytes(elems) as f64;
        let ingest = wire_per * n as f64 / self.spec.client_link_bps;
        let lanes = lanes.clamp(1, cores.max(1));
        let dense_total = update_bytes as f64 * n as f64;
        let wire_total = wire_per * n as f64;
        let dequant_total = enc.dequant_bytes(elems) as f64 * n as f64;
        let per_lane = dense_total / self.cost.fuse_bps
            + self.cost.decode_bytes(wire_total)
            + dequant_total / self.cost.dequant_bps;
        let speedup = (lanes as f64).min(self.cost.parallel_bw_cap);
        let fold = per_lane / speedup;
        let drain = (lanes as f64 + 1.0) * update_bytes as f64 / self.cost.fuse_bps;
        ingest.max(fold) + drain
    }

    /// [`VirtualCluster::streaming_time`] at an expected-participation
    /// factor `p ∈ (0, 1]`: of `n` registered parties only ~`n·p` deliver
    /// an upload (dropouts, stragglers past the round deadline), so the
    /// round's arrival span and fold work shrink accordingly.  The planner
    /// prices every quorum round through this entry; `p = 1` is exactly
    /// `streaming_time`.
    pub fn streaming_time_p(
        &self,
        update_bytes: u64,
        n: usize,
        cores: usize,
        lanes: usize,
        p: f64,
    ) -> f64 {
        let eff = (((n as f64) * p.clamp(0.0, 1.0)).ceil() as usize).min(n);
        self.streaming_time(update_bytes, eff, cores, lanes)
    }

    /// Virtual seconds from "first upload arrives" to "next model
    /// publishes" under the FedBuff-style async mode: the server folds a
    /// bounded buffer of the `k` freshest updates and publishes as soon as
    /// the buffer fills, so the publish latency is one `k`-sized streaming
    /// round instead of a quorum-sized one.  This is the async mode's
    /// latency win: `k ≪ n·p` means the model refreshes long before a
    /// sync quorum would seal, and stragglers never gate the clock.
    pub fn async_publish_time(&self, update_bytes: u64, k: usize, cores: usize, lanes: usize) -> f64 {
        self.streaming_time(update_bytes, k.max(1), cores, lanes)
    }

    /// Node-seconds of aggregator occupancy to fold one sync-round's worth
    /// of arrivals (`eff` uploads) through `k`-sized async buffers: the
    /// same ingest+fold work as a flat streaming round, plus one extra
    /// drain (S-way merge + finalize + install) per additional publish.
    /// The planner prices async $ from this occupancy — the latency win is
    /// not free: publishing `ceil(eff/k)` times re-pays the drain.
    pub fn async_occupancy(
        &self,
        update_bytes: u64,
        eff: usize,
        k: usize,
        cores: usize,
        lanes: usize,
    ) -> f64 {
        if eff == 0 {
            return 0.0;
        }
        let k = k.clamp(1, eff);
        let base = self.streaming_time(update_bytes, eff, cores, lanes);
        let extra_publishes = eff.div_ceil(k).saturating_sub(1) as f64;
        let lanes_f = lanes.clamp(1, cores.max(1)) as f64;
        let drain = (lanes_f + 1.0) * update_bytes as f64 / self.cost.fuse_bps;
        base + extra_publishes * drain
    }

    /// Encoding-aware [`VirtualCluster::async_publish_time`]: a buffered
    /// async publish IS a `k`-sized streaming fold, so it inherits the
    /// encoding's wire/dequantize terms the same way.  `DenseF32`
    /// delegates exactly to the dense entry.
    pub fn async_publish_time_enc(
        &self,
        update_bytes: u64,
        k: usize,
        cores: usize,
        lanes: usize,
        enc: Encoding,
    ) -> f64 {
        self.streaming_time_enc(update_bytes, k.max(1), cores, lanes, enc)
    }

    /// Encoding-aware [`VirtualCluster::async_occupancy`]: the base fold
    /// work prices at the encoding; the per-publish drain is a dense O(C)
    /// merge either way (the accumulator always holds f32).
    pub fn async_occupancy_enc(
        &self,
        update_bytes: u64,
        eff: usize,
        k: usize,
        cores: usize,
        lanes: usize,
        enc: Encoding,
    ) -> f64 {
        if eff == 0 {
            return 0.0;
        }
        let k = k.clamp(1, eff);
        let base = self.streaming_time_enc(update_bytes, eff, cores, lanes, enc);
        let extra_publishes = eff.div_ceil(k).saturating_sub(1) as f64;
        let lanes_f = lanes.clamp(1, cores.max(1)) as f64;
        let drain = (lanes_f + 1.0) * update_bytes as f64 / self.cost.fuse_bps;
        base + extra_publishes * drain
    }

    /// Virtual phase split of a 2-tier hierarchical round over `edges`
    /// edge aggregators: `(edge_s, root_s)`.
    ///
    /// * **edge phase** — every edge runs a flat streaming round over its
    ///   ~`n/edges` cohort *in parallel*, each through its own DC's client
    ///   switch, so the phase lasts one cohort's [`streaming_time`]
    ///   (this division of the ingest span is the latency win);
    /// * **root phase** — the root folds `edges` C-sized partials (one per
    ///   edge — the root-ingest-bytes win: `edges·C` instead of `n·C`
    ///   through the root's switch), plus the [`tier_sync_s`] barrier: the
    ///   root cannot seal before the slowest relay seals, drains and
    ///   forwards.
    ///
    /// The barrier is what keeps small fleets on the flat plan: below a
    /// few dozen parties the whole flat ingest span is cheaper than one
    /// tier hop, which is exactly the crossover `fig_hierarchical_scaling`
    /// pins.
    ///
    /// [`streaming_time`]: VirtualCluster::streaming_time
    /// [`tier_sync_s`]: CostModel::tier_sync_s
    pub fn hierarchical_breakdown(
        &self,
        update_bytes: u64,
        n: usize,
        cores: usize,
        lanes: usize,
        edges: usize,
    ) -> (f64, f64) {
        if n == 0 {
            return (0.0, 0.0);
        }
        let edges = edges.clamp(1, n);
        let cohort = n.div_ceil(edges);
        let edge_s = self.streaming_time(update_bytes, cohort, cores, lanes);
        let root_s =
            self.streaming_time(update_bytes, edges, cores, lanes) + self.cost.tier_sync_s;
        (edge_s, root_s)
    }

    /// Encoding-aware 2-tier phase split.  The asymmetry is structural:
    /// cohort clients may ship compressed frames to their edge (the edge
    /// phase prices at the encoding's bytes + dequantize), but every
    /// relay dequantizes at ingest and forwards a DENSE f32 partial — the
    /// root phase is always the dense model.  Compression therefore
    /// shrinks the *flat* plan's whole ingest span but only the
    /// hierarchy's edge phase, so the flat-beats-hierarchy region grows:
    /// the root-ingest crossover moves to LARGER fleets (the shift
    /// `fig_encoding_throughput` pins).
    pub fn hierarchical_breakdown_enc(
        &self,
        update_bytes: u64,
        n: usize,
        cores: usize,
        lanes: usize,
        edges: usize,
        enc: Encoding,
    ) -> (f64, f64) {
        if n == 0 {
            return (0.0, 0.0);
        }
        let edges = edges.clamp(1, n);
        let cohort = n.div_ceil(edges);
        let edge_s = self.streaming_time_enc(update_bytes, cohort, cores, lanes, enc);
        let root_s =
            self.streaming_time(update_bytes, edges, cores, lanes) + self.cost.tier_sync_s;
        (edge_s, root_s)
    }

    /// End-to-end latency of the encoding-aware 2-tier round.
    pub fn hierarchical_time_enc(
        &self,
        update_bytes: u64,
        n: usize,
        cores: usize,
        lanes: usize,
        edges: usize,
        enc: Encoding,
    ) -> f64 {
        let (e, r) = self.hierarchical_breakdown_enc(update_bytes, n, cores, lanes, edges, enc);
        e + r
    }

    /// End-to-end latency of the 2-tier round: the phases are sequential
    /// (the root's ingest IS the relays' output).
    pub fn hierarchical_time(
        &self,
        update_bytes: u64,
        n: usize,
        cores: usize,
        lanes: usize,
        edges: usize,
    ) -> f64 {
        let (e, r) = self.hierarchical_breakdown(update_bytes, n, cores, lanes, edges);
        e + r
    }

    /// Wire bytes the ROOT ingests in a flat round: `n` update frames
    /// (5-byte frame header + 28-byte update header + data + crc).
    pub fn flat_root_bytes(&self, update_bytes: u64, n: usize) -> u64 {
        n as u64 * (update_bytes + 37)
    }

    /// [`VirtualCluster::flat_root_bytes`] under a wire encoding: `n`
    /// encoded frames (5-byte frame header + 8-byte nonce + the codec's
    /// 40-byte header + payload + crc).  `DenseF32` carries ~20 bytes/frame
    /// more header than the plain upload format; every compressed encoding
    /// shrinks the total by its payload ratio.
    pub fn flat_root_bytes_enc(&self, update_bytes: u64, n: usize, enc: Encoding) -> u64 {
        let elems = update_bytes / 4;
        n as u64 * (13 + enc.wire_bytes(elems))
    }

    /// Wire bytes the ROOT ingests in a 2-tier round: one partial frame
    /// per edge (5-byte frame header + 8-byte nonce + 40-byte partial
    /// header + sums + crc) plus 8 bytes per cohort member for the
    /// contributing-party set.  For `n ≫ edges` this is the ~`n/edges`×
    /// reduction that lifts the "millions of clients behind one socket"
    /// ceiling.
    pub fn hierarchical_root_bytes(&self, update_bytes: u64, n: usize, edges: usize) -> u64 {
        let edges = edges.clamp(1, n.max(1));
        edges as u64 * (update_bytes + 57) + 8 * n as u64
    }

    // ---------------------------------------------------------------
    // Distributed path (Figs 7–13)
    // ---------------------------------------------------------------

    /// Partition count the paper's policy would pick.
    pub fn partitions(&self, n_files: usize) -> usize {
        crate::mapreduce::default_partitions(n_files, self.total_cores())
    }

    /// Virtual phase breakdown for a distributed aggregation of `n`
    /// updates of `update_bytes` (the Fig 7/9 read/sum/reduce bars) at the
    /// full cluster width.
    pub fn distributed_breakdown(&self, update_bytes: u64, n: usize, cache: bool) -> Breakdown {
        self.distributed_breakdown_for_cores(update_bytes, n, cache, self.total_cores())
    }

    /// Same model at an explicit pool width: the dispatch planner prices
    /// the distributed path at every candidate executor count k by calling
    /// this with `total_cores = k × cores_per_executor`.
    pub fn distributed_breakdown_for_cores(
        &self,
        update_bytes: u64,
        n: usize,
        cache: bool,
        total_cores: usize,
    ) -> Breakdown {
        let mut bd = Breakdown::new();
        let total_cores = total_cores.max(1);
        let parts = crate::mapreduce::default_partitions(n, total_cores);
        let cores = total_cores.min(parts.max(1));
        let total_bytes = update_bytes as f64 * n as f64;
        let waves = (parts as f64 / cores as f64).ceil();

        // read+partition: one full pass over the data from the DFS, spread
        // over min(parts, cores) concurrent readers but bounded by the
        // datanodes' aggregate disk bandwidth.
        let disk_agg = self.cost.dfs_read_bps * self.spec.datanodes as f64;
        let reader_agg = (self.cost.dfs_read_bps * cores as f64).min(disk_agg);
        let read = total_bytes / reader_agg
            + self.cost.decode_bytes(total_bytes) / cores as f64
            + waves * self.cost.task_overhead_s;
        bd.add("read_partition", read);

        // sum: count extraction — cached partitions make this almost free,
        // uncached re-reads the data (the paper's large-model penalty).
        let sum = if cache {
            waves * self.cost.task_overhead_s + n as f64 * 1e-7
        } else {
            total_bytes / reader_agg + waves * self.cost.task_overhead_s
        };
        bd.add("sum", sum);

        // reduce: the weighted-average fold over cores, plus driver combine
        // of per-partition partials (one update-size buffer per partition).
        let fold = total_bytes / (self.cost.fuse_bps * cores as f64);
        let combine = parts as f64 * update_bytes as f64 / self.cost.fuse_bps;
        let reduce = if cache {
            fold + combine + waves * self.cost.task_overhead_s
        } else {
            // uncached: the reduce pass re-reads from the store
            total_bytes / reader_agg + fold + combine + waves * self.cost.task_overhead_s
        };
        bd.add("reduce", reduce);
        bd
    }

    /// Spark-context spin-up (paper §III-D3: <30 s for 10 executors).
    pub fn executor_startup(&self, executors: usize) -> f64 {
        self.cost.executor_startup_s * executors as f64
    }

    /// Fig 12 "average write time": `n` clients push `update_bytes` through
    /// the shared 1 GbE switch into the replicated store.
    pub fn client_write_time(&self, update_bytes: u64, n: usize) -> f64 {
        let per_client = self.spec.client_link_bps;
        let switch = self.spec.client_link_bps; // 1 GbE aggregate at the switch
        let store_agg = self.cost.dfs_write_bps * self.spec.datanodes as f64
            / self.spec.replication as f64;
        // effective per-client bandwidth under contention
        let eff = per_client.min(switch / n as f64).min(store_agg / n as f64);
        update_bytes as f64 / eff
    }

    /// Party capacity of the distributed path: bounded by HDFS storage,
    /// not node memory — the scalability headline (Figs 7–11).
    pub fn distributed_capacity(&self, update_bytes: u64, hdfs_bytes: u64) -> usize {
        (hdfs_bytes as f64 / (update_bytes as f64 * self.spec.replication as f64)) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vc() -> VirtualCluster {
        VirtualCluster::paper(CostModel::nominal())
    }

    #[test]
    fn capacity_matches_fig1_points() {
        let v = vc();
        let fed = v.single_node_capacity(170 << 30, (4.6 * 1024.0 * 1024.0) as u64, FEDAVG_DUP_FACTOR);
        let iter = v.single_node_capacity(170 << 30, (4.6 * 1024.0 * 1024.0) as u64, ITERAVG_DUP_FACTOR);
        // paper: 18 900 (fedavg) and 32 400 (iteravg)
        assert!((17_000..21_000).contains(&fed), "{fed}");
        assert!((29_000..36_000).contains(&iter), "{iter}");
    }

    #[test]
    fn capacity_shrinks_with_model_size() {
        let v = vc();
        let big = v.single_node_capacity(170 << 30, 956 << 20, FEDAVG_DUP_FACTOR);
        // paper: "for the 956 MB model less than 150 clients"
        assert!(big < 150, "{big}");
    }

    #[test]
    fn serial_ignores_cores_parallel_uses_them() {
        let v = vc();
        let s8 = v.single_node_time(4 << 20, 1000, 8, EngineKind::Serial, 1.0);
        let s64 = v.single_node_time(4 << 20, 1000, 64, EngineKind::Serial, 1.0);
        assert_eq!(s8, s64); // Fig 3
        let p8 = v.single_node_time(4 << 20, 1000, 8, EngineKind::Parallel, 1.0);
        let p64 = v.single_node_time(4 << 20, 1000, 64, EngineKind::Parallel, 1.0);
        // the bandwidth cap flattens 8->64 cores, but parallel beats serial
        assert!(p8 < s8);
        assert!(p64 < s64);
        // at many parties the gain sits in the paper's 30-45% band
        let gain = 100.0 * (s64 - p64) / s64;
        assert!((30.0..45.0).contains(&gain), "{gain}");
    }

    #[test]
    fn parallel_gain_narrows_for_few_parties() {
        // Fig 5's shape: large models support few parties -> small gain.
        let v = vc();
        let s = v.single_node_time(956 << 20, 91, 64, EngineKind::Serial, 1.0);
        let p = v.single_node_time(956 << 20, 91, 64, EngineKind::Parallel, 1.0);
        let gain_large = 100.0 * (s - p) / s;
        let s2 = v.single_node_time((4.6 * 1048576.0) as u64, 18900, 64, EngineKind::Serial, 1.0);
        let p2 = v.single_node_time((4.6 * 1048576.0) as u64, 18900, 64, EngineKind::Parallel, 1.0);
        let gain_small = 100.0 * (s2 - p2) / s2;
        assert!(gain_small > gain_large + 10.0, "{gain_small} vs {gain_large}");
    }

    #[test]
    fn parallel_loses_for_tiny_workloads() {
        // Numba ≈/> NumPy for small party counts (launch overhead).
        let v = vc();
        let s = v.single_node_time(4 << 20, 2, 64, EngineKind::Serial, 1.0);
        let p = v.single_node_time(4 << 20, 2, 64, EngineKind::Parallel, 1.0);
        assert!(p > s * 0.8, "parallel should not win big at n=2: {p} vs {s}");
    }

    #[test]
    fn streaming_is_ingest_bound_at_scale() {
        let v = vc();
        let u = (4.6 * 1024.0 * 1024.0) as u64;
        // 30 000 parties: the 1 GbE switch is the bottleneck, not the fold
        let t = v.streaming_time(u, 30_000, 64, 64);
        let ingest = v.streaming_ingest_span(u, 30_000);
        assert!(t >= ingest && t < ingest * 1.01, "{t} vs {ingest}");
        // and the overlap means it beats upload-then-MapReduce end to end
        let dist = v.client_write_time(u, 30_000) + v.distributed_breakdown(u, 30_000, true).total();
        assert!(t < dist, "streaming {t} must beat store+job {dist}");
        assert_eq!(v.streaming_time(u, 0, 64, 64), 0.0);
    }

    #[test]
    fn streaming_lanes_term_prices_ingest_parallelism() {
        // On the paper's 1 GbE switch streaming is ingest-bound and the
        // lanes term is moot; on a fast edge link (25 GbE) the node-side
        // decode+fold becomes the bottleneck, and one lock lane must be
        // priced slower than the sharded server, monotonically in S up to
        // the bandwidth cap.
        let spec = crate::config::ClusterSpec {
            client_link_bps: 25e9 / 8.0,
            ..crate::config::ClusterSpec::default()
        };
        let v = VirtualCluster::new(spec, CostModel::nominal());
        let u = 1u64 << 20;
        let n = 2_000;
        let one = v.streaming_time(u, n, 64, 1);
        let two = v.streaming_time(u, n, 64, 2);
        let many = v.streaming_time(u, n, 64, 64);
        assert!(two < one, "{two} !< {one}");
        // wide sharding still beats the lock lane, though its S-way merge
        // drain grows with the lane count
        assert!(many < one, "{many} !< {one}");
        // lanes are clamped by the core count: a 1-core node cannot fold
        // in parallel no matter how many shards it configures
        assert_eq!(v.streaming_time(u, n, 1, 64), v.streaming_time(u, n, 1, 1));
        // the 1 GbE paper geometry stays ingest-bound regardless of lanes
        let p = vc();
        let span = p.streaming_ingest_span(u, n);
        assert!(p.streaming_time(u, n, 64, 64) >= span);
    }

    #[test]
    fn participation_scales_the_streaming_span() {
        let v = vc();
        let u = (4.6 * 1024.0 * 1024.0) as u64;
        let full = v.streaming_time_p(u, 30_000, 64, 64, 1.0);
        assert_eq!(full, v.streaming_time(u, 30_000, 64, 64));
        // ingest-bound geometry: half the arrivals ≈ half the span
        let half = v.streaming_time_p(u, 30_000, 64, 64, 0.5);
        assert!((0.45..0.60).contains(&(half / full)), "{}", half / full);
        // monotone in p, and floored at zero arrivals
        assert!(v.streaming_time_p(u, 30_000, 64, 64, 0.2) < half);
        assert_eq!(v.streaming_time_p(u, 0, 64, 64, 0.5), 0.0);
    }

    #[test]
    fn async_publish_beats_the_sync_quorum_span() {
        // The async latency win: a K-sized buffer publishes after K
        // arrivals, while the sync round waits for the whole quorum.
        let v = vc();
        let u = (4.6 * 1024.0 * 1024.0) as u64;
        let publish = v.async_publish_time(u, 64, 64, 64);
        let quorum = v.streaming_time(u, 10_000, 64, 64);
        assert!(publish < quorum / 10.0, "{publish} vs {quorum}");
        // a buffer as large as the quorum is exactly the sync round
        assert_eq!(v.async_publish_time(u, 10_000, 64, 64), quorum);
        // degenerate buffer floors at one update
        assert_eq!(v.async_publish_time(u, 0, 64, 64), v.streaming_time(u, 1, 64, 64));
    }

    #[test]
    fn async_occupancy_repays_the_drain_per_publish() {
        let v = vc();
        let u = (4.6 * 1024.0 * 1024.0) as u64;
        let sync = v.streaming_time(u, 1024, 64, 64);
        // one buffer covering everything = exactly the sync fold work
        assert_eq!(v.async_occupancy(u, 1024, 1024, 64, 64), sync);
        // smaller buffers publish more often and cost strictly more
        let k64 = v.async_occupancy(u, 1024, 64, 64, 64);
        let k16 = v.async_occupancy(u, 1024, 16, 64, 64);
        assert!(k64 > sync, "{k64} !> {sync}");
        assert!(k16 > k64, "{k16} !> {k64}");
        assert_eq!(v.async_occupancy(u, 0, 64, 64, 64), 0.0);
    }

    #[test]
    fn hierarchy_beats_flat_past_the_crossover_on_the_paper_geometry() {
        // 1 GbE, 4.6 MB updates, 4 edges: the flat streaming round is
        // ingest-bound, so dividing the span 4 ways wins once the fleet
        // outgrows the per-tier sync barrier — and never below it.
        let v = vc();
        let u = (4.6 * 1024.0 * 1024.0) as u64;
        for n in [32usize, 64, 128, 1024, 30_000] {
            let flat = v.streaming_time(u, n, 64, 64);
            let hier = v.hierarchical_time(u, n, 64, 64, 4);
            assert!(hier < flat, "n={n}: hier {hier} !< flat {flat}");
        }
        for n in [2usize, 4, 8] {
            let flat = v.streaming_time(u, n, 64, 64);
            let hier = v.hierarchical_time(u, n, 64, 64, 4);
            assert!(hier > flat, "n={n}: the tier barrier must not pay off: {hier} vs {flat}");
        }
        // the phase split is consistent with the total
        let (e, r) = v.hierarchical_breakdown(u, 64, 64, 64, 4);
        assert!(e > 0.0 && r > v.cost.tier_sync_s);
        assert_eq!(e + r, v.hierarchical_time(u, 64, 64, 64, 4));
        assert_eq!(v.hierarchical_time(u, 0, 64, 64, 4), 0.0);
    }

    #[test]
    fn dense_f32_encoding_prices_exactly_like_the_dense_model() {
        // The encoding-aware entries must not perturb a single existing
        // pin: DenseF32 is bit-identical to the unencoded expressions.
        let v = vc();
        let u = (4.6 * 1024.0 * 1024.0) as u64;
        for n in [1usize, 8, 64, 1024, 30_000] {
            assert_eq!(
                v.streaming_time_enc(u, n, 64, 64, Encoding::DenseF32),
                v.streaming_time(u, n, 64, 64)
            );
            assert_eq!(
                v.hierarchical_time_enc(u, n, 64, 64, 4, Encoding::DenseF32),
                v.hierarchical_time(u, n, 64, 64, 4)
            );
            assert_eq!(
                v.async_publish_time_enc(u, n, 64, 64, Encoding::DenseF32),
                v.async_publish_time(u, n, 64, 64)
            );
            assert_eq!(
                v.async_occupancy_enc(u, n, 64.min(n), 64, 64, Encoding::DenseF32),
                v.async_occupancy(u, n, 64.min(n), 64, 64)
            );
        }
    }

    #[test]
    fn compressed_encodings_shrink_the_flat_span_and_pay_dequant() {
        let v = vc();
        let u = (4.6 * 1024.0 * 1024.0) as u64;
        let n = 10_000;
        let dense = v.streaming_time_enc(u, n, 64, 64, Encoding::DenseF32);
        let f16 = v.streaming_time_enc(u, n, 64, 64, Encoding::DenseF16);
        let i8t = v.streaming_time_enc(u, n, 64, 64, Encoding::QuantI8);
        let topk = v.streaming_time_enc(u, n, 64, 64, Encoding::TopK { permille: 100 });
        // ingest-bound geometry: halving the bytes ≈ halves the round
        assert!(f16 < dense * 0.6, "{f16} vs {dense}");
        assert!(i8t < f16, "{i8t} vs {f16}");
        assert!(topk < i8t, "{topk} vs {i8t}");
        // the dequant term is real: on an infinitely fast link a
        // pathological dequantizer makes the compressed fold slower than
        // dense, while the dense price does not move at all
        let spec = crate::config::ClusterSpec { client_link_bps: 1e15, ..Default::default() };
        let fast = VirtualCluster::new(spec.clone(), CostModel::nominal());
        let mut slow_dq = CostModel::nominal();
        slow_dq.dequant_bps = 1e6;
        let fast_slow = VirtualCluster::new(spec, slow_dq);
        assert!(
            fast_slow.streaming_time_enc(u, n, 64, 1, Encoding::QuantI8)
                > fast.streaming_time_enc(u, n, 64, 1, Encoding::QuantI8),
            "a slower dequantizer must price compressed folds higher"
        );
        assert!(
            fast_slow.streaming_time_enc(u, n, 64, 1, Encoding::QuantI8)
                > fast_slow.streaming_time(u, n, 64, 1),
            "with dequant dominant, compressed must cost more than dense"
        );
        assert_eq!(
            fast_slow.streaming_time(u, n, 64, 1),
            fast.streaming_time(u, n, 64, 1),
            "the dense path never pays dequant"
        );
        // byte model: compressed flat root ingest shrinks accordingly
        let dense_b = v.flat_root_bytes_enc(u, n, Encoding::DenseF32);
        let f16_b = v.flat_root_bytes_enc(u, n, Encoding::DenseF16);
        assert!(f16_b < dense_b * 6 / 10);
        assert!(dense_b >= v.flat_root_bytes(u, n), "codec header overhead is visible");
    }

    #[test]
    fn compression_moves_the_hierarchy_crossover_to_larger_fleets() {
        // Compression shrinks every client→aggregator leg but the
        // relay→root partials stay dense f32, so the fixed root phase +
        // tier barrier take longer to amortise: the smallest fleet where
        // the 2-tier plan wins must grow vs dense.
        let v = vc();
        let u = (4.6 * 1024.0 * 1024.0) as u64;
        let crossover = |enc: Encoding| -> usize {
            for n in 2..100_000usize {
                let flat = v.streaming_time_enc(u, n, 64, 64, enc);
                let hier = v.hierarchical_time_enc(u, n, 64, 64, 4, enc);
                if hier < flat {
                    return n;
                }
            }
            usize::MAX
        };
        let dense_x = crossover(Encoding::DenseF32);
        let f16_x = crossover(Encoding::DenseF16);
        let topk_x = crossover(Encoding::TopK { permille: 100 });
        // the dense crossover matches the fig_hierarchical_scaling pin
        // (hier wins by 32 parties, loses at 8)
        assert!(dense_x > 8 && dense_x <= 32, "{dense_x}");
        assert!(f16_x > dense_x, "f16 {f16_x} !> dense {dense_x}");
        assert!(topk_x > f16_x, "topk {topk_x} !> f16 {f16_x}");
    }

    #[test]
    fn root_ingest_bytes_shrink_by_the_edge_factor() {
        let v = vc();
        let u = (4.6 * 1024.0 * 1024.0) as u64;
        let flat = v.flat_root_bytes(u, 10_000);
        let hier = v.hierarchical_root_bytes(u, 10_000, 4);
        assert!(hier < flat / 1000, "{hier} vs {flat}");
        // degenerate shapes stay sane
        assert!(v.hierarchical_root_bytes(u, 2, 16) <= v.flat_root_bytes(u, 2) + 2 * 57);
        assert_eq!(v.flat_root_bytes(u, 0), 0);
    }

    #[test]
    fn distributed_breakdown_phases_scale_with_n() {
        let v = vc();
        let small = v.distributed_breakdown(4 << 20, 1_000, true);
        let big = v.distributed_breakdown(4 << 20, 100_000, true);
        assert!(big.get("read_partition") > small.get("read_partition"));
        assert!(big.get("reduce") > small.get("reduce"));
        assert!(big.total() > 10.0 * small.total());
    }

    #[test]
    fn cache_helps_sum_phase() {
        let v = vc();
        let cached = v.distributed_breakdown(4 << 20, 10_000, true);
        let uncached = v.distributed_breakdown(4 << 20, 10_000, false);
        assert!(cached.get("sum") < uncached.get("sum") / 5.0);
        assert!(cached.total() < uncached.total());
    }

    #[test]
    fn wider_pools_are_never_slower() {
        // The planner's k-sweep relies on the breakdown being monotone
        // non-increasing in pool width (same data, more readers/folders).
        let v = vc();
        let mut last = f64::INFINITY;
        for cores in [3usize, 6, 12, 24, 48] {
            let t = v.distributed_breakdown_for_cores(4 << 20, 20_000, true, cores).total();
            assert!(t <= last + 1e-9, "{cores} cores: {t} > {last}");
            last = t;
        }
    }

    #[test]
    fn full_width_breakdown_matches_explicit_cores() {
        let v = vc();
        let a = v.distributed_breakdown(4 << 20, 5_000, true);
        let b = v.distributed_breakdown_for_cores(4 << 20, 5_000, true, v.total_cores());
        assert_eq!(a, b);
    }

    #[test]
    fn write_time_grows_with_contention() {
        let v = vc();
        let few = v.client_write_time(91 << 20, 6);
        let many = v.client_write_time(91 << 20, 600);
        assert!(many > few);
    }

    #[test]
    fn distributed_capacity_uses_storage_not_memory() {
        let v = vc();
        // 2.6 TB HDFS (paper) with 4.6 MB updates, repl 2 -> ~296 k parties
        let cap = v.distributed_capacity((4.6 * 1024.0 * 1024.0) as u64, 2600u64 << 30);
        assert!(cap > 100_000, "{cap}"); // covers the paper's 100 k evaluation
    }

    #[test]
    fn startup_matches_paper_30s_claim() {
        let v = vc();
        let t = v.executor_startup(10);
        assert!(t <= 30.0, "10 executors must start in <30 s, got {t}");
        assert!(t >= 5.0);
    }
}
