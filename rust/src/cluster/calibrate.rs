//! Cost-model constants: nominal values + on-box calibration.
//!
//! `calibrate()` measures real throughputs with micro-runs of the actual
//! engines/substrates so virtual-time extrapolations inherit this box's
//! constants; `nominal()` is a fixed fallback (CI, docs) chosen to be
//! representative of the paper's Xeon Gold 6226R testbed.

use std::time::Instant;

use crate::dfs::{DfsClient, NameNode};
use crate::engine::{AggregationEngine, SerialEngine};
use crate::fusion::FedAvg;
use crate::metrics::Breakdown;
use crate::tensorstore::ModelUpdate;

/// Calibrated per-byte costs (bytes/sec unless noted).
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Serial in-memory fusion throughput (weighted-sum bytes/s).
    pub fuse_bps: f64,
    /// Amdahl serial fraction of the parallel engine (launch + finalize).
    pub parallel_serial_frac: f64,
    /// Per-core thread-launch overhead of the parallel engine (s).
    pub parallel_launch_s: f64,
    /// Memory-bandwidth cap on parallel speedup: fusion is a streaming op
    /// (~0.25 flop/byte), so extra cores only help until the socket's
    /// bandwidth saturates.  Fitted from the paper's measured Numba gains
    /// (−36 % @4.6 MB many parties, −39.6 % @ResNet50 900 parties):
    /// max speedup ≈ 1.65×.
    pub parallel_bw_cap: f64,
    /// Party count at which half the bandwidth-capped speedup is reached —
    /// Numba parallelises the per-party loop, so few parties mean little
    /// parallel work (the paper: "Numba ... gives a comparable performance
    /// to Numpy for smaller number of parties").
    pub parallel_n_half: f64,
    /// DFS read/write throughput per datanode.
    pub dfs_read_bps: f64,
    pub dfs_write_bps: f64,
    /// Wire-format decode throughput.
    pub decode_bps: f64,
    /// Dequantize throughput for compressed update payloads (f16 unpack /
    /// int8 scale-and-shift / top-k scatter), in *payload* bytes/s: what
    /// the receiver pays to turn a compressed frame into the dense f32s
    /// the fold consumes.  Dense-f32 frames skip this entirely (zero-copy
    /// borrow).
    pub dequant_bps: f64,
    /// Per-task scheduling overhead (Spark task launch ≈ 5–20 ms).
    pub task_overhead_s: f64,
    /// Executor container spin-up (paper: 10 containers < 30 s).
    pub executor_startup_s: f64,
    /// One-off dispatch latency of an AOT XLA execution (PJRT call setup).
    pub xla_launch_s: f64,
    /// Per-tier synchronisation barrier of a hierarchical round: the root
    /// cannot seal before the slowest edge aggregator seals its local
    /// quorum, drains its lanes and forwards the partial (relay deadline
    /// slack + seal/encode + one backhaul round-trip).  A prior, not
    /// measured — the planner's hierarchical EWMA family calibrates it
    /// against observed rounds like every other constant.
    pub tier_sync_s: f64,
}

impl CostModel {
    /// Representative fixed constants (Xeon Gold 6226R class).
    pub fn nominal() -> CostModel {
        CostModel {
            fuse_bps: 2.0e9,
            parallel_serial_frac: 0.05,
            parallel_launch_s: 2e-4,
            parallel_bw_cap: 1.65,
            parallel_n_half: 150.0,
            dfs_read_bps: 400e6,
            dfs_write_bps: 250e6,
            decode_bps: 1.5e9,
            dequant_bps: 2.5e9,
            task_overhead_s: 0.01,
            executor_startup_s: 2.5,
            xla_launch_s: 5e-4,
            tier_sync_s: 0.3,
        }
    }

    /// Decode cost in seconds for `bytes`.
    pub fn decode_bytes(&self, bytes: f64) -> f64 {
        bytes / self.decode_bps
    }

    /// Effective fuse throughput of the AOT XLA path: a single dispatch
    /// streaming at the socket's bandwidth ceiling (the same cap that
    /// bounds the parallel engine, without its per-core launch costs).
    pub fn xla_bps(&self) -> f64 {
        self.fuse_bps * self.parallel_bw_cap
    }

    /// Measure real constants on this box.  ~1 s of micro-runs.
    pub fn calibrate() -> CostModel {
        let mut m = CostModel::nominal();

        // Fusion throughput: serial FedAvg over 32 × 1 MiB updates.
        let len = 256 * 1024; // 1 MiB of f32
        let updates: Vec<ModelUpdate> = (0..32)
            .map(|i| ModelUpdate::new(i, 1.0, 0, vec![0.5; len]))
            .collect();
        let engine = SerialEngine::unbounded();
        let mut bd = Breakdown::new();
        let t0 = Instant::now();
        let _ = engine.aggregate(&FedAvg, &updates, &mut bd);
        let dt = t0.elapsed().as_secs_f64().max(1e-6);
        m.fuse_bps = (32.0 * len as f64 * 4.0) / dt;

        // DFS read/write: 8 × 1 MiB files through a temp store.
        let root = std::env::temp_dir().join(format!("elastiagg-cal-{}", std::process::id()));
        if let Ok(nn) = NameNode::create(&root, 1, 1, 8 << 20) {
            let dfs = DfsClient::new(nn);
            let payload = vec![7u8; 1 << 20];
            let t0 = Instant::now();
            for i in 0..8 {
                let _ = dfs.write(&format!("/cal/{i}"), &payload);
            }
            m.dfs_write_bps = (8.0 * payload.len() as f64) / t0.elapsed().as_secs_f64().max(1e-6);
            let t0 = Instant::now();
            for i in 0..8 {
                let _ = dfs.read(&format!("/cal/{i}"));
            }
            m.dfs_read_bps = (8.0 * payload.len() as f64) / t0.elapsed().as_secs_f64().max(1e-6);
        }
        let _ = std::fs::remove_dir_all(&root);

        // Decode throughput.
        let u = ModelUpdate::new(0, 1.0, 0, vec![1.0; 1 << 20]);
        let buf = u.encode();
        let t0 = Instant::now();
        for _ in 0..4 {
            let _ = ModelUpdate::decode(&buf);
        }
        m.decode_bps = (4.0 * buf.len() as f64) / t0.elapsed().as_secs_f64().max(1e-6);

        // Dequantize throughput: int8 payload -> dense f32, the per-byte
        // cost the encoding-aware planner charges for compressed frames.
        let frame = crate::tensorstore::codec::encode_update(
            &u,
            crate::tensorstore::Encoding::QuantI8,
        );
        let ev = crate::tensorstore::EncodedUpdateView::decode(&frame).expect("own frame");
        let payload = crate::tensorstore::Encoding::QuantI8.payload_bytes(1 << 20) as f64;
        let t0 = Instant::now();
        for _ in 0..4 {
            let _ = ev.decode_data();
        }
        m.dequant_bps = (4.0 * payload) / t0.elapsed().as_secs_f64().max(1e-6);

        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_is_sane() {
        let m = CostModel::nominal();
        assert!(m.fuse_bps > 1e8);
        assert!(m.parallel_serial_frac > 0.0 && m.parallel_serial_frac < 1.0);
        assert!(m.dfs_read_bps > m.dfs_write_bps / 10.0);
    }

    #[test]
    fn calibration_produces_positive_constants() {
        let m = CostModel::calibrate();
        assert!(m.fuse_bps > 1e6, "fuse {}", m.fuse_bps);
        assert!(m.dfs_read_bps > 1e6, "read {}", m.dfs_read_bps);
        assert!(m.dfs_write_bps > 1e6, "write {}", m.dfs_write_bps);
        assert!(m.decode_bps > 1e6, "decode {}", m.decode_bps);
        assert!(m.dequant_bps > 1e6, "dequant {}", m.dequant_bps);
    }
}
