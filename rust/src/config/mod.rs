//! Configuration system: the Table-I model zoo, cluster topology, and the
//! aggregation-service settings, loadable from JSON files and overridable
//! from the CLI.

pub mod models;

use crate::net::WaiterKind;
use crate::planner::DispatchPolicy;
use crate::tensorstore::Encoding;
use crate::util::json::Json;
use std::path::Path;

pub use models::{ModelSpec, ModelZoo};

/// Aggregator-node resources — the knobs Figures 1–3 sweep.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeResources {
    /// Usable aggregation memory in bytes (the paper's 170 GB).
    pub memory_bytes: u64,
    /// Core count (the paper's 64).
    pub cores: usize,
}

impl Default for NodeResources {
    fn default() -> Self {
        // Scaled default for one-box runs; benches override (incl. virtual
        // 170 GB sweeps through the cluster cost model).
        NodeResources { memory_bytes: 2 << 30, cores: 4 }
    }
}

/// Cluster topology for the distributed path (the paper's 4-node Spark/Yarn
/// over 3 HDFS datanodes, 1 GbE to the client machines).
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterSpec {
    pub workers: usize,
    pub cores_per_worker: usize,
    pub mem_per_worker: u64,
    pub datanodes: usize,
    pub replication: usize,
    /// Client-side uplink capacity in bytes/sec (paper: 1 GbE switch).
    pub client_link_bps: f64,
    /// Max memory per executor container (paper: 35 GB cap).
    pub executor_mem_cap: u64,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        ClusterSpec {
            workers: 4,
            cores_per_worker: 64,
            mem_per_worker: 197 << 30,
            datanodes: 3,
            replication: 2,
            client_link_bps: 125e6, // 1 Gb/s
            executor_mem_cap: 35 << 30,
        }
    }
}

/// Where this aggregator sits in the (optionally 2-tier) topology — the
/// same binary serves every role, selected by config.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeRole {
    /// Flat deployment: clients upload straight to this node (the paper's
    /// single-aggregator shape).  The default.
    Standalone,
    /// Edge aggregator: runs its local quorum round over its cohort, then
    /// acts as a client of `parent_addr`, uploading ONE weighted partial
    /// aggregate per round.
    Relay,
    /// Root of a 2-tier tree: accepts partial aggregates from relays (and
    /// direct uploads from stray clients) on a streaming round.
    Root,
}

impl NodeRole {
    pub fn parse(s: &str) -> Option<NodeRole> {
        match s.to_ascii_lowercase().as_str() {
            "standalone" | "flat" => Some(NodeRole::Standalone),
            "relay" | "edge" => Some(NodeRole::Relay),
            "root" => Some(NodeRole::Root),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            NodeRole::Standalone => "standalone",
            NodeRole::Relay => "relay",
            NodeRole::Root => "root",
        }
    }

    /// Whether this node participates in a 2-tier topology (and therefore
    /// must run the streaming ingest, the only state that folds partials).
    pub fn is_hierarchical(&self) -> bool {
        !matches!(self, NodeRole::Standalone)
    }
}

impl std::fmt::Display for NodeRole {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Settings of the adaptive aggregation service (Algorithm 1).
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub node: NodeResources,
    pub cluster: ClusterSpec,
    /// Monitor threshold: fraction of expected updates to wait for.
    pub monitor_threshold: f64,
    /// Monitor timeout in seconds.
    pub monitor_timeout_s: f64,
    /// Safety factor on the single-node memory check (headroom for the
    /// result buffer + framework overhead).
    pub memory_headroom: f64,
    /// Root dir for the DFS datanode directories.
    pub dfs_root: String,
    /// Model-size scale (1.0 = paper sizes; default 0.01 fits one box).
    pub size_scale: f64,
    /// Dispatch-planner policy: `min_latency`, `min_cost`, or
    /// `balanced:<alpha>` (the cost/efficiency trade-off knob).
    pub policy: DispatchPolicy,
    /// $/s rate of the aggregator node (plan pricing).
    pub node_usd_per_s: f64,
    /// $/s rate per distributed executor container (plan pricing).
    pub executor_usd_per_s: f64,
    /// Largest executor pool the planner/autoscaler may use.
    pub max_executors: usize,
    /// Quorum for a driven round, as a fraction of the expected uploads:
    /// at the round deadline, `ceil(fraction × expected)` folded updates
    /// aggregate as a Quorum round; fewer abort it.  1.0 = all-or-abort.
    pub quorum_fraction: f64,
    /// Deadline of a driven round in seconds (`run_round_configured`).
    pub round_deadline_s: f64,
    /// Prior on the fraction of registered parties that actually deliver
    /// an upload (edge fleets drop out and straggle); the planner prices
    /// K·p uploads and calibrates p from observed rounds.
    pub expected_participation: f64,
    /// This node's place in the (optionally 2-tier) topology.
    pub role: NodeRole,
    /// Parent aggregator address a `relay` forwards its partial to.
    pub parent_addr: Option<String>,
    /// This edge aggregator's id (stamped on forwarded partials).
    pub edge_id: u64,
    /// Edge aggregators available for a 2-tier plan: with ≥ 2 the planner
    /// enumerates + prices `PlanKind::Hierarchical` alongside the flat
    /// candidates (0 or 1 = flat only).
    pub edges: usize,
    /// Run the FedBuff-style asynchronous ingest instead of quorum rounds:
    /// uploads are admitted into a bounded staleness buffer and the model
    /// publishes on buffer-full or cadence, never on a quorum seal.
    pub async_mode: bool,
    /// Staleness-buffer capacity K (the "K freshest updates" bound).
    pub async_buffer: usize,
    /// Exponent `a` of the staleness discount `s(δ) = (1 + δ)^-a`
    /// (FedBuff's default is 0.5; 0 disables discounting, which makes the
    /// async fold bit-identical to the sync streaming fold).
    pub staleness_exponent: f64,
    /// Publish cadence in seconds: an async round publishes when the
    /// buffer fills OR this much time elapsed, whichever first.
    pub async_cadence_s: f64,
    /// Fraction of parties trimmed from EACH tail by the coordinate-wise
    /// trimmed mean (`algo = trimmed`): 0.2 drops the 20% largest and 20%
    /// smallest values per coordinate.  Domain [0, 0.5); values at or past
    /// 0.5 would trim everything and are rejected at load.
    pub trim_fraction: f64,
    /// Robust admission gate: uploads whose L2 norm exceeds
    /// `clip_factor × median_norm` have their fusion weight clipped down,
    /// and norms past `clip_factor² × median_norm` are rejected outright
    /// (typed `Rejected` reply + trust decay).  0 (the default) disables
    /// the gate entirely — no per-upload norm work, bit-identical rounds.
    pub clip_factor: f64,
    /// Multiplier applied to a party's trust score on each outlier /
    /// rejection event (domain [0, 1]; smaller = harsher).  Honest parties
    /// recover trust additively each sealed round.
    pub trust_decay: f64,
    /// Wire encoding clients are asked to upload with and the planner
    /// prices rounds at: `dense_f32` (lossless, zero-copy — the default),
    /// `f16`, `int8`, or `topk[:permille]`.  Compressed encodings shrink
    /// every client→aggregator frame; relay→root partials stay dense f32
    /// regardless.
    pub encoding: Encoding,
    /// Fold worker threads behind the network reactor's poll loop: the
    /// server runs `1 + workers` OS threads regardless of how many
    /// connections are live.  0 (the default) = one worker per node core.
    pub reactor_workers: usize,
    /// Liveness TTL in seconds: a driven round evicts registered parties
    /// whose last liveness signal (join / upload / heartbeat) is older
    /// than this, and seals once the quorum covers the *live* population
    /// instead of awaiting dead clients to the deadline.  0 (the default)
    /// disables eviction.  A positive TTL below `evict_cadence_s` is
    /// rejected at load: the wait loop only re-checks liveness once per
    /// cadence, so a sub-cadence TTL would evict every party on every
    /// tick regardless of heartbeats.
    pub liveness_ttl_s: f64,
    /// How often (seconds) a driven round's wait loop re-checks liveness
    /// and evicts stale parties.  Also the floor on `liveness_ttl_s`.
    pub evict_cadence_s: f64,
    /// Readiness backend the network reactor waits on: `auto` (epoll on
    /// Linux, kqueue on macOS/BSD, sweep elsewhere — the default),
    /// `sweep`, `epoll` or `kqueue`.  `ELASTIAGG_NO_EPOLL=1` forces
    /// sweep regardless of this knob.
    pub waiter: WaiterKind,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            node: NodeResources::default(),
            cluster: ClusterSpec::default(),
            monitor_threshold: 1.0,
            monitor_timeout_s: 600.0,
            memory_headroom: 1.10,
            dfs_root: "/tmp/elastiagg-dfs".to_string(),
            size_scale: 0.01,
            policy: DispatchPolicy::Balanced(0.5),
            node_usd_per_s: 8.5e-4,
            executor_usd_per_s: 5.6e-5,
            max_executors: 8,
            quorum_fraction: 1.0,
            round_deadline_s: 600.0,
            expected_participation: 1.0,
            role: NodeRole::Standalone,
            parent_addr: None,
            edge_id: 0,
            edges: 0,
            async_mode: false,
            async_buffer: 64,
            staleness_exponent: 0.5,
            async_cadence_s: 5.0,
            trim_fraction: 0.0,
            clip_factor: 0.0,
            trust_decay: 0.5,
            encoding: Encoding::DenseF32,
            reactor_workers: 0,
            liveness_ttl_s: 0.0,
            evict_cadence_s: 0.025,
            waiter: WaiterKind::Auto,
        }
    }
}

impl ServiceConfig {
    /// Load from a JSON file; missing keys keep their defaults.
    pub fn from_file(path: &Path) -> std::io::Result<ServiceConfig> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))?;
        Ok(Self::from_json(&j))
    }

    pub fn from_json(j: &Json) -> ServiceConfig {
        let mut c = ServiceConfig::default();
        if let Some(v) = j.get("memory_bytes").as_u64() {
            c.node.memory_bytes = v;
        }
        if let Some(v) = j.get("cores").as_usize() {
            c.node.cores = v;
        }
        if let Some(v) = j.get("workers").as_usize() {
            c.cluster.workers = v;
        }
        if let Some(v) = j.get("cores_per_worker").as_usize() {
            c.cluster.cores_per_worker = v;
        }
        if let Some(v) = j.get("mem_per_worker").as_u64() {
            c.cluster.mem_per_worker = v;
        }
        if let Some(v) = j.get("datanodes").as_usize() {
            c.cluster.datanodes = v;
        }
        if let Some(v) = j.get("replication").as_usize() {
            c.cluster.replication = v;
        }
        if let Some(v) = j.get("monitor_threshold").as_f64() {
            c.monitor_threshold = v;
        }
        if let Some(v) = j.get("monitor_timeout_s").as_f64() {
            c.monitor_timeout_s = v;
        }
        if let Some(v) = j.get("memory_headroom").as_f64() {
            c.memory_headroom = v;
        }
        if let Some(v) = j.get("dfs_root").as_str() {
            c.dfs_root = v.to_string();
        }
        if let Some(v) = j.get("size_scale").as_f64() {
            c.size_scale = v;
        }
        if let Some(p) = j.get("policy").as_str().and_then(DispatchPolicy::parse) {
            c.policy = p;
        }
        if let Some(v) = j.get("node_usd_per_s").as_f64() {
            c.node_usd_per_s = v;
        }
        if let Some(v) = j.get("executor_usd_per_s").as_f64() {
            c.executor_usd_per_s = v;
        }
        if let Some(v) = j.get("max_executors").as_usize() {
            c.max_executors = v;
        }
        if let Some(v) = j.get("quorum_fraction").as_f64() {
            c.quorum_fraction = v.clamp(0.0, 1.0);
        }
        if let Some(v) = j.get("round_deadline_s").as_f64() {
            // a negative/NaN/oversized deadline would panic in
            // Duration::from_secs_f64; cap at one year
            if v.is_finite() && v >= 0.0 {
                c.round_deadline_s = v.min(31_536_000.0);
            }
        }
        if let Some(v) = j.get("expected_participation").as_f64() {
            c.expected_participation = v.clamp(0.0, 1.0);
        }
        if let Some(r) = j.get("role").as_str().and_then(NodeRole::parse) {
            c.role = r;
        }
        if let Some(v) = j.get("parent_addr").as_str() {
            c.parent_addr = Some(v.to_string());
        }
        if let Some(v) = j.get("edge_id").as_u64() {
            c.edge_id = v;
        }
        if let Some(v) = j.get("edges").as_usize() {
            c.edges = v;
        }
        if let Some(v) = j.get("async_mode").as_bool() {
            c.async_mode = v;
        }
        if let Some(v) = j.get("async_buffer").as_usize() {
            c.async_buffer = v.max(1);
        }
        if let Some(v) = j.get("staleness_exponent").as_f64() {
            // the discount curve sanitises again, but reject junk at load
            // so to_json round-trips what the service will actually use
            if v.is_finite() && v >= 0.0 {
                c.staleness_exponent = v;
            }
        }
        if let Some(v) = j.get("async_cadence_s").as_f64() {
            // same Duration::from_secs_f64 domain as round_deadline_s
            if v.is_finite() && v >= 0.0 {
                c.async_cadence_s = v.min(31_536_000.0);
            }
        }
        if let Some(v) = j.get("trim_fraction").as_f64() {
            // ≥ 0.5 trims every contributor; NaN/negative would poison the
            // per-coordinate k — junk keeps the (off) default rather than
            // silently disabling a robustness knob the operator set
            if v.is_finite() && (0.0..0.5).contains(&v) {
                c.trim_fraction = v;
            }
        }
        if let Some(v) = j.get("clip_factor").as_f64() {
            // 0 = gate off; NaN/negative must not reach the norm compare
            if v.is_finite() && v >= 0.0 {
                c.clip_factor = v;
            }
        }
        if let Some(v) = j.get("trust_decay").as_f64() {
            // a decay outside [0, 1] would grow trust on misbehaviour
            if v.is_finite() && (0.0..=1.0).contains(&v) {
                c.trust_decay = v;
            }
        }
        if let Some(e) = j.get("encoding").as_str().and_then(Encoding::parse) {
            c.encoding = e;
        }
        if let Some(v) = j.get("reactor_workers").as_usize() {
            c.reactor_workers = v;
        }
        if let Some(w) = j.get("waiter").as_str().and_then(WaiterKind::parse) {
            c.waiter = w;
        }
        // evict_cadence_s parses BEFORE liveness_ttl_s: the TTL floor
        // below compares against whatever cadence this config carries.
        if let Some(v) = j.get("evict_cadence_s").as_f64() {
            // same Duration::from_secs_f64 domain as round_deadline_s,
            // and a zero cadence would spin the wait loop
            if v.is_finite() && v > 0.0 {
                c.evict_cadence_s = v.min(31_536_000.0);
            }
        }
        if let Some(v) = j.get("liveness_ttl_s").as_f64() {
            // same Duration::from_secs_f64 domain as round_deadline_s.
            // A positive TTL below the evict cadence is junk (see the
            // field docs): eviction stays off rather than misfiring.
            if v.is_finite() && (v == 0.0 || (v >= c.evict_cadence_s && v >= 0.0)) {
                c.liveness_ttl_s = v.min(31_536_000.0);
            }
        }
        c
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("memory_bytes", Json::num(self.node.memory_bytes as f64)),
            ("cores", Json::num(self.node.cores as f64)),
            ("workers", Json::num(self.cluster.workers as f64)),
            ("cores_per_worker", Json::num(self.cluster.cores_per_worker as f64)),
            ("mem_per_worker", Json::num(self.cluster.mem_per_worker as f64)),
            ("datanodes", Json::num(self.cluster.datanodes as f64)),
            ("replication", Json::num(self.cluster.replication as f64)),
            ("monitor_threshold", Json::num(self.monitor_threshold)),
            ("monitor_timeout_s", Json::num(self.monitor_timeout_s)),
            ("memory_headroom", Json::num(self.memory_headroom)),
            ("dfs_root", Json::str(&self.dfs_root)),
            ("size_scale", Json::num(self.size_scale)),
            ("policy", Json::str(&self.policy.to_string())),
            ("node_usd_per_s", Json::num(self.node_usd_per_s)),
            ("executor_usd_per_s", Json::num(self.executor_usd_per_s)),
            ("max_executors", Json::num(self.max_executors as f64)),
            ("quorum_fraction", Json::num(self.quorum_fraction)),
            ("round_deadline_s", Json::num(self.round_deadline_s)),
            ("expected_participation", Json::num(self.expected_participation)),
            ("role", Json::str(self.role.as_str())),
            (
                "parent_addr",
                match &self.parent_addr {
                    Some(a) => Json::str(a),
                    None => Json::Null,
                },
            ),
            ("edge_id", Json::num(self.edge_id as f64)),
            ("edges", Json::num(self.edges as f64)),
            ("async_mode", Json::Bool(self.async_mode)),
            ("async_buffer", Json::num(self.async_buffer as f64)),
            ("staleness_exponent", Json::num(self.staleness_exponent)),
            ("async_cadence_s", Json::num(self.async_cadence_s)),
            ("trim_fraction", Json::num(self.trim_fraction)),
            ("clip_factor", Json::num(self.clip_factor)),
            ("trust_decay", Json::num(self.trust_decay)),
            ("encoding", Json::str(&self.encoding.token())),
            ("reactor_workers", Json::num(self.reactor_workers as f64)),
            ("liveness_ttl_s", Json::num(self.liveness_ttl_s)),
            ("evict_cadence_s", Json::num(self.evict_cadence_s)),
            ("waiter", Json::str(self.waiter.token())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_cluster() {
        let c = ClusterSpec::default();
        assert_eq!(c.workers, 4);
        assert_eq!(c.cores_per_worker, 64);
        assert_eq!(c.datanodes, 3);
        assert_eq!(c.replication, 2);
        assert_eq!(c.executor_mem_cap, 35 << 30);
    }

    #[test]
    fn json_roundtrip() {
        let mut c = ServiceConfig::default();
        c.node.memory_bytes = 170 << 30;
        c.monitor_threshold = 0.9;
        let j = c.to_json();
        let c2 = ServiceConfig::from_json(&j);
        assert_eq!(c2.node.memory_bytes, 170 << 30);
        assert_eq!(c2.monitor_threshold, 0.9);
        assert_eq!(c2.cluster.replication, 2);
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let j = Json::parse(r#"{"cores": 64}"#).unwrap();
        let c = ServiceConfig::from_json(&j);
        assert_eq!(c.node.cores, 64);
        assert_eq!(c.cluster.workers, 4);
        assert_eq!(c.policy, DispatchPolicy::Balanced(0.5));
        assert_eq!(c.max_executors, 8);
    }

    #[test]
    fn planner_knobs_roundtrip() {
        let mut c = ServiceConfig::default();
        c.policy = DispatchPolicy::Balanced(0.25);
        c.node_usd_per_s = 1e-3;
        c.executor_usd_per_s = 2e-5;
        c.max_executors = 12;
        let c2 = ServiceConfig::from_json(&c.to_json());
        assert_eq!(c2.policy, DispatchPolicy::Balanced(0.25));
        assert_eq!(c2.node_usd_per_s, 1e-3);
        assert_eq!(c2.executor_usd_per_s, 2e-5);
        assert_eq!(c2.max_executors, 12);
    }

    #[test]
    fn fault_knobs_roundtrip_and_default_to_strict() {
        let c = ServiceConfig::default();
        assert_eq!(c.quorum_fraction, 1.0);
        assert_eq!(c.expected_participation, 1.0);
        let mut c2 = c.clone();
        c2.quorum_fraction = 0.6;
        c2.round_deadline_s = 12.5;
        c2.expected_participation = 0.8;
        let c3 = ServiceConfig::from_json(&c2.to_json());
        assert_eq!(c3.quorum_fraction, 0.6);
        assert_eq!(c3.round_deadline_s, 12.5);
        assert_eq!(c3.expected_participation, 0.8);
        // out-of-range values clamp to the [0, 1] fraction domain
        let j = Json::parse(r#"{"quorum_fraction": 2.5, "expected_participation": -1.0}"#).unwrap();
        let c4 = ServiceConfig::from_json(&j);
        assert_eq!(c4.quorum_fraction, 1.0);
        assert_eq!(c4.expected_participation, 0.0);
        // a negative deadline would panic Duration::from_secs_f64 — it
        // must be rejected at load, keeping the default
        let j = Json::parse(r#"{"round_deadline_s": -1}"#).unwrap();
        let c5 = ServiceConfig::from_json(&j);
        assert_eq!(c5.round_deadline_s, 600.0);
        // ... and an oversized one caps at a year (from_secs_f64 also
        // panics past ~1.8e19 s)
        let j = Json::parse(r#"{"round_deadline_s": 1e20}"#).unwrap();
        let c6 = ServiceConfig::from_json(&j);
        assert_eq!(c6.round_deadline_s, 31_536_000.0);
    }

    #[test]
    fn topology_knobs_roundtrip_and_default_flat() {
        let c = ServiceConfig::default();
        assert_eq!(c.role, NodeRole::Standalone);
        assert!(!c.role.is_hierarchical());
        assert_eq!(c.parent_addr, None);
        assert_eq!(c.edges, 0);
        let mut c2 = c.clone();
        c2.role = NodeRole::Relay;
        c2.parent_addr = Some("10.0.0.1:7000".to_string());
        c2.edge_id = 3;
        c2.edges = 4;
        let c3 = ServiceConfig::from_json(&c2.to_json());
        assert_eq!(c3.role, NodeRole::Relay);
        assert!(c3.role.is_hierarchical());
        assert_eq!(c3.parent_addr.as_deref(), Some("10.0.0.1:7000"));
        assert_eq!(c3.edge_id, 3);
        assert_eq!(c3.edges, 4);
        // role aliases + an unknown role keeping the default
        assert_eq!(NodeRole::parse("edge"), Some(NodeRole::Relay));
        assert_eq!(NodeRole::parse("flat"), Some(NodeRole::Standalone));
        let j = Json::parse(r#"{"role": "galactic"}"#).unwrap();
        assert_eq!(ServiceConfig::from_json(&j).role, NodeRole::Standalone);
    }

    #[test]
    fn async_knobs_roundtrip_and_default_to_sync() {
        let c = ServiceConfig::default();
        assert!(!c.async_mode);
        assert_eq!(c.async_buffer, 64);
        assert_eq!(c.staleness_exponent, 0.5);
        assert_eq!(c.async_cadence_s, 5.0);
        let mut c2 = c.clone();
        c2.async_mode = true;
        c2.async_buffer = 16;
        c2.staleness_exponent = 1.5;
        c2.async_cadence_s = 0.25;
        let c3 = ServiceConfig::from_json(&c2.to_json());
        assert!(c3.async_mode);
        assert_eq!(c3.async_buffer, 16);
        assert_eq!(c3.staleness_exponent, 1.5);
        assert_eq!(c3.async_cadence_s, 0.25);
        // a zero buffer is meaningless — floor at 1
        let j = Json::parse(r#"{"async_buffer": 0}"#).unwrap();
        assert_eq!(ServiceConfig::from_json(&j).async_buffer, 1);
        // junk exponents/cadences keep the defaults (the cadence shares
        // round_deadline_s's Duration::from_secs_f64 domain)
        let j = Json::parse(r#"{"staleness_exponent": -2, "async_cadence_s": -1}"#).unwrap();
        let c4 = ServiceConfig::from_json(&j);
        assert_eq!(c4.staleness_exponent, 0.5);
        assert_eq!(c4.async_cadence_s, 5.0);
        let j = Json::parse(r#"{"async_cadence_s": 1e20}"#).unwrap();
        assert_eq!(ServiceConfig::from_json(&j).async_cadence_s, 31_536_000.0);
    }

    #[test]
    fn encoding_knob_roundtrips_and_defaults_dense() {
        let c = ServiceConfig::default();
        assert_eq!(c.encoding, Encoding::DenseF32);
        let mut c2 = c.clone();
        c2.encoding = Encoding::TopK { permille: 250 };
        let c3 = ServiceConfig::from_json(&c2.to_json());
        assert_eq!(c3.encoding, Encoding::TopK { permille: 250 });
        let j = Json::parse(r#"{"encoding": "int8"}"#).unwrap();
        assert_eq!(ServiceConfig::from_json(&j).encoding, Encoding::QuantI8);
        // unknown tokens keep the lossless default
        let j = Json::parse(r#"{"encoding": "zip"}"#).unwrap();
        assert_eq!(ServiceConfig::from_json(&j).encoding, Encoding::DenseF32);
    }

    #[test]
    fn robust_knobs_roundtrip_and_reject_junk() {
        let c = ServiceConfig::default();
        assert_eq!(c.trim_fraction, 0.0);
        assert_eq!(c.clip_factor, 0.0);
        assert_eq!(c.trust_decay, 0.5);
        let mut c2 = c.clone();
        c2.trim_fraction = 0.2;
        c2.clip_factor = 3.0;
        c2.trust_decay = 0.25;
        let c3 = ServiceConfig::from_json(&c2.to_json());
        assert_eq!(c3.trim_fraction, 0.2);
        assert_eq!(c3.clip_factor, 3.0);
        assert_eq!(c3.trust_decay, 0.25);
        // junk must neither panic nor silently disable robustness: NaN,
        // negatives, and out-of-domain values all keep the defaults
        let j = Json::parse(
            r#"{"trim_fraction": -0.1, "clip_factor": -3, "trust_decay": 1.5}"#,
        )
        .unwrap();
        let c4 = ServiceConfig::from_json(&j);
        assert_eq!(c4.trim_fraction, 0.0);
        assert_eq!(c4.clip_factor, 0.0);
        assert_eq!(c4.trust_decay, 0.5);
        // trim ≥ 0.5 would trim every contributor — rejected at load
        let j = Json::parse(r#"{"trim_fraction": 0.5}"#).unwrap();
        assert_eq!(ServiceConfig::from_json(&j).trim_fraction, 0.0);
        // NaN doesn't parse as a JSON number, but an operator can still
        // produce it via 1e999 → inf in some writers; reject non-finite
        let j = Json::parse(r#"{"clip_factor": 1e999}"#).unwrap();
        assert_eq!(ServiceConfig::from_json(&j).clip_factor, 0.0);
    }

    #[test]
    fn reactor_and_liveness_knobs_roundtrip_and_reject_junk() {
        let c = ServiceConfig::default();
        assert_eq!(c.reactor_workers, 0, "0 = one fold worker per core");
        assert_eq!(c.liveness_ttl_s, 0.0, "0 = eviction off");
        let mut c2 = c.clone();
        c2.reactor_workers = 6;
        c2.liveness_ttl_s = 2.5;
        let c3 = ServiceConfig::from_json(&c2.to_json());
        assert_eq!(c3.reactor_workers, 6);
        assert_eq!(c3.liveness_ttl_s, 2.5);
        // the ttl shares round_deadline_s's Duration::from_secs_f64 domain:
        // negatives keep the default, oversized caps at a year
        let j = Json::parse(r#"{"liveness_ttl_s": -3}"#).unwrap();
        assert_eq!(ServiceConfig::from_json(&j).liveness_ttl_s, 0.0);
        let j = Json::parse(r#"{"liveness_ttl_s": 1e20}"#).unwrap();
        assert_eq!(ServiceConfig::from_json(&j).liveness_ttl_s, 31_536_000.0);
    }

    #[test]
    fn waiter_and_evict_cadence_knobs_roundtrip_and_reject_junk() {
        let c = ServiceConfig::default();
        assert_eq!(c.waiter, WaiterKind::Auto);
        assert_eq!(c.evict_cadence_s, 0.025, "matches the wait loop's old 25ms tick");
        let mut c2 = c.clone();
        c2.waiter = WaiterKind::Sweep;
        c2.evict_cadence_s = 0.1;
        let c3 = ServiceConfig::from_json(&c2.to_json());
        assert_eq!(c3.waiter, WaiterKind::Sweep);
        assert_eq!(c3.evict_cadence_s, 0.1);
        // unknown waiter token keeps the default instead of guessing
        let j = Json::parse(r#"{"waiter": "io_uring"}"#).unwrap();
        assert_eq!(ServiceConfig::from_json(&j).waiter, WaiterKind::Auto);
        // cadence shares the Duration domain; zero would spin the wait loop
        let j = Json::parse(r#"{"evict_cadence_s": 0}"#).unwrap();
        assert_eq!(ServiceConfig::from_json(&j).evict_cadence_s, 0.025);
        let j = Json::parse(r#"{"evict_cadence_s": -1}"#).unwrap();
        assert_eq!(ServiceConfig::from_json(&j).evict_cadence_s, 0.025);
        let j = Json::parse(r#"{"evict_cadence_s": 1e20}"#).unwrap();
        assert_eq!(ServiceConfig::from_json(&j).evict_cadence_s, 31_536_000.0);
    }

    #[test]
    fn sub_cadence_liveness_ttl_is_rejected() {
        // The wait loop re-checks liveness once per evict cadence: a TTL
        // below the cadence would evict every party on every tick no
        // matter how fast they heartbeat.  Such a TTL keeps eviction OFF.
        let j = Json::parse(r#"{"liveness_ttl_s": 0.01}"#).unwrap();
        let c = ServiceConfig::from_json(&j);
        assert_eq!(c.liveness_ttl_s, 0.0, "TTL below the default 25ms cadence");
        // at or above the cadence it loads normally
        let j = Json::parse(r#"{"liveness_ttl_s": 0.025}"#).unwrap();
        assert_eq!(ServiceConfig::from_json(&j).liveness_ttl_s, 0.025);
        // a custom cadence moves the floor with it — order-independent
        // because evict_cadence_s always parses first
        let j = Json::parse(r#"{"liveness_ttl_s": 0.2, "evict_cadence_s": 0.5}"#).unwrap();
        let c = ServiceConfig::from_json(&j);
        assert_eq!(c.evict_cadence_s, 0.5);
        assert_eq!(c.liveness_ttl_s, 0.0, "TTL below the configured cadence");
        let j = Json::parse(r#"{"liveness_ttl_s": 0.6, "evict_cadence_s": 0.5}"#).unwrap();
        assert_eq!(ServiceConfig::from_json(&j).liveness_ttl_s, 0.6);
    }

    #[test]
    fn bad_policy_string_keeps_default() {
        let j = Json::parse(r#"{"policy": "warp_speed"}"#).unwrap();
        let c = ServiceConfig::from_json(&j);
        assert_eq!(c.policy, DispatchPolicy::Balanced(0.5));
    }
}
