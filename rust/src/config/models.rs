//! Table I — the paper's benchmark model zoo.
//!
//! Each spec records the published update size; the flat parameter count is
//! `size_bytes / 4` (f32).  The default `size_scale = 0.01` shrinks every
//! model 1:100 so paper-shaped sweeps fit one box; fusion cost is linear in
//! bytes, and the benches report both the measured scaled points and the
//! paper-scale extrapolation through the cluster cost model.

/// One row of Table I.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub name: &'static str,
    /// Paper update size in bytes.
    pub size_bytes: u64,
    /// Human description of the architecture column in Table I.
    pub arch: &'static str,
}

impl ModelSpec {
    pub const fn new(name: &'static str, size_bytes: u64, arch: &'static str) -> ModelSpec {
        ModelSpec { name, size_bytes, arch }
    }

    /// Flat f32 parameter count at scale 1.0.
    pub fn param_count(&self) -> usize {
        (self.size_bytes / 4) as usize
    }

    /// Parameter count after applying the size scale (>= 1 element).
    pub fn scaled_params(&self, scale: f64) -> usize {
        (((self.size_bytes as f64) * scale / 4.0).round() as usize).max(1)
    }

    /// Scaled update size in bytes.
    pub fn scaled_bytes(&self, scale: f64) -> u64 {
        self.scaled_params(scale) as u64 * 4
    }
}

const MB: u64 = 1024 * 1024;

/// The full Table I in paper order.
pub const TABLE1: [ModelSpec; 9] = [
    ModelSpec::new("CNN4.6", (4.6 * MB as f64) as u64, "conv 32,64 + dense 128"),
    ModelSpec::new("CNN73", 73 * MB, "conv 32,256,512,1024 + dense 128"),
    ModelSpec::new("CNN179", 179 * MB, "conv 32,512,1024,1900 + dense 128"),
    ModelSpec::new("CNN239", 239 * MB, "conv 32,1024,1900,2400 + dense 128"),
    ModelSpec::new("CNN478", 478 * MB, "conv (32,1024,1900,2400)x2 + dense 128x2"),
    ModelSpec::new("CNN717", 717 * MB, "conv (32,1024,1900,2400)x3 + dense 128x3"),
    ModelSpec::new("CNN956", 956 * MB, "conv (32,1024,1900,2400)x2 + dense 128x4"),
    ModelSpec::new("Resnet50", 91 * MB, "He et al. 2015"),
    ModelSpec::new("VGG16", 528 * MB, "Simonyan & Zisserman 2014"),
];

/// Lookup + iteration facade over Table I.
pub struct ModelZoo;

impl ModelZoo {
    pub fn all() -> &'static [ModelSpec] {
        &TABLE1
    }

    pub fn get(name: &str) -> Option<&'static ModelSpec> {
        TABLE1.iter().find(|m| m.name.eq_ignore_ascii_case(name))
    }

    /// The CNN-size ladder used by Figs 2, 5, 9, 10 (exclude the two
    /// real-architecture models).
    pub fn cnn_ladder() -> Vec<&'static ModelSpec> {
        TABLE1.iter().filter(|m| m.name.starts_with("CNN")).collect()
    }

    /// The Fig-12 end-to-end set with the paper's party counts.
    pub fn fig12_set() -> Vec<(&'static ModelSpec, usize)> {
        vec![
            (ModelZoo::get("CNN956").unwrap(), 6),
            (ModelZoo::get("CNN478").unwrap(), 12),
            (ModelZoo::get("Resnet50").unwrap(), 60),
            (ModelZoo::get("CNN73").unwrap(), 84),
            (ModelZoo::get("CNN4.6").unwrap(), 1272),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_paper_rows() {
        assert_eq!(TABLE1.len(), 9);
        assert_eq!(ModelZoo::get("CNN4.6").unwrap().size_bytes, (4.6 * MB as f64) as u64);
        assert_eq!(ModelZoo::get("VGG16").unwrap().size_bytes, 528 * MB);
        assert_eq!(ModelZoo::get("resnet50").unwrap().size_bytes, 91 * MB);
    }

    #[test]
    fn param_counts_are_quarter_bytes() {
        for m in ModelZoo::all() {
            assert_eq!(m.param_count(), (m.size_bytes / 4) as usize);
        }
    }

    #[test]
    fn scaling_is_linear_and_nonzero() {
        let m = ModelZoo::get("CNN956").unwrap();
        let full = m.scaled_params(1.0);
        let tiny = m.scaled_params(0.01);
        assert!(((full as f64 / tiny as f64) - 100.0).abs() < 0.5);
        // degenerate scale still yields one parameter
        assert_eq!(m.scaled_params(1e-12), 1);
    }

    #[test]
    fn fig12_party_counts_match_paper() {
        let set = ModelZoo::fig12_set();
        let parties: Vec<usize> = set.iter().map(|(_, n)| *n).collect();
        assert_eq!(parties, vec![6, 12, 60, 84, 1272]);
    }

    #[test]
    fn cnn_ladder_ordered_by_size() {
        let ladder = ModelZoo::cnn_ladder();
        assert_eq!(ladder.len(), 7);
        for w in ladder.windows(2) {
            assert!(w[0].size_bytes < w[1].size_bytes);
        }
    }
}
