//! The FL aggregation server: binds the TCP front to the adaptive service.
//!
//! Request handling (per paper Fig 4 and §III-D3):
//! * `Register`  → party joins the registry, learns the current round;
//! * `Upload`    → small path: the update is ingested into the current
//!   round's in-memory state (charged against the node budget); on a
//!   *streaming* round the handler folds the update — decoded as a
//!   borrowed view straight out of the connection's pooled wire buffer —
//!   into one of S ≈ cores shard-local O(C) accumulators on receipt,
//!   instead of parking it; the Ack carries the redirect flag when the
//!   *next* round is predicted Large (streaming rounds keep the
//!   message-passing channel — that is the Fig 1 ceiling lift);
//! * `GetModel`  → returns the fused model once the round is published,
//!   framed zero-copy from the published `Arc`.
//!
//! Round progression is driven by the owner (examples / benches) via
//! [`FlServer::run_round`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::{
    AdaptiveService, AsyncError, AsyncRound, PartyRegistry, RoundError, RoundOutcome, RoundState,
    ServiceError, ServiceReport, WorkloadClass,
};
use crate::engine::StreamingFold;
use crate::fusion::{l2_norm, DiscountedFusion, FusionAlgorithm, StalenessDiscount, TrustWeighted};
use crate::memsim::MemoryBudget;
use crate::net::server::Handler;
use crate::net::{
    protocol, Message, NetServer, ProtoError, ReactorConfig, Reply, ServerHandle, TimerDriver,
};
use crate::tensorstore::{
    decode_stats, DecodeStats, EncodedUpdateView, ModelUpdateView, PartialAggregateView,
};
#[cfg(test)]
use crate::tensorstore::ModelUpdate;

pub mod relay;

pub use relay::{RelayRound, RelayServer};

pub struct FlServer {
    pub service: Arc<AdaptiveService>,
    pub registry: Arc<PartyRegistry>,
    algo: Arc<dyn FusionAlgorithm>,
    /// Bytes of one update at the current model size (classification input).
    update_bytes: u64,
    node_budget: MemoryBudget,
    current_round: AtomicU32,
    rounds: Mutex<BTreeMap<u32, Arc<RoundState>>>,
    /// The FedBuff-style buffered-publish state, present when the config
    /// enables `async_mode`: uploads bypass the quorum round machinery
    /// entirely and land in this bounded staleness buffer instead.
    async_round: Option<Arc<AsyncRound>>,
    /// Wakes the round loops (quorum wait, async fill, relay collect) the
    /// moment an ingest lands, replacing their fixed-cadence sleep polls;
    /// the loops only time out on real deadlines (round deadline, evict
    /// cadence).
    timer: TimerDriver,
}

impl FlServer {
    pub fn new(
        service: AdaptiveService,
        algo: Arc<dyn FusionAlgorithm>,
        update_bytes: u64,
    ) -> Arc<FlServer> {
        let node_budget = MemoryBudget::new(service.config().node.memory_bytes);
        let cfg = service.config();
        let async_round = if cfg.async_mode {
            Some(Arc::new(AsyncRound::new(cfg.async_buffer, node_budget.clone())))
        } else {
            None
        };
        let registry = Arc::new(PartyRegistry::new());
        // A positive clip factor switches robust mode on: every weight the
        // folds read goes through the trust/clip wrapper.  With uniform
        // trust and no sealed norm reference the wrapper is the bitwise
        // identity, so turning the knob on costs nothing until someone
        // misbehaves (pinned in `engine_parity`).
        let clip = cfg.clip_factor;
        let algo: Arc<dyn FusionAlgorithm> = if clip.is_finite() && clip > 0.0 {
            Arc::new(TrustWeighted::new(algo, registry.clone(), clip as f32))
        } else {
            algo
        };
        let s = Arc::new(FlServer {
            service: Arc::new(service),
            registry,
            algo,
            update_bytes,
            node_budget,
            current_round: AtomicU32::new(0),
            rounds: Mutex::new(BTreeMap::new()),
            async_round,
            timer: TimerDriver::new(),
        });
        s.open_round(0);
        s
    }

    pub fn current_round(&self) -> u32 {
        self.current_round.load(Ordering::Acquire)
    }

    /// Build a round's state for its class.  Streaming rounds fold at
    /// ingest into S ≈ cores shard lanes (at most S·O(C) reserved, less
    /// when the budget forces the lane fallback).
    fn make_state(&self, round: u32, class: WorkloadClass) -> RoundState {
        if class == WorkloadClass::Streaming {
            let lanes = self.service.config().node.cores.max(1);
            match RoundState::new_streaming(
                round,
                class,
                self.node_budget.clone(),
                self.algo.clone(),
                lanes,
            ) {
                Ok(st) => return st,
                // Unreachable today: `classify_full` returns Streaming only
                // for decomposable algorithms, which is exactly the fold's
                // construction precondition.  If the preconditions ever
                // diverge, fall back to a buffered Large round — per-upload
                // Acks then carry redirect_to_dfs, steering parties to the
                // store channel that path expects.
                Err(_) => {
                    return RoundState::new(round, WorkloadClass::Large, self.node_budget.clone())
                }
            }
        }
        RoundState::new(round, class, self.node_budget.clone())
    }

    /// The round class this server actually runs at `parties`: the
    /// three-way classifier, overridden to `Streaming` on hierarchical
    /// nodes (relay or root) whenever the hierarchy gate admits the
    /// algorithm — the streaming ingest is the only state that folds
    /// partial aggregates, and a relay must produce one.  A hierarchical
    /// node whose algorithm fails the gate (holistic, or O(C) overflow)
    /// degrades to the flat classes: median/Krum deployments stay flat.
    fn classify_effective(&self, parties: usize) -> WorkloadClass {
        if self.service.config().role.is_hierarchical()
            && self
                .service
                .hierarchy_feasible(self.update_bytes, self.algo.as_ref())
        {
            return WorkloadClass::Streaming;
        }
        self.service.classify_full(self.update_bytes, parties, self.algo.as_ref())
    }

    pub(crate) fn open_round(&self, round: u32) -> Arc<RoundState> {
        let expected = self.registry.active_count().max(1);
        let class = self.classify_effective(expected);
        let st = Arc::new(self.make_state(round, class));
        self.rounds.lock().unwrap().insert(round, st.clone());
        self.current_round.store(round, Ordering::Release);
        st
    }

    pub fn round_state(&self, round: u32) -> Option<Arc<RoundState>> {
        self.rounds.lock().unwrap().get(&round).cloned()
    }

    /// Replace an (empty) round's state with a re-classified one.
    ///
    /// Uploads race this: a connection may have fetched the OLD state and
    /// be folding into it right now.  Two defenses keep that window
    /// honest: the emptiness check is re-taken *under the rounds lock*
    /// (an upload that already landed keeps its state — and its class),
    /// and the replaced state is aborted, so a fold still in flight gets
    /// the typed `WrongPhase`/`Late` reply instead of a silent discard
    /// behind an Ack.  (A fold that completes in the final instruction
    /// window between the check and the abort can still be dropped — the
    /// callers' settle beat covers it; see `sim::run_scenario`.)
    fn reopen_round(&self, round: u32, class: WorkloadClass) -> Arc<RoundState> {
        let st = Arc::new(self.make_state(round, class));
        let mut rounds = self.rounds.lock().unwrap();
        if let Some(old) = rounds.get(&round) {
            if old.collected() > 0 {
                return old.clone();
            }
            let _ = old.abort();
        }
        rounds.insert(round, st.clone());
        st
    }

    /// Serve on `addr` (port 0 = ephemeral) with the readiness reactor,
    /// its fold worker pool sized from the config (`reactor_workers`,
    /// 0 = one worker per node core).
    pub fn start(self: &Arc<Self>, addr: &str) -> std::io::Result<ServerHandle> {
        let cfg = self.service.config();
        let workers = if cfg.reactor_workers == 0 {
            cfg.node.cores.max(1)
        } else {
            cfg.reactor_workers
        };
        NetServer::serve_with(
            addr,
            Arc::new(FlHandler(self.clone())),
            ReactorConfig { workers, waiter: cfg.waiter },
        )
    }

    /// Serve with the legacy thread-per-connection backend.  Kept so the
    /// reactor's round digests can be pinned bit-identical against it
    /// (`benches/fig_connection_scaling`); new deployments use
    /// [`FlServer::start`].
    pub fn start_threaded(self: &Arc<Self>, addr: &str) -> std::io::Result<ServerHandle> {
        NetServer::serve_threaded(addr, Arc::new(FlHandler(self.clone())))
    }

    /// Hand one decoded wire frame straight to the request path,
    /// bypassing the socket layer.  The virtual-client fleet
    /// ([`crate::sim::fleet`]) drives 100k-party rounds through exactly
    /// the zero-copy frame path the reactor dispatches to, without 100k
    /// sockets or threads.  `payload` should come from a 4-aligned
    /// buffer ([`crate::net::FrameBuf`]) so borrowed-view decode is
    /// exercised, not silently downgraded to the copy fallback.
    pub fn inject_frame(&self, tag: u8, payload: &[u8]) -> Result<Reply, ProtoError> {
        self.handle_frame(tag, payload)
    }

    /// The sanitised robust knobs `(clip_factor, trust_decay)`; a clip
    /// factor of 0 means robust mode is off and no per-upload norm work
    /// happens at all.
    fn robust_knobs(&self) -> (f32, f32) {
        let cfg = self.service.config();
        let clip = if cfg.clip_factor.is_finite() && cfg.clip_factor > 0.0 {
            cfg.clip_factor as f32
        } else {
            0.0
        };
        let decay = if cfg.trust_decay.is_finite() {
            (cfg.trust_decay as f32).clamp(0.0, 1.0)
        } else {
            0.5
        };
        (clip, decay)
    }

    /// The robust admission gate, run INSIDE the ingest closure so the
    /// rejection rides the round's typed-error plumbing: when robust mode
    /// is on and a norm reference is sealed, an update whose L2 norm
    /// exceeds `clip_factor² × reference` is refused outright — soft
    /// clipping (up to `clip_factor ×`) is the fusion wrapper's job; this
    /// gate handles the frames too hostile to fold at any weight.  A
    /// rejection decays the sender's trust immediately.  Returns the norm
    /// to record after a successful fold (`None` when robust mode is off —
    /// honest deployments pay zero norm work per upload).
    fn robust_check(&self, party: u64, data: &[f32]) -> Result<Option<f32>, RoundError> {
        let (clip, decay) = self.robust_knobs();
        if clip == 0.0 {
            return Ok(None);
        }
        let norm = l2_norm(data);
        if let Some(nref) = self.registry.norm_ref() {
            let reject_at = clip * clip * nref;
            if norm > reject_at {
                self.registry.penalize(party, decay);
                return Err(RoundError::Rejected { party, norm });
            }
        }
        Ok(Some(norm))
    }

    /// Record an accepted update's norm for this round's median seal.
    fn note_norm(&self, party: u64, norm: Option<f32>) {
        if let Some(n) = norm {
            self.registry.observe_norm(party, n);
        }
    }

    /// Round-seal reputation bookkeeping: a sealed (published) round folds
    /// its observed norms into the next round's reference and judges every
    /// contributor; an aborted round judges nobody.  No-op when robust
    /// mode is off.
    fn seal_robust_round(&self, sealed: bool) {
        let (clip, decay) = self.robust_knobs();
        if clip == 0.0 {
            return;
        }
        if sealed {
            self.registry.seal_norms(decay);
        } else {
            self.registry.reset_norms();
        }
    }

    /// Shared shape of the upload reply: route the ingest closure to the
    /// current round's state, turn protocol failures into typed REPLIES —
    /// never a coordinator crash: a retransmit gets `Duplicate` (with the
    /// accepted nonce), a frame that missed the seal gets `Late`, anything
    /// else (wrong shape, OOM) an `Error` — and carry the
    /// seamless-transition redirect flag on the Ack.
    ///
    /// `declared` is the round the update says it belongs to: a straggler
    /// whose round already sealed AND reopened must not be folded into the
    /// successor (a stale gradient would pollute the aggregate and burn
    /// the party's dedup slot) — it gets the same `Late` reply as one that
    /// raced the seal itself.
    fn upload_with<F>(&self, declared: u32, ingest: F) -> Message
    where
        F: FnOnce(&RoundState) -> Result<usize, RoundError>,
    {
        let round = self.current_round();
        if declared != round {
            return Message::Late { round };
        }
        // Hierarchical nodes (when the gate admits the algorithm) never
        // redirect to the store: the whole point of the 2-tier topology is
        // that cohort traffic stays on the message-passing channel and
        // only one partial crosses to the root.
        let hierarchical = self.service.config().role.is_hierarchical()
            && self
                .service
                .hierarchy_feasible(self.update_bytes, self.algo.as_ref());
        let redirect = !hierarchical
            && self.service.should_redirect(
                self.update_bytes,
                self.registry.active_count().max(1),
                self.algo.as_ref(),
            );
        match self.round_state(round) {
            // Small rounds park the update; streaming rounds fold it on
            // receipt (straight out of the wire buffer on the frame path)
            // and free it.
            Some(st) if st.class != WorkloadClass::Large => match ingest(&st) {
                Ok(_) => {
                    self.timer.notify();
                    Message::Ack { redirect_to_dfs: redirect }
                }
                Err(RoundError::Duplicate { party, nonce }) => {
                    Message::Duplicate { party, nonce }
                }
                Err(RoundError::WrongPhase { .. }) => Message::Late { round },
                Err(RoundError::Rejected { party, norm }) => Message::Rejected { party, norm },
                Err(e) => Message::Error(format!("ingest: {e}")),
            },
            Some(_) => {
                // Large round: message passing is the wrong channel —
                // instruct the party to use the store.
                Message::Ack { redirect_to_dfs: true }
            }
            None => Message::Error(format!("round {round} not open")),
        }
    }

    /// The partial-aggregate sibling of [`FlServer::upload_with`]: route
    /// the cohort's fold to the current round, answer with the same typed
    /// replies (a conflicting cohort member gets `Duplicate` naming that
    /// party; a seal race gets `Late`) — and NEVER a store redirect, which
    /// is meaningless for an already-folded cohort.
    fn upload_partial_with<F>(&self, declared: u32, ingest: F) -> Message
    where
        F: FnOnce(&RoundState) -> Result<usize, RoundError>,
    {
        let round = self.current_round();
        if declared != round {
            return Message::Late { round };
        }
        match self.round_state(round) {
            Some(st) => match ingest(&st) {
                Ok(_) => {
                    self.timer.notify();
                    Message::Ack { redirect_to_dfs: false }
                }
                Err(RoundError::Duplicate { party, nonce }) => {
                    Message::Duplicate { party, nonce }
                }
                Err(RoundError::WrongPhase { .. }) => Message::Late { round },
                Err(RoundError::NotStreaming) => Message::Error(format!(
                    "round {round} is not a hierarchical ingest (partials fold only on streaming rounds)"
                )),
                Err(e) => Message::Error(format!("partial ingest: {e}")),
            },
            None => Message::Error(format!("round {round} not open")),
        }
    }

    /// The async-mode upload path: the wire frame's round field is
    /// reinterpreted as the model version the client trained against, the
    /// staleness delta is computed at ingest, and the reply is a typed
    /// `AsyncAck {version, delta}` — never `Late`: a straggler's update is
    /// admitted with a discounted weight instead of rejected.  Retransmits
    /// keep the sync round's `Duplicate` idempotency contract; an update
    /// too stale for a full buffer gets `Late {round: version}` carrying
    /// the CURRENT version so the client retrains against a fresh model.
    fn async_offer(
        &self,
        ar: &AsyncRound,
        party: u64,
        nonce: u64,
        trained_version: u32,
        count: f32,
        data: &[f32],
    ) -> Message {
        match ar.offer(party, nonce, trained_version, count, data) {
            Ok(a) => {
                self.timer.notify();
                Message::AsyncAck { version: a.version, delta: a.delta }
            }
            Err(AsyncError::Duplicate { party, nonce }) => Message::Duplicate { party, nonce },
            Err(AsyncError::Stale { version }) => Message::Late { round: version },
            Err(e) => Message::Error(format!("async ingest: {e}")),
        }
    }

    /// The zero-copy request path ([`Handler::handle_frame`]): uploads are
    /// decoded as borrowed views and folded in place; model fetches are
    /// framed from the published `Arc` without cloning the weights.  Every
    /// other tag goes through the owned [`FlServer::handle`].
    fn handle_frame(&self, tag: u8, payload: &[u8]) -> Result<Reply, ProtoError> {
        match tag {
            protocol::TAG_UPLOAD => {
                let v = ModelUpdateView::decode(payload)?;
                self.registry.note_seen(v.party);
                if let Some(ar) = &self.async_round {
                    return Ok(Reply::Msg(
                        self.async_offer(ar, v.party, 0, v.round, v.count, &v.data),
                    ));
                }
                Ok(Reply::Msg(self.upload_with(v.round, |st| {
                    let norm = self.robust_check(v.party, &v.data)?;
                    let n = st.ingest_view(&v)?;
                    self.note_norm(v.party, norm);
                    Ok(n)
                })))
            }
            protocol::TAG_UPLOAD_NONCE => {
                if payload.len() < 8 {
                    return Err(ProtoError::BadPayload(format!(
                        "need 8 nonce bytes, got {}",
                        payload.len()
                    )));
                }
                let nonce = u64::from_le_bytes(payload[..8].try_into().unwrap());
                // the pooled buffer is 4-aligned and the nonce is 8 bytes,
                // so the update body still decodes as a borrowed view
                let v = ModelUpdateView::decode(&payload[8..])?;
                self.registry.note_seen(v.party);
                if let Some(ar) = &self.async_round {
                    return Ok(Reply::Msg(
                        self.async_offer(ar, v.party, nonce, v.round, v.count, &v.data),
                    ));
                }
                Ok(Reply::Msg(self.upload_with(v.round, |st| {
                    let norm = self.robust_check(v.party, &v.data)?;
                    let n = st.ingest_view_tagged(&v, nonce)?;
                    self.note_norm(v.party, norm);
                    Ok(n)
                })))
            }
            protocol::TAG_UPLOAD_ENC => {
                if payload.len() < 8 {
                    return Err(ProtoError::BadPayload(format!(
                        "need 8 nonce bytes, got {}",
                        payload.len()
                    )));
                }
                let nonce = u64::from_le_bytes(payload[..8].try_into().unwrap());
                // Encoded frame at offset 8 in the 4-aligned pool: the
                // 40-byte encoded header keeps a dense-f32 payload
                // 4-aligned, so full-precision frames still borrow; the
                // compressed encodings dequantize here into an owned f32
                // view ("dequantize-on-fold") and the round state never
                // sees anything but dense f32 data.
                let ev = EncodedUpdateView::decode(&payload[8..])?;
                let v = ev.to_model_view()?;
                self.registry.note_seen(v.party);
                if let Some(ar) = &self.async_round {
                    return Ok(Reply::Msg(
                        self.async_offer(ar, v.party, nonce, v.round, v.count, &v.data),
                    ));
                }
                Ok(Reply::Msg(self.upload_with(v.round, |st| {
                    let norm = self.robust_check(v.party, &v.data)?;
                    let n = st.ingest_view_tagged(&v, nonce)?;
                    self.note_norm(v.party, norm);
                    Ok(n)
                })))
            }
            protocol::TAG_UPLOAD_PARTIAL => {
                if payload.len() < 8 {
                    return Err(ProtoError::BadPayload(format!(
                        "need 8 nonce bytes, got {}",
                        payload.len()
                    )));
                }
                let nonce = u64::from_le_bytes(payload[..8].try_into().unwrap());
                // nonce-ahead layout: the partial's 40-byte header starts
                // at offset 8 in the 4-aligned pool, so its f32 sums decode
                // as a borrowed view
                let v = PartialAggregateView::decode(&payload[8..])?;
                Ok(Reply::Msg(
                    self.upload_partial_with(v.round, |st| st.ingest_partial_tagged(&v, nonce)),
                ))
            }
            protocol::TAG_GET_MODEL => {
                if payload.len() < 4 {
                    return Err(ProtoError::BadPayload(format!(
                        "need 4 bytes, got {}",
                        payload.len()
                    )));
                }
                let round = u32::from_le_bytes(payload[..4].try_into().unwrap());
                // Async mode has one rolling model, not per-round slots:
                // serve the latest publish (its version as the round id).
                if let Some(ar) = &self.async_round {
                    return Ok(match ar.model() {
                        Some(w) => Reply::Model { round: ar.version(), weights: w },
                        None => Reply::Msg(Message::NoModel { round }),
                    });
                }
                Ok(match self.round_state(round).and_then(|s| s.fused()) {
                    Some(w) => Reply::Model { round, weights: w },
                    None => Reply::Msg(Message::NoModel { round }),
                })
            }
            _ => Ok(Reply::Msg(self.handle(Message::decode(tag, payload)?))),
        }
    }

    fn handle(&self, msg: Message) -> Message {
        match msg {
            Message::Register { party } => {
                let round = self.current_round();
                self.registry.join(party, round, 0);
                Message::Registered { party, round }
            }
            Message::Heartbeat { party } => {
                // A liveness-only signal: refresh the stamp the TTL
                // eviction reads, reply with the current round so idle
                // parties still learn where the fleet is.
                self.registry.note_seen(party);
                Message::Registered { party, round: self.current_round() }
            }
            Message::Upload(u) => {
                self.registry.note_seen(u.party);
                if let Some(ar) = &self.async_round {
                    return self.async_offer(ar, u.party, 0, u.round, u.count, &u.data);
                }
                let declared = u.round;
                self.upload_with(declared, |st| {
                    let norm = self.robust_check(u.party, &u.data)?;
                    let party = u.party;
                    let n = st.ingest(u)?;
                    self.note_norm(party, norm);
                    Ok(n)
                })
            }
            Message::UploadNonce { nonce, update } => {
                self.registry.note_seen(update.party);
                if let Some(ar) = &self.async_round {
                    return self.async_offer(
                        ar,
                        update.party,
                        nonce,
                        update.round,
                        update.count,
                        &update.data,
                    );
                }
                let declared = update.round;
                self.upload_with(declared, |st| {
                    let norm = self.robust_check(update.party, &update.data)?;
                    let party = update.party;
                    let n = st.ingest_tagged(update, nonce)?;
                    self.note_norm(party, norm);
                    Ok(n)
                })
            }
            Message::UploadPartial { nonce, partial } => {
                let declared = partial.round;
                self.upload_partial_with(declared, |st| {
                    st.ingest_partial_tagged(&partial.as_view(), nonce)
                })
            }
            Message::UploadEnc { nonce, frame } => {
                let ev = match EncodedUpdateView::decode(&frame) {
                    Ok(ev) => ev,
                    Err(e) => return Message::Error(format!("encoded frame: {e}")),
                };
                let v = match ev.to_model_view() {
                    Ok(v) => v,
                    Err(e) => return Message::Error(format!("encoded payload: {e}")),
                };
                self.registry.note_seen(v.party);
                if let Some(ar) = &self.async_round {
                    return self.async_offer(ar, v.party, nonce, v.round, v.count, &v.data);
                }
                self.upload_with(v.round, |st| {
                    let norm = self.robust_check(v.party, &v.data)?;
                    let n = st.ingest_view_tagged(&v, nonce)?;
                    self.note_norm(v.party, norm);
                    Ok(n)
                })
            }
            Message::GetModel { round } => {
                if let Some(ar) = &self.async_round {
                    return match ar.model() {
                        Some(w) => {
                            Message::Model { round: ar.version(), weights: w.as_ref().clone() }
                        }
                        None => Message::NoModel { round },
                    };
                }
                match self.round_state(round).and_then(|s| s.fused()) {
                    Some(w) => Message::Model { round, weights: w.as_ref().clone() },
                    None => Message::NoModel { round },
                }
            }
            other => Message::Error(format!("unexpected message {other:?}")),
        }
    }

    /// Wait until `expected` updates arrived for the current round (small
    /// path) or `timeout` elapsed, then aggregate, publish and open the
    /// next round.  For Large rounds, delegates to the service's
    /// monitor+MapReduce path.
    ///
    /// This is the legacy quorum-of-one shape: whatever arrived by the
    /// deadline is aggregated, and only a fully empty round fails (as
    /// [`ServiceError::NoUpdates`], after aborting and reopening).
    pub fn run_round(
        &self,
        expected: usize,
        timeout: Duration,
    ) -> Result<(Vec<f32>, ServiceReport), ServiceError> {
        match self.run_round_quorum(expected, 1, timeout)? {
            RoundRun { result: Some(r), .. } => Ok(r),
            RoundRun { .. } => Err(ServiceError::NoUpdates),
        }
    }

    /// The sanitised liveness TTL from the config; `None` = eviction off.
    /// Defensively floored to the evict cadence: a TTL shorter than the
    /// sweep interval would evict parties that heartbeat perfectly on time
    /// (the config loader already rejects such values, but the field is
    /// `pub` and tests set it directly).
    fn liveness_ttl(&self) -> Option<Duration> {
        let s = self.service.config().liveness_ttl_s;
        if s.is_finite() && s > 0.0 {
            Some(Duration::from_secs_f64(s.min(31_536_000.0)).max(self.evict_cadence()))
        } else {
            None
        }
    }

    /// The sanitised stale-party sweep cadence (`evict_cadence_s`): how
    /// often the quorum wait re-checks heartbeats.  Floored at 1ms so a
    /// zeroed knob cannot turn the wait into a spin.
    fn evict_cadence(&self) -> Duration {
        let s = self.service.config().evict_cadence_s;
        if s.is_finite() && s > 0.0 {
            Duration::from_secs_f64(s.clamp(0.001, 31_536_000.0))
        } else {
            Duration::from_millis(25)
        }
    }

    /// [`FlServer::run_round_quorum`] with the quorum and deadline taken
    /// from the service config (`quorum_fraction` of `expected`,
    /// `round_deadline_s`).
    pub fn run_round_configured(&self, expected: usize) -> Result<RoundRun, ServiceError> {
        let cfg = self.service.config();
        let quorum = ((expected as f64) * cfg.quorum_fraction.clamp(0.0, 1.0)).ceil() as usize;
        // Defend the Duration conversion: a hand-edited config with a
        // negative, NaN or absurdly large deadline must degrade (seal
        // immediately / cap at a year), not panic the coordinator —
        // Duration::from_secs_f64 panics on negatives AND on values past
        // ~1.8e19 s.
        let deadline_s = cfg.round_deadline_s;
        let deadline_s = if deadline_s.is_finite() {
            deadline_s.clamp(0.0, 31_536_000.0) // ≤ one year
        } else {
            0.0
        };
        self.run_round_quorum(expected, quorum, Duration::from_secs_f64(deadline_s))
    }

    /// Drive the current round with quorum semantics: the round seals when
    /// all `expected` uploads arrived (→ [`RoundOutcome::Complete`]) or at
    /// the deadline, whichever first — at the deadline it aggregates the
    /// partial set if at least `quorum` folded (→ [`RoundOutcome::Quorum`]),
    /// otherwise it ABORTS: the ingest state is dropped, every memory
    /// reservation returns to the node budget, no model is published, and
    /// the next round opens (→ [`RoundOutcome::Aborted`]).  Uploads racing
    /// the seal are answered with the typed `Late` reply.
    ///
    /// Covers all three ingest paths: buffered Small rounds, sharded
    /// streaming rounds (seal-then-drain, so an abort cannot leak lane
    /// scratch), and Large rounds via the store monitor (whose own
    /// threshold/timeout machinery supplies the wait; a below-quorum
    /// partial set is discarded unpublished).  `quorum = expected`
    /// recovers all-or-abort; `quorum = 1` the legacy partial aggregate.
    /// The delivered/expected ratio of every sealed round feeds the
    /// planner's participation EWMA so the next plan prices K·p uploads.
    pub fn run_round_quorum(
        &self,
        expected: usize,
        quorum: usize,
        timeout: Duration,
    ) -> Result<RoundRun, ServiceError> {
        let expected = expected.max(1);
        let quorum = quorum.clamp(1, expected);
        let round = self.current_round();
        // Borrowed-vs-copied decode tallies over this driver's span: most
        // ingest lands during the collection wait below, so the delta is
        // the round's zero-copy health (surfaced via RoundRun::log_line).
        let decode_mark = decode_stats();
        let mut st = self.round_state(round).expect("current round open");
        // Parties may have joined since the round opened (§III-C): refresh
        // the classification from the live registry as long as nothing has
        // been ingested yet.
        if st.collected() == 0 {
            let class =
                self.classify_effective(self.registry.active_count().max(expected).max(1));
            if class != st.class {
                st = self.reopen_round(round, class);
            }
        }
        if st.class == WorkloadClass::Large {
            return self.finish_large_quorum(&st, round, expected, quorum).map(|mut run| {
                run.decode = decode_stats().since(decode_mark);
                run
            });
        }

        // Small + Streaming: the deadline timer IS the collection window.
        // With a liveness TTL configured, parties that stop signalling
        // (no register/upload/heartbeat) are evicted from the live set
        // during the wait, and the round seals early once everyone still
        // alive has delivered and quorum is met — a crashed fleet no
        // longer pins every round to the full deadline.
        //
        // The wait itself is event-driven: every accepted ingest pokes
        // `self.timer`, so the loop wakes the moment progress happens and
        // otherwise sleeps clear to the next real deadline (round deadline,
        // or the `evict_cadence_s` heartbeat sweep) — no fixed-cadence
        // polling.  The generation is captured BEFORE the predicates so an
        // upload landing between check and wait still wakes us.
        let deadline = Instant::now() + timeout;
        let ttl = self.liveness_ttl();
        let cadence = self.evict_cadence();
        let mut next_evict = Instant::now();
        loop {
            let gen = self.timer.generation();
            if st.collected() >= expected {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            if let Some(ttl) = ttl {
                if now >= next_evict {
                    self.registry.evict_stale(ttl, now);
                    next_evict = now + cadence;
                }
                let live = self.registry.active_count();
                if st.collected() >= quorum && st.collected() >= live {
                    break;
                }
            }
            let until = if ttl.is_some() { deadline.min(next_evict) } else { deadline };
            self.timer.wait_until(until, gen);
        }
        // Feed the heartbeat-derived live fraction into the planner's
        // turnout EWMA alongside the sealed delivered/expected sample: a
        // half-dead fleet lowers the priced participation from its silence
        // alone, not only from the updates it failed to deliver.
        if let Some(ttl) = ttl {
            let (live, registered) = self.registry.live_fraction(ttl, Instant::now());
            self.service.observe_liveness(live, registered);
        }
        // Seal FIRST, classify after: a straggler folding between a
        // pre-seal snapshot and the seal would otherwise yield an
        // inconsistent run (outcome Quorum with folded == expected) and
        // feed the participation EWMA a stale count.  `begin_aggregation`
        // and `finish_streaming` both return the post-seal truth.
        let (fused, report) = match st.class {
            WorkloadClass::Small => {
                let updates = st.begin_aggregation().map_err(ServiceError::Round)?;
                let folded = updates.len();
                self.service.observe_participation(folded, expected);
                if folded < quorum {
                    // below quorum: drop the partial set (its reservations
                    // were already released by the seal) and abort
                    drop(updates);
                    st.abort().map_err(ServiceError::Round)?;
                    self.seal_robust_round(false);
                    self.open_round(round + 1);
                    return Ok(RoundRun {
                        outcome: RoundOutcome::Aborted,
                        folded,
                        result: None,
                        decode: decode_stats().since(decode_mark),
                    });
                }
                self.service.aggregate_small(self.algo.as_ref(), &updates, round)?
            }
            _ => {
                // Streaming: every received update is already folded into
                // the O(C) accumulators; sealing + the S-way merge is all
                // that remains — ingest and compute overlapped.
                if st.collected() == 0 {
                    // an empty fold cannot finish(); abort straight away
                    st.abort().map_err(ServiceError::Round)?;
                    self.seal_robust_round(false);
                    self.open_round(round + 1);
                    self.service.observe_participation(0, expected);
                    return Ok(RoundRun {
                        outcome: RoundOutcome::Aborted,
                        folded: 0,
                        result: None,
                        decode: decode_stats().since(decode_mark),
                    });
                }
                let mut bd = crate::metrics::Breakdown::new();
                let t0 = Instant::now();
                // the count comes back with the weights so a straggler
                // folded right before the transition is in both
                let (fused, parties) = st.finish_streaming().map_err(ServiceError::Round)?;
                bd.add("reduce", t0.elapsed().as_secs_f64());
                self.service.observe_participation(parties, expected);
                if parties < quorum {
                    drop(fused); // below quorum: the partial fuse is discarded
                    st.abort().map_err(ServiceError::Round)?;
                    self.seal_robust_round(false);
                    self.open_round(round + 1);
                    return Ok(RoundRun {
                        outcome: RoundOutcome::Aborted,
                        folded: parties,
                        result: None,
                        decode: decode_stats().since(decode_mark),
                    });
                }
                (
                    fused,
                    ServiceReport {
                        round,
                        class: WorkloadClass::Streaming,
                        engine: "streaming",
                        parties,
                        partitions: 0,
                        executors: 0,
                        breakdown: bd,
                        monitor: None,
                        predicted: None,
                    },
                )
            }
        };
        let folded = report.parties;
        let outcome = if folded >= expected {
            RoundOutcome::Complete
        } else {
            RoundOutcome::Quorum
        };
        st.publish(fused.clone()).map_err(ServiceError::Round)?;
        // Judge the round's contributors and publish the sealed median as
        // the next round's clip/reject reference.
        self.seal_robust_round(true);
        self.open_round(round + 1);
        Ok(RoundRun {
            outcome,
            folded,
            result: Some((fused, report)),
            decode: decode_stats().since(decode_mark),
        })
    }

    /// The Large arm of the quorum round: the store monitor supplies the
    /// threshold/timeout wait; a below-quorum outcome discards the job's
    /// result unpublished (the store-side artifact is left for forensics)
    /// and aborts the round state.
    fn finish_large_quorum(
        &self,
        st: &RoundState,
        round: u32,
        expected: usize,
        quorum: usize,
    ) -> Result<RoundRun, ServiceError> {
        let _ = st.begin_aggregation(); // no in-memory updates to take
        match self
            .service
            .aggregate_large(self.algo.as_ref(), round, expected, self.update_bytes)
        {
            Ok((fused, report)) => {
                let folded = report.parties;
                self.service.observe_participation(folded, expected);
                let outcome = if folded >= expected {
                    RoundOutcome::Complete
                } else if folded >= quorum {
                    RoundOutcome::Quorum
                } else {
                    RoundOutcome::Aborted
                };
                if outcome == RoundOutcome::Aborted {
                    st.abort().map_err(ServiceError::Round)?;
                    self.open_round(round + 1);
                    return Ok(RoundRun {
                        outcome,
                        folded,
                        result: None,
                        decode: DecodeStats::default(),
                    });
                }
                st.publish(fused.clone()).map_err(ServiceError::Round)?;
                self.open_round(round + 1);
                Ok(RoundRun {
                    outcome,
                    folded,
                    result: Some((fused, report)),
                    decode: DecodeStats::default(),
                })
            }
            Err(ServiceError::NoUpdates) => {
                self.service.observe_participation(0, expected);
                st.abort().map_err(ServiceError::Round)?;
                self.open_round(round + 1);
                Ok(RoundRun {
                    outcome: RoundOutcome::Aborted,
                    folded: 0,
                    result: None,
                    decode: DecodeStats::default(),
                })
            }
            Err(e) => Err(e),
        }
    }

    /// The async buffered-publish state, when `async_mode` is on.
    pub fn async_state(&self) -> Option<&Arc<AsyncRound>> {
        self.async_round.as_ref()
    }

    /// Drive one async publish: wait until the buffer holds its K updates
    /// or `cadence` elapses (the two FedBuff publish triggers), then drain
    /// the buffer and fold it with staleness-discounted weights — each
    /// update through a [`DiscountedFusion`] scaled by `s(δ)` for the δ
    /// observed at that update's ingest — and install the fused model,
    /// bumping the version every later offer computes its delta against.
    ///
    /// An empty cadence tick publishes nothing (version unchanged) — the
    /// async analog of the sync abort, except nothing needs aborting: the
    /// buffer simply keeps filling toward the next tick.  Uploads racing
    /// the drain land in the next buffer (see [`AsyncRound::drain`]);
    /// nothing is rejected `Late` and nothing is dropped.
    pub fn run_async_round(&self, cadence: Duration) -> Result<AsyncRun, ServiceError> {
        let ar = self
            .async_round
            .as_ref()
            .expect("run_async_round requires async_mode")
            .clone();
        // Event-driven fill wait: every accepted async offer pokes
        // `self.timer`, so an early-full buffer publishes immediately and
        // an idle one sleeps clear to the cadence tick (no 2ms polling).
        let deadline = Instant::now() + cadence;
        loop {
            let gen = self.timer.generation();
            if ar.is_full() || Instant::now() >= deadline {
                break;
            }
            self.timer.wait_until(deadline, gen);
        }
        let entries = ar.drain();
        if entries.is_empty() {
            return Ok(AsyncRun { version: ar.version(), folded: 0, max_delta: 0, model: None });
        }
        let curve = StalenessDiscount::new(self.service.config().staleness_exponent);
        // The buffered payloads still hold their budget reservations, so
        // the fold's own O(C) scratch must come from the same budget —
        // peak accounting stays honest at K·C + C.
        let mut fold = StreamingFold::new(self.algo.as_ref(), 1, self.node_budget.clone())
            .map_err(ServiceError::Engine)?;
        let folded = entries.len();
        let mut max_delta = 0;
        for e in &entries {
            max_delta = max_delta.max(e.delta);
            let discounted = DiscountedFusion::for_delta(self.algo.as_ref(), curve, e.delta);
            let view = ModelUpdateView {
                party: e.party,
                count: e.count,
                round: e.trained_version,
                data: std::borrow::Cow::Borrowed(&e.data),
            };
            fold.fold_view(&discounted, &view).map_err(ServiceError::Engine)?;
        }
        let fused = fold.finish(self.algo.as_ref()).map_err(ServiceError::Engine)?;
        drop(entries); // release the buffer reservations
        let version = ar.install(fused.clone());
        Ok(AsyncRun { version, folded, max_delta, model: Some(fused) })
    }

    /// [`FlServer::run_async_round`] at the configured publish cadence
    /// (`async_cadence_s`, already sanitised by the config layer).
    pub fn run_async_configured(&self) -> Result<AsyncRun, ServiceError> {
        let cadence_s = self.service.config().async_cadence_s;
        let cadence_s = if cadence_s.is_finite() { cadence_s.clamp(0.0, 31_536_000.0) } else { 0.0 };
        self.run_async_round(Duration::from_secs_f64(cadence_s))
    }
}

/// What [`FlServer::run_async_round`] produced for one publish attempt.
#[derive(Debug)]
pub struct AsyncRun {
    /// Model version after this attempt (unchanged if nothing published).
    pub version: u32,
    /// Updates folded into this publish (0 = empty tick, no publish).
    pub folded: usize,
    /// Largest staleness delta among the folded updates.
    pub max_delta: u32,
    /// The published model; `None` on an empty tick.
    pub model: Option<Vec<f32>>,
}

/// What [`FlServer::run_round_quorum`] produced for one driven round.
#[derive(Debug)]
pub struct RoundRun {
    pub outcome: RoundOutcome,
    /// Updates folded (or monitored, for Large rounds) at seal time.
    pub folded: usize,
    /// The fused weights + report; `None` when the round aborted.
    pub result: Option<(Vec<f32>, ServiceReport)>,
    /// Borrowed-vs-copied wire-decode tallies accrued during this driver's
    /// span — the round's zero-copy health.  Borrowed = dense-f32 payloads
    /// served straight from the receive buffer; copied = compressed (or
    /// unaligned) payloads that had to materialise an owned `Vec<f32>`.
    /// Process-wide counters, so concurrent rounds bleed into each other;
    /// treat as a health signal, not an exact per-round ledger.
    pub decode: DecodeStats,
}

impl RoundRun {
    /// One-line round log, e.g.
    /// `round Quorum: folded=12 decode borrowed=12 copied=0`.
    pub fn log_line(&self) -> String {
        format!(
            "round {:?}: folded={} decode borrowed={} copied={}",
            self.outcome, self.folded, self.decode.borrowed, self.decode.copied
        )
    }
}

/// The TCP-facing newtype: routes raw frames into [`FlServer`]'s zero-copy
/// path while keeping the owned-message path for everything else.
struct FlHandler(Arc<FlServer>);

impl Handler for FlHandler {
    fn handle(&self, msg: Message) -> Message {
        self.0.handle(msg)
    }

    fn handle_frame(&self, tag: u8, payload: &[u8]) -> Result<Reply, ProtoError> {
        self.0.handle_frame(tag, payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{SyntheticParty, Transport};
    use crate::config::ServiceConfig;
    use crate::dfs::datanode::tempdir::TempDir;
    use crate::dfs::{DfsClient, NameNode};
    use crate::fusion::FedAvg;
    use crate::mapreduce::ExecutorConfig;
    use crate::metrics::Breakdown;
    use crate::net::NetClient;

    fn make_server(mem: u64, update_bytes: u64) -> (Arc<FlServer>, TempDir) {
        let td = TempDir::new();
        let nn = NameNode::create(td.path(), 2, 1, 1 << 20).unwrap();
        let dfs = DfsClient::new(nn);
        let mut cfg = ServiceConfig::default();
        cfg.node.memory_bytes = mem;
        cfg.node.cores = 2;
        cfg.monitor_timeout_s = 5.0;
        let svc = AdaptiveService::new(
            cfg,
            dfs,
            None,
            ExecutorConfig { executors: 1, cores_per_executor: 2, ..Default::default() },
        );
        (FlServer::new(svc, Arc::new(FedAvg), update_bytes), td)
    }

    #[test]
    fn small_round_end_to_end_over_tcp() {
        let (server, _td) = make_server(1 << 30, 400);
        let handle = server.start("127.0.0.1:0").unwrap();
        let addr = handle.addr().to_string();

        // register + upload from 6 parties over real sockets
        std::thread::scope(|s| {
            for p in 0..6u64 {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = NetClient::connect(&addr).unwrap();
                    let r = c.call(&Message::Register { party: p }).unwrap();
                    assert!(matches!(r, Message::Registered { .. }));
                    let mut party = SyntheticParty::new(p, 1);
                    let u = party.make_update(0, 100);
                    let r = c.call(&Message::Upload(u)).unwrap();
                    assert!(matches!(r, Message::Ack { .. }));
                });
            }
        });

        let (fused, report) = server.run_round(6, Duration::from_secs(5)).unwrap();
        assert_eq!(fused.len(), 100);
        assert_eq!(report.parties, 6);
        assert_eq!(report.class, WorkloadClass::Small);

        // model fetchable over the wire
        let mut c = NetClient::connect(&addr).unwrap();
        match c.call(&Message::GetModel { round: 0 }).unwrap() {
            Message::Model { round, weights } => {
                assert_eq!(round, 0);
                assert_eq!(weights, fused);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(server.current_round(), 1);
    }

    #[test]
    fn large_round_redirects_uploads_and_uses_mapreduce() {
        // tiny node memory -> every round classifies Large
        let (server, _td) = make_server(1024, 4000);
        for p in 0..5u64 {
            server.registry.join(p, 0, 10);
        }
        // re-open round so classification sees the registered parties
        server.open_round(1);
        let handle = server.start("127.0.0.1:0").unwrap();

        // a TCP upload is answered with a redirect
        let mut c = NetClient::connect(handle.addr()).unwrap();
        let mut party = SyntheticParty::new(0, 2);
        let u = party.make_update(1, 1000);
        match c.call(&Message::Upload(u)).unwrap() {
            Message::Ack { redirect_to_dfs } => assert!(redirect_to_dfs),
            other => panic!("{other:?}"),
        }

        // parties ship via the store instead
        let dfs = server.service.dfs().clone();
        let mut bd = Breakdown::new();
        for p in 0..5u64 {
            let mut party = SyntheticParty::new(p, 3);
            let u = party.make_update(1, 1000);
            party.ship(&u, &Transport::Dfs, Some(&dfs), &mut bd).unwrap();
        }
        let (fused, report) = server.run_round(5, Duration::from_secs(5)).unwrap();
        assert_eq!(fused.len(), 1000);
        assert_eq!(report.class, WorkloadClass::Large);
        assert_eq!(report.engine, "mapreduce");
        assert!(report.partitions >= 1);
    }

    #[test]
    fn streaming_round_lifts_ceiling_over_tcp() {
        // 1 MB node, 20 KB updates: 40 parties would need ~1.76 MB
        // buffered (dup 2.0 × headroom 1.1), but the round streams — every
        // TCP upload folds on receipt into one of S=2 shard lanes, peak
        // node memory stays at S·O(C) plus the in-flight frames of the
        // concurrently uploading connections, and no store/Spark is
        // touched.
        let update_len = 5_000usize;
        let (server, _td) = make_server(1 << 20, (update_len * 4) as u64);
        for p in 0..40u64 {
            server.registry.join(p, 0, 10);
        }
        server.open_round(1); // re-classify against the registered fleet
        let st = server.round_state(1).unwrap();
        assert_eq!(st.class, WorkloadClass::Streaming);
        assert!(st.is_streaming());

        let handle = server.start("127.0.0.1:0").unwrap();
        let addr = handle.addr().to_string();
        std::thread::scope(|s| {
            for p in 0..40u64 {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = NetClient::connect(&addr).unwrap();
                    let mut party = SyntheticParty::new(p, 7);
                    let u = party.make_update(1, update_len);
                    match c.call(&Message::Upload(u)).unwrap() {
                        // streaming keeps the message-passing channel
                        Message::Ack { redirect_to_dfs } => assert!(!redirect_to_dfs),
                        other => panic!("{other:?}"),
                    }
                });
            }
        });

        let (fused, report) = server.run_round(40, Duration::from_secs(10)).unwrap();
        assert_eq!(report.class, WorkloadClass::Streaming);
        assert_eq!(report.engine, "streaming");
        assert_eq!(report.parties, 40);
        assert!(!server.service.spark_started());
        // peak round memory: S=2 lane accumulators + the in-flight frames
        // (≤ 40 concurrent) — and strictly below what buffering 40 parked
        // updates would have charged, let alone the 2.0× dup the batch
        // engines add on top.
        let c_bytes = update_len as u64 * 4;
        assert!(
            server.node_budget.high_water() <= (2 + 40) * c_bytes,
            "peak {}",
            server.node_budget.high_water()
        );
        assert!(server.node_budget.high_water() < 40 * c_bytes * 2);

        // parity with the serial batch over the same update set
        let us: Vec<ModelUpdate> = (0..40u64)
            .map(|p| SyntheticParty::new(p, 7).make_update(1, update_len))
            .collect();
        let mut bd = Breakdown::new();
        let want = crate::engine::SerialEngine::unbounded()
            .aggregate(&FedAvg, &us, &mut bd)
            .unwrap();
        crate::util::prop::all_close(&fused, &want, 1e-3, 1e-4).unwrap();
    }

    #[test]
    fn ingest_oom_surfaces_as_error_message() {
        let (server, _td) = make_server(3000, 400);
        let st = server.round_state(0).unwrap();
        // 3000-byte budget, 400-byte updates (100 f32) -> 7 fit, 8th OOMs
        for p in 0..7u64 {
            st.ingest(ModelUpdate::new(p, 1.0, 0, vec![0.0; 100])).unwrap();
        }
        let reply = server.handle(Message::Upload(ModelUpdate::new(9, 1.0, 0, vec![0.0; 100])));
        assert!(matches!(reply, Message::Error(_)), "{reply:?}");
    }

    #[test]
    fn empty_round_times_out_cleanly() {
        let (server, _td) = make_server(1 << 20, 100);
        assert!(matches!(
            server.run_round(3, Duration::from_millis(30)),
            Err(ServiceError::NoUpdates)
        ));
    }

    #[test]
    fn full_set_completes_before_the_deadline() {
        let (server, _td) = make_server(1 << 30, 400);
        let st = server.round_state(0).unwrap();
        for p in 0..4u64 {
            st.ingest(ModelUpdate::new(p, 1.0, 0, vec![1.0; 100])).unwrap();
        }
        let t0 = Instant::now();
        let run = server.run_round_quorum(4, 2, Duration::from_secs(30)).unwrap();
        assert!(t0.elapsed() < Duration::from_secs(5), "must seal early, not at the deadline");
        assert_eq!(run.outcome, RoundOutcome::Complete);
        assert_eq!(run.folded, 4);
        let (fused, report) = run.result.unwrap();
        assert_eq!(fused.len(), 100);
        assert_eq!(report.parties, 4);
        assert_eq!(server.current_round(), 1);
    }

    #[test]
    fn partial_fleet_aggregates_at_quorum() {
        let (server, _td) = make_server(1 << 30, 400);
        let st = server.round_state(0).unwrap();
        for p in 0..3u64 {
            st.ingest(ModelUpdate::new(p, 1.0, 0, vec![1.0; 100])).unwrap();
        }
        // 3 of 5 delivered; quorum 2 → aggregate the partial set
        let run = server.run_round_quorum(5, 2, Duration::from_millis(50)).unwrap();
        assert_eq!(run.outcome, RoundOutcome::Quorum);
        assert_eq!(run.folded, 3);
        assert_eq!(run.result.as_ref().unwrap().1.parties, 3);
        assert!(server.round_state(0).unwrap().fused().is_some());
        // the turnout fed the planner's participation factor (3/5)
        assert!((server.service.participation() - 0.6).abs() < 1e-9);
    }

    #[test]
    fn below_quorum_round_aborts_and_frees_memory() {
        let (server, _td) = make_server(1 << 30, 400);
        let st = server.round_state(0).unwrap();
        st.ingest(ModelUpdate::new(0, 1.0, 0, vec![1.0; 100])).unwrap();
        assert!(server.node_budget.in_use() > 0);
        let run = server.run_round_quorum(5, 3, Duration::from_millis(40)).unwrap();
        assert_eq!(run.outcome, RoundOutcome::Aborted);
        assert_eq!(run.folded, 1);
        assert!(run.result.is_none());
        assert_eq!(
            server.node_budget.in_use(),
            0,
            "abort must release the parked update's reservation"
        );
        assert!(server.round_state(0).unwrap().fused().is_none(), "no model published");
        assert_eq!(server.current_round(), 1, "the next round opened");
    }

    #[test]
    fn liveness_eviction_seals_the_round_without_waiting_for_the_dead() {
        // 8 registered parties, 5 deliver, 3 crash silently.  With a
        // 150 ms liveness TTL the quorum waiter evicts the silent
        // parties mid-round and seals once everyone still live has
        // delivered, instead of burning the full 30 s deadline.
        let td = TempDir::new();
        let nn = NameNode::create(td.path(), 2, 1, 1 << 20).unwrap();
        let mut cfg = ServiceConfig::default();
        cfg.node.memory_bytes = 1 << 30;
        cfg.node.cores = 2;
        cfg.liveness_ttl_s = 0.15;
        let svc = AdaptiveService::new(
            cfg,
            DfsClient::new(nn),
            None,
            ExecutorConfig { executors: 1, cores_per_executor: 2, ..Default::default() },
        );
        let server = FlServer::new(svc, Arc::new(FedAvg), 400);
        for p in 0..8u64 {
            server.registry.join(p, 0, 0);
        }
        // a heartbeat is a liveness-only signal answered with the round
        match server.handle(Message::Heartbeat { party: 3 }) {
            Message::Registered { party: 3, round: 0 } => {}
            other => panic!("{other:?}"),
        }
        let st = server.round_state(0).unwrap();
        for p in 0..5u64 {
            st.ingest(ModelUpdate::new(p, 1.0, 0, vec![1.0; 100])).unwrap();
        }
        let t0 = Instant::now();
        let run = server.run_round_quorum(8, 4, Duration::from_secs(30)).unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "eviction must seal the round early, not at the 30 s deadline"
        );
        assert_eq!(run.outcome, RoundOutcome::Quorum);
        assert_eq!(run.folded, 5);
        assert!(run.result.is_some());
    }

    #[test]
    fn silent_half_fleet_lowers_the_priced_participation() {
        // Heartbeat cadence feeds the planner's turnout EWMA: when half a
        // 10-party fleet goes silent past the liveness TTL, the sealed
        // round's live fraction (and delivered count) must drag the
        // participation factor the NEXT plan prices against well below
        // the all-alive prior.
        let td = TempDir::new();
        let nn = NameNode::create(td.path(), 2, 1, 1 << 20).unwrap();
        let mut cfg = ServiceConfig::default();
        cfg.node.memory_bytes = 1 << 30;
        cfg.node.cores = 2;
        cfg.liveness_ttl_s = 0.1;
        let svc = AdaptiveService::new(
            cfg,
            DfsClient::new(nn),
            None,
            ExecutorConfig { executors: 1, cores_per_executor: 2, ..Default::default() },
        );
        let server = FlServer::new(svc, Arc::new(FedAvg), 400);
        for p in 0..10u64 {
            server.registry.join(p, 0, 1);
        }
        assert_eq!(server.service.participation(), 1.0, "all-alive prior before any round");
        // age every join stamp past the TTL, then only half the fleet
        // resumes heartbeating
        std::thread::sleep(Duration::from_millis(150));
        for p in 0..5u64 {
            server.handle(Message::Heartbeat { party: p });
        }
        let st = server.round_state(0).unwrap();
        for p in 0..5u64 {
            st.ingest(ModelUpdate::new(p, 1.0, 0, vec![1.0; 100])).unwrap();
        }
        let run = server.run_round_quorum(10, 3, Duration::from_secs(10)).unwrap();
        assert_eq!(run.outcome, RoundOutcome::Quorum);
        assert_eq!(run.folded, 5);
        let part = server.service.participation();
        assert!(
            part <= 0.6,
            "half the fleet is dead: the priced participation must follow, got {part}"
        );
        assert!(part >= 0.05, "the clamp floor still applies");
    }

    #[test]
    fn streaming_quorum_and_abort_cover_the_sharded_path() {
        // a fleet past the buffered ceiling: the round streams; quorum and
        // abort must work against the sharded fold (seal-then-drop)
        let update_len = 5_000usize;
        let (server, _td) = make_server(1 << 20, (update_len * 4) as u64);
        for p in 0..40u64 {
            server.registry.join(p, 0, 10);
        }
        server.open_round(1);
        let st = server.round_state(1).unwrap();
        assert!(st.is_streaming());
        for p in 0..30u64 {
            st.ingest(ModelUpdate::new(p, 1.0, 1, vec![1.0; update_len])).unwrap();
        }
        let run = server.run_round_quorum(40, 20, Duration::from_millis(50)).unwrap();
        assert_eq!(run.outcome, RoundOutcome::Quorum);
        assert_eq!(run.folded, 30);
        assert_eq!(run.result.as_ref().unwrap().1.engine, "streaming");

        // next round: only 2 of 40 arrive → abort releases the lane scratch
        let st = server.round_state(2).unwrap();
        assert!(st.is_streaming());
        for p in 0..2u64 {
            st.ingest(ModelUpdate::new(p, 1.0, 2, vec![1.0; update_len])).unwrap();
        }
        let run = server.run_round_quorum(40, 20, Duration::from_millis(40)).unwrap();
        assert_eq!(run.outcome, RoundOutcome::Aborted);
        assert_eq!(run.folded, 2);
        assert_eq!(
            server.node_budget.in_use(),
            0,
            "streaming abort must return the fold scratch to the budget"
        );
        assert_eq!(server.current_round(), 3);
    }

    #[test]
    fn duplicate_and_late_uploads_get_typed_replies() {
        let (server, _td) = make_server(1 << 30, 400);
        let u = ModelUpdate::new(5, 1.0, 0, vec![0.5; 100]);
        let r = server.handle(Message::UploadNonce { nonce: 0x9, update: u.clone() });
        assert!(matches!(r, Message::Ack { .. }), "{r:?}");
        // the retransmit is absorbed with the ACCEPTED nonce echoed back
        let r = server.handle(Message::UploadNonce { nonce: 0xA, update: u.clone() });
        assert_eq!(r, Message::Duplicate { party: 5, nonce: 0x9 });
        assert_eq!(server.round_state(0).unwrap().collected(), 1);
        // seal the round under the uploader's feet: a straggler is Late
        server.round_state(0).unwrap().abort().unwrap();
        let r = server.handle(Message::Upload(ModelUpdate::new(6, 1.0, 0, vec![0.5; 100])));
        assert_eq!(r, Message::Late { round: 0 });
    }

    #[test]
    fn encoded_uploads_fold_and_dedup_like_dense() {
        use crate::tensorstore::{codec, Encoding};
        let (server, _td) = make_server(1 << 30, 400);
        let data: Vec<f32> = (0..100).map(|i| (i as f32) * 0.01 - 0.5).collect();
        // dense-f32 encoded upload via the owned path
        let u = ModelUpdate::new(1, 1.0, 0, data.clone());
        let frame = codec::encode_update(&u, Encoding::DenseF32);
        let r = server.handle(Message::UploadEnc { nonce: 0x1, frame: frame.clone() });
        assert!(matches!(r, Message::Ack { .. }), "{r:?}");
        // retransmit absorbed with the ACCEPTED nonce echoed back
        let r = server.handle(Message::UploadEnc { nonce: 0x2, frame });
        assert_eq!(r, Message::Duplicate { party: 1, nonce: 0x1 });
        // an f16 frame from another party folds too (dequantize-on-fold),
        // here via the zero-copy frame path
        let u2 = ModelUpdate::new(2, 1.0, 0, data.clone());
        let mut payload = 0x3u64.to_le_bytes().to_vec();
        payload.extend_from_slice(&codec::encode_update(&u2, Encoding::DenseF16));
        let reply = server.handle_frame(protocol::TAG_UPLOAD_ENC, &payload).unwrap();
        assert!(matches!(reply, Reply::Msg(Message::Ack { .. })));
        assert_eq!(server.round_state(0).unwrap().collected(), 2);
        // fused mean of the exact and f16 copies lands within f16 error
        let run = server.run_round_quorum(2, 2, Duration::from_secs(10)).unwrap();
        let (fused, _) = run.result.unwrap();
        for (f, d) in fused.iter().zip(data.iter()) {
            assert!((f - d).abs() < 1e-3, "{f} vs {d}");
        }
        // a corrupt encoded frame is a typed error, not a crash
        let mut bad = codec::encode_update(&u, Encoding::QuantI8);
        bad[50] ^= 0x10;
        let r = server.handle(Message::UploadEnc { nonce: 0x9, frame: bad });
        assert!(matches!(r, Message::Error(_)), "{r:?}");
    }

    #[test]
    fn root_accepts_partials_and_dedups_stray_directs() {
        use crate::config::NodeRole;
        use crate::tensorstore::PartialAggregate;
        let td = TempDir::new();
        let nn = NameNode::create(td.path(), 2, 1, 1 << 20).unwrap();
        let mut cfg = ServiceConfig::default();
        cfg.node.memory_bytes = 1 << 20;
        cfg.node.cores = 2;
        cfg.role = NodeRole::Root;
        let svc = AdaptiveService::new(
            cfg,
            DfsClient::new(nn),
            None,
            ExecutorConfig { executors: 1, cores_per_executor: 2, ..Default::default() },
        );
        let server = FlServer::new(svc, Arc::new(FedAvg), 400);
        assert!(server.round_state(0).unwrap().is_streaming(), "root forces streaming");

        // an edge cohort of 3 (all-ones sums, weight 1 each)
        let p = PartialAggregate::new(9, 0, 3.0, vec![1, 2, 3], vec![3.0; 100]);
        let r = server.handle(Message::UploadPartial { nonce: 0x11, partial: p.clone() });
        assert!(matches!(r, Message::Ack { redirect_to_dfs: false }), "{r:?}");
        assert_eq!(server.round_state(0).unwrap().collected(), 3, "members, not frames");

        // a stray direct upload from a cohort member is a typed Duplicate
        let r = server.handle(Message::Upload(ModelUpdate::new(2, 1.0, 0, vec![1.0; 100])));
        assert_eq!(r, Message::Duplicate { party: 2, nonce: 0x11 });
        // and so is the relay's retransmit of the whole partial
        let r = server.handle(Message::UploadPartial { nonce: 0x12, partial: p.clone() });
        assert!(matches!(r, Message::Duplicate { party: 1, nonce: 0x11 }), "{r:?}");

        // a partial declaring a stale round is Late, exactly like a client
        let mut stale = p;
        stale.round = 9;
        let r = server.handle(Message::UploadPartial { nonce: 0x13, partial: stale });
        assert_eq!(r, Message::Late { round: 0 });

        // the quorum round seals over members and publishes
        let run = server.run_round_quorum(3, 2, Duration::from_millis(200)).unwrap();
        assert_eq!(run.outcome, RoundOutcome::Complete);
        assert_eq!(run.folded, 3);
        let (fused, _) = run.result.unwrap();
        assert!((fused[0] - 1.0).abs() < 1e-5, "mean of all-ones cohort");
    }

    #[test]
    fn flat_round_rejects_partials_with_typed_error() {
        let (server, _td) = make_server(1 << 30, 400);
        let p = crate::tensorstore::PartialAggregate::new(1, 0, 2.0, vec![5, 6], vec![2.0; 100]);
        let r = server.handle(Message::UploadPartial { nonce: 0x1, partial: p });
        match r {
            Message::Error(e) => assert!(e.contains("not a hierarchical ingest"), "{e}"),
            other => panic!("{other:?}"),
        }
        // the failed partial claimed nothing: its members upload normally
        let r = server.handle(Message::Upload(ModelUpdate::new(5, 1.0, 0, vec![1.0; 100])));
        assert!(matches!(r, Message::Ack { .. }), "{r:?}");
    }

    #[test]
    fn typed_replies_cross_the_wire() {
        let (server, _td) = make_server(1 << 30, 400);
        let handle = server.start("127.0.0.1:0").unwrap();
        let mut c = NetClient::connect(handle.addr()).unwrap();
        let u = ModelUpdate::new(7, 1.0, 0, vec![0.5; 100]);
        // the nonce-tagged upload takes the zero-copy frame path
        let r = c.call(&Message::UploadNonce { nonce: 0x77, update: u.clone() }).unwrap();
        assert!(matches!(r, Message::Ack { .. }), "{r:?}");
        let r = c.call(&Message::UploadNonce { nonce: 0x78, update: u }).unwrap();
        assert_eq!(r, Message::Duplicate { party: 7, nonce: 0x77 });
        server.round_state(0).unwrap().abort().unwrap();
        let r = c
            .call(&Message::UploadNonce {
                nonce: 0x79,
                update: ModelUpdate::new(8, 1.0, 0, vec![0.5; 100]),
            })
            .unwrap();
        assert_eq!(r, Message::Late { round: 0 });
    }

    #[test]
    fn encoded_uploads_cross_the_wire_and_count_as_borrowed() {
        use crate::client::SyntheticParty;
        use crate::tensorstore::{decode_stats, Encoding};
        let (server, _td) = make_server(1 << 30, 400);
        let handle = server.start("127.0.0.1:0").unwrap();
        let addr = handle.addr().to_string();
        let mut party = SyntheticParty::new(1, 99);
        let u = party.make_update(0, 200);
        let before = decode_stats();
        // dense-f32 encoded frame: lands in the pooled buffer at a
        // 4-aligned payload offset, so the decode must BORROW
        party.ship_encoded(&u, Encoding::DenseF32, 0x51, &addr).unwrap();
        let after = decode_stats();
        assert!(after.borrowed >= before.borrowed + 1, "encoded dense decode must borrow");
        // retransmit over the same path is absorbed (Ok, not an error)
        party.ship_encoded(&u, Encoding::DenseF32, 0x52, &addr).unwrap();
        // a quantized frame from another party folds via dequantize
        let mut p2 = SyntheticParty::new(2, 99);
        let u2 = p2.make_update(0, 200);
        p2.ship_encoded(&u2, Encoding::QuantI8, 0x53, &addr).unwrap();
        assert_eq!(server.round_state(0).unwrap().collected(), 2);
    }

    fn make_async_server(
        mem: u64,
        buffer: usize,
        exponent: f64,
    ) -> (Arc<FlServer>, TempDir) {
        let td = TempDir::new();
        let nn = NameNode::create(td.path(), 2, 1, 1 << 20).unwrap();
        let dfs = DfsClient::new(nn);
        let mut cfg = ServiceConfig::default();
        cfg.node.memory_bytes = mem;
        cfg.node.cores = 2;
        cfg.monitor_timeout_s = 5.0;
        cfg.async_mode = true;
        cfg.async_buffer = buffer;
        cfg.staleness_exponent = exponent;
        cfg.async_cadence_s = 0.05;
        let svc = AdaptiveService::new(
            cfg,
            dfs,
            None,
            ExecutorConfig { executors: 1, cores_per_executor: 2, ..Default::default() },
        );
        (FlServer::new(svc, Arc::new(FedAvg), 400), td)
    }

    #[test]
    fn async_round_end_to_end_over_tcp() {
        let (server, _td) = make_async_server(1 << 30, 4, 0.5);
        assert!(server.async_state().is_some());
        let handle = server.start("127.0.0.1:0").unwrap();
        let addr = handle.addr().to_string();

        // 4 version-0 uploads fill the buffer; each gets a typed AsyncAck
        // carrying the current version and this update's staleness delta
        std::thread::scope(|s| {
            for p in 0..4u64 {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = NetClient::connect(&addr).unwrap();
                    let u = ModelUpdate::new(p, 1.0, 0, vec![p as f32; 50]);
                    let r = c.call(&Message::UploadNonce { nonce: p, update: u }).unwrap();
                    assert_eq!(r, Message::AsyncAck { version: 0, delta: 0 });
                });
            }
        });
        let run = server.run_async_round(Duration::from_secs(5)).unwrap();
        assert_eq!(run.version, 1);
        assert_eq!(run.folded, 4);
        assert_eq!(run.max_delta, 0);
        // all fresh: the publish is the plain FedAvg mean
        let fused = run.model.unwrap();
        assert!((fused[0] - 1.5).abs() < 1e-6, "{}", fused[0]);

        // the model is served with its VERSION as the round id
        let mut c = NetClient::connect(&addr).unwrap();
        match c.call(&Message::GetModel { round: 0 }).unwrap() {
            Message::Model { round, weights } => {
                assert_eq!(round, 1);
                assert_eq!(weights, fused);
            }
            other => panic!("{other:?}"),
        }

        // second buffer: a straggler still trained on version 0 is ADMITTED
        // with delta 1 (not Late-rejected), a fresh party gets delta 0
        let stale = ModelUpdate::new(0, 1.0, 0, vec![10.0; 50]);
        let r = c.call(&Message::UploadNonce { nonce: 10, update: stale }).unwrap();
        assert_eq!(r, Message::AsyncAck { version: 1, delta: 1 });
        let fresh = ModelUpdate::new(1, 1.0, 1, vec![20.0; 50]);
        let r = c.call(&Message::UploadNonce { nonce: 11, update: fresh }).unwrap();
        assert_eq!(r, Message::AsyncAck { version: 1, delta: 0 });
        // cadence tick publishes the partial buffer (2 < K = 4)
        let run = server.run_async_round(Duration::from_millis(30)).unwrap();
        assert_eq!(run.version, 2);
        assert_eq!(run.folded, 2);
        assert_eq!(run.max_delta, 1);
        // the straggler folded at the FedBuff weight s(1) = 2^-1/2
        let s1 = (2.0f64).powf(-0.5) as f32;
        let want = (10.0 * s1 + 20.0) / (s1 + 1.0);
        let fused = run.model.unwrap();
        assert!((fused[0] - want).abs() < 1e-4, "{} vs {want}", fused[0]);
        assert_eq!(server.async_state().unwrap().drained(), 6);
    }

    #[test]
    fn async_typed_replies_duplicate_and_stale() {
        let (server, _td) = make_async_server(1 << 30, 1, 0.5);
        let r = server.handle(Message::UploadNonce {
            nonce: 0x5,
            update: ModelUpdate::new(3, 1.0, 0, vec![1.0; 20]),
        });
        assert_eq!(r, Message::AsyncAck { version: 0, delta: 0 });
        // the retransmit is absorbed with the accepted nonce echoed back
        let r = server.handle(Message::UploadNonce {
            nonce: 0x6,
            update: ModelUpdate::new(3, 1.0, 0, vec![1.0; 20]),
        });
        assert_eq!(r, Message::Duplicate { party: 3, nonce: 0x5 });
        // a full buffer rejects a version-tie as stale: Late carries the
        // CURRENT version so the client can fetch and retrain
        let r = server.handle(Message::Upload(ModelUpdate::new(4, 1.0, 0, vec![1.0; 20])));
        assert_eq!(r, Message::Late { round: 0 });
        // a wrong-shape offer is a typed error, not a crash
        let r = server.handle(Message::Upload(ModelUpdate::new(5, 1.0, 1, vec![1.0; 21])));
        assert!(matches!(r, Message::Error(_)), "{r:?}");
    }

    #[test]
    fn async_abort_mid_buffer_returns_every_reservation() {
        let (server, _td) = make_async_server(1 << 30, 8, 0.5);
        for p in 0..5u64 {
            let r = server.handle(Message::Upload(ModelUpdate::new(p, 1.0, 0, vec![1.0; 64])));
            assert!(matches!(r, Message::AsyncAck { .. }), "{r:?}");
        }
        assert_eq!(server.node_budget.in_use(), 5 * 64 * 4);
        server.async_state().unwrap().abort();
        assert_eq!(server.node_budget.in_use(), 0, "abort must return every reservation");
    }

    #[test]
    fn async_empty_tick_publishes_nothing() {
        let (server, _td) = make_async_server(1 << 30, 4, 0.5);
        let run = server.run_async_round(Duration::from_millis(10)).unwrap();
        assert_eq!(run.version, 0);
        assert_eq!(run.folded, 0);
        assert!(run.model.is_none());
        assert!(server.async_state().unwrap().model().is_none());
        let r = server.handle(Message::GetModel { round: 0 });
        assert_eq!(r, Message::NoModel { round: 0 });
    }

    #[test]
    fn async_late_upload_folds_into_the_next_publish_exactly_once() {
        let (server, _td) = make_async_server(1 << 30, 2, 0.5);
        server.handle(Message::Upload(ModelUpdate::new(0, 1.0, 0, vec![1.0; 8])));
        server.handle(Message::Upload(ModelUpdate::new(1, 1.0, 0, vec![3.0; 8])));
        let run = server.run_async_configured().unwrap();
        assert_eq!((run.version, run.folded), (1, 2));
        // a third upload after the publish: buffered, version-1 delta
        let r = server.handle(Message::Upload(ModelUpdate::new(2, 1.0, 0, vec![5.0; 8])));
        assert_eq!(r, Message::AsyncAck { version: 1, delta: 1 });
        let run = server.run_async_configured().unwrap();
        assert_eq!((run.version, run.folded), (2, 1));
        // every admitted upload folded exactly once, none dropped
        assert_eq!(server.async_state().unwrap().drained(), 3);
        assert_eq!(server.node_budget.in_use(), 0, "publishes release all buffer bytes");
    }

    #[test]
    fn run_round_configured_uses_the_quorum_knobs() {
        let td = TempDir::new();
        let nn = NameNode::create(td.path(), 2, 1, 1 << 20).unwrap();
        let mut cfg = ServiceConfig::default();
        cfg.node.memory_bytes = 1 << 30;
        cfg.node.cores = 2;
        cfg.quorum_fraction = 0.5;
        cfg.round_deadline_s = 0.05;
        let svc = AdaptiveService::new(
            cfg,
            DfsClient::new(nn),
            None,
            ExecutorConfig { executors: 1, cores_per_executor: 2, ..Default::default() },
        );
        let server = FlServer::new(svc, Arc::new(FedAvg), 400);
        let st = server.round_state(0).unwrap();
        for p in 0..3u64 {
            st.ingest(ModelUpdate::new(p, 1.0, 0, vec![1.0; 100])).unwrap();
        }
        // quorum = ceil(0.5 × 6) = 3 → the 3 delivered reach it
        let run = server.run_round_configured(6).unwrap();
        assert_eq!(run.outcome, RoundOutcome::Quorum);
        assert_eq!(run.folded, 3);
    }
}
