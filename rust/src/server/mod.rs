//! The FL aggregation server: binds the TCP front to the adaptive service.
//!
//! Request handling (per paper Fig 4 and §III-D3):
//! * `Register`  → party joins the registry, learns the current round;
//! * `Upload`    → small path: the update is ingested into the current
//!   round's in-memory state (charged against the node budget); on a
//!   *streaming* round the handler folds the update — decoded as a
//!   borrowed view straight out of the connection's pooled wire buffer —
//!   into one of S ≈ cores shard-local O(C) accumulators on receipt,
//!   instead of parking it; the Ack carries the redirect flag when the
//!   *next* round is predicted Large (streaming rounds keep the
//!   message-passing channel — that is the Fig 1 ceiling lift);
//! * `GetModel`  → returns the fused model once the round is published,
//!   framed zero-copy from the published `Arc`.
//!
//! Round progression is driven by the owner (examples / benches) via
//! [`FlServer::run_round`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::{
    AdaptiveService, PartyRegistry, RoundError, RoundState, ServiceError, ServiceReport,
    WorkloadClass,
};
use crate::fusion::FusionAlgorithm;
use crate::memsim::MemoryBudget;
use crate::net::server::Handler;
use crate::net::{protocol, Message, NetServer, ProtoError, Reply, ServerHandle};
use crate::tensorstore::ModelUpdateView;
#[cfg(test)]
use crate::tensorstore::ModelUpdate;

pub struct FlServer {
    pub service: Arc<AdaptiveService>,
    pub registry: Arc<PartyRegistry>,
    algo: Arc<dyn FusionAlgorithm>,
    /// Bytes of one update at the current model size (classification input).
    update_bytes: u64,
    node_budget: MemoryBudget,
    current_round: AtomicU32,
    rounds: Mutex<BTreeMap<u32, Arc<RoundState>>>,
}

impl FlServer {
    pub fn new(
        service: AdaptiveService,
        algo: Arc<dyn FusionAlgorithm>,
        update_bytes: u64,
    ) -> Arc<FlServer> {
        let node_budget = MemoryBudget::new(service.config().node.memory_bytes);
        let s = Arc::new(FlServer {
            service: Arc::new(service),
            registry: Arc::new(PartyRegistry::new()),
            algo,
            update_bytes,
            node_budget,
            current_round: AtomicU32::new(0),
            rounds: Mutex::new(BTreeMap::new()),
        });
        s.open_round(0);
        s
    }

    pub fn current_round(&self) -> u32 {
        self.current_round.load(Ordering::Acquire)
    }

    /// Build a round's state for its class.  Streaming rounds fold at
    /// ingest into S ≈ cores shard lanes (at most S·O(C) reserved, less
    /// when the budget forces the lane fallback).
    fn make_state(&self, round: u32, class: WorkloadClass) -> RoundState {
        if class == WorkloadClass::Streaming {
            let lanes = self.service.config().node.cores.max(1);
            match RoundState::new_streaming(
                round,
                class,
                self.node_budget.clone(),
                self.algo.clone(),
                lanes,
            ) {
                Ok(st) => return st,
                // Unreachable today: `classify_full` returns Streaming only
                // for decomposable algorithms, which is exactly the fold's
                // construction precondition.  If the preconditions ever
                // diverge, fall back to a buffered Large round — per-upload
                // Acks then carry redirect_to_dfs, steering parties to the
                // store channel that path expects.
                Err(_) => {
                    return RoundState::new(round, WorkloadClass::Large, self.node_budget.clone())
                }
            }
        }
        RoundState::new(round, class, self.node_budget.clone())
    }

    fn open_round(&self, round: u32) -> Arc<RoundState> {
        let expected = self.registry.active_count().max(1);
        let class = self.service.classify_full(self.update_bytes, expected, self.algo.as_ref());
        let st = Arc::new(self.make_state(round, class));
        self.rounds.lock().unwrap().insert(round, st.clone());
        self.current_round.store(round, Ordering::Release);
        st
    }

    pub fn round_state(&self, round: u32) -> Option<Arc<RoundState>> {
        self.rounds.lock().unwrap().get(&round).cloned()
    }

    /// Replace an (empty) round's state with a re-classified one.
    fn reopen_round(&self, round: u32, class: WorkloadClass) -> Arc<RoundState> {
        let st = Arc::new(self.make_state(round, class));
        self.rounds.lock().unwrap().insert(round, st.clone());
        st
    }

    /// Serve on `addr` (port 0 = ephemeral).
    pub fn start(self: &Arc<Self>, addr: &str) -> std::io::Result<ServerHandle> {
        NetServer::serve(addr, Arc::new(FlHandler(self.clone())))
    }

    /// Shared shape of the upload reply: route the ingest closure to the
    /// current round's state, turn protocol failures (wrong shape/phase,
    /// OOM) into error REPLIES — never a coordinator crash — and carry the
    /// seamless-transition redirect flag on the Ack.
    fn upload_with<F>(&self, ingest: F) -> Message
    where
        F: FnOnce(&RoundState) -> Result<usize, RoundError>,
    {
        let round = self.current_round();
        let redirect = self.service.should_redirect(
            self.update_bytes,
            self.registry.active_count().max(1),
            self.algo.as_ref(),
        );
        match self.round_state(round) {
            // Small rounds park the update; streaming rounds fold it on
            // receipt (straight out of the wire buffer on the frame path)
            // and free it.
            Some(st) if st.class != WorkloadClass::Large => match ingest(&st) {
                Ok(_) => Message::Ack { redirect_to_dfs: redirect },
                Err(e) => Message::Error(format!("ingest: {e}")),
            },
            Some(_) => {
                // Large round: message passing is the wrong channel —
                // instruct the party to use the store.
                Message::Ack { redirect_to_dfs: true }
            }
            None => Message::Error(format!("round {round} not open")),
        }
    }

    /// The zero-copy request path ([`Handler::handle_frame`]): uploads are
    /// decoded as borrowed views and folded in place; model fetches are
    /// framed from the published `Arc` without cloning the weights.  Every
    /// other tag goes through the owned [`FlServer::handle`].
    fn handle_frame(&self, tag: u8, payload: &[u8]) -> Result<Reply, ProtoError> {
        match tag {
            protocol::TAG_UPLOAD => {
                let v = ModelUpdateView::decode(payload)?;
                Ok(Reply::Msg(self.upload_with(|st| st.ingest_view(&v))))
            }
            protocol::TAG_GET_MODEL => {
                if payload.len() < 4 {
                    return Err(ProtoError::BadPayload(format!(
                        "need 4 bytes, got {}",
                        payload.len()
                    )));
                }
                let round = u32::from_le_bytes(payload[..4].try_into().unwrap());
                Ok(match self.round_state(round).and_then(|s| s.fused()) {
                    Some(w) => Reply::Model { round, weights: w },
                    None => Reply::Msg(Message::NoModel { round }),
                })
            }
            _ => Ok(Reply::Msg(self.handle(Message::decode(tag, payload)?))),
        }
    }

    fn handle(&self, msg: Message) -> Message {
        match msg {
            Message::Register { party } => {
                let round = self.current_round();
                self.registry.join(party, round, 0);
                Message::Registered { party, round }
            }
            Message::Upload(u) => self.upload_with(|st| st.ingest(u)),
            Message::GetModel { round } => match self.round_state(round).and_then(|s| s.fused()) {
                Some(w) => Message::Model { round, weights: w.as_ref().clone() },
                None => Message::NoModel { round },
            },
            other => Message::Error(format!("unexpected message {other:?}")),
        }
    }

    /// Wait until `expected` updates arrived for the current round (small
    /// path) or `timeout` elapsed, then aggregate, publish and open the
    /// next round.  For Large rounds, delegates to the service's
    /// monitor+MapReduce path.
    pub fn run_round(
        &self,
        expected: usize,
        timeout: Duration,
    ) -> Result<(Vec<f32>, ServiceReport), ServiceError> {
        let round = self.current_round();
        let mut st = self.round_state(round).expect("current round open");
        // Parties may have joined since the round opened (§III-C): refresh
        // the classification from the live registry as long as nothing has
        // been ingested yet.
        if st.collected() == 0 {
            let class = self.service.classify_full(
                self.update_bytes,
                self.registry.active_count().max(expected).max(1),
                self.algo.as_ref(),
            );
            if class != st.class {
                st = self.reopen_round(round, class);
            }
        }
        let result = match st.class {
            WorkloadClass::Small => {
                let deadline = Instant::now() + timeout;
                while st.collected() < expected && Instant::now() < deadline {
                    std::thread::sleep(Duration::from_millis(2));
                }
                let updates = st.begin_aggregation().map_err(ServiceError::Round)?;
                if updates.is_empty() {
                    return Err(ServiceError::NoUpdates);
                }
                self.service.aggregate_small(self.algo.as_ref(), &updates, round)
            }
            WorkloadClass::Streaming => {
                // Every received update is already folded into the O(C)
                // accumulator; all that remains after the barrier is the
                // finalize — ingest and compute overlapped.
                let deadline = Instant::now() + timeout;
                while st.collected() < expected && Instant::now() < deadline {
                    std::thread::sleep(Duration::from_millis(2));
                }
                if st.collected() == 0 {
                    return Err(ServiceError::NoUpdates);
                }
                let mut bd = crate::metrics::Breakdown::new();
                let t0 = Instant::now();
                // the count comes back with the weights so a straggler
                // folded right before the transition is in both
                let (fused, parties) = st.finish_streaming().map_err(ServiceError::Round)?;
                bd.add("reduce", t0.elapsed().as_secs_f64());
                Ok((
                    fused,
                    ServiceReport {
                        round,
                        class: WorkloadClass::Streaming,
                        engine: "streaming",
                        parties,
                        partitions: 0,
                        executors: 0,
                        breakdown: bd,
                        monitor: None,
                        predicted: None,
                    },
                ))
            }
            WorkloadClass::Large => {
                let _ = st.begin_aggregation(); // no in-memory updates
                self.service
                    .aggregate_large(self.algo.as_ref(), round, expected, self.update_bytes)
            }
        }?;
        st.publish(result.0.clone()).map_err(ServiceError::Round)?;
        self.open_round(round + 1);
        Ok(result)
    }
}

/// The TCP-facing newtype: routes raw frames into [`FlServer`]'s zero-copy
/// path while keeping the owned-message path for everything else.
struct FlHandler(Arc<FlServer>);

impl Handler for FlHandler {
    fn handle(&self, msg: Message) -> Message {
        self.0.handle(msg)
    }

    fn handle_frame(&self, tag: u8, payload: &[u8]) -> Result<Reply, ProtoError> {
        self.0.handle_frame(tag, payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{SyntheticParty, Transport};
    use crate::config::ServiceConfig;
    use crate::dfs::datanode::tempdir::TempDir;
    use crate::dfs::{DfsClient, NameNode};
    use crate::fusion::FedAvg;
    use crate::mapreduce::ExecutorConfig;
    use crate::metrics::Breakdown;
    use crate::net::NetClient;

    fn make_server(mem: u64, update_bytes: u64) -> (Arc<FlServer>, TempDir) {
        let td = TempDir::new();
        let nn = NameNode::create(td.path(), 2, 1, 1 << 20).unwrap();
        let dfs = DfsClient::new(nn);
        let mut cfg = ServiceConfig::default();
        cfg.node.memory_bytes = mem;
        cfg.node.cores = 2;
        cfg.monitor_timeout_s = 5.0;
        let svc = AdaptiveService::new(
            cfg,
            dfs,
            None,
            ExecutorConfig { executors: 1, cores_per_executor: 2, ..Default::default() },
        );
        (FlServer::new(svc, Arc::new(FedAvg), update_bytes), td)
    }

    #[test]
    fn small_round_end_to_end_over_tcp() {
        let (server, _td) = make_server(1 << 30, 400);
        let handle = server.start("127.0.0.1:0").unwrap();
        let addr = handle.addr().to_string();

        // register + upload from 6 parties over real sockets
        std::thread::scope(|s| {
            for p in 0..6u64 {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = NetClient::connect(&addr).unwrap();
                    let r = c.call(&Message::Register { party: p }).unwrap();
                    assert!(matches!(r, Message::Registered { .. }));
                    let mut party = SyntheticParty::new(p, 1);
                    let u = party.make_update(0, 100);
                    let r = c.call(&Message::Upload(u)).unwrap();
                    assert!(matches!(r, Message::Ack { .. }));
                });
            }
        });

        let (fused, report) = server.run_round(6, Duration::from_secs(5)).unwrap();
        assert_eq!(fused.len(), 100);
        assert_eq!(report.parties, 6);
        assert_eq!(report.class, WorkloadClass::Small);

        // model fetchable over the wire
        let mut c = NetClient::connect(&addr).unwrap();
        match c.call(&Message::GetModel { round: 0 }).unwrap() {
            Message::Model { round, weights } => {
                assert_eq!(round, 0);
                assert_eq!(weights, fused);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(server.current_round(), 1);
    }

    #[test]
    fn large_round_redirects_uploads_and_uses_mapreduce() {
        // tiny node memory -> every round classifies Large
        let (server, _td) = make_server(1024, 4000);
        for p in 0..5u64 {
            server.registry.join(p, 0, 10);
        }
        // re-open round so classification sees the registered parties
        server.open_round(1);
        let handle = server.start("127.0.0.1:0").unwrap();

        // a TCP upload is answered with a redirect
        let mut c = NetClient::connect(handle.addr()).unwrap();
        let mut party = SyntheticParty::new(0, 2);
        let u = party.make_update(1, 1000);
        match c.call(&Message::Upload(u)).unwrap() {
            Message::Ack { redirect_to_dfs } => assert!(redirect_to_dfs),
            other => panic!("{other:?}"),
        }

        // parties ship via the store instead
        let dfs = server.service.dfs().clone();
        let mut bd = Breakdown::new();
        for p in 0..5u64 {
            let mut party = SyntheticParty::new(p, 3);
            let u = party.make_update(1, 1000);
            party.ship(&u, &Transport::Dfs, Some(&dfs), &mut bd).unwrap();
        }
        let (fused, report) = server.run_round(5, Duration::from_secs(5)).unwrap();
        assert_eq!(fused.len(), 1000);
        assert_eq!(report.class, WorkloadClass::Large);
        assert_eq!(report.engine, "mapreduce");
        assert!(report.partitions >= 1);
    }

    #[test]
    fn streaming_round_lifts_ceiling_over_tcp() {
        // 1 MB node, 20 KB updates: 40 parties would need ~1.76 MB
        // buffered (dup 2.0 × headroom 1.1), but the round streams — every
        // TCP upload folds on receipt into one of S=2 shard lanes, peak
        // node memory stays at S·O(C) plus the in-flight frames of the
        // concurrently uploading connections, and no store/Spark is
        // touched.
        let update_len = 5_000usize;
        let (server, _td) = make_server(1 << 20, (update_len * 4) as u64);
        for p in 0..40u64 {
            server.registry.join(p, 0, 10);
        }
        server.open_round(1); // re-classify against the registered fleet
        let st = server.round_state(1).unwrap();
        assert_eq!(st.class, WorkloadClass::Streaming);
        assert!(st.is_streaming());

        let handle = server.start("127.0.0.1:0").unwrap();
        let addr = handle.addr().to_string();
        std::thread::scope(|s| {
            for p in 0..40u64 {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = NetClient::connect(&addr).unwrap();
                    let mut party = SyntheticParty::new(p, 7);
                    let u = party.make_update(1, update_len);
                    match c.call(&Message::Upload(u)).unwrap() {
                        // streaming keeps the message-passing channel
                        Message::Ack { redirect_to_dfs } => assert!(!redirect_to_dfs),
                        other => panic!("{other:?}"),
                    }
                });
            }
        });

        let (fused, report) = server.run_round(40, Duration::from_secs(10)).unwrap();
        assert_eq!(report.class, WorkloadClass::Streaming);
        assert_eq!(report.engine, "streaming");
        assert_eq!(report.parties, 40);
        assert!(!server.service.spark_started());
        // peak round memory: S=2 lane accumulators + the in-flight frames
        // (≤ 40 concurrent) — and strictly below what buffering 40 parked
        // updates would have charged, let alone the 2.0× dup the batch
        // engines add on top.
        let c_bytes = update_len as u64 * 4;
        assert!(
            server.node_budget.high_water() <= (2 + 40) * c_bytes,
            "peak {}",
            server.node_budget.high_water()
        );
        assert!(server.node_budget.high_water() < 40 * c_bytes * 2);

        // parity with the serial batch over the same update set
        let us: Vec<ModelUpdate> = (0..40u64)
            .map(|p| SyntheticParty::new(p, 7).make_update(1, update_len))
            .collect();
        let mut bd = Breakdown::new();
        let want = crate::engine::SerialEngine::unbounded()
            .aggregate(&FedAvg, &us, &mut bd)
            .unwrap();
        crate::util::prop::all_close(&fused, &want, 1e-3, 1e-4).unwrap();
    }

    #[test]
    fn ingest_oom_surfaces_as_error_message() {
        let (server, _td) = make_server(3000, 400);
        let st = server.round_state(0).unwrap();
        // 3000-byte budget, 400-byte updates (100 f32) -> 7 fit, 8th OOMs
        for p in 0..7u64 {
            st.ingest(ModelUpdate::new(p, 1.0, 0, vec![0.0; 100])).unwrap();
        }
        let reply = server.handle(Message::Upload(ModelUpdate::new(9, 1.0, 0, vec![0.0; 100])));
        assert!(matches!(reply, Message::Error(_)), "{reply:?}");
    }

    #[test]
    fn empty_round_times_out_cleanly() {
        let (server, _td) = make_server(1 << 20, 100);
        assert!(matches!(
            server.run_round(3, Duration::from_millis(30)),
            Err(ServiceError::NoUpdates)
        ));
    }
}
