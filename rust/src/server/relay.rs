//! The edge-relay role: an [`FlServer`] that runs its local quorum round
//! over its cohort, then acts as a *client* of its parent aggregator —
//! uploading ONE weighted partial aggregate instead of hauling every
//! cohort update upstream.
//!
//! Same binary, config-selected: a node whose [`ServiceConfig`] says
//! `role = "relay"` (+ `parent_addr`, `edge_id`) wraps its server in a
//! [`RelayServer`] and drives rounds with
//! [`RelayServer::run_relay_round`] instead of `FlServer::run_round_quorum`.
//! The relay's ingest side is the unmodified flat machinery — TCP frames,
//! sharded streaming fold, per-party dedup, quorum deadline; only the
//! *seal* differs: instead of finalizing, the round's raw accumulator and
//! folded-party set are packaged as a [`PartialAggregate`] and forwarded.
//!
//! Round cadence: relay and parent progress their round numbers in
//! lockstep (both open round R, the relay forwards a partial declaring R,
//! the parent folds it into ITS round R).  A partial arriving after the
//! parent sealed-and-reopened gets the parent's typed `Late` reply, exactly
//! like a straggling client upload.
//!
//! Compressed updates and the backhaul: cohort clients may ship
//! *encoded* frames (`TAG_UPLOAD_ENC` — f16/int8/top-k, see
//! [`codec`](crate::tensorstore::codec)) to their relay; the relay
//! dequantizes at ingest, so the partial it forwards is always dense f32
//! (the exact sum of whatever the cohort sent).  Compression therefore
//! shrinks the client→edge leg only — the relay→root leg stays
//! full-precision by construction, which is exactly the asymmetry the
//! cluster model prices when it shifts the flat-vs-hierarchical
//! crossover under compressed encodings.
//!
//! [`ServiceConfig`]: crate::config::ServiceConfig

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::{RoundError, RoundOutcome, ServiceError};
use crate::net::{Message, NetClient};
use crate::server::FlServer;
use crate::tensorstore::PartialAggregate;

/// An [`FlServer`] driven as an edge aggregator in a 2-tier tree.
pub struct RelayServer {
    pub server: Arc<FlServer>,
    parent: String,
    edge_id: u64,
}

/// What one relay-driven round produced.
#[derive(Debug)]
pub struct RelayRound {
    /// Outcome of the LOCAL cohort round (Complete = every expected cohort
    /// member arrived; Quorum = the deadline sealed a partial set; Aborted
    /// = below quorum, nothing forwarded).
    pub outcome: RoundOutcome,
    /// Cohort members folded locally at seal time.
    pub folded: usize,
    /// The parent's reply to the forwarded partial (`None` when the local
    /// round aborted before forwarding, or the parent was unreachable).
    pub forwarded: Option<Message>,
    /// Whether the parent's fused model was fetched and published into the
    /// local round, so cohort clients can `GetModel` from their relay.
    pub model_published: bool,
}

impl RelayServer {
    /// Wrap `server` as a relay forwarding to `parent` as edge `edge_id`.
    pub fn new(server: Arc<FlServer>, parent: &str, edge_id: u64) -> RelayServer {
        RelayServer { server, parent: parent.to_string(), edge_id }
    }

    /// Build from the server's own [`ServiceConfig`] topology knobs
    /// (`role = relay`, `parent_addr`, `edge_id`); `None` when the config
    /// does not describe a relay.
    ///
    /// [`ServiceConfig`]: crate::config::ServiceConfig
    pub fn from_config(server: Arc<FlServer>) -> Option<RelayServer> {
        let cfg = server.service.config();
        if cfg.role != crate::config::NodeRole::Relay {
            return None;
        }
        let parent = cfg.parent_addr.clone()?;
        let edge_id = cfg.edge_id;
        Some(RelayServer { server, parent, edge_id })
    }

    pub fn edge_id(&self) -> u64 {
        self.edge_id
    }

    /// Deterministic retransmission nonce for this edge's round-`r` partial
    /// (a relay re-sending an unacknowledged partial must reuse it).
    fn nonce(&self, round: u32) -> u64 {
        (self.edge_id << 32) ^ (round as u64) ^ 0x9E37_79B9
    }

    /// Drive one relay round: collect the cohort until all `expected`
    /// arrived or `deadline` passed, seal WITHOUT finalizing, forward the
    /// raw partial to the parent, then poll the parent (up to
    /// `parent_wait`) for the fused model and publish it locally.
    ///
    /// Below-quorum rounds abort exactly like the flat server's — the lane
    /// scratch returns to the budget and nothing crosses the backhaul; a
    /// whole-edge dropout therefore costs the root one missing partial,
    /// never a corrupt one.
    pub fn run_relay_round(
        &self,
        expected: usize,
        quorum: usize,
        deadline: Duration,
        parent_wait: Duration,
    ) -> Result<RelayRound, ServiceError> {
        let expected = expected.max(1);
        let quorum = quorum.clamp(1, expected);
        let round = self.server.current_round();
        let st = self.server.round_state(round).expect("current round open");
        if !st.is_streaming() {
            // the hierarchy gate rejected this algorithm (holistic, or the
            // O(C) accumulator overflows the node): this deployment is flat
            return Err(ServiceError::Round(RoundError::NotStreaming));
        }

        // Event-driven collect: every cohort ingest pokes the server's
        // timer driver, so the relay wakes on arrival and sleeps clear to
        // the deadline when the cohort is quiet (no 2ms polling).
        let deadline_t = Instant::now() + deadline;
        loop {
            let gen = self.server.timer.generation();
            if st.collected() >= expected || Instant::now() >= deadline_t {
                break;
            }
            self.server.timer.wait_until(deadline_t, gen);
        }
        // Settle beat: let a fold that slipped in just before the seal
        // mark its admission slot, so the forwarded party set matches the
        // accumulator (see `finish_streaming_partial`'s race note).
        std::thread::sleep(Duration::from_millis(5));

        if st.collected() == 0 {
            st.abort().map_err(ServiceError::Round)?;
            self.server.seal_robust_round(false);
            self.server.service.observe_participation(0, expected);
            self.server.open_round(round + 1);
            return Ok(RelayRound {
                outcome: RoundOutcome::Aborted,
                folded: 0,
                forwarded: None,
                model_published: false,
            });
        }
        let (acc, folded, parties) =
            st.finish_streaming_partial().map_err(ServiceError::Round)?;
        self.server.service.observe_participation(folded, expected);
        if folded < quorum {
            st.abort().map_err(ServiceError::Round)?;
            self.server.seal_robust_round(false);
            self.server.open_round(round + 1);
            return Ok(RelayRound {
                outcome: RoundOutcome::Aborted,
                folded,
                forwarded: None,
                model_published: false,
            });
        }
        let outcome = if folded >= expected {
            RoundOutcome::Complete
        } else {
            RoundOutcome::Quorum
        };

        // The relay judges ITS cohort: edge-local trust and the next
        // round's clip/reject reference come from the cohort it folded,
        // independent of the root's view of the relays.
        self.server.seal_robust_round(true);

        // One partial crosses the backhaul — the whole cohort's fold.  The
        // per-lane extremes sketch rides along, so a sketch-carrying robust
        // algorithm (trimmed mean) stays exact/bounded through the tier.
        let partial =
            PartialAggregate::new(self.edge_id, round, acc.wtot, parties, acc.sum)
                .with_sketch(acc.sketch);
        let forwarded = NetClient::connect(&self.parent).ok().and_then(|mut c| {
            c.call(&Message::UploadPartial { nonce: self.nonce(round), partial }).ok()
        });

        // Acting as a client to the end: fetch the parent's fused model and
        // publish it locally so the cohort fetches from its own edge.
        let mut model_published = false;
        if matches!(forwarded, Some(Message::Ack { .. })) {
            let wait = Instant::now() + parent_wait;
            if let Ok(mut c) = NetClient::connect(&self.parent) {
                while Instant::now() < wait {
                    match c.call(&Message::GetModel { round }) {
                        Ok(Message::Model { weights, .. }) => {
                            st.publish(weights).map_err(ServiceError::Round)?;
                            model_published = true;
                            break;
                        }
                        Ok(_) => std::thread::sleep(Duration::from_millis(10)),
                        Err(_) => break,
                    }
                }
            }
        }
        if !model_published {
            // the parent rejected the partial (Duplicate/Late) or never
            // published in time: the local round cannot serve a model
            let _ = st.abort();
        }
        // Resync on the parent's typed Late: it names the parent's CURRENT
        // round, so a relay that fell behind (parent sealed-and-reopened
        // mid-round) jumps straight to it instead of trailing one round
        // behind forever — every later partial would be Late again.
        let next = match &forwarded {
            Some(Message::Late { round: parent_round }) => (round + 1).max(*parent_round),
            _ => round + 1,
        };
        self.server.open_round(next);
        Ok(RelayRound { outcome, folded, forwarded, model_published })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NodeRole, ServiceConfig};
    use crate::coordinator::AdaptiveService;
    use crate::dfs::datanode::tempdir::TempDir;
    use crate::dfs::{DfsClient, NameNode};
    use crate::fusion::FedAvg;
    use crate::mapreduce::ExecutorConfig;
    use crate::net::NetClient;
    use crate::tensorstore::ModelUpdate;

    fn make_server(
        role: NodeRole,
        parent: Option<String>,
        edge_id: u64,
    ) -> (Arc<FlServer>, TempDir) {
        let td = TempDir::new();
        let nn = NameNode::create(td.path(), 2, 1, 1 << 20).unwrap();
        let mut cfg = ServiceConfig::default();
        cfg.node.memory_bytes = 1 << 20;
        cfg.node.cores = 2;
        cfg.monitor_timeout_s = 5.0;
        cfg.role = role;
        cfg.parent_addr = parent;
        cfg.edge_id = edge_id;
        let svc = AdaptiveService::new(
            cfg,
            DfsClient::new(nn),
            None,
            ExecutorConfig { executors: 1, cores_per_executor: 2, ..Default::default() },
        );
        (FlServer::new(svc, Arc::new(FedAvg), 400), td)
    }

    #[test]
    fn hierarchical_roles_force_streaming_rounds() {
        // a 2-party fleet with 400-byte updates would classify Small flat;
        // a root must still open a streaming round — the only state that
        // folds partials
        let (root, _td) = make_server(NodeRole::Root, None, 0);
        let st = root.round_state(0).unwrap();
        assert!(st.is_streaming());
        assert_eq!(st.class, crate::coordinator::WorkloadClass::Streaming);
        let (flat, _td2) = make_server(NodeRole::Standalone, None, 0);
        assert!(!flat.round_state(0).unwrap().is_streaming());
    }

    #[test]
    fn relay_round_forwards_one_partial_and_publishes_parent_model() {
        let (root, _td1) = make_server(NodeRole::Root, None, 0);
        let root_handle = root.start("127.0.0.1:0").unwrap();
        let parent_addr = root_handle.addr().to_string();

        let (edge, _td2) = make_server(NodeRole::Relay, Some(parent_addr.clone()), 7);
        let relay = RelayServer::from_config(edge.clone()).expect("relay config");
        assert_eq!(relay.edge_id(), 7);

        // 4 cohort clients upload to the RELAY over TCP — two plain, two
        // as encoded frames (lossless dense-f32 codec, so the forwarded
        // partial is bit-identical to the all-plain round)
        let edge_handle = edge.start("127.0.0.1:0").unwrap();
        let edge_addr = edge_handle.addr().to_string();
        std::thread::scope(|s| {
            for p in 0..4u64 {
                let addr = edge_addr.clone();
                s.spawn(move || {
                    let u = ModelUpdate::new(p, 1.0, 0, vec![1.0; 100]);
                    if p % 2 == 0 {
                        let mut c = NetClient::connect(&addr).unwrap();
                        let r = c.call(&Message::Upload(u)).unwrap();
                        assert!(matches!(r, Message::Ack { redirect_to_dfs: false }), "{r:?}");
                    } else {
                        let frame = crate::tensorstore::codec::encode_update(
                            &u,
                            crate::tensorstore::Encoding::DenseF32,
                        );
                        let mut c = NetClient::connect(&addr).unwrap();
                        let r = c.call(&Message::UploadEnc { nonce: p, frame }).unwrap();
                        assert!(matches!(r, Message::Ack { redirect_to_dfs: false }), "{r:?}");
                    }
                });
            }
        });

        // drive relay + root concurrently: the relay forwards, the root
        // seals its quorum round on the single partial (4 members)
        let (relay_run, root_run) = std::thread::scope(|s| {
            let rr = s.spawn(|| {
                relay.run_relay_round(
                    4,
                    2,
                    Duration::from_secs(3),
                    Duration::from_secs(5),
                )
            });
            let rt = s.spawn(|| root.run_round_quorum(4, 4, Duration::from_secs(5)));
            (rr.join().unwrap().unwrap(), rt.join().unwrap().unwrap())
        });
        assert_eq!(relay_run.outcome, RoundOutcome::Complete);
        assert_eq!(relay_run.folded, 4);
        assert!(matches!(relay_run.forwarded, Some(Message::Ack { .. })), "{:?}", relay_run);
        assert!(relay_run.model_published);
        assert_eq!(root_run.outcome, RoundOutcome::Complete);
        assert_eq!(root_run.folded, 4, "quorum counted cohort MEMBERS");

        // the cohort fetches the fused model from its own relay
        let mut c = NetClient::connect(&edge_addr).unwrap();
        match c.call(&Message::GetModel { round: 0 }).unwrap() {
            Message::Model { round, weights } => {
                assert_eq!(round, 0);
                assert_eq!(weights, root_run.result.unwrap().0);
            }
            other => panic!("{other:?}"),
        }
        // both sides advanced in lockstep
        assert_eq!(edge.current_round(), 1);
        assert_eq!(root.current_round(), 1);
    }

    #[test]
    fn empty_relay_round_aborts_without_forwarding() {
        let (edge, _td) = make_server(NodeRole::Relay, Some("127.0.0.1:1".to_string()), 3);
        let relay = RelayServer::from_config(edge.clone()).unwrap();
        let run = relay
            .run_relay_round(4, 2, Duration::from_millis(40), Duration::from_millis(10))
            .unwrap();
        assert_eq!(run.outcome, RoundOutcome::Aborted);
        assert_eq!(run.folded, 0);
        assert!(run.forwarded.is_none(), "nothing crosses the backhaul on abort");
        assert!(!run.model_published);
        assert_eq!(edge.current_round(), 1, "the next round opened");
    }

    #[test]
    fn relay_resyncs_to_the_parents_round_on_late() {
        // The parent sealed-and-reopened past the relay: the Late reply
        // names the parent's current round and the relay must jump to it,
        // not trail one round behind forever.
        let (root, _td1) = make_server(NodeRole::Root, None, 0);
        root.round_state(0).unwrap().abort().unwrap();
        root.open_round(3); // parent far ahead
        let root_handle = root.start("127.0.0.1:0").unwrap();

        let (edge, _td2) =
            make_server(NodeRole::Relay, Some(root_handle.addr().to_string()), 5);
        let relay = RelayServer::from_config(edge.clone()).unwrap();
        edge.round_state(0)
            .unwrap()
            .ingest(ModelUpdate::new(1, 1.0, 0, vec![1.0; 64]))
            .unwrap();
        let run = relay
            .run_relay_round(1, 1, Duration::from_millis(50), Duration::from_millis(50))
            .unwrap();
        assert!(matches!(run.forwarded, Some(Message::Late { round: 3 })), "{run:?}");
        assert!(!run.model_published);
        assert_eq!(edge.current_round(), 3, "the relay resynced to the parent's round");
    }

    #[test]
    fn from_config_rejects_non_relay_roles() {
        let (flat, _td) = make_server(NodeRole::Standalone, Some("x:1".into()), 0);
        assert!(RelayServer::from_config(flat).is_none());
        let (no_parent, _td2) = make_server(NodeRole::Relay, None, 0);
        assert!(RelayServer::from_config(no_parent).is_none());
    }
}
