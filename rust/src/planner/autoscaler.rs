//! Elastic executor-pool autoscaling with hysteresis.
//!
//! The planner emits a *desired* executor count every round; resizing the
//! real pool on every wish would thrash on alternating small/large traces
//! (grow, shrink, grow, …), paying the container spin-up cost each flip.
//! The autoscaler is the damper between wish and action: growing is eager
//! (an under-provisioned pool slows the very next round) while shrinking
//! requires the lower target to persist for `shrink_patience` consecutive
//! rounds, so a warm pool rides out interleaved small rounds.

/// Autoscaler bounds and damping.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AutoscalerConfig {
    /// Never shrink below this many executors (a warm floor keeps the
    /// distributed path's transition seamless, paper §III-D3).
    pub min_executors: usize,
    /// Never grow beyond this many executors.
    pub max_executors: usize,
    /// Consecutive rounds a *higher* target must persist before growing.
    pub grow_patience: usize,
    /// Consecutive rounds a *lower* target must persist before shrinking.
    pub shrink_patience: usize,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            min_executors: 1,
            max_executors: 16,
            grow_patience: 1,
            shrink_patience: 2,
        }
    }
}

/// What the autoscaler wants done to the pool after an observation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Keep the pool at its current size (the carried value).
    Hold(usize),
    /// Resize the pool to this many executors.
    ScaleTo(usize),
}

impl ScaleDecision {
    /// The executor count the pool should be at after this decision.
    pub fn target(&self) -> usize {
        match self {
            ScaleDecision::Hold(n) | ScaleDecision::ScaleTo(n) => *n,
        }
    }
}

/// Hysteresis state machine between the planner's wishes and the pool.
#[derive(Clone, Debug)]
pub struct Autoscaler {
    cfg: AutoscalerConfig,
    current: usize,
    pending: usize,
    streak: usize,
}

impl Autoscaler {
    pub fn new(cfg: AutoscalerConfig, initial: usize) -> Autoscaler {
        let current = initial.clamp(cfg.min_executors, cfg.max_executors.max(1));
        Autoscaler { cfg, current, pending: current, streak: 0 }
    }

    /// The executor count the pool is (believed to be) at.
    pub fn current(&self) -> usize {
        self.current
    }

    pub fn config(&self) -> &AutoscalerConfig {
        &self.cfg
    }

    /// Feed one round's desired executor count (0 means "no distributed
    /// work" and decays toward the warm floor).  Returns what to do.
    pub fn observe(&mut self, desired: usize) -> ScaleDecision {
        let desired = desired.clamp(self.cfg.min_executors, self.cfg.max_executors.max(1));
        if desired == self.current {
            self.streak = 0;
            self.pending = desired;
            return ScaleDecision::Hold(self.current);
        }
        if desired == self.pending {
            self.streak += 1;
        } else {
            self.pending = desired;
            self.streak = 1;
        }
        let patience = if desired > self.current {
            self.cfg.grow_patience
        } else {
            self.cfg.shrink_patience
        };
        if self.streak >= patience.max(1) {
            self.current = desired;
            self.streak = 0;
            ScaleDecision::ScaleTo(desired)
        } else {
            ScaleDecision::Hold(self.current)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scaler(initial: usize) -> Autoscaler {
        Autoscaler::new(AutoscalerConfig::default(), initial)
    }

    #[test]
    fn grows_eagerly() {
        let mut a = scaler(1);
        assert_eq!(a.observe(8), ScaleDecision::ScaleTo(8));
        assert_eq!(a.current(), 8);
    }

    #[test]
    fn shrink_requires_persistent_target() {
        let mut a = scaler(8);
        assert_eq!(a.observe(2), ScaleDecision::Hold(8)); // streak 1 of 2
        assert_eq!(a.observe(2), ScaleDecision::ScaleTo(2));
        assert_eq!(a.current(), 2);
    }

    #[test]
    fn no_oscillation_on_alternating_small_large_trace() {
        // Alternating small (k=1) / large (k=8) rounds: the pool must
        // grow once and then stay put — the exact thrash the paper's
        // static re-provisioning would pay for on every flip.
        let mut a = scaler(2);
        let mut scale_events = 0;
        for round in 0..20 {
            let desired = if round % 2 == 0 { 1 } else { 8 };
            if let ScaleDecision::ScaleTo(_) = a.observe(desired) {
                scale_events += 1;
            }
        }
        assert_eq!(scale_events, 1, "pool thrashed");
        assert_eq!(a.current(), 8);
    }

    #[test]
    fn interrupted_shrink_streak_resets() {
        let mut a = scaler(8);
        assert_eq!(a.observe(2), ScaleDecision::Hold(8));
        assert_eq!(a.observe(8), ScaleDecision::Hold(8)); // back to current: reset
        assert_eq!(a.observe(2), ScaleDecision::Hold(8)); // streak restarts at 1
        assert_eq!(a.observe(2), ScaleDecision::ScaleTo(2));
    }

    #[test]
    fn clamps_to_bounds() {
        let mut a = Autoscaler::new(
            AutoscalerConfig { min_executors: 2, max_executors: 6, ..Default::default() },
            4,
        );
        assert_eq!(a.observe(100), ScaleDecision::ScaleTo(6));
        // desired 0 clamps to the warm floor; needs shrink_patience rounds
        assert_eq!(a.observe(0), ScaleDecision::Hold(6));
        assert_eq!(a.observe(0), ScaleDecision::ScaleTo(2));
    }

    #[test]
    fn stable_target_holds_forever() {
        let mut a = scaler(4);
        for _ in 0..10 {
            assert_eq!(a.observe(4), ScaleDecision::Hold(4));
        }
    }
}
