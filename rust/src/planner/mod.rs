//! Cost-aware dispatch planning — the generalization of Algorithm 1.
//!
//! The seed of this repo dispatched each round with the paper's binary
//! test (`S < M` → single node, else distributed).  The planner keeps that
//! test as its *feasibility oracle* ([`WorkloadClassifier`]) but replaces
//! the either/or decision with explicit plan enumeration and pricing:
//!
//! 1. **Enumerate** every way the round could run: the serial, parallel
//!    and XLA single-node engines (when the round fits node memory), the
//!    streaming fold (when the algorithm decomposes and its O(C) working
//!    set fits — feasible far past the buffered party ceiling), plus the
//!    distributed MapReduce path at every executor count
//!    k ∈ {1..max_executors};
//! 2. **Price** each candidate with the calibrated [`CostModel`] constants
//!    (per-byte fuse throughput, DFS bandwidth, task overhead, container
//!    spin-up) and a [`PricingModel`] of $/node-second rates, yielding a
//!    [`PlanCost`] (latency, dollars) point per candidate;
//! 3. **Select** under the user's [`DispatchPolicy`] — `MinLatency`,
//!    `MinCost`, or the `Balanced(α)` Pareto knob;
//! 4. **Learn**: after the round runs, the observed wall-clock from the
//!    [`Breakdown`](crate::metrics::Breakdown) flows back in via
//!    [`DispatchPlanner::observe`], updating per-path EWMA correction
//!    factors so predictions track the box the service actually runs on.
//!    Every round's predicted-vs-observed pair is kept in a calibration
//!    ledger so drift is visible (`benches/fig_adaptive_policy` prints it).
//!
//! The [`Autoscaler`] sits between the planner's per-round wishes and the
//! real executor pool, damping resize thrash with hysteresis.

pub mod autoscaler;
pub mod cost;
pub mod policy;

pub use autoscaler::{Autoscaler, AutoscalerConfig, ScaleDecision};
pub use cost::{PlanCost, PricingModel};
pub use policy::DispatchPolicy;

use crate::cluster::{CostModel, EngineKind, VirtualCluster};
use crate::coordinator::{WorkloadClass, WorkloadClassifier};
use crate::fusion::FusionAlgorithm;
use crate::metrics::Ewma;
use crate::tensorstore::Encoding;

/// Which execution substrate a candidate plan uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanKind {
    /// Single-node serial engine (the NumPy-baseline analog).
    Serial,
    /// Single-node multi-core engine (the Numba analog).
    Parallel,
    /// Single-node AOT Pallas/XLA hot path.
    Xla,
    /// Single-node streaming fold: updates fold into an O(C) accumulator
    /// on arrival, so the plan is feasible past the buffered party
    /// ceiling and ingest overlaps compute.
    Streaming,
    /// 2-tier tree: `edges` edge aggregators each pre-fold their cohort in
    /// parallel and forward ONE weighted partial aggregate; the root folds
    /// `edges` partials instead of ingesting every client.  Divides the
    /// ingest span (latency) and the root's wire volume (bytes) by the
    /// edge count, at the price of occupying the edge nodes and one
    /// per-tier sync barrier — only decomposable algorithms qualify.
    Hierarchical { edges: usize },
    /// FedBuff-style asynchronous rounds: the server folds a bounded
    /// buffer of the `buffer` freshest updates with staleness-discounted
    /// weights and publishes on buffer-full, so no quorum barrier and no
    /// straggler ever gates the model clock.  Latency is one buffer-sized
    /// publish; dollars pay the per-publish drain and the discount's
    /// effective-weight loss — only decomposable algorithms qualify.
    Async { buffer: usize },
    /// MapReduce over the DFS with this many executor containers.
    Distributed { executors: usize },
}

impl PlanKind {
    /// The engine name `ServiceReport.engine` uses for this plan.
    pub fn engine_label(&self) -> &'static str {
        match self {
            PlanKind::Serial => "serial",
            PlanKind::Parallel => "parallel",
            PlanKind::Xla => "xla",
            PlanKind::Streaming => "streaming",
            PlanKind::Hierarchical { .. } => "hierarchical",
            PlanKind::Async { .. } => "async",
            PlanKind::Distributed { .. } => "mapreduce",
        }
    }

    /// Executor containers this plan occupies (0 for single-node plans).
    pub fn executors(&self) -> usize {
        match self {
            PlanKind::Distributed { executors } => *executors,
            _ => 0,
        }
    }

    pub fn is_distributed(&self) -> bool {
        matches!(self, PlanKind::Distributed { .. })
    }
}

/// One priced way to run a round.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CandidatePlan {
    pub kind: PlanKind,
    pub cost: PlanCost,
}

/// The planner's output for one round: the selected plan plus the full
/// priced candidate set (benches print it; tests assert over it).
#[derive(Clone, Debug)]
pub struct RoundPlan {
    /// Algorithm 1's feasibility class for this round.
    pub class: WorkloadClass,
    pub chosen: CandidatePlan,
    pub candidates: Vec<CandidatePlan>,
}

/// One row of the calibration ledger: what the model predicted for the
/// chosen plan vs. what actually happened.
#[derive(Clone, Copy, Debug)]
pub struct RoundCalibration {
    pub round: u32,
    pub kind: PlanKind,
    pub predicted_s: f64,
    pub observed_s: f64,
    pub predicted_usd: f64,
    pub observed_usd: f64,
}

impl RoundCalibration {
    /// Observed/predicted latency ratio (1.0 = perfectly calibrated).
    pub fn drift(&self) -> f64 {
        self.observed_s / self.predicted_s.max(1e-12)
    }

    /// The per-round log line the benches and driver print.
    pub fn log_line(&self) -> String {
        let plan = match self.kind {
            PlanKind::Distributed { executors } => format!("mapreduce(k={executors})"),
            PlanKind::Hierarchical { edges } => format!("hierarchical(e={edges})"),
            PlanKind::Async { buffer } => format!("async(K={buffer})"),
            k => k.engine_label().to_string(),
        };
        format!(
            "plan={plan} predicted {:.4}s/${:.6} observed {:.4}s/${:.6} drift x{:.2}",
            self.predicted_s, self.predicted_usd, self.observed_s, self.observed_usd,
            self.drift()
        )
    }
}

/// Planner knobs beyond the cluster geometry.
#[derive(Clone, Debug)]
pub struct PlannerConfig {
    pub policy: DispatchPolicy,
    /// Largest executor pool the distributed path may be planned at.
    pub max_executors: usize,
    /// Cores per executor container (paper: 3).
    pub cores_per_executor: usize,
    /// Cores of the aggregator node's single-node engines.
    pub node_cores: usize,
    /// Sharded-ingest lane count of the streaming server (S): the
    /// streaming plan is priced against this parallelism via
    /// [`VirtualCluster::streaming_time`]'s lanes term.  Typically equal
    /// to `node_cores` (the server shards one lane per core).
    pub ingest_lanes: usize,
    /// Fold-worker pool size behind the network reactor (the bounded pool
    /// decoded frames are dispatched to): the effective streaming ingest
    /// width is `min(ingest_lanes, reactor_workers)` — lanes beyond the
    /// pool can accept bytes but not fold them, so pricing wider would
    /// flatter every ingest-coupled plan.  0 = unbounded (the service
    /// wiring sizes the pool to the node's cores).
    pub reactor_workers: usize,
    /// Edge aggregators available to a 2-tier plan: with ≥ 2 a
    /// `PlanKind::Hierarchical` candidate is enumerated (and priced via
    /// [`VirtualCluster::hierarchical_breakdown`]) whenever the algorithm
    /// passes the hierarchy gate.  0 or 1 = flat candidates only.
    pub edges: usize,
    /// Whether the XLA engine is loaded (candidates are only enumerated
    /// for substrates that can actually run).
    pub xla_available: bool,
    /// EWMA weight of the newest observed/predicted ratio (0..1).
    pub feedback_beta: f64,
    /// Prior on the fraction of registered parties that actually deliver
    /// an upload: real edge fleets drop out and straggle, so a policy that
    /// prices K uploads when K·p arrive systematically over-estimates
    /// every plan.  Pricing uses K·p; *feasibility* (the classifier)
    /// keeps assuming the full K, so a surprise full turnout can never
    /// OOM a plan that was only priced optimistically.  Calibrated per
    /// round via [`DispatchPlanner::observe_participation`].
    pub expected_participation: f64,
    /// Async-mode buffer capacity (K): with ≥ 1 a [`PlanKind::Async`]
    /// candidate is enumerated whenever the algorithm passes the streaming
    /// gate (buffered async folds are streaming folds over K updates).
    /// 0 = async mode off, sync quorum candidates only.
    pub async_buffer: usize,
    /// Staleness-discount exponent `a` of the async candidate's weight
    /// curve `s(δ) = (1+δ)^-a` (FedBuff: 0.5).  Pricing converts the
    /// expected staleness under the observed turnout into an average
    /// discount: lower turnout → staler buffers → less effective weight
    /// per node-second → a pricier async plan.
    pub staleness_exponent: f64,
    /// Wire encoding the fleet's clients upload with: every ingest-coupled
    /// candidate (streaming, hierarchical edge phase, async) is priced at
    /// this encoding's per-update byte count plus its dequantize cost, and
    /// the per-byte WAN term (when [`PricingModel::wan_usd_per_byte`] is
    /// set) charges the encoded volume.  Relay→root partials and the
    /// distributed store path stay dense f32 regardless — that asymmetry
    /// is what moves the flat/hierarchical crossover under compression.
    pub encoding: Encoding,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            policy: DispatchPolicy::Balanced(0.5),
            max_executors: 8,
            cores_per_executor: 3,
            node_cores: 4,
            ingest_lanes: 4,
            reactor_workers: 0,
            edges: 0,
            xla_available: false,
            feedback_beta: 0.3,
            expected_participation: 1.0,
            async_buffer: 0,
            staleness_exponent: 0.5,
            encoding: Encoding::DenseF32,
        }
    }
}

/// The cost-aware dispatch planner.
pub struct DispatchPlanner {
    classifier: WorkloadClassifier,
    cluster: VirtualCluster,
    pricing: PricingModel,
    cfg: PlannerConfig,
    /// Observed/predicted latency correction for buffered single-node plans.
    corr_single: Ewma,
    /// Observed/predicted latency correction for the streaming-fold plan
    /// (its own family: throughput is ingest-coupled, unlike batch).
    corr_stream: Ewma,
    /// Observed/predicted latency correction for 2-tier hierarchical plans
    /// (its own family: dominated by the tier barrier + relay fan-in, a
    /// shape no flat plan shares).
    corr_hier: Ewma,
    /// Observed/predicted latency correction for async buffered-publish
    /// plans (its own family: per-publish cadence, not quorum-span-bound).
    corr_async: Ewma,
    /// Observed/predicted latency correction for distributed plans.
    corr_dist: Ewma,
    /// Observed delivered/expected turnout (the participation factor p).
    part: Ewma,
    ledger: Vec<RoundCalibration>,
}

impl DispatchPlanner {
    pub fn new(
        classifier: WorkloadClassifier,
        cluster: VirtualCluster,
        pricing: PricingModel,
        cfg: PlannerConfig,
    ) -> DispatchPlanner {
        let beta = cfg.feedback_beta.clamp(0.0, 1.0);
        DispatchPlanner {
            classifier,
            cluster,
            pricing,
            cfg,
            corr_single: Ewma::new(beta),
            corr_stream: Ewma::new(beta),
            corr_hier: Ewma::new(beta),
            corr_async: Ewma::new(beta),
            corr_dist: Ewma::new(beta),
            part: Ewma::new(beta),
            ledger: Vec::new(),
        }
    }

    /// The participation factor pricing currently uses: the observed EWMA
    /// once rounds have reported turnout, the configured prior before.
    pub fn participation(&self) -> f64 {
        self.part.value_or(self.cfg.expected_participation).clamp(0.05, 1.0)
    }

    /// Record a sealed round's delivered/expected turnout; returns the
    /// updated participation factor the next plan will price against.
    pub fn observe_participation(&mut self, delivered: usize, expected: usize) -> f64 {
        if expected > 0 {
            self.part.observe((delivered as f64 / expected as f64).clamp(0.0, 1.0));
        }
        self.participation()
    }

    /// Blend the registry's heartbeat-derived live fraction (`live` of
    /// `registered` parties seen within the liveness TTL) into the SAME
    /// EWMA sealed-round turnout feeds: heartbeat silence moves the priced
    /// participation before a single deadline is burned waiting on the
    /// dead.  Returns the updated factor.
    pub fn observe_liveness(&mut self, live: usize, registered: usize) -> f64 {
        if registered > 0 {
            self.part.observe((live as f64 / registered as f64).clamp(0.0, 1.0));
        }
        self.participation()
    }

    pub fn policy(&self) -> DispatchPolicy {
        self.cfg.policy
    }

    pub fn set_policy(&mut self, policy: DispatchPolicy) {
        self.cfg.policy = policy;
    }

    pub fn pricing(&self) -> &PricingModel {
        &self.pricing
    }

    /// Swap in freshly calibrated cost-model constants (e.g. from
    /// [`CostModel::calibrate`]); learned corrections are kept.
    pub fn set_cost_model(&mut self, cost: CostModel) {
        self.cluster.cost = cost;
    }

    /// The learned observed/predicted correction for a path family.
    pub fn correction(&self, distributed: bool) -> f64 {
        if distributed {
            self.corr_dist.value_or(1.0)
        } else {
            self.corr_single.value_or(1.0)
        }
    }

    /// The learned correction for a specific plan kind (streaming has its
    /// own EWMA family alongside single-node and distributed).
    pub fn correction_for(&self, kind: PlanKind) -> f64 {
        match kind {
            PlanKind::Distributed { .. } => self.corr_dist.value_or(1.0),
            PlanKind::Streaming => self.corr_stream.value_or(1.0),
            PlanKind::Hierarchical { .. } => self.corr_hier.value_or(1.0),
            PlanKind::Async { .. } => self.corr_async.value_or(1.0),
            _ => self.corr_single.value_or(1.0),
        }
    }

    /// Full predicted-vs-observed history, oldest first.
    pub fn ledger(&self) -> &[RoundCalibration] {
        &self.ledger
    }

    /// Enumerate and price every candidate plan for a round of `parties`
    /// updates of `update_bytes`, then select under the policy.
    ///
    /// `current_executors` is the warm pool size: distributed candidates
    /// only pay container spin-up for executors *beyond* it, which is what
    /// makes an elastically held pool cheaper than static re-provisioning.
    pub fn plan(
        &self,
        update_bytes: u64,
        parties: usize,
        algo: &dyn FusionAlgorithm,
        current_executors: usize,
    ) -> RoundPlan {
        let class = self.classifier.classify_with_streaming(update_bytes, parties, algo);
        // Feasibility (the class above) assumes the full K registered
        // parties; *pricing* assumes the K·p the fleet actually delivers
        // (p = 1.0 until the quorum rounds report real turnout).
        let p = self.participation();
        let eff = if parties == 0 {
            0
        } else {
            (((parties as f64) * p).ceil() as usize).clamp(1, parties)
        };
        let total_bytes = update_bytes as f64 * eff as f64;
        let enc = self.cfg.encoding;
        // Encoded wire volume the fleet uploads for `count` arrivals: the
        // plain upload framing for dense f32, the codec framing otherwise.
        let uplink_bytes = |count: usize| -> f64 {
            if enc.is_dense_f32() {
                self.cluster.flat_root_bytes(update_bytes, count) as f64
            } else {
                self.cluster.flat_root_bytes_enc(update_bytes, count, enc) as f64
            }
        };
        // Every flat candidate ingests the same encoded uplink volume;
        // zero dollars at the default (free-ingress) WAN rate.
        let wan_up = self.pricing.wan(uplink_bytes(eff));
        let mut candidates = Vec::new();

        if class == WorkloadClass::Small {
            let corr = self.corr_single.value_or(1.0);
            let node_cores = self.cfg.node_cores.max(1);
            let serial = corr
                * self.cluster.single_node_time(
                    update_bytes,
                    eff,
                    node_cores,
                    EngineKind::Serial,
                    1.0,
                );
            candidates.push(CandidatePlan {
                kind: PlanKind::Serial,
                cost: PlanCost::new(serial, self.pricing.single_node(serial) + wan_up),
            });
            let parallel = corr
                * self.cluster.single_node_time(
                    update_bytes,
                    eff,
                    node_cores,
                    EngineKind::Parallel,
                    1.0,
                );
            candidates.push(CandidatePlan {
                kind: PlanKind::Parallel,
                cost: PlanCost::new(parallel, self.pricing.single_node(parallel) + wan_up),
            });
            if self.cfg.xla_available && algo.decomposable() {
                // The AOT path streams at the socket's bandwidth ceiling
                // with one dispatch instead of per-core thread launches.
                let cost = &self.cluster.cost;
                let xla = corr * (total_bytes / cost.xla_bps() + cost.xla_launch_s);
                candidates.push(CandidatePlan {
                    kind: PlanKind::Xla,
                    cost: PlanCost::new(xla, self.pricing.single_node(xla) + wan_up),
                });
            }
        }

        // The streaming fold is feasible whenever the algorithm decomposes
        // and its O(C) working set fits the node — including past the
        // buffered party ceiling (that is the class it unlocks).  Wall
        // time is max(arrival span, fold throughput): ingest overlaps
        // compute, and no store hop is paid.  The fold side is priced at
        // the server's real sharded-ingest width (`ingest_lanes`), not at
        // a single lock lane.  Only the node is occupied, so cost is
        // node-rate × latency.
        if self.classifier.streaming_feasible(update_bytes, algo) {
            // The server's lane fallback collapses to fewer shards when
            // the budget cannot hold S accumulators plus an in-flight
            // frame — price against the width the budget actually admits
            // (memory/C − 1 in-flight), not the nominal S.
            let lane_cap = if update_bytes == 0 {
                usize::MAX
            } else {
                ((self.classifier.memory_bytes / update_bytes).saturating_sub(1)).max(1) as usize
            };
            // The reactor dispatches decoded frames to a bounded fold
            // worker pool; ingest width beyond it reads bytes but cannot
            // fold them, so every lanes term is capped by the pool.
            let worker_cap = if self.cfg.reactor_workers == 0 {
                usize::MAX
            } else {
                self.cfg.reactor_workers
            };
            // `eff` is the one K·p derivation for every candidate family
            // (streaming_time_p is the standalone participation entry for
            // direct callers; pricing must not re-derive the count).
            let stream = self.corr_stream.value_or(1.0)
                * self.cluster.streaming_time_enc(
                    update_bytes,
                    eff,
                    self.cfg.node_cores.max(1),
                    self.cfg.ingest_lanes.max(1).min(lane_cap).min(worker_cap),
                    enc,
                );
            candidates.push(CandidatePlan {
                kind: PlanKind::Streaming,
                cost: PlanCost::new(stream, self.pricing.streaming(stream) + wan_up),
            });

            // The 2-tier tree rides the same hierarchy gate (a partial IS a
            // `combine` operand, so streaming feasibility == hierarchy
            // feasibility): `edges` edge aggregators divide the ingest span
            // and the root's wire volume, paying the tier barrier and the
            // edge fleet's occupancy.  The policy arbitrates: MinLatency
            // takes the division once the fleet outgrows the barrier;
            // MinCost keeps the single-node flat fold.
            if self.cfg.edges >= 2 && eff >= 2 {
                let e = self.cfg.edges.min(eff);
                let lanes = self.cfg.ingest_lanes.max(1).min(lane_cap).min(worker_cap);
                let corr = self.corr_hier.value_or(1.0);
                let (edge_s, root_s) = self.cluster.hierarchical_breakdown_enc(
                    update_bytes,
                    eff,
                    self.cfg.node_cores.max(1),
                    lanes,
                    e,
                    enc,
                );
                // Sketch-carrying robust algorithms (trimmed mean) ship
                // per-lane extremes alongside each partial: the relay→root
                // leg and the root's fold both grow by the sketch-to-sum
                // ratio.  Zero for plain decomposable algorithms, so the
                // FedAvg pricing is bit-identical to the pre-robust planner.
                let sketch_mult = 1.0 + algo.partial_overhead();
                let lat = corr * (edge_s + root_s * sketch_mult);
                // clients→edges move encoded frames; relays→root always
                // forward dense f32 partials (the structural asymmetry)
                let wire = uplink_bytes(eff)
                    + self.cluster.hierarchical_root_bytes(update_bytes, eff, e) as f64
                        * sketch_mult;
                candidates.push(CandidatePlan {
                    kind: PlanKind::Hierarchical { edges: e },
                    cost: PlanCost::new(
                        lat,
                        self.pricing.hierarchical(lat, corr * edge_s, e)
                            + self.pricing.wan(wire),
                    ),
                });
            }

            // The FedBuff-style async plan rides the same streaming gate
            // (a buffered async fold IS a streaming fold over K updates).
            // Latency: one K-sized publish — the model refreshes as soon as
            // the K freshest arrivals fill the buffer, so stragglers never
            // gate the clock (the win MinLatency takes under heavy-tail
            // turnout).  Dollars: the same node does the same total fold
            // work plus one drain per extra publish, and every update's
            // weight is staleness-discounted — at the observed turnout p a
            // late party has missed ≈ (1-p)/p publishes, so low turnout
            // means stale buffers, a smaller average discount, and MORE
            // node-seconds per unit of effective aggregated weight (the
            // reason MinCost keeps the sync quorum at high turnout).
            if self.cfg.async_buffer >= 1 && eff >= 1 {
                let k = self.cfg.async_buffer.min(eff);
                let lanes = self.cfg.ingest_lanes.max(1).min(lane_cap).min(worker_cap);
                let corr = self.corr_async.value_or(1.0);
                let publish = corr
                    * self.cluster.async_publish_time_enc(
                        update_bytes,
                        k,
                        self.cfg.node_cores.max(1),
                        lanes,
                        enc,
                    );
                let occupancy = corr
                    * self.cluster.async_occupancy_enc(
                        update_bytes,
                        eff,
                        k,
                        self.cfg.node_cores.max(1),
                        lanes,
                        enc,
                    );
                let expected_delta = (1.0 - p) / p.max(1e-3);
                let a = self.cfg.staleness_exponent.max(0.0);
                let avg_discount = (1.0 + expected_delta).powf(-a);
                candidates.push(CandidatePlan {
                    kind: PlanKind::Async { buffer: k },
                    cost: PlanCost::new(
                        publish,
                        self.pricing.async_mode(occupancy, avg_discount) + wan_up,
                    ),
                });
            }
        }

        // The distributed path is always available (it is the only path
        // for Large rounds); enumerate it at every candidate pool size.
        //
        // Latency: the store upload IS on the critical path — Algorithm
        // 1's monitor gates the job on the uploads completing (the Fig
        // 12/13 "average write time"), unlike the small path whose ingest
        // overlaps collection.  Cost: executors are only charged for job
        // occupancy (spin-up + read/sum/reduce); during the upload phase
        // only the aggregator node is held.
        let cache = update_bytes < (64 << 20); // the paper's small-model rule
        let corr = self.corr_dist.value_or(1.0);
        let write = if eff == 0 {
            0.0
        } else {
            self.cluster.client_write_time(update_bytes, eff)
        };
        // The store path always moves dense f32 (the DFS holds the format
        // the MapReduce readers decode), so compression never discounts it.
        let wan_dense = self.pricing.wan(self.cluster.flat_root_bytes(update_bytes, eff) as f64);
        for k in 1..=self.cfg.max_executors.max(1) {
            let cores = k * self.cfg.cores_per_executor.max(1);
            let bd = self
                .cluster
                .distributed_breakdown_for_cores(update_bytes, eff, cache, cores);
            let startup = self
                .cluster
                .executor_startup(k.saturating_sub(current_executors));
            let occupancy = startup + corr * bd.total();
            let usd = self.pricing.single_node(write)
                + self.pricing.distributed(occupancy, k)
                + wan_dense;
            candidates.push(CandidatePlan {
                kind: PlanKind::Distributed { executors: k },
                cost: PlanCost::new(write + occupancy, usd),
            });
        }

        let chosen = *self
            .cfg
            .policy
            .select(&candidates)
            .expect("candidate set is never empty");
        RoundPlan { class, chosen, candidates }
    }

    /// Feed one executed round back into the model: the observed/predicted
    /// latency ratio updates the chosen path family's EWMA correction, and
    /// the pair is appended to the calibration ledger.
    pub fn observe(
        &mut self,
        round: u32,
        chosen: &CandidatePlan,
        observed_s: f64,
    ) -> RoundCalibration {
        self.observe_split(round, chosen, observed_s, 0.0)
    }

    /// Like [`DispatchPlanner::observe`], with the store-upload portion of
    /// `observed_s` split out so observed cost mirrors plan pricing
    /// (upload holds only the node; executors are charged for the rest).
    /// Pass `upload_s = 0` when the split is unknown.
    pub fn observe_split(
        &mut self,
        round: u32,
        chosen: &CandidatePlan,
        observed_s: f64,
        upload_s: f64,
    ) -> RoundCalibration {
        let ratio = (observed_s / chosen.cost.latency_s.max(1e-12)).clamp(0.05, 20.0);
        // The prediction was already scaled by the current correction, so
        // feeding the raw ratio back would converge to the *square root*
        // of the true miscalibration.  Updating toward corr × ratio makes
        // the fixed point exactly "predicted == observed".
        let corr = match chosen.kind {
            PlanKind::Distributed { .. } => &mut self.corr_dist,
            PlanKind::Streaming => &mut self.corr_stream,
            PlanKind::Hierarchical { .. } => &mut self.corr_hier,
            PlanKind::Async { .. } => &mut self.corr_async,
            _ => &mut self.corr_single,
        };
        let target = (corr.value_or(1.0) * ratio).clamp(0.05, 20.0);
        corr.observe(target);
        let upload_s = upload_s.clamp(0.0, observed_s);
        let observed_usd = match chosen.kind {
            PlanKind::Distributed { executors } => {
                self.pricing.single_node(upload_s)
                    + self.pricing.distributed(observed_s - upload_s, executors)
            }
            // Conservative: the edge/root split of the observed wall-clock
            // is unknown here, so every tier node is charged for the whole
            // round — observed $ can only overstate a hierarchical plan.
            PlanKind::Hierarchical { edges } => {
                self.pricing.hierarchical(observed_s, observed_s, edges)
            }
            _ => self.pricing.single_node(observed_s),
        };
        let cal = RoundCalibration {
            round,
            kind: chosen.kind,
            predicted_s: chosen.cost.latency_s,
            observed_s,
            predicted_usd: chosen.cost.usd,
            observed_usd,
        };
        self.ledger.push(cal);
        cal
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::FedAvg;

    const UPDATE_46MB: u64 = (4.6 * 1024.0 * 1024.0) as u64;

    fn planner(policy: DispatchPolicy) -> DispatchPlanner {
        DispatchPlanner::new(
            WorkloadClassifier::new(170 << 30, 1.1),
            VirtualCluster::paper(CostModel::nominal()),
            PricingModel::default(),
            PlannerConfig {
                policy,
                max_executors: 10,
                cores_per_executor: 3,
                node_cores: 64,
                ingest_lanes: 64,
                reactor_workers: 0,
                edges: 0,
                xla_available: false,
                feedback_beta: 0.3,
                expected_participation: 1.0,
                async_buffer: 0,
                staleness_exponent: 0.5,
                encoding: Encoding::DenseF32,
            },
        )
    }

    fn planner_with_edges(policy: DispatchPolicy, edges: usize) -> DispatchPlanner {
        DispatchPlanner::new(
            WorkloadClassifier::new(170 << 30, 1.1),
            VirtualCluster::paper(CostModel::nominal()),
            PricingModel::default(),
            PlannerConfig {
                policy,
                max_executors: 10,
                cores_per_executor: 3,
                node_cores: 64,
                ingest_lanes: 64,
                reactor_workers: 0,
                edges,
                xla_available: false,
                feedback_beta: 0.3,
                expected_participation: 1.0,
                async_buffer: 0,
                staleness_exponent: 0.5,
                encoding: Encoding::DenseF32,
            },
        )
    }

    fn planner_async(policy: DispatchPolicy, buffer: usize, p: f64) -> DispatchPlanner {
        DispatchPlanner::new(
            WorkloadClassifier::new(170 << 30, 1.1),
            VirtualCluster::paper(CostModel::nominal()),
            PricingModel::default(),
            PlannerConfig {
                policy,
                max_executors: 10,
                cores_per_executor: 3,
                node_cores: 64,
                ingest_lanes: 64,
                reactor_workers: 0,
                edges: 0,
                xla_available: false,
                feedback_beta: 0.3,
                expected_participation: p,
                async_buffer: buffer,
                staleness_exponent: 0.5,
                encoding: Encoding::DenseF32,
            },
        )
    }

    #[test]
    fn small_round_prefers_single_node() {
        let p = planner(DispatchPolicy::MinLatency);
        let plan = p.plan(UPDATE_46MB, 1000, &FedAvg, 0);
        assert_eq!(plan.class, WorkloadClass::Small);
        assert!(!plan.chosen.kind.is_distributed(), "{:?}", plan.chosen);
        // and it beats every distributed candidate on both axes
        for c in plan.candidates.iter().filter(|c| c.kind.is_distributed()) {
            assert!(plan.chosen.cost.dominates(&c.cost), "{c:?}");
        }
    }

    #[test]
    fn spilling_round_streams_instead_of_buffering() {
        let p = planner(DispatchPolicy::MinLatency);
        // 30 000 × 4.6 MB × dup 2.0 × headroom 1.1 ≈ 303 GB > 170 GB: the
        // buffered engines are out, but the O(C) fold fits easily.
        let plan = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        assert_eq!(plan.class, WorkloadClass::Streaming);
        assert!(plan.candidates.iter().all(|c| matches!(
            c.kind,
            PlanKind::Streaming | PlanKind::Distributed { .. }
        )));
        assert!(plan.candidates.iter().any(|c| c.kind == PlanKind::Streaming));
    }

    #[test]
    fn holistic_large_round_has_only_distributed_candidates() {
        use crate::fusion::CoordMedian;
        let p = planner(DispatchPolicy::MinLatency);
        // median cannot stream, so past the ceiling only MapReduce remains
        let plan = p.plan(UPDATE_46MB, 30_000, &CoordMedian, 0);
        assert_eq!(plan.class, WorkloadClass::Large);
        assert!(plan.candidates.iter().all(|c| c.kind.is_distributed()));
        assert!(plan.chosen.kind.is_distributed());
    }

    #[test]
    fn exact_s_equals_m_boundary_excludes_buffered_plans() {
        // Algorithm 1's test is strict: S < M.  At S == M exactly the
        // buffered single-node plans must NOT be enumerated; the round
        // streams (FedAvg decomposes and the O(C) fold fits).
        let p = DispatchPlanner::new(
            WorkloadClassifier::new(1000, 1.0),
            VirtualCluster::paper(CostModel::nominal()),
            PricingModel::default(),
            PlannerConfig::default(),
        );
        // 2 × 250 B × dup 2.0 (FedAvg) × headroom 1.0 = 1000 = M
        let plan = p.plan(250, 2, &FedAvg, 0);
        assert_eq!(plan.class, WorkloadClass::Streaming);
        assert!(!plan.candidates.iter().any(|c| matches!(
            c.kind,
            PlanKind::Serial | PlanKind::Parallel | PlanKind::Xla
        )));
    }

    #[test]
    fn streaming_selectable_under_all_policies_and_calibrated() {
        // The acceptance bar: the streaming plan is enumerated and chosen
        // under every policy for a past-the-ceiling decomposable round,
        // and observe() calibrates its own EWMA family.
        for policy in [
            DispatchPolicy::MinLatency,
            DispatchPolicy::MinCost,
            DispatchPolicy::Balanced(0.5),
        ] {
            let p = planner(policy);
            let plan = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
            // no store hop + ingest/compute overlap beats upload+MapReduce
            // on latency, and node-only occupancy beats it on dollars
            assert_eq!(plan.chosen.kind, PlanKind::Streaming, "{policy:?}");
        }
        let mut p = planner(DispatchPolicy::Balanced(0.5));
        let before = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        // the box folds a fixed 2x slower than the uncorrected model
        let truth = before.chosen.cost.latency_s * 2.0;
        for round in 0..10 {
            let plan = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
            p.observe(round, &plan.chosen, truth);
        }
        // the streaming family learned the 2x drift ...
        assert!((p.correction_for(PlanKind::Streaming) - 2.0).abs() < 0.25);
        let after = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        let stream = |pl: &RoundPlan| {
            pl.candidates.iter().find(|c| c.kind == PlanKind::Streaming).unwrap().cost.latency_s
        };
        assert!(stream(&after) > stream(&before) * 1.8);
        // ... without contaminating the other families
        assert_eq!(p.correction(false), 1.0);
        assert_eq!(p.correction(true), 1.0);
    }

    #[test]
    fn hierarchical_enumerated_only_with_edges_and_the_gate() {
        // no edges configured: never enumerated
        let p = planner(DispatchPolicy::MinLatency);
        let plan = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        assert!(!plan.candidates.iter().any(|c| matches!(c.kind, PlanKind::Hierarchical { .. })));
        // 4 edges + decomposable algorithm: enumerated and, at 1 GbE with
        // a big fleet, the latency winner
        let p = planner_with_edges(DispatchPolicy::MinLatency, 4);
        let plan = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        assert_eq!(plan.chosen.kind, PlanKind::Hierarchical { edges: 4 }, "{plan:?}");
        // holistic algorithms have no partial: the gate keeps them flat
        use crate::fusion::CoordMedian;
        let plan = p.plan(UPDATE_46MB, 30_000, &CoordMedian, 0);
        assert!(!plan.candidates.iter().any(|c| matches!(c.kind, PlanKind::Hierarchical { .. })));
        // below the tier-barrier crossover the flat plan stays chosen
        let plan = p.plan(UPDATE_46MB, 8, &FedAvg, 0);
        assert_ne!(
            plan.chosen.kind,
            PlanKind::Hierarchical { edges: 4 },
            "a tiny fleet must not pay the tier barrier"
        );
    }

    #[test]
    fn sketch_overhead_prices_the_robust_hierarchy_dearer() {
        use crate::fusion::TrimmedMean;
        // The trimmed mean rides the hierarchy gate via its mergeable
        // extremes sketch, but every forwarded partial hauls 2·cap extra
        // lanes: its hierarchical candidate must be enumerated AND priced
        // strictly above FedAvg's on both axes, while the flat streaming
        // candidate (no partials cross a wire) prices identically-shaped.
        let p = planner_with_edges(DispatchPolicy::MinLatency, 4);
        let tm = TrimmedMean::new(0.2, 8);
        let robust = p.plan(UPDATE_46MB, 30_000, &tm, 0);
        let plain = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        let hier = |pl: &RoundPlan| {
            pl.candidates
                .iter()
                .find(|c| matches!(c.kind, PlanKind::Hierarchical { .. }))
                .copied()
                .expect("hierarchical candidate enumerated")
        };
        let (rh, ph) = (hier(&robust), hier(&plain));
        assert!(rh.cost.latency_s > ph.cost.latency_s, "{rh:?} vs {ph:?}");
        assert!(rh.cost.usd >= ph.cost.usd, "{rh:?} vs {ph:?}");
        // the premium is bounded: only the root leg inflates, so the
        // robust plan stays within sketch_mult× of the plain one
        let mult = 1.0 + tm.partial_overhead();
        assert!(rh.cost.latency_s < ph.cost.latency_s * mult, "{rh:?} vs {ph:?}");
    }

    #[test]
    fn min_cost_keeps_the_flat_fold_over_hierarchy() {
        // hierarchy buys latency with edge-node occupancy: the MinCost
        // policy must keep the single-node streaming plan
        let p = planner_with_edges(DispatchPolicy::MinCost, 4);
        let plan = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        assert_eq!(plan.chosen.kind, PlanKind::Streaming);
        let hier = plan
            .candidates
            .iter()
            .find(|c| matches!(c.kind, PlanKind::Hierarchical { .. }))
            .expect("enumerated");
        let flat = plan.candidates.iter().find(|c| c.kind == PlanKind::Streaming).unwrap();
        assert!(hier.cost.usd > flat.cost.usd, "{hier:?} vs {flat:?}");
        assert!(hier.cost.latency_s < flat.cost.latency_s);
    }

    #[test]
    fn hierarchical_family_calibrates_independently() {
        let mut p = planner_with_edges(DispatchPolicy::MinLatency, 4);
        let before = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        assert!(matches!(before.chosen.kind, PlanKind::Hierarchical { .. }));
        let truth = before.chosen.cost.latency_s * 1.7;
        for round in 0..10 {
            let plan = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
            p.observe(round, &plan.chosen, truth);
        }
        assert!(
            (p.correction_for(PlanKind::Hierarchical { edges: 4 }) - 1.7).abs() < 0.25,
            "{}",
            p.correction_for(PlanKind::Hierarchical { edges: 4 })
        );
        // the drift was absorbed: late predictions sit within the EWMA band
        let cal = p.ledger().last().unwrap();
        assert!((cal.drift() - 1.0).abs() < 0.15, "drift {}", cal.drift());
        // ... without contaminating the flat families
        assert_eq!(p.correction_for(PlanKind::Streaming), 1.0);
        assert_eq!(p.correction(false), 1.0);
        assert_eq!(p.correction(true), 1.0);
        assert!(cal.log_line().contains("hierarchical(e=4)"));
    }

    #[test]
    fn async_enumerated_only_when_buffered_and_decomposable() {
        // buffer 0 = async mode off: never enumerated
        let p = planner(DispatchPolicy::MinLatency);
        let plan = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        assert!(!plan.candidates.iter().any(|c| matches!(c.kind, PlanKind::Async { .. })));
        // holistic algorithms have no streaming fold: the gate keeps async out
        use crate::fusion::CoordMedian;
        let p = planner_async(DispatchPolicy::MinLatency, 64, 1.0);
        let plan = p.plan(UPDATE_46MB, 30_000, &CoordMedian, 0);
        assert!(!plan.candidates.iter().any(|c| matches!(c.kind, PlanKind::Async { .. })));
        // the buffer is clamped to the arrivals a tiny fleet delivers
        let plan = p.plan(UPDATE_46MB, 8, &FedAvg, 0);
        assert!(plan.candidates.iter().any(|c| c.kind == PlanKind::Async { buffer: 8 }));
    }

    #[test]
    fn min_latency_takes_async_under_straggler_turnout() {
        // Heavy-tail fleet: 40% turnout means the sync quorum span waits
        // on stragglers, while the K=64 buffer publishes after the first
        // 64 arrivals — the async latency win MinLatency must take.
        let p = planner_async(DispatchPolicy::MinLatency, 64, 0.4);
        let plan = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        assert_eq!(plan.chosen.kind, PlanKind::Async { buffer: 64 }, "{plan:?}");
        let asy = plan.candidates.iter().find(|c| matches!(c.kind, PlanKind::Async { .. })).unwrap();
        let stream = plan.candidates.iter().find(|c| c.kind == PlanKind::Streaming).unwrap();
        assert!(
            asy.cost.latency_s < stream.cost.latency_s / 10.0,
            "{} vs {}",
            asy.cost.latency_s,
            stream.cost.latency_s
        );
    }

    #[test]
    fn min_cost_keeps_the_sync_quorum_at_high_turnout() {
        // Full turnout: fresh buffers, but async still re-pays the drain
        // per publish — MinCost must keep the flat streaming quorum.
        let p = planner_async(DispatchPolicy::MinCost, 64, 1.0);
        let plan = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        assert_eq!(plan.chosen.kind, PlanKind::Streaming, "{plan:?}");
        let usd_ratio = |pl: &RoundPlan| {
            let asy =
                pl.candidates.iter().find(|c| matches!(c.kind, PlanKind::Async { .. })).unwrap();
            let st = pl.candidates.iter().find(|c| c.kind == PlanKind::Streaming).unwrap();
            assert!(asy.cost.usd > st.cost.usd, "{asy:?} vs {st:?}");
            asy.cost.usd / st.cost.usd
        };
        let high = usd_ratio(&plan);
        // lower turnout = staler buffers = a smaller average discount, so
        // async's relative $ premium over sync must widen
        let low = usd_ratio(&planner_async(DispatchPolicy::MinCost, 64, 0.4).plan(
            UPDATE_46MB,
            30_000,
            &FedAvg,
            0,
        ));
        assert!(low > high, "{low} !> {high}");
    }

    #[test]
    fn async_family_calibrates_independently() {
        let mut p = planner_async(DispatchPolicy::MinLatency, 64, 0.5);
        let before = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        assert!(matches!(before.chosen.kind, PlanKind::Async { .. }));
        let truth = before.chosen.cost.latency_s * 1.7;
        for round in 0..10 {
            let plan = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
            p.observe(round, &plan.chosen, truth);
        }
        assert!(
            (p.correction_for(PlanKind::Async { buffer: 64 }) - 1.7).abs() < 0.25,
            "{}",
            p.correction_for(PlanKind::Async { buffer: 64 })
        );
        // ... without contaminating the sync families
        assert_eq!(p.correction_for(PlanKind::Streaming), 1.0);
        assert_eq!(p.correction(false), 1.0);
        assert_eq!(p.correction(true), 1.0);
        let cal = p.ledger().last().unwrap();
        assert!(cal.log_line().contains("async(K=64)"), "{}", cal.log_line());
    }

    #[test]
    fn raising_alpha_never_picks_a_slower_plan() {
        // Policy monotonicity over REAL candidate sets (not synthetic):
        // a large round (distributed-only, k sweeps the latency/cost
        // frontier) and a small round (mixed single-node + distributed).
        for (bytes, parties) in [(UPDATE_46MB, 30_000usize), (UPDATE_46MB, 1_000)] {
            let mut last = f64::INFINITY;
            for alpha in [0.0, 0.2, 0.4, 0.5, 0.6, 0.8, 1.0] {
                let p = planner(DispatchPolicy::Balanced(alpha));
                let plan = p.plan(bytes, parties, &FedAvg, 0);
                assert!(
                    plan.chosen.cost.latency_s <= last + 1e-9,
                    "alpha {alpha} on ({bytes}, {parties}): {} > {last}",
                    plan.chosen.cost.latency_s
                );
                last = plan.chosen.cost.latency_s;
            }
        }
    }

    #[test]
    fn min_cost_is_cheapest_min_latency_is_fastest() {
        let fast = planner(DispatchPolicy::MinLatency).plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        let cheap = planner(DispatchPolicy::MinCost).plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        assert!(fast.chosen.cost.latency_s <= cheap.chosen.cost.latency_s);
        assert!(cheap.chosen.cost.usd <= fast.chosen.cost.usd);
    }

    #[test]
    fn warm_pool_amortizes_startup() {
        let p = planner(DispatchPolicy::MinLatency);
        let cold = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        let warm = p.plan(UPDATE_46MB, 30_000, &FedAvg, 10);
        let k = PlanKind::Distributed { executors: 10 };
        let cold_k = cold.candidates.iter().find(|c| c.kind == k).unwrap();
        let warm_k = warm.candidates.iter().find(|c| c.kind == k).unwrap();
        assert!(warm_k.cost.latency_s < cold_k.cost.latency_s);
        // the gap is exactly the spin-up of 10 containers
        let gap = cold_k.cost.latency_s - warm_k.cost.latency_s;
        let spin = CostModel::nominal().executor_startup_s * 10.0;
        assert!((gap - spin).abs() < 1e-6, "{gap} vs {spin}");
    }

    #[test]
    fn feedback_converges_predictions_to_observations() {
        let mut p = planner(DispatchPolicy::MinLatency);
        let before = p.plan(UPDATE_46MB, 1000, &FedAvg, 0);
        // the box is a fixed 3× slower than the uncorrected model
        let truth = before.chosen.cost.latency_s * 3.0;
        let mut last_drift = f64::INFINITY;
        for round in 0..12 {
            let plan = p.plan(UPDATE_46MB, 1000, &FedAvg, 0);
            let cal = p.observe(round, &plan.chosen, truth);
            last_drift = cal.drift();
        }
        // the correction must reach the TRUE miscalibration (3×), not its
        // square root — i.e. late-round predictions match observations
        assert!((p.correction(false) - 3.0).abs() < 0.2, "{}", p.correction(false));
        assert!((last_drift - 1.0).abs() < 0.1, "drift {last_drift}");
        let after = p.plan(UPDATE_46MB, 1000, &FedAvg, 0);
        assert!(after.chosen.cost.latency_s > before.chosen.cost.latency_s);
        // the distributed family is calibrated independently
        assert_eq!(p.correction(true), 1.0);
    }

    #[test]
    fn ledger_records_predicted_vs_observed() {
        let mut p = planner(DispatchPolicy::Balanced(0.5));
        let plan = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        let cal = p.observe(7, &plan.chosen, plan.chosen.cost.latency_s * 1.25);
        assert_eq!(p.ledger().len(), 1);
        assert_eq!(cal.round, 7);
        assert!((cal.drift() - 1.25).abs() < 1e-9);
        assert!(cal.observed_usd > 0.0 && cal.predicted_usd > 0.0);
        assert!(cal.log_line().contains("predicted"));
    }

    #[test]
    fn participation_prior_prices_k_p_uploads_without_changing_class() {
        // A 0.6 prior must shrink every candidate's priced latency (the
        // fleet only delivers K·p uploads) while the feasibility class
        // keeps assuming the full K — a surprise full turnout can't OOM.
        let mut cfg = PlannerConfig {
            policy: DispatchPolicy::MinLatency,
            max_executors: 10,
            cores_per_executor: 3,
            node_cores: 64,
            ingest_lanes: 64,
            reactor_workers: 0,
            edges: 0,
            xla_available: false,
            feedback_beta: 0.3,
            expected_participation: 1.0,
            async_buffer: 0,
            staleness_exponent: 0.5,
            encoding: Encoding::DenseF32,
        };
        let full = DispatchPlanner::new(
            WorkloadClassifier::new(170 << 30, 1.1),
            VirtualCluster::paper(CostModel::nominal()),
            PricingModel::default(),
            cfg.clone(),
        )
        .plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        cfg.expected_participation = 0.6;
        let partial = DispatchPlanner::new(
            WorkloadClassifier::new(170 << 30, 1.1),
            VirtualCluster::paper(CostModel::nominal()),
            PricingModel::default(),
            cfg,
        )
        .plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        assert_eq!(full.class, partial.class, "feasibility must stay full-K");
        let stream = |pl: &RoundPlan| {
            pl.candidates
                .iter()
                .find(|c| c.kind == PlanKind::Streaming)
                .unwrap()
                .cost
                .latency_s
        };
        // streaming is ingest-bound at this geometry: span is linear in
        // the arriving upload count, so 0.6 turnout prices ≈ 0.6× the span
        let ratio = stream(&partial) / stream(&full);
        assert!((0.55..0.70).contains(&ratio), "{ratio}");
        // distributed candidates shrink too (fewer uploads to write+read)
        let dist = |pl: &RoundPlan, k: usize| {
            pl.candidates
                .iter()
                .find(|c| c.kind == PlanKind::Distributed { executors: k })
                .unwrap()
                .cost
                .latency_s
        };
        assert!(dist(&partial, 10) < dist(&full, 10));
    }

    #[test]
    fn observed_turnout_calibrates_participation() {
        let mut p = planner(DispatchPolicy::MinLatency);
        assert_eq!(p.participation(), 1.0);
        let before = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        // eight straight rounds at 80% turnout: the EWMA of a constant is
        // that constant from the first observation
        for _ in 0..8 {
            p.observe_participation(24_000, 30_000);
        }
        assert!((p.participation() - 0.8).abs() < 1e-9);
        let after = p.plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        assert!(after.chosen.cost.latency_s < before.chosen.cost.latency_s);
        // a zero-expected round must not poison the factor
        p.observe_participation(0, 0);
        assert!((p.participation() - 0.8).abs() < 1e-9);
        // and the factor is floored so pricing never collapses to zero
        for _ in 0..64 {
            p.observe_participation(0, 30_000);
        }
        assert!(p.participation() >= 0.05);
    }

    #[test]
    fn heartbeat_liveness_feeds_the_same_turnout_ewma() {
        // The registry's live fraction and sealed-round turnout share one
        // EWMA: a fleet going half-silent moves the priced participation
        // before any deadline is burned on the dead half.
        let mut p = planner(DispatchPolicy::MinLatency);
        assert_eq!(p.participation(), 1.0);
        for _ in 0..8 {
            p.observe_liveness(15_000, 30_000);
        }
        assert!((p.participation() - 0.5).abs() < 1e-9);
        // both feeds blend: a full-turnout sealed round pulls it back up
        let after = p.observe_participation(30_000, 30_000);
        assert!(after > 0.5 && after < 1.0);
        // degenerate registries must not poison the factor
        p.observe_liveness(0, 0);
        assert!((p.participation() - after).abs() < 1e-9);
    }

    fn planner_enc(policy: DispatchPolicy, edges: usize, enc: Encoding) -> DispatchPlanner {
        DispatchPlanner::new(
            WorkloadClassifier::new(170 << 30, 1.1),
            VirtualCluster::paper(CostModel::nominal()),
            PricingModel::default(),
            PlannerConfig {
                policy,
                max_executors: 10,
                node_cores: 64,
                ingest_lanes: 64,
                reactor_workers: 0,
                edges,
                encoding: enc,
                ..PlannerConfig::default()
            },
        )
    }

    #[test]
    fn compressed_encoding_shrinks_the_streaming_candidate() {
        // Past-the-ceiling round is ingest-bound: quartering the wire
        // bytes must quarter-ish the priced streaming latency, and the
        // DenseF32 encoding must price bit-identically to the legacy
        // dense-only planner (no existing pin moves).
        let dense = planner(DispatchPolicy::MinLatency).plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        let dense_enc = planner_enc(DispatchPolicy::MinLatency, 0, Encoding::DenseF32)
            .plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        let quant = planner_enc(DispatchPolicy::MinLatency, 0, Encoding::QuantI8)
            .plan(UPDATE_46MB, 30_000, &FedAvg, 0);
        let stream = |pl: &RoundPlan| {
            pl.candidates.iter().find(|c| c.kind == PlanKind::Streaming).unwrap().cost
        };
        assert_eq!(stream(&dense_enc), stream(&dense));
        let ratio = stream(&quant).latency_s / stream(&dense).latency_s;
        assert!((0.2..0.5).contains(&ratio), "quantized/dense latency ratio {ratio}");
        // distributed candidates are untouched: the store path moves dense
        // f32 whatever the fleet's uplink encoding
        for (q, d) in quant
            .candidates
            .iter()
            .filter(|c| c.kind.is_distributed())
            .zip(dense.candidates.iter().filter(|c| c.kind.is_distributed()))
        {
            assert_eq!(q, d);
        }
    }

    #[test]
    fn compression_moves_the_planner_crossover_to_larger_fleets() {
        // The smallest fleet whose hierarchical candidate beats the flat
        // streaming candidate, as the PLANNER prices them.  Compression
        // shrinks every client→aggregator leg but never the relay→root
        // partials, so the flat plan gains more and the crossover recedes.
        let xover = |enc: Encoding| {
            let p = planner_enc(DispatchPolicy::MinLatency, 4, enc);
            for n in 2..10_000usize {
                let plan = p.plan(UPDATE_46MB, n, &FedAvg, 0);
                let hier = plan
                    .candidates
                    .iter()
                    .find(|c| matches!(c.kind, PlanKind::Hierarchical { .. }));
                let flat = plan.candidates.iter().find(|c| c.kind == PlanKind::Streaming);
                if let (Some(h), Some(f)) = (hier, flat) {
                    if h.cost.latency_s < f.cost.latency_s {
                        return n;
                    }
                }
            }
            panic!("no crossover below 10k parties for {enc:?}");
        };
        let dense_x = xover(Encoding::DenseF32);
        let f16_x = xover(Encoding::DenseF16);
        let topk_x = xover(Encoding::TopK { permille: 100 });
        assert!(dense_x > 2, "{dense_x}");
        assert!(f16_x > dense_x, "f16 {f16_x} !> dense {dense_x}");
        assert!(topk_x > f16_x, "topk {topk_x} !> f16 {f16_x}");
    }

    #[test]
    fn metered_uplink_makes_compression_a_dollar_win() {
        // With a per-byte WAN rate the encoded wire volume lands on the $
        // axis: the quantized fleet's streaming plan must be cheaper than
        // the dense fleet's by roughly the byte ratio's share of the WAN
        // bill, while the store-backed distributed candidates (dense f32
        // either way) price identically.
        let metered = PricingModel { wan_usd_per_byte: 1e-9, ..PricingModel::default() };
        let mk = |enc: Encoding| {
            DispatchPlanner::new(
                WorkloadClassifier::new(170 << 30, 1.1),
                VirtualCluster::paper(CostModel::nominal()),
                metered.clone(),
                PlannerConfig {
                    policy: DispatchPolicy::MinCost,
                    max_executors: 10,
                    node_cores: 64,
                    ingest_lanes: 64,
                    encoding: enc,
                    ..PlannerConfig::default()
                },
            )
            .plan(UPDATE_46MB, 30_000, &FedAvg, 0)
        };
        let dense = mk(Encoding::DenseF32);
        let quant = mk(Encoding::QuantI8);
        let stream = |pl: &RoundPlan| {
            pl.candidates.iter().find(|c| c.kind == PlanKind::Streaming).unwrap().cost
        };
        assert!(
            stream(&quant).usd < stream(&dense).usd * 0.5,
            "{} !< half of {}",
            stream(&quant).usd,
            stream(&dense).usd
        );
        for (q, d) in quant
            .candidates
            .iter()
            .filter(|c| c.kind.is_distributed())
            .zip(dense.candidates.iter().filter(|c| c.kind.is_distributed()))
        {
            assert_eq!(q.cost.usd, d.cost.usd, "store path never discounts");
        }
    }

    #[test]
    fn zero_parties_plans_trivially_small() {
        let p = planner(DispatchPolicy::MinLatency);
        let plan = p.plan(UPDATE_46MB, 0, &FedAvg, 0);
        assert_eq!(plan.class, WorkloadClass::Small);
        assert!(!plan.chosen.kind.is_distributed());
        assert!(plan.chosen.cost.latency_s < 1e-6);
    }

    #[test]
    fn reactor_worker_cap_throttles_streaming_lanes() {
        // Ingest width beyond the fold worker pool reads bytes it cannot
        // fold, so pricing caps every lanes term at the pool size: the
        // same 64-lane config priced with a one-worker reactor must be
        // strictly slower than with an unbounded pool.
        let stream_latency = |workers: usize| {
            DispatchPlanner::new(
                WorkloadClassifier::new(170 << 30, 1.1),
                VirtualCluster::paper(CostModel::nominal()),
                PricingModel::default(),
                PlannerConfig {
                    policy: DispatchPolicy::MinLatency,
                    node_cores: 64,
                    ingest_lanes: 64,
                    reactor_workers: workers,
                    ..PlannerConfig::default()
                },
            )
            .plan(UPDATE_46MB, 30_000, &FedAvg, 0)
            .candidates
            .iter()
            .find(|c| c.kind == PlanKind::Streaming)
            .expect("streaming candidate enumerated")
            .cost
            .latency_s
        };
        let unbounded = stream_latency(0);
        let starved = stream_latency(1);
        assert!(starved > unbounded, "{starved} !> {unbounded}");
        // a pool at least as wide as the lanes changes nothing
        assert!((stream_latency(64) - unbounded).abs() < 1e-12);
    }
}
