//! Dispatch policies: how a round's candidate plans are ranked.
//!
//! All three policies are linear scalarizations of the normalized
//! (latency, cost) axes with a weight `α` ∈ [0, 1]: `MinCost` is α = 0,
//! `MinLatency` is α = 1, and `Balanced(α)` exposes the knob directly.
//! Linear scalarization gives the monotonicity the tests pin down —
//! raising α can never select a *slower* plan from the same candidate set
//! (sum the two optimality inequalities and the cross terms cancel).

use super::CandidatePlan;

/// User-facing cost/latency trade-off knob (the paper's §III "users can
/// manage the trade-off between cost and efficiency").
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DispatchPolicy {
    /// Fastest plan regardless of resource cost.
    MinLatency,
    /// Cheapest plan regardless of wall-clock.
    MinCost,
    /// Pareto knob: α = 0 behaves like `MinCost`, α = 1 like `MinLatency`.
    Balanced(f64),
}

impl DispatchPolicy {
    /// The latency weight this policy scores with.
    pub fn alpha(&self) -> f64 {
        match self {
            DispatchPolicy::MinLatency => 1.0,
            DispatchPolicy::MinCost => 0.0,
            DispatchPolicy::Balanced(a) => a.clamp(0.0, 1.0),
        }
    }

    /// Parse the config/CLI spelling: `min_latency`, `min_cost`,
    /// `balanced` (α = 0.5) or `balanced:<alpha>`.
    pub fn parse(s: &str) -> Option<DispatchPolicy> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "min_latency" | "minlatency" | "latency" => Some(DispatchPolicy::MinLatency),
            "min_cost" | "mincost" | "cost" => Some(DispatchPolicy::MinCost),
            "balanced" => Some(DispatchPolicy::Balanced(0.5)),
            _ => {
                let alpha = s
                    .strip_prefix("balanced:")?
                    .parse::<f64>()
                    .ok()
                    .filter(|a| a.is_finite())?;
                Some(DispatchPolicy::Balanced(alpha.clamp(0.0, 1.0)))
            }
        }
    }

    /// Pick the best candidate under this policy.  Both axes are
    /// normalized by the candidate-set minima so the score is scale-free;
    /// ties break toward lower latency, then lower cost, so selection is
    /// deterministic.  Returns `None` only for an empty candidate set.
    pub fn select<'a>(&self, candidates: &'a [CandidatePlan]) -> Option<&'a CandidatePlan> {
        if candidates.is_empty() {
            return None;
        }
        let a = self.alpha();
        let lmin = candidates
            .iter()
            .map(|c| c.cost.latency_s)
            .fold(f64::INFINITY, f64::min)
            .max(1e-12);
        let cmin = candidates
            .iter()
            .map(|c| c.cost.usd)
            .fold(f64::INFINITY, f64::min)
            .max(1e-12);
        let score =
            |p: &CandidatePlan| a * p.cost.latency_s / lmin + (1.0 - a) * p.cost.usd / cmin;
        candidates.iter().min_by(|x, y| {
            (score(x), x.cost.latency_s, x.cost.usd)
                .partial_cmp(&(score(y), y.cost.latency_s, y.cost.usd))
                .unwrap_or(std::cmp::Ordering::Equal)
        })
    }
}

impl std::fmt::Display for DispatchPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DispatchPolicy::MinLatency => write!(f, "min_latency"),
            DispatchPolicy::MinCost => write!(f, "min_cost"),
            DispatchPolicy::Balanced(a) => write!(f, "balanced:{a}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{PlanCost, PlanKind};
    use super::*;

    fn cand(kind: PlanKind, lat: f64, usd: f64) -> CandidatePlan {
        CandidatePlan { kind, cost: PlanCost::new(lat, usd) }
    }

    fn set() -> Vec<CandidatePlan> {
        vec![
            cand(PlanKind::Serial, 10.0, 0.010),
            cand(PlanKind::Parallel, 6.0, 0.006),
            cand(PlanKind::Distributed { executors: 2 }, 4.0, 0.012),
            cand(PlanKind::Distributed { executors: 8 }, 2.0, 0.030),
        ]
    }

    #[test]
    fn extremes_pick_extremes() {
        let c = set();
        let fast = DispatchPolicy::MinLatency.select(&c).unwrap();
        assert_eq!(fast.kind, PlanKind::Distributed { executors: 8 });
        let cheap = DispatchPolicy::MinCost.select(&c).unwrap();
        assert_eq!(cheap.kind, PlanKind::Parallel);
    }

    #[test]
    fn raising_alpha_never_picks_a_slower_plan() {
        let c = set();
        let mut last = f64::INFINITY;
        for alpha in [0.0, 0.1, 0.25, 0.4, 0.5, 0.6, 0.75, 0.9, 1.0] {
            let chosen = DispatchPolicy::Balanced(alpha).select(&c).unwrap();
            assert!(
                chosen.cost.latency_s <= last,
                "alpha {alpha}: latency {} > previous {last}",
                chosen.cost.latency_s
            );
            last = chosen.cost.latency_s;
        }
    }

    #[test]
    fn parse_round_trips() {
        for p in [
            DispatchPolicy::MinLatency,
            DispatchPolicy::MinCost,
            DispatchPolicy::Balanced(0.25),
        ] {
            assert_eq!(DispatchPolicy::parse(&p.to_string()), Some(p));
        }
        assert_eq!(DispatchPolicy::parse("balanced"), Some(DispatchPolicy::Balanced(0.5)));
        assert_eq!(DispatchPolicy::parse("nonsense"), None);
        // out-of-range alphas clamp; non-finite alphas are rejected
        assert_eq!(DispatchPolicy::parse("balanced:7"), Some(DispatchPolicy::Balanced(1.0)));
        assert_eq!(DispatchPolicy::parse("balanced:nan"), None);
        assert_eq!(DispatchPolicy::parse("balanced:inf"), None);
    }

    #[test]
    fn empty_set_selects_none() {
        assert!(DispatchPolicy::MinCost.select(&[]).is_none());
    }
}
