//! Plan pricing: the latency/dollar pair every candidate plan is scored on.
//!
//! The paper's headline is a *cost/efficiency trade-off* (2×+ cost
//! reduction at 8× time efficiency), so a plan's quality is a point in a
//! two-axis space, not a scalar.  [`PlanCost`] is that point;
//! [`PricingModel`] converts predicted occupancy (node-seconds and
//! executor-seconds) into dollars with cloud-style per-second rates.

/// Predicted — or, after a round runs, observed — latency and dollar cost
/// of one candidate plan.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlanCost {
    /// End-to-end round latency in seconds (virtual time at plan time).
    pub latency_s: f64,
    /// Modeled dollar cost of the resources the plan occupies.
    pub usd: f64,
}

impl PlanCost {
    pub fn new(latency_s: f64, usd: f64) -> PlanCost {
        PlanCost { latency_s, usd }
    }

    /// Strict Pareto dominance: better on BOTH axes.
    pub fn dominates(&self, other: &PlanCost) -> bool {
        self.latency_s < other.latency_s && self.usd < other.usd
    }
}

/// Per-second resource rates used to price plans.
///
/// The defaults are representative on-demand cloud rates for the paper's
/// testbed classes: the aggregator is a 64-core / 170 GB box (~$3.06/h)
/// and each distributed executor is a 3-core / 30 GB Yarn container
/// (~$0.20/h).  Override via `ServiceConfig::{node_usd_per_s,
/// executor_usd_per_s}` to price a different fleet.
#[derive(Clone, Debug, PartialEq)]
pub struct PricingModel {
    /// $/s of the always-on aggregator node (driver + single-node engines).
    pub node_usd_per_s: f64,
    /// $/s of one distributed executor container.
    pub executor_usd_per_s: f64,
    /// $/byte of client→aggregator wire traffic.  0 by default (intra-DC
    /// ingress is free on every major cloud), but edge fleets on metered
    /// uplinks (cellular, satellite backhaul) pay per byte — set this and
    /// the planner's per-encoding wire-byte counts turn compression into a
    /// *dollar* win, not just a latency one.
    pub wan_usd_per_byte: f64,
}

impl Default for PricingModel {
    fn default() -> Self {
        PricingModel { node_usd_per_s: 8.5e-4, executor_usd_per_s: 5.6e-5, wan_usd_per_byte: 0.0 }
    }
}

impl PricingModel {
    /// Dollar cost of occupying only the aggregator node for `latency_s`.
    pub fn single_node(&self, latency_s: f64) -> f64 {
        latency_s * self.node_usd_per_s
    }

    /// Dollar cost of the distributed path: the driver node plus
    /// `executors` containers, all held for the round's duration.
    pub fn distributed(&self, latency_s: f64, executors: usize) -> f64 {
        latency_s * (self.node_usd_per_s + executors as f64 * self.executor_usd_per_s)
    }

    /// Dollar cost of the streaming-fold plan: ingest overlaps the O(C)
    /// fold on the aggregator node alone — no store hop, no executor
    /// containers — so the plan occupies exactly node-seconds.  This is
    /// what makes streaming strictly cheaper than MapReduce for every
    /// round both can run.
    pub fn streaming(&self, latency_s: f64) -> f64 {
        self.single_node(latency_s)
    }

    /// Dollar cost of the 2-tier hierarchical plan: the root node is held
    /// for the whole round, and each of the `edges` edge aggregators is
    /// held for the edge phase (`edge_s`).  Edge nodes are priced at the
    /// node rate — so hierarchy buys its latency win with MORE occupied
    /// node-seconds than the flat streaming plan, which is exactly the
    /// trade-off the `Balanced(α)` policy arbitrates.
    pub fn hierarchical(&self, total_s: f64, edge_s: f64, edges: usize) -> f64 {
        self.single_node(total_s) + edges as f64 * self.node_usd_per_s * edge_s
    }

    /// Dollar cost of the FedBuff-style async plan: the aggregator node
    /// alone is occupied for `occupancy_s` node-seconds — but staleness
    /// discounting means each folded update contributes less than unit
    /// weight, so producing one sync-round's worth of *effective*
    /// aggregated weight takes `1/avg_discount` times the occupancy.
    /// `avg_discount = 1` (fresh fleet, zero exponent) degenerates to
    /// exactly the streaming price.
    pub fn async_mode(&self, occupancy_s: f64, avg_discount: f64) -> f64 {
        self.single_node(occupancy_s / avg_discount.clamp(1e-3, 1.0))
    }

    /// Dollar cost of moving `bytes` over the client uplink.  Zero at the
    /// default rate; the planner adds this term to every candidate from
    /// the *encoded* wire-byte count, so on a metered fleet a quantized
    /// or sparse encoding shows up directly in the $ axis.
    pub fn wan(&self, bytes: f64) -> f64 {
        bytes * self.wan_usd_per_byte
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_is_strict_on_both_axes() {
        let a = PlanCost::new(1.0, 1.0);
        assert!(PlanCost::new(0.5, 0.5).dominates(&a));
        assert!(!PlanCost::new(0.5, 1.0).dominates(&a)); // equal cost
        assert!(!PlanCost::new(0.5, 2.0).dominates(&a)); // worse cost
        assert!(!a.dominates(&a));
    }

    #[test]
    fn distributed_costs_more_per_second_than_single_node() {
        let p = PricingModel::default();
        assert!(p.distributed(10.0, 1) > p.single_node(10.0));
        assert!(p.distributed(10.0, 8) > p.distributed(10.0, 2));
    }

    #[test]
    fn streaming_occupies_node_only() {
        let p = PricingModel::default();
        assert_eq!(p.streaming(10.0), p.single_node(10.0));
        assert!(p.streaming(10.0) < p.distributed(10.0, 1));
    }

    #[test]
    fn hierarchical_costs_more_dollars_than_flat_streaming() {
        let p = PricingModel::default();
        // even when hierarchy halves the latency, the edge fleet's
        // occupancy makes it the pricier plan — the latency/$ trade-off
        assert!(p.hierarchical(5.0, 2.0, 4) > p.streaming(10.0) * 0.5);
        assert!(p.hierarchical(10.0, 3.0, 4) > p.streaming(10.0));
        // zero edges degenerates to the flat node occupancy
        assert_eq!(p.hierarchical(10.0, 3.0, 0), p.streaming(10.0));
    }

    #[test]
    fn async_price_inflates_with_staleness_discount() {
        let p = PricingModel::default();
        // a fresh fleet (discount 1) pays exactly the streaming rate
        assert_eq!(p.async_mode(10.0, 1.0), p.streaming(10.0));
        // discounted updates buy less effective weight per node-second
        assert!(p.async_mode(10.0, 0.5) > p.streaming(10.0));
        assert!(p.async_mode(10.0, 0.25) > p.async_mode(10.0, 0.5));
        // pathological discounts are clamped, never a division blow-up
        assert!(p.async_mode(10.0, 0.0).is_finite());
        assert_eq!(p.async_mode(10.0, 7.0), p.streaming(10.0));
    }

    #[test]
    fn wan_rate_is_free_by_default_and_linear_when_set() {
        let p = PricingModel::default();
        assert_eq!(p.wan(1e12), 0.0, "default fleets pay nothing per byte");
        let metered = PricingModel { wan_usd_per_byte: 2e-9, ..PricingModel::default() };
        assert!((metered.wan(1e9) - 2.0).abs() < 1e-9);
        assert_eq!(metered.wan(0.0), 0.0);
    }

    #[test]
    fn default_rates_are_plausible() {
        let p = PricingModel::default();
        // node ~$3/h, executor ~$0.2/h
        assert!((2.0..5.0).contains(&(p.node_usd_per_s * 3600.0)));
        assert!((0.1..0.5).contains(&(p.executor_usd_per_s * 3600.0)));
    }
}
